"""Nexmark on the stream engine: Q2 with a straggler (backlog-based shuffle
vs rebalance) and Q12 record-level correctness via the jax operator kernels.

    PYTHONPATH=src python examples/stream_nexmark.py
"""
import numpy as np

from repro.streams import nexmark
from repro.streams.engine import StreamEngine

print("== Q2 under a 10x straggler ==")
for part in ("rebalance", "backlog"):
    g = nexmark.q2(parallelism=8, partitioner=part)
    eng = StreamEngine(g, n_hosts=8, task_speed_override={9: 0.1})
    m = eng.run(60)
    print(f"  {part:10s} filter qps = {np.mean(m.qps['filter'][60:]):12.0f}")

print("== Q12 record-level kernels ==")
bids = nexmark.gen_bids(100_000, seed=0)
mask = nexmark.q2_filter(bids)
counts = nexmark.q12_window_counts(bids, window_s=10.0)
print(f"  Q2 selectivity = {float(mask.mean()):.4f}")
print(f"  Q12 windows x bidders = {counts.shape}, total = {int(counts.sum())}")
