"""Sparse-phase sharded sweep smoke: run a (configs × seeds) resiliency
grid over a deep-pipeline packed arena through the COMPACT tick lowering
with the seed axis sharded across host devices — the ISSUE 5 pipeline
end to end (compact phases + config-grid sharding + device-free ckpt
timeline refits).

    PYTHONPATH=src python examples/sparse_sweep.py                 # 2x8 grid
    PYTHONPATH=src python examples/sparse_sweep.py --jobs 36 --seeds 16 \\
        --configs 4 --duration 120 --devices 2

The script FAILS (non-zero exit) if the lowering silently falls back to
the dense path — scripts/ci.sh --sparse-smoke additionally exports
``REPRO_REQUIRE_PHASE_MODE=compact`` so the same guard trips inside the
engine itself.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=18,
                    help="co-located SS jobs packed into the arena")
    ap.add_argument("--configs", type=int, default=2,
                    help="restart-budget grid points")
    ap.add_argument("--seeds", type=int, default=8,
                    help="chaos seeds per config row")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="simulated horizon per scenario (seconds)")
    ap.add_argument("--devices", type=int, default=2,
                    help="device shards for the seed axis (>1 forces "
                         "host devices)")
    ap.add_argument("--ckpt", action="store_true",
                    help="sweep checkpoint intervals too (exercises the "
                         "batched timeline refit)")
    args = ap.parse_args()

    if args.devices > 1:   # before jax initializes
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    import numpy as np

    from repro.core.chaos import ChaosSpec, timeline_build_count
    from repro.streams import nexmark
    from repro.streams.chaos_sweep import sweep_configs
    from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                      select_phase_mode)
    from repro.streams.jax_engine import _Lowered

    arena = nexmark.ss_arena(n_tasks=args.jobs * 56, parallelism=8,
                             n_hosts=32)
    mode = select_phase_mode(arena.plan)
    if mode != "compact":
        raise SystemExit(
            f"sparse smoke FAILED: auto lowering picked {mode!r} for the "
            f"{arena.plan.n_tasks}-task deep arena (dense fallback)")
    base = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2,
                     storage_slow_prob=0.1 if args.ckpt else 0.0)
    restarts = np.linspace(10.0, 45.0, args.configs)
    if args.ckpt:
        grid = [{"failover": FailoverConfig(mode="region",
                                            region_restart_s=float(r)),
                 "ckpt": CheckpointConfig(interval_s=float(20 + 10 * i)),
                 "label": f"restart={r:.0f}s ckpt={20 + 10 * i:g}s"}
                for i, r in enumerate(restarts)]
    else:
        grid = [FailoverConfig(mode="region", region_restart_s=float(r))
                for r in restarts]
    builds0 = timeline_build_count()
    res = sweep_configs(arena, grid, range(args.seeds), base_spec=base,
                        duration_s=args.duration,
                        devices=(args.devices if args.devices > 1
                                 else None))
    builds = timeline_build_count() - builds0
    n = res.recovery_surface.size
    print(f"== {arena.n_jobs} SS jobs / {arena.plan.n_tasks} tasks "
          f"({len(arena.plan.ops)} ops, compact "
          f"phases): {len(grid)} configs x {args.seeds} seeds = {n} "
          f"scenarios in {res.wall_s:.2f}s "
          f"({res.scenarios_per_s:.1f} scenarios/s, "
          f"{args.devices} device shard(s)) ==")
    per_cs = "zero" if builds == 0 else str(builds)
    print(f"   host timeline replays during the grid: {per_cs} "
          f"(per-seed stream refits only)")
    for lbl, row in zip(res.labels, res.rows()):
        print(f"   {lbl:>24s}  rec_p50={row['recovery_p50_s']:6.1f}s  "
              f"slo_p95={row['slo_violation_frac_p95']:.3f}")
    if args.ckpt and builds != 0:
        raise SystemExit("sparse smoke FAILED: ckpt grid fell back to "
                         "per-(config, seed) host timeline rebuilds")
    # compact tick must actually be what ran (trace cache holds its desc)
    low = _Lowered(arena, n_hosts=32, dt=0.5, queue_cap=256.0,
                   failover=None, ckpt=None, seed=0)
    assert low.tensor.mode == "compact"


if __name__ == "__main__":
    main()
