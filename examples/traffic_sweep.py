"""Traffic-dynamics capacity gate: the scaler-config × traffic-pattern ×
failover-mode cube from ONE `sweep_configs` device call
(`streams.chaos_sweep.traffic_sweep`), over production load dynamics —
a diurnal curve, a 3x flash crowd, and a fast swing that drives an
eager autoscaler into oscillation.

Each cube cell runs the in-trace DS2 controller against a traced rate
schedule: utilization EWMAs, hysteresis, cooldown, the failover-aware
breaker and the thrash guard are all lowered into the tick, rescales
pay graceful hot-update downtime plus state-move seconds, and rate
schedules ride the pregenerated event tensors — so every cell shares
the schedule-free rows' chaos timelines.

    PYTHONPATH=src python examples/traffic_sweep.py             # 4x3x1 cube
    PYTHONPATH=src python examples/traffic_sweep.py --seeds 16 \\
        --duration 180

The script FAILS (non-zero exit) if the cube falls back to
per-(config, seed) host timeline rebuilds, if a no-scaler control row
rescales, or if the oscillation drill fails to latch the thrash guard —
scripts/ci.sh --traffic-smoke additionally exports
``REPRO_REQUIRE_PHASE_MODE=compact`` so a dense-lowering fallback trips
inside the engine itself.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=8,
                    help="chaos seeds per cube cell")
    ap.add_argument("--duration", type=float, default=120.0,
                    help="simulated horizon per scenario (seconds)")
    args = ap.parse_args()

    import numpy as np

    from repro.core.chaos import ChaosSpec, timeline_build_count
    from repro.streams import nexmark
    from repro.streams.chaos_sweep import traffic_sweep
    from repro.streams.engine import AutoscaleConfig, FailoverConfig

    g = nexmark.q3()
    base = ChaosSpec(host_kill_prob_per_s=0.002)
    fo = FailoverConfig(mode="region", detect_s=1.0)
    scalers = {
        "frozen": None,                      # fixed-provisioning control
        "ds2": AutoscaleConfig(interval_s=5.0, cooldown_s=10.0),
        # the oscillation drill: an eager controller with the thrash
        # guard armed — the fast swing below MUST latch it
        "eager": AutoscaleConfig(interval_s=3.0, cooldown_s=0.0,
                                 hysteresis=0.02, ewma_alpha=0.9,
                                 max_actions=1e18, thrash_flips=4.0,
                                 thrash_window_s=60.0),
    }
    t_flash = min(90.0, args.duration * 0.5)
    traffics = {
        "diurnal": {"diurnal": ((0.35, 240.0, 0.0),)},
        "flash": {"flash": ((t_flash, 10.0, 30.0, 3.0),)},
        "swing": {"diurnal": ((0.9, 12.0, 0.0),)},
    }

    builds0 = timeline_build_count()
    cube = traffic_sweep(g, range(args.seeds), base_spec=base,
                         duration_s=args.duration, scalers=scalers,
                         traffics=traffics, failovers={"region": fo})
    builds = timeline_build_count() - builds0

    n = cube.recovery.size
    print(f"== traffic cube {len(scalers)} scalers x {len(traffics)} "
          f"patterns x {args.seeds} seeds = {n} cells in "
          f"{cube.grid.wall_s:.2f}s "
          f"({cube.grid.scenarios_per_s:.1f} cells/s, ONE device call) ==")
    print(f"   host timeline builds during the cube: {builds} "
          f"(one per seed — rate schedules and scale events are "
          f"in-trace only)")
    cost0 = np.asarray(cube.cost)[0, :, 0].mean(-1)  # frozen bill/pattern
    for s, sc in enumerate(cube.scalers):
        for tr, tname in enumerate(cube.traffics):
            cell = lambda a: np.asarray(a)[s, tr, 0]
            thr = np.isfinite(cell(cube.thrash_t)).mean()
            print(f"   {sc:>7s} {tname:>8s}  "
                  f"rescales={cell(cube.rescales).mean():6.1f}  "
                  f"cost_x={cell(cube.cost).mean() / cost0[tr]:.3f}  "
                  f"slo_frac={cell(cube.slo).mean():.3f}  "
                  f"thrash_frac={thr:.2f}")

    if builds > args.seeds:
        raise SystemExit(
            "traffic smoke FAILED: the cube fell back to per-(config, "
            f"seed) timeline rebuilds ({builds} builds for "
            f"{args.seeds} seeds)")
    if (np.asarray(cube.rescales)[0] != 0).any():
        raise SystemExit(
            "traffic smoke FAILED: a no-scaler control row rescaled")
    eager = list(cube.scalers).index("eager")
    swing = list(cube.traffics).index("swing")
    latched = np.isfinite(np.asarray(cube.thrash_t)[eager, swing, 0])
    if not latched.all():
        raise SystemExit(
            "traffic smoke FAILED: the oscillation drill did not latch "
            f"the thrash guard in every seed "
            f"({int(latched.sum())}/{latched.size})")


if __name__ == "__main__":
    main()
