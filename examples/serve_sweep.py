"""Sweep-as-a-service smoke: boot `repro.launch.serve.SweepService`
in-process, fire two concurrent deployment-drill requests plus a
traffic-dynamics sweep at it, and consume incremental chunk results as
they land.

Demonstrates the service contract end to end:

- **Incremental results** — each request's (C, S_chunk) partial
  surfaces stream out per seed-chunk; the first chunk of the first
  drill lands while the slowest request is still running
  (time-to-first-result instead of time-to-last).
- **One shared jit cache** — the two drill requests have the same plan
  digest / grid shape / pow2 seed bucket, so the second rides the
  first's compiled trace: the script FAILS (non-zero exit) unless the
  requests record at least one trace-cache hit between them.
- **Chunk parity** — the chunked service cube is compared bit-for-bit
  against a monolithic in-process `deployment_drill` call; any drift
  exits non-zero.

    PYTHONPATH=src python examples/serve_sweep.py
    PYTHONPATH=src python examples/serve_sweep.py --seeds 16 --chunk 8

scripts/ci.sh --serve-smoke runs this script.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=8,
                    help="chaos seeds per request")
    ap.add_argument("--chunk", type=int, default=4,
                    help="seeds per device pass (chunk size)")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="simulated horizon per scenario (seconds)")
    args = ap.parse_args()

    import json
    import math
    import sys
    import threading
    import time

    import numpy as np

    from repro.core.chaos import ChaosSpec
    from repro.launch.serve import SweepService
    from repro.streams import nexmark
    from repro.streams.chaos_sweep import deployment_drill
    from repro.streams.engine import (AutoscaleConfig, CheckpointConfig,
                                      FailoverConfig, UpgradeConfig)

    failures: list[str] = []

    def check(ok: bool, msg: str) -> None:
        tag = "ok" if ok else "FAIL"
        print(f"  [{tag}] {msg}")
        if not ok:
            failures.append(msg)

    g = nexmark.q2(parallelism=4)
    seeds = range(args.seeds)
    base = ChaosSpec(host_kill_prob_per_s=0.001,
                     zk_down=((30.0, 34.0),),
                     hdfs_down=((32.0, 38.0),))
    fo = FailoverConfig(mode="single_task", detect_s=1.0,
                        single_restart_s=2.0)
    ckpt = CheckpointConfig(interval_s=10.0)
    drill_kw = dict(
        base_spec=base, duration_s=args.duration,
        policies={"hot": UpgradeConfig(t_upgrade_s=args.duration * 0.4,
                                       wave_stagger_s=2.0),
                  "cold": UpgradeConfig(t_upgrade_s=args.duration * 0.4,
                                        wave_stagger_s=2.0, hot=False)},
        canary_fracs=(0.25, 0.5),
        rollback_thresholds=(math.inf, 200.0),
        failover=fo, ckpt=ckpt, n_hosts=8)
    traffic_kw = dict(
        base_spec=ChaosSpec(host_kill_prob_per_s=0.002),
        duration_s=args.duration,
        scalers={"frozen": None,
                 "ds2": AutoscaleConfig(interval_s=5.0, cooldown_s=10.0)},
        traffics={"diurnal": {"diurnal": ((0.35, 240.0, 0.0),)}},
        failovers={"region": FailoverConfig(mode="region", detect_s=1.0)},
        ckpt=ckpt, n_hosts=8)

    print(f"== monolithic reference: (C=8, S={args.seeds}) drill cube ==")
    ref = deployment_drill(g, seeds, **drill_kw)
    print(f"  wall={ref.grid.wall_s:.2f}s  "
          f"({ref.grid.scenarios_per_s:.1f} scenarios/s)")

    print(f"== service: 2 drill requests + 1 traffic sweep, "
          f"chunk={args.chunk} ==")
    t0 = time.perf_counter()
    first_chunk_s: dict[int, float] = {}
    done_s: dict[int, float] = {}

    with SweepService(workers=2, default_seed_chunk=args.chunk) as svc:
        jobs = [
            svc.submit("deployment_drill", g, seeds, label="drill-a",
                       **drill_kw),
            svc.submit("deployment_drill", g, seeds, label="drill-b",
                       **drill_kw),
            svc.submit("traffic_sweep", nexmark.q3(), seeds,
                       label="traffic", **traffic_kw),
        ]

        def watch(job):
            for chunk in job.chunks(timeout=900):
                now = time.perf_counter() - t0
                first_chunk_s.setdefault(job.id, now)
                print(f"  [{job.request.label}] chunk {chunk.index}: "
                      f"seeds [{chunk.seed_lo}, {chunk.seed_hi}) "
                      f"device={chunk.device_s * 1e3:.0f}ms  t={now:.2f}s")
            done_s[job.id] = time.perf_counter() - t0

        watchers = [threading.Thread(target=watch, args=(j,))
                    for j in jobs]
        for w in watchers:
            w.start()
        results = [j.result(timeout=900) for j in jobs]
        for w in watchers:
            w.join(900)
        stats = svc.stats()

    print("== assertions ==")
    check(len(first_chunk_s) == len(jobs) == len(done_s),
          "every request streamed at least one chunk")
    first, slowest = min(first_chunk_s.values()), max(done_s.values())
    check(first < slowest,
          f"first chunk ({first:.2f}s) landed before the slowest "
          f"request completed ({slowest:.2f}s)")
    check(stats["cache_hits"] >= 1,
          f"requests shared a compiled trace "
          f"(cache hits={stats['cache_hits']}, "
          f"misses={stats['cache_misses']})")
    drift = [name for name in ("recovery", "slo", "lost", "rollback_t")
             if not np.array_equal(getattr(ref, name),
                                   getattr(results[0], name))]
    check(not drift,
          "chunked service cube is bit-identical to the monolithic "
          f"call{'' if not drift else f' (drifted: {drift})'}")
    check(np.array_equal(results[0].recovery, results[1].recovery),
          "the two drill requests returned identical cubes")
    check(results[2].slo.shape[-1] == args.seeds,
          "traffic sweep returned a full cube")
    for j in jobs:
        js = stats["jobs"][j.id]
        print(f"  [{js['label']}] state={js['state']} "
              f"chunks={js['chunks']} ttfr={js['ttfr_s']:.2f}s "
              f"wall={js['wall_s']:.2f}s prep={js['prep_s'] * 1e3:.0f}ms "
              f"device={js['device_s'] * 1e3:.0f}ms "
              f"hits={js['cache_hits']} misses={js['cache_misses']}")

    print(json.dumps({"trace_cache": stats["trace_cache"],
                      "cache_hits": stats["cache_hits"],
                      "cache_misses": stats["cache_misses"],
                      "completed": stats["completed"]}))
    if failures:
        print(f"SERVE SMOKE FAILED: {failures}")
        sys.exit(1)
    print("serve smoke OK")


if __name__ == "__main__":
    main()
