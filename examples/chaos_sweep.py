"""Chaos sweep (paper §V-B at release-pipeline scale): screen hundreds of
injected-failure scenarios against Nexmark Q2 and Q12 in ONE vmapped
`jit` call per graph, then report fleet-level recovery percentiles.

    PYTHONPATH=src python examples/chaos_sweep.py                 # 256 seeds
    PYTHONPATH=src python examples/chaos_sweep.py --seeds 16 --duration 60
"""
import argparse

from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep
from repro.streams.engine import CheckpointConfig, FailoverConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=256,
                    help="failure seeds per graph (one vmapped jit call)")
    ap.add_argument("--duration", type=float, default=120.0,
                    help="simulated horizon per scenario (seconds)")
    ap.add_argument("--graphs", default="q2,q12")
    args = ap.parse_args()

    base = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2,
                     storage_slow_prob=0.1)
    graphs = {
        "q2": (nexmark.q2(parallelism=8, partitioner="weakhash",
                          n_groups=4, service_rate=1.1e5),
               FailoverConfig(mode="single_task", single_restart_s=10.0)),
        "q12": (nexmark.q12(parallelism=8, service_rate=2.4e5),
                FailoverConfig(mode="region", region_restart_s=20.0)),
    }
    for name in args.graphs.split(","):
        graph, fo = graphs[name.strip()]
        res = sweep(graph, range(args.seeds), base_spec=base,
                    duration_s=args.duration, n_hosts=8, failover=fo,
                    ckpt=CheckpointConfig(interval_s=30.0, mode="region"))
        agg = res.aggregate()
        print(f"== {graph.name}: {agg['scenarios']} scenarios × "
              f"{res.n_ticks} ticks in {res.wall_s:.2f}s "
              f"({agg['scenarios_per_s']:.0f} scenarios/s, vmapped jit) ==")
        print(f"  scenarios with failures : {agg['failed_scenarios']}"
              f"  (unrecovered: {agg['unrecovered']})")
        print(f"  recovery time p50/p95/max: {agg['recovery_p50_s']:.1f} / "
              f"{agg['recovery_p95_s']:.1f} / {agg['recovery_max_s']:.1f} s")
        print(f"  SLO-violation frac p50/p95: "
              f"{agg['slo_violation_frac_p50']:.3f} / "
              f"{agg['slo_violation_frac_p95']:.3f}")
        print(f"  peak backlog {agg['max_backlog']:.2e} rec, dropped "
              f"{agg['dropped_total']:.0f} rec")
        worst = max(res.summaries, key=lambda s: (s.recovery_time_s
                                                  if s.n_failures else -1))
        print(f"  worst seed {worst.seed}: {worst.n_failures} failures, "
              f"recovery {worst.recovery_time_s:.1f}s, "
              f"max_lag {worst.max_lag:.2e}, "
              f"ckpt {worst.ckpt_success}/{worst.ckpt_attempts}")


if __name__ == "__main__":
    main()
