"""Deployment-drill release gate: the upgrade-policy × canary-fraction ×
rollback-threshold cube from ONE `sweep_configs` device call
(`streams.chaos_sweep.deployment_drill`), over a heterogeneous fleet of
join-shaped (Q3) and session-window-shaped (Q11) jobs.

Each cube cell runs a traced canary/rolling upgrade: region-sized waves
restart on a stagger paying hot-vs-cold restart costs lowered from the
`core.hotupdate` deploy model, the canaried slice runs a regressed
config (selectivity scale above the fleet's sink headroom), and the
in-trace controller auto-rolls the canary back when its backlog diverges
from the stable slice. Upgrades are in-trace only, so every cell shares
the drill-free rows' pregenerated chaos timelines.

    PYTHONPATH=src python examples/deployment_drill.py          # 2x2x2 cube
    PYTHONPATH=src python examples/deployment_drill.py --seeds 16 \\
        --jobs 8 --duration 120

The script FAILS (non-zero exit) if the drill grid falls back to
per-(config, seed) host timeline rebuilds, or if the induced-regression
cells fail to fire the auto-rollback — scripts/ci.sh --drill-smoke
additionally exports ``REPRO_REQUIRE_PHASE_MODE=compact`` so a
dense-lowering fallback trips inside the engine itself.
"""
import argparse
import dataclasses
import math


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=8,
                    help="chaos seeds per cube cell")
    ap.add_argument("--jobs", type=int, default=4,
                    help="fleet size (alternating Q3/Q11 jobs)")
    ap.add_argument("--fracs", type=int, default=2,
                    help="canary-fraction grid points")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="simulated horizon per scenario (seconds)")
    args = ap.parse_args()

    import numpy as np

    from repro.core.chaos import ChaosSpec, timeline_build_count
    from repro.core.startup import StartupConfig
    from repro.streams import nexmark
    from repro.streams.chaos_sweep import deployment_drill
    from repro.streams.engine import FailoverConfig, UpgradeConfig

    fleet = nexmark.drill_fleet(n_jobs=args.jobs, queue_cap=1e9)
    base = ChaosSpec(host_kill_prob_per_s=0.001,
                     zk_down=((30.0, 34.0),), hdfs_down=((32.0, 38.0),))
    fo = FailoverConfig(mode="single_task", detect_s=1.0,
                        single_restart_s=2.0)
    # the induced regression: canary selectivity 1.5 > fleet sink
    # headroom 1.2, so upgraded slices overload their sinks
    drill = UpgradeConfig(t_upgrade_s=10.0, wave_stagger_s=1.0,
                          canary_sel_scale=1.5,
                          rollback_window_s=4.0)
    policies = {
        "hot": dataclasses.replace(drill, hot=True),
        "cold+accel": dataclasses.replace(drill, hot=False,
                                          startup=StartupConfig()),
    }
    fracs = (0.5, 1.0)[:max(1, args.fracs)]
    thresholds = (math.inf, 100.0)

    builds0 = timeline_build_count()
    cube = deployment_drill(fleet, range(args.seeds), base_spec=base,
                            duration_s=args.duration, policies=policies,
                            canary_fracs=fracs,
                            rollback_thresholds=thresholds,
                            failover=fo, n_hosts=16)
    builds = timeline_build_count() - builds0

    n = cube.rollback_t.size
    print(f"== drill cube {len(policies)} policies x {len(fracs)} fracs "
          f"x {len(thresholds)} thresholds x {args.seeds} seeds = "
          f"{n} cells in {cube.grid.wall_s:.2f}s "
          f"({cube.grid.scenarios_per_s:.1f} cells/s, ONE device call) ==")
    print(f"   host timeline builds during the cube: {builds} "
          f"(one per seed — flat across "
          f"{len(policies) * len(fracs) * len(thresholds)} drill rows)")
    rb = np.asarray(cube.rollback_t)
    for p, pol in enumerate(cube.policies):
        for f, frac in enumerate(cube.canary_fracs):
            for th, thr in enumerate(cube.rollback_thresholds):
                cell = rb[p, f, th]
                fired = np.isfinite(cell)
                t_txt = (f"t_rb={cell[fired].mean():5.1f}s"
                         if fired.any() else "held    ")
                print(f"   {pol:>10s} canary={frac:g} thr="
                      f"{'off' if math.isinf(thr) else f'{thr:g}':>4s}"
                      f"  rollback {int(fired.sum())}/{len(cell)}  "
                      f"{t_txt}  slo_frac="
                      f"{np.asarray(cube.slo)[p, f, th].mean():.3f}")

    if builds > args.seeds:
        raise SystemExit(
            "drill smoke FAILED: the cube fell back to per-(config, "
            f"seed) timeline rebuilds ({builds} builds for "
            f"{args.seeds} seeds)")
    fired_frac = cube.rollback_frac[:, :, 1]   # finite-threshold slot
    if not (fired_frac == 1.0).all():
        raise SystemExit(
            "drill smoke FAILED: the induced regression did not fire "
            f"the auto-rollback in every gated cell ({fired_frac})")
    held = cube.rollback_t[:, :, 0]
    if not np.isinf(held).all():
        raise SystemExit(
            "drill smoke FAILED: a threshold=inf control row rolled "
            "back")


if __name__ == "__main__":
    main()
