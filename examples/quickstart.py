"""Quickstart: build an assigned architecture, train a few steps with the
full StreamShield resiliency stack, kill a 'worker', recover, and keep going.

    PYTHONPATH=src python examples/quickstart.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "stablelm-1.6b", "--smoke", "--steps", "25",
     "--inject-failure-at", "12", "--gamma", "full"],
    check=True)
