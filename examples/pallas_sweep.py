"""Fused-Pallas-tick sweep smoke: run a seed batch over a deep-pipeline
packed arena through the PALLAS phase mode — one fused kernel launch per
routing phase, the seed axis as the kernel grid dimension (ISSUE 6
pipeline end to end).

    PYTHONPATH=src python examples/pallas_sweep.py             # 6 jobs x 16 seeds
    PYTHONPATH=src python examples/pallas_sweep.py --jobs 18 --seeds 32 \\
        --duration 120

By default the kernel runs through the Pallas interpreter
(``REPRO_KERNEL_IMPL=interpret`` — jit/vmap/scan-traceable, the CPU-CI
stand-in for the compiled TPU kernel). The script FAILS (non-zero exit)
if the lowering falls back off the pallas mode or the impl resolves to
the jnp reference path — scripts/ci.sh --pallas-smoke additionally
exports ``REPRO_REQUIRE_PHASE_MODE=pallas`` so the same guard trips
inside the engine itself.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=6,
                    help="co-located SS jobs packed into the arena")
    ap.add_argument("--seeds", type=int, default=16,
                    help="chaos seeds in the native kernel-grid batch")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="simulated horizon per scenario (seconds)")
    args = ap.parse_args()

    # the smoke must exercise the actual kernel body, not the jnp ref
    os.environ.setdefault("REPRO_KERNEL_IMPL", "interpret")

    import numpy as np

    from repro.core.chaos import ChaosSpec
    from repro.kernels.common import resolve_impl
    from repro.streams import nexmark
    from repro.streams.jax_engine import _Lowered, run_batch

    impl = resolve_impl(None)
    if impl == "ref":
        raise SystemExit(
            "pallas smoke FAILED: kernel impl resolved to the jnp "
            "reference path (set REPRO_KERNEL_IMPL=interpret|pallas)")

    arena = nexmark.ss_arena(n_tasks=args.jobs * 56, parallelism=8,
                             n_hosts=32)
    low = _Lowered(arena, n_hosts=32, dt=0.5, queue_cap=256.0,
                   failover=None, ckpt=None, seed=0,
                   phase_mode="pallas")
    if low.tensor.mode != "pallas":
        raise SystemExit(
            f"pallas smoke FAILED: lowering fell back to "
            f"{low.tensor.mode!r}")

    base = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
    bm = run_batch(arena, range(args.seeds), duration_s=args.duration,
                   base_spec=base, phase_mode="pallas")
    dropped = np.sum(bm.dropped_by_job)
    emitted = np.sum(bm.emitted_by_job)
    print(f"== {arena.n_jobs} SS jobs / {arena.plan.n_tasks} tasks "
          f"({low.tensor.n_phases} fused phases, impl={impl}): "
          f"{args.seeds}-seed native kernel-grid batch, "
          f"{args.duration:g}s horizon ==")
    print(f"   emitted={emitted:.3e} records  dropped={dropped:.3e}  "
          f"peak lag={float(np.max(bm.source_lag)):.1f}")
    if not np.isfinite(emitted) or emitted <= 0:
        raise SystemExit("pallas smoke FAILED: no records emitted")


if __name__ == "__main__":
    main()
