"""Chaos drill (paper §V-B): hardware-level + process-level fault injection
against the stream engine and the cluster control plane, with the HA fallback
chain exercised end to end.

    PYTHONPATH=src python examples/chaos_drill.py
"""
import numpy as np

from repro.ckpt.storage import LocalFS
from repro.cluster.coordinator import Coordinator
from repro.cluster.scheduler import GodelSim
from repro.cluster.simulator import nexmark_edges
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import VirtualClock
from repro.core.startup import StartupConfig
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine)

print("== process-level chaos: host kill on the SS join ==")
for mode in ("region", "single_task"):
    chaos = ChaosEngine(ChaosSpec(seed=0, host_kill_at=((120.0, 3),)))
    eng = StreamEngine(nexmark.ss(parallelism=8), n_hosts=8, chaos=chaos,
                       failover=FailoverConfig(mode=mode,
                                               region_restart_s=60.0))
    m = eng.run(300)
    q = np.array(m.qps["join"])
    print(f"  {mode:12s} min_qps={q[250:].min():9.0f} "
          f"zero_ticks={(q == 0).sum()} dropped={m.dropped:.0f}")

print("== hardware-level chaos: slow HDFS during checkpoints ==")
chaos = ChaosEngine(ChaosSpec(seed=1, storage_slow_prob=0.05,
                              storage_slow_factor=10))
eng = StreamEngine(nexmark.ds(parallelism=6), n_hosts=6, chaos=chaos,
                   ckpt=CheckpointConfig(interval_s=30, mode="region"))
m = eng.run(7200)
print(f"  region ckpt success {m.ckpt_success}/{m.ckpt_attempts}")

print("== control-plane chaos: Gödel outage + ZK loss ==")
clock = VirtualClock()
chaos = ChaosEngine(ChaosSpec(zk_down=((30.0, 1e9),)))  # ZK never returns
coord = Coordinator(clock=clock, chaos=chaos,
                    hdfs_store=LocalFS("/tmp/repro-chaos-ha"),
                    godel=GodelSim(clock=clock, down_windows=((0.0, 8.0),)))
coord.become_leader("jm-0")
rec = coord.launch("job-1", n_tms=128, edges=nexmark_edges(16),
                   cfg=StartupConfig())
print(f"  submitted through outage: attempts={rec.submission_info['attempts']}"
      f" backoff={rec.submission_info['backoff_s']:.1f}s")
clock.sleep(60)  # inside the ZK outage window
print(f"  leader during ZK outage: {coord.current_leader()} "
      f"(hdfs fallback reads={coord.leader_svc.fallback_reads})")
