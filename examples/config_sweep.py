"""Resiliency-config grid sweep (Khaos-style tuning curves in one device
call): sweep a restart-budget × checkpoint-interval grid against Nexmark
Q12 over a batch of chaos seeds — the engine's third vmap axis — and
print the recovery-time-vs-budget / SLO-vs-interval curves the paper's
release gating reads off.

    PYTHONPATH=src python examples/config_sweep.py                # 4x4 grid
    PYTHONPATH=src python examples/config_sweep.py --restarts 3 \\
        --intervals 2 --seeds 8 --duration 120
"""
import argparse

import numpy as np

from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep_configs
from repro.streams.engine import CheckpointConfig, FailoverConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--restarts", type=int, default=4,
                    help="restart-budget grid points (10..60s)")
    ap.add_argument("--intervals", type=int, default=4,
                    help="checkpoint-interval grid points (15..60s)")
    ap.add_argument("--seeds", type=int, default=32,
                    help="chaos seeds per config row")
    ap.add_argument("--duration", type=float, default=240.0,
                    help="simulated horizon per scenario (seconds)")
    args = ap.parse_args()

    graph = nexmark.q12(parallelism=8, service_rate=2.4e5)
    base = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2,
                     storage_slow_prob=0.1)
    restarts = np.linspace(10.0, 60.0, args.restarts)
    intervals = np.linspace(15.0, 60.0, args.intervals)
    grid = [{"failover": FailoverConfig(mode="region",
                                        region_restart_s=float(r)),
             "ckpt": CheckpointConfig(interval_s=float(iv),
                                      mode="region"),
             "label": f"restart={r:.0f}s ckpt={iv:.0f}s"}
            for r in restarts for iv in intervals]
    res = sweep_configs(graph, grid, range(args.seeds), base_spec=base,
                        duration_s=args.duration, n_hosts=8)
    n = res.recovery_surface.size
    print(f"== {graph.name}: {len(grid)} configs × {args.seeds} seeds "
          f"({n} scenarios) in {res.wall_s:.2f}s "
          f"({res.scenarios_per_s:.0f} scenarios/s, one (C,S) grid per "
          f"device call) ==")
    print(f"{'config':>24} {'rec_p50':>8} {'rec_p95':>8} {'unrec':>6} "
          f"{'slo_p95':>8} {'ckpt_ok':>8}")
    for lbl, r, sr in zip(res.labels, res.rows(), res.results):
        ok = sum(s.ckpt_success for s in sr.summaries)
        at = sum(s.ckpt_attempts for s in sr.summaries)
        print(f"{lbl:>24} {r['recovery_p50_s']:>8.1f} "
              f"{r['recovery_p95_s']:>8.1f} {r['unrecovered']:>6d} "
              f"{r['slo_violation_frac_p95']:>8.3f} "
              f"{ok:>5d}/{at}")
    # the two headline curves, marginalized over the other knob
    rec = res.recovery_surface.reshape(len(restarts), len(intervals), -1)
    slo = res.slo_surface.reshape(len(restarts), len(intervals), -1)
    fin = np.where(np.isfinite(rec), rec, np.nan)
    print("\nrecovery-time vs restart budget (median over intervals+seeds):")
    for i, r in enumerate(restarts):
        print(f"  restart={r:5.1f}s -> {np.nanmedian(fin[i]):7.1f}s")
    print("SLO-violation frac vs checkpoint interval (median):")
    for k, iv in enumerate(intervals):
        print(f"  interval={iv:5.1f}s -> {np.median(slo[:, k]):7.3f}")


if __name__ == "__main__":
    main()
