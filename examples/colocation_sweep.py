"""Multi-job co-location chaos sweep (the paper's cluster perspective):
pack K jobs onto ONE shared host pool and sweep failure seeds over the
whole fleet in a single device call per shard — host kills couple every
co-located job's recovery, and the sweep reports per-job breakdowns.

    PYTHONPATH=src python examples/colocation_sweep.py                # 4 jobs, 256 seeds
    PYTHONPATH=src python examples/colocation_sweep.py --seeds 16 --duration 60
    PYTHONPATH=src python examples/colocation_sweep.py --devices 4    # sharded seed batch

``--devices N`` (> 1) forces N host devices (must be set before jax
initializes, which this script handles) and splits the seed batch across
them via the version-gated `repro.dist.sharding` shim.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=4, choices=range(2, 5),
                    help="co-located jobs packed into the arena")
    ap.add_argument("--seeds", type=int, default=256,
                    help="failure seeds (padded to the next power of two)")
    ap.add_argument("--duration", type=float, default=120.0,
                    help="simulated horizon per scenario (seconds)")
    ap.add_argument("--hosts", type=int, default=8,
                    help="shared host pool size")
    ap.add_argument("--devices", type=int, default=1,
                    help="device shards for the seed batch (>1 forces "
                         "host devices)")
    args = ap.parse_args()

    if args.devices > 1:   # before jax initializes
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    from repro.core.chaos import ChaosSpec
    from repro.dist.sharding import local_shard_count
    from repro.streams import nexmark
    from repro.streams.chaos_sweep import sweep
    from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                      pack_arena)

    graphs = [nexmark.q2(parallelism=8, partitioner="weakhash",
                         n_groups=4, service_rate=1.1e5),
              nexmark.q12(parallelism=8, service_rate=2.4e5),
              nexmark.ds(parallelism=6),
              nexmark.ss(parallelism=4)][:args.jobs]
    arena = pack_arena(graphs, "shared", n_hosts=args.hosts)
    base = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2,
                     storage_slow_prob=0.1)
    res = sweep(arena, range(args.seeds), base_spec=base,
                duration_s=args.duration,
                failover=FailoverConfig(mode="region",
                                        region_restart_s=20.0),
                ckpt=CheckpointConfig(interval_s=30.0, mode="region"),
                devices=(args.devices if args.devices > 1 else None))
    agg = res.aggregate()
    # report the shard count actually used, not the one requested (the
    # device forcing is best-effort when XLA_FLAGS was already set)
    n_dev = local_shard_count(args.devices if args.devices > 1 else None)
    print(f"== {arena.n_jobs} co-located jobs on {args.hosts} hosts: "
          f"{agg['scenarios']} seeds x {res.n_ticks} ticks in "
          f"{res.wall_s:.2f}s ({agg['scenarios_per_s']:.0f} scenarios/s, "
          f"{n_dev} device shard{'s' if n_dev > 1 else ''}) ==")
    print(f"  fleet: failures in {agg['failed_scenarios']} scenarios "
          f"(unrecovered: {agg['unrecovered']}), peak backlog "
          f"{agg['max_backlog']:.2e} rec")
    for name, jr in res.job_results.items():
        ja = jr.aggregate()
        print(f"  {name:<22s} recovery p50/p95 "
              f"{ja['recovery_p50_s']:6.1f}/{ja['recovery_p95_s']:6.1f} s"
              f"  SLO-viol p95 {ja['slo_violation_frac_p95']:.3f}"
              f"  dropped {ja['dropped_total']:.0f}")


if __name__ == "__main__":
    main()
