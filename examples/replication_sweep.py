"""Hybrid-replication tradeoff drill: the replication-mode ×
checkpoint-interval × storage-brownout-severity cube from ONE
`sweep_configs` device call (`streams.chaos_sweep.replication_tradeoff`),
under the full external-system HA drill — a region-correlated failure
burst, a storage brownout tent ramp stretching checkpoint uploads and
passive restores, and an MQ outage window gating the sources.

    PYTHONPATH=src python examples/replication_sweep.py              # 2x2x2 cube
    PYTHONPATH=src python examples/replication_sweep.py --seeds 16 \\
        --intervals 3 --brownouts 3 --duration 120

The script FAILS (non-zero exit) if the checkpoint-bearing grid falls
back to per-(config, seed) host timeline rebuilds — scripts/ci.sh
--ha-smoke additionally exports ``REPRO_REQUIRE_PHASE_MODE=compact`` so
a dense-lowering fallback trips inside the engine itself.
"""
import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=8,
                    help="chaos seeds per cube cell")
    ap.add_argument("--intervals", type=int, default=2,
                    help="checkpoint-interval grid points (incl. 'off')")
    ap.add_argument("--brownouts", type=int, default=2,
                    help="brownout-severity grid points (incl. 'none')")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="simulated horizon per scenario (seconds)")
    args = ap.parse_args()

    import numpy as np

    from repro.core.chaos import timeline_build_count
    from repro.core.replication import TimingModel
    from repro.streams import nexmark
    from repro.streams.chaos_sweep import replication_tradeoff
    from repro.streams.engine import FailoverConfig

    graph = nexmark.q12(parallelism=4)
    # the paper's release-gate drill minus the burst/brownout bits the
    # cube itself sweeps: MQ outage window + a mid-run region burst
    base = nexmark.ha_drill_spec(burst_t=20.0, brownout=(0.0, 0.0, 1.0),
                                 mq_outage=(45.0, 50.0),
                                 host_kill_prob_per_s=0.002)
    base = dataclasses.replace(base, brownout_at=())

    timing = TimingModel()
    failovers = {
        "hot_standby": FailoverConfig.from_replication(
            timing, mode="hot_standby"),
        "passive": FailoverConfig.from_replication(
            timing, mode="single_task", state_bytes=8 << 30),
    }
    intervals = (None, 10.0, 30.0, 60.0)[:max(1, args.intervals)]
    peaks = (1.0, 4.0, 8.0)[:max(1, args.brownouts)]
    bros = tuple(() if p == 1.0 else ((5.0, 35.0, p),) for p in peaks)

    builds0 = timeline_build_count()
    cube = replication_tradeoff(graph, range(args.seeds), base_spec=base,
                                duration_s=args.duration,
                                failovers=failovers,
                                ckpt_intervals=intervals, brownouts=bros,
                                n_hosts=8)
    builds = timeline_build_count() - builds0

    n = cube.recovery.size
    print(f"== replication cube {len(failovers)} modes x "
          f"{len(intervals)} intervals x {len(bros)} brownouts x "
          f"{args.seeds} seeds = {n} cells in {cube.grid.wall_s:.2f}s "
          f"({cube.grid.scenarios_per_s:.1f} cells/s, ONE device call) ==")
    print(f"   host timeline replays during the grid: "
          f"{'zero' if builds == 0 else builds} "
          f"(per-seed stream refits only)")
    rec = np.asarray(cube.recovery)
    lost = np.asarray(cube.lost)
    for m, mode in enumerate(cube.modes):
        for i, iv in enumerate(cube.ckpt_intervals):
            for b, peak in enumerate(cube.brownout_peaks):
                r = rec[m, i, b]
                fin = r[np.isfinite(r)]
                rr = f"{fin.mean():6.1f}s" if fin.size else "   inf "
                print(f"   {mode:>12s} ckpt="
                      f"{'off' if iv is None else f'{iv:g}s':>4s} "
                      f"brownout={peak:g}x  rec_mean={rr}  "
                      f"lost_mean={lost[m, i, b].mean():12.0f}")
    if builds != 0:
        raise SystemExit("ha smoke FAILED: replication grid fell back "
                         "to per-(config, seed) host timeline rebuilds")


if __name__ == "__main__":
    main()
