"""WeakHash MoE serving: batched prefill + decode of a (reduced) arctic-480b
with State-LazyLoad weight restore and WeakHash group routing, then a skew
drill: a hot expert's load under strict vs weakhash routing.

    PYTHONPATH=src python examples/weakhash_moe_serving.py
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

subprocess.run(
    [sys.executable, "-m", "repro.launch.model_serve", "--arch", "arctic-480b",
     "--requests", "4", "--prompt-len", "32", "--decode-steps", "8",
     "--lazyload"],
    check=True)

# ---- skew drill -----------------------------------------------------------
from repro.kernels.weakhash_route import ref as R  # noqa: E402

rng = np.random.default_rng(0)
T, E = 4096, 64
logits = rng.normal(size=(T, E)).astype(np.float32)
logits[:, 5] += 3.0  # hot expert (hot key)
keys = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.int32)
cap = 2 * T // E
strict = R.weakhash_route(jnp.asarray(logits), top_k=2, capacity=cap,
                          mode="strict")
weak = R.weakhash_route(jnp.asarray(logits), top_k=2, capacity=cap,
                        n_groups=16, mode="weakhash", token_keys=keys)
print(f"hot-expert demand: strict={float(strict.demand.max()):.0f} "
      f"weakhash={float(weak.demand.max()):.0f}")
print(f"dropped tokens:    strict={1 - float(strict.keep.mean()):.2%} "
      f"weakhash={1 - float(weak.keep.mean()):.2%}")
