"""Parity pins for the JAX engine twin (`streams/jax_engine.py`).

The jax engine is pinned to the numpy `StreamEngine` the same way the
numpy engine is pinned to `reference_engine.py`: identical chaos event
streams (pregenerated draw-for-draw), metrics parity at 1e-5 over full
runs — across every partitioner, both failover modes, the checkpoint
coordinator, and under Poisson host kills + stragglers. The vmapped
batch path is additionally pinned row-for-row to standalone runs, and
the compiled-run cache is pinned to one trace per plan shape.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine)
from repro.streams.jax_engine import (JaxStreamEngine, get_cached_run_fns,
                                      run_batch)

TOL = dict(rtol=1e-5, atol=1e-5)


def assert_metrics_match(np_eng, jax_metrics, label="", tol=TOL):
    ma, mb = np_eng.metrics, jax_metrics
    for n in np_eng.g.topo_order():
        np.testing.assert_allclose(np.array(ma.qps[n]), mb.qps[n],
                                   err_msg=f"{label} qps[{n}]", **tol)
        np.testing.assert_allclose(np.array(ma.backlog[n]), mb.backlog[n],
                                   err_msg=f"{label} backlog[{n}]", **tol)
    np.testing.assert_allclose(np.array(ma.t), mb.t, atol=0)
    np.testing.assert_allclose(np.array(ma.source_lag), mb.source_lag,
                               **tol)
    np.testing.assert_allclose(ma.emitted, mb.emitted, rtol=1e-5)
    np.testing.assert_allclose(ma.dropped, mb.dropped, **tol)
    assert (ma.ckpt_attempts, ma.ckpt_success, ma.ckpt_failed) == \
        (mb.ckpt_attempts, mb.ckpt_success, mb.ckpt_failed), label
    # device-side scan counter agrees with the host-side timeline
    assert mb.ckpt_epoch == mb.ckpt_attempts, label
    assert ma.recoveries == mb.recoveries, label


def _run_pair(make_graph, duration, **kw):
    kw_np = dict(kw)
    spec = kw_np.pop("chaos_spec", None)
    if spec is not None:
        kw_np["chaos"] = ChaosEngine(spec)
    a = StreamEngine(make_graph(), **kw_np)
    a.run(duration)
    kw_jx = dict(kw)
    if spec is not None:
        kw_jx["chaos"] = kw_jx.pop("chaos_spec")
    b = JaxStreamEngine(make_graph(), **kw_jx)
    mb = b.run(duration)
    return a, b, mb


# ----------------------------------------------------------------------
# single-seed parity (full runs, 1e-5)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("partitioner", ["rebalance", "hash", "weakhash",
                                         "backlog", "group_rescale"])
def test_jax_parity_partitioners(partitioner):
    slow = {t: 1e-3 for t in range(16, 32, 5)}  # stragglers → congestion
    a, _, mb = _run_pair(
        lambda: nexmark.q2(parallelism=16, partitioner=partitioner,
                           n_groups=4),
        60, n_hosts=16, task_speed_override=slow, seed=3)
    assert_metrics_match(a, mb, partitioner)


def test_jax_parity_forward_chain():
    a, _, mb = _run_pair(lambda: nexmark.ds(parallelism=6), 120, n_hosts=6)
    assert_metrics_match(a, mb, "forward")


@pytest.mark.parametrize("mode", ["region", "single_task"])
def test_jax_parity_host_kill(mode):
    a, _, mb = _run_pair(
        lambda: nexmark.ss(parallelism=8), 300, n_hosts=8,
        chaos_spec=ChaosSpec(seed=0, host_kill_at=((100.0, 2),)),
        failover=FailoverConfig(mode=mode, region_restart_s=60.0))
    assert_metrics_match(a, mb, mode)
    assert len(mb.recoveries) == 1
    if mode == "single_task":
        assert mb.dropped > 0


def test_jax_parity_poisson_kills_and_stragglers():
    """Long run, random kill process + stragglers: the pregenerated event
    tensors must consume the chaos rng draw-for-draw with the numpy
    engine or everything after the first divergent draw falls apart."""
    spec = ChaosSpec(seed=5, host_kill_prob_per_s=0.002,
                     straggler_frac=0.25, straggler_factor=4.0)
    a, _, mb = _run_pair(
        lambda: nexmark.q12(parallelism=8), 600, n_hosts=8,
        chaos_spec=spec,
        failover=FailoverConfig(mode="region", region_restart_s=20.0))
    assert len(mb.recoveries) > 1          # chaos actually fired
    assert_metrics_match(a, mb, "poisson")


def test_jax_parity_checkpoints():
    for cm in ("region", "global"):
        a, _, mb = _run_pair(
            lambda: nexmark.ds(parallelism=6), 400, n_hosts=6,
            chaos_spec=ChaosSpec(seed=2, storage_slow_prob=0.3,
                                 storage_slow_factor=10),
            ckpt=CheckpointConfig(interval_s=30, mode=cm))
        assert mb.ckpt_attempts > 0
        assert_metrics_match(a, mb, cm)


def test_jax_parity_ckpt_under_kills():
    """Interleaved rng consumers: kill draws + checkpoint storage draws."""
    spec = ChaosSpec(seed=7, host_kill_prob_per_s=0.001,
                     storage_slow_prob=0.2, storage_slow_factor=12)
    a, _, mb = _run_pair(
        lambda: nexmark.ds(parallelism=6), 500, n_hosts=6,
        chaos_spec=spec,
        failover=FailoverConfig(mode="region", region_restart_s=15.0),
        ckpt=CheckpointConfig(interval_s=40, mode="region"))
    assert mb.ckpt_attempts > 0
    assert_metrics_match(a, mb, "ckpt+kills")


# ----------------------------------------------------------------------
# vmapped batch: row i == standalone seed i, and both == numpy engine
# ----------------------------------------------------------------------
def test_jax_batch_rows_match_standalone_and_numpy():
    base = ChaosSpec(host_kill_prob_per_s=0.003, straggler_frac=0.2)
    fo = FailoverConfig(mode="region", region_restart_s=20.0)
    def graph():
        return nexmark.q2(parallelism=8, partitioner="weakhash",
                          n_groups=4)
    seeds = list(range(6))
    bm = run_batch(graph(), seeds, base_spec=base, duration_s=120,
                   n_hosts=8, failover=fo)
    assert bm.source_lag.shape == (6, 240)
    for i in seeds:
        spec = dataclasses.replace(base, seed=i)
        # batch row i == standalone jax run with seed i (same lowering,
        # so down to vmap-reduction reassociation only)
        m = JaxStreamEngine(graph(), n_hosts=8, chaos=spec,
                            failover=fo).run(120)
        np.testing.assert_allclose(bm.source_lag[i], m.source_lag,
                                   rtol=1e-12, atol=1e-9)
        np.testing.assert_allclose(bm.dropped[i], m.dropped,
                                   rtol=1e-12, atol=1e-9)
        assert bm.recoveries[i] == m.recoveries
        # ... and both pin to the numpy engine at 1e-5
        a = StreamEngine(graph(), n_hosts=8, chaos=ChaosEngine(spec),
                         failover=fo)
        a.run(120)
        assert_metrics_match(a, bm.row(i), f"seed {i}")


# ----------------------------------------------------------------------
# trace cache: one trace per plan shape
# ----------------------------------------------------------------------
def test_jit_cache_one_trace_per_plan_shape():
    def g(s):
        return nexmark.q2(parallelism=12, partitioner="weakhash",
                          n_groups=4, source_rate=s)
    e1 = JaxStreamEngine(g(0.8e6), n_hosts=8, chaos=ChaosSpec(seed=1))
    e2 = JaxStreamEngine(g(0.5e6), n_hosts=8, chaos=ChaosSpec(seed=2))
    # same plan shape → the very same cached callable
    assert e1.lowered.desc == e2.lowered.desc
    fn1, _ = get_cached_run_fns(e1.lowered.desc)
    fn2, _ = get_cached_run_fns(e2.lowered.desc)
    assert fn1 is fn2
    before = fn1._cache_size()
    e1.run(30)
    e2.run(30)   # different rates/seeds, same shapes → no retrace
    assert fn1._cache_size() - before == 1
    # a different plan shape misses the cache (different callable)
    e3 = JaxStreamEngine(nexmark.q2(parallelism=4), n_hosts=4)
    fn3, _ = get_cached_run_fns(e3.lowered.desc)
    assert fn3 is not fn1


def test_padded_batches_reuse_one_trace_across_sizes():
    """Retrace-free batching: the seed axis pads to the next power of
    two, so S ∈ {7, 8} share ONE trace of the vmapped run fn and
    S ∈ {200, 256} share ONE more — varying batch sizes never recompile
    within a pow2 bucket, and results still carry exactly S rows."""
    g = nexmark.q2(parallelism=4, partitioner="weakhash", n_groups=2)
    spec = ChaosSpec(host_kill_prob_per_s=0.003)
    from repro.streams.jax_engine import _Lowered
    low = _Lowered(g, n_hosts=4, dt=0.5, queue_cap=256.0, failover=None,
                   ckpt=None, seed=0)
    _, batch_fn = get_cached_run_fns(low.desc)
    before = batch_fn._cache_size()
    sizes = (7, 8, 200, 256)
    for s in sizes:
        bm = run_batch(g, range(s), base_spec=spec, duration_s=20,
                       n_hosts=4)
        assert bm.source_lag.shape[0] == s       # pad rows sliced off
    assert batch_fn._cache_size() - before == 2  # {7,8} and {200,256}
    # opting out of padding traces per exact size (the old behavior)
    run_batch(g, range(5), base_spec=spec, duration_s=20, n_hosts=4,
              pad_seeds=False)
    assert batch_fn._cache_size() - before == 3
    # padded row values match the unpadded run bit-for-bit
    a = run_batch(g, range(5), base_spec=spec, duration_s=20, n_hosts=4)
    b = run_batch(g, range(5), base_spec=spec, duration_s=20, n_hosts=4,
                  pad_seeds=False)
    np.testing.assert_allclose(a.source_lag, b.source_lag, rtol=0, atol=0)


def test_run_batch_rejects_empty_seed_batch():
    with pytest.raises(ValueError, match="at least one"):
        run_batch(nexmark.q2(parallelism=4), [], duration_s=10,
                  base_spec=ChaosSpec(), n_hosts=4)


def test_sweep_accepts_full_chaos_spec_entries():
    from repro.streams.chaos_sweep import sweep
    res = sweep(nexmark.q2(parallelism=4),
                [ChaosSpec(seed=1), ChaosSpec(seed=2)],
                base_spec=ChaosSpec(), duration_s=30, n_hosts=4)
    assert [s.seed for s in res.summaries] == [1, 2]


def test_jax_parity_scheduled_kill_of_hostless_id():
    """Scheduled kills are unbounded by the host count actually used
    (n_hosts=8 but only 4 tasks → hosts 0-3); a kill of a hostless id
    must be a no-op in both engines, not a crash."""
    spec = ChaosSpec(seed=0, host_kill_at=((2.0, 7),))
    a, _, mb = _run_pair(lambda: nexmark.q2(parallelism=2), 20,
                         n_hosts=8, chaos_spec=spec)
    assert mb.recoveries == []
    assert_metrics_match(a, mb, "hostless kill")
