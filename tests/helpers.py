"""Test helpers: subprocess runner for multi-device (forced host platform)
tests — the main test process must keep seeing 1 CPU device."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_multidevice(code: str, n_devices: int = 8,
                    timeout: int = 900) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def assert_ok(r: subprocess.CompletedProcess):
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


# ----------------------------------------------------------------------
# Tiny hypothesis fallback: when the real library is absent, @given runs
# the test over seeded random draws (enough for the two property tests
# here; install `hypothesis` for real shrinking/edge-case search).
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _FallbackStrategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def tuples(*ss):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in ss))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elem, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 10

            def draw(rng):
                n = int(rng.integers(min_size, hi + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _FallbackStrategies()

    def settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples", 25)
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # NOTE: deliberately no functools.wraps — pytest must see the
            # zero-arg signature, not the original one (whose parameters it
            # would try to resolve as fixtures).
            def wrapper():
                rng = _np.random.default_rng(0)
                n = getattr(wrapper, "_max_examples", 25)
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strats))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
