"""Test helpers: subprocess runner for multi-device (forced host platform)
tests — the main test process must keep seeing 1 CPU device."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_multidevice(code: str, n_devices: int = 8,
                    timeout: int = 900) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def assert_ok(r: subprocess.CompletedProcess):
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
