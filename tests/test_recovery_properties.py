"""Recovery-correctness invariants for the external-system chaos layer:
hot-standby vs passive replication, storage brownouts, MQ outage gates
and region bursts — property tests pinned numpy-vs-jax against the
frozen `reference_engine.py` oracle, plus the FallbackStorage /
LeaderService outage drill."""
from __future__ import annotations

import numpy as np
import pytest

from helpers import given, settings, st
from repro.core.chaos import (ChaosEngine, ChaosSpec, brownout_curve,
                              brownout_factor_at, ckpt_age_curve,
                              timeline_build_count)
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine)
from repro.streams.jax_engine import JaxStreamEngine, run_config_batch
from repro.streams.reference_engine import ReferenceStreamEngine


def _drill_spec(seed: int, peak: float = 6.0) -> ChaosSpec:
    return nexmark.ha_drill_spec(seed=seed, burst_t=20.0,
                                 brownout=(10.0, 50.0, peak),
                                 mq_outage=(55.0, 62.0))


def _run_all(g, spec, fo, ck, duration=90.0, n_hosts=6):
    ref = ReferenceStreamEngine(g, chaos=ChaosEngine(spec), failover=fo,
                                ckpt=ck, n_hosts=n_hosts)
    mr = ref.run(duration)
    eng = StreamEngine(g, chaos=ChaosEngine(spec), failover=fo, ckpt=ck,
                       n_hosts=n_hosts)
    me = eng.run(duration)
    rows = {}
    for pm in ("dense", "compact"):
        jx = JaxStreamEngine(g, chaos=spec, failover=fo, ckpt=ck,
                             n_hosts=n_hosts, phase_mode=pm)
        rows[pm] = jx.run(duration)
    return mr, me, rows


# ----------------------------------------------------------------------
# cross-engine parity under external-system chaos
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode,kw", [
    ("hot_standby", {}),
    ("region", dict(restore_base_s=2.0, replay_rate=0.5,
                    lazyload_stagger_s=0.3)),
    ("single_task", dict(restore_base_s=1.0, replay_rate=1.0)),
])
def test_external_chaos_parity_vs_reference(mode, kw):
    g = nexmark.q12(parallelism=4)
    fo = FailoverConfig(mode=mode, **kw)
    ck = CheckpointConfig(interval_s=8.0, upload_s=2.0)
    mr, me, rows = _run_all(g, _drill_spec(3), fo, ck)
    ref_lag = np.asarray(mr.source_lag)
    scale = max(1.0, float(np.abs(ref_lag).max()))
    assert np.max(np.abs(np.asarray(me.source_lag) - ref_lag)) \
        <= 1e-5 * scale
    assert me.recoveries == mr.recoveries
    assert (mr.ckpt_attempts, mr.ckpt_success) == \
        (me.ckpt_attempts, me.ckpt_success)
    for pm, mj in rows.items():
        assert np.max(np.abs(np.asarray(mj.source_lag) - ref_lag)) \
            <= 1e-5 * scale, pm
    # dense == compact bit-for-bit
    d, c = rows["dense"], rows["compact"]
    np.testing.assert_array_equal(np.asarray(d.source_lag),
                                  np.asarray(c.source_lag))
    for op in d.qps:
        np.testing.assert_allclose(np.asarray(d.qps[op]),
                                   np.asarray(c.qps[op]), rtol=1e-12)


def test_pallas_lowering_matches_compact():
    g = nexmark.q12(parallelism=4)
    fo = FailoverConfig(mode="hot_standby")
    spec = _drill_spec(5)
    out = {}
    for pm in ("compact", "pallas"):
        jx = JaxStreamEngine(g, chaos=spec, failover=fo,
                             ckpt=CheckpointConfig(interval_s=8.0,
                                                   upload_s=2.0),
                             n_hosts=6, phase_mode=pm)
        out[pm] = jx.run(60.0)
    np.testing.assert_array_equal(np.asarray(out["compact"].source_lag),
                                  np.asarray(out["pallas"].source_lag))


# ----------------------------------------------------------------------
# invariant: hot standby never loses emitted records vs passive
# ----------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 40), st.floats(1.5, 10.0))
def test_hot_standby_never_loses_records(seed, peak):
    """Single-task passive recovery drops records routed to dead tasks
    (γ=partial); a hot standby assumes execution instead — same chaos
    draws must never show MORE drops (and never fewer emits) under
    hot_standby."""
    g = nexmark.q2(parallelism=4)
    spec = _drill_spec(seed, peak)
    hot = StreamEngine(g, chaos=ChaosEngine(spec),
                       failover=FailoverConfig(mode="hot_standby"),
                       n_hosts=6).run(60.0)
    passive = StreamEngine(g, chaos=ChaosEngine(spec),
                           failover=FailoverConfig(
                               mode="single_task", restore_base_s=2.0,
                               replay_rate=1.0),
                           n_hosts=6).run(60.0)
    assert hot.dropped == 0.0
    assert hot.dropped <= passive.dropped
    assert hot.emitted >= passive.emitted - 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 40))
def test_hot_standby_downtime_independent_of_ckpt_age(seed):
    """Hot-standby recovery cost is switch + staleness only — recovery
    entries must not grow with checkpoint age or brownout severity."""
    g = nexmark.q2(parallelism=4)
    fo = FailoverConfig(mode="hot_standby", detect_s=0.5,
                        standby_switch_s=0.05, standby_staleness_s=0.5)
    for peak in (1.0, 8.0):
        spec = _drill_spec(seed, peak)
        m = StreamEngine(g, chaos=ChaosEngine(spec), failover=fo,
                         n_hosts=6).run(60.0)
        for r in m.recoveries:
            assert r["mode"] == "hot_standby"
            assert r["downtime"] == pytest.approx(0.5 + 0.05 + 0.5)


# ----------------------------------------------------------------------
# invariant: brownout-stretched checkpoints never ack early
# ----------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 30), st.floats(2.0, 12.0))
def test_brownout_checkpoints_never_ack_early(seed, peak):
    """A brownout multiplies every upload duration, so an attempt that
    succeeds UNDER the brownout must also succeed without it (with the
    same rng draws), and success counts are monotone non-increasing in
    brownout severity."""
    g = nexmark.q2(parallelism=3)
    ck = CheckpointConfig(interval_s=6.0, upload_s=2.0,
                          retry_failed_region=False)
    base = ChaosSpec(seed=seed, storage_slow_prob=0.3,
                     storage_slow_factor=2.5)
    import dataclasses as dc
    succ, attempts = [], []
    for p in (1.0, peak, 2.0 * peak):
        spec = dc.replace(base, brownout_at=(
            () if p == 1.0 else ((0.0, 1e9, p),)))
        m = StreamEngine(g, chaos=ChaosEngine(spec), ckpt=ck,
                         n_hosts=4).run(60.0)
        succ.append(m.ckpt_success)
        attempts.append(m.ckpt_attempts)
    # the attempt schedule is brownout-independent; only success is
    assert attempts[0] == attempts[1] == attempts[2]
    assert succ[0] >= succ[1] >= succ[2]


def test_brownout_curve_matches_scalar_factor():
    ramps = ((5.0, 15.0, 4.0), (10.0, 30.0, 2.0))
    ts = np.linspace(0.0, 35.0, 141)
    curve = brownout_curve(ramps, ts)
    for i, t in enumerate(ts):
        assert curve[i] == brownout_factor_at(ramps, float(t))
    # outside every ramp the factor is exactly 1 (bit-identity contract)
    assert brownout_factor_at(ramps, 35.0) == 1.0


def test_ckpt_age_curve_is_tick_exclusive():
    ts = np.array([0.0, 1.0, 2.0, 3.0])
    ok = np.array([0, 1, 0, 0], np.int16)
    age = ckpt_age_curve(ts, ok, 1)[:, 0]
    # success at tick 1 only lowers the age from tick 2 on
    np.testing.assert_allclose(age, [0.0, 1.0, 1.0, 2.0])


# ----------------------------------------------------------------------
# MQ outage gate: sources emit nothing inside the window
# ----------------------------------------------------------------------
def test_mq_outage_gates_sources_across_engines():
    g = nexmark.q2(parallelism=4)
    spec = ChaosSpec(seed=1, mq_down=((10.0, 20.0),))
    mr = ReferenceStreamEngine(g, chaos=ChaosEngine(spec),
                               n_hosts=4).run(40.0)
    me = StreamEngine(g, chaos=ChaosEngine(spec), n_hosts=4).run(40.0)
    mj = JaxStreamEngine(g, chaos=spec, n_hosts=4,
                         phase_mode="compact").run(40.0)
    no = StreamEngine(g, chaos=ChaosEngine(ChaosSpec(seed=1)),
                      n_hosts=4).run(40.0)
    # 10s of a 40s run gated → emitted drops by exactly that share
    assert me.emitted == pytest.approx(no.emitted * 0.75)
    assert mr.emitted == pytest.approx(me.emitted)
    assert float(np.sum(np.asarray(mj.emitted))) == \
        pytest.approx(me.emitted, rel=1e-9)


def test_region_burst_downs_all_region_hosts():
    g = nexmark.q12(parallelism=4)
    spec = ChaosSpec(seed=2, burst_at=((15.0, 0),))
    fo = FailoverConfig(mode="region")
    me = StreamEngine(g, chaos=ChaosEngine(spec), failover=fo,
                      n_hosts=6).run(40.0)
    assert me.recoveries, "burst must trigger at least one recovery"
    assert all(abs(r["t"] - 15.0) <= 0.5 for r in me.recoveries)
    mj = JaxStreamEngine(g, chaos=spec, failover=fo, n_hosts=6,
                         phase_mode="dense").run(40.0)
    np.testing.assert_allclose(np.asarray(mj.source_lag),
                               np.asarray(me.source_lag), atol=1e-6)


# ----------------------------------------------------------------------
# grid path: config-axis brownouts stay bit-identical to rebuilds and
# timeline_build_count stays flat
# ----------------------------------------------------------------------
def test_config_grid_brownout_matches_rebuild():
    g = nexmark.q2(parallelism=4)
    base = ChaosSpec(seed=7, host_kill_prob_per_s=0.004,
                     storage_slow_prob=0.2, storage_slow_factor=2.0)
    fo = FailoverConfig(mode="region", restore_base_s=2.0,
                        replay_rate=1.0)
    ck = CheckpointConfig(interval_s=8.0, upload_s=2.0)
    bro = ((0.0, 1e9, 5.0),)
    c0 = timeline_build_count()
    rows = run_config_batch(
        g, [{"failover": fo, "ckpt": ck},
            {"failover": fo, "ckpt": ck, "brownout": bro}],
        range(3), base_spec=base, duration_s=60.0, n_hosts=6,
        phase_mode="compact")
    assert timeline_build_count() == c0  # grid refit, zero full rebuilds
    import dataclasses as dc
    heavy = dc.replace(base, brownout_at=bro, seed=base.seed)
    for s in range(3):
        spec = dc.replace(heavy, seed=s)
        jx = JaxStreamEngine(g, chaos=spec, failover=fo, ckpt=ck,
                             n_hosts=6, phase_mode="compact")
        m = jx.run(60.0)
        np.testing.assert_array_equal(
            np.asarray(rows[1].source_lag[s]), np.asarray(m.source_lag))


def test_lazyload_stagger_orders_region_ready_times():
    """Lazy-load restore: a task blocks only until its OWN region is
    restored — later regions pay a strictly larger surcharge."""
    # ds() is forward chains → one region per chain, so region ranks
    # actually differ within the job (q2/q12 all-to-all = one region)
    g = nexmark.ds(parallelism=4)
    spec = ChaosSpec(seed=4, burst_at=((15.0, 1),))
    fo = FailoverConfig(mode="region", lazyload_stagger_s=1.5)
    me = StreamEngine(g, chaos=ChaosEngine(spec), failover=fo,
                      n_hosts=6).run(40.0)
    mj = JaxStreamEngine(g, chaos=spec, failover=fo, n_hosts=6,
                         phase_mode="compact").run(40.0)
    np.testing.assert_allclose(np.asarray(mj.source_lag),
                               np.asarray(me.source_lag), atol=1e-6)
    # per-task ready times inside the engine are staggered by region rank
    eng = StreamEngine(g, chaos=ChaosEngine(spec), failover=fo, n_hosts=6)
    assert float(eng._lazy_extra.max()) > 0.0
    assert float(eng._lazy_extra.min()) == 0.0


# ----------------------------------------------------------------------
# FallbackStorage + LeaderService outage drill
# ----------------------------------------------------------------------
def test_storage_and_leader_outage_drill():
    """The paper's HA drill: HDFS namenode goes dark mid-run — puts land
    on the fallback store, reads fall back, and the leader service keeps
    answering from its HDFS-fallback path without terminating jobs."""
    import tempfile

    from repro.core.backoff import RetryPolicy
    from repro.core.clock import VirtualClock
    from repro.core.ha import LeaderService, ZooKeeperSim
    from repro.ckpt.storage import FallbackStorage, ObjectStoreSim, SimHDFS

    clock = VirtualClock()
    root = tempfile.mkdtemp(prefix="ha_drill_")
    primary = SimHDFS(root + "/primary", clock=clock)
    fallback = ObjectStoreSim(root + "/fallback", clock=clock)
    store = FallbackStorage(primary, fallback, clock=clock,
                            policy=RetryPolicy(base_delay_s=0.01,
                                               max_attempts=2))
    store.put("pre", b"pre-outage")
    primary.available = False          # namenode outage
    store.put("during", b"written-during-outage")
    assert store.fallback_puts == 1
    assert store.get("during") == b"written-during-outage"
    primary.available = True           # namenode back
    assert store.get("pre") == b"pre-outage"

    # leader metadata: ZK quorum lost mid-window → HDFS fallback read,
    # no job termination (the paper's dual-store HA semantics)
    zk = ZooKeeperSim(clock=clock,
                      chaos=ChaosEngine(ChaosSpec(
                          zk_down=((clock.now() + 1.0,
                                    clock.now() + 100.0),))))
    svc = LeaderService(zk, store, clock=clock)
    svc.elect("jm-host-7")
    clock.sleep(5.0)                   # step into the outage window
    rec = svc.get_leader()
    assert rec.leader_id == "jm-host-7"
    assert svc.fallback_reads == 1
    assert svc.terminations == 0
