"""Parity pins for the vectorized hot path.

* StreamEngine (routing-plan arena) vs the preserved per-edge reference
  interpreter: identical EngineMetrics (1e-6) across every partitioner,
  failover mode and the checkpoint coordinator.
* weakhash_assign: vectorized water-fill vs the sequential greedy — exact
  per-task counts (hence exact load_cv) for integer-valued loads.
* Fused single-pass weakhash_route kernel vs the jnp oracle across tile
  counts (nt = 1, 2, 4) in interpret mode.
"""
import numpy as np
import pytest

from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.weakhash import candidate_group, load_cv, weakhash_assign
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine)
from repro.streams.graph import LogicalEdge, LogicalGraph, LogicalOp
from repro.streams.reference_engine import ReferenceStreamEngine


# ----------------------------------------------------------------------
# engine parity
# ----------------------------------------------------------------------
def _assert_metrics_equal(ref_eng, vec_eng, label=""):
    ma, mb = ref_eng.metrics, vec_eng.metrics
    for n in ref_eng.g.topo_order():
        np.testing.assert_allclose(np.array(ma.qps[n]), mb.qps[n],
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"{label} qps[{n}]")
        np.testing.assert_allclose(np.array(ma.backlog[n]), mb.backlog[n],
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"{label} backlog[{n}]")
    np.testing.assert_allclose(np.array(ma.t), mb.t, atol=0)
    np.testing.assert_allclose(np.array(ma.source_lag), mb.source_lag,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ma.dropped, mb.dropped, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ma.emitted, mb.emitted, rtol=1e-6)
    assert (ma.ckpt_attempts, ma.ckpt_success, ma.ckpt_failed) == \
        (mb.ckpt_attempts, mb.ckpt_success, mb.ckpt_failed), label
    assert ma.recoveries == mb.recoveries, label


def _run_pair(make_graph, duration, **kw):
    def mk(cls):
        kw2 = dict(kw)
        if "chaos_spec" in kw2:
            kw2["chaos"] = ChaosEngine(kw2.pop("chaos_spec"))
        return cls(make_graph(), **kw2)
    a = mk(ReferenceStreamEngine)
    a.run(duration)
    b = mk(StreamEngine)
    b.run(duration)
    return a, b


@pytest.mark.parametrize("partitioner", ["rebalance", "hash", "weakhash",
                                         "backlog", "group_rescale"])
def test_engine_parity_partitioners(partitioner):
    slow = {t: 1e-3 for t in range(16, 32, 5)}  # stragglers → congestion
    a, b = _run_pair(
        lambda: nexmark.q2(parallelism=16, partitioner=partitioner,
                           n_groups=4),
        60, n_hosts=16, task_speed_override=slow, seed=3)
    _assert_metrics_equal(a, b, partitioner)


def test_engine_parity_mixed_graph():
    """All adaptive partitioners chained in one graph."""
    def g():
        par, sr = 20, 1.5e5
        return LogicalGraph(
            "mixed",
            ops=(LogicalOp("source", par, sr, is_source=True,
                           source_rate=0.8e6),
                 LogicalOp("keyed", par, sr, selectivity=0.9),
                 LogicalOp("agg", par, sr, selectivity=0.5),
                 LogicalOp("writer", par, sr),
                 LogicalOp("sink", par, sr)),
            edges=(LogicalEdge("source", "keyed", "hash", key_skew_zipf=0.8),
                   LogicalEdge("keyed", "agg", "weakhash", n_groups=4),
                   LogicalEdge("agg", "writer", "backlog"),
                   LogicalEdge("writer", "sink", "group_rescale",
                               n_groups=4)))
    a, b = _run_pair(g, 120)
    _assert_metrics_equal(a, b, "mixed")


@pytest.mark.parametrize("mode", ["region", "single_task"])
def test_engine_parity_host_kill(mode):
    a, b = _run_pair(
        lambda: nexmark.ss(parallelism=8), 300, n_hosts=8,
        chaos_spec=ChaosSpec(seed=0, host_kill_at=((100.0, 2),)),
        failover=FailoverConfig(mode=mode, region_restart_s=60.0))
    _assert_metrics_equal(a, b, mode)
    assert len(b.metrics.recoveries) == 1


def test_engine_parity_checkpoints():
    for cm in ("region", "global"):
        a, b = _run_pair(
            lambda: nexmark.ds(parallelism=6), 400, n_hosts=6,
            chaos_spec=ChaosSpec(seed=2, storage_slow_prob=0.3,
                                 storage_slow_factor=10),
            ckpt=CheckpointConfig(interval_s=30, mode=cm))
        assert b.metrics.ckpt_attempts > 0
        _assert_metrics_equal(a, b, cm)


# ----------------------------------------------------------------------
# weakhash_assign parity
# ----------------------------------------------------------------------
def test_weakhash_assign_counts_match_sequential():
    """Vectorized water-fill reproduces the sequential greedy's per-task
    counts exactly (integer starting loads) — load_cv parity is exact."""
    rng = np.random.default_rng(0)
    for trial in range(40):
        n_groups = int(rng.integers(1, 9))
        gsz = int(rng.integers(1, 7))
        n_tasks = n_groups * gsz
        keys = rng.integers(0, 1 << 20, int(rng.integers(0, 400)))
        loads = (rng.integers(0, 50, n_tasks).astype(np.float64)
                 if trial % 2 else None)
        a = weakhash_assign(keys, n_tasks, n_groups, loads=loads,
                            sequential=True)
        b = weakhash_assign(keys, n_tasks, n_groups, loads=loads)
        assert np.array_equal(np.bincount(a, minlength=n_tasks),
                              np.bincount(b, minlength=n_tasks)), trial
        assert load_cv(a, n_tasks) == load_cv(b, n_tasks)
        # bounded candidate set is preserved
        assert np.array_equal(b // gsz, candidate_group(keys, n_groups))


def test_weakhash_assign_float_loads_cv_parity():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 1 << 20, 3000)
    loads = rng.uniform(0.0, 30.0, 32)
    a = weakhash_assign(keys, 32, 8, loads=loads, sequential=True)
    b = weakhash_assign(keys, 32, 8, loads=loads)
    assert abs(load_cv(a, 32) - load_cv(b, 32)) < 1e-9


# ----------------------------------------------------------------------
# fused kernel parity (interpret mode)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("block_t", [512, 256, 128])  # nt = 1, 2, 4
def test_fused_kernel_parity_tilings(block_t):
    import jax.numpy as jnp
    from repro.kernels.weakhash_route import kernel as K, ref as R
    rng = np.random.default_rng(7)
    T, E, k = 512, 32, 2
    logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 10_000, T), jnp.int32)
    cap = 4 * T // E
    idx, _, gid, demand = K.weakhash_route_ints(
        logits, top_k=k, capacity=cap, n_groups=8, mode="weakhash",
        token_keys=keys, block_t=block_t, interpret=True)
    rr = R.weakhash_route(logits, top_k=k, capacity=cap, n_groups=8,
                          mode="weakhash", token_keys=keys)
    assert bool(jnp.all(idx == rr.expert_idx))
    assert bool(jnp.all(gid == rr.group_id))
    rk = K.weakhash_route(logits, top_k=k, capacity=cap, n_groups=8,
                          mode="weakhash", token_keys=keys, interpret=True)
    assert bool(jnp.all(rk.position == rr.position))
    assert bool(jnp.all(rk.keep == rr.keep))
