"""Sharded (config × seed) sweeps, device-free checkpoint-grid timeline
refits, and per-job ChaosSpec lists (ISSUE 5 tentpole parts 2–3 +
satellite).

Pillars:

* **Grid timelines == per-(config, seed) replays, bit-for-bit** —
  `core.chaos.build_grid_timelines` materializes the chaos draw streams
  once per seed and refits every config's checkpoint attempt schedule
  by offset indexing; kills, attempt/success tensors, stragglers and
  recovery events equal `build_chaos_timeline` exactly while
  `timeline_build_count()` stays flat.
* **Sharded config grids == single-device, bit-for-bit** — the flat
  seed axis of `run_config_batch(devices=N)` splits across forced host
  devices (subprocess, `repro.dist.sharding.sharded_grid_fn`).
* **Per-job chaos** — `chaos=` spec lists draw per job in the job's
  local host domain, lifted through the host map: disjoint-host packing
  equals K independent runs in BOTH engines, and the jax twin stays
  pinned to numpy on a shared pool.
"""
import dataclasses

import numpy as np
import pytest

from helpers import assert_ok, run_multidevice
from repro.core import chaos as chaos_mod
from repro.core.chaos import (ChaosEngine, ChaosSpec,
                              build_chaos_timeline, build_grid_timelines,
                              timeline_build_count)
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine, pack_arena)
from repro.streams.jax_engine import (JaxStreamEngine, run_batch,
                                      run_config_batch)

TOL = dict(rtol=1e-12, atol=1e-9)


# ----------------------------------------------------------------------
# vectorized checkpoint-grid timelines
# ----------------------------------------------------------------------
def _placement(n_tasks=24, n_hosts=8, region_size=6):
    task_host = np.arange(n_tasks) % n_hosts
    task_region = np.arange(n_tasks) // region_size
    regions = [set(np.nonzero(task_region == r)[0].tolist())
               for r in range(n_tasks // region_size)]
    return task_host, task_region, regions


def test_grid_timelines_bit_identical():
    """The crown-jewel pin: every (config, seed) cell of the batched
    builder equals a standalone host replay bit-for-bit, across mixed
    region/global modes, retry on/off, interval grids, a ckpt-free row,
    scheduled + Poisson kills, stragglers, and single_task failover."""
    task_host, task_region, regions = _placement()
    T, dt, n_hosts = 300, 0.5, 8
    specs = [ChaosSpec(seed=s, host_kill_prob_per_s=0.004,
                       straggler_frac=0.3, storage_slow_prob=0.3,
                       storage_slow_factor=12,
                       host_kill_at=((30.0, 2),)) for s in range(5)]
    # draw-free storage seed: retries of kill-downed regions consume NO
    # draws (the `not probs[s]` branch) yet still decide the attempt
    specs.append(ChaosSpec(seed=7, host_kill_prob_per_s=0.02,
                           straggler_frac=0.3, storage_slow_prob=0.0))
    cfgs = [dict(failover_mode="region", detect_s=1.0,
                 region_restart_s=25.0, single_restart_s=3.0,
                 ckpt_interval_s=iv, ckpt_mode=mode, ckpt_upload_s=up,
                 ckpt_retry=retry)
            for (iv, mode, up, retry) in
            [(20.0, "region", 4.0, True), (45.0, "region", 4.0, False),
             (10.0, "global", 4.0, True), (None, "region", 4.0, True),
             (30.0, "region", 6.0, True)]]
    cfgs.append(dict(failover_mode="single_task", detect_s=2.0,
                     region_restart_s=25.0, single_restart_s=4.0,
                     ckpt_interval_s=35.0, ckpt_mode="region",
                     ckpt_upload_s=4.0, ckpt_retry=True))
    # retry stream-offset corner branches: upload > interval (every
    # retry fails on its FIRST draw — one-draw short-circuit) and
    # upload*slow_factor <= interval (every retry draw passes — full
    # region consumed); an off-by-one in either desynchronizes all
    # later kill/storage draws for the seed
    cfgs.append(dict(failover_mode="region", detect_s=1.0,
                     region_restart_s=25.0, single_restart_s=3.0,
                     ckpt_interval_s=3.0, ckpt_mode="region",
                     ckpt_upload_s=5.0, ckpt_retry=True))
    cfgs.append(dict(failover_mode="region", detect_s=1.0,
                     region_restart_s=25.0, single_restart_s=3.0,
                     ckpt_interval_s=60.0, ckpt_mode="region",
                     ckpt_upload_s=1.0, ckpt_retry=True))
    n0 = timeline_build_count()
    grid = build_grid_timelines(specs, cfgs, n_ticks=T, dt=dt,
                                n_hosts=n_hosts, task_host=task_host,
                                task_region=task_region, regions=regions)
    assert timeline_build_count() == n0   # zero per-(c,s) host replays
    for c, cfg in enumerate(cfgs):
        for s, sp in enumerate(specs):
            ref = build_chaos_timeline(sp, n_ticks=T, dt=dt,
                                       n_hosts=n_hosts,
                                       task_host=task_host,
                                       task_region=task_region,
                                       regions=regions, **cfg)
            tl = grid[c][s]
            np.testing.assert_array_equal(tl.kills, ref.kills,
                                          err_msg=f"kills c{c} s{s}")
            np.testing.assert_array_equal(tl.ckpt_at, ref.ckpt_at)
            np.testing.assert_array_equal(tl.ckpt_ok, ref.ckpt_ok,
                                          err_msg=f"ckpt_ok c{c} s{s}")
            np.testing.assert_array_equal(tl.task_speed, ref.task_speed)
            assert (tl.ckpt_attempts, tl.ckpt_success, tl.ckpt_failed) \
                == (ref.ckpt_attempts, ref.ckpt_success,
                    ref.ckpt_failed), (c, s)
            assert tl.recoveries == ref.recoveries, (c, s)


def test_ckpt_grid_sweep_zero_host_rebuilds():
    """run_config_batch on a checkpoint-interval grid consumes ZERO
    per-(config, seed) host timeline replays — and its rows still equal
    standalone engines (which DO replay) at 1e-12."""
    grid = [(FailoverConfig(mode="region", region_restart_s=15.0),
             CheckpointConfig(interval_s=iv, mode="region"))
            for iv in (20.0, 35.0, 50.0)]
    spec = ChaosSpec(host_kill_prob_per_s=0.002, storage_slow_prob=0.3,
                     storage_slow_factor=12)
    n0 = timeline_build_count()
    out = run_config_batch(nexmark.ds(parallelism=6), grid, range(4),
                           base_spec=spec, duration_s=150, n_hosts=6)
    assert timeline_build_count() == n0
    assert chaos_mod._TIMELINE_STATS["grid_replays"] > 0
    for c, (fo, ck) in enumerate(grid):
        m = JaxStreamEngine(
            nexmark.ds(parallelism=6), n_hosts=6,
            chaos=ChaosSpec(host_kill_prob_per_s=0.002,
                            storage_slow_prob=0.3,
                            storage_slow_factor=12, seed=2),
            failover=fo, ckpt=ck).run(150)
        np.testing.assert_allclose(out[c].source_lag[2], m.source_lag,
                                   err_msg=f"cfg{c}", **TOL)
        assert int(out[c].ckpt_attempts[2]) == m.ckpt_attempts
        assert int(out[c].ckpt_success[2]) == m.ckpt_success


def test_grid_falls_back_for_perjob_ckpt_rows():
    """Per-job coordinator lists stay on the per-config rebuild path
    (their draw interleavings are job-scoped) — and still match
    standalone runs."""
    arena = pack_arena([nexmark.q2(parallelism=8),
                        nexmark.q12(parallelism=8)], "shared", n_hosts=8)
    cks = [CheckpointConfig(interval_s=20.0), CheckpointConfig(
        interval_s=35.0)]
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    spec = ChaosSpec(host_kill_prob_per_s=0.002, storage_slow_prob=0.2)
    n0 = timeline_build_count()
    out = run_config_batch(arena, [{"failover": fo, "ckpt": cks}],
                           [0, 1], base_spec=spec, duration_s=100)
    assert timeline_build_count() > n0     # fallback path exercised
    m = JaxStreamEngine(arena, chaos=dataclasses.replace(spec, seed=1),
                        failover=fo, ckpt=cks).run(100)
    np.testing.assert_allclose(out[0].source_lag[1], m.source_lag, **TOL)


# ----------------------------------------------------------------------
# sharded config grids (subprocess with forced host devices)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_config_grid_bit_identical():
    code = """
import numpy as np
from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import CheckpointConfig, FailoverConfig
from repro.streams.chaos_sweep import sweep_configs
from repro.streams.jax_engine import run_config_batch

g = nexmark.q2(parallelism=8, partitioner="weakhash", n_groups=4)
spec = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
grid = [FailoverConfig(mode="region", region_restart_s=r)
        for r in (10.0, 40.0)]
one = run_config_batch(g, grid, range(6), base_spec=spec, duration_s=60)
four = run_config_batch(g, grid, range(6), base_spec=spec, duration_s=60,
                        devices=4)
for c in range(2):
    np.testing.assert_array_equal(np.asarray(one[c].source_lag),
                                  np.asarray(four[c].source_lag))
    np.testing.assert_array_equal(np.asarray(one[c].qps),
                                  np.asarray(four[c].qps))

# ckpt-bearing grid: per-config kill tensors split on the seed axis
grid2 = [(FailoverConfig(mode="region", region_restart_s=15.0),
          CheckpointConfig(interval_s=iv, mode="region"))
         for iv in (20.0, 45.0)]
spec2 = ChaosSpec(host_kill_prob_per_s=0.002, storage_slow_prob=0.3,
                  storage_slow_factor=12)
one = run_config_batch(nexmark.ds(parallelism=6), grid2, range(5),
                       base_spec=spec2, duration_s=100, n_hosts=6)
four = run_config_batch(nexmark.ds(parallelism=6), grid2, range(5),
                        base_spec=spec2, duration_s=100, n_hosts=6,
                        devices=4)
for c in range(2):
    np.testing.assert_array_equal(np.asarray(one[c].source_lag),
                                  np.asarray(four[c].source_lag))

res = sweep_configs(g, grid, range(8), base_spec=spec, duration_s=60,
                    devices=2)
assert res.recovery_surface.shape == (2, 8)
print("sharded grid ok")
"""
    assert_ok(run_multidevice(code, 4))


def test_devices_reject_mixes():
    with pytest.raises(ValueError, match="devices"):
        run_config_batch(nexmark.q2(parallelism=4),
                         [FailoverConfig()], [0], duration_s=10,
                         base_spec=ChaosSpec(), mixes=[[1.0]], devices=2)


# ----------------------------------------------------------------------
# per-job ChaosSpec lists
# ----------------------------------------------------------------------
def _perjob_setup():
    graphs = [nexmark.q2(parallelism=8, partitioner="weakhash",
                         n_groups=4), nexmark.q12(parallelism=8)]
    specs = [ChaosSpec(seed=11, host_kill_prob_per_s=0.01,
                       straggler_frac=0.3),
             ChaosSpec(seed=22, host_kill_prob_per_s=0.002,
                       straggler_frac=0.05, storage_slow_prob=0.3,
                       storage_slow_factor=12)]
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    ck = CheckpointConfig(interval_s=25.0, mode="region")
    return graphs, specs, fo, ck


def test_perjob_chaos_disjoint_equals_independent():
    """Disjoint-host packing with per-job ChaosSpecs == K independent
    runs, each under its own spec: per-job chaos draws in the job's
    LOCAL host domain, so the packed streams replicate the solo ones."""
    graphs, specs, fo, ck = _perjob_setup()
    arena = pack_arena(graphs, "disjoint", n_hosts=8)
    a = StreamEngine(arena, chaos=[ChaosEngine(s) for s in specs],
                     failover=fo, ckpt=ck)
    a.run(120)
    assert len(a.metrics.recoveries) > 0
    for j, g in enumerate(graphs):
        solo = StreamEngine(g, n_hosts=8, chaos=ChaosEngine(specs[j]),
                            failover=fo, ckpt=ck)
        solo.run(120)
        pre = arena.jobs[j].prefix
        for name in g.topo_order():
            np.testing.assert_allclose(
                a.metrics.backlog[pre + name], solo.metrics.backlog[name],
                rtol=1e-9, atol=1e-9, err_msg=f"{j}/{name}")
        assert a.metrics.ckpt_by_job[j, 0] == solo.metrics.ckpt_attempts
        mine = [dict(r) for r in a.metrics.recoveries
                if r.get("job") == j]
        for r in mine:
            r.pop("job")
        assert mine == solo.metrics.recoveries, j


def test_perjob_chaos_jax_numpy_parity_shared_pool():
    """Shared pool: per-job kill processes couple co-located jobs (a
    lifted kill downs every job on the host), and the jax twin's
    pregenerated per-job timeline stays pinned to the live engine."""
    graphs, specs, fo, ck = _perjob_setup()
    arena = pack_arena(graphs, "shared", n_hosts=8)
    a = StreamEngine(arena, chaos=[ChaosEngine(s) for s in specs],
                     failover=fo, ckpt=ck)
    a.run(120)
    mj = JaxStreamEngine(arena, chaos=specs, failover=fo, ckpt=ck).run(
        120)
    for name in arena.graph.topo_order():
        np.testing.assert_allclose(np.array(a.metrics.backlog[name]),
                                   mj.backlog[name], rtol=1e-5,
                                   atol=1e-5, err_msg=name)
    np.testing.assert_allclose(np.array(a.metrics.source_lag),
                               mj.source_lag, rtol=1e-5, atol=1e-5)
    assert a.metrics.recoveries == mj.recoveries
    np.testing.assert_array_equal(a.metrics.ckpt_by_job, mj.ckpt_by_job)
    # both jobs saw kills from their own processes
    jobs_hit = {r["job"] for r in mj.recoveries}
    assert jobs_hit == {0, 1}


def test_perjob_chaos_batch_rows_match_standalone():
    """run_batch with a per-job base_spec list: row s == a standalone
    run whose job-j spec is reseeded ``perjob_sweep_seed(base[j].seed,
    s, j)`` (the documented collision-free decorrelation mix)."""
    from repro.streams.jax_engine import perjob_sweep_seed
    graphs, specs, fo, _ = _perjob_setup()
    arena = pack_arena(graphs, "shared", n_hosts=8)
    bm = run_batch(arena, range(3), base_spec=specs, duration_s=60,
                   failover=fo)
    for s in range(3):
        per = [dataclasses.replace(b, seed=perjob_sweep_seed(b.seed, s,
                                                             j))
               for j, b in enumerate(specs)]
        m = JaxStreamEngine(arena, chaos=per, failover=fo).run(60)
        np.testing.assert_allclose(bm.source_lag[s], m.source_lag,
                                   err_msg=f"seed{s}", **TOL)


def test_perjob_chaos_list_rejected_without_arena():
    with pytest.raises(ValueError, match="per-job chaos"):
        StreamEngine(nexmark.q2(parallelism=4), n_hosts=4,
                     chaos=[ChaosEngine(), ChaosEngine()])
    with pytest.raises(ValueError, match="per-job chaos"):
        JaxStreamEngine(nexmark.q2(parallelism=4), n_hosts=4,
                        chaos=[ChaosSpec(), ChaosSpec()]).run(10)
