"""Stream engine + cluster sim: region derivation, backlog shuffle vs
stragglers (Fig 6), region checkpointing success (Fig 8), single-task
recovery QPS (Fig 9), startup phases (Table II / Fig 5), scheduler HA."""
import numpy as np
import pytest

from repro.cluster.scheduler import GodelSim, ResilientSubmitter
from repro.cluster.simulator import ClusterSim, nexmark_edges
from repro.core.backoff import RetryPolicy
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import VirtualClock
from repro.core.startup import StartupConfig, intern_plan
from repro.core.weakhash import load_cv, strong_hash, weakhash_assign
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine)
from repro.streams.graph import expand


# ----------------------------------------------------------------------
# graph / regions
# ----------------------------------------------------------------------
def test_region_derivation_forward_chains():
    g = nexmark.ds(parallelism=6)
    phys = expand(g, n_hosts=6)
    assert len(phys.regions) == 6, "forward chains → one region per chain"


def test_region_derivation_all_to_all_merges():
    g = nexmark.ss(parallelism=4)
    phys = expand(g, n_hosts=4)
    assert len(phys.regions) == 1, "keyed join merges everything"


# ----------------------------------------------------------------------
# Fig 6: backlog shuffle under stragglers
# ----------------------------------------------------------------------
def _q2_throughput(partitioner, seed=0):
    g = nexmark.q2(parallelism=16, source_rate=1e6, service_rate=1.5e5,
                   partitioner=partitioner)
    # 10% of filter tasks are delayed 1000× per record (paper setup)
    overrides = {}
    phys_tasks = 16
    slow = set(range(0, phys_tasks * 2)[16::10])  # every 10th filter task
    eng = StreamEngine(g, n_hosts=16, seed=seed,
                       task_speed_override={t: 1e-3 for t in slow})
    m = eng.run(120)
    return np.mean(m.qps["filter"][40:])


def test_backlog_shuffle_beats_rebalance_under_skew():
    base = _q2_throughput("rebalance")
    shuffled = _q2_throughput("backlog")
    assert shuffled > 3 * base, (base, shuffled)


def test_weakhash_diffuses_hot_keys():
    rng = np.random.default_rng(0)
    keys = rng.zipf(1.2, 20_000) % 4096
    cv_strong = load_cv(strong_hash(keys, 32), 32)
    cv_weak = load_cv(weakhash_assign(keys, 32, 8), 32)
    assert cv_weak < 0.5 * cv_strong, (cv_strong, cv_weak)


def test_weakhash_candidates_bounded():
    keys = np.arange(10_000)
    n_tasks, n_groups = 32, 8
    assign = weakhash_assign(keys, n_tasks, n_groups)
    from repro.core.weakhash import candidate_group
    grp = candidate_group(keys, n_groups)
    gsz = n_tasks // n_groups
    assert np.all(assign // gsz == grp), \
        "every record stays inside its bounded candidate group"


# ----------------------------------------------------------------------
# Fig 8: checkpoint success rates
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode,lo,hi", [("global", 0.40, 0.65),
                                        ("region", 0.88, 1.0)])
def test_checkpoint_success_rates(mode, lo, hi):
    chaos = ChaosEngine(ChaosSpec(seed=2, storage_slow_prob=0.05,
                                  storage_slow_factor=10))
    eng = StreamEngine(nexmark.ds(parallelism=6), n_hosts=6, chaos=chaos,
                       ckpt=CheckpointConfig(interval_s=30, mode=mode))
    m = eng.run(43_200)  # the paper's 12 h
    rate = m.ckpt_success / m.ckpt_attempts
    assert lo <= rate <= hi, (mode, rate)


# ----------------------------------------------------------------------
# Fig 9: single-task recovery on the SS join
# ----------------------------------------------------------------------
def _ss_qps(mode):
    chaos = ChaosEngine(ChaosSpec(seed=0, host_kill_at=((300.0, 2),)))
    eng = StreamEngine(nexmark.ss(parallelism=8), n_hosts=8, chaos=chaos,
                       failover=FailoverConfig(mode=mode,
                                               region_restart_s=120.0,
                                               single_restart_s=3.0))
    m = eng.run(900)
    q = np.array(m.qps["join"])
    t = np.array(m.t)
    return t, q, m


def test_fig9_single_task_vs_region_failover():
    t, q_region, _ = _ss_qps("region")
    after = (t > 305) & (t < 400)
    assert q_region[after].min() == 0.0, "region failover zeroes the join"
    t, q_str, m = _ss_qps("single_task")
    steady = np.mean(q_str[(t > 100) & (t < 295)])
    dip = q_str[(t > 305) & (t < 400)].min()
    assert dip > 0.5 * steady, "STR keeps the join flowing"
    assert m.dropped > 0, "γ=partial: records to the dead task are dropped"
    assert m.dropped / max(m.emitted, 1) < 0.05, "loss stays minor"


# ----------------------------------------------------------------------
# Table II / Fig 5: startup phases
# ----------------------------------------------------------------------
def test_startup_phases_scale_and_improve():
    res = {}
    for n in (512, 2048):
        sim_b = ClusterSim(n, seed=1)
        sim_s = ClusterSim(n, seed=1)
        edges = nexmark_edges(64)
        base = sim_b.startup(edges, StartupConfig.baseline())
        ss = sim_s.startup(edges, StartupConfig())
        res[n] = (base, ss)
        assert ss.alloc_ms < base.alloc_ms
        assert ss.deploy_ms < base.deploy_ms
    base512, ss512 = res[512]
    base2048, ss2048 = res[2048]
    assert base2048.alloc_ms > base512.alloc_ms, "alloc grows with scale"
    assert base2048.alloc_ms > 0.5 * (base2048.parse_ms + base2048.deploy_ms), \
        "allocation dominates startup (paper's headline observation)"
    # parse: interning pays off at scale (Fig 5: SS slower at 512, faster later)
    assert ss2048.parse_ms < base2048.parse_ms


def test_hotupdate_skips_allocation():
    sim = ClusterSim(512, seed=1)
    ph = sim.startup(nexmark_edges(32),
                     StartupConfig(hotupdate=True))
    assert ph.alloc_ms == 0.0


def test_plan_interning_dedups():
    edges = nexmark_edges(64)
    plan = intern_plan(edges)
    assert plan.n_unique < plan.n_edges / 10
    assert plan.serialized_bytes < plan.baseline_bytes / 5


# ----------------------------------------------------------------------
# scheduler: backoff + idempotent resubmission through an outage
# ----------------------------------------------------------------------
def test_scheduler_retry_through_outage():
    clock = VirtualClock()
    godel = GodelSim(clock=clock, down_windows=((0.0, 5.0),))
    sub = ResilientSubmitter(godel, policy=RetryPolicy(base_delay_s=1.0,
                                                       jitter=0.0,
                                                       max_attempts=8))
    rec, info = sub.submit({"job_id": "j1", "n_tms": 4})
    assert info["attempts"] > 1 and rec.job_id == "j1"
    # resubmission of the same job is de-duplicated end to end
    rec2, info2 = sub.submit({"job_id": "j1", "n_tms": 4})
    assert info2["duplicate"] and godel.submissions["j1"] is rec2


# ----------------------------------------------------------------------
# nexmark operator kernels vs numpy oracles
# ----------------------------------------------------------------------
def test_q2_filter_oracle():
    bids = nexmark.gen_bids(5000, seed=1)
    mask = np.asarray(nexmark.q2_filter(bids))
    expect = (np.asarray(bids["auction"]) % 123) == 0
    assert np.array_equal(mask, expect)


def test_q12_window_counts_oracle():
    bids = nexmark.gen_bids(2000, seed=2)
    counts = np.asarray(nexmark.q12_window_counts(bids, 10.0, 5000))
    ts, bidder = np.asarray(bids["ts"]), np.asarray(bids["bidder"])
    for w, b in [(0, int(bidder[0])), (3, 17)]:
        expect = int(((ts // 10).astype(int) == w).astype(int)
                     @ (bidder == b).astype(int))
        assert counts[w, b] == expect
    assert counts.sum() == 2000


def test_ss_join_oracle():
    rng = np.random.default_rng(3)
    fk = rng.integers(0, 50, 200)
    lk = rng.integers(0, 80, 100)
    fv = rng.normal(size=(200, 4)).astype(np.float32)
    lv = rng.normal(size=(100, 2)).astype(np.float32)
    import jax.numpy as jnp
    joined, hit = nexmark.ss_join(jnp.asarray(fk), jnp.asarray(fv),
                                  jnp.asarray(lk), jnp.asarray(lv))
    hit = np.asarray(hit)
    assert np.array_equal(hit, np.isin(lk, fk))
    assert joined.shape == (100, 6)
