"""Nexmark failure scenarios (paper Table III workloads under chaos).

Q2 and Q12 run through BOTH engines (numpy `StreamEngine` and the JAX
twin) for a short horizon with one injected host kill; the assertions
pin actual *recovery*, not just survival:

* Q2 + weakhash + single-task failover — the live sources keep pushing
  into the degraded candidate group, so backlog visibly piles up and
  must drain after the task restarts; source lag (retained backlog —
  sources never re-emit in this sim, so it is monotone) must plateau.
* Q12 + region failover — the all-to-all hash hop makes the whole graph
  one region, so the kill silences the job; recovery means window qps
  returns to the pre-kill steady state and queues stay drained.

Seeds the "larger Nexmark scenarios" ROADMAP item.
"""
import numpy as np
import pytest

from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import FailoverConfig, StreamEngine
from repro.streams.jax_engine import JaxStreamEngine

KILL = ChaosSpec(seed=0, host_kill_at=((60.0, 1),))


def _run_both(graph_fn, fo, duration=240.0):
    a = StreamEngine(graph_fn(), n_hosts=8, chaos=ChaosEngine(KILL),
                     failover=fo)
    ma = a.run(duration)
    mb = JaxStreamEngine(graph_fn(), n_hosts=8, chaos=KILL,
                         failover=fo).run(duration)
    # engines agree on the whole scenario (1e-5, full run)
    for n in a.g.topo_order():
        np.testing.assert_allclose(np.array(ma.backlog[n]), mb.backlog[n],
                                   rtol=1e-5, atol=1e-5, err_msg=n)
        np.testing.assert_allclose(np.array(ma.qps[n]), mb.qps[n],
                                   rtol=1e-5, atol=1e-5, err_msg=n)
    np.testing.assert_allclose(np.array(ma.source_lag), mb.source_lag,
                               rtol=1e-5, atol=1e-5)
    assert ma.recoveries == mb.recoveries
    return a, ma, mb


def test_q2_single_task_kill_backlog_drains():
    fo = FailoverConfig(mode="single_task", single_restart_s=20.0)
    a, ma, mb = _run_both(
        lambda: nexmark.q2(parallelism=8, partitioner="weakhash",
                           n_groups=4, service_rate=1.1e5), fo)
    assert len(mb.recoveries) == 1
    ts = np.array(ma.t)
    lag = np.array(ma.source_lag)
    bk = np.array(ma.backlog["filter"])
    pre = (ts > 30) & (ts < 60)
    steady_bk = float(np.median(bk[pre]))
    # the kill visibly backs the group up ...
    outage_peak = float(bk[(ts >= 60) & (ts <= 90)].max())
    assert outage_peak > 10 * steady_bk + 1e4
    lag_outage = lag[ts.searchsorted(100)] - lag[ts.searchsorted(59)]
    assert lag_outage > 1e5
    # ... backlog drains once the task is back ...
    assert bk[ts > 200].max() <= 1.5 * steady_bk + 1e3
    # ... and retained source lag returns below threshold (plateaus):
    # post-recovery growth under 5% of the outage growth
    lag_tail = lag[-1] - lag[ts.searchsorted(200)]
    assert lag_tail <= 0.05 * lag_outage


def test_q12_region_kill_qps_recovers():
    fo = FailoverConfig(mode="region", region_restart_s=10.0)
    a, ma, mb = _run_both(
        lambda: nexmark.q12(parallelism=8, service_rate=2.4e5), fo)
    assert len(mb.recoveries) == 1
    rec = mb.recoveries[0]
    assert rec["t"] == pytest.approx(60.0, abs=0.5)
    ts = np.array(ma.t)
    q = np.array(ma.qps["window_count"])
    steady = float(np.median(q[(ts > 30) & (ts < 60)]))
    assert steady > 0
    down_end = rec["t"] + rec["downtime"]
    # the region kill silences the window operator ...
    assert q[(ts > rec["t"] + 2) & (ts < down_end - 1)].max() == 0.0
    # ... and qps returns to the steady state after restart
    tail = q[ts > down_end + 30]
    assert tail.min() >= 0.95 * steady
    # queues stay drained: backlog and lag back below (pre-kill) threshold
    for n in ("window_count", "sink"):
        assert np.array(ma.backlog[n])[ts > down_end + 30].max() <= \
            np.array(ma.backlog[n])[(ts > 30) & (ts < 60)].max() + 1e-6
    lag = np.array(ma.source_lag)
    assert lag[ts > down_end + 30].max() <= lag[(ts > 30) & (ts < 60)].max() \
        + 1e-6
