"""Fused Pallas tick-phase lowering (ISSUE 6 tentpole).

Pillars:

* **Three-way parity at 1e-12** — the fused-kernel pallas mode
  (`repro.kernels.tick_phase` + `jax_engine._build_pallas_run`)
  reproduces BOTH the dense arena-wide tick and the compact row-table
  tick over every partitioner family, kill-heavy seeds that empty
  whole phases, and a 2k-task deep-pipeline mega-arena.
* **Interpret == ref** — the actual Pallas kernel run through the
  interpreter (`REPRO_KERNEL_IMPL=interpret`, the CPU-CI stand-in for
  the compiled TPU kernel) agrees with the jnp reference lowering on
  the raw `ops.tick_phase` contract.
* **One trace per bucket** — the pallas run-fn cache keys on the pow2
  bucket signature + resolved impl, never on table contents.
* **Guards** — ``REPRO_REQUIRE_PHASE_MODE=pallas`` refuses fallbacks;
  pallas is explicit-only (never auto-selected); the seed-width-aware
  auto selector widens the compact region for wide sweeps.

The autouse fixture pins ``REPRO_KERNEL_IMPL=interpret`` so every
engine-level test here exercises the real kernel body, not just the
reference lowering.
"""
import numpy as np
import pytest

from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import (FailoverConfig, build_plan,
                                  select_phase_mode)
from repro.streams.jax_engine import (JaxStreamEngine, _FN_CACHE,
                                      _Lowered, _enable_x64,
                                      get_cached_run_fns, run_batch)

TOL = dict(rtol=1e-12, atol=1e-9)


@pytest.fixture(autouse=True)
def _interpret_impl(monkeypatch):
    """Route every pallas-mode run through the actual kernel body via
    the Pallas interpreter (CPU CI has no TPU to compile it)."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "interpret")


def _triple(graph, duration=120, n_hosts=8, **kw):
    return [JaxStreamEngine(graph, n_hosts=n_hosts, phase_mode=m,
                            **kw).run(duration)
            for m in ("dense", "compact", "pallas")]


def _assert_match(md, mp):
    for n in md.qps:
        np.testing.assert_allclose(md.qps[n], mp.qps[n],
                                   err_msg=f"qps[{n}]", **TOL)
        np.testing.assert_allclose(md.backlog[n], mp.backlog[n],
                                   err_msg=f"backlog[{n}]", **TOL)
    np.testing.assert_allclose(md.source_lag, mp.source_lag, **TOL)
    np.testing.assert_allclose(md.dropped, mp.dropped, **TOL)
    np.testing.assert_allclose(md.emitted, mp.emitted, **TOL)


@pytest.mark.parametrize("partitioner", ["rebalance", "hash", "weakhash",
                                         "backlog", "rescale",
                                         "group_rescale"])
def test_pallas_matches_dense_and_compact(partitioner):
    spec = ChaosSpec(seed=1, host_kill_prob_per_s=0.004,
                     straggler_frac=0.2)
    md, mc, mp = _triple(nexmark.q2(parallelism=16,
                                    partitioner=partitioner, n_groups=4),
                         chaos=spec,
                         failover=FailoverConfig(mode="region",
                                                 region_restart_s=20.0))
    _assert_match(md, mp)
    _assert_match(mc, mp)


def test_pallas_matches_dense_kill_heavy():
    """Kill-heavy seed: whole regions die repeatedly, phases run
    near-empty — fused-kernel masks/pads must keep routing, drops and
    requeues pinned to dense through every outage."""
    spec = ChaosSpec(seed=5, host_kill_prob_per_s=0.05,
                     straggler_frac=0.3)
    md, _, mp = _triple(nexmark.ss(parallelism=8), duration=240,
                        chaos=spec,
                        failover=FailoverConfig(mode="region",
                                                region_restart_s=10.0))
    assert len(mp.recoveries) > 5          # the chaos actually fired
    _assert_match(md, mp)


def test_pallas_matches_dense_2k_arena():
    """Deep-pipeline mega-arena (36 packed SS jobs, 6 phases): one
    jitted short run per mode, 1e-12 parity on the raw ys."""
    arena = nexmark.ss_arena(n_tasks=2016, parallelism=8, n_hosts=32)
    spec = ChaosSpec(seed=0, host_kill_prob_per_s=0.01,
                     straggler_frac=0.2)
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    outs = {}
    for mode in ("dense", "pallas"):
        low = _Lowered(arena, n_hosts=32, dt=0.5, queue_cap=256.0,
                       failover=fo, ckpt=None, seed=0, phase_mode=mode)
        run_fn, _ = get_cached_run_fns(low.desc)
        with _enable_x64():
            st, xs, _ = low.prepare(spec, 32)
            _, ys = run_fn(low.arrays, st, xs)
            outs[mode] = {k: np.asarray(v) for k, v in ys.items()}
    for k in outs["dense"]:
        np.testing.assert_allclose(outs["dense"][k], outs["pallas"][k],
                                   err_msg=k, **TOL)


def test_pallas_batch_is_natively_seed_batched():
    """run_batch in pallas mode carries the seed axis natively (kernel
    grid dimension, no outer vmap) and still matches the dense batch."""
    arena = nexmark.ss_arena(n_tasks=168, parallelism=4, n_hosts=8)
    spec = ChaosSpec(host_kill_prob_per_s=0.02, straggler_frac=0.2)
    bd = run_batch(arena, range(5), duration_s=60, base_spec=spec,
                   phase_mode="dense")
    bp = run_batch(arena, range(5), duration_s=60, base_spec=spec,
                   phase_mode="pallas")
    np.testing.assert_allclose(bd.source_lag, bp.source_lag, **TOL)
    np.testing.assert_allclose(bd.qps, bp.qps, **TOL)
    np.testing.assert_allclose(bd.backlog, bp.backlog, **TOL)
    np.testing.assert_allclose(bd.emitted_by_job, bp.emitted_by_job,
                               **TOL)
    np.testing.assert_allclose(bd.dropped_by_job, bp.dropped_by_job,
                               **TOL)


def test_tick_phase_interpret_matches_ref():
    """Raw kernel contract: ops.tick_phase under the interpreter equals
    the jnp reference on a packed SS phase, for every phase."""
    from repro.kernels.tick_phase import pack_phase_tables, tick_phase

    arena = nexmark.ss_arena(n_tasks=168, parallelism=4, n_hosts=8)
    low = _Lowered(arena, n_hosts=8, dt=0.5, queue_cap=256.0,
                   failover=None, ckpt=None, seed=0, phase_mode="pallas")
    rng = np.random.default_rng(7)
    with _enable_x64():
        import jax.numpy as jnp
        S, T = 8, low.plan.n_tasks
        produced = jnp.asarray(rng.uniform(0, 50.0, (S, T)))
        alive = jnp.asarray((rng.uniform(size=(S, T)) > 0.15)
                            .astype(float))
        free = jnp.asarray(rng.uniform(0, 256.0, (S, T)))
        for fi, ph in enumerate(low.tensor.phases):
            if not ph.D:
                continue
            tb = pack_phase_tables(low.arrays["edges"][fi],
                                   low.arrays["qcap"],
                                   low.arrays["mode_single"])
            ref = tick_phase(produced, alive, free, tb,
                             has_blk=ph.B > 0, has_grp=ph.G > 0,
                             impl="ref")
            ker = tick_phase(produced, alive, free, tb,
                             has_blk=ph.B > 0, has_grp=ph.G > 0,
                             impl="interpret")
            for r, k in zip(ref, ker):
                np.testing.assert_allclose(np.asarray(r), np.asarray(k),
                                           err_msg=f"phase {fi}", **TOL)


def test_one_trace_per_bucket_pallas():
    """Two same-shaped graphs with DIFFERENT partitioner kinds share
    one pallas bucket signature → one compiled run-fn serves both."""
    a = JaxStreamEngine(nexmark.q2(parallelism=8,
                                   partitioner="rebalance"),
                        n_hosts=8, phase_mode="pallas")
    b = JaxStreamEngine(nexmark.q2(parallelism=8, partitioner="backlog"),
                        n_hosts=8, phase_mode="pallas")
    assert a.lowered.desc == b.lowered.desc
    n0 = len(_FN_CACHE)
    ma = a.run(30)
    n1 = len(_FN_CACHE)
    mb = b.run(30)
    assert len(_FN_CACHE) == n1 and n1 <= n0 + 1
    assert ma.qps["filter"].shape == mb.qps["filter"].shape
    # pallas and compact descs differ (separate trace families)
    c = JaxStreamEngine(nexmark.q2(parallelism=8,
                                   partitioner="rebalance"),
                        n_hosts=8, phase_mode="compact")
    assert c.lowered.desc != a.lowered.desc


def test_require_phase_mode_pallas_guard(monkeypatch):
    """REPRO_REQUIRE_PHASE_MODE=pallas makes any fallback loud —
    scripts/ci.sh --pallas-smoke runs under it."""
    monkeypatch.setenv("REPRO_REQUIRE_PHASE_MODE", "pallas")
    with pytest.raises(RuntimeError, match="refusing to fall back"):
        _Lowered(nexmark.q2(parallelism=4), n_hosts=4, dt=0.5,
                 queue_cap=256.0, failover=None, ckpt=None, seed=0,
                 phase_mode="auto")
    low = _Lowered(nexmark.q2(parallelism=4), n_hosts=4, dt=0.5,
                   queue_cap=256.0, failover=None, ckpt=None, seed=0,
                   phase_mode="pallas")
    assert low.tensor.mode == "pallas"


def test_phase_mode_seed_width_selection():
    """pallas is never auto-selected; the seed-width argument widens
    the compact region (wide sweeps amortize row-table overhead)."""
    plan = build_plan(nexmark.ss(parallelism=8), 0.5, 256.0)
    assert select_phase_mode(plan, seed_width=1) == "dense"
    assert select_phase_mode(plan, seed_width=64) == "compact"
    assert select_phase_mode(plan, "pallas") == "pallas"
    for w in (1, 64):
        assert select_phase_mode(plan, seed_width=w) != "pallas"
    # tiny graphs stay dense at any width via the absolute floor
    tiny = build_plan(nexmark.q2(parallelism=2), 0.5, 256.0)
    assert select_phase_mode(tiny, seed_width=1) == "dense"
