"""SLO policies, autoscaler, replication, single-task recovery, lazyload,
hotupdate — the engine/cluster resiliency mechanisms end to end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import Completeness, SLOConfig, ShapeConfig, get_smoke_arch
from repro.configs.registry import make_run
from repro.core import regions as R
from repro.core.autoscaler import DS2Scaler, OpMetrics, ScalerConfig
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import VirtualClock
from repro.core.lazyload import LazyRestorer
from repro.core.region_checkpoint import RegionCheckpointer
from repro.core.replication import ReplicationManager, TimingModel
from repro.core.single_task_recovery import MultiWorkerTrainer, RecoveryTiming
from repro.core.slo import InfeasibleSLO, policy_for
from repro.ckpt.storage import SimHDFS
from repro.models import build


# ----------------------------------------------------------------------
# SLO decision table (paper Table I)
# ----------------------------------------------------------------------
def test_slo_table():
    p = policy_for(SLOConfig(Completeness.PARTIAL, 0.1, 0.5))
    assert p.replication == "active" and p.recovery == "single_task"
    p = policy_for(SLOConfig(Completeness.FULL, 1.0, 30.0))
    assert p.replication == "passive" and p.recovery == "region"
    assert p.rescue_overflow
    p = policy_for(SLOConfig(Completeness.FULL, 60.0, 7200.0))
    assert p.ckpt_mode == "global" and p.ckpt_interval_s >= 600
    with pytest.raises(InfeasibleSLO):
        policy_for(SLOConfig(Completeness.PARTIAL, 60.0, 7200.0))


# ----------------------------------------------------------------------
# DS2 autoscaler
# ----------------------------------------------------------------------
def _metrics(rate, par, true_rate, backlog=0.0, bp=False):
    # busy time such that processed/busy == true_rate per task
    processed = min(rate, par * true_rate) * 60
    busy = processed / true_rate
    return [OpMetrics("op", rate, processed, busy, par, backlog, bp)]


def test_ds2_scales_up_to_demand():
    sc = DS2Scaler(ScalerConfig(cooldown_s=0, window=1, ewma_alpha=1.0))
    d = sc.observe(0.0, _metrics(rate=10_000, par=4, true_rate=100))
    assert d and d[0].new >= int(10_000 / 100 / 0.9)


def test_ds2_scales_down_and_veto():
    sc = DS2Scaler(ScalerConfig(cooldown_s=0, window=1, ewma_alpha=1.0))
    d = sc.observe(0.0, _metrics(rate=800, par=64, true_rate=100))
    assert d and d[0].new < 64
    veto = DS2Scaler(ScalerConfig(cooldown_s=0, ewma_alpha=1.0),
                     shrink_veto=lambda t: True)
    assert veto.observe(0.0, _metrics(rate=800, par=64, true_rate=100)) == []


def test_ds2_hysteresis_and_cooldown():
    sc = DS2Scaler(ScalerConfig(cooldown_s=1000, hysteresis=0.5,
                                ewma_alpha=1.0))
    assert sc.observe(0.0, _metrics(rate=4100, par=50, true_rate=100)) == []
    d = sc.observe(1.0, _metrics(rate=40_000, par=50, true_rate=100))
    assert d
    # cooldown blocks the immediate follow-up
    assert sc.observe(2.0, _metrics(rate=80_000, par=d[0].new,
                                    true_rate=100)) == []


def test_ds2_rollback_and_breaker():
    cfg = ScalerConfig(cooldown_s=0, ewma_alpha=1.0, breaker_failures=2)
    sc = DS2Scaler(cfg)
    d = sc.observe(0.0, _metrics(rate=50_000, par=10, true_rate=100))
    assert d
    rb = sc.notify_result("op", 1.0, success=False)
    assert rb is not None and rb.new == 10, "failed resize rolls back"
    sc.observe(2.0, _metrics(rate=90_000, par=10, true_rate=100))
    sc.notify_result("op", 3.0, success=False)
    assert sc.observe(4.0, _metrics(rate=90_000, par=10,
                                    true_rate=100)) == [], "breaker open"


# ----------------------------------------------------------------------
# replication manager
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_model():
    m = build(get_smoke_arch("stablelm-1.6b"))
    return m, m.init(jax.random.PRNGKey(0))


def _checkpointer(tmp, model, clock):
    regions = R.partition_regions(model.param_specs(), 3)
    store = SimHDFS(tmp, clock=clock, chaos=ChaosEngine())
    return RegionCheckpointer(store, "j", regions, clock=clock)


def test_active_vs_passive_recovery_latency(small_model, tmp_path):
    model, params = small_model
    clock = VirtualClock()
    timing = TimingModel(restore_bps=1e5)  # restore cost visible at smoke size
    pol_a = policy_for(SLOConfig(Completeness.PARTIAL, 0.1, 0.5))
    mgr_a = ReplicationManager(pol_a, _checkpointer(tmp_path / "a", model,
                                                    clock),
                               timing=timing, clock=clock)
    pol_p = policy_for(SLOConfig(Completeness.FULL, 1.0, 30.0))
    mgr_p = ReplicationManager(pol_p, _checkpointer(tmp_path / "p", model,
                                                    clock),
                               timing=timing, clock=clock)
    state = params
    for step in range(3):
        mgr_a.on_step(step, state)
        mgr_p.on_step(step, state)
        clock.sleep(60)
    _, oc_a = mgr_a.on_failure(3, params)
    _, oc_p = mgr_p.on_failure(3, params)
    assert oc_a.downtime_s < oc_p.downtime_s, \
        "active replication must recover faster than passive"
    assert oc_a.mode == "active" and oc_p.mode == "passive"


# ----------------------------------------------------------------------
# single-task recovery (Fig 9 semantics on a real jax trainer)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["single_task", "global_restart"])
def test_single_task_recovery_qps(mode, small_model):
    model, _ = small_model
    run = make_run("stablelm-1.6b", "train_4k")
    run = dataclasses.replace(run, model=model.cfg,
                              shape=ShapeConfig("s", 16, 2, "train"))
    chaos = ChaosEngine(ChaosSpec(seed=0, host_kill_at=((5.0, 1),)))
    tr = MultiWorkerTrainer(model, run, n_workers=4, mode=mode,
                            step_time_s=1.0, chaos=chaos,
                            timing=RecoveryTiming(global_restore_s=10,
                                                  global_replay_s=10))
    trace = tr.run_for(30.0)
    qps = np.array([p["qps"] for p in trace])
    full = qps.max()
    if mode == "global_restart":
        assert (qps == 0).sum() >= 10, "global restart zeroes throughput"
    else:
        assert (qps == 0).sum() == 0, "survivors keep processing"
        assert qps.min() >= full * (3 / 4) - 1e-6, "dip bounded by 1/N"
    assert qps[-1] == full, "throughput recovers"


def test_str_worker_rejoins_with_peer_params(small_model):
    model, _ = small_model
    run = make_run("stablelm-1.6b", "train_4k")
    run = dataclasses.replace(run, model=model.cfg,
                              shape=ShapeConfig("s", 16, 2, "train"))
    chaos = ChaosEngine(ChaosSpec(seed=0, host_kill_at=((3.0, 0),)))
    tr = MultiWorkerTrainer(model, run, n_workers=3, mode="single_task",
                            step_time_s=1.0, chaos=chaos)
    tr.run_for(20.0)
    p0 = jax.tree.leaves(tr.workers[0].params)
    p1 = jax.tree.leaves(tr.workers[1].params)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(p0, p1)), "rebuilt replica == healthy peer"


# ----------------------------------------------------------------------
# lazyload
# ----------------------------------------------------------------------
def test_lazyload_matches_eager(small_model, tmp_path):
    model, params = small_model
    clock = VirtualClock()
    ck = _checkpointer(tmp_path / "l", model, clock)
    ck.save(1, params)
    eager, _ = ck.restore(params, gamma="full")
    lazy = LazyRestorer(ck, params, gamma="full")
    tree = lazy.wait_all()
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(eager)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert len(lazy.timeline) == len(ck.regions)


def test_lazyload_priority_order_ready_first(small_model, tmp_path):
    model, params = small_model
    clock = VirtualClock()
    ck = _checkpointer(tmp_path / "l2", model, clock)
    ck.save(1, params)
    lazy = LazyRestorer(ck, params, gamma="full", priority=[2, 1, 0],
                        max_workers=1)
    lazy.wait_region(2)
    assert 2 in lazy.ready_regions()
    lazy.wait_all()


def test_lazyload_fetch_error_surfaces(small_model, tmp_path):
    # regression: a failed storage.get inside the ThreadPoolExecutor used
    # to leave the region's event unset forever, so wait_region raised a
    # misleading TimeoutError instead of the storage error.
    from repro.ckpt.storage import StorageUnavailable

    model, params = small_model
    clock = VirtualClock()
    ck = _checkpointer(tmp_path / "l3", model, clock)
    ck.save(1, params)

    def dead_get(key):
        raise StorageUnavailable("datanode gone")

    ck.storage.get = dead_get
    lazy = LazyRestorer(ck, params, gamma="full")
    with pytest.raises(StorageUnavailable):
        lazy.wait_region(0, timeout=1.0)
    with pytest.raises(StorageUnavailable):
        lazy.wait_all(timeout=1.0)


def test_lazyload_shuts_executor_down(small_model, tmp_path):
    # regression: the restore executor used to leak per LazyRestorer.
    model, params = small_model
    clock = VirtualClock()
    ck = _checkpointer(tmp_path / "l4", model, clock)
    ck.save(1, params)
    lazy = LazyRestorer(ck, params, gamma="full")
    lazy.wait_all()
    assert lazy._pool._shutdown, "executor must not leak per restore"


# ----------------------------------------------------------------------
# hotupdate
# ----------------------------------------------------------------------
def test_hotupdate_reuses_executable_and_state(small_model):
    from repro.core.hotupdate import HotUpdateManager
    model, params = small_model
    mgr = HotUpdateManager()

    def make_step():
        @jax.jit
        def step(state, x):
            return jax.tree.map(lambda p: p * 0.999, state), x.sum()
        return step

    x = jnp.ones((8, 8))
    cold = mgr.deploy("v1", make_step, params, (x,), reuse_state=False)
    hot = mgr.deploy("v1", make_step, params, (x,))
    assert hot.kind == "hot" and cold.kind == "cold"
    assert mgr.cache.hits == 1
    assert hot.total_s < cold.total_s
    # new business logic: recompiles but still reuses device state
    hot2 = mgr.deploy("v2", make_step, params, (x,))
    assert hot2.kind == "hot" and mgr.cache.misses == 2
