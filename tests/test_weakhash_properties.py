"""Property-based tests for `weakhash_assign` invariants (via the
hypothesis shim in tests/helpers.py — real hypothesis when installed):

* counts sum to N and every key stays inside its candidate group
  (bounded candidate set — the WeakHash §III-A contract);
* capacity/balance: least-loaded water-filling never spreads a group
  wider than max(initial spread, 1);
* permutation-of-keys invariance of the per-task counts;
* chunked-streaming mode: ``chunk >= N`` reproduces the batch
  assignment exactly, ``chunk=1`` degenerates to the sequential greedy,
  and every chunk size preserves the invariants.
"""
import numpy as np

from helpers import given, settings, st
from repro.core.weakhash import candidate_group, load_cv, weakhash_assign


def _keys(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, 1 << 20, n)


@settings(max_examples=30)
@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 400),
       st.integers(0, 10_000))
def test_counts_sum_and_candidate_containment(n_groups, gsz, n_keys, seed):
    n_tasks = n_groups * gsz
    keys = _keys(seed, n_keys)
    out = weakhash_assign(keys, n_tasks, n_groups)
    counts = np.bincount(out, minlength=n_tasks)
    assert counts.sum() == n_keys
    assert np.array_equal(out // gsz, candidate_group(keys, n_groups))
    # capacity bound: zero starting loads → water level caps every task
    # at ceil(group_keys / gsz); spread within a group is at most 1
    per_group = counts.reshape(n_groups, gsz)
    assert (per_group.max(1) - per_group.min(1) <= 1).all()
    gkeys = np.bincount(candidate_group(keys, n_groups),
                        minlength=n_groups)
    assert (per_group.max(1) <= np.ceil(gkeys / gsz)).all()


@settings(max_examples=25)
@given(st.integers(1, 6), st.integers(2, 6), st.integers(1, 300),
       st.integers(0, 10_000))
def test_balance_never_widens_initial_spread(n_groups, gsz, n_keys, seed):
    n_tasks = n_groups * gsz
    rng = np.random.default_rng(seed)
    keys = _keys(seed + 1, n_keys)
    loads = rng.integers(0, 40, n_tasks).astype(np.float64)
    out = weakhash_assign(keys, n_tasks, n_groups, loads=loads)
    final = loads + np.bincount(out, minlength=n_tasks)
    fg = final.reshape(n_groups, gsz)
    lg = loads.reshape(n_groups, gsz)
    spread0 = lg.max(1) - lg.min(1)
    spread1 = fg.max(1) - fg.min(1)
    assert (spread1 <= np.maximum(spread0, 1.0)).all()


@settings(max_examples=25)
@given(st.integers(1, 8), st.integers(1, 5), st.integers(1, 400),
       st.integers(0, 10_000))
def test_group_counts_permutation_invariance(n_groups, gsz, n_keys, seed):
    n_tasks = n_groups * gsz
    keys = _keys(seed, n_keys)
    perm = np.random.default_rng(seed + 7).permutation(n_keys)
    a = np.bincount(weakhash_assign(keys, n_tasks, n_groups),
                    minlength=n_tasks)
    b = np.bincount(weakhash_assign(keys[perm], n_tasks, n_groups),
                    minlength=n_tasks)
    assert np.array_equal(a, b)
    assert load_cv(weakhash_assign(keys, n_tasks, n_groups), n_tasks) == \
        load_cv(weakhash_assign(keys[perm], n_tasks, n_groups), n_tasks)


# ----------------------------------------------------------------------
# chunked-streaming mode
# ----------------------------------------------------------------------
@settings(max_examples=25)
@given(st.integers(1, 6), st.integers(1, 5), st.integers(1, 300),
       st.integers(1, 64), st.integers(0, 10_000))
def test_chunked_mode_invariants(n_groups, gsz, n_keys, chunk, seed):
    n_tasks = n_groups * gsz
    keys = _keys(seed, n_keys)
    out = weakhash_assign(keys, n_tasks, n_groups, chunk=chunk)
    counts = np.bincount(out, minlength=n_tasks)
    assert counts.sum() == n_keys
    assert np.array_equal(out // gsz, candidate_group(keys, n_groups))


@settings(max_examples=20)
@given(st.integers(1, 6), st.integers(1, 5), st.integers(0, 300),
       st.integers(0, 10_000))
def test_chunk_of_full_batch_is_the_batch(n_groups, gsz, n_keys, seed):
    """chunk >= N is ONE water-fill — the batch assignment, key-for-key."""
    n_tasks = n_groups * gsz
    keys = _keys(seed, n_keys)
    batch = weakhash_assign(keys, n_tasks, n_groups)
    for chunk in (max(n_keys, 1), n_keys + 17):
        chunked = weakhash_assign(keys, n_tasks, n_groups, chunk=chunk)
        assert np.array_equal(chunked, batch)
        assert np.array_equal(np.bincount(chunked, minlength=n_tasks),
                              np.bincount(batch, minlength=n_tasks))


@settings(max_examples=15)
@given(st.integers(1, 6), st.integers(1, 5), st.integers(0, 200),
       st.integers(0, 10_000))
def test_chunk_one_degenerates_to_sequential(n_groups, gsz, n_keys, seed):
    """chunk=1 is one least-loaded pick per key — the sequential greedy
    exactly (arrival order, lowest-index tie break), per key."""
    n_tasks = n_groups * gsz
    keys = _keys(seed, n_keys)
    rng = np.random.default_rng(seed + 3)
    loads = rng.integers(0, 20, n_tasks).astype(np.float64)
    a = weakhash_assign(keys, n_tasks, n_groups, loads=loads, chunk=1)
    b = weakhash_assign(keys, n_tasks, n_groups, loads=loads,
                        sequential=True)
    assert np.array_equal(a, b)


@settings(max_examples=15)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(50, 300),
       st.integers(0, 10_000))
def test_chunked_interpolates_between_batch_and_sequential(
        n_groups, gsz, n_keys, seed):
    """Chunked counts stay balanced: per-group spread stays ≤ 1 for any
    chunk size when starting from flat loads (each chunk water-fills on
    refreshed loads, so imbalance never accumulates)."""
    n_tasks = n_groups * gsz
    keys = _keys(seed, n_keys)
    for chunk in (7, 32, 128):
        out = weakhash_assign(keys, n_tasks, n_groups, chunk=chunk)
        per_group = np.bincount(out, minlength=n_tasks).reshape(
            n_groups, gsz)
        assert (per_group.max(1) - per_group.min(1) <= 1).all(), chunk
