"""Storage + external-dependency HA: backoff, idempotency, fallback stores,
ZK→HDFS leader fallback, termination on double failure (paper §IV-B)."""
import numpy as np
import pytest

from helpers import given, settings, st  # hypothesis, or seeded fallback

from repro.ckpt.storage import (FallbackStorage, LocalFS, ObjectStoreSim,
                                SimHDFS, StorageUnavailable)
from repro.core.backoff import (IdempotencyRegistry, PermanentError,
                                RetryPolicy, TransientError, retry)
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import VirtualClock
from repro.core.ha import JobTerminated, LeaderService, ZooKeeperSim


def test_retry_succeeds_after_transients():
    clock = VirtualClock()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientError("boom")
        return "ok"

    out, stats = retry(flaky, RetryPolicy(base_delay_s=0.1), clock)
    assert out == "ok" and stats.attempts == 3
    assert clock.now() > 0, "backoff must consume (virtual) time"


def test_retry_gives_up_and_delays_grow():
    clock = VirtualClock()
    policy = RetryPolicy(base_delay_s=1.0, multiplier=2.0, max_attempts=4,
                         jitter=0.0)
    with pytest.raises(PermanentError):
        retry(lambda: (_ for _ in ()).throw(TransientError("x")), policy,
              clock)
    # 1 + 2 + 4 (no delay after final attempt)
    assert clock.now() == pytest.approx(7.0)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31), st.integers(1, 5))
def test_idempotency_registry(job, repeats):
    reg = IdempotencyRegistry()
    calls = {"n": 0}

    def submit():
        calls["n"] += 1
        return f"exec-{job}"

    token = IdempotencyRegistry.token("job", job)
    results = [reg.run(token, submit) for _ in range(repeats)]
    assert calls["n"] == 1, "duplicate submissions must not re-execute"
    assert all(r[0] == f"exec-{job}" for r in results)
    assert [r[1] for r in results] == [False] + [True] * (repeats - 1)


def test_fallback_storage_survives_primary_outage(tmp_path):
    clock = VirtualClock()
    primary = SimHDFS(tmp_path / "p", clock=clock,
                      chaos=ChaosEngine(ChaosSpec(seed=1,
                                                  storage_fail_prob=1.0)))
    fallback = ObjectStoreSim(tmp_path / "f", clock=clock)
    fs = FallbackStorage(primary, fallback, clock=clock,
                         policy=RetryPolicy(base_delay_s=0.01,
                                            max_attempts=2))
    fs.put("k", b"data")
    assert fs.fallback_puts == 1
    assert fs.get("k") == b"data"


def test_atomic_writes_idempotent(tmp_path):
    fs = LocalFS(tmp_path)
    fs.put("a/b", b"v1")
    fs.put("a/b", b"v1")  # retried write is a no-op effectswise
    assert fs.get("a/b") == b"v1"
    assert fs.list() == ["a/b"]


def test_leader_fallback_chain(tmp_path):
    clock = VirtualClock()
    chaos = ChaosEngine(ChaosSpec(zk_down=((10.0, 100.0),)))
    zk = ZooKeeperSim(clock=clock, chaos=chaos)
    hdfs = LocalFS(tmp_path)
    svc = LeaderService(zk, hdfs, clock=clock)
    svc.elect("jm-0")
    assert svc.get_leader().leader_id == "jm-0"
    clock.sleep(20)  # ZK now down
    assert svc.get_leader().leader_id == "jm-0"
    assert svc.fallback_reads == 1, "must fall back to the HDFS copy"


def test_leader_double_failure_terminates(tmp_path):
    clock = VirtualClock()
    chaos = ChaosEngine(ChaosSpec(zk_down=((0.0, 100.0),)))
    zk = ZooKeeperSim(clock=clock, chaos=chaos)

    class DeadStore:
        def get(self, k):
            raise KeyError(k)

        def put(self, k, v):
            raise StorageUnavailable("down")

    svc = LeaderService(zk, DeadStore(), clock=clock)
    with pytest.raises(JobTerminated):
        svc.get_leader()
    assert svc.terminations == 1


def test_leader_inconsistency_terminates(tmp_path):
    clock = VirtualClock()
    chaos = ChaosEngine(ChaosSpec(zk_down=((5.0, 100.0),)))
    zk = ZooKeeperSim(clock=clock, chaos=chaos)
    hdfs = LocalFS(tmp_path)
    svc = LeaderService(zk, hdfs, clock=clock)
    svc.elect("jm-0")
    # HDFS copy tampered / stale while ZK is down → terminate for correctness
    from repro.core.ha import LeaderRecord
    hdfs.put("ha/leader", LeaderRecord("jm-9", 42).to_bytes())
    clock.sleep(10)
    with pytest.raises(JobTerminated):
        svc.get_leader()


def test_missing_leader_on_healthy_zk_is_not_an_outage(tmp_path):
    # regression: a missing leader key on a HEALTHY ZK used to be caught
    # together with ZKUnavailable and silently served from the HDFS copy,
    # inflating fallback_reads.
    from repro.core.ha import LeaderRecord, NoLeader

    clock = VirtualClock()
    zk = ZooKeeperSim(clock=clock, chaos=ChaosEngine())
    hdfs = LocalFS(tmp_path)
    hdfs.put("ha/leader", LeaderRecord("stale-jm", 7).to_bytes())
    svc = LeaderService(zk, hdfs, clock=clock)
    with pytest.raises(NoLeader):
        svc.get_leader()
    assert svc.fallback_reads == 0, ("no-leader on healthy ZK must not be "
                                     "served from the HDFS copy")


def test_programming_error_in_fallback_is_not_a_double_outage(tmp_path):
    # regression: the bare `except Exception` around the HDFS fallback
    # turned programming errors into JobTerminated "double outages".
    clock = VirtualClock()
    chaos = ChaosEngine(ChaosSpec(zk_down=((0.0, 100.0),)))
    zk = ZooKeeperSim(clock=clock, chaos=chaos)

    class BuggyStore:
        def get(self, k):
            raise ZeroDivisionError("bug in the fallback path")

    svc = LeaderService(zk, BuggyStore(), clock=clock)
    with pytest.raises(ZeroDivisionError):
        svc.get_leader()
    assert svc.terminations == 0


def test_simhdfs_slow_reads_not_counted_as_slow_puts(tmp_path):
    # regression: _charge incremented slow_puts from get() too.
    clock = VirtualClock()
    chaos = ChaosEngine(ChaosSpec(seed=0, storage_slow_prob=1.0,
                                  storage_slow_factor=10.0))
    s = SimHDFS(tmp_path, clock=clock, chaos=chaos, bandwidth_bps=1e6,
                base_latency_s=0.0)
    s.put("k", b"x" * 1000)
    s.get("k")
    assert s.slow_puts == 1, "a slow GET must not count as a slow upload"
    assert s.slow_gets == 1


def test_simhdfs_charges_time(tmp_path):
    clock = VirtualClock()
    chaos = ChaosEngine(ChaosSpec(seed=0, storage_slow_prob=1.0,
                                  storage_slow_factor=10.0))
    s = SimHDFS(tmp_path, clock=clock, chaos=chaos, bandwidth_bps=1e6,
                base_latency_s=0.0)
    s.put("k", b"x" * 1_000_000)
    assert clock.now() == pytest.approx(10.0), "slow factor must apply"
    assert s.slow_puts == 1


def test_simhdfs_brownout_scales_upload_queueing(tmp_path):
    """Concurrent uploads queue on the single upload pipeline, and the
    queueing delay scales with `brownout_factor_at`: a brownout does not
    just stretch each op independently, it backs up the whole queue
    (regression: arrival-time queueing was not modeled — the virtual
    clock's blocking sleeps made sequential callers never wait, so
    brownouts left `queue_wait_s` at zero)."""
    peak = 6.0

    def run(ramps, tag):
        clock = VirtualClock()
        clock.sleep(100.0)      # mid-ramp, where the factor is `peak`
        s = SimHDFS(tmp_path / tag, clock=clock,
                    chaos=ChaosEngine(ChaosSpec(seed=1,
                                                brownout_at=ramps)),
                    bandwidth_bps=1e8, base_latency_s=0.02)
        t0 = clock.now()
        # both region uploads of one snapshot arrive at the snapshot
        # instant — the second queues behind the first
        s.put("a", b"x" * (1 << 20), arrival_s=t0)
        dur_first = clock.now() - t0
        s.put("b", b"x" * (1 << 20), arrival_s=t0)
        return dur_first, s.queue_wait_s

    dur_calm, wait_calm = run((), "calm")
    dur_brown, wait_brown = run(((0.0, 200.0, peak),), "brown")
    # the queued op waits exactly as long as its predecessor's service
    assert wait_calm == pytest.approx(dur_calm)
    assert wait_brown == pytest.approx(dur_brown)
    # brownout stretches service → the queue backs up with the factor
    assert wait_brown == pytest.approx(peak * wait_calm, rel=0.02)
