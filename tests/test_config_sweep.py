"""Resiliency-config grid axis (ISSUE 4): the engine's third vmap axis.

Pillars:

* **Row parity** — every config row of `run_config_batch` equals a
  standalone `JaxStreamEngine` run with that exact config at 1e-12
  (identical lowering, so down to vmap-reduction reassociation only),
  and pins to the numpy engine at 1e-5. Holds with the kill-tensor
  sharing fast path (no checkpoints) AND with per-config rebuilt
  timelines (checkpoint grids).
* **One trace per grid shape** — resiliency floats (detect, restart
  budgets, mode masks, qcap, selectivities) are traced leaves, so
  sweeping config VALUES never retraces; only a new (C, S) shape does.
* **Per-job configs** — `FailoverConfig`/`CheckpointConfig` lists inside
  a `PackedArena`: disjoint-host packing with per-job configs equals K
  independent runs, each with its own config, in both engines.
"""
import numpy as np
import pytest

from repro.core.chaos import ChaosEngine, ChaosSpec, refit_failover
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep_configs
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine, pack_arena)
from repro.streams.jax_engine import (JaxStreamEngine,
                                      get_cached_config_fn,
                                      run_config_batch)

TOL = dict(rtol=1e-12, atol=1e-9)
KILLS = ((20.0, 2),)


def _graph():
    return nexmark.q2(parallelism=8, partitioner="weakhash", n_groups=4)


GRID = [FailoverConfig(mode="region", region_restart_s=10.0),
        FailoverConfig(mode="region", region_restart_s=40.0,
                       detect_s=2.5),
        FailoverConfig(mode="single_task", single_restart_s=4.0)]


# ----------------------------------------------------------------------
# config-batch row i == standalone run with that config
# ----------------------------------------------------------------------
def test_config_batch_rows_match_standalone():
    spec = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
    seeds = list(range(4))
    out = run_config_batch(_graph(), GRID, seeds, base_spec=spec,
                           duration_s=120, n_hosts=8)
    assert len(out) == len(GRID)
    for c, fo in enumerate(GRID):
        bm = out[c]
        assert bm.source_lag.shape == (4, 240)
        for i in seeds:
            sspec = ChaosSpec(host_kill_prob_per_s=0.004,
                              straggler_frac=0.2, seed=i)
            m = JaxStreamEngine(_graph(), n_hosts=8, chaos=sspec,
                                failover=fo).run(120)
            np.testing.assert_allclose(bm.source_lag[i], m.source_lag,
                                       err_msg=f"cfg{c} seed{i}", **TOL)
            np.testing.assert_allclose(bm.dropped[i], m.dropped, **TOL)
            assert bm.recoveries[i] == m.recoveries, (c, i)
    # ... and the grid pins to the numpy engine at 1e-5
    a = StreamEngine(_graph(), n_hosts=8,
                     chaos=ChaosEngine(ChaosSpec(
                         host_kill_prob_per_s=0.004, straggler_frac=0.2,
                         seed=1)),
                     failover=GRID[2])
    a.run(120)
    np.testing.assert_allclose(np.asarray(a.metrics.source_lag),
                               out[2].source_lag[1], rtol=1e-5, atol=1e-5)
    # the budget axis is live: same kills, per-config downtimes
    d0 = [r["downtime"] for r in out[0].recoveries[0]]
    d1 = [r["downtime"] for r in out[1].recoveries[0]]
    assert set(d0) == {11.0} and set(d1) == {42.5}


def test_config_batch_ckpt_interval_axis():
    """Checkpoint-interval grids rebuild per-config timelines (storage
    draws are config-dependent) — rows must still equal standalone
    runs."""
    grid = [(FailoverConfig(mode="region", region_restart_s=15.0),
             CheckpointConfig(interval_s=iv, mode="region"))
            for iv in (20.0, 45.0)]
    spec = ChaosSpec(host_kill_prob_per_s=0.002, storage_slow_prob=0.3,
                     storage_slow_factor=12)
    seeds = [0, 1, 2]
    out = run_config_batch(nexmark.ds(parallelism=6), grid, seeds,
                           base_spec=spec, duration_s=200, n_hosts=6)
    attempts = [int(out[c].ckpt_attempts[0]) for c in range(2)]
    assert attempts[0] > attempts[1] > 0       # interval axis is live
    for c, (fo, ck) in enumerate(grid):
        for i in seeds:
            m = JaxStreamEngine(
                nexmark.ds(parallelism=6), n_hosts=6,
                chaos=ChaosSpec(host_kill_prob_per_s=0.002,
                                storage_slow_prob=0.3,
                                storage_slow_factor=12, seed=i),
                failover=fo, ckpt=ck).run(200)
            np.testing.assert_allclose(out[c].source_lag[i],
                                       m.source_lag,
                                       err_msg=f"cfg{c} seed{i}", **TOL)
            assert int(out[c].ckpt_attempts[i]) == m.ckpt_attempts
            assert int(out[c].ckpt_success[i]) == m.ckpt_success
            assert int(out[c].ckpt_epoch[i]) == m.ckpt_attempts


def test_config_mix_seed_cube():
    """configs × mixes compose: the identity-mix slice of the (M, C, S)
    cube equals the plain (C, S) grid bit-for-bit."""
    arena = pack_arena([nexmark.q2(parallelism=8), nexmark.q12(
        parallelism=8)], "shared", n_hosts=8)
    spec = ChaosSpec(seed=3, host_kill_prob_per_s=0.003)
    grid = [FailoverConfig(mode="region", region_restart_s=r)
            for r in (10.0, 30.0)]
    base = run_config_batch(arena, grid, range(3), base_spec=spec,
                            duration_s=60)
    cube = run_config_batch(arena, grid, range(3), base_spec=spec,
                            duration_s=60,
                            mixes=[[1.0, 1.0], [0.5, 2.0]])
    for c in range(2):
        np.testing.assert_allclose(cube[0][c].source_lag,
                                   base[c].source_lag, rtol=0, atol=0)
        # emission scales per job by exactly the mix multiplier
        np.testing.assert_allclose(
            cube[1][c].emitted_by_job,
            base[c].emitted_by_job * np.array([0.5, 2.0]), rtol=1e-9)


# ----------------------------------------------------------------------
# trace cache: one trace per grid shape, config values are traced
# ----------------------------------------------------------------------
def test_config_grid_one_trace_per_shape():
    from repro.streams.jax_engine import _Lowered
    g = _graph()
    low = _Lowered(g, n_hosts=8, dt=0.5, queue_cap=256.0, failover=None,
                   ckpt=None, seed=0)
    # ckpt-free grids use the shared-kills trace variant (one (S,T,H)
    # kill tensor broadcast over the config axis)
    fn = get_cached_config_fn(low.desc, shared_kills=True)
    before = fn._cache_size()
    spec = ChaosSpec(host_kill_prob_per_s=0.004)
    run_config_batch(g, GRID[:2], range(4), base_spec=spec,
                     duration_s=30, n_hosts=8)
    # different VALUES (and even a different failover MODE mix): the
    # (2, 4) grid shape is unchanged → the same trace serves it
    grid2 = [FailoverConfig(mode="single_task", single_restart_s=2.0),
             {"failover": GRID[0], "qcap_scale": 0.5, "sel_scale": 1.1}]
    run_config_batch(g, grid2, range(4), base_spec=spec,
                     duration_s=30, n_hosts=8)
    assert fn._cache_size() - before == 1
    # a new grid shape (C=3) traces once more
    run_config_batch(g, GRID, range(4), base_spec=spec,
                     duration_s=30, n_hosts=8)
    assert fn._cache_size() - before == 2


def test_qcap_and_selectivity_scales_are_live():
    spec = ChaosSpec(seed=0)        # failure-free: isolate the knobs
    grid = [{"failover": None}, {"failover": None, "sel_scale": 0.5}]
    out = run_config_batch(nexmark.q12(parallelism=4), grid, [0],
                           base_spec=spec, duration_s=30, n_hosts=4)
    # halving window_count selectivity halves sink-side traffic
    q_full = out[0].qps[0, :, -1].sum()
    q_half = out[1].qps[0, :, -1].sum()
    assert q_half < 0.75 * q_full


# ----------------------------------------------------------------------
# per-job configs inside one arena
# ----------------------------------------------------------------------
def _per_job_setup():
    graphs = [nexmark.q2(parallelism=8, partitioner="weakhash",
                         n_groups=4), nexmark.q12(parallelism=8)]
    fos = [FailoverConfig(mode="region", region_restart_s=12.0),
           FailoverConfig(mode="single_task", single_restart_s=4.0,
                          detect_s=2.0)]
    arena = pack_arena(graphs, "disjoint", n_hosts=8)
    at = sum((arena.lift_kills(j, KILLS) for j in range(2)), ())
    return graphs, fos, arena, ChaosSpec(host_kill_at=at)


@pytest.mark.parametrize("engine_cls", [StreamEngine, JaxStreamEngine])
def test_per_job_failover_disjoint_equals_independent(engine_cls):
    """Disjoint-host packing with per-job FailoverConfigs (different
    modes AND budgets) == K independent runs, each under its own
    config."""
    graphs, fos, arena, spec = _per_job_setup()
    chaos = ChaosEngine(spec) if engine_cls is StreamEngine else spec
    eng = engine_cls(arena, chaos=chaos, failover=fos)
    m = eng.run(60)
    pm = m if engine_cls is JaxStreamEngine else eng.metrics
    for j, g in enumerate(graphs):
        solo_chaos = (ChaosEngine(ChaosSpec(host_kill_at=KILLS))
                      if engine_cls is StreamEngine
                      else ChaosSpec(host_kill_at=KILLS))
        solo = engine_cls(g, n_hosts=8, chaos=solo_chaos, failover=fos[j])
        sm = solo.run(60)
        if engine_cls is StreamEngine:
            sm = solo.metrics
        pre = arena.jobs[j].prefix
        for name in g.topo_order():
            np.testing.assert_allclose(
                np.asarray(pm.backlog[pre + name]),
                np.asarray(sm.backlog[name]),
                rtol=1e-6, atol=1e-6, err_msg=f"{j}/{name}")
        np.testing.assert_allclose(pm.emitted_by_job[j], sm.emitted,
                                   rtol=1e-9)
        np.testing.assert_allclose(pm.dropped_by_job[j], sm.dropped,
                                   atol=1e-9)
        mine = [dict(r) for r in pm.recoveries if r.get("job") == j]
        for r in mine:
            r.pop("job")
        assert mine == sm.recoveries, j
    # job 1 runs single_task: its drops are real, job 0's are zero
    assert pm.dropped_by_job[1] > 0
    assert pm.dropped_by_job[0] == 0


def test_per_job_ckpt_schedules_and_parity():
    """Per-job CheckpointConfigs: each job checkpoints on its own
    schedule (per-job counters in both engines), and with draw-free
    storage (slow_prob=0) the packed run equals K independent runs."""
    graphs, fos, arena, spec = _per_job_setup()
    cks = [CheckpointConfig(interval_s=20.0, mode="region"),
           CheckpointConfig(interval_s=35.0, mode="region")]
    a = StreamEngine(arena, chaos=ChaosEngine(spec), failover=fos,
                     ckpt=cks)
    a.run(120)
    mb = JaxStreamEngine(arena, chaos=spec, failover=fos,
                         ckpt=cks).run(120)
    want = np.array([120 // 20, 120 // 35])
    np.testing.assert_array_equal(a.metrics.ckpt_by_job[:, 0], want)
    np.testing.assert_array_equal(mb.ckpt_by_job[:, 0], want)
    assert a.metrics.ckpt_attempts == mb.ckpt_attempts == want.sum()
    assert mb.ckpt_epoch == mb.ckpt_attempts
    np.testing.assert_array_equal(a.metrics.ckpt_by_job, mb.ckpt_by_job)
    for j, g in enumerate(graphs):
        solo = StreamEngine(g, n_hosts=8,
                            chaos=ChaosEngine(ChaosSpec(
                                host_kill_at=KILLS)),
                            failover=fos[j], ckpt=cks[j])
        solo.run(120)
        assert solo.metrics.ckpt_attempts == want[j]
        pre = arena.jobs[j].prefix
        for name in g.topo_order():
            np.testing.assert_allclose(
                a.metrics.backlog[pre + name], solo.metrics.backlog[name],
                rtol=1e-9, atol=1e-9, err_msg=f"{j}/{name}")


def test_per_job_config_inside_config_grid():
    """Per-job FailoverConfig lists work as grid ROWS of
    run_config_batch: row parity against the standalone per-job-config
    engine."""
    graphs, fos, arena, spec = _per_job_setup()
    grid = [{"failover": fos, "label": "per-job"},
            {"failover": FailoverConfig(mode="region",
                                        region_restart_s=25.0)}]
    out = run_config_batch(arena, grid, [0, 1], base_spec=spec,
                           duration_s=60)
    m = JaxStreamEngine(arena, chaos=spec, failover=fos).run(60)
    np.testing.assert_allclose(out[0].source_lag[0], m.source_lag, **TOL)
    assert out[0].recoveries[0] == m.recoveries


def test_per_job_failover_list_rejected_without_arena():
    with pytest.raises(ValueError, match="per-job"):
        StreamEngine(nexmark.q2(parallelism=4), n_hosts=4,
                     failover=[FailoverConfig(), FailoverConfig()])


# ----------------------------------------------------------------------
# sweep driver surfaces + refit guard
# ----------------------------------------------------------------------
def test_sweep_configs_recovery_surface():
    grid = [FailoverConfig(mode="region", region_restart_s=r)
            for r in (10.0, 60.0)]
    # one scheduled early kill per scenario (stragglers vary by seed) and
    # a horizon long enough that every scenario recovers: the surface is
    # then a clean recovery-time-vs-restart-budget curve
    res = sweep_configs(_graph(), grid, range(6),
                        base_spec=ChaosSpec(host_kill_at=((10.0, 2),),
                                            straggler_frac=0.2),
                        duration_s=400, n_hosts=8)
    rec = res.recovery_surface
    assert rec.shape == (2, 6)
    assert res.slo_surface.shape == (2, 6)
    assert len(res.results) == 2 and len(res.labels) == 2
    rows = res.rows()
    assert all(r["failed_scenarios"] == 6 for r in rows)
    assert np.isfinite(rec).all()
    # recovery is bounded below by the failover outage window (detect +
    # restart), so the budget axis shifts the whole surface floor
    assert rec[1].min() >= 60.0
    assert rec[0].min() < 60.0
    # the straggler-free scenario recovers right at the outage boundary
    assert rec[0][0] == pytest.approx(11.0)
    assert rec[1][0] == pytest.approx(61.0)


def test_refit_failover_rejects_ckpt_timelines():
    from repro.core.chaos import build_chaos_timeline
    task_host = np.arange(8) % 4
    tl = build_chaos_timeline(
        ChaosSpec(seed=0), n_ticks=40, dt=0.5, n_hosts=4,
        task_host=task_host, task_region=np.zeros(8, int),
        regions=[set(range(8))], failover_mode="region",
        ckpt_interval_s=5.0)
    assert tl.ckpt_attempts > 0
    with pytest.raises(ValueError, match="checkpoint-free"):
        refit_failover(tl, task_host=task_host,
                       task_region=np.zeros(8, int))
