"""Traffic-dynamics chaos: traced diurnal/flash-crowd load, in-trace DS2
autoscaling, and rescale-during-recovery drills.

Pins the traffic contract across all engine lowerings:

* a schedule that evaluates to a constant 1.0 rate factor (zero-amplitude
  diurnal, unit-peak flash) is a bit-exact no-op — rate curves multiply
  emission and must never perturb the draw streams;
* numpy == jax (1e-5) and dense == compact == pallas (1e-12) under the
  full `traffic_drill_spec` drill: diurnal + flash crowd + a host burst
  INSIDE the flash hold window + the in-trace DS2 controller rescaling
  while failover recovery is still replaying;
* the thrash guard latches under induced autoscaler oscillation and
  halts further actions; the failover-aware breaker opens under a kill
  storm and degrades gracefully (load shed) instead of rescaling into
  the outage;
* the `traffic_sweep` (scaler × traffic × failover × seed) cube comes
  out of ONE `sweep_configs` call with `timeline_build_count` flat
  (rate schedules and scale events are in-trace only);
* regression pins for the host-side control plane: per-op breaker
  counts and stale-rollback expiry in `DS2Scaler.notify_result`,
  exception chaining in `backoff.retry`, and in-flight await in
  `IdempotencyRegistry.run`.
"""
import dataclasses
import math
import threading

import numpy as np
import pytest

from repro.core.autoscaler import DS2Scaler, OpMetrics, ScalerConfig
from repro.core.backoff import (IdempotencyRegistry, PermanentError,
                                RetryPolicy, TransientError, retry)
from repro.core.chaos import (ChaosEngine, ChaosSpec, timeline_build_count,
                              traffic_curve)
from repro.core.clock import VirtualClock
from repro.streams import nexmark
from repro.streams.chaos_sweep import traffic_sweep
from repro.streams.engine import (AutoscaleConfig, FailoverConfig,
                                  StreamEngine)
from repro.streams.jax_engine import JaxStreamEngine, run_batch

FO = FailoverConfig(mode="region", detect_s=1.0)
DS2 = AutoscaleConfig(interval_s=5.0, cooldown_s=10.0, ewma_alpha=0.35,
                      hysteresis=0.15)


# ----------------------------------------------------------------------
# (a) constant-rate schedule == no schedule, bit-exact
# ----------------------------------------------------------------------
def test_constant_schedule_is_bit_exact_noop():
    """Zero-amplitude diurnal and unit-peak flash entries evaluate to a
    factor of exactly 1.0, so the scheduled run replays the constant-rate
    run draw-for-draw."""
    ts = np.arange(0.0, 60.0, 0.5)
    curve = traffic_curve(((0.0, 240.0, 0.0),), ((10.0, 5.0, 5.0, 1.0),),
                          ts)
    assert np.array_equal(curve, np.ones_like(ts))

    g = nexmark.q3()
    base_spec = ChaosSpec(seed=3, host_kill_prob_per_s=0.002)
    flat_spec = dataclasses.replace(
        base_spec, diurnal=((0.0, 240.0, 0.0),),
        flash_at=((10.0, 5.0, 5.0, 1.0),))
    base = StreamEngine(g, chaos=ChaosEngine(base_spec), failover=FO,
                        queue_cap=1e9).run(60.0)
    flat = StreamEngine(g, chaos=ChaosEngine(flat_spec), failover=FO,
                        queue_cap=1e9).run(60.0)
    assert flat.emitted == base.emitted
    assert flat.dropped == base.dropped
    assert np.array_equal(np.asarray(base.source_lag),
                          np.asarray(flat.source_lag))
    for n in base.backlog:
        assert np.array_equal(np.asarray(base.backlog[n]),
                              np.asarray(flat.backlog[n]))

    j_base = JaxStreamEngine(g, chaos=base_spec, failover=FO,
                             queue_cap=1e9, phase_mode="compact").run(60.0)
    j_flat = JaxStreamEngine(g, chaos=flat_spec, failover=FO,
                             queue_cap=1e9, phase_mode="compact").run(60.0)
    assert np.array_equal(np.asarray(j_base.source_lag),
                          np.asarray(j_flat.source_lag))
    assert j_flat.emitted == j_base.emitted


def test_inert_autoscale_leaves_are_noop():
    """An engine built WITHOUT a scaler carries the inert autoscale
    leaves; they must not perturb the PR-8 drill-era results (speed
    stays 1, no actions, no thrash)."""
    g = nexmark.q3()
    spec = ChaosSpec(seed=7, host_kill_prob_per_s=0.004)
    m = JaxStreamEngine(g, chaos=spec, failover=FO,
                        phase_mode="compact").run(60.0)
    assert m.n_rescale == 0.0
    assert math.isinf(m.thrash_t)
    n_tasks = sum(o.parallelism for o in g.ops)
    assert m.resource_s == pytest.approx(n_tasks * 60.0)


# ----------------------------------------------------------------------
# (b) full drill parity: rescale-during-recovery across lowerings
# ----------------------------------------------------------------------
def _drill():
    """Flash crowd [90, 130]s, host burst at 110s (inside the hold), a
    diurnal swing and background kills: the scaler reacts to the surge
    while failover recovery is still replaying."""
    return nexmark.traffic_drill_spec(seed=5, host_kill_prob_per_s=0.003)


def test_numpy_matches_jax_rescale_during_recovery():
    g = nexmark.q3()
    spec = _drill()
    m_np = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                        autoscale=DS2).run(150.0)
    m_j = JaxStreamEngine(g, chaos=spec, failover=FO, autoscale=DS2,
                          phase_mode="compact").run(150.0)
    assert m_np.n_rescale > 0, "the surge must actually trigger rescales"
    assert m_j.n_rescale == m_np.n_rescale
    assert m_j.resource_s == pytest.approx(m_np.resource_s, rel=1e-9)
    assert m_j.emitted == pytest.approx(m_np.emitted, rel=1e-9)
    np.testing.assert_allclose(np.asarray(m_j.source_lag),
                               np.asarray(m_np.source_lag), atol=1e-5)
    for n in m_np.backlog:
        np.testing.assert_allclose(np.asarray(m_j.backlog[n]),
                                   np.asarray(m_np.backlog[n]), atol=1e-5)


def test_dense_compact_pallas_agree_under_drill():
    g = nexmark.q3()
    spec = _drill()
    runs = {}
    for mode in ("dense", "compact", "pallas"):
        runs[mode] = JaxStreamEngine(g, chaos=spec, failover=FO,
                                     autoscale=DS2,
                                     phase_mode=mode).run(150.0)
    ref = runs["compact"]
    assert ref.n_rescale > 0
    for mode in ("dense", "pallas"):
        m = runs[mode]
        assert m.n_rescale == ref.n_rescale
        assert m.thrash_t == ref.thrash_t
        assert m.resource_s == pytest.approx(ref.resource_s, abs=1e-9)
        np.testing.assert_allclose(np.asarray(m.source_lag),
                                   np.asarray(ref.source_lag),
                                   rtol=0, atol=1e-12)
        for n in ref.backlog:
            np.testing.assert_allclose(np.asarray(m.backlog[n]),
                                       np.asarray(ref.backlog[n]),
                                       rtol=0, atol=1e-12)


def test_autoscaler_tracks_flash_crowd():
    """Under the flash crowd the scaler buys capacity and beats the
    frozen-parallelism run on integrated source lag."""
    g = nexmark.q3()
    spec = nexmark.traffic_drill_spec(seed=5)
    frozen = JaxStreamEngine(g, chaos=spec, failover=FO,
                             phase_mode="compact").run(150.0)
    scaled = JaxStreamEngine(g, chaos=spec, failover=FO, autoscale=DS2,
                             phase_mode="compact").run(150.0)
    assert scaled.n_rescale > 0
    lag_f = np.asarray(frozen.source_lag)
    lag_s = np.asarray(scaled.source_lag)
    assert lag_s.sum() < 0.8 * lag_f.sum(), \
        "scaling into the surge must beat frozen parallelism on lag"


# ----------------------------------------------------------------------
# (c) guards: thrash latch and failover-aware breaker
# ----------------------------------------------------------------------
def test_thrash_guard_latches_and_halts_actions():
    """A fast square-ish load swing with zero cooldown makes the
    controller flip direction every interval; the guard must latch and
    stop the oscillation instead of rescaling forever."""
    g = nexmark.q3()
    spec = ChaosSpec(seed=2, diurnal=((0.9, 12.0, 0.0),))
    osc = AutoscaleConfig(interval_s=3.0, cooldown_s=0.0, hysteresis=0.02,
                          ewma_alpha=0.9, max_actions=1e18)
    guarded = dataclasses.replace(osc, thrash_flips=4.0,
                                  thrash_window_s=60.0)
    free = dataclasses.replace(osc, thrash_flips=1e18)
    m_g = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                       autoscale=guarded).run(120.0)
    m_f = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                       autoscale=free).run(120.0)
    assert math.isfinite(m_g.thrash_t), "thrash guard must latch"
    assert math.isinf(m_f.thrash_t)
    assert m_g.n_rescale < m_f.n_rescale, \
        "after the latch no further actions fire"
    # same latch in the traced lowering
    j_g = JaxStreamEngine(g, chaos=spec, failover=FO, autoscale=guarded,
                          phase_mode="compact").run(120.0)
    assert math.isfinite(j_g.thrash_t)
    assert j_g.thrash_t == pytest.approx(m_g.thrash_t)
    assert j_g.n_rescale == m_g.n_rescale


def test_breaker_opens_under_kill_storm_and_sheds():
    """Failovers landing right after scale actions trip the traced
    breaker: actions stop and the fleet degrades gracefully by shedding
    load instead of rescaling into the outage."""
    g = nexmark.q3()
    # a fast load swing keeps the controller acting every interval, and
    # the host kills land inside fail_window_s of those actions
    spec = ChaosSpec(seed=4, host_kill_at=((20.0, 0), (22.0, 1), (24.0, 2)),
                     diurnal=((0.9, 12.0, 0.0),))
    hot = AutoscaleConfig(interval_s=3.0, cooldown_s=0.0, hysteresis=0.02,
                          ewma_alpha=0.9, max_actions=1e18,
                          thrash_flips=1e18,
                          breaker_failures=2.0, breaker_reset_s=300.0,
                          fail_window_s=30.0, shed_factor=0.5)
    off = dataclasses.replace(hot, breaker_failures=1e18)
    m_b = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                       autoscale=hot).run(120.0)
    m_o = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                       autoscale=off).run(120.0)
    assert m_b.n_rescale < 0.5 * m_o.n_rescale, \
        "an open breaker must block further scale actions"
    # shed shows up as less work flowing downstream: same breaker
    # trajectory, shed 0.5 vs 1.0, some op's backlog must bend
    noshed = dataclasses.replace(hot, shed_factor=1.0)
    m_n = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                       autoscale=noshed).run(120.0)
    assert any(not np.array_equal(np.asarray(m_b.backlog[n]),
                                  np.asarray(m_n.backlog[n]))
               for n in m_b.backlog), \
        "load shed must actually bend the pipeline"
    # traced parity under the breaker drill
    j_b = JaxStreamEngine(g, chaos=spec, failover=FO, autoscale=hot,
                          phase_mode="compact").run(120.0)
    assert j_b.n_rescale == m_b.n_rescale
    np.testing.assert_allclose(np.asarray(j_b.source_lag),
                               np.asarray(m_b.source_lag), atol=1e-5)


# ----------------------------------------------------------------------
# (d) the traffic cube: ONE sweep_configs call, flat timeline builds
# ----------------------------------------------------------------------
def test_traffic_cube_flat_builds():
    g = nexmark.q3()
    seeds = [1, 2]
    before = timeline_build_count()
    tw = traffic_sweep(
        g, seeds, base_spec=ChaosSpec(seed=0, host_kill_prob_per_s=0.002),
        duration_s=60.0,
        scalers={"off": None, "ds2": DS2},
        traffics={"base": ((), ()),
                  "surge": {"flash": ((20.0, 5.0, 15.0, 2.0),)}},
        failovers={"region": FO,
                   "single": FailoverConfig(mode="single_task")})
    builds = timeline_build_count() - before
    assert builds == len(seeds), \
        "rate schedules and scale events are in-trace only: one " \
        "timeline per seed, flat across all 8 cube config rows"
    assert tw.recovery.shape == (2, 2, 2, len(seeds))
    assert tw.cost.shape == (2, 2, 2, len(seeds))
    assert (tw.rescales[0] == 0).all(), "no-scaler rows never rescale"
    assert (tw.rescales[1] > 0).any(), "the DS2 rows must act"
    # the no-scaler resource bill is exactly flat speed × tasks × time
    n_tasks = sum(o.parallelism for o in g.ops)
    assert np.allclose(tw.cost[0], n_tasks * 60.0)
    assert any("ds2" in lbl for lbl in tw.grid.labels)
    assert any("surge" in lbl for lbl in tw.grid.labels)


def test_run_batch_carries_autoscale_metrics():
    g = nexmark.q3()
    specs = [_drill(), dataclasses.replace(_drill(), seed=9)]
    batch = run_batch(g, specs, duration_s=150.0, failover=FO,
                      autoscale=DS2, phase_mode="compact")
    assert batch.n_rescale.shape == (2,)
    assert (batch.n_rescale > 0).all()
    assert (batch.resource_s > 0).all()
    single = JaxStreamEngine(g, chaos=specs[0], failover=FO,
                             autoscale=DS2, phase_mode="compact").run(150.0)
    assert batch.n_rescale[0] == single.n_rescale
    np.testing.assert_allclose(batch.source_lag[0],
                               np.asarray(single.source_lag),
                               rtol=0, atol=1e-12)


# ----------------------------------------------------------------------
# (e) control-plane regressions: DS2Scaler, backoff, idempotency
# ----------------------------------------------------------------------
def test_scaler_breaker_counts_failures_per_op():
    """A healthy op's successful resize must not mask a flapping op: the
    breaker counts consecutive failures PER OP."""
    cfg = ScalerConfig(cooldown_s=0, ewma_alpha=1.0, breaker_failures=2)
    sc = DS2Scaler(cfg)
    sc.notify_result("flappy", 1.0, success=False)
    sc.notify_result("healthy", 2.0, success=True)   # must NOT reset
    sc.notify_result("flappy", 3.0, success=False)
    m = [OpMetrics("flappy", 50_000, 600_000, 6_000, 10)]
    assert sc.observe(4.0, m) == [], \
        "two flappy failures trip the breaker despite the healthy success"


def test_scaler_stale_pending_rollback_expires():
    """A resize that aged past cooldown_s without a reported failure is
    settled; a later unrelated failure must not roll back to it."""
    cfg = ScalerConfig(cooldown_s=10.0, ewma_alpha=1.0,
                       breaker_failures=100)
    sc = DS2Scaler(cfg)
    d = sc.observe(0.0, [OpMetrics("op", 50_000, 600_000, 6_000, 10)])
    assert d, "the resize must be proposed"
    rb = sc.notify_result("op", 100.0, success=False)
    assert rb is None, \
        "an anchor older than cooldown_s must not produce a rollback"
    # a fresh resize still rolls back on prompt failure
    d2 = sc.observe(101.0, [OpMetrics("op", 90_000, 600_000, 6_000,
                                      d[0].new)])
    assert d2
    rb2 = sc.notify_result("op", 102.0, success=False)
    assert rb2 is not None and rb2.new == d[0].new


def test_retry_chains_the_last_transient():
    clock = VirtualClock()
    boom = TransientError("dependency down")
    with pytest.raises(PermanentError) as ei:
        retry(lambda: (_ for _ in ()).throw(boom),
              RetryPolicy(base_delay_s=0.01, max_attempts=3), clock)
    assert ei.value.__cause__ is boom, \
        "retry must chain the last TransientError for diagnosis"


def test_idempotency_awaits_inflight_token():
    """A duplicate submission arriving while the first is still
    executing must await it and return its result — not re-execute."""
    reg = IdempotencyRegistry()
    started = threading.Event()
    release = threading.Event()
    calls = {"n": 0}

    def slow():
        calls["n"] += 1
        started.set()
        assert release.wait(5.0)
        return "done"

    first = {}

    def runner():
        first["out"] = reg.run("tok", slow)

    th = threading.Thread(target=runner)
    th.start()
    assert started.wait(5.0)
    dup = {}

    def dup_runner():
        dup["out"] = reg.run("tok", slow)

    td = threading.Thread(target=dup_runner)
    td.start()
    release.set()
    th.join(5.0)
    td.join(5.0)
    assert calls["n"] == 1, "the in-flight token must not re-execute"
    assert first["out"] == ("done", False)
    assert dup["out"] == ("done", True)


def test_idempotency_failed_inflight_hands_over_to_waiter():
    """If the first execution raises, the waiter takes over the retry —
    the failed attempt produced no effect to deduplicate against."""
    reg = IdempotencyRegistry()
    started = threading.Event()
    release = threading.Event()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            started.set()
            assert release.wait(5.0)
            raise TransientError("first attempt dies mid-flight")
        return "second"

    err = {}

    def runner():
        try:
            reg.run("tok", flaky)
        except TransientError as e:
            err["e"] = e

    th = threading.Thread(target=runner)
    th.start()
    assert started.wait(5.0)
    out = {}

    def waiter():
        out["r"] = reg.run("tok", flaky)

    tw = threading.Thread(target=waiter)
    tw.start()
    release.set()
    th.join(5.0)
    tw.join(5.0)
    assert "e" in err, "the first caller sees the failure"
    assert out["r"] == ("second", False), \
        "the waiter re-executes after the in-flight failure"
    assert calls["n"] == 2
