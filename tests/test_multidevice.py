"""Multi-device integration (subprocess with forced host devices — the main
process must keep seeing 1 CPU device): EP MoE parity local-vs-shard_map,
distributed-LSE decode parity, mini dry-run lower+compile on a (2,4) mesh,
elastic resharding, LocalSGD pod sync."""
import pytest

from helpers import assert_ok, run_multidevice

pytestmark = pytest.mark.slow


def test_moe_shard_map_matches_local():
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_arch
from repro.dist.sharding import ShardingCtx, DEFAULT_RULES
from repro.models import moe as M
from repro.launch.mesh import make_mesh

cfg = get_smoke_arch("arctic-480b")   # 4 experts top-2 in smoke form
mesh = make_mesh((2, 4), ("data", "model"))
rules = dict(DEFAULT_RULES)
ctx = ShardingCtx(mesh=mesh, rules=rules)

n_slots = 4
pl_ = M.moe_params(cfg, n_slots=n_slots)
pl_loc = M.moe_params(cfg, n_slots=1)
import repro.dist.sharding as shd
rng = jax.random.PRNGKey(0)
params = shd.tree_init(rng, pl_)
# identical logical weights for the local layout
params_loc = dict(params)
for k in ("up", "down", "gate"):
    if k in params:
        w = params[k]
        params_loc[k] = w.reshape((1, n_slots * w.shape[1]) + w.shape[2:])

B, S, d = 4, 16, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.3
keys = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 1000)

from repro.dist.sharding import NO_SHARDING
y_loc, aux_loc, drop_loc = M._local_moe(
    params_loc, x, keys, cfg, mode="strict", rescue=False,
    capacity_factor=64.0)
y_dist, aux_d, drop_d = M.apply_moe(
    params, x, keys, cfg, ctx, mode="strict", rescue=False,
    slot_axes=("model",), capacity_factor=64.0)
err = float(jnp.max(jnp.abs(y_loc - y_dist)))
scale = float(jnp.max(jnp.abs(y_loc))) + 1e-9
assert err / scale < 2e-2, (err, scale)
# aux is a per-shard estimator pmean'd across devices (the standard
# distributed-MoE choice); it differs from the global-batch estimator by a
# covariance term — same scale, not bitwise equal.
assert abs(float(aux_loc) - float(aux_d)) < 0.25 * max(abs(float(aux_loc)), 1.0)
print("moe parity ok", err / scale)
"""
    assert_ok(run_multidevice(code, 8))


def test_distributed_lse_decode_matches_ref():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_arch
from repro.dist.sharding import ShardingCtx
from repro.launch.mesh import make_mesh
from repro.models import attention as A
from repro.kernels.decode_attention import ref as R

mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardingCtx(mesh=mesh)
B, S, KV, hd = 4, 64, 2, 16   # KV=2 < model=4 → kv_seq sharding path
H = 4
q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
valid = 50
out = A._distributed_decode(q, k, v, valid, ctx)
ref = R.decode_attention(q, k, v, kv_valid_len=valid)[:, None]
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-4, err
print("distributed decode ok", err)
"""
    assert_ok(run_multidevice(code, 8))


def test_mini_dryrun_all_kinds():
    """Full lower+compile of train/prefill/decode for a reduced MoE arch and
    a reduced hybrid arch on a (2,4) mesh — the dry-run machinery end to end."""
    code = """
import dataclasses, jax
from repro.configs import registry, base
from repro.configs.base import ShapeConfig
from repro.launch import dryrun
import repro.launch.mesh as mesh_mod

# shrink the production mesh for the test
mesh_mod.make_production_mesh = lambda multi_pod=False: mesh_mod.make_mesh(
    (2, 2, 2) if multi_pod else (2, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"))

for arch in ("arctic-480b", "zamba2-2.7b"):
    smoke = registry.get_smoke_arch(arch)
    for kind, shape in (("train", ShapeConfig("t", 32, 8, "train")),
                        ("prefill", ShapeConfig("p", 32, 8, "prefill")),
                        ("decode", ShapeConfig("d", 32, 8, "decode"))):
        run = base.RunConfig(model=smoke, shape=shape)
        lowered, info = dryrun.lower_cell(run, unroll=False)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        print(arch, kind, "ok")
    run = base.RunConfig(model=smoke, shape=ShapeConfig("t", 32, 8, "train"),
                         multi_pod=True)
    lowered, info = dryrun.lower_cell(run, unroll=False)
    lowered.compile()
    print(arch, "multi-pod ok")
"""
    r = run_multidevice(code, 8)
    assert_ok(r)
    assert r.stdout.count("ok") == 8


def test_elastic_resize_and_localsgd():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.train.elastic import LocalSGDPods, LocalSGDConfig, elastic_resize
from repro.dist.sharding import ShardingCtx
from repro.configs import get_smoke_arch
from repro.models import build

m = build(get_smoke_arch("stablelm-1.6b"))
params = m.init(jax.random.PRNGKey(0))
ctx8 = ShardingCtx(mesh=make_mesh((4, 2), ("data", "model")))
ctx4 = ShardingCtx(mesh=make_mesh((2, 2), ("data", "model")))
pspecs = m.param_pspecs(ctx8)
from repro.train.optimizer import make_optimizer
from repro.configs import OptimizerConfig
opt = make_optimizer(OptimizerConfig())
state = opt.init(params)
ospecs = jax.tree.map(lambda s: s if isinstance(s, jax.sharding.PartitionSpec)
                      else s, opt.state_specs(m.param_specs()))
import repro.dist.sharding as shd
opspec = shd.tree_pspecs(opt.state_specs(m.param_specs()), ctx8)
p2, s2, rep = elastic_resize(params, state, m.param_pspecs(ctx4),
                             opspec, ctx4.mesh)
# values preserved across the shrink (pod-loss survival)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("elastic resize ok", rep.new_devices)

# LocalSGD pod sync: identical pods stay identical; divergent pods average
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
pods = LocalSGDPods(mesh, LocalSGDConfig(compress=True))
w = jnp.ones((8, 8), jnp.float32)
anchor = w
spec_tree = {"w": P()}
sync = pods.sync_fn(spec_tree)
out = sync({"w": w * 3.0}, {"w": anchor})
np.testing.assert_allclose(np.asarray(out["w"]), 3.0, rtol=1e-2)
print("localsgd ok")
"""
    assert_ok(run_multidevice(code, 8))


def test_sharded_chaos_sweep_matches_unsharded():
    """Seed-batch device sharding (repro.dist.sharding shim — pmap on
    this jax, shard_map on >= 0.6): a 16-seed sweep split across 4
    forced host devices must reproduce the single-device vmapped sweep
    to reassociation tolerance, on a packed 2-job arena."""
    code = """
import numpy as np
from repro.core.chaos import ChaosSpec
from repro.dist.sharding import local_shard_count
from repro.streams import nexmark
from repro.streams.engine import FailoverConfig, pack_arena
from repro.streams.jax_engine import run_batch

assert local_shard_count("auto") == 4
arena = pack_arena([nexmark.q2(parallelism=8, partitioner="weakhash",
                               n_groups=4),
                    nexmark.q12(parallelism=8)], "shared", n_hosts=8)
spec = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
fo = FailoverConfig(mode="region", region_restart_s=20.0)
a = run_batch(arena, range(16), base_spec=spec, duration_s=60,
              failover=fo)
b = run_batch(arena, range(16), base_spec=spec, duration_s=60,
              failover=fo, devices="auto")
np.testing.assert_allclose(a.source_lag, b.source_lag, rtol=1e-12,
                           atol=1e-9)
np.testing.assert_allclose(a.emitted_by_job, b.emitted_by_job,
                           rtol=1e-12)
np.testing.assert_allclose(a.backlog, b.backlog, rtol=1e-9, atol=1e-6)
print("sharded sweep ok", b.source_lag.shape)
"""
    assert_ok(run_multidevice(code, 4))


def test_pipeline_parallel_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.dist.pipeline_parallel import pipeline_apply

mesh = make_mesh((4,), ("pipe",))
n_stages, L_per, d = 4, 2, 16
rng = jax.random.PRNGKey(0)
ws = jax.random.normal(rng, (n_stages, L_per, d, d)) * 0.1

def block(params, h):  # params (L_per, d, d)
    def layer(h, w):
        return jnp.tanh(h @ w), None
    h, _ = jax.lax.scan(layer, h, params)
    return h

x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
y_pipe = pipeline_apply(mesh, block, ws, x, n_micro=4)
# sequential reference
h = x
for s in range(n_stages):
    h = block(ws[s], h)
err = float(jnp.max(jnp.abs(y_pipe - h)))
assert err < 1e-5, err
print("pipeline parallel ok", err)
"""
    assert_ok(run_multidevice(code, 4))
