"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True) vs the
pure-jnp oracle in ref.py — the assigned kernel deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(7)


def _rand(shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------
FLASH_CASES = [
    # B, Sq, Sk, H, KV, D, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 2, 2, 32, True, 64, jnp.float32),
    (2, 128, 128, 8, 2, 64, False, 0, jnp.float32),
    (1, 64, 64, 4, 1, 128, True, 0, jnp.float32),
    (1, 128, 128, 4, 4, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_fwd_bwd(case):
    from repro.kernels.flash_attention import kernel as K, ref as R
    B, Sq, Sk, H, KV, D, causal, window, dtype = case
    q, k, v = (_rand((B, Sq, H, D), dtype), _rand((B, Sk, KV, D), dtype),
               _rand((B, Sk, KV, D), dtype))
    out = K.flash_attention(q, k, v, causal=causal, window=window,
                            interpret=True, block_q=64, block_k=64)
    refo = R.attention(q, k, v, causal=causal, window=window, chunk=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refo, np.float32), atol=tol, rtol=tol)
    if dtype == jnp.float32:
        f = lambda *a: (K.flash_attention(*a, causal=causal, window=window,
                                          interpret=True, block_q=64,
                                          block_k=64) ** 2).sum()
        g = lambda *a: (R.attention(*a, causal=causal, window=window,
                                    chunk=64).astype(jnp.float32) ** 2).sum()
        gk = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-4)


def test_flash_ref_matches_exact_blocks():
    from repro.kernels.flash_attention import ref as R
    q, k, v = (_rand((1, 256, 4, 32), jnp.float32),
               _rand((1, 256, 2, 32), jnp.float32),
               _rand((1, 256, 2, 32), jnp.float32))
    a = R.attention(q, k, v, causal=True, chunk=64)
    b = R.attention_exact_blocks(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------
DECODE_CASES = [
    (2, 512, 8, 2, 64, 300, 0, None),
    (1, 1024, 4, 4, 128, 1024, 0, None),
    (2, 256, 4, 1, 64, 200, 128, 220),
    (1, 384, 2, 2, 64, None, 0, None),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention(case):
    from repro.kernels.decode_attention import kernel as K, ref as R
    B, S, H, KV, D, valid, win, pos = case
    q = _rand((B, 1, H, D), jnp.float32)
    k = _rand((B, S, KV, D), jnp.float32)
    v = _rand((B, S, KV, D), jnp.float32)
    o = K.decode_attention(q, k, v, kv_valid_len=valid, window=win, pos=pos,
                           block_k=128, interpret=True)
    orf = R.decode_attention(q, k, v, kv_valid_len=valid, window=win, pos=pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-5,
                               rtol=2e-5)


def test_decode_partial_merge_equals_full():
    """Sharded partial (m,l,o) merge == unsharded attention — the invariant
    behind the distributed-LSE decode path."""
    from repro.kernels.decode_attention import ref as R
    B, S, H, KV, D = 2, 512, 4, 2, 64
    q = _rand((B, 1, H, D), jnp.float32)
    k = _rand((B, S, KV, D), jnp.float32)
    v = _rand((B, S, KV, D), jnp.float32)
    valid = 400
    full = R.decode_attention(q, k, v, kv_valid_len=valid)
    n_sh = 4
    parts = []
    for i in range(n_sh):
        sl = slice(i * S // n_sh, (i + 1) * S // n_sh)
        parts.append(R.decode_attention_partial(
            q, k[:, sl], v[:, sl], kv_valid_len=valid,
            k_offset=i * S // n_sh))
    os_, ms, ls = (jnp.stack([p[j] for p in parts]) for j in range(3))
    merged = R.merge_partials(os_, ms, ls)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------
SSD_CASES = [
    (2, 512, 8, 64, 32, 128),
    (1, 256, 4, 32, 16, 64),
    (1, 128, 16, 64, 128, 128),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_vs_refs(case):
    from repro.kernels.ssd_scan import kernel as K, ref as R
    B, S, H, P, N, chunk = case
    x = _rand((B, S, H, P), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = _rand((B, S, N), jnp.float32) * 0.3
    Cm = _rand((B, S, N), jnp.float32) * 0.3
    D = _rand((H,), jnp.float32)
    yk, stk = K.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True,
                         head_block=min(4, H))
    yr, str_ = R.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    yn, stn = R.ssd_scan_naive(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yn), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(stn), atol=1e-4,
                               rtol=1e-4)


def test_ssd_decode_step_matches_scan():
    from repro.kernels.ssd_scan import ref as R
    B, S, H, P, N = 1, 64, 4, 16, 8
    x = _rand((B, S, H, P), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = _rand((B, S, N), jnp.float32) * 0.3
    Cm = _rand((B, S, N), jnp.float32) * 0.3
    y_full, st_full = R.ssd_scan_naive(x, dt, A, Bm, Cm)
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, st = R.ssd_decode_step(st, x[:, t], dt[:, t], A, Bm[:, t],
                                    Cm[:, t])
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_full), atol=1e-4,
                               rtol=1e-4)


# ----------------------------------------------------------------------
# weakhash routing
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode,n_groups,E,k", [
    ("strict", 1, 16, 2), ("weakhash", 4, 16, 2), ("weakhash", 8, 64, 2),
    ("strict", 1, 8, 1),
])
def test_weakhash_kernel_parity(mode, n_groups, E, k):
    from repro.kernels.weakhash_route import kernel as K, ref as R
    T = 512
    logits = _rand((T, E), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 10_000, T), jnp.int32)
    cap = 4 * T // E
    rk = K.weakhash_route(logits, top_k=k, capacity=cap, n_groups=n_groups,
                          mode=mode, token_keys=keys, interpret=True)
    rr = R.weakhash_route(logits, top_k=k, capacity=cap, n_groups=n_groups,
                          mode=mode, token_keys=keys)
    assert bool(jnp.all(rk.expert_idx == rr.expert_idx))
    assert bool(jnp.all(rk.position == rr.position))
    assert bool(jnp.all(rk.keep == rr.keep))
    np.testing.assert_allclose(np.asarray(rk.weights), np.asarray(rr.weights),
                               atol=1e-6)


def test_weakhash_group_containment():
    """WeakHash invariant: every selected expert lies in the token's group."""
    from repro.kernels.weakhash_route import ref as R
    T, E, G = 256, 32, 8
    logits = _rand((T, E), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 1 << 30, T), jnp.int32)
    r = R.weakhash_route(logits, top_k=2, capacity=64, n_groups=G,
                         mode="weakhash", token_keys=keys)
    gsz = E // G
    assert bool(jnp.all(r.expert_idx // gsz == r.group_id[:, None]))


def test_dispatch_combine_roundtrip():
    """With ample capacity and top-1 routing of one-hot-friendly inputs,
    dispatch→identity-expert→combine reproduces the input."""
    from repro.kernels.weakhash_route import ref as R
    T, E, d = 64, 4, 8
    x = _rand((T, d), jnp.float32)
    logits = _rand((T, E), jnp.float32)
    r = R.weakhash_route(logits, top_k=1, capacity=T, n_groups=1,
                         mode="strict")
    buf = R.dispatch(x, r, E, T)
    y = R.combine(buf, r, T)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_weakhash_carry_forward_single_tile_matches_exact():
    """Carry-forward with a zero prior and ONE token tile sees the full
    batch histogram before selecting — it must reproduce the exact
    two-phase kernel bit-for-bit (the parity anchor of the
    approximation)."""
    from repro.kernels.weakhash_route import kernel as K
    T, E, G, k = 256, 16, 4, 2
    logits = _rand((T, E), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 10_000, T), jnp.int32)
    cap = 4 * T // E
    kw = dict(top_k=k, capacity=cap, n_groups=G, token_keys=keys,
              block_t=T, interpret=True)
    exact = K.weakhash_route_ints(logits, **kw)
    carry = K.weakhash_route_ints(logits, carry_forward=True, **kw)
    for a, b, name in zip(exact, carry, ("idx", "pos", "gid", "demand")):
        assert bool(jnp.all(a == b)), name


def test_weakhash_carry_forward_multi_tile_single_pass():
    """nt > 1: the single-pass variant keeps every structural invariant
    (group containment, valid arrival positions, demand export == the
    exact batch top-1 histogram) and chaining a prior demand shifts
    selections away from the previously-loaded experts."""
    from repro.kernels.weakhash_route import kernel as K, ref as R
    T, E, G, k = 512, 16, 4, 2
    logits = _rand((T, E), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 10_000, T), jnp.int32)
    cap = 4 * T // E
    kw = dict(top_k=k, capacity=cap, n_groups=G, token_keys=keys,
              block_t=128, interpret=True)
    exact = K.weakhash_route_ints(logits, **kw)
    carry = K.weakhash_route_ints(logits, carry_forward=True, **kw)
    gsz = E // G
    assert bool(jnp.all(carry[0] // gsz == carry[2][:, None]))
    # demand export is the batch's own top-1 histogram — identical to the
    # exact kernel's phase-0 export, so batches chain losslessly
    assert bool(jnp.all(carry[3] == exact[3]))
    # positions are a valid arrival order: recomputing token-major
    # positions from idx gives a permutation with the same per-expert
    # counts
    pos_ref = R._positions_in_expert(carry[0], E)
    counts_a = jnp.bincount(carry[0].reshape(-1), length=E)
    counts_b = jnp.bincount(exact[0].reshape(-1), length=E)
    assert int(counts_a.sum()) == int(counts_b.sum()) == T * k
    assert bool(jnp.all(pos_ref < T * k))
    # chaining: a heavy prior on one expert pushes selections off it
    hot = int(jnp.argmax(carry[3]))
    prior = jnp.zeros((E,), jnp.float32).at[hot].set(10.0 * cap)
    chained = K.weakhash_route_ints(logits, carry_forward=True,
                                    prior_demand=prior, **kw)
    sel = lambda r: int(jnp.sum(r[0] == hot))  # noqa: E731
    assert sel(chained) < sel(carry)


def test_weakhash_carry_forward_deterministic():
    from repro.kernels.weakhash_route import kernel as K
    T, E = 256, 8
    logits = _rand((T, E), jnp.float32)
    kw = dict(top_k=1, capacity=64, n_groups=1, mode="strict",
              block_t=128, interpret=True)
    a = K.weakhash_route_ints(logits, carry_forward=True, **kw)
    b = K.weakhash_route_ints(logits, carry_forward=True, **kw)
    for x, y in zip(a, b):
        assert bool(jnp.all(x == y))
