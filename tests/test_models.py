"""Model-level correctness: decode/teacher-forcing parity across all
families, MoE routing semantics, SWA ring caches, optimizers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (Family, OptimizerConfig, ShapeConfig,
                           get_smoke_arch)
from repro.dist import NO_SHARDING
from repro.models import build
from repro.models import encdec, hybrid, mamba_lm, transformer
from repro.train.optimizer import clip_by_global_norm, make_optimizer

PARITY_ARCHS = ["stablelm-1.6b", "granite-34b", "mamba2-1.3b", "zamba2-2.7b",
                "whisper-medium", "phi-3-vision-4.2b"]


def _full_logits(cfg, params, batch):
    fam = cfg.family.value
    if fam in ("dense", "moe", "vlm"):
        lg, _ = transformer.forward(params, batch, cfg, NO_SHARDING,
                                    remat="none",
                                    moe_opts={"mode": "strict",
                                              "capacity_factor": 8.0})
        return lg
    if fam == "ssm":
        lg, _ = mamba_lm.forward(params, batch, cfg, NO_SHARDING,
                                 remat="none")
        return lg
    if fam == "hybrid":
        lg, _ = hybrid.forward(params, batch, cfg, NO_SHARDING, remat="none")
        return lg
    enc = encdec.encode(params, batch["frames"].astype(jnp.bfloat16), cfg,
                        NO_SHARDING, remat="none")
    return encdec.decode_train(params, batch["tokens"], enc, cfg, NO_SHARDING,
                               remat="none")


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_arch(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    S = 16
    batch = m.demo_batch(ShapeConfig("p", S, 2, "prefill"),
                         jax.random.PRNGKey(2))
    full = _full_logits(cfg, params, batch)
    ntok = batch["tokens"].shape[1]
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :ntok - 1]
    mo = {"mode": "strict", "capacity_factor": 8.0}
    lg, cache, pos = m.prefill(params, b2, NO_SHARDING, s_max=S, moe_opts=mo)
    lg2, _ = m.decode_step(params, cache, batch["tokens"][:, ntok - 1:],
                           jnp.asarray(pos, jnp.int32), NO_SHARDING,
                           moe_opts=mo)
    a = np.asarray(full[:, -1], np.float32)
    b = np.asarray(lg2[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-6)
    assert err < 0.05, (arch, err)


def test_swa_ring_cache_continuation():
    """Sliding-window ring: decode after a prefill longer than the window
    matches teacher forcing (mixtral family)."""
    cfg = dataclasses.replace(get_smoke_arch("mixtral-8x22b"), swa_window=8)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    S = 20  # > window, window ∤ S
    batch = m.demo_batch(ShapeConfig("p", S, 2, "prefill"),
                         jax.random.PRNGKey(3))
    mo = {"mode": "strict", "capacity_factor": 8.0}
    full = _full_logits(cfg, params, batch)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :S - 1]
    lg, cache, pos = m.prefill(params, b2, NO_SHARDING, s_max=S, moe_opts=mo)
    lg2, _ = m.decode_step(params, cache, batch["tokens"][:, S - 1:],
                           jnp.asarray(pos, jnp.int32), NO_SHARDING,
                           moe_opts=mo)
    err = np.max(np.abs(np.asarray(full[:, -1], np.float32)
                        - np.asarray(lg2[:, 0], np.float32)))
    scalev = np.max(np.abs(np.asarray(full[:, -1], np.float32))) + 1e-6
    assert err / scalev < 0.05, err / scalev


# ----------------------------------------------------------------------
# MoE semantics
# ----------------------------------------------------------------------
def test_moe_rescue_keeps_all_tokens():
    from repro.kernels.weakhash_route import ref as R
    T, E = 128, 8
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(T, E)),
                         jnp.float32)
    # tight capacity (aggregate == T·k) → strict mode drops on imbalance;
    # rescue must re-route overflow toward spare experts (γ=full)
    cap = 2 * T // E
    r_drop = R.weakhash_route(logits, top_k=2, capacity=cap, mode="strict")
    r_rescue = R.weakhash_route(logits, top_k=2, capacity=cap, mode="strict",
                                rescue=True)
    assert float(r_drop.keep.mean()) < 1.0
    assert float(r_rescue.keep.mean()) > float(r_drop.keep.mean())


def test_moe_weakhash_reduces_hot_expert_overflow():
    from repro.kernels.weakhash_route import ref as R
    rng = np.random.default_rng(1)
    T, E = 1024, 16
    logits = rng.normal(size=(T, E)).astype(np.float32)
    logits[:, 3] += 3.0  # hot expert
    keys = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.int32)
    cap = 2 * T // E
    strict = R.weakhash_route(jnp.asarray(logits), top_k=2, capacity=cap,
                              mode="strict")
    weak = R.weakhash_route(jnp.asarray(logits), top_k=2, capacity=cap,
                            n_groups=4, mode="weakhash", token_keys=keys)
    assert float(weak.demand.max()) < float(strict.demand.max()), \
        "load-aware group routing must flatten the hot expert"
    assert float(weak.keep.mean()) > float(strict.keep.mean())


def test_local_moe_forward_finite():
    cfg = get_smoke_arch("arctic-480b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.demo_batch(ShapeConfig("t", 32, 2, "train"))
    for mode in ("strict", "weakhash"):
        loss, aux = m.loss_fn(params, batch, NO_SHARDING,
                              moe_opts={"mode": mode})
        assert jnp.isfinite(loss)
        assert 0.0 <= float(aux["drop_frac"]) < 0.5


# ----------------------------------------------------------------------
# optimizers
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizer_descends_quadratic(name):
    opt = make_optimizer(OptimizerConfig(name=name, lr=0.1, weight_decay=0.0))
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]),
              "b": jnp.ones((4, 5)) * 2.0}
    state = opt.init(params)
    loss = lambda p: (p["w"] ** 2).sum() + (p["b"] ** 2).sum()
    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert loss(params) < 0.2 * l0, (name, float(loss(params)))


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizer_state_specs_match_init(name):
    from repro.dist import sharding as shd
    cfg = get_smoke_arch("minitron-8b")
    m = build(cfg)
    opt = make_optimizer(OptimizerConfig(name=name))
    params = m.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    specs = opt.state_specs(m.param_specs())
    abstract = shd.tree_abstract(specs)
    real = jax.tree.map(lambda x: (x.shape, str(x.dtype)), state)
    spec = jax.tree.map(lambda s: (s.shape, str(s.dtype)), abstract)
    assert real == spec


def test_grad_clip_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    from repro.train.optimizer import global_norm
    assert float(norm) > 1.0
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ----------------------------------------------------------------------
# gradient compression (beyond-paper distributed-optimization trick)
# ----------------------------------------------------------------------
def test_int8_compression_error_feedback_unbiased():
    from repro.train.elastic import compress_tree
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    residual = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    total_true = jnp.zeros((256,))
    total_sent = jnp.zeros((256,))
    for _ in range(50):
        q, s, residual = compress_tree(g, residual)
        from repro.train.elastic import dequantize_int8
        total_sent += dequantize_int8(q["w"], s["w"])
        total_true += g["w"]
    # error feedback: accumulated transmitted ≈ accumulated true
    rel = float(jnp.linalg.norm(total_sent - total_true)
                / jnp.linalg.norm(total_true))
    assert rel < 0.01, rel
