# NOTE: deliberately does NOT set xla_force_host_platform_device_count —
# smoke tests and benches must see 1 device; multi-device tests run in
# subprocesses (tests/helpers.py).
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).parent))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")
