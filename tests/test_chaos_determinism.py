"""Chaos determinism regression pins (paper §V-B: every drill must be
reproducible bit-for-bit) plus the pregenerated-event-tensor contract:
`build_chaos_timeline` must consume the chaos rng stream draw-for-draw
as the live engine does, so a timeline is interchangeable with
sequential draws."""
import numpy as np

from repro.core.chaos import ChaosEngine, ChaosSpec, build_chaos_timeline
from repro.streams import nexmark
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine)


def test_chaos_engine_streams_are_deterministic():
    spec = ChaosSpec(seed=3, host_kill_prob_per_s=0.01,
                     storage_slow_prob=0.3, storage_slow_factor=8.0,
                     straggler_frac=0.25)
    a, b = ChaosEngine(spec), ChaosEngine(spec)
    for h in range(8):
        assert a.host_speed(h) == b.host_speed(h)
    for i in range(200):
        t0, t1 = i * 0.5, (i + 1) * 0.5
        ka = a.step_kills(t0, t1, n_hosts=8)
        kb = b.step_kills(t0, t1, n_hosts=8)
        assert ka == kb
        for h in ka:
            a.revive(h)
            b.revive(h)
        np.testing.assert_array_equal(a.storage_latency_factors(16),
                                      b.storage_latency_factors(16))


def test_pregenerated_kill_tensor_matches_sequential_draws():
    spec = ChaosSpec(seed=11, host_kill_prob_per_s=0.02)
    n_ticks, dt, n_hosts = 400, 0.5, 8
    task_host = np.arange(16) % n_hosts
    tl = build_chaos_timeline(
        spec, n_ticks=n_ticks, dt=dt, n_hosts=n_hosts,
        task_host=task_host, task_region=np.zeros(16, int),
        regions=[set(range(16))], failover_mode="region")
    assert tl.kills.any()
    eng = ChaosEngine(spec)
    t = 0.0
    for i in range(n_ticks):
        kills = eng.step_kills(t, t + dt, n_hosts=n_hosts)
        assert np.nonzero(tl.kills[i])[0].tolist() == kills, i
        for h in kills:
            eng.revive(h)
        t += dt


def test_timeline_rejects_desynchronizing_defaults():
    """Configurations that would silently diverge from the live engine's
    rng consumption (or crash mid-replay) must fail fast."""
    import pytest
    spec = ChaosSpec(seed=0, host_kill_prob_per_s=0.05)
    with pytest.raises(ValueError, match="task_region"):
        build_chaos_timeline(spec, n_ticks=10, dt=0.5, n_hosts=4,
                             task_host=np.arange(8) % 4,
                             failover_mode="region")
    with pytest.raises(ValueError, match="regions"):
        build_chaos_timeline(ChaosSpec(seed=0), n_ticks=10, dt=0.5,
                             n_hosts=4, task_host=np.arange(8) % 4,
                             failover_mode="none", ckpt_interval_s=2.0)


def test_timeline_is_reproducible():
    spec = ChaosSpec(seed=4, host_kill_prob_per_s=0.01,
                     storage_slow_prob=0.2, straggler_frac=0.3)
    kw = dict(n_ticks=300, dt=0.5, n_hosts=6,
              task_host=np.arange(12) % 6,
              task_region=np.arange(12) % 3,
              regions=[set(range(0, 4)), set(range(4, 8)),
                       set(range(8, 12))],
              failover_mode="region", ckpt_interval_s=30.0)
    a = build_chaos_timeline(spec, **kw)
    b = build_chaos_timeline(spec, **kw)
    np.testing.assert_array_equal(a.kills, b.kills)
    np.testing.assert_array_equal(a.task_speed, b.task_speed)
    np.testing.assert_array_equal(a.ckpt_ok, b.ckpt_ok)
    assert a.recoveries == b.recoveries


def test_timeline_matches_live_engine_run():
    """Integration pin: the pregenerated timeline reproduces the live
    numpy engine's straggler speeds, recovery events and checkpoint
    outcomes — interleaved kill + storage draws included."""
    spec = ChaosSpec(seed=5, host_kill_prob_per_s=0.002,
                     straggler_frac=0.25, storage_slow_prob=0.2,
                     storage_slow_factor=12)
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    ck = CheckpointConfig(interval_s=40, mode="region")
    eng = StreamEngine(nexmark.ds(parallelism=6), n_hosts=6,
                       chaos=ChaosEngine(spec), failover=fo, ckpt=ck)
    m = eng.run(500)
    tl = build_chaos_timeline(
        spec, n_ticks=1000, dt=eng.dt, n_hosts=eng._n_hosts,
        task_host=eng._task_host, task_region=eng._task_region,
        regions=eng.phys.regions, failover_mode=fo.mode,
        detect_s=fo.detect_s, region_restart_s=fo.region_restart_s,
        single_restart_s=fo.single_restart_s,
        ckpt_interval_s=ck.interval_s, ckpt_mode=ck.mode,
        ckpt_upload_s=ck.upload_s, ckpt_retry=ck.retry_failed_region)
    np.testing.assert_array_equal(tl.task_speed, eng._speed)
    assert tl.recoveries == m.recoveries
    assert len(tl.recoveries) > 0
    assert (tl.ckpt_attempts, tl.ckpt_success, tl.ckpt_failed) == \
        (m.ckpt_attempts, m.ckpt_success, m.ckpt_failed)
    np.testing.assert_array_equal(tl.ts, np.array(m.t))
