"""Deployment-drill chaos: traced canary/rolling upgrades with in-trace
auto-rollback.

Pins the drill contract across all engine lowerings:

* an upgrade to an *identical* config with zero wave downtime is a
  bit-exact no-op (graceful waves never touch queues or draw streams);
* an induced canary regression fires the auto-rollback while the STABLE
  slice stays in parity with a never-upgraded run — checked against the
  pre-vectorization `ReferenceStreamEngine` oracle at 1e-5;
* dense == compact at 1e-12 under a full drill (waves + canary config
  deltas + rollback + external-system chaos);
* hot deploys are strictly cheaper than cold across the whole
  `StartupConfig.policy_grid()`;
* the `deployment_drill` cube comes out of ONE `sweep_configs` call
  with `timeline_build_count` flat (upgrades are in-trace only).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.chaos import (ChaosEngine, ChaosSpec,
                              timeline_build_count)
from repro.core.hotupdate import deploy_downtime
from repro.core.startup import StartupConfig
from repro.streams import nexmark
from repro.streams.chaos_sweep import deployment_drill
from repro.streams.engine import (FailoverConfig, StreamEngine,
                                  UpgradeConfig)
from repro.streams.jax_engine import JaxStreamEngine, run_batch
from repro.streams.reference_engine import ReferenceStreamEngine

FO = FailoverConfig(mode="single_task", detect_s=1.0, single_restart_s=2.0)
# induced-regression drill: canary selectivity scale (1.5) exceeds the
# fleet's downstream sink headroom (1.2), so the canary slice's sinks
# overload while the stable slice keeps draining
REGRESSION = UpgradeConfig(t_upgrade_s=10.0, wave_stagger_s=1.0, hot=True,
                           canary_sel_scale=1.5,
                           rollback_threshold=100.0,
                           rollback_window_s=4.0)


# ----------------------------------------------------------------------
# (a) identical-config upgrade == no-op (graceful waves, bit-exact)
# ----------------------------------------------------------------------
def test_identical_config_upgrade_is_noop():
    g = nexmark.q3()
    spec = ChaosSpec(seed=3, host_kill_prob_per_s=0.002)
    noop = UpgradeConfig(t_upgrade_s=10.0, wave_stagger_s=1.0,
                         wave_down_s=0.0)   # same config, free waves
    base = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                        queue_cap=1e9).run(60.0)
    drill = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                         queue_cap=1e9, upgrade=noop).run(60.0)
    assert np.array_equal(np.asarray(base.source_lag),
                          np.asarray(drill.source_lag))
    assert drill.emitted == base.emitted
    assert drill.dropped == base.dropped
    assert math.isinf(drill.rollback_t)
    for n in base.backlog:
        assert np.array_equal(np.asarray(base.backlog[n]),
                              np.asarray(drill.backlog[n]))


def test_upgrade_waves_pay_restart_downtime_then_recover():
    """Hot waves with real downtime pause each region-sized slice (a
    wave takes down a whole failover region, sources included, so the
    cost surfaces as paused emission), then the fleet returns to the
    drill-free steady state."""
    g = nexmark.q3()
    spec = ChaosSpec(seed=3)
    up = UpgradeConfig(t_upgrade_s=10.0, wave_stagger_s=2.0, hot=True)
    base = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                        queue_cap=1e9).run(120.0)
    drill = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                         queue_cap=1e9, upgrade=up).run(120.0)
    assert drill.emitted < base.emitted, \
        "sources pause during their own waves"
    # the pause is the wave downtime: emission deficit ≈ rate × down_s
    rate = sum(o.source_rate for o in g.ops if o.is_source)
    deficit = base.emitted - drill.emitted
    down = deploy_downtime(None, hot=True)
    assert deficit == pytest.approx(rate * down, rel=0.25)
    bk_b = sum(np.asarray(base.backlog[n]) for n in base.backlog)
    bk_d = sum(np.asarray(drill.backlog[n]) for n in drill.backlog)
    assert bk_d[-1] == pytest.approx(bk_b[-1], abs=1e-6), \
        "fleet must drain back to drill-free steady state"


# ----------------------------------------------------------------------
# (b) induced regression: rollback fires, stable slice stays in parity
#     with a never-upgraded run (vs the reference-engine oracle, 1e-5)
# ----------------------------------------------------------------------
def test_rollback_fires_and_stable_slice_matches_reference():
    arena = nexmark.drill_fleet(n_jobs=2, host_map="disjoint",
                                queue_cap=1e9)
    spec = ChaosSpec(seed=0)          # chaos-free: the drill IS the event
    up = dataclasses.replace(REGRESSION, canary_jobs=(0,))
    batch = run_batch(arena, [spec], duration_s=60.0, failover=FO,
                      n_hosts=16, upgrade=up, phase_mode="compact")

    # the induced regression must trip the in-trace controller
    assert np.isfinite(batch.rollback_t[0]), \
        "auto-rollback must fire on the canary slice"
    t_rb = float(batch.rollback_t[0])
    assert t_rb > up.t_upgrade_s

    # job 1 (q11) never upgraded: its SLO metrics match a standalone
    # never-upgraded run on the pre-vectorization oracle
    stable = batch.job_view(arena.jobs[1])
    ref = ReferenceStreamEngine(nexmark.q11(), n_hosts=16, dt=0.5,
                                queue_cap=1e9,
                                chaos=ChaosEngine(spec), failover=FO)
    ref_m = ref.run(60.0)
    lag_ref = np.asarray(ref_m.source_lag)
    np.testing.assert_allclose(stable.source_lag[0], lag_ref, atol=1e-5)
    for n in stable.op_names:
        col = stable.op_names.index(n)
        np.testing.assert_allclose(stable.backlog[0][:, col],
                                   np.asarray(ref_m.backlog[n]),
                                   atol=1e-5)

    # ... while the canary job (q3) visibly regressed vs its own
    # never-upgraded reference during the canary window
    canary = batch.job_view(arena.jobs[0])
    ref_c = ReferenceStreamEngine(nexmark.q3(), n_hosts=16, dt=0.5,
                                  queue_cap=1e9,
                                  chaos=ChaosEngine(spec), failover=FO)
    ref_cm = ref_c.run(60.0)
    sink = canary.op_names.index("sink")
    dev = np.abs(canary.backlog[0][:, sink]
                 - np.asarray(ref_cm.backlog["sink"])).max()
    assert dev > 100.0, "canary slice's sink must actually regress"


def test_rollback_reverts_canary_config():
    """After the rollback wave the canary slice runs base config again:
    its backlog drains instead of growing without bound."""
    g = nexmark.q3()
    spec = ChaosSpec(seed=0)
    up = dataclasses.replace(REGRESSION, canary_frac=1.0)
    m = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                     queue_cap=1e9, upgrade=up).run(120.0)
    assert math.isfinite(m.rollback_t)
    held = StreamEngine(
        g, chaos=ChaosEngine(spec), failover=FO, queue_cap=1e9,
        upgrade=dataclasses.replace(up, rollback_threshold=math.inf),
    ).run(120.0)
    assert math.isinf(held.rollback_t)
    sink_rb = np.asarray(m.backlog["sink"])
    sink_held = np.asarray(held.backlog["sink"])
    assert sink_held[-1] > 10.0 * max(sink_rb[-1], 1e-9), \
        "without rollback the regressed sink keeps diverging"
    assert sink_rb[-1] < sink_rb.max() / 2.0, \
        "after rollback the canary backlog must drain"


# ----------------------------------------------------------------------
# (c) dense == compact at 1e-12 under a full drill
# ----------------------------------------------------------------------
def test_dense_equals_compact_under_full_drill():
    arena = nexmark.drill_fleet(n_jobs=4, queue_cap=1e9)
    spec = ChaosSpec(seed=11, host_kill_prob_per_s=0.002,
                     zk_down=((12.0, 18.0),), hdfs_down=((15.0, 22.0),),
                     brownout_at=((5.0, 40.0, 3.0),))
    up = dataclasses.replace(
        REGRESSION, canary_frac=0.5,
        canary_failover=FailoverConfig(mode="single_task", detect_s=2.0,
                                       single_restart_s=4.0))
    runs = {}
    for mode in ("dense", "compact"):
        m = JaxStreamEngine(arena, chaos=spec, failover=FO,
                            upgrade=up, phase_mode=mode).run(60.0)
        runs[mode] = m
    d, c = runs["dense"], runs["compact"]
    assert d.rollback_t == c.rollback_t
    np.testing.assert_allclose(np.asarray(d.source_lag),
                               np.asarray(c.source_lag),
                               rtol=0, atol=1e-12)
    for n in d.backlog:
        np.testing.assert_allclose(np.asarray(d.backlog[n]),
                                   np.asarray(c.backlog[n]),
                                   rtol=0, atol=1e-12)
    assert d.emitted == pytest.approx(c.emitted, abs=1e-12)
    assert d.dropped == pytest.approx(c.dropped, abs=1e-12)


def test_numpy_matches_jax_under_full_drill():
    arena = nexmark.drill_fleet(n_jobs=4, queue_cap=1e9)
    spec = ChaosSpec(seed=11, host_kill_prob_per_s=0.002,
                     zk_down=((12.0, 18.0),), hdfs_down=((15.0, 22.0),))
    up = dataclasses.replace(REGRESSION, canary_frac=0.5)
    m_np = StreamEngine(arena, chaos=ChaosEngine(spec), failover=FO,
                        upgrade=up).run(60.0)
    m_j = JaxStreamEngine(arena, chaos=spec, failover=FO,
                          upgrade=up, phase_mode="compact").run(60.0)
    assert m_j.rollback_t == pytest.approx(m_np.rollback_t)
    np.testing.assert_allclose(np.asarray(m_j.source_lag),
                               np.asarray(m_np.source_lag), atol=1e-5)


# ----------------------------------------------------------------------
# (d) hot restarts strictly cheaper than cold across the startup grid
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cfg", StartupConfig.policy_grid(),
                         ids=lambda c: f"reuse={int(c.object_reuse)}"
                                       f",batch={int(c.batched_deploy)}"
                                       f",strag="
                                       f"{int(c.straggler_mitigation)}")
def test_hot_deploy_strictly_cheaper_than_cold(cfg):
    hot = deploy_downtime(cfg, hot=True)
    cold = deploy_downtime(cfg, hot=False)
    assert 0.0 < hot < cold


def test_wave_downtime_lowered_from_startup_policy():
    """An accelerated startup config lowers the per-wave downtime, and
    that downtime lands in the traced wave arithmetic."""
    fast = StartupConfig()            # all accelerations on
    slow = StartupConfig.baseline()
    assert deploy_downtime(fast, hot=False) < deploy_downtime(slow,
                                                              hot=False)
    g = nexmark.q3()
    spec = ChaosSpec(seed=0)
    emitted = {}
    for name, st_cfg in (("fast", fast), ("slow", slow)):
        up = UpgradeConfig(t_upgrade_s=10.0, hot=False, startup=st_cfg)
        m = StreamEngine(g, chaos=ChaosEngine(spec), failover=FO,
                         queue_cap=1e9, upgrade=up).run(90.0)
        emitted[name] = m.emitted
    assert emitted["fast"] > emitted["slow"], \
        "shorter cold waves pause the sources for less total time"


# ----------------------------------------------------------------------
# (e) the drill cube: ONE sweep_configs call, flat timeline builds
# ----------------------------------------------------------------------
def test_deployment_drill_cube_flat_builds():
    arena = nexmark.drill_fleet(n_jobs=2, queue_cap=1e9)
    seeds = [1, 2]
    before = timeline_build_count()
    cube = deployment_drill(
        arena, seeds, base_spec=ChaosSpec(seed=0),
        duration_s=40.0,
        policies={"hot": dataclasses.replace(REGRESSION, hot=True),
                  "cold": dataclasses.replace(REGRESSION, hot=False)},
        canary_fracs=(0.5, 1.0),
        rollback_thresholds=(math.inf, 100.0),
        failover=FO, n_hosts=16, phase_mode="compact")
    builds = timeline_build_count() - before
    assert builds == len(seeds), \
        "upgrades are in-trace only: one timeline per seed, flat " \
        "across all 8 drill config rows"
    assert cube.rollback_t.shape == (2, 2, 2, len(seeds))
    # threshold=inf rows never roll back; the induced regression with a
    # finite threshold always does
    assert np.isinf(cube.rollback_t[:, :, 0]).all()
    assert np.isfinite(cube.rollback_t[:, :, 1]).all()
    assert cube.rollback_frac[:, :, 1].min() == 1.0
    # labels carry the drill axes for release-gate tables
    assert any("drill" in lbl or "canary" in lbl
               for lbl in cube.grid.labels)
