"""Region checkpointing: merge semantics, restore round-trips, and property
tests over random failure patterns (hypothesis)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, settings, st  # hypothesis, or seeded fallback

from repro.ckpt.manifest import Manifest, RegionSnapshot
from repro.ckpt.storage import LocalFS, ObjectStoreSim, SimHDFS, FallbackStorage
from repro.configs import get_smoke_arch
from repro.core import regions as R
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import VirtualClock
from repro.core.region_checkpoint import RegionCheckpointer
from repro.models import build


@pytest.fixture(scope="module")
def model_and_params():
    m = build(get_smoke_arch("stablelm-1.6b"))
    return m, m.init(jax.random.PRNGKey(0))


def _ckpt(tmp, regions, mode="region", chaos=None, clock=None):
    clock = clock or VirtualClock()
    store = SimHDFS(tmp, clock=clock, chaos=chaos or ChaosEngine())
    return RegionCheckpointer(store, "job", regions, mode=mode, clock=clock)


def test_partition_covers_everything(model_and_params):
    m, params = model_and_params
    regions = R.partition_regions(m.param_specs(), 4)
    paths = set()
    for reg in regions:
        for s in reg.slices:
            key = (s.path, s.layer_lo)
            assert key not in paths, "overlapping slices"
            paths.add(key)
    # every leaf appears
    leaf_paths = {p for p, _ in R._flatten_with_paths(m.param_specs())}
    covered = {s.path for reg in regions for s in reg.slices}
    assert covered == leaf_paths


def test_restore_roundtrip_exact(model_and_params, tmp_path):
    m, params = model_and_params
    regions = R.partition_regions(m.param_specs(), 3)
    ck = _ckpt(tmp_path / "s", regions)
    ck.save(5, params)
    restored, info = ck.restore(params, gamma="full")
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert set(info["steps"].values()) == {5}


def test_merge_semantics_full_vs_partial(model_and_params, tmp_path):
    m, params = model_and_params
    regions = R.partition_regions(m.param_specs(), 4)
    ck = _ckpt(tmp_path / "s", regions)
    ck.save(1, params)
    # simulate a failed region-2 upload at step 2 by editing the manifest
    params2 = jax.tree.map(lambda x: x + 1, params)
    ck.save(2, params2)
    ck.manifest.history[2] = [s for s in ck.manifest.history[2] if s.step != 2]
    _, info_p = ck.restore(params, gamma="partial")
    assert info_p["steps"][2] == 1 and info_p["steps"][0] == 2
    assert info_p["staleness"][2] == 1
    _, info_f = ck.restore(params, gamma="full")
    assert set(info_f["steps"].values()) == {1}, \
        "γ=full must fall back to the newest globally consistent step"


def test_global_mode_aborts_on_failure(model_and_params, tmp_path):
    m, params = model_and_params
    regions = R.partition_regions(m.param_specs(), 4)
    chaos = ChaosEngine(ChaosSpec(seed=5, storage_fail_prob=0.6))
    ck = _ckpt(tmp_path / "s", regions, mode="global", chaos=chaos)
    reports = [ck.save(i, params) for i in range(6)]
    failed = [r for r in reports if not r.success]
    assert failed, "chaos should break at least one attempt"
    stats = ck.success_rate()
    assert stats["usable_rate"] < 1.0


def test_region_mode_stays_usable_under_chaos(model_and_params, tmp_path):
    m, params = model_and_params
    regions = R.partition_regions(m.param_specs(), 4)
    chaos = ChaosEngine(ChaosSpec(seed=5, storage_fail_prob=0.3))
    ck = _ckpt(tmp_path / "s", regions, mode="region", chaos=chaos)
    for i in range(6):
        ck.save(i, jax.tree.map(lambda x, i=i: x + i, params))
    restored, info = ck.restore(params, gamma="partial")
    assert max(info["staleness"].values()) <= 6
    stats = ck.success_rate()
    assert stats["usable_rate"] == 1.0, \
        "region mode merges failures instead of aborting"


# ----------------------------------------------------------------------
# property tests over random failure patterns (manifest-level)
# ----------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.booleans()), min_size=1,
                max_size=24))
def test_manifest_merge_invariants(events):
    """For any sequence of (step, ok)-per-region events:
    γ=full view is step-uniform; γ=partial staleness = newest - per-region."""
    n_regions = 3
    man = Manifest("j", n_regions)
    steps_by_region = {r: [] for r in range(n_regions)}
    step = 0
    for inc, ok in events:
        step += 1 + inc
        for r in range(n_regions):
            if ok or (r + step) % 2:  # failure pattern varies by region
                man.add(RegionSnapshot(r, step, {}, 0))
                steps_by_region[r].append(step)
    if not all(steps_by_region.values()):
        return
    view = man.merge_view("partial")
    newest = max(s.step for s in view.values())
    for r, snap in view.items():
        assert snap.step == max(steps_by_region[r])
        assert man.staleness(view)[r] == newest - snap.step
    common = set.intersection(*(set(v) for v in steps_by_region.values()))
    if common:
        viewf = man.merge_view("full")
        assert len({s.step for s in viewf.values()}) == 1
        assert viewf[0].step == max(common)
    else:
        with pytest.raises(LookupError):
            man.merge_view("full")


def test_content_dedup(model_and_params, tmp_path):
    """Identical region content re-uploads nothing (content addressing)."""
    m, params = model_and_params
    regions = R.partition_regions(m.param_specs(), 2)
    clock = VirtualClock()
    store = SimHDFS(tmp_path / "s", clock=clock, chaos=ChaosEngine())
    ck = RegionCheckpointer(store, "job", regions, clock=clock)
    ck.save(1, params)
    n1 = store.put_count
    ck.save(2, params)  # same bytes
    assert store.put_count <= n1 + 2, "only manifests should be re-written"
