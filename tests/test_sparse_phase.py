"""Sparse-phase (compact) tick lowering (ISSUE 5 tentpole, part 1).

Pillars:

* **Compact == dense at 1e-12** — the row-table sparse tick
  (`engine.CompactPhase` + `jax_engine._build_compact_run`) reproduces
  the dense arena-wide tick over every partitioner family, both
  failover modes, kill-heavy seeds that empty whole phases, and a
  10k-task deep-pipeline mega-arena.
* **One trace per bucket** — compact index/mask tables are traced
  parameters, so same-shaped plans with *different contents* (e.g.
  different partitioner kinds) share one compiled trace; only the pow2
  bucket signature keys the cache.
* **Auto selection** — `select_phase_mode` picks compact exactly when
  the eliminated arena-wide segment reductions dominate (deep packed
  arenas), dense for small/shallow graphs, and the
  ``REPRO_REQUIRE_PHASE_MODE`` guard refuses silent fallbacks.
"""
import numpy as np
import pytest

from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import (FailoverConfig, build_plan, pack_arena,
                                  select_phase_mode)
from repro.streams.jax_engine import (JaxStreamEngine, _FN_CACHE,
                                      _Lowered, get_cached_run_fns,
                                      _enable_x64)

TOL = dict(rtol=1e-12, atol=1e-9)


def _pair(graph, duration=120, n_hosts=8, **kw):
    md = JaxStreamEngine(graph, n_hosts=n_hosts, phase_mode="dense",
                         **kw).run(duration)
    mc = JaxStreamEngine(graph, n_hosts=n_hosts, phase_mode="compact",
                         **kw).run(duration)
    return md, mc


def _assert_match(md, mc):
    for n in md.qps:
        np.testing.assert_allclose(md.qps[n], mc.qps[n],
                                   err_msg=f"qps[{n}]", **TOL)
        np.testing.assert_allclose(md.backlog[n], mc.backlog[n],
                                   err_msg=f"backlog[{n}]", **TOL)
    np.testing.assert_allclose(md.source_lag, mc.source_lag, **TOL)
    np.testing.assert_allclose(md.dropped, mc.dropped, **TOL)
    np.testing.assert_allclose(md.emitted, mc.emitted, **TOL)


@pytest.mark.parametrize("partitioner", ["rebalance", "hash", "weakhash",
                                         "backlog", "rescale",
                                         "group_rescale"])
def test_compact_matches_dense_partitioners(partitioner):
    spec = ChaosSpec(seed=1, host_kill_prob_per_s=0.004,
                     straggler_frac=0.2)
    md, mc = _pair(nexmark.q2(parallelism=16, partitioner=partitioner,
                              n_groups=4),
                   chaos=spec,
                   failover=FailoverConfig(mode="region",
                                           region_restart_s=20.0))
    _assert_match(md, mc)


@pytest.mark.parametrize("graph_fn", [
    lambda: nexmark.q12(parallelism=8),
    lambda: nexmark.ss(parallelism=8),
])
def test_compact_matches_dense_pipelines(graph_fn):
    spec = ChaosSpec(seed=3, host_kill_prob_per_s=0.004,
                     straggler_frac=0.25)
    md, mc = _pair(graph_fn(), chaos=spec,
                   failover=FailoverConfig(mode="single_task",
                                           single_restart_s=4.0))
    _assert_match(md, mc)
    assert mc.dropped > 0 or not md.recoveries


def test_compact_matches_dense_kill_heavy():
    """Kill-heavy seed: whole regions go down repeatedly, so phases run
    near-empty — the masks/pads of the compact rows must keep routing,
    drops and requeues pinned to dense through every outage."""
    spec = ChaosSpec(seed=5, host_kill_prob_per_s=0.05,
                     straggler_frac=0.3)
    md, mc = _pair(nexmark.ss(parallelism=8), duration=240, chaos=spec,
                   failover=FailoverConfig(mode="region",
                                           region_restart_s=10.0))
    assert len(mc.recoveries) > 5          # the chaos actually fired
    _assert_match(md, mc)


def test_compact_matches_dense_10k_arena():
    """Deep-pipeline mega-arena (36 packed SS jobs, 6 phases — the
    CI-sized twin of the 10k-task benchmark arena): one jitted short
    run per mode, 1e-12 parity."""
    arena = nexmark.ss_arena(n_tasks=2016, parallelism=8, n_hosts=32)
    assert select_phase_mode(arena.plan) == "compact"
    spec = ChaosSpec(seed=0, host_kill_prob_per_s=0.01,
                     straggler_frac=0.2)
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    outs = {}
    for mode in ("dense", "compact"):
        low = _Lowered(arena, n_hosts=32, dt=0.5, queue_cap=256.0,
                       failover=fo, ckpt=None, seed=0, phase_mode=mode)
        run_fn, _ = get_cached_run_fns(low.desc)
        with _enable_x64():
            st, xs, _ = low.prepare(spec, 32)
            _, ys = run_fn(low.arrays, st, xs)
            outs[mode] = {k: np.asarray(v) for k, v in ys.items()}
    for k in outs["dense"]:
        np.testing.assert_allclose(outs["dense"][k], outs["compact"][k],
                                   err_msg=k, **TOL)


def test_one_trace_per_bucket():
    """Two same-shaped graphs with DIFFERENT partitioner kinds land in
    the same compact bucket signature → one compiled trace serves both
    (index/mask tables are traced, not baked), and the results still
    differ (the content is live)."""
    a = JaxStreamEngine(nexmark.q2(parallelism=8,
                                   partitioner="rebalance"),
                        n_hosts=8, phase_mode="compact")
    b = JaxStreamEngine(nexmark.q2(parallelism=8, partitioner="backlog"),
                        n_hosts=8, phase_mode="compact")
    assert a.lowered.desc == b.lowered.desc
    n0 = len(_FN_CACHE)
    ma = a.run(30)
    n1 = len(_FN_CACHE)
    mb = b.run(30)
    assert len(_FN_CACHE) == n1 and n1 <= n0 + 1
    # dense mode keys on content: same pair, two descs
    c = JaxStreamEngine(nexmark.q2(parallelism=8,
                                   partitioner="rebalance"),
                        n_hosts=8, phase_mode="dense")
    d = JaxStreamEngine(nexmark.q2(parallelism=8, partitioner="backlog"),
                        n_hosts=8, phase_mode="dense")
    assert c.lowered.desc != d.lowered.desc
    assert ma.qps["filter"].shape == mb.qps["filter"].shape


def test_phase_mode_auto_selection():
    # shallow/small graphs stay dense
    assert select_phase_mode(
        build_plan(nexmark.q2(parallelism=8), 0.5, 256.0)) == "dense"
    # deep packed arenas go compact
    assert select_phase_mode(
        nexmark.ss_arena(n_tasks=2016, parallelism=8).plan) == "compact"
    assert select_phase_mode(
        nexmark.q12_arena(n_tasks=2016, parallelism=8).plan) == "compact"
    with pytest.raises(ValueError, match="dense|compact|auto"):
        select_phase_mode(build_plan(nexmark.q2(), 0.5, 256.0), "spicy")


def test_require_phase_mode_guard(monkeypatch):
    """scripts/ci.sh smoke targets set REPRO_REQUIRE_PHASE_MODE so a
    silent fallback to the dense path fails loudly."""
    monkeypatch.setenv("REPRO_REQUIRE_PHASE_MODE", "compact")
    with pytest.raises(RuntimeError, match="refusing to fall back"):
        _Lowered(nexmark.q2(parallelism=4), n_hosts=4, dt=0.5,
                 queue_cap=256.0, failover=None, ckpt=None, seed=0,
                 phase_mode="auto")
    # explicit compact passes the guard
    low = _Lowered(nexmark.q2(parallelism=4), n_hosts=4, dt=0.5,
                   queue_cap=256.0, failover=None, ckpt=None, seed=0,
                   phase_mode="compact")
    assert low.tensor.mode == "compact"


def test_compact_config_grid_rows_match_dense():
    """The config axis composes with the compact lowering: a (C, S)
    grid run through phase_mode='compact' equals the dense grid row for
    row at 1e-12."""
    from repro.streams.jax_engine import run_config_batch
    g = nexmark.ss(parallelism=8)
    grid = [FailoverConfig(mode="region", region_restart_s=r)
            for r in (10.0, 40.0)]
    spec = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
    outd = run_config_batch(g, grid, range(4), base_spec=spec,
                            duration_s=60, phase_mode="dense")
    outc = run_config_batch(g, grid, range(4), base_spec=spec,
                            duration_s=60, phase_mode="compact")
    for c in range(2):
        np.testing.assert_allclose(np.asarray(outd[c].source_lag),
                                   np.asarray(outc[c].source_lag), **TOL)
        np.testing.assert_allclose(np.asarray(outd[c].qps),
                                   np.asarray(outc[c].qps), **TOL)


def test_compact_packed_arena_job_metrics():
    """Per-job emitted/dropped segments survive the compact lowering on
    a packed arena (row tables by job)."""
    arena = pack_arena([nexmark.q2(parallelism=8),
                        nexmark.q12(parallelism=8)], "shared", n_hosts=8)
    spec = ChaosSpec(seed=2, host_kill_prob_per_s=0.01)
    fo = FailoverConfig(mode="single_task", single_restart_s=3.0)
    md, mc = _pair(arena, chaos=spec, failover=fo)
    np.testing.assert_allclose(md.emitted_by_job, mc.emitted_by_job,
                               **TOL)
    np.testing.assert_allclose(md.dropped_by_job, mc.dropped_by_job,
                               **TOL)
