"""Sweep-as-a-service: chunked-execution bit-parity, the shared jit
cache across concurrent requests, incremental chunk publishing, the
prep/device timing split, and the pallas+devices boundary/downgrade."""
import math
import threading

import numpy as np
import pytest

from repro.core.chaos import ChaosSpec, timeline_build_count
from repro.launch.serve import SweepRequest, SweepService
from repro.streams import nexmark
from repro.streams.chaos_sweep import SweepChunk, deployment_drill, sweep
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  UpgradeConfig)
from repro.streams.jax_engine import (_Lowered, get_cached_config_fn,
                                      run_batch, run_config_batch,
                                      trace_cache_stats)

SEEDS = list(range(13))                 # deliberately non-pow2
CHUNKS = (1, 4, 5)                      # unit, pow2, ragged-last
SPEC = ChaosSpec(host_kill_prob_per_s=0.01, zk_down=((10.0, 12.0),))
FO = FailoverConfig(mode="single_task", detect_s=1.0,
                    single_restart_s=2.0)
CKPT = CheckpointConfig(interval_s=6.0)   # forces the grid-refit path
POLICIES = {"hot": UpgradeConfig(t_upgrade_s=8.0, wave_stagger_s=1.0)}

SURFACES = ("recovery_surface", "slo_surface", "backlog_surface",
            "lost_surface", "rollback_surface", "thrash_surface",
            "rescale_surface", "cost_surface")


def _drill(**kw):
    """The (C=4, S=13) flagship drill cube: 1 policy × 2 canary fracs ×
    2 rollback thresholds, ckpt-bearing (grid timeline path)."""
    return deployment_drill(
        nexmark.q2(parallelism=2), SEEDS, base_spec=SPEC,
        duration_s=30.0, policies=POLICIES, canary_fracs=(0.25, 0.5),
        rollback_thresholds=(math.inf, 200.0), failover=FO, ckpt=CKPT,
        n_hosts=4, **kw)


@pytest.fixture(scope="module")
def mono():
    before = timeline_build_count()
    cube = _drill()
    return cube, timeline_build_count() - before


# ----------------------------------------------------------------------
# chunked == monolithic, bit for bit, for every chunk-size class
# ----------------------------------------------------------------------
@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_drill_bit_parity(mono, chunk):
    mono_cube, mono_builds = mono
    before = timeline_build_count()
    cube = _drill(seed_chunk=chunk)
    builds = timeline_build_count() - before
    # no per-chunk host replays beyond the offset refit: the chunked
    # run builds exactly as many timelines as the monolithic one (zero
    # on the grid path — streams are drawn once, schedules refitted)
    assert builds == mono_builds == 0
    for name in SURFACES:
        a = np.asarray(getattr(mono_cube.grid, name))
        b = np.asarray(getattr(cube.grid, name))
        assert np.array_equal(a, b), f"{name} drifted at chunk={chunk}"
    # raw per-config batch rows too, not just the derived surfaces
    for m_res, c_res in zip(mono_cube.grid.results, cube.grid.results):
        assert np.array_equal(m_res.batch.source_lag,
                              c_res.batch.source_lag)
        assert np.array_equal(m_res.batch.qps, c_res.batch.qps)
        assert np.array_equal(m_res.batch.ckpt_epoch,
                              c_res.batch.ckpt_epoch)


def test_chunked_plain_sweep_bit_parity():
    g = nexmark.q2(parallelism=2)
    kw = dict(base_spec=SPEC, duration_s=30.0, failover=FO, n_hosts=4)
    mono_res = sweep(g, range(9), **kw)
    for chunk in (1, 4):                # 4 → ragged last chunk of 1
        res = sweep(g, range(9), seed_chunk=chunk, **kw)
        assert np.array_equal(mono_res.batch.source_lag,
                              res.batch.source_lag)
        assert np.array_equal(mono_res.batch.backlog, res.batch.backlog)
        assert [s.recovery_time_s for s in mono_res.summaries] == \
               [s.recovery_time_s for s in res.summaries]


# ----------------------------------------------------------------------
# incremental publishing: partial surfaces are exact column slices
# ----------------------------------------------------------------------
def test_chunk_publishing_slices(mono):
    mono_cube, _ = mono
    chunks: list[SweepChunk] = []
    cube = _drill(seed_chunk=5, on_chunk=chunks.append)
    assert [(c.seed_lo, c.seed_hi) for c in chunks] == \
           [(0, 5), (5, 10), (10, 13)]
    assert [c.index for c in chunks] == [0, 1, 2]
    assert sum(c.n_seeds for c in chunks) == len(SEEDS)
    for c in chunks:
        assert c.prep_s >= 0.0 and c.device_s > 0.0
        for name in SURFACES:
            part = np.asarray(getattr(c, name))
            full = np.asarray(getattr(cube.grid, name))
            assert part.shape == (4, c.n_seeds)
            assert np.array_equal(part, full[:, c.seed_lo:c.seed_hi])
        # chunk summaries carry real per-scenario rows
        assert len(c.summaries) == 4
        assert [s.seed for s in c.summaries[0]] == \
               SEEDS[c.seed_lo:c.seed_hi]
    # and the full cube still matches the monolithic one
    assert np.array_equal(mono_cube.grid.recovery_surface,
                          cube.grid.recovery_surface)


# ----------------------------------------------------------------------
# timing split: prep_s / device_s / total_s, compat scenarios_per_s
# ----------------------------------------------------------------------
def test_timing_split(mono):
    cube = _drill(seed_chunk=5)
    grid = cube.grid
    assert grid.prep_s > 0.0
    assert grid.device_s > 0.0
    assert grid.total_s == grid.wall_s > 0.0
    # compat: the old throughput field stays total-derived
    assert grid.scenarios_per_s == pytest.approx(
        grid.recovery_surface.size / grid.wall_s)
    r = sweep(nexmark.q2(parallelism=2), range(5), base_spec=SPEC,
              duration_s=30.0, failover=FO, n_hosts=4, seed_chunk=2)
    assert r.device_s > 0.0 and r.total_s == r.wall_s
    assert r.scenarios_per_s == pytest.approx(len(r.summaries) /
                                              r.wall_s)


# ----------------------------------------------------------------------
# service: one compiled trace across concurrent requests
# ----------------------------------------------------------------------
def test_one_trace_across_concurrent_requests():
    g = nexmark.q2(parallelism=3)       # fresh plan shape for this test
    kw = dict(base_spec=SPEC, duration_s=30.0, policies=POLICIES,
              canary_fracs=(0.25, 0.5),
              rollback_thresholds=(math.inf, 200.0), failover=FO,
              ckpt=CKPT, n_hosts=4, phase_mode="dense")
    low = _Lowered(g, n_hosts=4, dt=0.5, queue_cap=256.0, failover=FO,
                   ckpt=CKPT, seed=0, phase_mode="dense")
    fn = get_cached_config_fn(low.desc, shared_kills=False)
    before = fn._cache_size()
    with SweepService(workers=2) as svc:
        j1 = svc.submit("deployment_drill", g, range(8), seed_chunk=4,
                        label="drill-a", **kw)
        j2 = svc.submit("deployment_drill", g, range(8), seed_chunk=4,
                        label="drill-b", **kw)
        r1, r2 = j1.result(600), j2.result(600)
        stats = svc.stats()
    # both requests ran every chunk through ONE compiled trace (same
    # plan digest / grid shape / pow2 seed bucket / phase mode)
    assert fn._cache_size() - before == 1
    # per-request counters: the probe above created the cached run fn,
    # so both requests HIT the process-global fn cache
    assert stats["cache_hits"] >= 1
    assert stats["cache_hits"] + stats["cache_misses"] == 2
    assert stats["completed"] == 2
    assert np.array_equal(r1.recovery, r2.recovery)
    assert np.array_equal(r1.rollback_t, r2.rollback_t)
    for jid in (j1.id, j2.id):
        js = stats["jobs"][jid]
        assert js["state"] == "done" and js["chunks"] == 2
        assert js["ttfr_s"] is not None and js["wall_s"] is not None


def test_incremental_results_and_replay(mono):
    # traces for the chunk buckets are warm (fixture + parity tests):
    # first-chunk latency must beat full-cube latency
    with SweepService(workers=1) as svc:
        job = svc.submit("deployment_drill", nexmark.q2(parallelism=2),
                         SEEDS, seed_chunk=5, base_spec=SPEC,
                         duration_s=30.0, policies=POLICIES,
                         canary_fracs=(0.25, 0.5),
                         rollback_thresholds=(math.inf, 200.0),
                         failover=FO, ckpt=CKPT, n_hosts=4)
        seen = []
        for chunk in job.chunks(timeout=600):
            seen.append((chunk.seed_lo, chunk.seed_hi, job.done()))
        cube = job.result(1.0)
    # the first chunk arrived while the job was still running — the
    # whole point of incremental publishing
    assert seen[0][:2] == (0, 5) and seen[0][2] is False
    assert len(seen) == 3
    assert job.stats["ttfr_s"] < job.stats["wall_s"]
    # late subscriber replays the buffered history after completion
    replay = [c.index for c in job.chunks(timeout=1.0)]
    assert replay == [0, 1, 2]
    assert np.array_equal(cube.grid.recovery_surface,
                          mono[0].grid.recovery_surface)


def test_service_error_propagation():
    with SweepService(workers=1) as svc:
        job = svc.submit("sweep_configs", nexmark.q2(parallelism=2),
                         range(2), base_spec=SPEC, duration_s=10.0)
        with pytest.raises(KeyError):   # missing configs=
            job.result(60.0)
        assert job.stats["state"] == "failed"
    with pytest.raises(ValueError, match="unknown request kind"):
        SweepRequest("nope", None, [])


# ----------------------------------------------------------------------
# pallas + devices: actionable boundary error, service auto-downgrade
# ----------------------------------------------------------------------
def test_pallas_devices_boundary_error():
    g = nexmark.q2(parallelism=2)
    with pytest.raises(NotImplementedError) as ei:
        run_config_batch(g, [FO], range(2), base_spec=SPEC,
                         duration_s=10.0, n_hosts=4,
                         phase_mode="pallas", devices=2)
    msg = str(ei.value)
    assert "devices=None" in msg and "seed_chunk" in msg
    assert "compact" in msg
    with pytest.raises(NotImplementedError, match="seed_chunk"):
        run_batch(g, range(2), base_spec=SPEC, duration_s=10.0,
                  n_hosts=4, phase_mode="pallas", devices=2)


def test_service_downgrades_pallas_devices():
    with SweepService(workers=1) as svc:
        job = svc.submit("sweep", nexmark.q2(parallelism=2), range(3),
                         base_spec=SPEC, duration_s=10.0, failover=FO,
                         n_hosts=4, phase_mode="pallas", devices=2)
        res = job.result(600.0)
    assert len(res.summaries) == 3
    reason = job.stats["downgrade"]
    assert reason is not None
    assert "devices=2" in reason and "seed_chunk" in reason
    assert job.stats["state"] == "done"


def test_trace_cache_stats_shape():
    s = trace_cache_stats()
    assert set(s) == {"hits", "misses"}
    assert s["hits"] >= 0 and s["misses"] >= 0


def test_concurrent_subscribers_one_job(mono):
    """Two consumer threads over one job each see the full chunk
    stream (multi-consumer buffered publisher)."""
    with SweepService(workers=1) as svc:
        job = svc.submit("deployment_drill", nexmark.q2(parallelism=2),
                         SEEDS, seed_chunk=5, base_spec=SPEC,
                         duration_s=30.0, policies=POLICIES,
                         canary_fracs=(0.25, 0.5),
                         rollback_thresholds=(math.inf, 200.0),
                         failover=FO, ckpt=CKPT, n_hosts=4)
        out = {0: [], 1: []}

        def consume(k):
            for c in job.chunks(timeout=600):
                out[k].append(c.index)

        threads = [threading.Thread(target=consume, args=(k,))
                   for k in out]
        for t in threads:
            t.start()
        for t in threads:
            t.join(600)
        job.result(1.0)
    assert out[0] == out[1] == [0, 1, 2]
