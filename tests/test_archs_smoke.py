"""Per-architecture smoke tests (assigned deliverable): every arch
instantiates a REDUCED same-family config and runs one forward/train step on
CPU, asserting output shapes + finite values."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ShapeConfig, get_smoke_arch
from repro.configs.registry import make_run
from repro.dist import NO_SHARDING
from repro.models import build
from repro.train import train_loop
from repro.train.optimizer import make_optimizer

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch(request):
    return request.param


def test_forward_and_train_step(arch):
    cfg = get_smoke_arch(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.demo_batch(SMOKE_SHAPE)

    loss, aux = model.loss_fn(params, batch, NO_SHARDING)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    assert jnp.isfinite(aux["ce"])

    run = make_run(arch, "train_4k")
    import dataclasses
    run = dataclasses.replace(run, model=cfg, shape=SMOKE_SHAPE)
    step = train_loop.make_train_step(model, run, NO_SHARDING)
    opt_state = step.optimizer.init(params)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"]) and metrics["grad_norm"] > 0
    # params actually moved
    moved = any(
        not jnp.allclose(jnp.asarray(a, jnp.float32),
                         jnp.asarray(b, jnp.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


def test_serve_prefill_decode(arch):
    cfg = get_smoke_arch(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    pre = ShapeConfig("p", 16, 2, "prefill")
    batch = model.demo_batch(pre)
    logits, cache, pos = model.prefill(params, batch, NO_SHARDING, s_max=32)
    assert logits.shape == (2, 1, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lg2, cache = model.decode_step(params, cache, tok,
                                   jnp.asarray(pos, jnp.int32), NO_SHARDING)
    assert lg2.shape == (2, 1, cfg.vocab)
    assert jnp.all(jnp.isfinite(lg2.astype(jnp.float32)))
