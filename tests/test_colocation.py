"""Mega-arena correctness: packed co-located jobs vs independent runs.

Two pillars (ISSUE 3 / paper's cluster perspective):

* **Disjoint parity** — K jobs packed onto disjoint host ranges are
  K independent clusters: every per-job metric of the packed run must
  match the standalone `StreamEngine`/`JaxStreamEngine` runs at 1e-6.
* **Shared-host interference** — with overlapping host maps, one chaos
  host kill must down tasks of EVERY co-located job on that host, in
  both engines, with per-job recovery attribution.

Plus: packed numpy-vs-jax parity under random chaos, per-job sweep
summaries, the job-mix vmap axis, device-sharded sweeping, retrace-free
seed padding, and the opt-in numpy baseline of the sweep driver.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  StreamEngine, pack_arena)
from repro.streams.jax_engine import (JaxStreamEngine, run_batch,
                                      run_mix_batch)

TOL = dict(rtol=1e-6, atol=1e-6)
KILLS = ((20.0, 2),)                      # job-local host kill schedule


def _jobs():
    return [nexmark.q2(parallelism=8, partitioner="weakhash", n_groups=4),
            nexmark.q12(parallelism=8)]


def _lifted_spec(arena):
    """One global spec delivering each job's local KILLS schedule."""
    at = sum((arena.lift_kills(j, KILLS) for j in range(arena.n_jobs)), ())
    return ChaosSpec(host_kill_at=at)


# ----------------------------------------------------------------------
# disjoint-host packing == K independent runs (parity, 1e-6)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["region", "single_task"])
def test_disjoint_packed_matches_independent_numpy(mode):
    graphs = _jobs()
    fo = FailoverConfig(mode=mode, region_restart_s=15.0,
                        single_restart_s=5.0)
    arena = pack_arena(graphs, "disjoint", n_hosts=8)
    packed = StreamEngine(arena, chaos=ChaosEngine(_lifted_spec(arena)),
                          failover=fo)
    packed.run(60)
    for j, g in enumerate(graphs):
        solo = StreamEngine(g, n_hosts=8,
                            chaos=ChaosEngine(ChaosSpec(host_kill_at=KILLS)),
                            failover=fo)
        solo.run(60)
        pre = arena.jobs[j].prefix
        for name in g.topo_order():
            np.testing.assert_allclose(
                packed.metrics.backlog[pre + name],
                solo.metrics.backlog[name], err_msg=f"backlog {j}/{name}",
                **TOL)
            np.testing.assert_allclose(
                packed.metrics.qps[pre + name], solo.metrics.qps[name],
                err_msg=f"qps {j}/{name}", **TOL)
        np.testing.assert_allclose(packed.metrics.emitted_by_job[j],
                                   solo.metrics.emitted, rtol=1e-9)
        np.testing.assert_allclose(packed.metrics.dropped_by_job[j],
                                   solo.metrics.dropped, atol=1e-9)
        # per-job recovery events mirror the solo run's (plus the job tag)
        mine = [dict(r) for r in packed.metrics.recoveries
                if r.get("job") == j]
        for r in mine:
            r.pop("job")
        assert mine == solo.metrics.recoveries


def test_disjoint_packed_matches_independent_jax():
    graphs = _jobs()
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    arena = pack_arena(graphs, "disjoint", n_hosts=8)
    pm = JaxStreamEngine(arena, chaos=_lifted_spec(arena),
                         failover=fo).run(60)
    for j, g in enumerate(graphs):
        sm = JaxStreamEngine(g, n_hosts=8,
                             chaos=ChaosSpec(host_kill_at=KILLS),
                             failover=fo).run(60)
        pre = arena.jobs[j].prefix
        for name in g.topo_order():
            np.testing.assert_allclose(pm.backlog[pre + name],
                                       sm.backlog[name],
                                       err_msg=f"{j}/{name}", **TOL)
        np.testing.assert_allclose(pm.emitted_by_job[j], sm.emitted,
                                   rtol=1e-9)


# ----------------------------------------------------------------------
# shared-host kills: interference drill through both engines
# ----------------------------------------------------------------------
@pytest.mark.parametrize("engine_cls", [StreamEngine, JaxStreamEngine])
def test_shared_host_kill_downs_every_colocated_job(engine_cls):
    graphs = _jobs()
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    arena = pack_arena(graphs, "shared", n_hosts=8)
    spec = ChaosSpec(host_kill_at=KILLS)
    chaos = ChaosEngine(spec) if engine_cls is StreamEngine else spec
    eng = engine_cls(arena, chaos=chaos, failover=fo)
    m = eng.run(60)
    recs = m.recoveries
    # ONE host kill → one recovery event PER co-located job
    assert {r["job"] for r in recs} == {0, 1}
    assert all(r["t"] == recs[0]["t"] for r in recs)
    assert all(r["tasks"] > 0 for r in recs)
    # both jobs' pipelines stall: downstream qps of each job dips to 0
    # inside the outage window
    t = np.asarray(m.t)
    outage = (t >= 20.0) & (t <= 20.0 + 16.0)
    for j, g in enumerate(graphs):
        sink = arena.jobs[j].prefix + g.topo_order()[-1]
        assert float(np.min(np.asarray(m.qps[sink])[outage])) == 0.0, sink


def test_packed_random_chaos_numpy_jax_parity():
    """Packed arena under Poisson kills + stragglers + checkpoints: the
    numpy engine and the JAX twin consume the identical chaos stream over
    the shared pool, so full-run metrics pin at 1e-5."""
    graphs = _jobs()
    fo = FailoverConfig(mode="region", region_restart_s=20.0)
    ck = CheckpointConfig(interval_s=30.0, mode="region")
    spec = ChaosSpec(seed=5, host_kill_prob_per_s=0.004,
                     straggler_frac=0.2, storage_slow_prob=0.2)
    arena = pack_arena(graphs, "shared", n_hosts=8)
    a = StreamEngine(arena, chaos=ChaosEngine(spec), failover=fo, ckpt=ck)
    a.run(120)
    mb = JaxStreamEngine(arena, chaos=spec, failover=fo, ckpt=ck).run(120)
    assert len(mb.recoveries) > 1        # chaos actually fired
    for name in arena.graph.topo_order():
        np.testing.assert_allclose(np.array(a.metrics.backlog[name]),
                                   mb.backlog[name], rtol=1e-5, atol=1e-5,
                                   err_msg=name)
    assert a.metrics.recoveries == mb.recoveries
    np.testing.assert_allclose(a.metrics.emitted_by_job,
                               mb.emitted_by_job, rtol=1e-6)
    assert (a.metrics.ckpt_attempts, a.metrics.ckpt_success) == \
        (mb.ckpt_attempts, mb.ckpt_success)


# ----------------------------------------------------------------------
# per-job sweep summaries
# ----------------------------------------------------------------------
def test_packed_sweep_reports_per_job_breakdowns():
    graphs = _jobs()
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    arena = pack_arena(graphs, "disjoint", n_hosts=8)
    # kill only job 0's hosts: job 0 must report failures, job 1 none
    spec = ChaosSpec(host_kill_at=arena.lift_kills(0, KILLS))
    res = sweep(arena, [ChaosSpec(host_kill_at=arena.lift_kills(0, KILLS),
                                  seed=s) for s in range(3)],
                base_spec=spec, duration_s=60)
    assert set(res.job_results) == {j.name for j in arena.jobs}
    r0 = res.job_results[arena.jobs[0].name]
    r1 = res.job_results[arena.jobs[1].name]
    assert all(s.n_failures == 1 for s in r0.summaries)
    assert all(s.n_failures == 0 for s in r1.summaries)
    assert all(s.recovery_time_s > 0 for s in r0.summaries)
    assert all(s.recovery_time_s == 0 for s in r1.summaries)
    # per-job emitted segments sum to the fleet total
    em = res.batch.emitted_by_job
    np.testing.assert_allclose(em.sum(axis=1), res.batch.emitted)


def test_sweep_numpy_baseline_is_opt_in():
    g = nexmark.q2(parallelism=4)
    spec = ChaosSpec(host_kill_prob_per_s=0.003)
    res = sweep(g, range(3), base_spec=spec, duration_s=30, n_hosts=4)
    assert res.numpy_check is None       # the default: no replay cost
    res = sweep(g, range(3), base_spec=spec, duration_s=30, n_hosts=4,
                compare_numpy=True)
    assert res.numpy_check["seeds_checked"] == [0, 1, 2]
    assert res.numpy_check["max_rel_lag_dev"] < 1e-5


# ----------------------------------------------------------------------
# job-mix vmap axis + device-sharded batches
# ----------------------------------------------------------------------
def test_mix_batch_second_vmap_axis():
    arena = pack_arena(_jobs(), "shared", n_hosts=8)
    spec = ChaosSpec(seed=3, host_kill_prob_per_s=0.003)
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    mixes = [[1.0, 1.0], [0.5, 2.0]]
    out = run_mix_batch(arena, mixes, range(3), base_spec=spec,
                        duration_s=60, failover=fo)
    base = run_batch(arena, range(3), base_spec=spec, duration_s=60,
                     failover=fo)
    # identity mix row == the plain batch
    np.testing.assert_allclose(out[0].source_lag, base.source_lag,
                               rtol=1e-9, atol=1e-9)
    # emission scales per job by exactly the mix multiplier (chaos and
    # liveness are rate-independent)
    np.testing.assert_allclose(out[1].emitted_by_job,
                               base.emitted_by_job * np.array([0.5, 2.0]),
                               rtol=1e-9)


def test_mix_batch_rejects_bad_mix_width():
    arena = pack_arena(_jobs(), "shared", n_hosts=8)
    with pytest.raises(ValueError, match="one multiplier per job"):
        run_mix_batch(arena, [[1.0, 1.0, 1.0]], [0], duration_s=10,
                      base_spec=ChaosSpec())


def test_sharded_batch_matches_unsharded():
    """devices= routes through the repro.dist shim (pmap on this jax);
    with one local device the shard axis is 1 but the full pmap path and
    result reassembly run — results must be identical."""
    g = nexmark.q2(parallelism=4, partitioner="weakhash", n_groups=2)
    spec = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
    a = run_batch(g, range(5), base_spec=spec, duration_s=40, n_hosts=4)
    b = run_batch(g, range(5), base_spec=spec, duration_s=40, n_hosts=4,
                  devices=1)
    np.testing.assert_allclose(a.source_lag, b.source_lag, rtol=1e-12,
                               atol=1e-9)
    np.testing.assert_allclose(a.emitted, b.emitted, rtol=1e-12)
    c = run_batch(g, range(5), base_spec=spec, duration_s=40, n_hosts=4,
                  devices="auto")
    np.testing.assert_allclose(a.source_lag, c.source_lag, rtol=1e-12,
                               atol=1e-9)


# ----------------------------------------------------------------------
# pack_arena API contracts
# ----------------------------------------------------------------------
def test_pack_arena_layout_contracts():
    graphs = _jobs()
    arena = pack_arena(graphs, "shared", n_hosts=8)
    assert arena.n_jobs == 2 and arena.n_hosts == 8
    n0 = sum(o.parallelism for o in graphs[0].ops)
    assert (arena.jobs[0].task_lo, arena.jobs[0].task_hi) == (0, n0)
    assert arena.jobs[1].task_lo == n0
    assert arena.plan.n_tasks == arena.jobs[1].task_hi
    # job op columns partition the topo op axis, names un-namespaced
    cols = np.concatenate([j.op_cols for j in arena.jobs])
    assert sorted(cols) == list(range(len(arena.plan.ops)))
    assert arena.jobs[0].op_names == list(graphs[0].topo_order())
    # disjoint pool is K× larger; shared pool hosts overlap
    dis = pack_arena(graphs, "disjoint", n_hosts=8)
    assert dis.n_hosts == 16
    assert set(dis.jobs[0].hosts) & set(dis.jobs[1].hosts) == set()
    assert set(arena.jobs[0].hosts) == set(arena.jobs[1].hosts)
    # regions never merge across jobs
    for r in arena.phys.regions:
        assert len({arena.job_of_task[t] for t in r}) == 1


def test_pack_arena_rejects_bad_input():
    with pytest.raises(ValueError, match="at least one"):
        pack_arena([])
    with pytest.raises(ValueError, match="rows for"):
        pack_arena(_jobs(), [np.arange(8)], n_hosts=8)
    with pytest.raises(ValueError, match="all local hosts"):
        pack_arena(_jobs(), [np.arange(8), np.arange(4)], n_hosts=8)


def test_single_job_arena_matches_plain_graph():
    """K=1 packing is the identity refactor: same metrics as the plain
    engine construction (bit-level for numpy, 1e-12 for jax)."""
    g = nexmark.q12(parallelism=8)
    spec = ChaosSpec(seed=1, host_kill_prob_per_s=0.004)
    fo = FailoverConfig(mode="region", region_restart_s=15.0)
    arena = pack_arena([g], "shared", n_hosts=8)
    a = StreamEngine(g, n_hosts=8, chaos=ChaosEngine(spec), failover=fo)
    a.run(60)
    b = StreamEngine(arena, chaos=ChaosEngine(spec), failover=fo)
    b.run(60)
    for name in g.topo_order():
        np.testing.assert_allclose(a.metrics.backlog[name],
                                   b.metrics.backlog["j0." + name],
                                   rtol=0, atol=0)
    assert a.metrics.emitted == b.metrics.emitted
    # recovery events differ only by the job tag
    stripped = [dict(r) for r in b.metrics.recoveries]
    for r in stripped:
        r.pop("job")
    assert stripped == a.metrics.recoveries
