#!/usr/bin/env bash
# Tier-1 CI: test suite + quick benchmark smoke.
#
#   scripts/ci.sh                     # non-slow tests + quick benches
#   scripts/ci.sh --full              # include the slow multi-device subprocess tests
#   scripts/ci.sh --sweep-smoke       # also run a 16-seed chaos sweep (vmapped jit, CPU)
#   scripts/ci.sh --colocation-smoke  # also run a 4-job 16-seed sharded co-location sweep
#   scripts/ci.sh --config-smoke      # also run a small (seeds × configs) resiliency grid
#   scripts/ci.sh --sparse-smoke      # also run a sharded config grid through the COMPACT
#                                     # (sparse-phase) tick over a deep-pipeline arena
#   scripts/ci.sh --pallas-smoke      # also run a 16-seed sweep through the fused PALLAS
#                                     # tick (interpreter impl, native kernel-grid batch)
#   scripts/ci.sh --ha-smoke          # also run the hybrid-replication-vs-checkpoint cube
#                                     # (brownouts + MQ outage + region burst, compact tick,
#                                     # non-zero exit on any timeline-rebuild fallback)
#   scripts/ci.sh --drill-smoke       # also run the deployment-drill cube (canary/rolling
#                                     # upgrades + in-trace auto-rollback, compact tick;
#                                     # non-zero exit on timeline-rebuild fallback OR on the
#                                     # induced regression failing to fire the rollback)
#   scripts/ci.sh --traffic-smoke     # also run the traffic-dynamics cube (diurnal/flash
#                                     # rate schedules + in-trace DS2 autoscaling, compact
#                                     # tick; non-zero exit on timeline-rebuild fallback OR
#                                     # on the oscillation drill failing to latch the
#                                     # thrash guard)
#   scripts/ci.sh --serve-smoke       # also boot the in-process SweepService: two
#                                     # concurrent deployment-drill requests + a traffic
#                                     # sweep with incremental chunk results; non-zero exit
#                                     # if the first chunk fails to land before the slowest
#                                     # request completes, if the requests fail to share a
#                                     # compiled trace (zero cache hits), or on any
#                                     # chunked-vs-monolithic parity drift
#
# Smoke targets fail LOUDLY on silent lowering fallbacks: the sparse
# smoke exports REPRO_REQUIRE_PHASE_MODE=compact (the engine refuses to
# lower dense under it) and examples/sparse_sweep.py exits non-zero if
# the auto selector or the ckpt-grid refit degrade.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
if [[ "${1:-}" == "--full" ]]; then
  python -m pytest -x -q
else
  python -m pytest -x -q -m "not slow"
fi

echo "== quick benchmark smoke =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py --quick

if [[ "${1:-}" == "--sweep-smoke" ]]; then
  echo "== chaos-sweep smoke: 16 seeds, one vmapped jit call =="
  python examples/chaos_sweep.py --seeds 16 --duration 60
fi

if [[ "${1:-}" == "--colocation-smoke" ]]; then
  echo "== co-location smoke: 4 jobs, 16 seeds, 2 device shards =="
  python examples/colocation_sweep.py --jobs 4 --seeds 16 --duration 60 --devices 2
fi

if [[ "${1:-}" == "--config-smoke" ]]; then
  echo "== config-grid smoke: 2x2 resiliency grid x 8 seeds, one (C,S) jit call =="
  python examples/config_sweep.py --restarts 2 --intervals 2 --seeds 8 --duration 60
fi

if [[ "${1:-}" == "--sparse-smoke" ]]; then
  echo "== sparse smoke: compact-phase ckpt grid x 8 seeds, 2 device shards =="
  REPRO_REQUIRE_PHASE_MODE=compact \
    python examples/sparse_sweep.py --jobs 18 --configs 2 --seeds 8 \
      --duration 60 --devices 2 --ckpt
fi

if [[ "${1:-}" == "--pallas-smoke" ]]; then
  echo "== pallas smoke: fused-kernel tick, 16 seeds, interpreter impl =="
  REPRO_REQUIRE_PHASE_MODE=pallas REPRO_KERNEL_IMPL=interpret \
    python examples/pallas_sweep.py --jobs 6 --seeds 16 --duration 60
fi

if [[ "${1:-}" == "--ha-smoke" ]]; then
  echo "== HA smoke: replication-vs-checkpoint cube with brownouts, compact tick =="
  REPRO_REQUIRE_PHASE_MODE=compact \
    python examples/replication_sweep.py --seeds 8 --intervals 2 \
      --brownouts 2 --duration 60
fi

if [[ "${1:-}" == "--drill-smoke" ]]; then
  echo "== drill smoke: deployment cube (canary upgrades + auto-rollback), compact tick =="
  REPRO_REQUIRE_PHASE_MODE=compact \
    python examples/deployment_drill.py --seeds 8 --jobs 4 --duration 60
fi

if [[ "${1:-}" == "--traffic-smoke" ]]; then
  echo "== traffic smoke: rate-schedule cube (DS2 autoscaling + thrash drill), compact tick =="
  REPRO_REQUIRE_PHASE_MODE=compact \
    python examples/traffic_sweep.py --seeds 8 --duration 90
fi

if [[ "${1:-}" == "--serve-smoke" ]]; then
  echo "== serve smoke: SweepService, 2 concurrent drills + traffic sweep, chunked =="
  python examples/serve_sweep.py --seeds 8 --chunk 4 --duration 60
fi

echo "CI OK"
