"""Job-startup acceleration policies (paper §III-C): execution-plan object
interning (memory object reuse), batched task deployment, and slow-starting
TaskManager mitigation. The mechanics run inside cluster/simulator.py; this
module holds the policy objects + the plan-interning logic (which is real,
not simulated: descriptors are deduplicated by structural hash)."""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class StartupConfig:
    object_reuse: bool = True          # intern execution-plan edge objects
    batched_deploy: bool = True        # 1 RPC per TM instead of per task
    straggler_mitigation: bool = True
    alloc_threshold_s: float = 120.0   # trigger for over-provisioning
    overprovision_frac: float = 0.3    # of the TMs still missing
    overprovision_cap: int = 5         # paper: "bounded by a configurable max"
    hotupdate: bool = False            # reuse slots of the previous job

    @staticmethod
    def baseline() -> "StartupConfig":
        return StartupConfig(object_reuse=False, batched_deploy=False,
                             straggler_mitigation=False)

    @staticmethod
    def policy_grid() -> list["StartupConfig"]:
        """All 8 on/off combinations of the three acceleration flags —
        the startup-policy axis that deployment drills sweep when
        lowering per-wave downtimes (`core.hotupdate.deploy_downtime`)."""
        return [StartupConfig(object_reuse=bool(o), batched_deploy=bool(b),
                              straggler_mitigation=bool(s))
                for o in (0, 1) for b in (0, 1) for s in (0, 1)]


# ----------------------------------------------------------------------
# Execution-plan interning (memory object reuse): identical edge descriptors
# (same partitioner, same schema) collapse to one interned instance, shrinking
# both the object count and the serialized deployment payload.
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EdgeDescriptor:
    src_op: str
    dst_op: str
    partitioner: str
    schema: tuple[str, ...]

    def structural_key(self) -> str:
        # identity EXCLUDES the op names: edges sharing partitioner+schema
        # reuse one serialized body (paper: "identical or semantically
        # similar edges ... reuses them instead of creating new instances")
        return hashlib.sha1(
            f"{self.partitioner}|{','.join(self.schema)}".encode()).hexdigest()


@dataclasses.dataclass
class InternedPlan:
    n_edges: int
    n_unique: int
    serialized_bytes: int
    baseline_bytes: int

    @property
    def dedup_ratio(self) -> float:
        return self.n_unique / max(self.n_edges, 1)


def intern_plan(edges: list[EdgeDescriptor],
                per_edge_bytes: int = 2048) -> InternedPlan:
    unique: dict[str, EdgeDescriptor] = {}
    key_memo: dict[int, str] = {}   # plans replicate shared descriptor
    for e in edges:                 # objects; hash each body only once
        k = key_memo.get(id(e))
        if k is None:
            k = key_memo[id(e)] = e.structural_key()
        unique.setdefault(k, e)
    n, u = len(edges), len(unique)
    # interned: one body per unique edge + an 8-byte reference per instance
    return InternedPlan(n, u, u * per_edge_bytes + n * 8, n * per_edge_bytes)


# ----------------------------------------------------------------------
@dataclasses.dataclass
class StragglerReport:
    detected: list[int]
    extra_requested: int
    released: int


class StragglerMitigator:
    """Detect slow-starting TMs from registration latencies and request
    bounded spare capacity (paper §III-C two-step strategy)."""

    def __init__(self, cfg: StartupConfig):
        self.cfg = cfg

    def detect(self, latencies: dict[int, float | None],
               now_s: float) -> list[int]:
        """TMs that are substantially slower than their peers: not yet
        registered and past 2× the median registered latency."""
        done = [v for v in latencies.values() if v is not None]
        if not done:
            return []
        med = float(np.median(done))
        return [tm for tm, v in latencies.items()
                if v is None and now_s > max(2 * med, 10.0)]

    def extra_tms(self, n_missing: int) -> int:
        if not self.cfg.straggler_mitigation or n_missing <= 0:
            return 0
        return int(min(np.ceil(self.cfg.overprovision_frac * n_missing),
                       self.cfg.overprovision_cap))
