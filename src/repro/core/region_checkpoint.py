"""Region checkpointing (paper §III-B, Fig 3) + the baseline global scheme.

Global (baseline, Flink-original): one failed upload aborts the entire
checkpoint attempt — nothing is recorded for that step.

Region (StreamShield): every region uploads independently; failed regions
simply keep their previous snapshot and the manifest merge still yields a
usable global checkpoint (γ=full restores the newest step all regions share;
γ=partial takes latest-per-region with bounded staleness). Uploads are
content-addressed + atomic ⇒ retried uploads are idempotent.
"""
from __future__ import annotations

import dataclasses
import io
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any

import numpy as np

from repro.ckpt.manifest import Manifest, RegionSnapshot
from repro.ckpt.storage import content_key
from repro.core import regions as R
from repro.core.backoff import PermanentError, RetryPolicy, retry
from repro.core.clock import WallClock


def _pack(arr: np.ndarray) -> bytes:
    """Self-describing array blob (handles ml_dtypes like bfloat16, which
    np.lib.format cannot round-trip)."""
    import json
    arr = np.ascontiguousarray(arr)
    meta = json.dumps({"dtype": str(arr.dtype),
                       "shape": list(arr.shape)}).encode()
    return zlib.compress(
        len(meta).to_bytes(4, "little") + meta + arr.tobytes(), level=1)


def _unpack(data: bytes) -> np.ndarray:
    import json
    import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)
    b = zlib.decompress(data)
    n = int.from_bytes(b[:4], "little")
    meta = json.loads(b[4:4 + n])
    dt = np.dtype(meta["dtype"])
    return np.frombuffer(b[4 + n:], dtype=dt).reshape(meta["shape"]).copy()


@dataclasses.dataclass
class CheckpointReport:
    step: int
    ok_regions: list[int]
    failed_regions: list[int]
    nbytes: int
    wall_s: float
    mode: str

    @property
    def success(self) -> bool:
        return not self.failed_regions

    @property
    def usable(self) -> bool:  # region mode: merged view still valid
        return self.mode == "region" or self.success


class RegionCheckpointer:
    """mode="region" (StreamShield) or "global" (baseline for Fig 8)."""

    def __init__(self, storage, job_id: str, regions: list[R.Region], *,
                 mode: str = "region", policy: RetryPolicy | None = None,
                 clock=None, max_workers: int = 4, dedup: bool = True):
        assert mode in ("region", "global")
        self.storage = storage
        self.job_id = job_id
        self.regions = regions
        self.mode = mode
        self.policy = policy or RetryPolicy(base_delay_s=0.05, max_attempts=3)
        self.clock = clock or WallClock()
        self.manifest = Manifest(job_id, len(regions))
        self.reports: list[CheckpointReport] = []
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._dedup = dedup
        self._seen_keys: set[str] = set()

    # ------------------------------------------------------------------
    def _upload_region(self, region: R.Region, step: int,
                       tree) -> RegionSnapshot:
        t0 = self.clock.now()
        data = R.extract_region(tree, region)
        keys: dict[str, str] = {}
        nbytes = 0
        for path, arr in data.items():
            blob = _pack(arr)
            key = f"ckpt/{self.job_id}/{content_key(blob)}"
            if not (self._dedup and key in self._seen_keys):
                def put(key=key, blob=blob):
                    return self.storage.put(key, blob)
                retry(put, self.policy, self.clock)
                self._seen_keys.add(key)
            keys[path] = key
            nbytes += len(blob)
        return RegionSnapshot(region.region_id, step, keys, nbytes,
                              wall_s=self.clock.now() - t0)

    def save(self, step: int, tree, *, async_: bool = False):
        if async_:
            return self._pool.submit(self._save_sync, step, tree)
        return self._save_sync(step, tree)

    def _save_sync(self, step: int, tree) -> CheckpointReport:
        t0 = self.clock.now()
        ok, failed, snaps, total = [], [], [], 0
        for region in self.regions:
            try:
                snap = self._upload_region(region, step, tree)
                snaps.append(snap)
                ok.append(region.region_id)
                total += snap.nbytes
            except PermanentError:
                failed.append(region.region_id)
        if self.mode == "global" and failed:
            # baseline semantics: the whole attempt aborts — record nothing
            rep = CheckpointReport(step, ok, failed, total,
                                   self.clock.now() - t0, self.mode)
        else:
            for snap in snaps:
                self.manifest.add(snap)
            rep = CheckpointReport(step, ok, failed, total,
                                   self.clock.now() - t0, self.mode)
            try:
                retry(lambda: self.manifest.save(self.storage), self.policy,
                      self.clock)
            except PermanentError:
                # in-memory manifest stays authoritative; persisted pointer
                # is stale until the next successful save
                rep.failed_regions = sorted(set(rep.failed_regions)
                                            | {-1})  # -1 = manifest write
        self.reports.append(rep)
        return rep

    # ------------------------------------------------------------------
    def restore(self, template_tree, *, gamma: str = "full",
                step: int | None = None):
        """Rebuild a full tree (numpy leaves) from the merged manifest view.
        Returns (tree, info) where info records per-region steps/staleness."""
        view = self.manifest.merge_view(gamma, step)
        tree = _deep_mutable(template_tree)
        for region in self.regions:
            snap = view[region.region_id]
            data = {p: _unpack(self.storage.get(k))
                    for p, k in snap.keys.items()}
            R.insert_region(tree, region, data)
        info = {"steps": {r: s.step for r, s in view.items()},
                "staleness": self.manifest.staleness(view)}
        return tree, info

    def success_rate(self) -> dict[str, Any]:
        usable = sum(1 for r in self.reports
                     if (r.success if self.mode == "global" else True))
        attempted = len(self.reports)
        fully = sum(1 for r in self.reports if r.success)
        return {"attempted": attempted, "usable": usable,
                "fully_successful": fully,
                "usable_rate": usable / max(attempted, 1),
                "full_rate": fully / max(attempted, 1)}


def _deep_mutable(tree):
    if isinstance(tree, dict):
        return {k: _deep_mutable(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_deep_mutable(v) for v in tree]
    return np.asarray(tree)
