"""Hybrid replication (paper §IV-A): passive replication (periodic region
checkpoints, restore-on-failure) is the default; latency-critical jobs switch
to active replication (a live standby replica assuming execution immediately).

The manager is policy-driven (core/slo.py) and exposes a uniform
``on_failure`` that returns a RecoveryOutcome with the recovery-time
decomposition — used by tests and the Fig 9-style drills.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core.slo import ResiliencyPolicy


@dataclasses.dataclass
class RecoveryOutcome:
    mode: str
    detect_s: float
    restore_s: float
    replay_s: float
    lost_steps: int

    @property
    def downtime_s(self) -> float:
        return self.detect_s + self.restore_s + self.replay_s


@dataclasses.dataclass(frozen=True)
class TimingModel:
    detect_s: float = 0.5
    restore_bps: float = 2e9          # checkpoint read bandwidth
    step_time_s: float = 0.5
    standby_switch_s: float = 0.05    # active failover latency

    def tick_failover_kwargs(self, *, nbytes: float = 0.0) -> dict:
        """Lower this timing model into tick-engine failover kwargs
        (`streams.engine.FailoverConfig(mode=..., **kwargs)`). Kept as a
        plain dict so `core` never imports `streams`. Active replication
        maps to hot-standby switch + one step of staleness replay;
        passive restore reads `nbytes` at `restore_bps` (stretched by
        any storage brownout at kill time) and replays one second of
        work per second of checkpoint age."""
        return dict(detect_s=self.detect_s,
                    standby_switch_s=self.standby_switch_s,
                    standby_staleness_s=self.step_time_s,
                    restore_base_s=nbytes / self.restore_bps,
                    replay_rate=1.0)


class ReplicationManager:
    def __init__(self, policy: ResiliencyPolicy, checkpointer, *,
                 timing: TimingModel | None = None, clock=None):
        self.policy = policy
        self.ckpt = checkpointer
        self.timing = timing or TimingModel()
        self.clock = clock or checkpointer.clock
        self._standby: Any = None
        self._standby_step: int = -1
        self._last_ckpt_t = -1e18
        self._last_ckpt_step = -1
        self.events: list[RecoveryOutcome] = []

    # -- steady-state duties ------------------------------------------------
    def on_step(self, step: int, state, *, copy_fn: Callable = None) -> dict:
        """Call after every training/serving step. Maintains the standby
        (active) or the checkpoint cadence (passive)."""
        out = {"checkpointed": False, "standby_synced": False}
        if self.policy.replication == "active":
            copy = copy_fn or (lambda tree: tree)
            self._standby = copy(state)
            self._standby_step = step
            out["standby_synced"] = True
        t = self.clock.now()
        if t - self._last_ckpt_t >= self.policy.ckpt_interval_s:
            rep = self.ckpt.save(step, state)
            self._last_ckpt_t = t
            if rep.usable:
                self._last_ckpt_step = step
            out["checkpointed"] = rep.usable
        return out

    # -- failure path ---------------------------------------------------
    def on_failure(self, step: int, template_state) -> tuple[Any, RecoveryOutcome]:
        tm = self.timing
        if self.policy.replication == "active" and self._standby is not None:
            oc = RecoveryOutcome("active", tm.detect_s, tm.standby_switch_s,
                                 replay_s=max(0, step - self._standby_step)
                                 * tm.step_time_s,
                                 lost_steps=0)
            self.events.append(oc)
            return self._standby, oc
        gamma = "full" if self.policy.rescue_overflow else "partial"
        state, info = self.ckpt.restore(template_state, gamma=gamma)
        ckpt_step = min(info["steps"].values()) if info["steps"] else -1
        nbytes = sum(r.nbytes for r in self.ckpt.regions)
        lost = max(0, step - ckpt_step)
        oc = RecoveryOutcome(
            "passive", tm.detect_s, nbytes / tm.restore_bps,
            replay_s=0.0 if gamma == "partial" else lost * tm.step_time_s,
            lost_steps=lost if gamma == "partial" else 0)
        self.events.append(oc)
        return state, oc
