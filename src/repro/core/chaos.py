"""Chaos engine (paper §V-B): deterministic fault injection at the hardware
level (storage latency/failures, stragglers, network degradation) and the
process level (host/TaskManager kills). All draws come from a seeded
generator, so every drill is reproducible bit-for-bit."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    seed: int = 0
    # storage (HDFS-sim): slow uploads + hard failures
    storage_slow_prob: float = 0.0
    storage_slow_factor: float = 10.0
    storage_fail_prob: float = 0.0
    # process level
    host_kill_prob_per_s: float = 0.0
    host_kill_at: tuple[tuple[float, int], ...] = ()   # (time, host_id)
    # stragglers: fraction of hosts that are slow by `straggler_factor`
    straggler_frac: float = 0.0
    straggler_factor: float = 4.0
    # network
    net_delay_factor: float = 1.0
    # coordination (ZK-sim) outage windows
    zk_down: tuple[tuple[float, float], ...] = ()
    hdfs_down: tuple[tuple[float, float], ...] = ()


class ChaosEngine:
    def __init__(self, spec: ChaosSpec | None = None):
        self.spec = spec or ChaosSpec()
        self._rng = np.random.default_rng(self.spec.seed)
        self._killed: set[int] = set()
        self._stragglers: dict[int, bool] = {}

    # -- storage -------------------------------------------------------
    def storage_latency_factor(self) -> float:
        if self.spec.storage_slow_prob and \
                self._rng.random() < self.spec.storage_slow_prob:
            return self.spec.storage_slow_factor
        return 1.0

    def storage_latency_factors(self, n: int) -> np.ndarray:
        """Vectorized batch of `n` latency factors. Draw-for-draw equivalent
        to `n` sequential `storage_latency_factor()` calls (numpy Generators
        produce the same stream for `random(n)` as for n scalar draws), so
        the vectorized engine stays bit-identical to the reference."""
        if not self.spec.storage_slow_prob:
            return np.ones(n)
        slow = self._rng.random(n) < self.spec.storage_slow_prob
        return np.where(slow, self.spec.storage_slow_factor, 1.0)

    def storage_fails(self) -> bool:
        return bool(self.spec.storage_fail_prob
                    and self._rng.random() < self.spec.storage_fail_prob)

    # -- hosts -----------------------------------------------------------
    def is_straggler(self, host_id: int) -> bool:
        if host_id not in self._stragglers:
            self._stragglers[host_id] = bool(
                self.spec.straggler_frac
                and self._rng.random() < self.spec.straggler_frac)
        return self._stragglers[host_id]

    def host_speed(self, host_id: int) -> float:
        return (1.0 / self.spec.straggler_factor
                if self.is_straggler(host_id) else 1.0)

    def step_kills(self, t0: float, t1: float, n_hosts: int) -> list[int]:
        """Hosts killed in (t0, t1]: scheduled kills + Poisson random kills."""
        kills = [h for (t, h) in self.spec.host_kill_at
                 if t0 < t <= t1 and h not in self._killed]
        if self.spec.host_kill_prob_per_s:
            p = 1.0 - np.exp(-self.spec.host_kill_prob_per_s * (t1 - t0))
            for h in range(n_hosts):
                if h not in self._killed and self._rng.random() < p:
                    kills.append(h)
        self._killed.update(kills)
        return sorted(set(kills))

    def revive(self, host_id: int) -> None:
        self._killed.discard(host_id)

    def alive(self, host_id: int) -> bool:
        return host_id not in self._killed

    # -- coordination services -------------------------------------------
    def zk_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.zk_down)

    def hdfs_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.hdfs_down)
