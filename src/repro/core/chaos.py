"""Chaos engine (paper §V-B): deterministic fault injection at the hardware
level (storage latency/failures, stragglers, network degradation) and the
process level (host/TaskManager kills). All draws come from a seeded
generator, so every drill is reproducible bit-for-bit."""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    seed: int = 0
    # storage (HDFS-sim): slow uploads + hard failures
    storage_slow_prob: float = 0.0
    storage_slow_factor: float = 10.0
    storage_fail_prob: float = 0.0
    # process level
    host_kill_prob_per_s: float = 0.0
    host_kill_at: tuple[tuple[float, int], ...] = ()   # (time, host_id)
    # stragglers: fraction of hosts that are slow by `straggler_factor`
    straggler_frac: float = 0.0
    straggler_factor: float = 4.0
    # network
    net_delay_factor: float = 1.0
    # coordination (ZK-sim) outage windows
    zk_down: tuple[tuple[float, float], ...] = ()
    hdfs_down: tuple[tuple[float, float], ...] = ()


class ChaosEngine:
    def __init__(self, spec: ChaosSpec | None = None):
        self.spec = spec or ChaosSpec()
        self._rng = np.random.default_rng(self.spec.seed)
        self._killed: set[int] = set()
        self._stragglers: dict[int, bool] = {}

    # -- storage -------------------------------------------------------
    def storage_latency_factor(self) -> float:
        if self.spec.storage_slow_prob and \
                self._rng.random() < self.spec.storage_slow_prob:
            return self.spec.storage_slow_factor
        return 1.0

    def storage_latency_factors(self, n: int) -> np.ndarray:
        """Vectorized batch of `n` latency factors. Draw-for-draw equivalent
        to `n` sequential `storage_latency_factor()` calls (numpy Generators
        produce the same stream for `random(n)` as for n scalar draws), so
        the vectorized engine stays bit-identical to the reference."""
        if not self.spec.storage_slow_prob:
            return np.ones(n)
        slow = self._rng.random(n) < self.spec.storage_slow_prob
        return np.where(slow, self.spec.storage_slow_factor, 1.0)

    def storage_fails(self) -> bool:
        return bool(self.spec.storage_fail_prob
                    and self._rng.random() < self.spec.storage_fail_prob)

    # -- hosts -----------------------------------------------------------
    def is_straggler(self, host_id: int) -> bool:
        if host_id not in self._stragglers:
            self._stragglers[host_id] = bool(
                self.spec.straggler_frac
                and self._rng.random() < self.spec.straggler_frac)
        return self._stragglers[host_id]

    def host_speed(self, host_id: int) -> float:
        return (1.0 / self.spec.straggler_factor
                if self.is_straggler(host_id) else 1.0)

    def step_kills(self, t0: float, t1: float, n_hosts: int) -> list[int]:
        """Hosts killed in (t0, t1]: scheduled kills + Poisson random kills.

        The Poisson draws are batched — one ``random(n_alive)`` call over
        the alive hosts in ascending id order, which numpy Generators
        guarantee is the same stream as n_alive sequential scalar draws —
        so large host pools (multi-job arenas) don't pay per-host Python
        rng calls every tick."""
        kills = [h for (t, h) in self.spec.host_kill_at
                 if t0 < t <= t1 and h not in self._killed]
        if self.spec.host_kill_prob_per_s:
            p = 1.0 - np.exp(-self.spec.host_kill_prob_per_s * (t1 - t0))
            if self._killed:
                alive = np.array([h for h in range(n_hosts)
                                  if h not in self._killed])
            else:
                alive = np.arange(n_hosts)
            if len(alive):
                kills.extend(
                    int(h) for h in alive[self._rng.random(len(alive)) < p])
        self._killed.update(kills)
        return sorted(set(kills))

    def revive(self, host_id: int) -> None:
        self._killed.discard(host_id)

    def alive(self, host_id: int) -> bool:
        return host_id not in self._killed

    # -- coordination services -------------------------------------------
    def zk_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.zk_down)

    def hdfs_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.hdfs_down)


def failover_recovery_entries(t: float, mode: str, hit: np.ndarray,
                              downtime,
                              job_of_task: np.ndarray | None = None
                              ) -> list[dict]:
    """Recovery-event dicts for one failover action over `hit` tasks.

    Single-job runs (``job_of_task=None``) keep the historical one-entry
    format. Packed multi-job arenas (`streams.engine.pack_arena`) emit one
    entry per affected job — ascending job id, with a ``"job"`` key — so a
    shared-host kill that downs tasks of several co-located jobs is
    attributable per job. `downtime` may be a scalar or a per-task vector
    (per-job failover configs): each job's entry reports the downtime of
    its own hit tasks, which per-job configs keep uniform within a job.
    Used by both the live `StreamEngine` and the pregenerated timeline so
    the two stay comparable with ``==``."""
    dt_arr = np.asarray(downtime, dtype=float)
    if job_of_task is None:
        d = float(dt_arr.flat[0]) if dt_arr.ndim else float(dt_arr)
        return [{"t": t, "mode": mode, "tasks": int(hit.sum()),
                 "downtime": d}]

    def _dt(j):
        if dt_arr.ndim == 0:
            return float(dt_arr)
        return float(dt_arr[hit & (job_of_task == j)][0])

    return [{"t": t, "mode": mode,
             "tasks": int((hit & (job_of_task == j)).sum()),
             "downtime": _dt(j), "job": int(j)}
            for j in np.unique(job_of_task[hit])]


_MODE_CODE = {"none": 0, "region": 1, "single_task": 2}


def failover_mode_codes(failover_mode, n_tasks: int) -> np.ndarray:
    """Normalize a failover mode (name string or per-task int-code vector)
    to an ``(n_tasks,)`` int8 code vector: 0 none, 1 region, 2
    single_task. Per-task codes are how per-job `FailoverConfig`s reach
    the chaos timeline and the engines without `core` importing
    `streams`."""
    if isinstance(failover_mode, str):
        return np.full(n_tasks, _MODE_CODE[failover_mode], np.int8)
    codes = np.asarray(failover_mode, dtype=np.int8)
    if codes.shape != (n_tasks,):
        raise ValueError(f"mode codes must be (n_tasks,)={n_tasks}, "
                         f"got {codes.shape}")
    return codes


def _per_task(v, n_tasks: int) -> np.ndarray:
    return np.broadcast_to(np.asarray(v, dtype=float), (n_tasks,))


def _resolve_failover_tick(t, host, task_host, task_region, mode_codes,
                           down_s, down_r, down, recoveries, job_of_task):
    """One host kill → failover response (shared by the pregenerated
    timeline, `refit_failover` and — semantically — the live engine's
    `_fail_host`): region-mode victims expand to their regions, then
    single_task-mode victims restart alone. Region entries precede
    single_task entries when one shared-host kill hits jobs of both
    modes."""
    victims = task_host == host
    vr = victims & (mode_codes == 1)
    if vr.any():
        hit = np.isin(task_region, task_region[vr])
        down[hit] = t + down_r[hit]
        recoveries.extend(failover_recovery_entries(
            t, "region", hit, down_r, job_of_task))
    vs = victims & (mode_codes == 2)
    if vs.any():
        down[vs] = t + down_s[vs]
        recoveries.extend(failover_recovery_entries(
            t, "single_task", vs, down_s, job_of_task))


def run_checkpoint_attempt(eng: ChaosEngine, alive: np.ndarray, *,
                           interval_s: float, mode: str, upload_s: float,
                           retry: bool, regions, task_lo: int = 0) -> bool:
    """One checkpoint attempt over the tasks covered by `alive` (their
    liveness at attempt time): per-task upload-factor draws against the
    interval timeout, then global abort-on-any-failure or per-region
    evaluation with one short-circuiting retry of a failed region.

    THE single definition of the attempt's rng consumption — shared by
    the live `StreamEngine` coordinators (whole-arena and per-job) and
    the pregenerated timeline replay, so the draw stream cannot
    desynchronize between them. `regions` hold global task ids;
    `task_lo` maps them into `alive` for per-job slices."""
    factors = eng.storage_latency_factors(len(alive))
    task_fail = (upload_s * factors > interval_s) | ~alive
    if mode == "global":
        return bool(not task_fail.any())
    for region in regions:
        bad = any(task_fail[tid - task_lo] for tid in region)
        if bad and retry:
            # one in-attempt retry of the region's uploads
            # (short-circuits on the first slow draw, exactly like the
            # engine's any(...) generator)
            bad = any(upload_s * eng.storage_latency_factor() > interval_s
                      for _ in region)
        if bad:
            return False  # region keeps previous snapshot; attempt
            # counted failed by the caller, job continues (no abort)
    return True


# ----------------------------------------------------------------------
# Pregenerated event tensors (accelerator backends / chaos sweeps)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ChaosTimeline:
    """Chaos events for one run, materialized as per-tick tensors.

    A `jit`-compiled engine cannot consume the sequential numpy rng draws
    of `ChaosEngine` mid-scan, so the whole chaos/failover/checkpoint
    control timeline is replayed here on the host — draw-for-draw in the
    exact order `streams.engine.StreamEngine` consumes the rng stream
    (straggler speeds at init; per tick: kill draws, then checkpoint
    storage draws) — and exported as dense arrays the device loop indexes
    by tick. Kill/checkpoint *times* are thereby quantized to tick
    boundaries, which is exactly the resolution the tick engines observe
    them at anyway.
    """
    dt: float
    n_ticks: int
    ts: np.ndarray             # (n_ticks,) tick-start times (accumulated)
    task_speed: np.ndarray     # (n_tasks,) chaos straggler speed factors
    kills: np.ndarray          # (n_ticks, n_hosts) bool host killed in tick
    ckpt_at: np.ndarray        # (n_ticks,) i16 checkpoint attempts in tick
    ckpt_ok: np.ndarray        # (n_ticks,) i16 successes in tick
    ckpt_attempts: int
    ckpt_success: int
    ckpt_failed: int
    recoveries: list[dict]     # same dict layout as EngineMetrics.recoveries
    # per-job checkpoint counters — populated only when per-job
    # CheckpointConfigs drive the replay ((n_jobs, 3) attempts/success/
    # failed); None for a single shared coordinator
    ckpt_by_job: np.ndarray | None = None


def build_chaos_timeline(
        spec: ChaosSpec, *, n_ticks: int, dt: float, n_hosts: int,
        task_host: np.ndarray, task_region: np.ndarray | None = None,
        regions: list | None = None,
        failover_mode="region", detect_s=1.0,
        region_restart_s=45.0, single_restart_s=3.0,
        ckpt_interval_s=None, ckpt_mode="region",
        ckpt_upload_s=4.0, ckpt_retry=True,
        job_of_task: np.ndarray | None = None) -> ChaosTimeline:
    """Replay the engine's chaos rng consumption for `n_ticks` ticks.

    Host kills, checkpoint outcomes and failover downtimes are all
    data-independent of queue dynamics (downtime depends only on kills +
    failover config), so the full control timeline is computable here
    without simulating a single record. `task_host`/`task_region`/`regions`
    describe the physical placement (same arrays the engine derives from
    `PhysicalGraph`); failover/checkpoint parameters mirror
    `FailoverConfig`/`CheckpointConfig` field-for-field (passed as plain
    scalars to keep `core` free of a `streams` import).

    Per-job configs ride the same scalar contract as vectors/sequences:

    * `failover_mode` may be a per-task int8 code vector (see
      `failover_mode_codes`) and `detect_s` / `*_restart_s` per-task
      float vectors — how `streams.engine.per_task_failover` lowers a
      per-job `FailoverConfig` list.
    * `ckpt_interval_s` / `ckpt_mode` / `ckpt_upload_s` / `ckpt_retry`
      may be length-``n_jobs`` sequences (requires `job_of_task`; a None
      interval disables job j's coordinator): each job then runs its own
      coordinator drawing upload factors for its OWN tasks only, jobs in
      ascending id order within a tick — the stream contract mirrored by
      `StreamEngine._run_checkpoint_job`. `ckpt_at` counts attempts per
      tick (all jobs), and `ckpt_by_job` carries the per-job counters.
    """
    eng = ChaosEngine(spec)
    task_host = np.asarray(task_host)
    n_tasks = len(task_host)
    mode_codes = failover_mode_codes(failover_mode, n_tasks)
    down_s = _per_task(detect_s, n_tasks) + _per_task(single_restart_s,
                                                      n_tasks)
    down_r = _per_task(detect_s, n_tasks) + _per_task(region_restart_s,
                                                      n_tasks)
    kills_possible = bool(spec.host_kill_at or spec.host_kill_prob_per_s)
    if kills_possible and (mode_codes == 1).any() and task_region is None:
        raise ValueError(
            "failover_mode='region' with kills enabled requires task_region")
    per_job_ckpt = isinstance(ckpt_interval_s, (list, tuple, np.ndarray))
    if per_job_ckpt and job_of_task is None:
        raise ValueError("per-job ckpt_interval_s requires job_of_task")
    any_ckpt = (any(iv is not None for iv in ckpt_interval_s)
                if per_job_ckpt else ckpt_interval_s is not None)
    region_ckpt = (any(m != "global" for m in ckpt_mode)
                   if isinstance(ckpt_mode, (list, tuple, np.ndarray))
                   else ckpt_mode != "global")
    if any_ckpt and region_ckpt and regions is None:
        raise ValueError(
            "region checkpoint mode requires regions (the retry draws "
            "consume the rng stream — omitting them would desynchronize "
            "every later draw from the live engine)")
    # straggler draws happen at first sight of each host, in task order —
    # identical to StreamEngine.__init__'s per-task host_speed() queries
    task_speed = np.array([eng.host_speed(int(h)) for h in task_host])

    ts = np.zeros(n_ticks)
    kills = np.zeros((n_ticks, n_hosts), bool)
    ckpt_at = np.zeros(n_ticks, np.int16)
    ckpt_ok = np.zeros(n_ticks, np.int16)
    down = np.zeros(n_tasks)
    recoveries: list[dict] = []
    attempts = success = failed = 0
    if per_job_ckpt:
        n_jobs = int(np.max(job_of_task)) + 1
        jobs = _JobCkpt.from_seq(n_jobs, ckpt_interval_s, ckpt_mode,
                                 ckpt_upload_s, ckpt_retry, job_of_task,
                                 regions)
        ckpt_by_job = np.zeros((n_jobs, 3), int)
    else:
        next_ckpt = (ckpt_interval_s if ckpt_interval_s is not None
                     else math.inf)
        ckpt_by_job = None
    t = 0.0
    for i in range(n_ticks):
        ts[i] = t
        if kills_possible:
            for host in eng.step_kills(t, t + dt, n_hosts=n_hosts):
                if host < n_hosts:
                    # scheduled kills are unbounded by n_hosts; a kill of
                    # a hostless id is a no-op (the engine just revives)
                    kills[i, host] = True
                _resolve_failover_tick(t, host, task_host, task_region,
                                       mode_codes, down_s, down_r, down,
                                       recoveries, job_of_task)
                eng.revive(host)   # replacement host, as in _fail_host
        if per_job_ckpt:
            for jc in jobs:
                if t + dt < jc.next_at:
                    continue
                ok = jc.attempt(eng, down, t)
                ckpt_at[i] += 1
                ckpt_ok[i] += int(ok)
                attempts += 1
                success += int(ok)
                failed += int(not ok)
                ckpt_by_job[jc.job] += (1, int(ok), int(not ok))
        elif t + dt >= next_ckpt:
            ckpt_at[i] = 1
            attempts += 1
            ok = run_checkpoint_attempt(
                eng, down <= t, interval_s=ckpt_interval_s,
                mode=ckpt_mode, upload_s=ckpt_upload_s, retry=ckpt_retry,
                regions=regions or ())
            ckpt_ok[i] = int(ok)
            success += int(ok)
            failed += int(not ok)
            next_ckpt += ckpt_interval_s
        t = t + dt
    return ChaosTimeline(dt, n_ticks, ts, task_speed, kills, ckpt_at,
                         ckpt_ok, attempts, success, failed, recoveries,
                         ckpt_by_job=ckpt_by_job)


class _JobCkpt:
    """Per-job checkpoint coordinator state for the timeline replay —
    draws upload factors for the job's own task slice only, mirroring
    `StreamEngine._run_checkpoint_job` draw-for-draw."""

    def __init__(self, job, interval, mode, upload, retry, lo, hi, regions):
        self.job, self.interval, self.mode = job, interval, mode
        self.upload, self.retry = upload, retry
        self.lo, self.hi, self.regions = lo, hi, regions
        self.next_at = interval if interval is not None else math.inf

    @classmethod
    def from_seq(cls, n_jobs, intervals, modes, uploads, retries,
                 job_of_task, regions):
        def seq(v, default):
            if isinstance(v, (list, tuple, np.ndarray)):
                if len(v) != n_jobs:
                    raise ValueError(
                        f"per-job ckpt params need one entry per job "
                        f"({len(v)} != {n_jobs})")
                return list(v)
            return [v if v is not None else default] * n_jobs

        intervals = seq(intervals, None)
        modes = seq(modes, "region")
        uploads = seq(uploads, 4.0)
        retries = seq(retries, True)
        out = []
        for j in range(n_jobs):
            mask = np.asarray(job_of_task) == j
            lo = int(np.nonzero(mask)[0][0])
            hi = int(np.nonzero(mask)[0][-1]) + 1
            if int(mask.sum()) != hi - lo:
                raise ValueError("per-job ckpt needs contiguous job "
                                 "task slices")
            regs = [r for r in (regions or ())
                    if lo <= min(r) < hi]
            out.append(cls(j, intervals[j], modes[j], uploads[j],
                           retries[j], lo, hi, regs))
        return out

    def attempt(self, eng: ChaosEngine, down: np.ndarray, t: float) -> bool:
        self.next_at += self.interval
        return run_checkpoint_attempt(
            eng, down[self.lo:self.hi] <= t, interval_s=self.interval,
            mode=self.mode, upload_s=self.upload, retry=self.retry,
            regions=self.regions, task_lo=self.lo)


def refit_failover(tl: ChaosTimeline, *, task_host: np.ndarray,
                   task_region: np.ndarray | None = None,
                   failover_mode="region", detect_s=1.0,
                   region_restart_s=45.0, single_restart_s=3.0,
                   job_of_task: np.ndarray | None = None) -> ChaosTimeline:
    """Re-resolve a pregenerated timeline's failover metadata (recovery
    events) under different failover parameters WITHOUT consuming any rng
    — the cheap path that lets config sweeps share one set of chaos draws
    across a whole restart-budget grid.

    Only valid for timelines with no checkpoint activity: checkpoint
    storage draws interleave with kill draws and their count depends on
    task liveness (hence on the failover config), so a ckpt-bearing
    timeline is config-specific and must be rebuilt per config."""
    if tl.ckpt_attempts:
        raise ValueError(
            "refit_failover needs a checkpoint-free timeline (storage "
            "draws are failover-config-dependent — rebuild per config)")
    task_host = np.asarray(task_host)
    n_tasks = len(task_host)
    mode_codes = failover_mode_codes(failover_mode, n_tasks)
    down_s = _per_task(detect_s, n_tasks) + _per_task(single_restart_s,
                                                      n_tasks)
    down_r = _per_task(detect_s, n_tasks) + _per_task(region_restart_s,
                                                      n_tasks)
    if (mode_codes == 1).any() and tl.kills.any() and task_region is None:
        raise ValueError("region failover refit requires task_region")
    down = np.zeros(n_tasks)
    recoveries: list[dict] = []
    for i in np.nonzero(tl.kills.any(axis=1))[0]:
        t = float(tl.ts[i])
        for host in np.nonzero(tl.kills[i])[0]:
            _resolve_failover_tick(t, int(host), task_host, task_region,
                                   mode_codes, down_s, down_r, down,
                                   recoveries, job_of_task)
    return dataclasses.replace(tl, recoveries=recoveries)
