"""Chaos engine (paper §V-B): deterministic fault injection at the hardware
level (storage latency/failures, stragglers, network degradation) and the
process level (host/TaskManager kills). All draws come from a seeded
generator, so every drill is reproducible bit-for-bit."""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    seed: int = 0
    # storage (HDFS-sim): slow uploads + hard failures
    storage_slow_prob: float = 0.0
    storage_slow_factor: float = 10.0
    storage_fail_prob: float = 0.0
    # process level
    host_kill_prob_per_s: float = 0.0
    host_kill_at: tuple[tuple[float, int], ...] = ()   # (time, host_id)
    # stragglers: fraction of hosts that are slow by `straggler_factor`
    straggler_frac: float = 0.0
    straggler_factor: float = 4.0
    # network
    net_delay_factor: float = 1.0
    # coordination (ZK-sim) outage windows
    zk_down: tuple[tuple[float, float], ...] = ()
    hdfs_down: tuple[tuple[float, float], ...] = ()


class ChaosEngine:
    def __init__(self, spec: ChaosSpec | None = None):
        self.spec = spec or ChaosSpec()
        self._rng = np.random.default_rng(self.spec.seed)
        self._killed: set[int] = set()
        self._stragglers: dict[int, bool] = {}

    # -- storage -------------------------------------------------------
    def storage_latency_factor(self) -> float:
        if self.spec.storage_slow_prob and \
                self._rng.random() < self.spec.storage_slow_prob:
            return self.spec.storage_slow_factor
        return 1.0

    def storage_latency_factors(self, n: int) -> np.ndarray:
        """Vectorized batch of `n` latency factors. Draw-for-draw equivalent
        to `n` sequential `storage_latency_factor()` calls (numpy Generators
        produce the same stream for `random(n)` as for n scalar draws), so
        the vectorized engine stays bit-identical to the reference."""
        if not self.spec.storage_slow_prob:
            return np.ones(n)
        slow = self._rng.random(n) < self.spec.storage_slow_prob
        return np.where(slow, self.spec.storage_slow_factor, 1.0)

    def storage_fails(self) -> bool:
        return bool(self.spec.storage_fail_prob
                    and self._rng.random() < self.spec.storage_fail_prob)

    # -- hosts -----------------------------------------------------------
    def is_straggler(self, host_id: int) -> bool:
        if host_id not in self._stragglers:
            self._stragglers[host_id] = bool(
                self.spec.straggler_frac
                and self._rng.random() < self.spec.straggler_frac)
        return self._stragglers[host_id]

    def host_speed(self, host_id: int) -> float:
        return (1.0 / self.spec.straggler_factor
                if self.is_straggler(host_id) else 1.0)

    def step_kills(self, t0: float, t1: float, n_hosts: int) -> list[int]:
        """Hosts killed in (t0, t1]: scheduled kills + Poisson random kills.

        The Poisson draws are batched — one ``random(n_alive)`` call over
        the alive hosts in ascending id order, which numpy Generators
        guarantee is the same stream as n_alive sequential scalar draws —
        so large host pools (multi-job arenas) don't pay per-host Python
        rng calls every tick."""
        kills = [h for (t, h) in self.spec.host_kill_at
                 if t0 < t <= t1 and h not in self._killed]
        if self.spec.host_kill_prob_per_s:
            p = 1.0 - np.exp(-self.spec.host_kill_prob_per_s * (t1 - t0))
            if self._killed:
                alive = np.array([h for h in range(n_hosts)
                                  if h not in self._killed])
            else:
                alive = np.arange(n_hosts)
            if len(alive):
                kills.extend(
                    int(h) for h in alive[self._rng.random(len(alive)) < p])
        self._killed.update(kills)
        return sorted(set(kills))

    def revive(self, host_id: int) -> None:
        self._killed.discard(host_id)

    def alive(self, host_id: int) -> bool:
        return host_id not in self._killed

    # -- coordination services -------------------------------------------
    def zk_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.zk_down)

    def hdfs_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.hdfs_down)


def failover_recovery_entries(t: float, mode: str, hit: np.ndarray,
                              downtime: float,
                              job_of_task: np.ndarray | None = None
                              ) -> list[dict]:
    """Recovery-event dicts for one failover action over `hit` tasks.

    Single-job runs (``job_of_task=None``) keep the historical one-entry
    format. Packed multi-job arenas (`streams.engine.pack_arena`) emit one
    entry per affected job — ascending job id, with a ``"job"`` key — so a
    shared-host kill that downs tasks of several co-located jobs is
    attributable per job. Used by both the live `StreamEngine` and the
    pregenerated timeline so the two stay comparable with ``==``."""
    if job_of_task is None:
        return [{"t": t, "mode": mode, "tasks": int(hit.sum()),
                 "downtime": downtime}]
    return [{"t": t, "mode": mode,
             "tasks": int((hit & (job_of_task == j)).sum()),
             "downtime": downtime, "job": int(j)}
            for j in np.unique(job_of_task[hit])]


# ----------------------------------------------------------------------
# Pregenerated event tensors (accelerator backends / chaos sweeps)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ChaosTimeline:
    """Chaos events for one run, materialized as per-tick tensors.

    A `jit`-compiled engine cannot consume the sequential numpy rng draws
    of `ChaosEngine` mid-scan, so the whole chaos/failover/checkpoint
    control timeline is replayed here on the host — draw-for-draw in the
    exact order `streams.engine.StreamEngine` consumes the rng stream
    (straggler speeds at init; per tick: kill draws, then checkpoint
    storage draws) — and exported as dense arrays the device loop indexes
    by tick. Kill/checkpoint *times* are thereby quantized to tick
    boundaries, which is exactly the resolution the tick engines observe
    them at anyway.
    """
    dt: float
    n_ticks: int
    ts: np.ndarray             # (n_ticks,) tick-start times (accumulated)
    task_speed: np.ndarray     # (n_tasks,) chaos straggler speed factors
    kills: np.ndarray          # (n_ticks, n_hosts) bool host killed in tick
    ckpt_at: np.ndarray        # (n_ticks,) bool checkpoint attempted
    ckpt_ok: np.ndarray        # (n_ticks,) bool checkpoint succeeded
    ckpt_attempts: int
    ckpt_success: int
    ckpt_failed: int
    recoveries: list[dict]     # same dict layout as EngineMetrics.recoveries


def build_chaos_timeline(
        spec: ChaosSpec, *, n_ticks: int, dt: float, n_hosts: int,
        task_host: np.ndarray, task_region: np.ndarray | None = None,
        regions: list | None = None,
        failover_mode: str = "region", detect_s: float = 1.0,
        region_restart_s: float = 45.0, single_restart_s: float = 3.0,
        ckpt_interval_s: float | None = None, ckpt_mode: str = "region",
        ckpt_upload_s: float = 4.0, ckpt_retry: bool = True,
        job_of_task: np.ndarray | None = None) -> ChaosTimeline:
    """Replay the engine's chaos rng consumption for `n_ticks` ticks.

    Host kills, checkpoint outcomes and failover downtimes are all
    data-independent of queue dynamics (downtime depends only on kills +
    failover config), so the full control timeline is computable here
    without simulating a single record. `task_host`/`task_region`/`regions`
    describe the physical placement (same arrays the engine derives from
    `PhysicalGraph`); failover/checkpoint parameters mirror
    `FailoverConfig`/`CheckpointConfig` field-for-field (passed as plain
    scalars to keep `core` free of a `streams` import).
    """
    eng = ChaosEngine(spec)
    task_host = np.asarray(task_host)
    n_tasks = len(task_host)
    kills_possible = bool(spec.host_kill_at or spec.host_kill_prob_per_s)
    if kills_possible and failover_mode == "region" and task_region is None:
        raise ValueError(
            "failover_mode='region' with kills enabled requires task_region")
    if ckpt_interval_s is not None and ckpt_mode != "global" \
            and regions is None:
        raise ValueError(
            "region checkpoint mode requires regions (the retry draws "
            "consume the rng stream — omitting them would desynchronize "
            "every later draw from the live engine)")
    # straggler draws happen at first sight of each host, in task order —
    # identical to StreamEngine.__init__'s per-task host_speed() queries
    task_speed = np.array([eng.host_speed(int(h)) for h in task_host])

    ts = np.zeros(n_ticks)
    kills = np.zeros((n_ticks, n_hosts), bool)
    ckpt_at = np.zeros(n_ticks, bool)
    ckpt_ok = np.zeros(n_ticks, bool)
    down = np.zeros(n_tasks)
    recoveries: list[dict] = []
    attempts = success = failed = 0
    next_ckpt = ckpt_interval_s if ckpt_interval_s is not None else math.inf
    t = 0.0
    for i in range(n_ticks):
        ts[i] = t
        if kills_possible:
            for host in eng.step_kills(t, t + dt, n_hosts=n_hosts):
                if host < n_hosts:
                    # scheduled kills are unbounded by n_hosts; a kill of
                    # a hostless id is a no-op (the engine just revives)
                    kills[i, host] = True
                victims = task_host == host
                if victims.any() and failover_mode != "none":
                    if failover_mode == "single_task":
                        hit = victims
                        downtime = detect_s + single_restart_s
                    else:
                        hit = np.isin(task_region, task_region[victims])
                        downtime = detect_s + region_restart_s
                    down[hit] = t + downtime
                    recoveries.extend(failover_recovery_entries(
                        t, failover_mode, hit, downtime, job_of_task))
                eng.revive(host)   # replacement host, as in _fail_host
        if t + dt >= next_ckpt:
            ckpt_at[i] = True
            attempts += 1
            timeout = ckpt_interval_s
            factors = eng.storage_latency_factors(n_tasks)
            alive = down <= t
            task_fail = (ckpt_upload_s * factors > timeout) | ~alive
            if ckpt_mode == "global":
                ok = bool(not task_fail.any())
            else:
                ok = True
                for region in (regions or ()):
                    bad = any(task_fail[tid] for tid in region)
                    if bad and ckpt_retry:
                        # one in-attempt retry of the region's uploads
                        # (short-circuits on the first slow draw, exactly
                        # like the engine's any(...) generator)
                        bad = any(
                            ckpt_upload_s * eng.storage_latency_factor()
                            > timeout for _ in region)
                    if bad:
                        ok = False
                        break
            ckpt_ok[i] = ok
            success += int(ok)
            failed += int(not ok)
            next_ckpt += ckpt_interval_s
        t = t + dt
    return ChaosTimeline(dt, n_ticks, ts, task_speed, kills, ckpt_at,
                         ckpt_ok, attempts, success, failed, recoveries)
