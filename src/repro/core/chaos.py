"""Chaos engine (paper §V-B): deterministic fault injection at the hardware
level (storage latency/failures, stragglers, network degradation) and the
process level (host/TaskManager kills). All draws come from a seeded
generator, so every drill is reproducible bit-for-bit."""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    seed: int = 0
    # storage (HDFS-sim): slow uploads + hard failures
    storage_slow_prob: float = 0.0
    storage_slow_factor: float = 10.0
    storage_fail_prob: float = 0.0
    # process level
    host_kill_prob_per_s: float = 0.0
    host_kill_at: tuple[tuple[float, int], ...] = ()   # (time, host_id)
    # stragglers: fraction of hosts that are slow by `straggler_factor`
    straggler_frac: float = 0.0
    straggler_factor: float = 4.0
    # network
    net_delay_factor: float = 1.0
    # coordination (ZK-sim) outage windows
    zk_down: tuple[tuple[float, float], ...] = ()
    hdfs_down: tuple[tuple[float, float], ...] = ()
    # external systems (paper §IV): storage brownouts as latency-factor
    # *ramps* (t0, t1, peak) — the multiplier climbs 1→peak→1 over
    # [t0, t1) and stretches storage ops / checkpoint-attempt durations —
    # MQ/coordinator outage windows that gate source operators, and
    # region-correlated failure bursts (time, region_id) downing every
    # host that serves the region. All three are deterministic: they
    # consume NO rng draws, so they can never desynchronize the replayed
    # draw stream between the live engines and the pregenerated timelines.
    brownout_at: tuple[tuple[float, float, float], ...] = ()
    mq_down: tuple[tuple[float, float], ...] = ()
    burst_at: tuple[tuple[float, int], ...] = ()
    # deployment drills (paper §V): scheduled rolling-upgrade start times.
    # Like the family above these are deterministic and consume NO rng
    # draws — upgrade waves never touch the pregenerated kill/checkpoint
    # timelines, they are pure time arithmetic inside the engines' ticks
    # (streams.engine.UpgradeConfig carries the HOW: canary fraction,
    # wave stagger, hot-vs-cold restart costs, rollback policy).
    upgrade_at: tuple[float, ...] = ()
    # traffic dynamics (paper §III-A): deterministic source-rate
    # schedules. `diurnal` sinusoids (amp, period_s, phase_s) multiply
    # the source rate by 1 + amp*sin(2π(t + phase_s)/period_s); an
    # amp=0.0 entry is the exactly-1.0 identity (the constant-schedule
    # no-op guarantee is bit-exact). `flash_at` flash-crowd spikes
    # (t0, ramp_s, hold_s, peak) ramp 1→peak over ramp_s, hold at peak
    # for hold_s, then ramp back down over ramp_s; overlapping entries
    # multiply. `rate_phase_s` shifts every diurnal entry of THIS spec —
    # per-job spec lists de-synchronize co-located jobs' peaks across a
    # packed arena with otherwise identical schedules. All deterministic:
    # they consume NO rng draws (same contract as the family above), so
    # rate schedules never touch the pregenerated kill/ckpt timelines.
    diurnal: tuple[tuple[float, float, float], ...] = ()
    flash_at: tuple[tuple[float, float, float, float], ...] = ()
    rate_phase_s: float = 0.0


class ChaosEngine:
    def __init__(self, spec: ChaosSpec | None = None):
        self.spec = spec or ChaosSpec()
        self._rng = np.random.default_rng(self.spec.seed)
        self._killed: set[int] = set()
        self._stragglers: dict[int, bool] = {}
        self._extra_kill_at: list[tuple[float, int]] = []

    # -- storage -------------------------------------------------------
    def storage_latency_factor(self) -> float:
        if self.spec.storage_slow_prob and \
                self._rng.random() < self.spec.storage_slow_prob:
            return self.spec.storage_slow_factor
        return 1.0

    def storage_latency_factors(self, n: int) -> np.ndarray:
        """Vectorized batch of `n` latency factors. Draw-for-draw equivalent
        to `n` sequential `storage_latency_factor()` calls (numpy Generators
        produce the same stream for `random(n)` as for n scalar draws), so
        the vectorized engine stays bit-identical to the reference."""
        if not self.spec.storage_slow_prob:
            return np.ones(n)
        slow = self._rng.random(n) < self.spec.storage_slow_prob
        return np.where(slow, self.spec.storage_slow_factor, 1.0)

    def storage_fails(self) -> bool:
        return bool(self.spec.storage_fail_prob
                    and self._rng.random() < self.spec.storage_fail_prob)

    # -- hosts -----------------------------------------------------------
    def is_straggler(self, host_id: int) -> bool:
        if host_id not in self._stragglers:
            self._stragglers[host_id] = bool(
                self.spec.straggler_frac
                and self._rng.random() < self.spec.straggler_frac)
        return self._stragglers[host_id]

    def host_speed(self, host_id: int) -> float:
        return (1.0 / self.spec.straggler_factor
                if self.is_straggler(host_id) else 1.0)

    def step_kills(self, t0: float, t1: float, n_hosts: int) -> list[int]:
        """Hosts killed in (t0, t1]: scheduled kills + Poisson random kills.

        The Poisson draws are batched — one ``random(n_alive)`` call over
        the alive hosts in ascending id order, which numpy Generators
        guarantee is the same stream as n_alive sequential scalar draws —
        so large host pools (multi-job arenas) don't pay per-host Python
        rng calls every tick."""
        kills = [h for (t, h) in (tuple(self.spec.host_kill_at)
                                  + tuple(self._extra_kill_at))
                 if t0 < t <= t1 and h not in self._killed]
        if self.spec.host_kill_prob_per_s:
            p = 1.0 - np.exp(-self.spec.host_kill_prob_per_s * (t1 - t0))
            if self._killed:
                alive = np.array([h for h in range(n_hosts)
                                  if h not in self._killed])
            else:
                alive = np.arange(n_hosts)
            if len(alive):
                kills.extend(
                    int(h) for h in alive[self._rng.random(len(alive)) < p])
        self._killed.update(kills)
        return sorted(set(kills))

    def revive(self, host_id: int) -> None:
        self._killed.discard(host_id)

    def alive(self, host_id: int) -> bool:
        return host_id not in self._killed

    def schedule_kills(self, events) -> None:
        """Register extra deterministic (time, host) kill events, consumed
        by `step_kills` exactly like `spec.host_kill_at` (no rng drawn).
        Used to expand region-correlated failure bursts once task→host
        placement is known."""
        self._extra_kill_at.extend((float(t), int(h)) for t, h in events)

    # -- coordination services -------------------------------------------
    def zk_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.zk_down)

    def hdfs_available(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.spec.hdfs_down)

    # -- external systems -------------------------------------------------
    def brownout_factor(self, t: float) -> float:
        """Deterministic storage-brownout latency multiplier at time t."""
        return brownout_factor_at(self.spec.brownout_at, t)

    def mq_available(self, t: float) -> bool:
        """MQ/coordinator availability — gates source operators."""
        return not any(a <= t < b for a, b in self.spec.mq_down)

    def traffic_factor(self, t: float) -> float:
        """Deterministic source-rate multiplier at time t (diurnal
        sinusoids × flash-crowd ramps, phase-shifted by the spec's
        ``rate_phase_s``)."""
        return traffic_factor_at(self.spec.diurnal, self.spec.flash_at, t,
                                 phase_s=self.spec.rate_phase_s)

    def leader_available(self, t: float) -> bool:
        """JobManager leader reachability at time t, lowered from the
        `cluster.coordinator.Coordinator` ZK → HDFS fallback chain: the
        leader address stays discoverable while EITHER service is up, so
        sources are throttled only where a `zk_down` window overlaps an
        `hdfs_down` window (both legs of the HA chain dark)."""
        return self.zk_available(t) or self.hdfs_available(t)


def brownout_factor_at(ramps, t: float) -> float:
    """Storage-brownout multiplier at time `t`: each (t0, t1, peak) ramp
    climbs linearly 1→peak over the first half of [t0, t1) and falls back
    peak→1 over the second half; overlapping ramps multiply (so merging
    two ramp tuples composes their factors)."""
    f = 1.0
    for (a, b, peak) in ramps:
        if a <= t < b:
            frac = 1.0 - abs(2.0 * (t - a) / (b - a) - 1.0)
            f *= 1.0 + (peak - 1.0) * frac
    return f


def brownout_curve(ramps, ts) -> np.ndarray:
    """Vectorized `brownout_factor_at` over an array of times."""
    ts = np.asarray(ts, dtype=float)
    out = np.ones(ts.shape)
    for (a, b, peak) in ramps:
        inside = (ts >= a) & (ts < b)
        if not inside.any():
            continue
        frac = 1.0 - np.abs(2.0 * (ts - a) / (b - a) - 1.0)
        out = np.where(inside, out * (1.0 + (peak - 1.0) * frac), out)
    return out


def traffic_factor_at(diurnal, flash_at, t: float, *,
                      phase_s: float = 0.0) -> float:
    """Source-rate multiplier at time `t`: diurnal sinusoids
    ``1 + amp*sin(2π(t + phase_s + phase)/period)`` × flash-crowd
    trapezoids ``(t0, ramp_s, hold_s, peak)`` (1→peak over ramp_s, held
    for hold_s, back down over ramp_s). Entries multiply; the result is
    floored at 0 (a deep diurnal trough cannot emit negative records).
    ``amp=0`` / ``peak=1`` entries are the exact 1.0 identity."""
    f = 1.0
    for (amp, period, phase) in diurnal:
        f *= 1.0 + amp * math.sin(
            2.0 * math.pi * (t + phase_s + phase) / period)
    for (t0, ramp, hold, peak) in flash_at:
        if t0 <= t < t0 + 2.0 * ramp + hold:
            u = t - t0
            if u < ramp:
                frac = u / ramp
            elif u < ramp + hold:
                frac = 1.0
            else:
                frac = 1.0 - (u - ramp - hold) / ramp
            f *= 1.0 + (peak - 1.0) * frac
    return max(f, 0.0)


def traffic_curve(diurnal, flash_at, ts, *, phase_s: float = 0.0
                  ) -> np.ndarray:
    """Vectorized `traffic_factor_at` over an array of times. The
    schedule-free call returns EXACT ones (multiplying source emission
    by it is a bit-exact no-op)."""
    ts = np.asarray(ts, dtype=float)
    out = np.ones(ts.shape)
    for (amp, period, phase) in diurnal:
        out = out * (1.0 + amp * np.sin(
            2.0 * np.pi * (ts + phase_s + phase) / period))
    for (t0, ramp, hold, peak) in flash_at:
        inside = (ts >= t0) & (ts < t0 + 2.0 * ramp + hold)
        if not inside.any():
            continue
        u = ts - t0
        frac = np.where(u < ramp, u / ramp,
                        np.where(u < ramp + hold, 1.0,
                                 1.0 - (u - ramp - hold) / ramp))
        out = np.where(inside, out * (1.0 + (peak - 1.0) * frac), out)
    return np.maximum(out, 0.0)


def mq_gate_curve(windows, ts) -> np.ndarray:
    """1.0/0.0 source gate per time (1 = MQ available, sources emit)."""
    ts = np.asarray(ts, dtype=float)
    gate = np.ones(ts.shape)
    for (a, b) in windows:
        gate[(ts >= a) & (ts < b)] = 0.0
    return gate


def coordinator_gate_curve(zk_down, hdfs_down, ts) -> np.ndarray:
    """1.0/0.0 source gate per time for coordinator leader loss: 0 only
    where a `zk_down` window overlaps an `hdfs_down` window (leader lost
    AND the HDFS fallback leg unreachable — the
    `cluster.coordinator.LeaderService` chain has no one to answer).
    Composes multiplicatively with `mq_gate_curve`."""
    ts = np.asarray(ts, dtype=float)
    zk_out = np.zeros(ts.shape, dtype=bool)
    for (a, b) in zk_down:
        zk_out |= (ts >= a) & (ts < b)
    hdfs_out = np.zeros(ts.shape, dtype=bool)
    for (a, b) in hdfs_down:
        hdfs_out |= (ts >= a) & (ts < b)
    gate = np.ones(ts.shape)
    gate[zk_out & hdfs_out] = 0.0
    return gate


def burst_kill_schedule(burst_at, task_host, task_region):
    """Expand region-correlated failure bursts into deterministic
    (time, host) kill events: a (t, region) burst downs every host
    serving >= 1 task of that region, under the same ``t0 < t <= t1``
    tick-window convention as `host_kill_at`. Pass local task/host views
    for per-job chaos domains."""
    if not burst_at:
        return ()
    if task_region is None:
        raise ValueError("burst_at requires task_region placement")
    task_host = np.asarray(task_host)
    task_region = np.asarray(task_region)
    out = []
    for (tb, reg) in burst_at:
        hosts = np.unique(task_host[task_region == int(reg)])
        out.extend((float(tb), int(h)) for h in hosts)
    return tuple(out)


def ckpt_age_curve(ts, ok, n_jobs: int) -> np.ndarray:
    """(n_ticks, n_jobs) checkpoint age at each tick start: ts[i] minus
    the tick-start time of the latest success STRICTLY before tick i
    (kills precede the tick's own attempt in every replay), with a 0.0
    start-of-run baseline — age = t until the first success, i.e. a
    passive restore replays from the beginning of the run. `ok` is the
    per-tick success count, (n_ticks,) for a shared coordinator
    (broadcast over jobs) or (n_ticks, n_jobs) for per-job ones."""
    ts = np.asarray(ts, dtype=float)
    ok = np.asarray(ok)
    ok2 = ok[:, None] if ok.ndim == 1 else ok
    ok2 = np.broadcast_to(ok2 > 0, (len(ts), n_jobs))
    last = np.zeros((len(ts), n_jobs))
    if len(ts) > 1:
        succ = np.where(ok2[:-1], ts[:-1, None], 0.0)
        last[1:] = np.maximum.accumulate(succ, axis=0)
    return ts[:, None] - last


def failover_recovery_entries(t: float, mode: str, hit: np.ndarray,
                              downtime,
                              job_of_task: np.ndarray | None = None
                              ) -> list[dict]:
    """Recovery-event dicts for one failover action over `hit` tasks.

    Single-job runs (``job_of_task=None``) keep the historical one-entry
    format. Packed multi-job arenas (`streams.engine.pack_arena`) emit one
    entry per affected job — ascending job id, with a ``"job"`` key — so a
    shared-host kill that downs tasks of several co-located jobs is
    attributable per job. `downtime` may be a scalar or a per-task vector
    (per-job failover configs): each job's entry reports the downtime of
    its own hit tasks, which per-job configs keep uniform within a job.
    Used by both the live `StreamEngine` and the pregenerated timeline so
    the two stay comparable with ``==``."""
    dt_arr = np.asarray(downtime, dtype=float)
    if job_of_task is None:
        d = float(dt_arr.flat[0]) if dt_arr.ndim else float(dt_arr)
        return [{"t": t, "mode": mode, "tasks": int(hit.sum()),
                 "downtime": d}]

    def _dt(j):
        if dt_arr.ndim == 0:
            return float(dt_arr)
        return float(dt_arr[hit & (job_of_task == j)][0])

    return [{"t": t, "mode": mode,
             "tasks": int((hit & (job_of_task == j)).sum()),
             "downtime": _dt(j), "job": int(j)}
            for j in np.unique(job_of_task[hit])]


_MODE_CODE = {"none": 0, "region": 1, "single_task": 2, "hot_standby": 3}


def failover_mode_codes(failover_mode, n_tasks: int) -> np.ndarray:
    """Normalize a failover mode (name string or per-task int-code vector)
    to an ``(n_tasks,)`` int8 code vector: 0 none, 1 region, 2
    single_task, 3 hot_standby. Per-task codes are how per-job
    `FailoverConfig`s reach the chaos timeline and the engines without
    `core` importing `streams`."""
    if isinstance(failover_mode, str):
        return np.full(n_tasks, _MODE_CODE[failover_mode], np.int8)
    codes = np.asarray(failover_mode, dtype=np.int8)
    if codes.shape != (n_tasks,):
        raise ValueError(f"mode codes must be (n_tasks,)={n_tasks}, "
                         f"got {codes.shape}")
    return codes


def _per_task(v, n_tasks: int) -> np.ndarray:
    return np.broadcast_to(np.asarray(v, dtype=float), (n_tasks,))


def _resolve_failover_tick(t, host, task_host, task_region, mode_codes,
                           down_s, down_r, down, recoveries, job_of_task,
                           down_h=None, extra=None):
    """One host kill → failover response (shared by the pregenerated
    timeline, `refit_failover` and — semantically — the live engine's
    `_fail_host`): region-mode victims expand to their regions, then
    single_task-mode victims restart alone, then hot_standby victims
    switch to their standby replica. Entries keep that order when one
    shared-host kill hits jobs of several modes.

    `extra` is the per-task passive-restore surcharge at kill time —
    ``restore_base * brownout + ckpt_age * replay_rate + lazy_extra`` —
    added to region/single downtimes (restores re-read the checkpoint);
    hot_standby pays `down_h` (detect + switch + staleness replay) only,
    since the standby never touches checkpoint storage."""
    victims = task_host == host
    vr = victims & (mode_codes == 1)
    if vr.any():
        hit = np.isin(task_region, task_region[vr])
        d = down_r if extra is None else down_r + extra
        down[hit] = t + d[hit]
        recoveries.extend(failover_recovery_entries(
            t, "region", hit, d, job_of_task))
    vs = victims & (mode_codes == 2)
    if vs.any():
        d = down_s if extra is None else down_s + extra
        down[vs] = t + d[vs]
        recoveries.extend(failover_recovery_entries(
            t, "single_task", vs, d, job_of_task))
    vh = victims & (mode_codes == 3)
    if vh.any() and down_h is not None:
        down[vh] = t + down_h[vh]
        recoveries.extend(failover_recovery_entries(
            t, "hot_standby", vh, down_h, job_of_task))


def run_checkpoint_attempt(eng: ChaosEngine, alive: np.ndarray, *,
                           interval_s: float, mode: str, upload_s: float,
                           retry: bool, regions, task_lo: int = 0,
                           t: float = 0.0) -> bool:
    """One checkpoint attempt over the tasks covered by `alive` (their
    liveness at attempt time): per-task upload-factor draws against the
    interval timeout, then global abort-on-any-failure or per-region
    evaluation with one short-circuiting retry of a failed region.

    THE single definition of the attempt's rng consumption — shared by
    the live `StreamEngine` coordinators (whole-arena and per-job) and
    the pregenerated timeline replay, so the draw stream cannot
    desynchronize between them. `regions` hold global task ids;
    `task_lo` maps them into `alive` for per-job slices. `t` is the
    attempt time: a storage brownout active at `t` stretches every
    upload by the (deterministic) ramp factor, so a brownout-inflated
    attempt can never ack early — it fails the interval timeout
    instead. The brownout multiplier consumes no rng, so the draw
    stream is unchanged."""
    bf = eng.brownout_factor(t)
    factors = eng.storage_latency_factors(len(alive))
    task_fail = (upload_s * factors * bf > interval_s) | ~alive
    if mode == "global":
        return bool(not task_fail.any())
    for region in regions:
        bad = any(task_fail[tid - task_lo] for tid in region)
        if bad and retry:
            # one in-attempt retry of the region's uploads
            # (short-circuits on the first slow draw, exactly like the
            # engine's any(...) generator)
            bad = any(upload_s * eng.storage_latency_factor() * bf
                      > interval_s for _ in region)
        if bad:
            return False  # region keeps previous snapshot; attempt
            # counted failed by the caller, job continues (no abort)
    return True


# host-replay accounting: every build_chaos_timeline call is one full
# per-tick host replay. Config-grid sweeps must NOT scale this with the
# grid (`build_grid_timelines` replays per seed, then refits per config
# with vectorized draws) — benchmarks read the counter to prove it.
_TIMELINE_STATS = {"builds": 0, "grid_replays": 0}


def timeline_build_count() -> int:
    """Number of per-tick host timeline replays (`build_chaos_timeline`
    calls) so far in this process."""
    return _TIMELINE_STATS["builds"]


# ----------------------------------------------------------------------
# Pregenerated event tensors (accelerator backends / chaos sweeps)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ChaosTimeline:
    """Chaos events for one run, materialized as per-tick tensors.

    A `jit`-compiled engine cannot consume the sequential numpy rng draws
    of `ChaosEngine` mid-scan, so the whole chaos/failover/checkpoint
    control timeline is replayed here on the host — draw-for-draw in the
    exact order `streams.engine.StreamEngine` consumes the rng stream
    (straggler speeds at init; per tick: kill draws, then checkpoint
    storage draws) — and exported as dense arrays the device loop indexes
    by tick. Kill/checkpoint *times* are thereby quantized to tick
    boundaries, which is exactly the resolution the tick engines observe
    them at anyway.
    """
    dt: float
    n_ticks: int
    ts: np.ndarray             # (n_ticks,) tick-start times (accumulated)
    task_speed: np.ndarray     # (n_tasks,) chaos straggler speed factors
    kills: np.ndarray          # (n_ticks, n_hosts) bool host killed in tick
    ckpt_at: np.ndarray        # (n_ticks,) i16 checkpoint attempts in tick
    ckpt_ok: np.ndarray        # (n_ticks,) i16 successes in tick
    ckpt_attempts: int
    ckpt_success: int
    ckpt_failed: int
    recoveries: list[dict]     # same dict layout as EngineMetrics.recoveries
    # per-job checkpoint counters — populated only when per-job
    # CheckpointConfigs drive the replay ((n_jobs, 3) attempts/success/
    # failed); None for a single shared coordinator
    ckpt_by_job: np.ndarray | None = None
    # per-tick per-job success counts ((n_ticks, n_jobs) i16) — populated
    # by per-job coordinator replays so checkpoint-AGE tensors (hot-standby
    # vs passive restore cost) can be derived per job; None for a shared
    # coordinator (broadcast `ckpt_ok` instead, see `ckpt_age_curve`)
    ckpt_ok_by_job: np.ndarray | None = None


def build_chaos_timeline(
        spec: ChaosSpec, *, n_ticks: int, dt: float, n_hosts: int,
        task_host: np.ndarray, task_region: np.ndarray | None = None,
        regions: list | None = None,
        failover_mode="region", detect_s=1.0,
        region_restart_s=45.0, single_restart_s=3.0,
        ckpt_interval_s=None, ckpt_mode="region",
        ckpt_upload_s=4.0, ckpt_retry=True,
        job_of_task: np.ndarray | None = None,
        standby_switch_s=0.05, standby_staleness_s=0.5,
        restore_base_s=0.0, replay_rate=0.0,
        lazy_extra_s=0.0) -> ChaosTimeline:
    """Replay the engine's chaos rng consumption for `n_ticks` ticks.

    Host kills, checkpoint outcomes and failover downtimes are all
    data-independent of queue dynamics (downtime depends only on kills +
    failover config), so the full control timeline is computable here
    without simulating a single record. `task_host`/`task_region`/`regions`
    describe the physical placement (same arrays the engine derives from
    `PhysicalGraph`); failover/checkpoint parameters mirror
    `FailoverConfig`/`CheckpointConfig` field-for-field (passed as plain
    scalars to keep `core` free of a `streams` import).

    Per-job configs ride the same scalar contract as vectors/sequences:

    * `failover_mode` may be a per-task int8 code vector (see
      `failover_mode_codes`) and `detect_s` / `*_restart_s` per-task
      float vectors — how `streams.engine.per_task_failover` lowers a
      per-job `FailoverConfig` list.
    * `ckpt_interval_s` / `ckpt_mode` / `ckpt_upload_s` / `ckpt_retry`
      may be length-``n_jobs`` sequences (requires `job_of_task`; a None
      interval disables job j's coordinator): each job then runs its own
      coordinator drawing upload factors for its OWN tasks only, jobs in
      ascending id order within a tick — the stream contract mirrored by
      `StreamEngine._run_checkpoint_job`. `ckpt_at` counts attempts per
      tick (all jobs), and `ckpt_by_job` carries the per-job counters.

    Hybrid-replication parameters (all scalars or per-task vectors, 0/
    defaults keep historical numbers bit-identical): `standby_switch_s` /
    `standby_staleness_s` price a `hot_standby` (code 3) failover as
    detect + switch + staleness replay, with NO checkpoint-restore
    surcharge; `restore_base_s` (scaled by the brownout factor at kill
    time), `replay_rate` (seconds of replay per second of checkpoint
    age) and `lazy_extra_s` (lazy-load region ready-time offset) form
    the passive-restore surcharge added to region/single downtimes.
    """
    _TIMELINE_STATS["builds"] += 1
    eng = ChaosEngine(spec)
    task_host = np.asarray(task_host)
    n_tasks = len(task_host)
    mode_codes = failover_mode_codes(failover_mode, n_tasks)
    down_s = _per_task(detect_s, n_tasks) + _per_task(single_restart_s,
                                                      n_tasks)
    down_r = _per_task(detect_s, n_tasks) + _per_task(region_restart_s,
                                                      n_tasks)
    down_h = (_per_task(detect_s, n_tasks)
              + _per_task(standby_switch_s, n_tasks)
              + _per_task(standby_staleness_s, n_tasks))
    restore_base = _per_task(restore_base_s, n_tasks)
    replay = _per_task(replay_rate, n_tasks)
    lazy_extra = _per_task(lazy_extra_s, n_tasks)
    has_extra = bool(restore_base.any() or replay.any() or lazy_extra.any())
    if spec.burst_at:
        eng.schedule_kills(burst_kill_schedule(spec.burst_at, task_host,
                                               task_region))
    kills_possible = bool(spec.host_kill_at or spec.host_kill_prob_per_s
                          or spec.burst_at)
    if kills_possible and (mode_codes == 1).any() and task_region is None:
        raise ValueError(
            "failover_mode='region' with kills enabled requires task_region")
    per_job_ckpt = isinstance(ckpt_interval_s, (list, tuple, np.ndarray))
    if per_job_ckpt and job_of_task is None:
        raise ValueError("per-job ckpt_interval_s requires job_of_task")
    any_ckpt = (any(iv is not None for iv in ckpt_interval_s)
                if per_job_ckpt else ckpt_interval_s is not None)
    region_ckpt = (any(m != "global" for m in ckpt_mode)
                   if isinstance(ckpt_mode, (list, tuple, np.ndarray))
                   else ckpt_mode != "global")
    if any_ckpt and region_ckpt and regions is None:
        raise ValueError(
            "region checkpoint mode requires regions (the retry draws "
            "consume the rng stream — omitting them would desynchronize "
            "every later draw from the live engine)")
    # straggler draws happen at first sight of each host, in task order —
    # identical to StreamEngine.__init__'s per-task host_speed() queries
    task_speed = np.array([eng.host_speed(int(h)) for h in task_host])

    ts = np.zeros(n_ticks)
    kills = np.zeros((n_ticks, n_hosts), bool)
    ckpt_at = np.zeros(n_ticks, np.int16)
    ckpt_ok = np.zeros(n_ticks, np.int16)
    down = np.zeros(n_tasks)
    recoveries: list[dict] = []
    attempts = success = failed = 0
    if per_job_ckpt:
        n_jobs = int(np.max(job_of_task)) + 1
        jobs = _JobCkpt.from_seq(n_jobs, ckpt_interval_s, ckpt_mode,
                                 ckpt_upload_s, ckpt_retry, job_of_task,
                                 regions)
        ckpt_by_job = np.zeros((n_jobs, 3), int)
        ckpt_ok_job = np.zeros((n_ticks, n_jobs), np.int16)
        last_ok = np.zeros(n_jobs)
    else:
        next_ckpt = (ckpt_interval_s if ckpt_interval_s is not None
                     else math.inf)
        ckpt_by_job = None
        ckpt_ok_job = None
        last_ok = 0.0
    t = 0.0
    for i in range(n_ticks):
        ts[i] = t
        if kills_possible:
            hosts = eng.step_kills(t, t + dt, n_hosts=n_hosts)
            extra = None
            if hosts and has_extra:
                bf = eng.brownout_factor(t)
                age = (t - last_ok[job_of_task] if per_job_ckpt
                       else t - last_ok)
                extra = restore_base * bf + age * replay + lazy_extra
            for host in hosts:
                if host < n_hosts:
                    # scheduled kills are unbounded by n_hosts; a kill of
                    # a hostless id is a no-op (the engine just revives)
                    kills[i, host] = True
                _resolve_failover_tick(t, host, task_host, task_region,
                                       mode_codes, down_s, down_r, down,
                                       recoveries, job_of_task,
                                       down_h=down_h, extra=extra)
                eng.revive(host)   # replacement host, as in _fail_host
        if per_job_ckpt:
            for jc in jobs:
                if t + dt < jc.next_at:
                    continue
                ok = jc.attempt(eng, down, t)
                ckpt_at[i] += 1
                ckpt_ok[i] += int(ok)
                attempts += 1
                success += int(ok)
                failed += int(not ok)
                ckpt_by_job[jc.job] += (1, int(ok), int(not ok))
                ckpt_ok_job[i, jc.job] += int(ok)
                if ok:
                    last_ok[jc.job] = t
        elif t + dt >= next_ckpt:
            ckpt_at[i] = 1
            attempts += 1
            ok = run_checkpoint_attempt(
                eng, down <= t, interval_s=ckpt_interval_s,
                mode=ckpt_mode, upload_s=ckpt_upload_s, retry=ckpt_retry,
                regions=regions or (), t=t)
            ckpt_ok[i] = int(ok)
            success += int(ok)
            failed += int(not ok)
            next_ckpt += ckpt_interval_s
            if ok:
                last_ok = t
        t = t + dt
    return ChaosTimeline(dt, n_ticks, ts, task_speed, kills, ckpt_at,
                         ckpt_ok, attempts, success, failed, recoveries,
                         ckpt_by_job=ckpt_by_job,
                         ckpt_ok_by_job=ckpt_ok_job)


class _JobCkpt:
    """Per-job checkpoint coordinator state for the timeline replay —
    draws upload factors for the job's own task slice only, mirroring
    `StreamEngine._run_checkpoint_job` draw-for-draw."""

    def __init__(self, job, interval, mode, upload, retry, lo, hi, regions):
        self.job, self.interval, self.mode = job, interval, mode
        self.upload, self.retry = upload, retry
        self.lo, self.hi, self.regions = lo, hi, regions
        self.next_at = interval if interval is not None else math.inf

    @classmethod
    def from_seq(cls, n_jobs, intervals, modes, uploads, retries,
                 job_of_task, regions):
        def seq(v, default):
            if isinstance(v, (list, tuple, np.ndarray)):
                if len(v) != n_jobs:
                    raise ValueError(
                        f"per-job ckpt params need one entry per job "
                        f"({len(v)} != {n_jobs})")
                return list(v)
            return [v if v is not None else default] * n_jobs

        intervals = seq(intervals, None)
        modes = seq(modes, "region")
        uploads = seq(uploads, 4.0)
        retries = seq(retries, True)
        out = []
        for j in range(n_jobs):
            mask = np.asarray(job_of_task) == j
            lo = int(np.nonzero(mask)[0][0])
            hi = int(np.nonzero(mask)[0][-1]) + 1
            if int(mask.sum()) != hi - lo:
                raise ValueError("per-job ckpt needs contiguous job "
                                 "task slices")
            regs = [r for r in (regions or ())
                    if lo <= min(r) < hi]
            out.append(cls(j, intervals[j], modes[j], uploads[j],
                           retries[j], lo, hi, regs))
        return out

    def attempt(self, eng: ChaosEngine, down: np.ndarray, t: float) -> bool:
        self.next_at += self.interval
        return run_checkpoint_attempt(
            eng, down[self.lo:self.hi] <= t, interval_s=self.interval,
            mode=self.mode, upload_s=self.upload, retry=self.retry,
            regions=self.regions, task_lo=self.lo, t=t)


def refit_failover(tl: ChaosTimeline, *, task_host: np.ndarray,
                   task_region: np.ndarray | None = None,
                   failover_mode="region", detect_s=1.0,
                   region_restart_s=45.0, single_restart_s=3.0,
                   job_of_task: np.ndarray | None = None,
                   standby_switch_s=0.05, standby_staleness_s=0.5,
                   restore_base_s=0.0, replay_rate=0.0, lazy_extra_s=0.0,
                   spec: ChaosSpec | None = None) -> ChaosTimeline:
    """Re-resolve a pregenerated timeline's failover metadata (recovery
    events) under different failover parameters WITHOUT consuming any rng
    — the cheap path that lets config sweeps share one set of chaos draws
    across a whole restart-budget grid.

    Only valid for timelines with no checkpoint activity: checkpoint
    storage draws interleave with kill draws and their count depends on
    task liveness (hence on the failover config), so a ckpt-bearing
    timeline is config-specific and must be rebuilt per config. With no
    checkpoints the checkpoint age at a kill is the kill time itself
    (full replay since run start); pass `spec` so the brownout ramps can
    scale `restore_base_s` at each kill time."""
    if tl.ckpt_attempts:
        raise ValueError(
            "refit_failover needs a checkpoint-free timeline (storage "
            "draws are failover-config-dependent — rebuild per config)")
    task_host = np.asarray(task_host)
    n_tasks = len(task_host)
    mode_codes = failover_mode_codes(failover_mode, n_tasks)
    down_s = _per_task(detect_s, n_tasks) + _per_task(single_restart_s,
                                                      n_tasks)
    down_r = _per_task(detect_s, n_tasks) + _per_task(region_restart_s,
                                                      n_tasks)
    down_h = (_per_task(detect_s, n_tasks)
              + _per_task(standby_switch_s, n_tasks)
              + _per_task(standby_staleness_s, n_tasks))
    restore_base = _per_task(restore_base_s, n_tasks)
    replay = _per_task(replay_rate, n_tasks)
    lazy_extra = _per_task(lazy_extra_s, n_tasks)
    has_extra = bool(restore_base.any() or replay.any() or lazy_extra.any())
    ramps = spec.brownout_at if spec is not None else ()
    if (mode_codes == 1).any() and tl.kills.any() and task_region is None:
        raise ValueError("region failover refit requires task_region")
    down = np.zeros(n_tasks)
    recoveries: list[dict] = []
    for i in np.nonzero(tl.kills.any(axis=1))[0]:
        t = float(tl.ts[i])
        extra = None
        if has_extra:
            bf = brownout_factor_at(ramps, t)
            extra = restore_base * bf + t * replay + lazy_extra
        for host in np.nonzero(tl.kills[i])[0]:
            _resolve_failover_tick(t, int(host), task_host, task_region,
                                   mode_codes, down_s, down_r, down,
                                   recoveries, job_of_task,
                                   down_h=down_h, extra=extra)
    return dataclasses.replace(tl, recoveries=recoveries)


# ----------------------------------------------------------------------
# Batched (config × seed) timeline refit — checkpoint-bearing grids
# ----------------------------------------------------------------------
class _SeedStream:
    """All uniform draws of one `ChaosSpec` seed, materialized lazily as
    one indexable prefix array.

    numpy Generators produce the same double stream for ``random(n)`` as
    for ``n`` scalar ``random()`` calls, so ANY interleaving of the
    engine's straggler / kill / checkpoint-storage draws is replayable
    by plain offset indexing into this buffer — drawn ONCE per seed and
    shared read-only by every config of a grid. The straggler draws
    (first-seen hosts in task order, exactly `ChaosEngine.host_speed`)
    are resolved eagerly; `base` is the stream offset after them."""

    def __init__(self, spec: ChaosSpec, task_host: np.ndarray):
        self.spec = spec
        self._rng = np.random.default_rng(spec.seed)
        self._buf = np.zeros(0)
        n_tasks = len(task_host)
        if spec.straggler_frac:
            # first-seen host order == per-task host_speed query order
            _, first = np.unique(task_host, return_index=True)
            seen = task_host[np.sort(first)]
            draws = self.at(0, len(seen))
            slow = draws < spec.straggler_frac
            speed = {int(h): (1.0 / spec.straggler_factor if s else 1.0)
                     for h, s in zip(seen, slow)}
            self.task_speed = np.array([speed[int(h)] for h in task_host])
            self.base = len(seen)
        else:
            self.task_speed = np.ones(n_tasks)
            self.base = 0

    def at(self, lo: int, hi: int) -> np.ndarray:
        """Stream doubles [lo, hi) (grows the buffer on demand — the
        generator keeps producing the same stream across growths)."""
        if hi > len(self._buf):
            grow = max(hi - len(self._buf), 4096, len(self._buf) // 2)
            self._buf = np.concatenate([self._buf,
                                        self._rng.random(grow)])
        return self._buf[lo:hi]


def _attempt_schedule(ts: np.ndarray, dt: float, interval) -> tuple:
    """(attempt tick indices, per-tick attempt counts) of a single
    checkpoint coordinator — the exact ``t + dt >= next_ckpt`` walk of
    `build_chaos_timeline` (one attempt per tick max)."""
    n_ticks = len(ts)
    ckpt_at = np.zeros(n_ticks, np.int16)
    att = []
    if interval is not None:
        nxt = interval
        for i in range(n_ticks):
            if ts[i] + dt >= nxt:
                att.append(i)
                ckpt_at[i] = 1
                nxt += interval
    return att, ckpt_at


def _grid_kill_segment(st: _SeedStream, off: int, lo: int, hi: int,
                       n_hosts: int, ts: np.ndarray, dt: float,
                       sched: dict) -> tuple:
    """Replay the kill draws of ticks [lo, hi] for one seed from stream
    offset `off` (storage draws never interleave inside a segment).
    Returns (new offset, {tick: sorted kill host list})."""
    spec = st.spec
    nt = hi - lo + 1
    events: dict[int, list] = {}
    if spec.host_kill_prob_per_s:
        blk = st.at(off, off + nt * n_hosts).reshape(nt, n_hosts)
        off += nt * n_hosts
        # per-tick kill probability, float-faithful to step_kills
        p = 1.0 - np.exp(-spec.host_kill_prob_per_s
                         * ((ts[lo:hi + 1] + dt) - ts[lo:hi + 1]))
        hit_t, hit_h = np.nonzero(blk < p[:, None])
        for i, h in zip(hit_t, hit_h):
            events.setdefault(lo + int(i), []).append(int(h))
    for i in range(lo, hi + 1):
        if i in sched:
            events.setdefault(i, []).extend(sched[i])
    return off, {i: sorted(set(hs)) for i, hs in sorted(events.items())}


class GridTimelineBuilder:
    """Chunk-capable (config × seed) timeline refit — the host-prep half
    of seed-chunked grid sweeps.

    Construction materializes only the *seed-static* state: per-seed
    `_SeedStream` draw buffers (created lazily, on first touch of each
    seed), scheduled-kill buckets and storage-draw parameters. Any seed
    slice of the grid is then built on demand via `chunk(lo, hi)` —
    per-seed stream offsets restart from each stream's own base, so a
    chunk's timelines are bit-identical to the same rows of a one-shot
    `build_grid_timelines` call (every per-seed quantity — draw offsets,
    downtime horizons, last-success times — is seed-independent). This
    is what lets `jax_engine` overlap chunk ``k+1``'s host prep with
    chunk ``k``'s device pass without any per-chunk host replays:
    `timeline_build_count()` stays flat no matter how the seed axis is
    chunked."""

    def __init__(self, specs, configs, *, n_ticks: int, dt: float,
                 n_hosts: int, task_host: np.ndarray,
                 task_region: np.ndarray | None = None,
                 regions: list | None = None,
                 job_of_task: np.ndarray | None = None):
        self.specs = list(specs)
        self.configs = list(configs)
        self.task_host = np.asarray(task_host)
        self.task_region = task_region
        self.job_of_task = job_of_task
        self.n_ticks = n_ticks
        self.dt = dt
        self.n_hosts = n_hosts
        self.n_tasks = len(self.task_host)
        self._streams: list[_SeedStream | None] = [None] * len(self.specs)
        self._counted = False

        # tick-start times via the same float accumulation as the replay
        ts = np.zeros(n_ticks)
        t = 0.0
        for i in range(n_ticks):
            ts[i] = t
            t = t + dt
        self.ts = ts

        # per-seed scheduled kills, bucketed by tick (window t0 < t <=
        # t1) — region-correlated bursts expand to host kills and merge
        # right here, exactly like ChaosEngine.schedule_kills feeds
        # step_kills
        self.scheds = []
        for sp in self.specs:
            sched: dict[int, list] = {}
            for (tk, h) in (tuple(sp.host_kill_at)
                            + burst_kill_schedule(sp.burst_at,
                                                  self.task_host,
                                                  task_region)):
                w = np.nonzero((ts < tk) & (tk <= ts + dt))[0]
                if len(w):
                    sched.setdefault(int(w[0]), []).append(int(h))
            self.scheds.append(sched)

        # region row-tables for the vectorized bad-region test
        regions = list(regions or ())
        self.reg_arrs = [np.fromiter(sorted(r), int, len(r))
                         for r in regions]

        # seed-static storage-draw parameters (shared by every config)
        self.probs = np.array([sp.storage_slow_prob for sp in self.specs])
        self.facs = np.array([sp.storage_slow_factor
                              for sp in self.specs])

    def _stream(self, s: int) -> _SeedStream:
        if self._streams[s] is None:
            self._streams[s] = _SeedStream(self.specs[s], self.task_host)
        return self._streams[s]

    def chunk(self, seed_lo: int, seed_hi: int) -> list:
        """``[C][seed_hi - seed_lo]`` timelines for the seed slice —
        bit-identical to the same columns of the full grid."""
        if not self._counted:
            # one grid replay per config regardless of chunking — the
            # accounting a one-shot build_grid_timelines call records
            _TIMELINE_STATS["grid_replays"] += len(self.configs)
            self._counted = True
        return [self._chunk_row(cfg, seed_lo, seed_hi)
                for cfg in self.configs]

    def _chunk_row(self, cfg: dict, seed_lo: int, seed_hi: int) -> list:
        n_tasks, n_ticks = self.n_tasks, self.n_ticks
        ts, dt, n_hosts = self.ts, self.dt, self.n_hosts
        task_host, task_region = self.task_host, self.task_region
        job_of_task, reg_arrs = self.job_of_task, self.reg_arrs
        streams = [self._stream(s) for s in range(seed_lo, seed_hi)]
        scheds = self.scheds[seed_lo:seed_hi]
        probs = self.probs[seed_lo:seed_hi]
        facs = self.facs[seed_lo:seed_hi]
        mode_codes = failover_mode_codes(cfg.get("failover_mode",
                                                 "region"), n_tasks)
        down_s = (_per_task(cfg.get("detect_s", 1.0), n_tasks)
                  + _per_task(cfg.get("single_restart_s", 3.0), n_tasks))
        down_r = (_per_task(cfg.get("detect_s", 1.0), n_tasks)
                  + _per_task(cfg.get("region_restart_s", 45.0), n_tasks))
        down_h = (_per_task(cfg.get("detect_s", 1.0), n_tasks)
                  + _per_task(cfg.get("standby_switch_s", 0.05), n_tasks)
                  + _per_task(cfg.get("standby_staleness_s", 0.5),
                              n_tasks))
        restore_base = _per_task(cfg.get("restore_base_s", 0.0), n_tasks)
        replay = _per_task(cfg.get("replay_rate", 0.0), n_tasks)
        lazy_extra = _per_task(cfg.get("lazy_extra_s", 0.0), n_tasks)
        has_extra = bool(restore_base.any() or replay.any()
                         or lazy_extra.any())
        cfg_ramps = tuple(cfg.get("brownout_at", ()))
        interval = cfg.get("ckpt_interval_s")
        ck_mode = cfg.get("ckpt_mode", "region")
        upload = cfg.get("ckpt_upload_s", 4.0)
        retry = cfg.get("ckpt_retry", True)
        att, ckpt_at = _attempt_schedule(ts, dt, interval)

        S = len(streams)
        off = np.array([st.base for st in streams])
        down = np.zeros((S, n_tasks))
        last_ok = np.zeros(S)
        kills = np.zeros((S, n_ticks, n_hosts), bool)
        recs: list[list] = [[] for _ in range(S)]
        ok_by_seed = np.zeros((S, n_ticks), np.int16)

        bounds = att + ([n_ticks - 1] if (not att or att[-1]
                                          != n_ticks - 1) else [])
        prev = 0
        for bi, b in enumerate(bounds):
            # kill draws for ticks [prev, b] — contiguous per seed
            for s, st in enumerate(streams):
                if not (st.spec.host_kill_prob_per_s or scheds[s]):
                    continue
                off[s], events = _grid_kill_segment(
                    st, int(off[s]), prev, b, n_hosts, ts, dt, scheds[s])
                for i, hosts in events.items():
                    tk = float(ts[i])
                    extra = None
                    if has_extra:
                        # last_ok[s] is constant within a kill segment
                        # (attempts only happen at segment bounds)
                        bf = brownout_factor_at(
                            tuple(st.spec.brownout_at) + cfg_ramps, tk)
                        extra = (restore_base * bf
                                 + (tk - last_ok[s]) * replay + lazy_extra)
                    for host in hosts:
                        if host < n_hosts:
                            kills[s, i, host] = True
                        _resolve_failover_tick(
                            tk, host, task_host, task_region,
                            mode_codes, down_s, down_r, down[s], recs[s],
                            job_of_task, down_h=down_h, extra=extra)
            prev = b + 1
            if bi >= len(att):
                continue
            # checkpoint attempt at tick b (time ts[b]), all seeds
            i_att = b
            t_att = float(ts[i_att])
            alive = down <= t_att
            # brownout multiplier at attempt time: seed ramps × config
            # ramps, composed exactly like run_checkpoint_attempt's bf
            bf_att = np.array([brownout_factor_at(
                tuple(st.spec.brownout_at) + cfg_ramps, t_att)
                for st in streams])
            factors = np.ones((S, n_tasks))
            for s, st in enumerate(streams):
                if probs[s]:
                    u = st.at(int(off[s]), int(off[s]) + n_tasks)
                    off[s] += n_tasks
                    factors[s] = np.where(u < probs[s], facs[s], 1.0)
            task_fail = (upload * factors * bf_att[:, None]
                         > interval) | ~alive
            if ck_mode == "global":
                ok = ~task_fail.any(axis=1)
            else:
                ok = np.ones(S, bool)
                active = np.ones(S, bool)
                for r, rtasks in enumerate(reg_arrs):
                    if not active.any():
                        break
                    bad = task_fail[:, rtasks].any(axis=1) & active
                    if not bad.any():
                        continue
                    if retry:
                        for s in np.nonzero(bad)[0]:
                            st = streams[s]
                            if not probs[s]:
                                bad[s] = upload * bf_att[s] > interval
                            elif upload * bf_att[s] > interval:
                                off[s] += 1          # first draw decides
                            elif upload * facs[s] * bf_att[s] <= interval:
                                off[s] += len(rtasks)   # all draws pass
                                bad[s] = False
                            else:
                                u = st.at(int(off[s]),
                                          int(off[s]) + len(rtasks))
                                slow = u < probs[s]
                                if slow.any():
                                    off[s] += int(slow.argmax()) + 1
                                else:
                                    off[s] += len(rtasks)
                                    bad[s] = False
                    ok[bad] = False
                    active &= ~bad
            ok_by_seed[:, i_att] = ok
            last_ok[ok] = t_att

        n_att = len(att)
        row = []
        for s in range(S):
            succ = int(ok_by_seed[s].sum())
            row.append(ChaosTimeline(
                dt, n_ticks, ts, streams[s].task_speed, kills[s],
                ckpt_at.copy(), ok_by_seed[s], n_att, succ,
                n_att - succ, recs[s], ckpt_by_job=None))
        return row


def build_grid_timelines(specs, configs, *, n_ticks: int, dt: float,
                         n_hosts: int, task_host: np.ndarray,
                         task_region: np.ndarray | None = None,
                         regions: list | None = None,
                         job_of_task: np.ndarray | None = None) -> list:
    """Timelines for a (config × seed) grid WITHOUT per-(config, seed)
    host replays: the chaos draw streams are materialized once per seed
    (`_SeedStream`), then each config's checkpoint attempt schedule is
    refitted onto them with vectorized offset indexing — kill blocks
    between attempts land as one reshape+compare, storage draws as one
    batched gather per attempt, and only the rare kill events and bad
    checkpoint regions walk host loops.

    `specs` is one `ChaosSpec` per seed. `configs` is one dict per grid
    row with keys ``failover_mode`` (name or per-task code vector),
    ``detect_s`` / ``region_restart_s`` / ``single_restart_s`` /
    ``standby_switch_s`` / ``standby_staleness_s`` / ``restore_base_s``
    / ``replay_rate`` / ``lazy_extra_s`` (scalars or per-task vectors),
    ``ckpt_interval_s`` / ``ckpt_mode`` / ``ckpt_upload_s`` /
    ``ckpt_retry`` (single-coordinator checkpoint parameters; a None
    interval disables checkpointing for that row — per-job coordinator
    sequences are NOT supported here, callers fall back to per-config
    `build_chaos_timeline`), and ``brownout_at`` (config-level brownout
    ramps APPENDED to each seed spec's own ramps — deterministic, so
    brownout severity rides the config axis without any extra draws).

    Returns ``[C][S]`` `ChaosTimeline`s bit-identical to
    ``build_chaos_timeline(replace(specs[s], brownout_at=specs[s]
    .brownout_at + configs[c]["brownout_at"]), **rest_of_row)`` — pinned
    by tests/test_sparse_sweep.py — while `timeline_build_count()` stays
    flat. Seed-chunked callers use `GridTimelineBuilder` directly; this
    is its full-range spelling."""
    return GridTimelineBuilder(
        specs, configs, n_ticks=n_ticks, dt=dt, n_hosts=n_hosts,
        task_host=task_host, task_region=task_region, regions=regions,
        job_of_task=job_of_task).chunk(0, len(list(specs)))


# ----------------------------------------------------------------------
# Per-job chaos specs (one ChaosSpec per co-located job)
# ----------------------------------------------------------------------
def build_perjob_chaos_timeline(
        specs, *, n_ticks: int, dt: float, n_hosts: int,
        task_host: np.ndarray, job_hosts, task_local_host: np.ndarray,
        job_of_task: np.ndarray,
        task_region: np.ndarray | None = None, regions: list | None = None,
        failover_mode="region", detect_s=1.0,
        region_restart_s=45.0, single_restart_s=3.0,
        ckpt_interval_s=None, ckpt_mode="region",
        ckpt_upload_s=4.0, ckpt_retry=True,
        standby_switch_s=0.05, standby_staleness_s=0.5,
        restore_base_s=0.0, replay_rate=0.0,
        lazy_extra_s=0.0) -> ChaosTimeline:
    """Per-job chaos replay: job ``j`` runs its own `ChaosEngine` seeded
    from ``specs[j]``, drawing stragglers and host kills in its *local*
    host domain (``len(job_hosts[j])`` hosts, the same domain an
    independent run of that job would draw in) and lifting kill targets
    into the shared pool through ``job_hosts[j]`` — so different kill
    rates / straggler intensities / drill schedules per co-located job
    share one arena while a lifted kill still downs EVERY job placed on
    that pool host.

    Draw-order contract (mirrored by `streams.engine.StreamEngine` with
    a per-job ``chaos=`` list): per-job straggler draws happen at first
    sight of each local host in task order (tasks of job j are
    contiguous, so engine j's draws batch together); per tick, jobs draw
    kills in ascending job order, then per-job checkpoint coordinators
    attempt in ascending job order, each drawing ONLY from its own
    engine. A pool host killed by several jobs' processes in one tick
    resolves once (first-killing job wins the recovery entry).

    Checkpoint parameters may be scalars (every job gets the same
    config, on its own coordinator and stream) or length-``n_jobs``
    sequences, as in `build_chaos_timeline`'s per-job coordinators —
    with per-job chaos there is no shared-coordinator mode, because
    there is no single engine to draw a whole-arena attempt from.
    """
    _TIMELINE_STATS["builds"] += 1
    specs = list(specs)
    n_jobs = len(specs)
    task_host = np.asarray(task_host)
    job_of_task = np.asarray(job_of_task)
    task_local_host = np.asarray(task_local_host)
    n_tasks = len(task_host)
    engines = [ChaosEngine(sp) for sp in specs]
    mode_codes = failover_mode_codes(failover_mode, n_tasks)
    down_s = _per_task(detect_s, n_tasks) + _per_task(single_restart_s,
                                                      n_tasks)
    down_r = _per_task(detect_s, n_tasks) + _per_task(region_restart_s,
                                                      n_tasks)
    down_h = (_per_task(detect_s, n_tasks)
              + _per_task(standby_switch_s, n_tasks)
              + _per_task(standby_staleness_s, n_tasks))
    restore_base = _per_task(restore_base_s, n_tasks)
    replay = _per_task(replay_rate, n_tasks)
    lazy_extra = _per_task(lazy_extra_s, n_tasks)
    has_extra = bool(restore_base.any() or replay.any() or lazy_extra.any())
    for j, (sp, eng) in enumerate(zip(specs, engines)):
        if sp.burst_at:
            # per-job bursts expand in the job's LOCAL host domain (the
            # same domain its kills draw in) and lift through job_hosts
            m = job_of_task == j
            eng.schedule_kills(burst_kill_schedule(
                sp.burst_at, task_local_host[m],
                None if task_region is None else task_region[m]))
    kills_possible = [bool(sp.host_kill_at or sp.host_kill_prob_per_s
                           or sp.burst_at)
                      for sp in specs]
    if any(kills_possible) and (mode_codes == 1).any() \
            and task_region is None:
        raise ValueError(
            "failover_mode='region' with kills enabled requires task_region")
    # straggler draws: first sight of each local host, in task order —
    # job slices are contiguous, so each engine consumes exactly the
    # stream an independent run of its job would
    task_speed = np.array([
        engines[int(job_of_task[tid])].host_speed(
            int(task_local_host[tid])) for tid in range(n_tasks)])

    any_ckpt = (any(iv is not None for iv in ckpt_interval_s)
                if isinstance(ckpt_interval_s, (list, tuple, np.ndarray))
                else ckpt_interval_s is not None)
    if any_ckpt:
        jobs_ck = _JobCkpt.from_seq(n_jobs, ckpt_interval_s, ckpt_mode,
                                    ckpt_upload_s, ckpt_retry,
                                    job_of_task, regions)
        ckpt_by_job = np.zeros((n_jobs, 3), int)
        ckpt_ok_job = np.zeros((n_ticks, n_jobs), np.int16)
    else:
        jobs_ck = []
        ckpt_by_job = None
        ckpt_ok_job = None
    last_ok = np.zeros(n_jobs)

    ts = np.zeros(n_ticks)
    kills = np.zeros((n_ticks, n_hosts), bool)
    ckpt_at = np.zeros(n_ticks, np.int16)
    ckpt_ok = np.zeros(n_ticks, np.int16)
    down = np.zeros(n_tasks)
    recoveries: list[dict] = []
    attempts = success = failed = 0
    t = 0.0
    for i in range(n_ticks):
        ts[i] = t
        failed_pool: set[int] = set()
        extra_memo: list = [None]

        def kill_extra(t=t):
            # per-task passive-restore surcharge at this tick, using each
            # task's OWN job's brownout ramps and checkpoint age
            if not has_extra:
                return None
            if extra_memo[0] is None:
                bfj = np.array([brownout_factor_at(sp.brownout_at, t)
                                for sp in specs])
                extra_memo[0] = (restore_base * bfj[job_of_task]
                                 + (t - last_ok)[job_of_task] * replay
                                 + lazy_extra)
            return extra_memo[0]

        for j, eng in enumerate(engines):
            if not kills_possible[j]:
                continue
            local_map = np.asarray(job_hosts[j])
            for lh in eng.step_kills(t, t + dt, n_hosts=len(local_map)):
                if lh < len(local_map):
                    pool = int(local_map[lh])
                    if pool not in failed_pool:
                        failed_pool.add(pool)
                        if pool < n_hosts:
                            kills[i, pool] = True
                        _resolve_failover_tick(
                            t, pool, task_host, task_region, mode_codes,
                            down_s, down_r, down, recoveries, job_of_task,
                            down_h=down_h, extra=kill_extra())
                eng.revive(lh)
        for jc in jobs_ck:
            if t + dt < jc.next_at:
                continue
            ok = jc.attempt(engines[jc.job], down, t)
            ckpt_at[i] += 1
            ckpt_ok[i] += int(ok)
            attempts += 1
            success += int(ok)
            failed += int(not ok)
            ckpt_by_job[jc.job] += (1, int(ok), int(not ok))
            ckpt_ok_job[i, jc.job] += int(ok)
            if ok:
                last_ok[jc.job] = t
        t = t + dt
    return ChaosTimeline(dt, n_ticks, ts, task_speed, kills, ckpt_at,
                         ckpt_ok, attempts, success, failed, recoveries,
                         ckpt_by_job=ckpt_by_job,
                         ckpt_ok_by_job=ckpt_ok_job)
