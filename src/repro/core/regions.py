"""Checkpoint regions — the failure-recovery unit (paper §III-B).

In Flink a region is a set of tasks bounded by blocking exchanges; here a
region is a slice of the training state that snapshots/restores
independently: stacked per-layer parameters split along their layer axis,
non-stacked leaves (embeddings, heads, shared blocks) assigned whole to
regions balanced by byte size. The SAME partitioner drives the trainer's
RegionCheckpointer and the chaos/bench reproductions of Fig 8.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from repro.dist.sharding import ParamSpec

SpecLeaf = lambda x: isinstance(x, ParamSpec)  # noqa: E731


@dataclasses.dataclass(frozen=True)
class LeafSlice:
    path: str               # "/"-joined tree path
    layer_lo: int | None    # None → whole leaf
    layer_hi: int | None
    nbytes: int


@dataclasses.dataclass(frozen=True)
class Region:
    region_id: int
    slices: tuple[LeafSlice, ...]

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.slices)


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    out = []

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, path + (str(i),))
        else:
            out.append(("/".join(path), node))

    rec(tree, ())
    return out


def get_path(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[int(part)] if isinstance(node, (list, tuple)) else node[part]
    return node


def set_path(tree, path: str, value) -> None:
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[int(part)] if isinstance(node, (list, tuple)) else node[part]
    last = parts[-1]
    if isinstance(node, (list, tuple)):
        node[int(last)] = value
    else:
        node[last] = value


def partition_regions(spec_tree, n_regions: int) -> list[Region]:
    """Split a ParamSpec tree into n_regions regions. Leaves whose first
    logical axis is "layers" are sliced along dim 0; other leaves are
    greedily packed into the least-loaded region."""
    flat = _flatten_with_paths(spec_tree)
    slices: list[list[LeafSlice]] = [[] for _ in range(n_regions)]
    loads = [0] * n_regions

    def leaf_bytes(spec: ParamSpec) -> int:
        size = np.dtype(spec.dtype).itemsize if spec.dtype is not None else 2
        return math.prod(spec.shape) * size

    for path, spec in flat:
        assert isinstance(spec, ParamSpec), (path, spec)
        if spec.axes and spec.axes[0] == "layers" and spec.shape[0] >= n_regions:
            L = spec.shape[0]
            per = leaf_bytes(spec) // L
            bounds = [round(r * L / n_regions) for r in range(n_regions + 1)]
            for r in range(n_regions):
                lo, hi = bounds[r], bounds[r + 1]
                if hi > lo:
                    slices[r].append(LeafSlice(path, lo, hi, per * (hi - lo)))
                    loads[r] += per * (hi - lo)
        else:
            r = loads.index(min(loads))
            b = leaf_bytes(spec)
            slices[r].append(LeafSlice(path, None, None, b))
            loads[r] += b

    return [Region(r, tuple(slices[r])) for r in range(n_regions)]


def extract_region(tree, region: Region) -> dict[str, np.ndarray]:
    """Pull a region's data out of a materialized tree as numpy arrays."""
    out = {}
    for s in region.slices:
        leaf = np.asarray(get_path(tree, s.path))
        if s.layer_lo is not None:
            out[f"{s.path}@{s.layer_lo}:{s.layer_hi}"] = leaf[s.layer_lo:s.layer_hi]
        else:
            out[s.path] = leaf
    return out


def insert_region(tree, region: Region, data: dict[str, np.ndarray],
                  as_jax: bool = False):
    """Write a region's arrays back into a (mutable, dict-based) tree."""
    import jax.numpy as jnp
    for s in region.slices:
        if s.layer_lo is not None:
            key = f"{s.path}@{s.layer_lo}:{s.layer_hi}"
            leaf = np.asarray(get_path(tree, s.path)).copy()
            leaf[s.layer_lo:s.layer_hi] = data[key]
        else:
            leaf = data[s.path]
        set_path(tree, s.path, jnp.asarray(leaf) if as_jax else leaf)
    return tree
