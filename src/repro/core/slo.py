"""SLO → resiliency policy (the paper's Table I as a decision table).

S = (γ, λ_max, τ_max). The policy selects the replication mode, the recovery
strategy, the MoE routing strictness (WeakHash is only legal when minor loss
is tolerable OR the lookup is idempotent), and the checkpoint cadence.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import Completeness, SLOConfig


class InfeasibleSLO(ValueError):
    """Hour-level recovery with loss tolerance: 'Not applicable; prone to
    system malfunctions' (paper Table I)."""


@dataclasses.dataclass(frozen=True)
class ResiliencyPolicy:
    replication: str            # "active" | "passive"
    recovery: str               # "single_task" | "region" | "global"
    moe_mode: str               # "weakhash" | "strict"
    rescue_overflow: bool       # γ=full keeps every token
    ckpt_interval_s: float
    ckpt_mode: str              # "region" | "global"
    description: str = ""


def policy_for(slo: SLOConfig) -> ResiliencyPolicy:
    tier = slo.recovery_tier
    partial = slo.gamma == Completeness.PARTIAL

    if tier == "sub_second":
        if partial:
            # latency-critical services (targeted ads / realtime reco)
            return ResiliencyPolicy(
                replication="active", recovery="single_task",
                moe_mode="weakhash", rescue_overflow=False,
                ckpt_interval_s=30.0, ckpt_mode="region",
                description="active replicas + single-task recovery; "
                            "WeakHash may drop overflow")
        # the 'ideally preferred' cell: active replicas, no loss
        return ResiliencyPolicy(
            replication="active", recovery="region",
            moe_mode="weakhash", rescue_overflow=True,
            ckpt_interval_s=30.0, ckpt_mode="region",
            description="active replicas, lossless failover")
    if tier == "sub_minute":
        if partial:
            # log-driven analytical pipelines
            return ResiliencyPolicy(
                replication="passive", recovery="single_task",
                moe_mode="weakhash", rescue_overflow=False,
                ckpt_interval_s=60.0, ckpt_mode="region",
                description="passive + single-task recovery (minor loss ok)")
        # revenue-critical / data synchronization
        return ResiliencyPolicy(
            replication="passive", recovery="region",
            moe_mode="weakhash", rescue_overflow=True,
            ckpt_interval_s=60.0, ckpt_mode="region",
            description="passive + region failover, strict completeness")
    # hour-level
    if partial:
        raise InfeasibleSLO(
            "hour-level recovery with loss tolerance is not a viable "
            "operating point (paper Table I)")
    return ResiliencyPolicy(
        replication="passive", recovery="global",
        moe_mode="strict", rescue_overflow=True,
        ckpt_interval_s=600.0, ckpt_mode="global",
        description="offline warehousing: low-cadence global checkpoints")
