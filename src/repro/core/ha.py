"""High availability under external dependencies (paper §IV-B).

ZooKeeper-sim: leader metadata + session semantics with chaos-driven outage
windows. StreamShield's mechanism: a redundant copy of the leader metadata in
HDFS; on ZK failure the coordinator falls back to the HDFS copy and keeps
running jobs alive. Only when BOTH are unavailable — or the HDFS copy
disagrees with in-memory state — are jobs terminated to preserve correctness.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.core.backoff import TransientError
from repro.core.chaos import ChaosEngine
from repro.core.clock import WallClock


class ZKUnavailable(TransientError):
    pass


@dataclasses.dataclass
class LeaderRecord:
    leader_id: str
    epoch: int

    def to_bytes(self) -> bytes:
        return json.dumps(dataclasses.asdict(self), sort_keys=True).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "LeaderRecord":
        return LeaderRecord(**json.loads(b))


class ZooKeeperSim:
    """Tiny KV + leader-election service with chaos availability windows."""

    def __init__(self, *, clock=None, chaos: ChaosEngine | None = None):
        self.clock = clock or WallClock()
        self.chaos = chaos or ChaosEngine()
        self._kv: dict[str, bytes] = {}
        self._epoch = 0

    def _check(self):
        if not self.chaos.zk_available(self.clock.now()):
            raise ZKUnavailable("zk quorum lost")

    def set(self, key: str, value: bytes) -> None:
        self._check()
        self._kv[key] = value

    def get(self, key: str) -> bytes:
        self._check()
        if key not in self._kv:
            raise KeyError(key)
        return self._kv[key]

    def elect(self, candidate: str) -> LeaderRecord:
        self._check()
        self._epoch += 1
        rec = LeaderRecord(candidate, self._epoch)
        self._kv["leader"] = rec.to_bytes()
        return rec


class JobTerminated(RuntimeError):
    pass


class NoLeader(RuntimeError):
    """ZK quorum is healthy but no leader has been elected — a normal
    pre-election state, NOT an outage (no HDFS fallback)."""


class LeaderService:
    """Leader metadata with the HDFS redundant copy + fallback semantics."""

    def __init__(self, zk: ZooKeeperSim, hdfs_store, *, clock=None):
        self.zk = zk
        self.hdfs = hdfs_store
        self.clock = clock or zk.clock
        self.in_memory: LeaderRecord | None = None
        self.fallback_reads = 0
        self.terminations = 0

    def elect(self, candidate: str) -> LeaderRecord:
        rec = self.zk.elect(candidate)
        self.in_memory = rec
        # redundant copy (paper: "maintains a redundant copy of the leader
        # metadata in HDFS in addition to ZooKeeper")
        self.hdfs.put("ha/leader", rec.to_bytes())
        return rec

    def get_leader(self) -> LeaderRecord:
        try:
            return LeaderRecord.from_bytes(self.zk.get("leader"))
        except ZKUnavailable:
            pass  # quorum lost → fall back to the HDFS copy below
        except KeyError:
            # healthy quorum, no leader znode: pre-election, not an outage
            raise NoLeader("no leader elected") from None
        # ZK down → fall back to the HDFS copy
        try:
            rec = LeaderRecord.from_bytes(self.hdfs.get("ha/leader"))
            self.fallback_reads += 1
        except (KeyError, TransientError):
            self.terminations += 1
            raise JobTerminated("both ZooKeeper and HDFS leader metadata "
                                "unavailable") from None
        if self.in_memory is not None and (
                rec.leader_id != self.in_memory.leader_id
                or rec.epoch != self.in_memory.epoch):
            self.terminations += 1
            raise JobTerminated("HDFS leader metadata inconsistent with "
                                "in-memory state")
        return rec
