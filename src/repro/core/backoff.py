"""Exponential backoff + idempotent retries (paper §IV-B, Gödel fault
tolerance): spaced retries avoid hammering a degraded dependency; idempotency
tokens guarantee repeated requests cause no duplicate effects (job-uniqueness
validation on resubmission)."""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Any, Callable

import numpy as np


class TransientError(Exception):
    """Retryable failure (dependency briefly unavailable / throttled)."""


class PermanentError(Exception):
    """Non-retryable failure."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    base_delay_s: float = 0.1
    multiplier: float = 2.0
    max_delay_s: float = 30.0
    max_attempts: int = 6
    jitter: float = 0.25  # fraction of the delay, deterministic per-seed

    def delay(self, attempt: int, rng: np.random.Generator) -> float:
        d = min(self.base_delay_s * self.multiplier ** attempt,
                self.max_delay_s)
        if self.jitter:
            d *= 1.0 + self.jitter * float(rng.uniform(-1.0, 1.0))
        return d


@dataclasses.dataclass
class RetryStats:
    attempts: int = 0
    total_delay_s: float = 0.0
    succeeded: bool = False


def retry(fn: Callable[[], Any], policy: RetryPolicy, clock,
          seed: int = 0) -> tuple[Any, RetryStats]:
    """Run fn with exponential backoff on TransientError. Raises the last
    TransientError (wrapped as PermanentError) after max_attempts."""
    rng = np.random.default_rng(seed)
    stats = RetryStats()
    last: Exception | None = None
    for attempt in range(policy.max_attempts):
        stats.attempts = attempt + 1
        try:
            out = fn()
            stats.succeeded = True
            return out, stats
        except TransientError as e:
            last = e
            if attempt == policy.max_attempts - 1:
                break
            d = policy.delay(attempt, rng)
            stats.total_delay_s += d
            clock.sleep(d)
    raise PermanentError(
        f"gave up after {stats.attempts} attempts: {last}") from last


class IdempotencyRegistry:
    """De-duplicates retried submissions: the same token always maps to the
    first completed result (paper: "job uniqueness validation to prevent
    duplicate executions arising from repeated submissions")."""

    def __init__(self):
        self._done: dict[str, Any] = {}
        self._inflight: dict[str, threading.Event] = {}
        self._lock = threading.Lock()

    @staticmethod
    def token(*parts: Any) -> str:
        h = hashlib.sha256("|".join(str(p) for p in parts).encode())
        return h.hexdigest()[:24]

    def run(self, token: str, fn: Callable[[], Any]) -> tuple[Any, bool]:
        """Returns (result, was_duplicate).

        A token already executing (in flight) is NOT executed again: the
        duplicate caller awaits the first execution and returns its
        result with ``was_duplicate=True``. If the first execution
        raises, the token is released and a waiter takes over the retry
        (the failed attempt produced no effect to deduplicate against).
        """
        while True:
            with self._lock:
                if token in self._done:
                    return self._done[token], True
                ev = self._inflight.get(token)
                if ev is None:
                    ev = self._inflight[token] = threading.Event()
                    break
            ev.wait()  # first execution finished (or failed) — re-check
        try:
            out = fn()
        except BaseException:
            with self._lock:
                del self._inflight[token]
            ev.set()
            raise
        with self._lock:
            self._done[token] = out
            del self._inflight[token]
        ev.set()
        return out, False
