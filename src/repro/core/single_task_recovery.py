"""Single-task recovery (paper §III-B) on a multi-worker data-parallel
trainer.

Baseline (region/global failover): a worker failure restarts the whole job —
throughput drops to zero for restore + replay (Fig 9 left).

Single-task recovery: only the failed worker stops; in-flight records bound
for it are dropped (γ=partial), its parameters are rebuilt from a healthy DP
peer (parameters are replica-identical), and it rejoins. The survivors never
stop — throughput dips by ~1/N for the rebuild window.

The trainer runs REAL jax train steps on a reduced config; time is virtual so
the QPS traces are deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.core.chaos import ChaosEngine
from repro.core.clock import VirtualClock


@dataclasses.dataclass
class WorkerState:
    params: Any
    opt_state: Any
    alive: bool = True
    rebuild_until: float = -1.0


@dataclasses.dataclass(frozen=True)
class RecoveryTiming:
    detect_s: float = 0.5
    respawn_s: float = 2.0          # container/TM restart
    peer_copy_s: float = 1.0        # params copy from a healthy peer
    global_restore_s: float = 30.0  # full-job restore from checkpoint
    global_replay_s: float = 60.0   # replay from last checkpoint


class MultiWorkerTrainer:
    """N virtual DP workers; grads averaged across *alive* workers each step
    (numerically identical to dropping the failed worker's microbatch)."""

    def __init__(self, model, run, n_workers: int, *, step_time_s: float = 0.5,
                 records_per_worker_step: int = 1024,
                 mode: str = "single_task",
                 timing: RecoveryTiming | None = None,
                 chaos: ChaosEngine | None = None, seed: int = 0):
        assert mode in ("single_task", "global_restart")
        from repro.dist.sharding import NO_SHARDING
        from repro.train import train_loop
        from repro.train.optimizer import make_optimizer

        self.model = model
        self.ctx = NO_SHARDING
        self.mode = mode
        self.timing = timing or RecoveryTiming()
        self.chaos = chaos or ChaosEngine()
        self.clock = VirtualClock()
        self.step_time_s = step_time_s
        self.rps = records_per_worker_step
        self.n = n_workers

        raw = train_loop.make_train_step(model, run, self.ctx)
        self._step_fn = jax.jit(raw)
        self._opt = raw.optimizer

        params = model.init(jax.random.PRNGKey(seed))
        opt_state = self._opt.init(params)
        # DP replicas start identical (true replication)
        self.workers = [WorkerState(params, opt_state) for _ in range(n_workers)]
        self.run = run
        self.step = 0
        self.trace: list[dict] = []
        self._rng = np.random.default_rng(seed)
        self._global_down_until = -1.0

    # ------------------------------------------------------------------
    def _make_batch(self, seed: int):
        shape = dataclasses.replace(self.run.shape, global_batch=2)
        return self.model.demo_batch(shape, jax.random.PRNGKey(seed))

    def run_for(self, duration_s: float) -> list[dict]:
        t_end = self.clock.now() + duration_s
        while self.clock.now() < t_end:
            self._tick()
        return self.trace

    def _tick(self) -> None:
        t0 = self.clock.now()
        kills = self.chaos.step_kills(t0, t0 + self.step_time_s, self.n)
        for k in kills:
            self._on_failure(k, t0)

        if t0 < self._global_down_until:
            # global restart in progress: zero throughput
            self.trace.append({"t": t0, "qps": 0.0, "alive": 0,
                               "step": self.step})
            self.clock.sleep(self.step_time_s)
            return

        alive = [w for w in self.workers if w.alive and
                 t0 >= w.rebuild_until]
        # workers finishing rebuild rejoin with a peer's params
        for w in self.workers:
            if w.alive and 0 <= w.rebuild_until <= t0 and w.params is None:
                peer = next(x for x in self.workers if x.params is not None)
                w.params, w.opt_state = peer.params, peer.opt_state
        if alive:
            # one representative jax step (replicas are identical), batch =
            # concat of alive workers' microbatches — here: any worker's batch
            w0 = alive[0]
            batch = self._make_batch(self.step)
            params, opt_state, metrics = self._step_fn(
                w0.params, w0.opt_state, batch)
            for w in alive:
                w.params, w.opt_state = params, opt_state
            self.step += 1
        qps = len(alive) * self.rps / self.step_time_s
        self.trace.append({"t": t0, "qps": qps, "alive": len(alive),
                           "step": self.step})
        self.clock.sleep(self.step_time_s)

    # ------------------------------------------------------------------
    def _on_failure(self, worker_id: int, t: float) -> None:
        tm = self.timing
        if self.mode == "global_restart":
            # the native failover chain: everything restarts
            self._global_down_until = t + (tm.detect_s + tm.global_restore_s
                                           + tm.global_replay_s)
            return
        w = self.workers[worker_id]
        w.params = None  # lost with the host
        w.opt_state = None
        w.rebuild_until = t + tm.detect_s + tm.respawn_s + tm.peer_copy_s
        self.chaos.revive(worker_id)  # host replaced
        w.alive = True
