"""DS2-based autoscaling with StreamShield's production hardening
(paper §III-A): metric smoothing + compensation, hysteresis, automatic
rollback on failed adjustments, business-driven shrink vetoes, rate limiting
and a failover-aware circuit breaker.

DS2 (Kalavri et al., OSDI'18): an operator's *true* processing rate is
records processed per unit of busy time; target parallelism is the ratio of
the rate the operator must sustain (propagated topologically from sources
through per-edge selectivity) to the per-task true rate.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable

import numpy as np


@dataclasses.dataclass
class OpMetrics:
    op: str
    input_rate: float        # records/s arriving
    processed: float         # records processed this window
    busy_time_s: float       # total busy task-seconds in the window
    parallelism: int
    backlog: float = 0.0
    backpressured: bool = False
    is_source: bool = False


@dataclasses.dataclass(frozen=True)
class ScalerConfig:
    window: int = 6                  # EWMA smoothing horizon (windows)
    ewma_alpha: float = 0.35
    hysteresis: float = 0.15         # ignore <15% parallelism deltas
    cooldown_s: float = 120.0
    target_utilization: float = 0.8  # headroom above the DS2 point
    min_parallelism: int = 1
    max_parallelism: int = 4096
    source_busy_correction: float = 1.1   # paper: adjust source busy time
    backlog_drain_s: float = 120.0   # drain backlog within this budget
    max_actions_per_hour: int = 12   # rate limiting
    breaker_failures: int = 3        # circuit breaker threshold
    breaker_reset_s: float = 1800.0


@dataclasses.dataclass
class ScaleDecision:
    op: str
    old: int
    new: int
    reason: str


class DS2Scaler:
    def __init__(self, cfg: ScalerConfig | None = None,
                 shrink_veto: Callable[[float], bool] | None = None):
        """shrink_veto(t) → True blocks downscaling (peak-hour policy)."""
        self.cfg = cfg or ScalerConfig()
        self.shrink_veto = shrink_veto or (lambda t: False)
        self._rate_ewma: dict[str, float] = {}
        self._last_action_t: dict[str, float] = defaultdict(lambda: -1e18)
        self._actions: deque[float] = deque()
        self._pending_rollback: dict[str, tuple[int, float]] = {}
        self._breaker_until = -1e18
        # per-op consecutive-failure counts: a flapping op must trip the
        # breaker even while every OTHER op resizes cleanly (a global
        # counter would be reset by any healthy op's success)
        self._failures: dict[str, int] = defaultdict(int)
        self.history: list[ScaleDecision] = []

    # ------------------------------------------------------------------
    def _true_rate(self, m: OpMetrics) -> float:
        """Smoothed per-task true processing rate (records / busy-second)."""
        busy = max(m.busy_time_s, 1e-9)
        if m.is_source:
            busy *= self.cfg.source_busy_correction
        raw = m.processed / busy
        if m.backpressured:
            # saturated busy signals understate capability; substitute the
            # actual processing rate as the floor (paper's compensation)
            raw = max(raw, m.processed / max(m.busy_time_s, 1e-9))
        prev = self._rate_ewma.get(m.op, raw)
        sm = (1 - self.cfg.ewma_alpha) * prev + self.cfg.ewma_alpha * raw
        self._rate_ewma[m.op] = sm
        return sm

    def observe(self, t: float, metrics: list[OpMetrics],
                ) -> list[ScaleDecision]:
        cfg = self.cfg
        self._expire_pending(t)
        if t < self._breaker_until:
            return []
        # rate limiting window
        while self._actions and self._actions[0] < t - 3600:
            self._actions.popleft()

        decisions = []
        for m in metrics:
            true_rate = self._true_rate(m)
            if true_rate <= 0:
                continue
            target = m.input_rate / cfg.target_utilization
            if m.backlog > 0:
                target += m.backlog / cfg.backlog_drain_s
            want = int(np.ceil(target / true_rate))
            want = int(np.clip(want, cfg.min_parallelism,
                               cfg.max_parallelism))
            cur = m.parallelism
            if want == cur:
                continue
            if abs(want - cur) / max(cur, 1) < cfg.hysteresis:
                continue
            if t - self._last_action_t[m.op] < cfg.cooldown_s:
                continue
            if want < cur and self.shrink_veto(t):
                continue
            if len(self._actions) >= cfg.max_actions_per_hour:
                continue
            d = ScaleDecision(m.op, cur, want,
                              f"true_rate={true_rate:.1f}/task "
                              f"target={target:.0f}/s backlog={m.backlog:.0f}")
            decisions.append(d)
            self.history.append(d)
            self._actions.append(t)
            self._last_action_t[m.op] = t
            self._pending_rollback[m.op] = (cur, t)
        return decisions

    # -- safety rails -----------------------------------------------------
    def _expire_pending(self, t: float) -> None:
        """Drop rollback anchors older than the cooldown: a resize that
        aged past ``cooldown_s`` without a reported failure is settled,
        and a later unrelated failure must not roll back to it. With no
        cooldown configured there is no settling window — anchors stay
        live until their outcome is reported."""
        if self.cfg.cooldown_s <= 0:
            return
        stale = [op for op, (_, t0) in self._pending_rollback.items()
                 if t - t0 > self.cfg.cooldown_s]
        for op in stale:
            del self._pending_rollback[op]

    def notify_result(self, op: str, t: float, *, success: bool
                      ) -> ScaleDecision | None:
        """Report the outcome of applying a decision. On failure: roll back
        to the previous parallelism; repeated failures of the SAME op trip
        the breaker (counts are per-op — a healthy op's success must not
        mask a flapping one)."""
        self._expire_pending(t)
        prev = self._pending_rollback.pop(op, None)
        if success:
            self._failures[op] = 0
            return None
        self._failures[op] += 1
        if self._failures[op] >= self.cfg.breaker_failures:
            self._breaker_until = t + self.cfg.breaker_reset_s
        if prev is None:
            return None
        rollback = ScaleDecision(op, -1, prev[0], "rollback (failed resize)")
        self.history.append(rollback)
        return rollback
