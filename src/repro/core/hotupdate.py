"""HotUpdate (paper §III-C): restart a job with new business logic while
reusing the existing resources — here, the TPU-native analogues:

* device buffers (params / optimizer state) stay resident and are donated to
  the new version's step function instead of being torn down and re-uploaded;
* compiled executables are cached by (logic fingerprint, shapes, shardings) —
  an unchanged stage re-jits for free;
* the persistent XLA compilation cache survives process restarts.

``HotUpdateManager.update`` returns a timing report (teardown / compile /
first-step) so cold vs hot restarts are directly comparable (paper: "HotUpdate
can reduce the job restart latency to 20 seconds").
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Callable

import jax


def enable_persistent_cache(path: str = "/tmp/repro-xla-cache") -> None:
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


def _fingerprint(*parts: Any) -> str:
    return hashlib.sha256("|".join(str(p) for p in parts).encode()).hexdigest()[:16]


@dataclasses.dataclass
class RestartReport:
    kind: str                 # "cold" | "hot"
    compile_s: float
    transfer_s: float
    first_step_s: float

    @property
    def total_s(self) -> float:
        return self.compile_s + self.transfer_s + self.first_step_s


class ExecutableCache:
    def __init__(self):
        self._cache: dict[str, Any] = {}
        self.hits = 0
        self.misses = 0

    def get_or_compile(self, key: str, build: Callable[[], Any]) -> Any:
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        self.misses += 1
        out = build()
        self._cache[key] = out
        return out


#: nominal per-wave restart cost components (seconds) for the
#: deployment-drill lowering — the same compile / transfer / first-step
#: decomposition `RestartReport` measures on real hardware, frozen into
#: deterministic scalars so drill downtimes are reproducible. The cold
#: compile figure matches the paper's "restart latency to 20 seconds"
#: headline (cold ≈ compile + transfer + first-step ≈ 27s, hot ≈ 20s
#: saved → ~7s).
DEPLOY_COMPILE_S = 18.0       # full re-jit of every stage
DEPLOY_CACHED_COMPILE_S = 2.0  # executable-cache hit (fingerprint match)
DEPLOY_TRANSFER_S = 6.0       # state re-upload to device (cold only)
DEPLOY_FIRST_STEP_S = 3.0     # warmup step / dispatch plumbing


def deploy_downtime(startup=None, *, hot: bool = True) -> float:
    """Deterministic seconds of downtime one rolling-upgrade wave pays,
    lowered from the `RestartReport` cost model plus a
    `core.startup.StartupConfig`'s mitigations:

    * ``hot`` deploys reuse device state (transfer_s = 0) and hit the
      executable cache (compile_s collapses to the cached figure) —
      strictly cheaper than cold for every startup-flag combination;
    * ``object_reuse`` skips plan re-interning (shaves first-step cost);
    * ``batched_deploy`` amortizes dispatch round-trips across the wave's
      tasks (halves the remaining first-step cost);
    * ``straggler_mitigation`` over-provisions the wave by
      ``overprovision_frac`` spare task managers, so the wave's ready
      time is not gated on its slowest replacement (shaves the tail off
      transfer + first-step).

    Returns a plain float (no rng, no device work) — the engines bake it
    into the traced per-wave ``up_until`` arithmetic."""
    from repro.core.startup import StartupConfig
    cfg = startup or StartupConfig()
    compile_s = DEPLOY_CACHED_COMPILE_S if hot else DEPLOY_COMPILE_S
    transfer_s = 0.0 if hot else DEPLOY_TRANSFER_S
    first_step_s = DEPLOY_FIRST_STEP_S
    if cfg.object_reuse:
        first_step_s *= 0.7
    if cfg.batched_deploy:
        first_step_s *= 0.5
    if cfg.straggler_mitigation:
        tail = 1.0 / (1.0 + min(cfg.overprovision_frac, 1.0))
        transfer_s *= tail
        first_step_s *= tail
    return compile_s + transfer_s + first_step_s


class HotUpdateManager:
    """Holds the live job (state on device + compiled step); `update`
    switches business logic versions."""

    def __init__(self, *, cache: ExecutableCache | None = None):
        self.cache = cache or ExecutableCache()
        self.state: Any = None
        self.step_fn: Any = None
        self.version: str | None = None
        self.reports: list[RestartReport] = []

    def deploy(self, version: str, make_step: Callable[[], Callable],
               state: Any, example_args: tuple, *,
               reuse_state: bool = True) -> RestartReport:
        """Deploy `version`. Hot path: state buffers reused (no re-upload),
        executable from cache if this version compiled before."""
        hot = reuse_state and self.state is not None
        t0 = time.perf_counter()
        if hot:
            state = self.state  # buffers stay on device
            transfer_s = 0.0
        else:
            state = jax.tree.map(jax.device_put, state)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            transfer_s = time.perf_counter() - t0

        key = _fingerprint(version, jax.tree.structure(state))
        t1 = time.perf_counter()
        step = self.cache.get_or_compile(key, make_step)
        compile_s = time.perf_counter() - t1

        t2 = time.perf_counter()
        out = step(state, *example_args)
        jax.block_until_ready(out)
        first_step_s = time.perf_counter() - t2

        self.state = out[0] if isinstance(out, tuple) else out
        self.step_fn = step
        self.version = version
        rep = RestartReport("hot" if hot else "cold", compile_s, transfer_s,
                            first_step_s)
        self.reports.append(rep)
        return rep
