"""State LazyLoad (paper §III-B): decouple job resumption from full state
materialization. Regions restore asynchronously in priority order (execution
order: embeddings → early layers → …); compute blocks only on the region it
is about to touch, overlapping restore with processing. Time-to-first-token
improves by ~the tail of the restore, measured by bench/lazyload tests.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import regions as R
from repro.core.region_checkpoint import _deep_mutable, _unpack


class LazyRestorer:
    def __init__(self, checkpointer, template_tree, *, gamma: str = "full",
                 priority: list[int] | None = None, max_workers: int = 2):
        self.ckpt = checkpointer
        self.view = checkpointer.manifest.merge_view(gamma)
        self.tree = _deep_mutable(template_tree)
        self.regions = {r.region_id: r for r in checkpointer.regions}
        order = priority if priority is not None else sorted(self.regions)
        self._ready: dict[int, threading.Event] = {
            rid: threading.Event() for rid in self.regions}
        self._errors: dict[int, BaseException] = {}
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._lock = threading.Lock()
        self.timeline: dict[int, float] = {}
        self._t0 = checkpointer.clock.now()
        for rid in order:
            self._pool.submit(self._fetch, rid)
        # all fetches are queued; let the workers exit once they drain
        self._pool.shutdown(wait=False)

    def _fetch(self, rid: int) -> None:
        try:
            region = self.regions[rid]
            snap = self.view[rid]
            data = {p: _unpack(self.ckpt.storage.get(k))
                    for p, k in snap.keys.items()}
            with self._lock:
                R.insert_region(self.tree, region, data)
                self.timeline[rid] = self.ckpt.clock.now() - self._t0
        except BaseException as exc:  # surfaced from wait_region, not lost
            self._errors[rid] = exc
        finally:
            self._ready[rid].set()

    # ------------------------------------------------------------------
    def wait_region(self, rid: int, timeout: float | None = 60.0):
        """Block until region rid is materialized (demand-driven access)."""
        if not self._ready[rid].wait(timeout):
            raise TimeoutError(f"region {rid} not restored in {timeout}s")
        err = self._errors.get(rid)
        if err is not None:
            raise err

    def wait_all(self, timeout: float | None = 120.0):
        for rid in self.regions:
            self.wait_region(rid, timeout)
        return self.tree

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def ready_regions(self) -> list[int]:
        return [rid for rid, ev in self._ready.items()
                if ev.is_set() and rid not in self._errors]

    def run_when_ready(self, rid: int, fn, *args):
        """Execute fn once region rid is present (pipelined serve path)."""
        self.wait_region(rid)
        return fn(*args)
