"""WeakHash (paper §III-A): relax the strict key→task binding to a bounded
candidate set + dynamic (load-aware) selection. Host-side numpy version used
by the stream engine, the data pipeline and the cluster sim; the token-path
twin lives in kernels/weakhash_route (jnp/Pallas).

`weakhash_assign` is vectorized: instead of the O(N·gsz) sequential greedy
loop it computes, per candidate group, the exact per-task key counts the
greedy process would produce (a water-filling argument — see
`_group_counts`), then materializes assignments in one scatter.

Tie-order relaxation (documented, tested): the sequential greedy interleaves
keys across tasks in arrival order; the vectorized path assigns each group's
keys task-major (task 0's quota first, then task 1's, ...). Per-task counts
— and therefore `load_cv` and group containment — are IDENTICAL (bit-exact
for integer-valued starting loads, to within one float-ulp tie reshuffle
otherwise); only which individual key lands on which in-group task differs.
Pass ``sequential=True`` for the original arrival-order semantics.
"""
from __future__ import annotations

import numpy as np

KNUTH = np.uint32(2654435761)


def strong_hash(keys: np.ndarray, n_tasks: int) -> np.ndarray:
    """Flink-style keyBy: key → exactly one task."""
    return ((keys.astype(np.uint64) * 2654435761) % n_tasks).astype(np.int64)


def candidate_group(keys: np.ndarray, n_groups: int) -> np.ndarray:
    return ((keys.astype(np.uint64) * 2654435761) % n_groups).astype(np.int64)


def _group_counts(L: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Exact per-task key counts of sequential least-loaded filling.

    Greedy least-loaded with unit increments picks exactly the k smallest
    values of the virtual grid {L[j] + i : i >= 0} (ties broken toward the
    lower task index). The count for task j is therefore the number of its
    grid values below the k-th smallest ("water level"), found here by a
    vectorized bisection per group.

    L: (G, m) starting loads per group; k: (G,) keys per group.
    Returns integer counts (G, m) with counts.sum(1) == k.
    """
    G, m = L.shape
    kf = k.astype(np.float64)
    base = L.min(axis=1)
    lo = base - 1.0                # N(lo) = 0 < k
    hi = base + kf                 # argmin task alone yields k+1 ≥ k
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        cnt = np.maximum(np.floor(mid[:, None] - L) + 1.0, 0.0).sum(axis=1)
        ge = cnt >= kf
        hi = np.where(ge, mid, hi)
        lo = np.where(ge, lo, mid)
    c = np.maximum(np.floor(hi[:, None] - L) + 1.0, 0.0).astype(np.int64)
    surplus = c.sum(axis=1) - k
    # remove the surplus from the tie candidates (tasks whose topmost picked
    # value sits at the water level), highest task index first — mirroring
    # the greedy's lowest-index-wins tie break
    top = L + (c - 1)
    cand = (c > 0) & (top > lo[:, None])
    rank_from_right = np.cumsum(cand[:, ::-1], axis=1)[:, ::-1]
    c -= cand & (rank_from_right <= surplus[:, None])
    c[k == 0] = 0
    return c


def weakhash_assign(keys: np.ndarray, n_tasks: int, n_groups: int,
                    loads: np.ndarray | None = None,
                    rng: np.random.Generator | None = None,
                    sequential: bool = False,
                    chunk: int | None = None) -> np.ndarray:
    """Assign each key to a task within its candidate group, least-loaded
    first (records within a batch update the load estimate greedily,
    mirroring credit consumption). Vectorized; see the module docstring for
    the tie-order relaxation versus ``sequential=True``.

    ``chunk=C`` enables the chunked-streaming mode: the water-fill runs
    per chunk of C keys and the load estimates are refreshed between
    chunks, interpolating between the batch semantics (``chunk >= N``
    reproduces the batch assignment array exactly — one chunk IS the
    batch) and the sequential credit semantics (``chunk=1`` degenerates
    to one least-loaded pick per key, i.e. ``sequential=True``
    key-for-key)."""
    assert n_tasks % n_groups == 0, (n_tasks, n_groups)
    gsz = n_tasks // n_groups
    if chunk is not None and not sequential:
        assert chunk > 0, chunk
        loads_c = (np.zeros(n_tasks, np.float64) if loads is None
                   else loads.astype(np.float64).copy())
        out = np.empty(len(keys), np.int64)
        for lo in range(0, len(keys), chunk):
            part = weakhash_assign(keys[lo:lo + chunk], n_tasks, n_groups,
                                   loads=loads_c)
            out[lo:lo + chunk] = part
            loads_c += np.bincount(part, minlength=n_tasks)
        return out
    group = candidate_group(keys, n_groups)
    loads = np.zeros(n_tasks, np.float64) if loads is None else loads.astype(
        np.float64).copy()
    if sequential:
        # greedy sequential least-loaded pick (arrival-order semantics;
        # kept as the reference for the vectorized path's parity tests)
        out = np.empty(len(keys), np.int64)
        for i, g in enumerate(group):
            base = g * gsz
            cand = loads[base:base + gsz]
            j = int(np.argmin(cand))
            out[i] = base + j
            loads[base + j] += 1.0
        return out
    k_per_group = np.bincount(group, minlength=n_groups)
    counts = _group_counts(loads.reshape(n_groups, gsz), k_per_group)
    # group-sorted key positions receive tasks task-major per group
    task_seq = np.repeat(np.arange(n_tasks, dtype=np.int64),
                         counts.reshape(-1))
    order = np.argsort(group, kind="stable")
    out = np.empty(len(keys), np.int64)
    out[order] = task_seq
    return out


def load_cv(assignments: np.ndarray, n_tasks: int,
            weights: np.ndarray | None = None) -> float:
    """Coefficient of variation of per-task load (skew metric)."""
    w = np.ones(len(assignments)) if weights is None else weights
    loads = np.bincount(assignments, weights=w, minlength=n_tasks)
    mu = loads.mean()
    return float(loads.std() / mu) if mu > 0 else 0.0
