"""WeakHash (paper §III-A): relax the strict key→task binding to a bounded
candidate set + dynamic (load-aware) selection. Host-side numpy version used
by the stream engine, the data pipeline and the cluster sim; the token-path
twin lives in kernels/weakhash_route (jnp/Pallas).
"""
from __future__ import annotations

import numpy as np

KNUTH = np.uint32(2654435761)


def strong_hash(keys: np.ndarray, n_tasks: int) -> np.ndarray:
    """Flink-style keyBy: key → exactly one task."""
    return ((keys.astype(np.uint64) * 2654435761) % n_tasks).astype(np.int64)


def candidate_group(keys: np.ndarray, n_groups: int) -> np.ndarray:
    return ((keys.astype(np.uint64) * 2654435761) % n_groups).astype(np.int64)


def weakhash_assign(keys: np.ndarray, n_tasks: int, n_groups: int,
                    loads: np.ndarray | None = None,
                    rng: np.random.Generator | None = None) -> np.ndarray:
    """Assign each key to a task within its candidate group, least-loaded
    first (records within a batch update the load estimate greedily, mirroring
    credit consumption)."""
    assert n_tasks % n_groups == 0, (n_tasks, n_groups)
    gsz = n_tasks // n_groups
    group = candidate_group(keys, n_groups)
    loads = np.zeros(n_tasks, np.float64) if loads is None else loads.astype(
        np.float64).copy()
    out = np.empty(len(keys), np.int64)
    # greedy sequential least-loaded pick (vectorized per unique group batch
    # would reorder ties; sequential matches the streaming arrival semantics)
    for i, g in enumerate(group):
        base = g * gsz
        cand = loads[base:base + gsz]
        j = int(np.argmin(cand))
        out[i] = base + j
        loads[base + j] += 1.0
    return out


def load_cv(assignments: np.ndarray, n_tasks: int,
            weights: np.ndarray | None = None) -> float:
    """Coefficient of variation of per-task load (skew metric)."""
    w = np.ones(len(assignments)) if weights is None else weights
    loads = np.bincount(assignments, weights=w, minlength=n_tasks)
    mu = loads.mean()
    return float(loads.std() / mu) if mu > 0 else 0.0
