"""StreamShield core: the paper's resiliency mechanisms as first-class
features of the JAX runtime (engine / cluster / release perspectives)."""
