"""Shuffle / partitioning strategies (paper §III-A, Fig 2).

Credit-based flow control: every channel (up-task → down-task) has a bounded
credit budget = free buffer slots at the receiver. Backlog-based shuffle
diverts records away from channels whose backlog exceeds a threshold;
Group-Rescale confines rebalancing to disjoint task groups so co-located
stragglers can be bypassed without global all-to-all wiring.

All strategies are vectorized numpy: `assign(keys, state) → down-task idx`.
The same strategies drive the stream engine, the host data pipeline, and the
Fig 6 reproduction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import weakhash as wh


@dataclasses.dataclass
class ChannelState:
    """Per (this up-task → all down-tasks) channel view."""
    n_down: int
    credits: np.ndarray          # free buffer slots per channel
    backlog: np.ndarray          # queued records per down-task
    rr_cursor: int = 0

    @staticmethod
    def fresh(n_down: int, credit_budget: int = 64) -> "ChannelState":
        return ChannelState(n_down, np.full(n_down, credit_budget, np.int64),
                            np.zeros(n_down, np.int64))


class Rebalance:
    """Round-robin over ALL downstream tasks (Fig 2a)."""
    name = "rebalance"

    def assign(self, n: int, st: ChannelState, keys=None) -> np.ndarray:
        idx = (st.rr_cursor + np.arange(n)) % st.n_down
        st.rr_cursor = int((st.rr_cursor + n) % st.n_down)
        return idx


class Rescale:
    """Round-robin over a FIXED local subset (Fig 2b)."""
    name = "rescale"

    def __init__(self, subset: np.ndarray):
        self.subset = np.asarray(subset)

    def assign(self, n: int, st: ChannelState, keys=None) -> np.ndarray:
        idx = self.subset[(st.rr_cursor + np.arange(n)) % len(self.subset)]
        st.rr_cursor = int((st.rr_cursor + n) % len(self.subset))
        return idx


class GroupRescale:
    """Round-robin within the task's GROUP (Fig 2c) — wider than Rescale's
    fixed pair, narrower than Rebalance; lets healthy upstreams bypass a
    straggling co-located downstream."""
    name = "group_rescale"

    def __init__(self, group_members: np.ndarray):
        self.members = np.asarray(group_members)

    def assign(self, n: int, st: ChannelState, keys=None) -> np.ndarray:
        idx = self.members[(st.rr_cursor + np.arange(n)) % len(self.members)]
        st.rr_cursor = int((st.rr_cursor + n) % len(self.members))
        return idx


class BacklogShuffle:
    """Backlog-based shuffle: round-robin, but channels whose backlog exceeds
    `threshold` (credits exhausted) are excluded; records divert to the
    least-backlogged candidates. Scope can be the full fan-out or a group."""
    name = "backlog"

    def __init__(self, threshold: int = 48,
                 members: np.ndarray | None = None):
        self.threshold = threshold
        self.members = members  # None → all

    def assign(self, n: int, st: ChannelState, keys=None) -> np.ndarray:
        cand = (np.arange(st.n_down) if self.members is None
                else np.asarray(self.members))
        backlog = st.backlog[cand]
        open_mask = backlog < self.threshold
        if not open_mask.any():
            # every channel congested: fall back to least-backlogged
            order = cand[np.argsort(backlog, kind="stable")]
            return order[np.arange(n) % len(order)]
        open_cand = cand[open_mask]
        # weight inversely by backlog: emptier channels take more records
        free = (self.threshold - st.backlog[open_cand]).astype(np.float64)
        quota = np.maximum(np.round(free / free.sum() * n), 0).astype(int)
        # distribute remainder round-robin
        out = np.repeat(open_cand, quota)[:n]
        if len(out) < n:
            extra = open_cand[(st.rr_cursor + np.arange(n - len(out)))
                              % len(open_cand)]
            st.rr_cursor = int((st.rr_cursor + n - len(out)) % len(open_cand))
            out = np.concatenate([out, extra])
        return out


class KeyHash:
    """Strict keyBy (baseline for WeakHash comparisons)."""
    name = "hash"

    def assign(self, n: int, st: ChannelState, keys=None) -> np.ndarray:
        assert keys is not None
        return wh.strong_hash(np.asarray(keys), st.n_down)


class WeakHash:
    """Key → bounded candidate group → least-loaded member (paper §III-A).

    Uses the vectorized water-fill assignment by default (exact per-task
    counts, task-major key order within a batch); pass ``sequential=True``
    for strict arrival-order greedy semantics (slow, reference path).
    """
    name = "weakhash"

    def __init__(self, n_groups: int, sequential: bool = False):
        self.n_groups = n_groups
        self.sequential = sequential

    def assign(self, n: int, st: ChannelState, keys=None) -> np.ndarray:
        assert keys is not None
        return wh.weakhash_assign(np.asarray(keys), st.n_down, self.n_groups,
                                  loads=st.backlog.astype(np.float64),
                                  sequential=self.sequential)
