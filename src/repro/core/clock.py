"""Clock abstraction: wall clock for production, virtual clock for the
deterministic simulators (cluster, streams, chaos drills)."""
from __future__ import annotations

import heapq
import itertools
import time as _time


class WallClock:
    def now(self) -> float:
        return _time.time()

    def sleep(self, s: float) -> None:
        _time.sleep(s)


class VirtualClock:
    """Deterministic simulated time; sleep() advances instantly."""

    def __init__(self, start: float = 0.0):
        self._t = start

    def now(self) -> float:
        return self._t

    def sleep(self, s: float) -> None:
        self._t += max(0.0, s)

    def advance_to(self, t: float) -> None:
        self._t = max(self._t, t)


class EventLoop:
    """Minimal discrete-event loop over a VirtualClock."""

    def __init__(self, clock: VirtualClock | None = None):
        self.clock = clock or VirtualClock()
        self._q: list = []
        self._counter = itertools.count()

    def schedule(self, delay: float, fn, *args) -> None:
        heapq.heappush(self._q, (self.clock.now() + delay,
                                 next(self._counter), fn, args))

    def run_until(self, t_end: float) -> None:
        while self._q and self._q[0][0] <= t_end:
            t, _, fn, args = heapq.heappop(self._q)
            self.clock.advance_to(t)
            fn(*args)
        self.clock.advance_to(t_end)

    def run_all(self) -> None:
        while self._q:
            t, _, fn, args = heapq.heappop(self._q)
            self.clock.advance_to(t)
            fn(*args)
