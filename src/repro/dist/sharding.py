"""Logical-axis sharding layer: ParamSpec trees + ShardingCtx.

Every parameter / cache / optimizer-slot leaf is declared once as a
:class:`ParamSpec` — shape, *logical* axis names, init and dtype. The same
declaration materializes

* real arrays              (``tree_init`` — smoke tests, single host),
* ``ShapeDtypeStruct``s    (``tree_abstract`` — the dry-run path, no
  allocation),
* ``PartitionSpec``s       (``tree_pspecs`` — mesh lowering), and
* ``NamedSharding``s       (``tree_shardings``).

Logical → mesh axes go through a *rules* dict (``DEFAULT_RULES``); callers
override entries per profile (e.g. the dry-run switches ``"expert"`` to the
run's dispatch axes and clears ``"embed"`` for serving — no per-step FSDP
all-gathers at decode). Rule application is defensive: a mesh axis is used
only if it exists in the mesh, is not already taken by an earlier dim of the
same spec, and divides the dim size — otherwise that dim is replicated. This
is what lets one model definition lower on any mesh shape.

``NO_SHARDING`` is the single-device context (mesh=None): ``constrain`` is
the identity and every spec is fully replicated.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Logical axis name → tuple of mesh axis names (applied left to right).
# "embed" over the data axis = FSDP; tensor-parallel dims over "model";
# "batch" over every data-parallel axis present ("pod" first on multi-pod).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),          # FSDP (cleared for serving profiles)
    "ff": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "d_inner": ("model",),
    "ssm_heads": ("model",),
    "expert": ("model",),        # dry-run overrides with the run's slot axes
    "seq": ("model",),           # active only when sequence_parallel
    "kv_seq": ("model",),        # distributed-LSE decode fallback
    "layers": (),
}

# Default leaf dtype when a spec leaves dtype=None: bf16, matching the
# byte accounting in core/regions.py (2 bytes per unspecified leaf) and the
# training setup (bf16 weights, f32 optimizer slots declared explicitly).
_DEFAULT_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One leaf: shape + logical axes (+ init/dtype/scale)."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"            # "normal" | "zeros" | "ones"
    dtype: Any = None               # None → bfloat16 (_DEFAULT_DTYPE)
    scale: float | None = None      # normal() stddev; None → 0.02

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(self.shape))
        object.__setattr__(self, "axes", tuple(self.axes))
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


_IS_SPEC = lambda x: isinstance(x, ParamSpec)  # noqa: E731


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Mesh + logical-axis rules; mesh=None = single device (NO_SHARDING)."""
    mesh: Any = None
    rules: dict[str, tuple[str, ...]] | None = None
    sequence_parallel: bool = True
    unroll: bool | int = False

    # -- rule resolution ------------------------------------------------
    def _rule(self, name: str | None) -> tuple[str, ...]:
        if name is None or self.mesh is None:
            return ()
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        if name == "seq" and not self.sequence_parallel:
            return ()
        return tuple(rules.get(name, ()))

    def axis_size(self, mesh_axis: str) -> int:
        if self.mesh is None:
            return 1
        return int(dict(self.mesh.shape).get(mesh_axis, 1))

    def divides(self, name: str | None, size: int) -> bool:
        """Whether `size` splits evenly over the mesh axes mapped to the
        logical axis `name` (True means sharding that dim loses nothing)."""
        axes = [a for a in self._rule(name) if a in dict(self.mesh.shape)] \
            if self.mesh is not None else []
        prod = math.prod(self.axis_size(a) for a in axes) if axes else 1
        return prod > 1 and size % prod == 0

    def spec(self, axes: tuple[str | None, ...],
             shape: tuple[int, ...]) -> P:
        """PartitionSpec for logical `axes` of an array of `shape`, applying
        the rules defensively (missing / non-dividing / already-used mesh
        axes fall back to replication for that dim)."""
        if self.mesh is None:
            return P()
        mesh_shape = dict(self.mesh.shape)
        used: set[str] = set()
        entries: list[Any] = []
        for dim, name in zip(shape, axes):
            picked: list[str] = []
            prod = 1
            for a in self._rule(name):
                if a not in mesh_shape or a in used:
                    continue
                nxt = prod * mesh_shape[a]
                if dim % nxt != 0:
                    continue
                picked.append(a)
                prod = nxt
            used.update(picked)
            entries.append(tuple(picked) if len(picked) > 1
                           else (picked[0] if picked else None))
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def constrain(self, x: jax.Array, *axes: str | None) -> jax.Array:
        """with_sharding_constraint through the logical rules (identity when
        there is no mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(tuple(axes), x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


NO_SHARDING = ShardingCtx(mesh=None)


# ----------------------------------------------------------------------
# Seed-batch device sharding (chaos sweeps) — version-gated shim
# ----------------------------------------------------------------------
def jax_version() -> tuple[int, int]:
    major, minor = jax.__version__.split(".")[:2]
    return (int(major), int(minor))


def shard_map_available() -> bool:
    """True when the top-level `jax.shard_map` API exists (jax >= 0.6).
    The container ships 0.4.x, where `pmap` is the sharding vehicle; the
    gate keeps one call site working across both toolchains (ROADMAP's
    version-gated `repro/dist` shim item)."""
    return jax_version() >= (0, 6) and hasattr(jax, "shard_map")


def local_shard_count(requested: int | str | None) -> int:
    """Resolve a device-shard request against the local device count.
    ``None`` → 1 (no sharding), ``"auto"`` → all local devices, an int is
    clamped to the available devices."""
    n_local = jax.local_device_count()
    if requested is None:
        return 1
    if requested == "auto":
        return n_local
    return max(1, min(int(requested), n_local))


def sharded_seed_fn(run, *, xs_axes, n_shards: int, donate_state=True):
    """Device-sharded twin of ``jit(vmap(run))`` over a seed batch.

    ``run(pa, state, xs)`` is the per-seed scan; the returned callable
    takes a FLAT seed batch (leading axis ``S``, a multiple of
    ``n_shards``) and splits it across local devices. ``pa`` is
    replicated; ``state`` leaves and the seed-indexed ``xs`` leaves (axis
    0 in `xs_axes`) carry the seed axis. The per-seed scan is
    embarrassingly parallel, so the split maps straight onto local
    devices: `pmap` on jax 0.4.x (shard axis folded out / back in around
    the call), `jax.shard_map` on >= 0.6. The state argument is donated —
    each call's arena state buffers are consumed in place instead of
    being copied."""
    donate = (1,) if donate_state else ()
    if shard_map_available():  # pragma: no cover - requires jax >= 0.6
        import numpy as np
        from jax.sharding import Mesh

        inner = jax.vmap(run, in_axes=(None, 0, xs_axes))
        mesh = Mesh(np.array(jax.local_devices()[:n_shards]), ("seeds",))
        seeded = lambda a: P("seeds") if a == 0 else P()  # noqa: E731
        fn = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P("seeds"),
                      {k: seeded(a) for k, a in xs_axes.items()}),
            out_specs=P("seeds"))
        return jax.jit(fn, donate_argnums=donate)

    inner = jax.vmap(run, in_axes=(None, 0, xs_axes))
    shard_axes = {k: (0 if a == 0 else None) for k, a in xs_axes.items()}
    pfn = jax.pmap(inner, in_axes=(None, 0, shard_axes),
                   donate_argnums=donate)

    def call(pa, state, xs):
        def split(x):
            x = jnp.asarray(x)
            return x.reshape((n_shards, x.shape[0] // n_shards)
                             + x.shape[1:])

        state_s = jax.tree.map(split, state)
        xs_s = {k: (split(v) if shard_axes[k] == 0 else v)
                for k, v in xs.items()}
        out = pfn(pa, state_s, xs_s)
        return jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), out)

    return call


def sharded_grid_fn(run, *, pa_axes, xs_axes, cfg_xs_axes, seed_axes,
                    n_shards: int):
    """Device-sharded twin of the doubly-vmapped ``(C, S)`` config-grid
    run (`jax_engine.get_cached_config_fn`), split over the SEED axis.

    ``run(pa, state, xs)`` is the per-seed scan. The inner function
    vmaps seeds (``xs_axes``) then configs (``pa_axes`` over the traced
    resiliency leaves, ``cfg_xs_axes`` over the per-config xs leaves);
    the outer layer splits the flat seed axis — ``state`` leaves on axis
    0, each xs leaf on ``seed_axes[k]`` (None = replicated: the tick
    times, and the per-config ckpt schedules which carry no seed axis)
    — across local devices. Each (config, seed) chain is embarrassingly
    parallel, so outputs merge back to ``(C, S, ...)`` bit-for-bit with
    the single-device grid. `pmap` on jax 0.4.x, `jax.shard_map` on
    >= 0.6. State is NOT donated: grid outputs carry an extra config
    axis, so the per-shard input buffers are never reusable."""
    inner = jax.vmap(jax.vmap(run, in_axes=(None, 0, xs_axes)),
                     in_axes=(pa_axes, None, cfg_xs_axes))
    seed_axis = dict(seed_axes)

    if shard_map_available():  # pragma: no cover - requires jax >= 0.6
        import numpy as np
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.local_devices()[:n_shards]), ("seeds",))

        def spec_of(ax):
            if ax is None:
                return P()
            return P(*((None,) * ax + ("seeds",)))

        fn = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P("seeds"),
                      {k: spec_of(a) for k, a in seed_axis.items()}),
            out_specs=P(None, "seeds"))
        return jax.jit(fn)

    pfn = jax.pmap(inner,
                   in_axes=(None, 0, {k: (None if a is None else 0)
                                      for k, a in seed_axis.items()}))

    def call(pa, state, xs):
        def split(x, axis):
            x = jnp.asarray(x)
            shp = x.shape
            x = x.reshape(shp[:axis]
                          + (n_shards, shp[axis] // n_shards)
                          + shp[axis + 1:])
            return jnp.moveaxis(x, axis, 0)

        state_s = jax.tree.map(lambda v: split(v, 0), state)
        xs_s = {k: (v if seed_axis[k] is None
                    else split(v, seed_axis[k]))
                for k, v in xs.items()}
        out = pfn(pa, state_s, xs_s)
        # (shard, C, S_local, ...) -> (C, shard*S_local, ...)
        return jax.tree.map(
            lambda x: jnp.moveaxis(x, 0, 1).reshape(
                (x.shape[1], x.shape[0] * x.shape[2]) + x.shape[3:]),
            out)

    return call


def batch_axes_for(mesh, batch: int) -> tuple[str, ...]:
    """Data-parallel mesh axes whose product divides `batch` (longest
    prefix of ("pod", "data") present in the mesh)."""
    out: tuple[str, ...] = ()
    prod = 1
    shape = dict(mesh.shape)
    for a in ("pod", "data"):
        if a in shape and batch % (prod * shape[a]) == 0:
            out += (a,)
            prod *= shape[a]
    return out


# ----------------------------------------------------------------------
# Tree materializers
# ----------------------------------------------------------------------
def _leaf_dtype(s: ParamSpec):
    return s.dtype if s.dtype is not None else _DEFAULT_DTYPE


def _init_leaf(rng: jax.Array, s: ParamSpec) -> jax.Array:
    dt = _leaf_dtype(s)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    std = s.scale if s.scale is not None else 0.02
    return (jax.random.normal(rng, s.shape, jnp.float32) * std).astype(dt)


def tree_init(rng: jax.Array, spec_tree) -> Any:
    """Materialize real arrays for every ParamSpec leaf (split rng per leaf)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_IS_SPEC)
    rngs = jax.random.split(rng, max(len(leaves), 1))
    return jax.tree.unflatten(
        treedef, [_init_leaf(r, s) for r, s in zip(rngs, leaves)])


def tree_abstract(spec_tree) -> Any:
    """ShapeDtypeStruct stand-ins (no allocation — the dry-run currency)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, _leaf_dtype(s)),
        spec_tree, is_leaf=_IS_SPEC)


def tree_pspecs(spec_tree, ctx: ShardingCtx) -> Any:
    """PartitionSpec per leaf via the ctx rules."""
    return jax.tree.map(lambda s: ctx.spec(s.axes, s.shape),
                        spec_tree, is_leaf=_IS_SPEC)


def tree_shardings(spec_tree, ctx: ShardingCtx) -> Any:
    """NamedSharding per leaf (None leaves when ctx has no mesh)."""
    if ctx.mesh is None:
        return jax.tree.map(lambda s: None, spec_tree, is_leaf=_IS_SPEC)
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, ctx.spec(s.axes, s.shape)),
        spec_tree, is_leaf=_IS_SPEC)
