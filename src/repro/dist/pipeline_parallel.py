"""GPipe-style pipeline parallelism over a 1-D "pipe" mesh axis.

Each device holds one stage's parameters; microbatches flow through the
stage ring with ``ppermute``. The schedule is the classic fill/steady/drain
loop (``n_micro + n_stages - 1`` steps); the last stage's outputs are
psum-broadcast so the result is replicated (and exactly equals running the
stages sequentially on one device).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # newer jax exports shard_map at top level
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # pragma: no cover - jax<0.6 fallback
    from jax.experimental.shard_map import shard_map as _shard_map


def pipeline_apply(mesh, block, stage_params, x, *, n_micro: int = None):
    """Run ``x`` through ``n_stages`` blocks laid out over the mesh.

    mesh: 1-axis mesh (the pipeline axis); its size = number of stages.
    block(params_s, h) -> h : one stage's computation.
    stage_params: pytree whose leaves have a leading ``n_stages`` dim.
    x: (B, ...) activations; B must be divisible by n_micro.
    """
    axis = mesh.axis_names[0]
    n_stages = dict(mesh.shape)[axis]
    if n_micro is None:
        n_micro = n_stages
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    micro = x.reshape((n_micro, mb) + x.shape[1:])
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    try:  # the replication-check kwarg was renamed check_rep → check_vma
        smap = partial(_shard_map, mesh=mesh, in_specs=(param_specs, P()),
                       out_specs=P(), check_vma=False)
        smap(lambda p, m: m)  # trigger kwarg validation eagerly
    except TypeError:
        smap = partial(_shard_map, mesh=mesh, in_specs=(param_specs, P()),
                       out_specs=P(), check_rep=False)

    @smap
    def run(params_local, micro_all):
        w = jax.tree.map(lambda p: p[0], params_local)   # this stage's slice
        idx = jax.lax.axis_index(axis)
        carry = jnp.zeros_like(micro_all[0])             # stage input buffer
        outs = jnp.zeros_like(micro_all)
        for t in range(n_micro + n_stages - 1):
            inject = micro_all[min(t, n_micro - 1)]
            feed = jnp.where(jnp.logical_and(idx == 0, t < n_micro),
                             inject, carry)
            y = block(w, feed)
            m = t - (n_stages - 1)
            if m >= 0:  # last stage emits microbatch m at step t
                outs = outs.at[m].set(
                    jnp.where(idx == n_stages - 1, y, outs[m]))
            carry = jax.lax.ppermute(y, axis, fwd)
        # replicate the last stage's outputs everywhere (out_specs = P())
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs

    return run(stage_params, micro).reshape((B,) + x.shape[1:])
