"""Distributed substrate: logical-axis sharding + pipeline parallelism."""
from repro.dist import sharding  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    DEFAULT_RULES,
    NO_SHARDING,
    ParamSpec,
    ShardingCtx,
    batch_axes_for,
    tree_abstract,
    tree_init,
    tree_pspecs,
    tree_shardings,
)
