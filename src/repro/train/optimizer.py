"""Pure-JAX optimizers: AdamW, Adafactor, SGD-momentum.

State is declared through the same ParamSpec machinery as model params, so
the dry-run gets abstract optimizer state + shardings without allocation
(``state_specs`` maps each parameter's ParamSpec to its slot ParamSpecs).

Adafactor (factored second moments) is the production choice for arctic-480b:
Adam's fp32 moments at 480B parameters exceed one pod's per-chip HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.dist.sharding import ParamSpec

_SPEC_LEAF = lambda x: isinstance(x, ParamSpec)  # noqa: E731


@dataclasses.dataclass(frozen=True)
class Optimizer:
    cfg: OptimizerConfig
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (g, state, p) -> (p, state)
    state_specs: Callable[[Any], Any]


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    if cfg.name == "sgdm":
        return _sgdm(cfg)
    raise ValueError(cfg.name)


# ----------------------------------------------------------------------
def _adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(pspecs):
        f32 = lambda s: ParamSpec(s.shape, s.axes, dtype=jnp.float32,
                                  init="zeros")
        return {"m": jax.tree.map(f32, pspecs, is_leaf=_SPEC_LEAF),
                "v": jax.tree.map(f32, pspecs, is_leaf=_SPEC_LEAF),
                "step": ParamSpec((), (), dtype=jnp.int32, init="zeros")}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * gf
            v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(cfg, init, update, state_specs)


# ----------------------------------------------------------------------
def _factored(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 2


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    eps2 = 1e-30

    def init(params):
        def slot(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(slot, params),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(pspecs):
        def slot(s: ParamSpec):
            if _factored(s.shape):
                return {"vr": ParamSpec(s.shape[:-1], s.axes[:-1],
                                        dtype=jnp.float32, init="zeros"),
                        "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                        s.axes[:-2] + s.axes[-1:],
                                        dtype=jnp.float32, init="zeros")}
            return {"v": ParamSpec(s.shape, s.axes, dtype=jnp.float32,
                                   init="zeros")}
        return {"slots": jax.tree.map(slot, pspecs, is_leaf=_SPEC_LEAF),
                "step": ParamSpec((), (), dtype=jnp.int32, init="zeros")}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        decay = 1.0 - t ** -0.8  # standard Adafactor schedule

        def upd(g, sl, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps2
            if _factored(p.shape):
                vr = decay * sl["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * sl["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = (gf
                     / jnp.sqrt(vr / jnp.maximum(denom, eps2))[..., None]
                     / jnp.sqrt(vc)[..., None, :])
                new_sl = {"vr": vr, "vc": vc}
            else:
                v = decay * sl["v"] + (1 - decay) * g2
                u = gf / jnp.sqrt(v)
                new_sl = {"v": v}
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(u * u) + eps2)
            u = u / jnp.maximum(1.0, rms)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), new_sl

        is_slot = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        out = jax.tree.map(upd, grads, state["slots"], params,
                           is_leaf=lambda x: False)
        # out mirrors params tree with (new_p, new_slot) tuples at leaves —
        # but tree.map already descended into grads/params leaves, so leaves
        # of `out` are tuples:
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"slots": new_s, "step": step}

    return Optimizer(cfg, init, update, state_specs)


# ----------------------------------------------------------------------
def _sgdm(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(pspecs):
        f32 = lambda s: ParamSpec(s.shape, s.axes, dtype=jnp.float32,
                                  init="zeros")
        return {"m": jax.tree.map(f32, pspecs, is_leaf=_SPEC_LEAF),
                "step": ParamSpec((), (), dtype=jnp.int32, init="zeros")}

    def update(grads, state, params):
        def upd(g, m, p):
            m = cfg.b1 * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - cfg.lr * m).astype(p.dtype), m
        out = jax.tree.map(upd, grads, state["m"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "step": state["step"] + 1}

    return Optimizer(cfg, init, update, state_specs)
