"""Train/serve step factories.

``make_train_step`` builds the jit-able (params, opt_state, batch) →
(params, opt_state, metrics) function with the run's remat policy, SLO-derived
MoE routing options, optional gradient-accumulation microbatching, and the
StreamShield knobs (WeakHash mode, Group-Rescale dispatch scope).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import Completeness, RunConfig
from repro.dist.sharding import ShardingCtx
from repro.models import moe as moe_lib
from repro.models.model_zoo import Model
from repro.train import optimizer as opt_lib


def expert_slot_axes(run: RunConfig) -> tuple[str, ...]:
    """Training confines the dispatch all-to-all to the ICI-contiguous
    "model" axis (Group-Rescale); serving spreads replicated experts over
    the whole pod (global EP — WeakHash replica selection)."""
    if run.shape.kind != "train" or not run.sharding.grouped_a2a:
        return ("data", "model")
    return ("model",)


def moe_opts_for(run: RunConfig) -> dict:
    """SLO → routing policy (paper Table I): γ=full keeps every token
    (rescue overflow); γ=partial may drop (WeakHash's loss-tolerant relax)."""
    opts: dict[str, Any] = {
        "mode": "weakhash" if run.model.moe.enabled else "strict",
        "rescue": run.slo.gamma == Completeness.FULL,
        "slot_axes": expert_slot_axes(run),
        "replicate": (run.shape.kind != "train" and run.model.moe.enabled
                      and moe_lib.serve_replicate(run.model)),
        "capacity_floor": run.sharding.moe_capacity_floor,
    }
    return opts


def make_train_step(model: Model, run: RunConfig, ctx: ShardingCtx,
                    moe_opts: dict | None = None) -> Callable:
    opt = opt_lib.make_optimizer(run.optimizer)
    mo = moe_opts if moe_opts is not None else moe_opts_for(run)
    remat = run.sharding.remat
    n_micro = run.sharding.microbatches

    attn_opts = ({"exact_blocks": True}
                 if run.sharding.exact_attn_blocks else {})

    def loss_fn(params, batch):
        kw = {"attn_opts": attn_opts} if attn_opts else {}
        return model.loss_fn(params, batch, ctx, remat=remat, moe_opts=mo,
                             **kw)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if n_micro <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads
        # gradient accumulation: scan over microbatches
        split = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
            batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def body(acc, mb):
            (loss, aux), g = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32) / n_micro,
                               acc, g)
            return acc, (loss, aux)

        grads, (losses, auxes) = jax.lax.scan(body, zeros, split)
        loss = losses.mean()
        aux = jax.tree.map(lambda a: a.mean(), auxes)
        return loss, aux, grads

    def train_step(params, opt_state, batch):
        loss, aux, grads = compute_grads(params, batch)
        if run.sharding.grad_reduce_bf16:
            # cast before the cross-replica reduction XLA inserts — halves
            # the dominant gradient all-reduce bytes (§Perf)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16) if g.dtype == jnp.float32
                else g, grads)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, run.optimizer.grad_clip)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   **{k: v for k, v in aux.items()}}
        return params, opt_state, metrics

    train_step.optimizer = opt  # expose for state init/specs
    return train_step


def make_prefill_step(model: Model, run: RunConfig, ctx: ShardingCtx,
                      moe_opts: dict | None = None) -> Callable:
    mo = moe_opts if moe_opts is not None else moe_opts_for(run)

    def prefill_step(params, batch):
        logits, cache, pos = model.prefill(
            params, batch, ctx, s_max=run.shape.seq_len, remat="none",
            moe_opts=mo)
        return logits, cache, pos

    return prefill_step


def make_decode_step(model: Model, run: RunConfig, ctx: ShardingCtx,
                     moe_opts: dict | None = None) -> Callable:
    mo = moe_opts if moe_opts is not None else moe_opts_for(run)

    def decode_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos, ctx,
                                          moe_opts=mo)
        return logits, cache

    return decode_step
