"""Elastic scaling + pod-level resiliency for the distributed trainer.

* ``reshard``: move (params, opt_state) onto a new mesh (grown or shrunk DP
  axis) — the mechanism behind DS2-driven elastic resizing and behind
  pod-eviction recovery (a failed pod = the surviving sub-mesh continues).
* ``LocalSGDPods``: multi-pod training where each pod steps independently and
  pods synchronize every K steps with int8-compressed deltas over the "pod"
  axis (DCN) — compute/comm overlap by construction, bounded staleness, and
  single-task recovery at pod granularity (a dead pod just misses the sync).
* int8 gradient/delta compression: symmetric per-tensor scale, error feedback
  accumulator to keep the quantization unbiased over time.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd


def reshard(tree, spec_tree, new_mesh: Mesh):
    """Place every leaf on new_mesh with its PartitionSpec (device_put moves
    data; works across shrunk/grown meshes)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.Array,)))


@dataclasses.dataclass
class ResizeReport:
    old_devices: int
    new_devices: int
    moved_bytes: int
    wall_s: float


def elastic_resize(params, opt_state, pspec_params, pspec_opt,
                   new_mesh: Mesh) -> tuple[Any, Any, ResizeReport]:
    import time
    t0 = time.perf_counter()
    old_n = len(params and jax.tree.leaves(params)[0].devices() or [1])
    params = reshard(params, pspec_params, new_mesh)
    opt_state = reshard(opt_state, pspec_opt, new_mesh)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    moved = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    return params, opt_state, ResizeReport(
        old_n, new_mesh.size, moved, time.perf_counter() - t0)


def resize_move_seconds(delta_units: float, *,
                        state_bytes_per_unit: float = 64e6,
                        bandwidth_Bps: float = 1e9,
                        overhead_s: float = 0.0) -> float:
    """Deterministic reshard move-cost model: seconds to move the state
    behind a resize of ``|delta_units|`` parallelism units.

    Mirrors `elastic_resize`'s ``moved_bytes`` accounting (bytes follow
    the resized capacity share; transfer is bandwidth-bound over DCN) as
    a pure closed form, so traced lowerings (the stream engines'
    in-trace autoscaler) can charge rescale downtime without a device
    round-trip. Consumes NO rng draws."""
    moved = abs(float(delta_units)) * float(state_bytes_per_unit)
    return float(overhead_s) + moved / max(float(bandwidth_Bps), 1e-9)


# ----------------------------------------------------------------------
# int8 compression with error feedback
# ----------------------------------------------------------------------
def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(tree, residual):
    """Quantize tree+residual; returns (q_tree, scales, new_residual)."""
    def f(x, r):
        xf = x.astype(jnp.float32) + r
        q, s = quantize_int8(xf)
        deq = dequantize_int8(q, s)
        return q, s, xf - deq

    out = jax.tree.map(f, tree, residual)
    unzip = lambda i: jax.tree.map(lambda o: o[i], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return unzip(0), unzip(1), unzip(2)


# ----------------------------------------------------------------------
@dataclasses.dataclass
class LocalSGDConfig:
    sync_every: int = 8
    compress: bool = True


class LocalSGDPods:
    """Each pod trains independently; every `sync_every` steps the pods
    average their parameter deltas (int8-compressed) across the "pod" axis.
    Pod failure between syncs loses only that pod's local progress — the
    survivors' average still advances (single-task recovery at pod scope)."""

    def __init__(self, mesh: Mesh, cfg: LocalSGDConfig | None = None):
        assert "pod" in mesh.shape, "LocalSGDPods needs a 'pod' axis"
        self.mesh = mesh
        self.cfg = cfg or LocalSGDConfig()

    def sync_fn(self, pspec_tree):
        """Build the jit-able cross-pod sync: params -> averaged params.
        Works on anchor + delta so int8 quantization error stays tiny."""
        mesh = self.mesh
        compress = self.cfg.compress

        def _strip_pod(spec, ndim):
            entries = (tuple(spec) + (None,) * ndim)[:ndim]
            out = []
            for s in entries:
                if s == "pod":
                    out.append(None)
                elif isinstance(s, tuple):
                    t = tuple(a for a in s if a != "pod")
                    out.append(t if t else None)
                else:
                    out.append(s)
            return P(*out)

        def sync(params, anchor):
            def leaf(p, a, spec):
                local_spec = _strip_pod(spec, p.ndim)

                @partial(jax.shard_map, mesh=mesh,
                         in_specs=(local_spec, local_spec),
                         out_specs=local_spec, check_vma=False)
                def avg(pl, al):
                    delta = (pl - al).astype(jnp.float32)
                    if compress:
                        q, s = quantize_int8(delta)
                        d = dequantize_int8(q, s)
                    else:
                        d = delta
                    d = jax.lax.pmean(d, "pod")
                    return (al.astype(jnp.float32) + d).astype(pl.dtype)

                return avg(p, a)

            return jax.tree.map(leaf, params, anchor, pspec_tree,
                                is_leaf=lambda x: isinstance(x, jax.Array))

        return sync
