"""Gödel-analogue scheduler front-end: rate-limited grants, chaos-driven
unavailability windows, idempotent submission with exponential backoff
(paper §IV-B: "when job submission fails due to temporary Gödel
unavailability, StreamShield automatically retries with exponential backoff
and performs job uniqueness validation")."""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.backoff import (IdempotencyRegistry, RetryPolicy,
                                TransientError, retry)
from repro.core.chaos import ChaosEngine
from repro.core.clock import VirtualClock


class SchedulerUnavailable(TransientError):
    pass


@dataclasses.dataclass
class Submission:
    job_id: str
    n_tms: int
    accepted_at: float


class GodelSim:
    """Control-plane endpoint with outage windows (chaos.zk_down reused as a
    generic unavailability schedule via `down_windows`)."""

    def __init__(self, *, clock: VirtualClock | None = None,
                 down_windows: tuple[tuple[float, float], ...] = (),
                 chaos: ChaosEngine | None = None):
        self.clock = clock or VirtualClock()
        self.down = down_windows
        self.chaos = chaos or ChaosEngine()
        self.submissions: dict[str, Submission] = {}
        self.received = 0

    def _available(self) -> bool:
        t = self.clock.now()
        return not any(a <= t < b for a, b in self.down)

    def submit(self, job_id: str, n_tms: int) -> Submission:
        self.received += 1
        if not self._available():
            raise SchedulerUnavailable(f"godel down at t={self.clock.now()}")
        if job_id in self.submissions:
            # duplicate execution would double-allocate; the scheduler is
            # idempotent on job_id
            return self.submissions[job_id]
        sub = Submission(job_id, n_tms, self.clock.now())
        self.submissions[job_id] = sub
        return sub


class ResilientSubmitter:
    """Client-side: backoff retries + uniqueness validation."""

    def __init__(self, godel: GodelSim, *,
                 policy: RetryPolicy | None = None):
        self.godel = godel
        self.policy = policy or RetryPolicy(base_delay_s=1.0, max_delay_s=60.0,
                                            max_attempts=8)
        self.registry = IdempotencyRegistry()

    def submit(self, job_spec: dict[str, Any]) -> tuple[Submission, dict]:
        token = IdempotencyRegistry.token(job_spec["job_id"],
                                          job_spec.get("version", 0))

        def attempt():
            out, stats = retry(
                lambda: self.godel.submit(job_spec["job_id"],
                                          job_spec["n_tms"]),
                self.policy, self.godel.clock)
            return out, stats

        (sub, stats), dup = self.registry.run(token, attempt)
        return sub, {"attempts": stats.attempts, "duplicate": dup,
                     "backoff_s": stats.total_delay_s}
