"""Cluster simulator: TaskManager lifecycle + startup-phase accounting.

Reproduces the paper's Table II / Fig 5 decomposition — job parsing, resource
allocation, task deployment — for the baseline and the StreamShield startup
optimizations. Mechanics:

* parsing: execution-plan construction; cost scales with edge objects; the
  object-reuse path pays a small interning overhead but touches far fewer
  objects at scale (SS parse is slightly slower at 512 TMs, ~2× faster at
  2048 — matching Fig 5).
* allocation (Gödel): rate-limited container grants + heavy-tailed container
  image downloads (I/O-saturated hosts = stragglers). The job needs ALL TMs;
  StreamShield over-provisions a bounded number of spares once allocation
  passes a threshold and releases them after the job is running.
* deployment: per-task descriptor serialization + RPC; StreamShield batches
  all descriptors per TM into one RPC and (with object reuse) sends interned
  descriptor bodies once.

Deterministic per seed (numpy Generator).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chaos import ChaosEngine
from repro.core.startup import (EdgeDescriptor, StartupConfig,
                                StragglerMitigator, intern_plan)


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    # Gödel allocation
    grant_rate_per_s: float = 9.0         # scheduler grant throughput
    image_time_median_s: float = 18.0     # container image download
    image_time_sigma: float = 0.55        # lognormal sigma
    straggler_frac: float = 0.012         # I/O-saturated hosts
    straggler_mult: float = 8.0
    register_s: float = 1.5               # TM registration after start
    # deployment
    rpc_overhead_ms: float = 6.0          # per-RPC round trip via JobManager
    serialize_per_task_ms: float = 2.6    # descriptor build+serialize
    batch_overhead_ms: float = 9.0        # batched-RPC assembly per TM
    interned_serialize_factor: float = 0.35
    # parsing
    parse_base_ms: float = 120.0
    parse_per_edge_us: float = 170.0
    intern_per_edge_us: float = 95.0
    parse_intern_base_ms: float = 330.0   # hash tables etc. (hurts small jobs)


@dataclasses.dataclass
class StartupPhases:
    parse_ms: float
    alloc_ms: float
    deploy_ms: float
    extra_tms: int = 0
    released_tms: int = 0

    @property
    def total_ms(self) -> float:
        return self.parse_ms + self.alloc_ms + self.deploy_ms


class ClusterSim:
    def __init__(self, n_tms: int, *, slots_per_tm: int = 2,
                 params: ClusterParams | None = None, seed: int = 0,
                 chaos: ChaosEngine | None = None):
        self.n = n_tms
        self.slots_per_tm = slots_per_tm
        self.p = params or ClusterParams()
        self.rng = np.random.default_rng(seed)
        self.chaos = chaos or ChaosEngine()

    # -- phase 1: job parsing ------------------------------------------------
    def parse(self, edges: list[EdgeDescriptor],
              cfg: StartupConfig) -> float:
        p = self.p
        n = len(edges)
        if not cfg.object_reuse:
            return p.parse_base_ms + n * p.parse_per_edge_us / 1000.0
        plan = intern_plan(edges)
        return (p.parse_intern_base_ms
                + plan.n_unique * p.parse_per_edge_us / 1000.0
                + n * p.intern_per_edge_us / 1000.0 * 0.3)

    # -- phase 2: resource allocation -----------------------------------------
    def _tm_ready_times(self, n: int, offset_rank: int = 0) -> np.ndarray:
        p = self.p
        grant = (offset_rank + np.arange(n)) / p.grant_rate_per_s
        mu = np.log(p.image_time_median_s)
        img = self.rng.lognormal(mu, p.image_time_sigma, size=n)
        stragglers = self.rng.random(n) < p.straggler_frac
        img = np.where(stragglers, img * p.straggler_mult, img)
        return grant + img + p.register_s

    def allocate(self, cfg: StartupConfig) -> tuple[float, int, int]:
        """Returns (alloc_seconds, extra_requested, released)."""
        ready = np.sort(self._tm_ready_times(self.n))
        if not cfg.straggler_mitigation:
            return float(ready[-1]), 0, 0
        # at the threshold, count TMs still missing and over-provision
        thr = cfg.alloc_threshold_s
        missing = int((ready > thr).sum())
        mit = StragglerMitigator(cfg)
        extra = mit.extra_tms(missing)
        if extra == 0:
            return float(ready[-1]), 0, 0
        spare_ready = self._tm_ready_times(extra, offset_rank=self.n) + thr
        pool = np.sort(np.concatenate([ready, spare_ready]))
        # the job starts once n slots are filled by ANY ready TM
        alloc_end = float(pool[self.n - 1])
        released = extra  # spares released once running (paper)
        return alloc_end, extra, released

    # -- phase 3: task deployment ---------------------------------------------
    def deploy(self, n_tasks: int, cfg: StartupConfig,
               dedup_ratio: float = 0.12) -> float:
        p = self.p
        ser = p.serialize_per_task_ms
        if cfg.batched_deploy:
            ser_eff = ser * (p.interned_serialize_factor if cfg.object_reuse
                             else 0.75)  # batching amortizes headers alone
            return (self.n * (p.rpc_overhead_ms + p.batch_overhead_ms)
                    + n_tasks * ser_eff)
        return n_tasks * (ser + p.rpc_overhead_ms)

    # -- full startup ---------------------------------------------------------
    def startup(self, edges: list[EdgeDescriptor], cfg: StartupConfig,
                n_tasks: int | None = None) -> StartupPhases:
        n_tasks = n_tasks or self.n * self.slots_per_tm
        parse_ms = self.parse(edges, cfg)
        alloc_s, extra, released = self.allocate(cfg)
        deploy_ms = self.deploy(n_tasks, cfg)
        if cfg.hotupdate:
            # slots reused from the previous job: no allocation at all
            alloc_s, extra, released = 0.0, 0, 0
        return StartupPhases(parse_ms, alloc_s * 1000.0, deploy_ms,
                             extra, released)


def nexmark_edges(n_tasks_per_op: int, n_ops: int = 3) -> list[EdgeDescriptor]:
    """Physical-plan edges of a Nexmark-style chain (one edge object per task
    pair on all-to-all hops, per task on forward hops). The per-hop edge
    descriptors are structurally identical, so build one and replicate —
    an all-to-all hop at n=2048 is 4.2M descriptors; constructing them
    one-by-one dominated large startup benches."""
    edges: list[EdgeDescriptor] = []
    for i in range(n_ops - 1):
        part = "hash" if i % 2 else "forward"
        count = (n_tasks_per_op if part == "forward"
                 else n_tasks_per_op * n_tasks_per_op)
        proto = EdgeDescriptor(f"op{i}", f"op{i+1}", part,
                               ("bid", "price", "ts"))
        edges.extend([proto] * count)
    return edges
