"""JobManager-analogue coordinator: leader election with the HA fallback
chain (ZK → HDFS copy → terminate), job lifecycle, and startup orchestration
gluing the scheduler + cluster sim + startup policies together."""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.cluster.scheduler import GodelSim, ResilientSubmitter
from repro.cluster.simulator import ClusterSim, StartupPhases
from repro.core.backoff import PermanentError
from repro.core.chaos import ChaosEngine
from repro.core.clock import VirtualClock
from repro.core.ha import JobTerminated, LeaderService, ZooKeeperSim
from repro.core.startup import StartupConfig


@dataclasses.dataclass
class JobRecord:
    job_id: str
    phases: StartupPhases
    submission_info: dict
    leader: str


class Coordinator:
    def __init__(self, *, clock: VirtualClock | None = None,
                 chaos: ChaosEngine | None = None, hdfs_store=None,
                 godel: GodelSim | None = None):
        self.clock = clock or VirtualClock()
        self.chaos = chaos or ChaosEngine()
        self.zk = ZooKeeperSim(clock=self.clock, chaos=self.chaos)
        self.hdfs = hdfs_store
        self.leader_svc = (LeaderService(self.zk, hdfs_store,
                                         clock=self.clock)
                           if hdfs_store is not None else None)
        self.godel = godel or GodelSim(clock=self.clock, chaos=self.chaos)
        self.submitter = ResilientSubmitter(self.godel)
        self.jobs: dict[str, JobRecord] = {}

    def become_leader(self, candidate: str = "jm-0"):
        if self.leader_svc is None:
            return None
        return self.leader_svc.elect(candidate)

    def current_leader(self) -> str:
        if self.leader_svc is None:
            return "jm-0"
        return self.leader_svc.get_leader().leader_id  # may raise JobTerminated

    def launch(self, job_id: str, *, n_tms: int, edges, cfg: StartupConfig,
               sim: ClusterSim | None = None,
               n_tasks: int | None = None) -> JobRecord:
        sub, info = self.submitter.submit({"job_id": job_id, "n_tms": n_tms})
        sim = sim or ClusterSim(n_tms, chaos=self.chaos)
        phases = sim.startup(edges, cfg, n_tasks=n_tasks)
        leader = self.current_leader() if self.leader_svc else "jm-0"
        rec = JobRecord(job_id, phases, info, leader)
        self.jobs[job_id] = rec
        self.clock.sleep(phases.total_ms / 1000.0)
        return rec
