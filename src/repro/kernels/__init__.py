"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit-friendly wrapper that dispatches pallas / interpret / ref
  ref.py    — pure-jnp oracle (also the non-TPU lowering path)
"""
