"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) chunked scan.

Follows Dao & Gu, arXiv:2405.21060 §6: the sequence is split into chunks; the
intra-chunk recurrence is computed as decay-masked matmuls (MXU friendly); a
sequential ``lax.scan`` over chunks carries the SSM state, so the largest
intermediate is one (Q × Q × H) tile per chunk — memory-bounded at 32k/500k.

Layouts (single B/C group, broadcast over heads — the Mamba-2 default):
  x  (B, S, H, P)   inputs per head (P = head_dim)
  dt (B, S, H)      positive step sizes (already softplus'ed + bias)
  A  (H,)           negative scalars (per head)
  Bm (B, S, N)      input projection  (N = d_state)
  Cm (B, S, N)      output projection
  D  (H,)           skip
Returns y (B, S, H, P), final_state (B, H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan(x, dt, A, Bm, Cm, D=None, *, chunk: int = 256,
             initial_state=None):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, chunk)
    nc = S // Q

    f32 = jnp.float32
    # chunk-major so scan can slice per chunk: (nc, B, Q, ...)
    xc = x.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, nc, Q, H).transpose(1, 0, 2, 3).astype(f32)
    Bc = Bm.reshape(B, nc, Q, N).transpose(1, 0, 2, 3).astype(f32)
    Cc = Cm.reshape(B, nc, Q, N).transpose(1, 0, 2, 3).astype(f32)

    mask = jnp.tril(jnp.ones((Q, Q), bool))
    init = (jnp.zeros((B, H, P, N), f32) if initial_state is None
            else initial_state.astype(f32))

    def chunk_step(state, inp):
        xq, dtq, Bq, Cq = inp                     # (B,Q,H,P) (B,Q,H) (B,Q,N)
        dA = dtq * A.astype(f32)[None, None, :]   # (B,Q,H), ≤ 0
        s = jnp.cumsum(dA, axis=1)                # inclusive log-decay
        total = s[:, -1]                          # (B,H)

        # intra-chunk: y_q += Σ_{j≤q} (C_q·B_j) exp(s_q - s_j) dt_j x_j
        rel = s[:, :, None, :] - s[:, None, :, :]             # (B,Q,Q,H)
        L = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
        cb = jnp.einsum("bqn,bkn->bqk", Cq, Bq)               # (B,Q,Q)
        w = cb[..., None] * L * dtq[:, None, :, :]            # (B,Q,Q,H)
        y = jnp.einsum("bqkh,bkhp->bqhp", w, xq.astype(f32))

        # inter-chunk: previous state decayed into each position
        decay_in = jnp.exp(s)                                 # (B,Q,H)
        y = y + jnp.einsum("bqn,bhpn->bqhp", Cq, state) * decay_in[..., None]

        # state update: state' = state·exp(total) + Σ_j dt_j x_j B_j exp(total - s_j)
        decay_out = jnp.exp(total[:, None, :] - s)            # (B,Q,H)
        xw = xq.astype(f32) * (dtq * decay_out)[..., None]
        new_state = (state * jnp.exp(total)[:, :, None, None]
                     + jnp.einsum("bqhp,bqn->bhpn", xw, Bq))
        return new_state, y

    last, ys = jax.lax.scan(chunk_step, init, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    if D is not None:
        y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), last


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t, D=None):
    """One-token recurrent update.

    state (B,H,P,N); x_t (B,H,P); dt_t (B,H); B_t/C_t (B,N).
    Returns (y_t (B,H,P), new_state)."""
    f32 = jnp.float32
    dA = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])        # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn",
                     x_t.astype(f32) * dt_t.astype(f32)[..., None],
                     B_t.astype(f32))
    new_state = state.astype(f32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(f32))
    if D is not None:
        y = y + x_t.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x_t.dtype), new_state


def ssd_scan_naive(x, dt, A, Bm, Cm, D=None, *, initial_state=None):
    """O(S) sequential reference (ground truth for the chunked form)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    state = (jnp.zeros((B, H, P, N), f32) if initial_state is None
             else initial_state.astype(f32))

    def step(carry, t):
        y_t, new = ssd_decode_step(carry, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        return new, y_t

    state, ys = jax.lax.scan(step, state, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state
