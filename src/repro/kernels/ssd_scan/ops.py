"""jit-level wrapper for the Mamba-2 SSD scan with impl dispatch."""
from __future__ import annotations

from repro.kernels.common import resolve_impl
from repro.kernels.ssd_scan import ref

ssd_decode_step = ref.ssd_decode_step
ssd_scan_naive = ref.ssd_scan_naive


def ssd_scan(x, dt, A, Bm, Cm, D=None, *, chunk: int = 256,
             initial_state=None, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref" or initial_state is not None:
        return ref.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                            initial_state=initial_state)
    return _ssd_kernel_vjp(x, dt, A, Bm, Cm, D, chunk, impl == "interpret")


import functools as _ft  # noqa: E402
import jax as _jax  # noqa: E402


@_ft.partial(_jax.custom_vjp, nondiff_argnums=(6, 7))
def _ssd_kernel_vjp(x, dt, A, Bm, Cm, D, chunk, interpret):
    """Kernel forward; backward recomputes through the jnp oracle (the
    chunked SSD fwd is cheap relative to the surrounding projections)."""
    from repro.kernels.ssd_scan import kernel
    return kernel.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                           interpret=interpret)


def _ssd_fwd(x, dt, A, Bm, Cm, D, chunk, interpret):
    out = _ssd_kernel_vjp(x, dt, A, Bm, Cm, D, chunk, interpret)
    return out, (x, dt, A, Bm, Cm, D)


def _ssd_bwd(chunk, interpret, res, g):
    x, dt, A, Bm, Cm, D = res
    _, vjp = _jax.vjp(
        lambda *a: ref.ssd_scan(*a, chunk=chunk), x, dt, A, Bm, Cm, D)
    return vjp(g)


_ssd_kernel_vjp.defvjp(_ssd_fwd, _ssd_bwd)
