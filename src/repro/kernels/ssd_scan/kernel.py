"""Pallas TPU Mamba-2 SSD chunked scan.

Grid (B, n_head_blocks, nc) — the chunk axis is last (sequential), so the
inter-chunk SSM state lives in a (hb, P, N) fp32 VMEM scratch that carries
across chunks; intra-chunk work is decay-masked batched matmuls on the MXU.
Forward only: the backward pass recomputes through the jnp oracle
(ops.ssd_scan wraps this kernel in a custom_vjp whose bwd is the ref vjp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import pltpu_scratch

DEFAULT_HEAD_BLOCK = 8


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, st_ref,
                state_scr, *, Q, nc, use_D):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (hb, Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (hb, Q)
    A = A_ref[...].astype(jnp.float32)        # (hb,)
    Bm = B_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)         # (Q, N)

    dA = dt * A[:, None]                       # (hb, Q) ≤ 0
    s = jnp.cumsum(dA, axis=1)
    total = s[:, -1]                           # (hb,)

    rel = s[:, :, None] - s[:, None, :]        # (hb, Q, Q)
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(mask[None], jnp.exp(rel), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb[None] * L * dt[:, None, :]          # (hb, Q, Q)
    y = jax.lax.dot_general(w, x, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)   # (hb,Q,P)

    state = state_scr[...]                     # (hb, P, N)
    # inter-chunk: y += exp(s) * C · state
    y_in = jax.lax.dot_general(state, Cm, (((2,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    # y_in: (hb, P, Q) → (hb, Q, P)
    y = y + jnp.transpose(y_in, (0, 2, 1)) * jnp.exp(s)[:, :, None]

    if use_D:
        y = y + x * D_ref[...].astype(jnp.float32)[:, None, None]

    # state update
    decay_out = jnp.exp(total[:, None] - s)    # (hb, Q)
    xw = x * (dt * decay_out)[:, :, None]      # (hb, Q, P)
    upd = jax.lax.dot_general(xw, Bm, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    # upd: (hb, P, N)
    state_scr[...] = state * jnp.exp(total)[:, None, None] + upd

    y_ref[0] = y.astype(y_ref.dtype)
    st_ref[0] = state_scr[...]


def ssd_scan(x, dt, A, Bm, Cm, D=None, *, chunk: int = 256,
             initial_state=None, head_block: int = DEFAULT_HEAD_BLOCK,
             interpret: bool = False):
    assert initial_state is None, \
        "kernel path starts from zero state (prefill); decode uses " \
        "ssd_decode_step"
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    hb = min(head_block, H)
    assert H % hb == 0
    nh = H // hb

    xt = x.transpose(0, 2, 1, 3)              # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)               # (B, H, S)

    kernel = functools.partial(_ssd_kernel, Q=Q, nc=nc, use_D=D is not None)
    if D is None:
        D = jnp.zeros((H,), jnp.float32)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, hb, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hb, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((hb,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((hb,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, hb, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hb, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu_scratch((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), Bm, Cm, jnp.asarray(D, jnp.float32))
    return y.transpose(0, 2, 1, 3), st
