"""Pure-jnp oracle for single-token decode attention (flash-decode style).

Also provides the partial-softmax (m, l, o) form used by the distributed-LSE
merge across a KV-sequence-sharded cache (dist/collectives.py) — the TPU-native
adaptation for archs whose kv_heads do not divide the model axis (kv ∈ {1, 8}).

Layouts: q (B, 1, H, D); cache k/v (B, S, KV, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention(q, k, v, *, kv_valid_len=None, window: int = 0,
                     pos=None, scale: float | None = None):
    """Direct decode attention. pos: absolute position of the query token
    (required when window > 0 with a ring cache it is not needed — the ring
    already bounds the cache — pass None)."""
    out, _, _ = decode_attention_partial(
        q, k, v, kv_valid_len=kv_valid_len, window=window, pos=pos, scale=scale)
    return out


def decode_attention_partial(q, k, v, *, kv_valid_len=None, window: int = 0,
                             pos=None, k_offset: jax.Array | int = 0,
                             scale: float | None = None):
    """Returns (o, m, l): un-normalized-by-global output with local max m and
    local sum l, suitable for cross-shard merge. o (B,H,D), m/l (B,H)."""
    B, Sq, H, D = q.shape
    assert Sq == 1, "decode step takes exactly one new token"
    _, S, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KV, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    k_pos = k_offset + jnp.arange(S)
    mask = jnp.ones((S,), bool)
    if kv_valid_len is not None:
        mask = k_pos < jnp.asarray(kv_valid_len)
    if window and pos is not None:
        mask &= k_pos > jnp.asarray(pos) - window
    scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)                      # (B,KV,G)
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(jnp.isfinite(m)[..., None], e, 0.0)  # all-masked shard
    l = jnp.sum(e, axis=-1)                           # (B,KV,G)
    o = jnp.einsum("bkgs,bskd->bkgd", e.astype(v.dtype), v)
    safe_l = jnp.where(l > 0, l, 1.0)
    o = (o / safe_l[..., None].astype(o.dtype)).reshape(B, H, D)
    m = jnp.where(jnp.isfinite(m), m, -jnp.inf)
    return o, m.reshape(B, H), l.reshape(B, H)


def merge_partials(os, ms, ls):
    """Merge per-shard partials along a leading shard axis.

    os (N,B,H,D) locally-normalized outputs; ms/ls (N,B,H)."""
    m_star = jnp.max(ms, axis=0)                       # (B,H)
    w = jnp.exp(ms - m_star[None]) * ls                # un-normalize weights
    denom = jnp.sum(w, axis=0)
    denom = jnp.where(denom > 0, denom, 1.0)
    w = (w / denom[None]).astype(os.dtype)
    return jnp.sum(os * w[..., None], axis=0)          # (B,H,D)
