"""jit-level wrapper for decode attention with impl dispatch."""
from __future__ import annotations

from repro.kernels.common import resolve_impl
from repro.kernels.decode_attention import ref

merge_partials = ref.merge_partials


def decode_attention(q, k, v, *, kv_valid_len=None, window: int = 0,
                     pos=None, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.decode_attention(q, k, v, kv_valid_len=kv_valid_len,
                                    window=window, pos=pos)
    from repro.kernels.decode_attention import kernel
    return kernel.decode_attention(q, k, v, kv_valid_len=kv_valid_len,
                                   window=window, pos=pos,
                                   interpret=(impl == "interpret"))


def decode_attention_partial(q, k, v, *, kv_valid_len=None, window: int = 0,
                             pos=None, k_offset=0, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.decode_attention_partial(
            q, k, v, kv_valid_len=kv_valid_len, window=window, pos=pos,
            k_offset=k_offset)
    from repro.kernels.decode_attention import kernel
    return kernel.decode_attention_partial(
        q, k, v, kv_valid_len=kv_valid_len, window=window, pos=pos,
        k_offset=k_offset, interpret=(impl == "interpret"))
