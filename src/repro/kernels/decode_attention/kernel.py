"""Pallas TPU decode attention (flash-decode): one query token against a long
KV cache, split over KV blocks with running (m, l, acc) merge in VMEM scratch.

Grid (B, KV, nk) — nk sequential. Also exposes the locally-normalized partial
form (o, m, l) consumed by the cross-chip distributed-LSE merge
(dist: KV-sequence-sharded caches for kv_heads ∈ {1, 8} archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import pltpu_scratch

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   m_scr, l_scr, acc_scr, *, scale, block_k, nk,
                   window, k_offset_static):
    kb = pl.program_id(2)
    k0 = kb * block_k

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[0]
    off = valid_ref[2]  # shard offset (traced: rank * S_local)

    @pl.when(k0 + off < valid)
    def _compute():
        q = q_ref[0, 0]                                  # (G, D)
        k = k_ref[0, 0]                                  # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = (off + k0
               + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1))
        mask = pos < valid
        if window:
            lo = valid_ref[1]                            # query abs position
            mask = jnp.logical_and(mask, pos > lo - window)
        s = jnp.where(mask, s, NEG_INF)                  # (G, bk)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _fin():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l


def _run(q, k, v, valid_len, *, window, pos, k_offset, block_k, interpret):
    B, Sq, H, D = q.shape
    assert Sq == 1
    _, S, KV, _ = k.shape
    G = H // KV
    block_k = min(block_k, S)
    nk = pl.cdiv(S, block_k)
    scale = D ** -0.5

    qg = q.reshape(B, KV, G, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if valid_len is None:
        valid_len = S + (k_offset if isinstance(k_offset, int) else 0)
    scalars = jnp.stack([jnp.asarray(valid_len, jnp.int32),
                         jnp.asarray(pos if pos is not None else 0,
                                     jnp.int32),
                         jnp.asarray(k_offset, jnp.int32)])

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, nk=nk, window=window,
        k_offset_static=0)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((3,), lambda b, h, j: (0,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu_scratch((G,), jnp.float32),
            pltpu_scratch((G,), jnp.float32),
            pltpu_scratch((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, qg, kt, vt)
    return (o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def decode_attention(q, k, v, *, kv_valid_len=None, window=0, pos=None,
                     block_k=DEFAULT_BLOCK_K, interpret=False):
    o, _, _ = _run(q, k, v, kv_valid_len, window=window, pos=pos,
                   k_offset=0, block_k=block_k, interpret=interpret)
    return o  # (B,H,D)


def decode_attention_partial(q, k, v, *, kv_valid_len=None, window=0,
                             pos=None, k_offset=0,
                             block_k=DEFAULT_BLOCK_K, interpret=False):
    return _run(q, k, v, kv_valid_len, window=window, pos=pos,
                k_offset=k_offset, block_k=block_k, interpret=interpret)
