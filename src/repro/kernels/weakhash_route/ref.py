"""Pure-jnp oracle for WeakHash MoE routing (the paper's §III-A technique).

StreamShield's WeakHash "relaxes the strict key-to-task binding by mapping each
key to a bounded set of candidate tasks and dynamically selecting the execution
task". The MoE adaptation:

* strict mode (Flink's hash partitioning / vanilla top-k): each token's experts
  are the global top-k of the router — a hot expert saturates its capacity and
  overflow tokens are dropped (or, in γ=full mode, rescued by a second pass).
* weakhash mode: experts are partitioned into ``n_groups`` disjoint groups
  (aligned with device groups — Group-Rescale). A token's candidate set is one
  group; within it, selection is *load-aware*: router scores are penalized by
  the group-local demand estimate, diffusing hot keys across the group.

All outputs are deterministic functions of (logits, prior loads) so the Pallas
kernel and this oracle agree exactly. The kernel is a FUSED single pass
(demand histogram + select in one launch; kernel.py) — its global-demand
semantics are pinned to this oracle by tests/test_kernels.py and
tests/test_engine_vectorized.py across tile counts.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RouteResult:
    expert_idx: jax.Array   # (T, k) int32, chosen experts
    weights: jax.Array      # (T, k) f32, combine weights (renormalized)
    position: jax.Array     # (T, k) int32, slot within expert buffer
    keep: jax.Array         # (T, k) bool, False = dropped by capacity
    group_id: jax.Array     # (T,)  int32, candidate group per token
    demand: jax.Array       # (E,)  f32, pre-capacity expert demand
    aux_loss: jax.Array     # scalar, switch-style load-balance loss


def positions_in_bucket(ids: jax.Array, n_buckets: int) -> jax.Array:
    """Arrival-order slot of each id within its bucket. ids (...,) → (...,)."""
    flat = ids.reshape(-1)
    onehot = jax.nn.one_hot(flat, n_buckets, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    return pos.reshape(ids.shape)


def _positions_in_expert(expert_idx: jax.Array, n_experts: int) -> jax.Array:
    """Arrival-order slot of each (token, k) assignment within its expert.

    expert_idx (T, k) → positions (T, k). Token-major arrival order (matches
    the kernel's sequential tile walk)."""
    T, k = expert_idx.shape
    flat = expert_idx.reshape(-1)                       # (T*k,) token-major
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                # exclusive prefix
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    return pos.reshape(T, k)


def weakhash_route(
    logits: jax.Array,                  # (T, E) router logits (f32)
    *,
    top_k: int,
    capacity: int,
    n_groups: int = 1,
    mode: Literal["strict", "weakhash"] = "weakhash",
    token_keys: jax.Array | None = None,  # (T,) int32 keys (e.g. token ids)
    prior_load: jax.Array | None = None,  # (E,) f32 running load estimate
    load_penalty: float = 1.0,
    rescue: bool = False,               # γ=full: re-route capacity overflow
) -> RouteResult:
    T, E = logits.shape
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    if mode == "strict" or n_groups <= 1:
        group_id = jnp.zeros((T,), jnp.int32)
        masked = logits
    else:
        assert E % n_groups == 0, (E, n_groups)
        gsz = E // n_groups
        if token_keys is not None:
            # WeakHash: bounded candidate set from a cheap key hash
            # (Knuth multiplicative; deterministic across hosts).
            hashed = token_keys.astype(jnp.uint32) * jnp.uint32(2654435761)
            group_id = (hashed % jnp.uint32(n_groups)).astype(jnp.int32)
        else:
            # router-preferred group: argmax of group-pooled scores
            pooled = probs.reshape(T, n_groups, gsz).sum(-1)
            group_id = jnp.argmax(pooled, axis=-1).astype(jnp.int32)
        expert_group = jnp.arange(E, dtype=jnp.int32) // gsz
        in_group = expert_group[None, :] == group_id[:, None]
        masked = jnp.where(in_group, logits, -jnp.inf)

    scores = masked
    if mode == "weakhash":
        # load-aware dispatch: penalize in-proportion to demand estimate.
        demand0 = jax.nn.one_hot(jnp.argmax(masked, -1), E, dtype=jnp.float32).sum(0)
        load = demand0 if prior_load is None else prior_load + demand0
        scores = masked - load_penalty * (load[None, :] / float(max(capacity, 1)))

    _, expert_idx = jax.lax.top_k(scores, top_k)
    expert_idx = expert_idx.astype(jnp.int32)
    gates = jnp.take_along_axis(probs, expert_idx, axis=1)
    weights = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    position = _positions_in_expert(expert_idx, E)
    keep = position < capacity

    if rescue:
        # γ=full second pass: overflowed assignments are re-routed to the
        # least-demanded expert in the candidate set that still has room.
        demand = jax.nn.one_hot(expert_idx.reshape(-1), E,
                                dtype=jnp.float32).sum(0)
        spare = jnp.maximum(capacity - demand, 0.0)
        fallback = jnp.argmax(
            jnp.where(jnp.isfinite(masked), spare[None, :], -1.0), axis=-1)
        fb = jnp.broadcast_to(fallback[:, None], expert_idx.shape)
        expert_idx = jnp.where(keep, expert_idx, fb.astype(jnp.int32))
        position = _positions_in_expert(expert_idx, E)
        keep = position < capacity

    demand = jax.nn.one_hot(expert_idx.reshape(-1), E, dtype=jnp.float32).sum(0)

    # switch-style aux loss on the *unmasked* router distribution
    me = probs.mean(0)                                   # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(logits, -1), E, dtype=jnp.float32).mean(0)
    aux = E * jnp.sum(me * top1)

    return RouteResult(expert_idx=expert_idx, weights=weights,
                       position=position, keep=keep, group_id=group_id,
                       demand=demand, aux_loss=aux)


def dispatch(x: jax.Array, route: RouteResult, n_experts: int,
             capacity: int) -> jax.Array:
    """Scatter tokens into (E, C, d) expert buffers (dropped → zero rows)."""
    T, d = x.shape
    k = route.expert_idx.shape[1]
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    e = route.expert_idx.reshape(-1)
    p = jnp.clip(route.position.reshape(-1), 0, capacity - 1)
    keep = route.keep.reshape(-1)
    src = jnp.repeat(x, k, axis=0) * keep[:, None].astype(x.dtype)
    # dropped tokens scatter to slot 0 with zero payload; mode="drop" guards OOB
    return buf.at[e, p].add(src, mode="drop")


def combine(expert_out: jax.Array, route: RouteResult, T: int) -> jax.Array:
    """Gather expert outputs back per token, weighted. expert_out (E,C,d)."""
    k = route.expert_idx.shape[1]
    e = route.expert_idx.reshape(-1)
    p = jnp.clip(route.position.reshape(-1), 0, expert_out.shape[1] - 1)
    rows = expert_out[e, p]                                # (T*k, d)
    w = (route.weights * route.keep).reshape(-1, 1).astype(expert_out.dtype)
    return (rows * w).reshape(T, k, -1).sum(axis=1)
