"""jit-level wrapper for WeakHash routing with impl dispatch.

impl="ref" runs the jnp oracle; otherwise the fused single-pass Pallas
kernel (kernel.py: demand + select share one launch and one (E,) VMEM
scratch; interpret mode when impl="interpret").
"""
from __future__ import annotations

from repro.kernels.common import resolve_impl
from repro.kernels.weakhash_route import ref

RouteResult = ref.RouteResult
dispatch = ref.dispatch
combine = ref.combine


def weakhash_route(logits, *, top_k, capacity, n_groups=1, mode="weakhash",
                   token_keys=None, prior_load=None, load_penalty=1.0,
                   rescue=False, carry_forward=False,
                   impl: str | None = None):
    impl = resolve_impl(impl)
    if impl == "ref":
        # the oracle's prior_load term IS the carry-forward load signal
        # (prior + current-batch demand0), so ref serves both modes
        return ref.weakhash_route(
            logits, top_k=top_k, capacity=capacity, n_groups=n_groups,
            mode=mode, token_keys=token_keys, prior_load=prior_load,
            load_penalty=load_penalty, rescue=rescue)
    from repro.kernels.weakhash_route import kernel
    return kernel.weakhash_route(
        logits, top_k=top_k, capacity=capacity, n_groups=n_groups, mode=mode,
        token_keys=token_keys, prior_load=prior_load,
        load_penalty=load_penalty, rescue=rescue,
        carry_forward=carry_forward,
        interpret=(impl == "interpret"))
