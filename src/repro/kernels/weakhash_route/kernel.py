"""Pallas TPU WeakHash routing kernels (integer outputs; differentiable
combine weights are reconstructed outside from the router probabilities).

Two phases, both gridded over token tiles:
  1. demand: group-masked argmax histogram over all tokens (sequential
     accumulation into an (E,) scratch — the load estimate).
  2. select: demand-penalized scores → iterative top-k → arrival-order
     slot positions via an (E,) running-count scratch that carries across
     the sequential token-tile grid (matching the oracle's token-major
     cumsum exactly).

VPU-only (no MXU); token tiles are 8×128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import pltpu_scratch

NEG_INF = -1e30
DEFAULT_BLOCK_T = 256
KNUTH = 2654435761


def _group_mask(keys, n_groups, E, gsz):
    """(bt, E) bool mask of each token's candidate group."""
    hashed = keys.astype(jnp.uint32) * jnp.uint32(KNUTH)
    gid = (hashed % jnp.uint32(n_groups)).astype(jnp.int32)     # (bt,)
    eg = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], E), 1) // gsz
    return eg == gid[:, None], gid


def _demand_kernel(logits_ref, keys_ref, dem_ref, dem_scr, *,
                   n_groups, E, gsz, nt, use_groups):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        dem_scr[...] = jnp.zeros_like(dem_scr)

    logits = logits_ref[...]
    if use_groups:
        mask, _ = _group_mask(keys_ref[...], n_groups, E, gsz)
        logits = jnp.where(mask, logits, NEG_INF)
    top1 = jnp.argmax(logits, axis=-1)                          # (bt,)
    onehot = (top1[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1))
    dem_scr[...] += jnp.sum(onehot.astype(jnp.float32), axis=0)

    @pl.when(t == nt - 1)
    def _fin():
        dem_ref[...] = dem_scr[...]


def _select_kernel(logits_ref, keys_ref, dem_ref, idx_ref, pos_ref, gid_ref,
                   count_scr, *, top_k, capacity, n_groups, E, gsz,
                   load_penalty, mode):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        count_scr[...] = jnp.zeros_like(count_scr)

    logits = logits_ref[...].astype(jnp.float32)                # (bt, E)
    bt = logits.shape[0]
    if mode == "weakhash":
        mask, gid = _group_mask(keys_ref[...], n_groups, E, gsz)
        masked = jnp.where(mask, logits, NEG_INF)
        scores = masked - load_penalty * (dem_ref[...][None, :]
                                          / float(max(capacity, 1)))
    else:
        masked = logits
        scores = logits
        gid = jnp.zeros((bt,), jnp.int32)
    gid_ref[...] = gid

    counts = count_scr[...]                                     # (E,) f32
    eye = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    sel = scores
    for j in range(top_k):
        e_j = jnp.argmax(sel, axis=-1).astype(jnp.int32)        # (bt,)
        onehot = (eye == e_j[:, None]).astype(jnp.float32)
        # arrival positions: running count + exclusive prefix within tile
        prefix = jnp.cumsum(onehot, axis=0) - onehot
        pos_j = jnp.sum((counts[None, :] + prefix) * onehot, axis=-1)
        idx_ref[:, j] = e_j
        pos_ref[:, j] = pos_j.astype(jnp.int32)
        counts = counts + jnp.sum(onehot, axis=0)
        sel = jnp.where(eye == e_j[:, None], NEG_INF, sel)
    count_scr[...] = counts


def weakhash_route_ints(logits, *, top_k, capacity, n_groups=1,
                        mode="weakhash", token_keys=None, load_penalty=1.0,
                        block_t=DEFAULT_BLOCK_T, interpret=False):
    """Integer routing outputs: (expert_idx, position, group_id, demand).

    NOTE: the oracle's per-(token,k)-flattened arrival order is token-major
    with all k selections of token t adjacent; this kernel assigns positions
    per selection column j across the tile instead. Both are valid
    arrival orders; for exact oracle parity the wrapper recomputes positions
    when cross-validating — see ops.weakhash_route.
    """
    T, E = logits.shape
    bt = min(block_t, T)
    assert T % bt == 0
    nt = T // bt
    gsz = E // max(n_groups, 1)
    keys = (token_keys if token_keys is not None
            else jnp.zeros((T,), jnp.int32))
    use_groups = mode == "weakhash" and n_groups > 1

    demand = pl.pallas_call(
        functools.partial(_demand_kernel, n_groups=n_groups, E=E, gsz=gsz,
                          nt=nt, use_groups=use_groups),
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0)),
                  pl.BlockSpec((bt,), lambda t: (t,))],
        out_specs=pl.BlockSpec((E,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((E,), jnp.float32),
        scratch_shapes=[pltpu_scratch((E,), jnp.float32)],
        interpret=interpret,
    )(logits.astype(jnp.float32), keys.astype(jnp.int32))

    idx, pos, gid = pl.pallas_call(
        functools.partial(_select_kernel, top_k=top_k, capacity=capacity,
                          n_groups=n_groups, E=E, gsz=gsz,
                          load_penalty=load_penalty, mode=mode),
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0)),
                  pl.BlockSpec((bt,), lambda t: (t,)),
                  pl.BlockSpec((E,), lambda t: (0,))],
        out_specs=[pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
                   pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
                   pl.BlockSpec((bt,), lambda t: (t,))],
        out_shape=[jax.ShapeDtypeStruct((T, top_k), jnp.int32),
                   jax.ShapeDtypeStruct((T, top_k), jnp.int32),
                   jax.ShapeDtypeStruct((T,), jnp.int32)],
        scratch_shapes=[pltpu_scratch((E,), jnp.float32)],
        interpret=interpret,
    )(logits.astype(jnp.float32), keys.astype(jnp.int32), demand)
    return idx, pos, gid, demand


def weakhash_route(logits, *, top_k, capacity, n_groups=1, mode="weakhash",
                   token_keys=None, prior_load=None, load_penalty=1.0,
                   rescue=False, interpret=False):
    """Kernel-backed RouteResult; rescue (γ=full second pass) and prior_load
    fall back to the oracle (cold paths)."""
    from repro.kernels.weakhash_route import ref
    if rescue or prior_load is not None:
        return ref.weakhash_route(
            logits, top_k=top_k, capacity=capacity, n_groups=n_groups,
            mode=mode, token_keys=token_keys, prior_load=prior_load,
            load_penalty=load_penalty, rescue=rescue)
    idx, _, gid, demand = weakhash_route_ints(
        logits, top_k=top_k, capacity=capacity, n_groups=n_groups, mode=mode,
        token_keys=token_keys, load_penalty=load_penalty,
        interpret=interpret)
    # positions in oracle token-major order (cheap; keeps dispatch parity)
    position = ref._positions_in_expert(idx, logits.shape[1])
    keep = position < capacity
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates = jnp.take_along_axis(probs, idx, axis=1)
    weights = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    top1 = jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[1],
                          dtype=jnp.float32).mean(0)
    aux = logits.shape[1] * jnp.sum(me * top1)
    dem = jax.nn.one_hot(idx.reshape(-1), logits.shape[1],
                         dtype=jnp.float32).sum(0)
    return ref.RouteResult(expert_idx=idx, weights=weights, position=position,
                           keep=keep, group_id=gid, demand=dem, aux_loss=aux)
