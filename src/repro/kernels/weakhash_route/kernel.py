"""Pallas TPU WeakHash routing kernel (integer outputs; differentiable
combine weights are reconstructed outside from the router probabilities).

Single fused ``pallas_call`` over a ``(2, nt)`` phase-major grid (TPU grids
iterate the last dimension fastest, so all of phase 0 runs before phase 1):

  phase 0 (demand): group-masked argmax histogram over all token tiles,
     accumulated into an (E,) VMEM scratch — the load estimate. The final
     demand never round-trips through HBM between phases (the pre-fusion
     version ran two kernels and re-read the (E,) demand from HBM on every
     select tile); it is exported once as an output for the API.
  phase 1 (select): demand-penalized scores → iterative top-k → arrival-
     order slot positions via an (E,) running-count scratch that carries
     across the sequential token-tile grid (matching the oracle's
     column-major cumsum exactly). The per-selection prefix cumsum is
     HOISTED out of the top-k loop: the k onehot matrices are stacked
     (k·bt, E) column-major and one cumsum produces every position.

When the whole token axis fits one tile (nt == 1) both phases run on a
single resident block, so the logits are read from HBM exactly once.

For nt > 1 the exact global-demand semantics force a second read of the
logits (phase 1 revisits every tile). The optional demand
"carry-forward" variant (`_carry_kernel`, ``carry_forward=True``) drops
to ONE pass: the penalty uses the previous batch's demand plus a running
histogram of already-processed tiles instead of the exact whole-batch
histogram — bit-identical to exact when nt == 1, an approximation
otherwise whose routing-quality delta (load CV vs exact) is recorded in
results/weakhash_carry_forward.json by benchmarks/bench_weakhash.py.

VPU-only (no MXU); token tiles are 8×128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import pltpu_scratch

NEG_INF = -1e30
DEFAULT_BLOCK_T = 256
KNUTH = 2654435761


def _group_mask(keys, n_groups, E, gsz):
    """(bt, E) bool mask of each token's candidate group."""
    hashed = keys.astype(jnp.uint32) * jnp.uint32(KNUTH)
    gid = (hashed % jnp.uint32(n_groups)).astype(jnp.int32)     # (bt,)
    eg = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], E), 1) // gsz
    return eg == gid[:, None], gid


def _fused_kernel(logits_ref, keys_ref, idx_ref, pos_ref, gid_ref, dem_ref,
                  dem_scr, count_scr, *, top_k, capacity, n_groups, E, gsz,
                  nt, load_penalty, mode, use_groups):
    phase = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(jnp.logical_and(phase == 0, t == 0))
    def _init():
        dem_scr[...] = jnp.zeros_like(dem_scr)
        count_scr[...] = jnp.zeros_like(count_scr)

    logits = logits_ref[...].astype(jnp.float32)                # (bt, E)
    bt = logits.shape[0]
    if use_groups:
        mask, gid = _group_mask(keys_ref[...], n_groups, E, gsz)
        masked = jnp.where(mask, logits, NEG_INF)
    else:
        masked = logits
        gid = jnp.zeros((bt,), jnp.int32)
    gid_ref[...] = gid
    eye = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)

    @pl.when(phase == 0)
    def _demand():
        top1 = jnp.argmax(masked, axis=-1)                      # (bt,)
        onehot = (top1[:, None] == eye)
        dem_scr[...] += jnp.sum(onehot.astype(jnp.float32), axis=0)
        # deterministic phase-0 writeback for the revisited output tiles
        idx_ref[...] = jnp.zeros_like(idx_ref)
        pos_ref[...] = jnp.zeros_like(pos_ref)

        @pl.when(t == nt - 1)
        def _export():
            dem_ref[...] = dem_scr[...]

    @pl.when(phase == 1)
    def _select():
        if mode == "weakhash":
            scores = masked - load_penalty * (dem_scr[...][None, :]
                                              / float(max(capacity, 1)))
        else:
            scores = masked

        counts = count_scr[...]                                 # (E,) f32
        sel = scores
        onehots = []
        for j in range(top_k):
            e_j = jnp.argmax(sel, axis=-1).astype(jnp.int32)    # (bt,)
            idx_ref[:, j] = e_j
            onehots.append((eye == e_j[:, None]).astype(jnp.float32))
            sel = jnp.where(eye == e_j[:, None], NEG_INF, sel)
        # positions: ONE column-major cumsum over the stacked selections
        # replaces the per-j cumsum the loop used to carry (row (j, t) sees
        # every selection of earlier columns plus earlier tokens of its own
        # column — exactly the reference's arrival order)
        stacked = jnp.concatenate(onehots, axis=0)              # (k·bt, E)
        prefix = jnp.cumsum(stacked, axis=0) - stacked
        pos_flat = jnp.sum((counts[None, :] + prefix) * stacked, axis=-1)
        for j in range(top_k):
            pos_ref[:, j] = pos_flat[j * bt:(j + 1) * bt].astype(jnp.int32)
        count_scr[...] = counts + jnp.sum(stacked, axis=0)


def _carry_kernel(logits_ref, keys_ref, prior_ref, idx_ref, pos_ref,
                  gid_ref, dem_ref, dem_scr, count_scr, *, top_k, capacity,
                  n_groups, E, gsz, nt, load_penalty, mode, use_groups):
    """Single-pass demand "carry-forward" variant (grid ``(nt,)``).

    Exact mode needs two passes because every token's penalty uses the
    FULL batch's demand histogram. Carry-forward replaces that global
    estimate with ``prior_ref`` (the previous batch's demand — the
    streaming load signal) plus the running histogram of tiles already
    processed, so each logits tile is read from HBM exactly once even
    for nt > 1. With one tile (nt == 1) the running histogram IS the
    full batch histogram, so carry-forward with a zero prior reproduces
    the exact kernel bit-for-bit — the parity anchor in
    tests/test_kernels.py. Quality impact (routing load CV vs exact) is
    measured by benchmarks/bench_weakhash.py into
    results/weakhash_carry_forward.json.
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        dem_scr[...] = prior_ref[...]
        count_scr[...] = jnp.zeros_like(count_scr)

    logits = logits_ref[...].astype(jnp.float32)                # (bt, E)
    bt = logits.shape[0]
    if use_groups:
        mask, gid = _group_mask(keys_ref[...], n_groups, E, gsz)
        masked = jnp.where(mask, logits, NEG_INF)
    else:
        masked = logits
        gid = jnp.zeros((bt,), jnp.int32)
    gid_ref[...] = gid
    eye = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)

    # the tile's own top-1 histogram joins the load estimate BEFORE its
    # selection (exact mode also counts a token's own batch in demand0)
    top1 = jnp.argmax(masked, axis=-1)
    dem_scr[...] += jnp.sum((top1[:, None] == eye).astype(jnp.float32),
                            axis=0)
    if mode == "weakhash":
        scores = masked - load_penalty * (dem_scr[...][None, :]
                                          / float(max(capacity, 1)))
    else:
        scores = masked

    counts = count_scr[...]                                     # (E,) f32
    sel = scores
    onehots = []
    for j in range(top_k):
        e_j = jnp.argmax(sel, axis=-1).astype(jnp.int32)        # (bt,)
        idx_ref[:, j] = e_j
        onehots.append((eye == e_j[:, None]).astype(jnp.float32))
        sel = jnp.where(eye == e_j[:, None], NEG_INF, sel)
    stacked = jnp.concatenate(onehots, axis=0)                  # (k·bt, E)
    prefix = jnp.cumsum(stacked, axis=0) - stacked
    pos_flat = jnp.sum((counts[None, :] + prefix) * stacked, axis=-1)
    for j in range(top_k):
        pos_ref[:, j] = pos_flat[j * bt:(j + 1) * bt].astype(jnp.int32)
    count_scr[...] = counts + jnp.sum(stacked, axis=0)

    @pl.when(t == nt - 1)
    def _export():
        # the batch's OWN top-1 histogram (same statistic exact mode
        # exports) — chain it into the next batch's prior_demand
        dem_ref[...] = dem_scr[...] - prior_ref[...]


def weakhash_route_ints(logits, *, top_k, capacity, n_groups=1,
                        mode="weakhash", token_keys=None, load_penalty=1.0,
                        block_t=DEFAULT_BLOCK_T, interpret=False,
                        carry_forward=False, prior_demand=None):
    """Integer routing outputs: (expert_idx, position, group_id, demand).

    ``carry_forward=True`` selects the truly single-pass variant for
    nt > 1: the demand penalty uses ``prior_demand`` (previous batch's
    histogram, zeros when None) plus the running histogram of earlier
    tiles instead of the exact whole-batch histogram (see
    `_carry_kernel`); the returned demand stays the batch's own top-1
    histogram so callers can chain batches.

    NOTE: the oracle's per-(token,k)-flattened arrival order is token-major
    with all k selections of token t adjacent; this kernel assigns positions
    per selection column j across the tile instead. Both are valid
    arrival orders; for exact oracle parity the wrapper recomputes positions
    when cross-validating — see ops.weakhash_route.
    """
    T, E = logits.shape
    bt = min(block_t, T)
    assert T % bt == 0
    nt = T // bt
    gsz = E // max(n_groups, 1)
    keys = (token_keys if token_keys is not None
            else jnp.zeros((T,), jnp.int32))
    use_groups = mode == "weakhash" and n_groups > 1

    if carry_forward:
        prior = (jnp.zeros((E,), jnp.float32) if prior_demand is None
                 else prior_demand.astype(jnp.float32))
        return pl.pallas_call(
            functools.partial(_carry_kernel, top_k=top_k,
                              capacity=capacity, n_groups=n_groups, E=E,
                              gsz=gsz, nt=nt, load_penalty=load_penalty,
                              mode=mode, use_groups=use_groups),
            grid=(nt,),
            in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0)),
                      pl.BlockSpec((bt,), lambda t: (t,)),
                      pl.BlockSpec((E,), lambda t: (0,))],
            out_specs=[pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
                       pl.BlockSpec((bt, top_k), lambda t: (t, 0)),
                       pl.BlockSpec((bt,), lambda t: (t,)),
                       pl.BlockSpec((E,), lambda t: (0,))],
            out_shape=[jax.ShapeDtypeStruct((T, top_k), jnp.int32),
                       jax.ShapeDtypeStruct((T, top_k), jnp.int32),
                       jax.ShapeDtypeStruct((T,), jnp.int32),
                       jax.ShapeDtypeStruct((E,), jnp.float32)],
            scratch_shapes=[pltpu_scratch((E,), jnp.float32),
                            pltpu_scratch((E,), jnp.float32)],
            interpret=interpret,
        )(logits.astype(jnp.float32), keys.astype(jnp.int32), prior)

    idx, pos, gid, demand = pl.pallas_call(
        functools.partial(_fused_kernel, top_k=top_k, capacity=capacity,
                          n_groups=n_groups, E=E, gsz=gsz, nt=nt,
                          load_penalty=load_penalty, mode=mode,
                          use_groups=use_groups),
        grid=(2, nt),
        in_specs=[pl.BlockSpec((bt, E), lambda p, t: (t, 0)),
                  pl.BlockSpec((bt,), lambda p, t: (t,))],
        out_specs=[pl.BlockSpec((bt, top_k), lambda p, t: (t, 0)),
                   pl.BlockSpec((bt, top_k), lambda p, t: (t, 0)),
                   pl.BlockSpec((bt,), lambda p, t: (t,)),
                   pl.BlockSpec((E,), lambda p, t: (0,))],
        out_shape=[jax.ShapeDtypeStruct((T, top_k), jnp.int32),
                   jax.ShapeDtypeStruct((T, top_k), jnp.int32),
                   jax.ShapeDtypeStruct((T,), jnp.int32),
                   jax.ShapeDtypeStruct((E,), jnp.float32)],
        scratch_shapes=[pltpu_scratch((E,), jnp.float32),
                        pltpu_scratch((E,), jnp.float32)],
        interpret=interpret,
    )(logits.astype(jnp.float32), keys.astype(jnp.int32))
    return idx, pos, gid, demand


def weakhash_route(logits, *, top_k, capacity, n_groups=1, mode="weakhash",
                   token_keys=None, prior_load=None, load_penalty=1.0,
                   rescue=False, interpret=False, carry_forward=False):
    """Kernel-backed RouteResult; rescue (γ=full second pass) falls back
    to the oracle (cold path). ``carry_forward=True`` runs the
    single-pass kernel with ``prior_load`` as the previous batch's
    demand (the streaming chain signal)."""
    from repro.kernels.weakhash_route import ref
    if rescue or (prior_load is not None and not carry_forward):
        return ref.weakhash_route(
            logits, top_k=top_k, capacity=capacity, n_groups=n_groups,
            mode=mode, token_keys=token_keys, prior_load=prior_load,
            load_penalty=load_penalty, rescue=rescue)
    idx, _, gid, demand = weakhash_route_ints(
        logits, top_k=top_k, capacity=capacity, n_groups=n_groups, mode=mode,
        token_keys=token_keys, load_penalty=load_penalty,
        interpret=interpret, carry_forward=carry_forward,
        prior_demand=prior_load)
    # positions in oracle token-major order (cheap; keeps dispatch parity)
    position = ref._positions_in_expert(idx, logits.shape[1])
    keep = position < capacity
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates = jnp.take_along_axis(probs, idx, axis=1)
    weights = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    top1 = jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[1],
                          dtype=jnp.float32).mean(0)
    aux = logits.shape[1] * jnp.sum(me * top1)
    dem = jax.nn.one_hot(idx.reshape(-1), logits.shape[1],
                         dtype=jnp.float32).sum(0)
    return ref.RouteResult(expert_idx=idx, weights=weights, position=position,
                           keep=keep, group_id=gid, demand=dem, aux_loss=aux)
