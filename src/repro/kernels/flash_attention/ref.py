"""Pure-jnp oracle for blockwise (flash) attention.

This is simultaneously (a) the correctness reference for the Pallas kernel and
(b) the implementation used when lowering on non-TPU backends (dry-run): it is
*blockwise* — scores never materialize beyond one (q_chunk × kv) tile — so the
32k-prefill cells compile with bounded temp memory.

Layouts: q (B, Sq, H, D); k/v (B, Skv, KV, D) with H = KV * G (GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _chunk_attend(q, k, v, mask, scale):
    """One q-chunk against full kv. q (B,c,KV,G,D); k/v (B,S,KV,D);
    mask (B_or_1, c, 1_or_KV, S) boolean (True = attend)."""
    scores = jnp.einsum("bckgd,bskd->bckgs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, :, :, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # Fully-masked rows (e.g. padded cache) produce NaN from softmax(-inf).
    probs = jnp.where(jnp.any(mask[:, :, :, None, :], axis=-1, keepdims=True),
                      probs, 0.0)
    out = jnp.einsum("bckgs,bskd->bckgd", probs.astype(v.dtype), v)
    return out


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: int = 0,
              q_offset: jax.Array | int = 0,
              kv_valid_len: jax.Array | None = None,
              chunk: int = 512,
              unroll: bool = False,
              scale: float | None = None) -> jax.Array:
    """Blockwise attention with causal / sliding-window / cache-length masks.

    q_offset: absolute position of q[0] (decode/chunked prefill).
    kv_valid_len: number of valid cache entries (decode); None = all.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)

    k_pos = jnp.arange(Skv)

    def mask_for(q_pos):  # q_pos (c,) absolute positions
        m = jnp.ones((q_pos.shape[0], Skv), bool)
        if causal:
            m &= k_pos[None, :] <= q_pos[:, None]
        if window:
            m &= k_pos[None, :] > q_pos[:, None] - window
        m = m[None]  # (1, c, S)
        if kv_valid_len is not None:
            m &= (k_pos[None, None, :] < jnp.asarray(kv_valid_len).reshape(-1, 1, 1))
        return m[:, :, None, :]  # (B|1, c, 1, S)

    if Sq <= chunk:
        q_pos = q_offset + jnp.arange(Sq)
        out = _chunk_attend(qg, k, v, mask_for(q_pos), scale)
        return out.reshape(B, Sq, H, D)

    if Sq % chunk:  # e.g. whisper's 1500-frame encoder: pad q, slice out
        pad = chunk - Sq % chunk
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = attention(qp, k, v, causal=causal, window=window,
                        q_offset=q_offset, kv_valid_len=kv_valid_len,
                        chunk=chunk, unroll=unroll, scale=scale)
        return out[:, :Sq]
    nq = Sq // chunk
    qs = qg.reshape(B, nq, chunk, KV, G, D).transpose(1, 0, 2, 3, 4, 5)

    if unroll:
        # python loop — every chunk appears in HLO (accurate cost_analysis)
        outs = []
        for i in range(nq):
            q_pos = q_offset + i * chunk + jnp.arange(chunk)
            outs.append(_chunk_attend(qs[i], k, v, mask_for(q_pos), scale))
        out = jnp.stack(outs)
    else:
        def body(_, xs):
            qc, idx = xs
            q_pos = q_offset + idx * chunk + jnp.arange(chunk)
            oc = _chunk_attend(qc, k, v, mask_for(q_pos), scale)
            return None, oc

        _, out = jax.lax.scan(body, None, (qs, jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, D)
    return out


def attention_exact_blocks(q, k, v, *, causal: bool = True, window: int = 0,
                           chunk: int = 512, scale: float | None = None):
    """Exact-causal variant: python loop with static kv slices so no FLOPs are
    spent on fully-masked kv blocks (the §Perf 'causal_blocks' optimization).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)
    k_pos_full = jnp.arange(Skv)
    outs = []
    nq = max(1, Sq // chunk)
    chunk = Sq // nq
    for i in range(nq):
        lo = i * chunk
        hi = lo + chunk
        kv_lo = max(0, hi - window) if window else 0
        kv_lo = (kv_lo // 128) * 128  # keep lane-aligned slices
        kv_hi = min(Skv, hi) if causal else Skv
        ks, vs = k[:, kv_lo:kv_hi], v[:, kv_lo:kv_hi]
        q_pos = lo + jnp.arange(chunk)
        m = jnp.ones((chunk, kv_hi - kv_lo), bool)
        if causal:
            m &= k_pos_full[kv_lo:kv_hi][None, :] <= q_pos[:, None]
        if window:
            m &= k_pos_full[kv_lo:kv_hi][None, :] > q_pos[:, None] - window
        outs.append(_chunk_attend(qg[:, lo:hi], ks, vs, m[None, :, None, :], scale))
    return jnp.concatenate(outs, axis=1).reshape(B, Sq, H, D)
