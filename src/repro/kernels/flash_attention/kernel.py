"""Pallas TPU flash attention: fwd + bwd, GQA, causal, sliding window.

Tiling: grid (B, H, nq, nk) — the kv axis is the *last* (sequential on TPU)
grid dimension, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and persists across kv steps. Block shapes are (block_q × head_dim)
and (block_k × head_dim) VMEM tiles, MXU-aligned (multiples of 128 on the
contracting/lane dims; head_dim up to 256 supported).

Causal/SWA masking is two-level: kv blocks entirely outside the visible
range are skipped with ``pl.when`` (no MXU work); partially-visible blocks
apply an element mask. The backward pass runs two kernels: dq (grid over kv
last) and dkv (grid over q last), both recomputing probabilities from the
saved per-row LSE, exactly like FlashAttention-2.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _visible(causal, window, q0, k0, bq, bk):
    """Block-level visibility for (q0..q0+bq) × (k0..k0+bk)."""
    full_after = (k0 + bk - 1 <= q0) if causal else True
    any_vis = (k0 <= q0 + bq - 1) if causal else True
    if window:
        any_vis = jnp.logical_and(any_vis, k0 + bk - 1 > q0 - window)
        full_after = jnp.logical_and(full_after, k0 >= q0 + bq - window)
    return any_vis, full_after


def _element_mask(causal, window, q0, k0, bq, bk):
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        m &= ki <= qi
    if window:
        m &= ki > qi - window
    return m


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                causal, window, scale, block_q, block_k, nk):
    qb, kb = pl.program_id(2), pl.program_id(3)
    q0 = qb * block_q
    k0 = kb * block_k

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    any_vis, _ = _visible(causal, window, q0, k0, block_q, block_k)

    @pl.when(any_vis)
    def _compute():
        q = q_ref[0, 0]                      # (bq, D)
        k = k_ref[0, 0]                      # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        mask = _element_mask(causal, window, q0, k0, block_q, block_k)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        # guard fully-masked rows: NEG_INF - NEG_INF would exp() to 1
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(kb == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(safe)


def _fwd(q, k, v, *, causal, window, scale, block_q, block_k, interpret):
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    # layout: (B, H, S, D) blocks per (batch, head)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, nk=nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu_scratch((block_q,), jnp.float32),
            pltpu_scratch((block_q,), jnp.float32),
            pltpu_scratch((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3), lse


def pltpu_scratch(shape, dtype):
    from jax.experimental import pallas as pl  # noqa
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.VMEM(shape, dtype)


# ----------------------------------------------------------------------
# backward: dq kernel (kv sequential), dkv kernel (q sequential)
# ----------------------------------------------------------------------
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, causal, window, scale, block_q, block_k, nk):
    kb = pl.program_id(3)
    q0 = pl.program_id(2) * block_q
    k0 = kb * block_k

    @pl.when(kb == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    any_vis, _ = _visible(causal, window, q0, k0, block_q, block_k)

    @pl.when(any_vis)
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _element_mask(causal, window, q0, k0, block_q, block_k)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_scr[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _fin():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                causal, window, scale, block_q, block_k, nq):
    qb = pl.program_id(3)
    q0 = qb * block_q
    k0 = pl.program_id(2) * block_k

    @pl.when(qb == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    any_vis, _ = _visible(causal, window, q0, k0, block_q, block_k)

    @pl.when(any_vis)
    def _compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _element_mask(causal, window, q0, k0, block_q, block_k)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                      # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale             # (bq, bk)
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qb == nq - 1)
    def _fin():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd(res, g, *, causal, window, scale, block_q, block_k, interpret):
    q, k, v, out, lse = res
    do = g
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = pl.cdiv(Sq, block_q), pl.cdiv(Sk, block_k)

    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    dot = do.transpose(0, 2, 1, 3)
    delta = jnp.sum(dot.astype(jnp.float32)
                    * out.transpose(0, 2, 1, 3).astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window,
                          scale=scale, block_q=block_q, block_k=block_k,
                          nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu_scratch((block_q, D), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    dkg, dvg = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window,
                          scale=scale, block_q=block_q, block_k=block_k,
                          nq=nq),
        grid=(B, H, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, j, i, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, j, i, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, j, i: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, j, i: (b, h, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sk, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sk, D), jnp.float32),
        ],
        scratch_shapes=[pltpu_scratch((block_k, D), jnp.float32),
                        pltpu_scratch((block_k, D), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    # reduce per-q-head grads to kv heads (GQA)
    dk = dkg.reshape(B, KV, G, Sk, D).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dvg.reshape(B, KV, G, Sk, D).sum(axis=2).transpose(0, 2, 1, 3)
    return (dq.transpose(0, 2, 1, 3), dk.astype(k.dtype), dv.astype(v.dtype))


# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out, _ = _fwd(q, k, v, causal=causal, window=window, scale=scale,
                  block_q=block_q, block_k=block_k, interpret=interpret)
    return out


def _flash_fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out, lse = _fwd(q, k, v, causal=causal, window=window, scale=scale,
                    block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, scale, block_q, block_k, interpret, res, g):
    return _bwd(res, g, causal=causal, window=window, scale=scale,
                block_q=block_q, block_k=block_k, interpret=interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_valid_len=None, scale=None,
                    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                    interpret=False):
    """Public entry. q (B,Sq,H,D); k/v (B,Skv,KV,D). q_offset/kv_valid_len
    are not supported in the kernel path (full-sequence train/prefill only)."""
    assert kv_valid_len is None and (isinstance(q_offset, int)
                                     and q_offset == 0), \
        "kernel path covers full-sequence train/prefill"
    D = q.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    return _flash(q, k, v, causal, window, scale, block_q, block_k, interpret)
