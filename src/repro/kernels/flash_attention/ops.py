"""jit-level wrapper for flash attention with impl dispatch."""
from __future__ import annotations

import jax

from repro.kernels.common import resolve_impl
from repro.kernels.flash_attention import ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_valid_len=None, chunk: int = 512,
                    exact_blocks: bool = False, unroll: bool = False,
                    impl: str | None = None):
    """q (B,Sq,H,D); k/v (B,Skv,KV,D) → (B,Sq,H,D).

    exact_blocks: statically slice kv per q-chunk (no flops on fully-masked
    blocks) — the §Perf "causal_blocks" optimization; only valid when
    q_offset == 0 and kv_valid_len is None (train/prefill full-sequence case).
    """
    impl = resolve_impl(impl)
    if impl == "ref" or q.shape[1] == 1:
        if exact_blocks and isinstance(q_offset, int) and q_offset == 0 \
                and kv_valid_len is None and q.shape[1] > chunk:
            return ref.attention_exact_blocks(
                q, k, v, causal=causal, window=window, chunk=chunk)
        return ref.attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_valid_len=kv_valid_len,
                             chunk=chunk, unroll=unroll)
    from repro.kernels.flash_attention import kernel
    return kernel.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_valid_len=kv_valid_len, interpret=(impl == "interpret"))
