"""jnp oracle of the fused tick-phase kernel (also the non-TPU path).

Exactly the row-table math of `jax_engine._build_compact_run`'s routing
block, natively batched over a leading ``(S,)`` seed axis: row gathers
become 2D column gathers, pads keep contributing exact +0.0 to sums and
+inf to head-of-line minima, and every epsilon / fallback select is
byte-for-byte the compact tick's (including the weakhash dummy-entry
0/0 that the fallback ``where`` selects away), so pallas == compact ==
dense at 1e-12 (tests/test_pallas_tick.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def _rsum(vals, idx, mask):
    return (vals[:, idx] * mask).sum(-1)


def _rmin(vals, idx, mask):
    return jnp.where(mask > 0.5, vals[:, idx], jnp.inf).min(-1)


def tick_phase_ref(produced, alive, free, tb, *, has_blk: bool,
                   has_grp: bool):
    """One routing phase over a seed batch.

    ``produced`` / ``alive`` / ``free`` are ``(S, n_tasks)``; ``tb`` is
    the packed table dict from `ops.pack_phase_tables`. Returns
    ``(accepted, dropped_d, overflow_e)`` of shapes ``(S, D)`` /
    ``(S, D)`` / ``(S, E)`` — the caller deposits, attributes drops to
    job segments and re-queues edge overflow exactly as the compact
    tick does."""
    dst, fwd_src, edge_of, grp_of, blk_of = tb["di"]
    (m_fwd, m_blk, m_hash, m_wh, m_bk, is_norm, m_acc_s, m_acc_b,
     dinb, share, mass, qcap_d, mode_s_d) = tb["df"]
    alive_d = alive[:, dst]
    free_d = free[:, dst]
    # per-source-op slot totals — O(live src tasks)
    tot_slot = _rsum(produced, tb["s_idx"], tb["s_mask"])
    tot_e = tot_slot[:, tb["soe"][0]]
    tot_d = tot_e[:, edge_of]
    # forward: pointwise src task → dst task
    arr_fwd = produced[:, fwd_src] * alive_d
    # rescale family: per-block rate over alive destinations
    if has_blk:
        prod_blk = _rsum(produced, tb["bs_idx"], tb["bs_mask"])
        alive_blk = _rsum(alive_d * dinb, tb["br_idx"], tb["br_mask"])
        has = alive_blk > 0.0
        rate_blk = jnp.where(has,
                             prod_blk / jnp.where(has, alive_blk, 1.0),
                             0.0)
        arr_blk = jnp.where(dinb > 0.0, rate_blk[:, blk_of] * alive_d,
                            0.0)
    else:
        arr_blk = jnp.zeros_like(alive_d)
    # weakhash: group mass spread ∝ free capacity (fallback to
    # alive-uniform when a whole group is down)
    if has_grp:
        wh = m_wh > 0.5
        cap_w = jnp.maximum(free_d, 1e-9) * alive_d
        alive_eps = alive_d + 1e-9
        capsum = _rsum(jnp.where(wh, cap_w, 0.0), tb["gr_idx"],
                       tb["gr_mask"])
        capsum_fb = _rsum(jnp.where(wh, alive_eps, 0.0), tb["gr_idx"],
                          tb["gr_mask"])
        fall = capsum <= 0.0
        cap2 = jnp.where(fall[:, grp_of], alive_eps, cap_w) * alive_d
        capsum2 = jnp.where(fall, capsum_fb, capsum)
        val_wh = cap2 * mass / capsum2[:, grp_of]
    else:
        val_wh = jnp.zeros_like(alive_d)
    # backlog: divert away from congested channels
    open_ = (free_d > qcap_d * 0.25).astype(produced.dtype)
    val_bk = (jnp.maximum(free_d, 1e-9) * alive_d
              * jnp.maximum(open_, 0.05))
    val_nrm = jnp.where(m_wh > 0.5, val_wh,
                        jnp.where(m_bk > 0.5, val_bk,
                                  alive_d)) * is_norm
    rs = _rsum(val_nrm, tb["er_idx"], tb["er_mask"])
    ratio_e = jnp.where(rs > 0.0, tot_e / rs, 0.0)
    arr_nrm = val_nrm * ratio_e[:, edge_of]
    arriving = jnp.where(m_fwd > 0.5, arr_fwd,
                         jnp.where(m_blk > 0.5, arr_blk,
                                   jnp.where(m_hash > 0.5,
                                             tot_d * share, arr_nrm)))
    dead_s = (alive_d <= 0.0) & (mode_s_d > 0.0)
    dropped_d = jnp.where(dead_s, arriving, 0.0)
    arriving = jnp.where(dead_s, 0.0, arriving)
    # acceptance: head-of-line / per-block / adaptive credits
    live = arriving > 1e-9
    ratio = jnp.where(live, free_d / jnp.maximum(arriving, 1e-300),
                      jnp.inf)
    lam_e = jnp.minimum(_rmin(ratio, tb["er_idx"], tb["er_mask"]), 1.0)
    if has_blk:
        lam_b = jnp.minimum(_rmin(ratio, tb["br_idx"], tb["br_mask"]),
                            1.0)
        acc_blk = arriving * lam_b[:, blk_of]
    else:
        acc_blk = arriving
    accepted = jnp.where(m_acc_s > 0.5, arriving * lam_e[:, edge_of],
                         jnp.where(m_acc_b > 0.5, acc_blk,
                                   jnp.minimum(arriving, free_d)))
    overflow_e = _rsum(arriving - accepted, tb["er_idx"], tb["er_mask"])
    return accepted, dropped_d, overflow_e
