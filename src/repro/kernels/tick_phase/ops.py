"""jit-level wrapper for the fused tick-phase kernel with impl dispatch.

`pack_phase_tables` stacks a traced `engine.CompactPhase` edge dict
(``pa["edges"][fi]``) into the kernel's input layout — two packed
row-major tables (int structure + float masks/params, one ref each
inside the kernel instead of ~20) plus the pow2 row buckets.
`tick_phase` dispatches pallas / interpret / ref via
`repro.kernels.common.resolve_impl`; the seed-block grid size comes
from `launch.roofline.choose_block_rows` against the VMEM budget.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import resolve_impl
from repro.kernels.tick_phase import ref
from repro.launch.roofline import choose_block_rows

# di/df packed-table row layouts (keep in sync with ref.tick_phase_ref
# and kernel._phase_kernel unpacking)
DI_ROWS = ("dst_task", "fwd_src", "edge_of", "grp_of", "blk_of")
DF_ROWS = ("m_fwd", "m_blk", "m_hash", "m_weakhash", "m_backlog",
           "is_norm", "m_acc_static", "m_acc_block", "dst_in_blk",
           "share", "mass", "qcap_d", "mode_single_d")
# kernel input order after the three task-state blocks
TABLE_KEYS = ("di", "df", "s_idx", "s_mask", "soe", "er_idx", "er_mask",
              "gr_idx", "gr_mask", "br_idx", "br_mask", "bs_idx",
              "bs_mask")


def pack_phase_tables(eph: dict, qcap, mode_single) -> dict:
    """Pack one phase's traced `CompactPhase` dict into the kernel
    table layout. ``qcap_d`` / ``mode_single_d`` are pre-gathered onto
    the dst axis here (once per run, outside the scan) so the kernel
    never touches the arena-sized config rows."""
    dst = jnp.asarray(eph["dst_task"], jnp.int32)
    di = jnp.stack([dst] + [jnp.asarray(eph[k], jnp.int32)
                            for k in DI_ROWS[1:]])
    df = jnp.stack([jnp.asarray(eph[k]) for k in DF_ROWS[:-2]]
                   + [jnp.asarray(qcap)[dst],
                      jnp.asarray(mode_single)[dst]])
    return {
        "di": di, "df": df,
        "s_idx": jnp.asarray(eph["s_idx"], jnp.int32),
        "s_mask": jnp.asarray(eph["s_mask"]),
        "soe": jnp.asarray(eph["slot_of_edge"], jnp.int32)[None, :],
        "er_idx": jnp.asarray(eph["er_idx"], jnp.int32),
        "er_mask": jnp.asarray(eph["er_mask"]),
        "gr_idx": jnp.asarray(eph["gr_idx"], jnp.int32),
        "gr_mask": jnp.asarray(eph["gr_mask"]),
        "br_idx": jnp.asarray(eph["br_idx"], jnp.int32),
        "br_mask": jnp.asarray(eph["br_mask"]),
        "bs_idx": jnp.asarray(eph["bs_idx"], jnp.int32),
        "bs_mask": jnp.asarray(eph["bs_mask"]),
    }


def table_bytes(tb: dict) -> int:
    """Static VMEM footprint of one phase's packed tables."""
    return int(sum(np.prod(v.shape) * v.dtype.itemsize
                   for v in tb.values()))


def choose_seed_block(n_seeds: int, n_tasks: int, D: int, E: int,
                      tbytes: int) -> int:
    """Seed-block rows for the phase grid, sized against the VMEM
    budget: per-seed working set = the three (n_tasks,) task-state
    rows + ~8 (D,) stage intermediates (the two shared scratch
    accumulators, the three outputs, routing temps) + 2 (E,) edge
    rows, all f64; the packed tables are grid-invariant residents."""
    row_bytes = (3 * n_tasks + 8 * D + 2 * E) * 8
    sb = min(choose_block_rows(row_bytes, fixed_bytes=tbytes), n_seeds)
    while n_seeds % sb:
        sb //= 2
    return max(sb, 1)


def tick_phase(produced, alive, free, tb, *, has_blk: bool,
               has_grp: bool, impl: str | None = None,
               seed_block: int | None = None):
    """(accepted, dropped_d, overflow_e) of one fused routing phase
    over a ``(S, n_tasks)`` seed batch — see `ref.tick_phase_ref` for
    the contract, `kernel.fused_phase` for the launch."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.tick_phase_ref(produced, alive, free, tb,
                                  has_blk=has_blk, has_grp=has_grp)
    from repro.kernels.tick_phase import kernel
    if seed_block is None:
        seed_block = choose_seed_block(
            produced.shape[0], produced.shape[1], tb["di"].shape[1],
            tb["er_idx"].shape[0], table_bytes(tb))
    return kernel.fused_phase(produced, alive, free, tb,
                              has_blk=has_blk, has_grp=has_grp,
                              seed_block=seed_block,
                              interpret=(impl == "interpret"))
