"""Pallas kernel: one fused launch per `engine.CompactPhase` phase.

Grid ``(S // sb,)`` — the seed (scenario) axis is the Pallas grid
dimension; each program owns an ``(sb, n_tasks)`` seed block of the
three task-state inputs and the full pow2 row-table bucket set (the
PR 5 compact tables ride along as full-block inputs, so block shapes
ARE the bucket signature). The whole routing phase fuses into the one
launch:

  stage 1 (gather + route): task-state gathers, per-source-op slot
     totals, forward / per-block rescale / weakhash group-capacity /
     backlog normalization — the ``(sb, D)`` arriving accumulator lands
     in a VMEM scratch shared with the later stages (it never
     round-trips through HBM between the route, drop and accept
     stages, which is the entire point of the fusion).
  stage 2 (dead-single drop): single_task-mode drops split off the
     arriving scratch; the head-of-line free/arriving ratio lands in
     the second shared scratch.
  stage 3 (accept + overflow): per-edge / per-block row minima over the
     ratio scratch, accept-mask application, per-edge overflow rows.

Numerics mirror `jax_engine._build_compact_run` term for term (pads
+0.0 into sums / +inf into minima, every epsilon and fallback select
identical) so the fused phase holds 1e-12 parity with the dense and
compact lowerings. ``interpret=True`` runs the same kernel through the
Pallas interpreter on CPU — jit/vmap/scan-traceable, used by CI.

Seed-block sizing comes from `launch.roofline.choose_block_rows`
against the VMEM budget (see `ops.choose_seed_block`), not guesswork.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import pltpu_scratch
from repro.kernels.tick_phase.ops import TABLE_KEYS


def _phase_kernel(p_ref, alive_ref, free_ref, di_ref, df_ref, sidx_ref,
                  smask_ref, soe_ref, eri_ref, erm_ref, gri_ref, grm_ref,
                  bri_ref, brm_ref, bsi_ref, bsm_ref,
                  acc_ref, drop_ref, ovf_ref, arr_scr, ratio_scr, *,
                  has_blk, has_grp):
    def rsum(vals, idx, mask):
        return (vals[:, idx] * mask).sum(-1)

    def rmin(vals, idx, mask):
        return jnp.where(mask > 0.5, vals[:, idx], jnp.inf).min(-1)

    produced = p_ref[...]                                # (sb, n_tasks)
    alive = alive_ref[...]
    free = free_ref[...]
    dst, fwd_src, edge_of, grp_of, blk_of = di_ref[...]
    (m_fwd, m_blk, m_hash, m_wh, m_bk, is_norm, m_acc_s, m_acc_b,
     dinb, share, mass, qcap_d, mode_s_d) = df_ref[...]
    eri, erm = eri_ref[...], erm_ref[...]
    alive_d = alive[:, dst]                              # (sb, D)
    free_d = free[:, dst]

    # ---- stage 1: gather + route → arriving lands in shared scratch
    tot_slot = rsum(produced, sidx_ref[...], smask_ref[...])
    tot_e = tot_slot[:, soe_ref[...][0]]
    tot_d = tot_e[:, edge_of]
    arr_fwd = produced[:, fwd_src] * alive_d
    if has_blk:
        prod_blk = rsum(produced, bsi_ref[...], bsm_ref[...])
        alive_blk = rsum(alive_d * dinb, bri_ref[...], brm_ref[...])
        has = alive_blk > 0.0
        rate_blk = jnp.where(has,
                             prod_blk / jnp.where(has, alive_blk, 1.0),
                             0.0)
        arr_blk = jnp.where(dinb > 0.0, rate_blk[:, blk_of] * alive_d,
                            0.0)
    else:
        arr_blk = jnp.zeros_like(alive_d)
    if has_grp:
        wh = m_wh > 0.5
        cap_w = jnp.maximum(free_d, 1e-9) * alive_d
        alive_eps = alive_d + 1e-9
        gri, grm = gri_ref[...], grm_ref[...]
        capsum = rsum(jnp.where(wh, cap_w, 0.0), gri, grm)
        capsum_fb = rsum(jnp.where(wh, alive_eps, 0.0), gri, grm)
        fall = capsum <= 0.0
        cap2 = jnp.where(fall[:, grp_of], alive_eps, cap_w) * alive_d
        capsum2 = jnp.where(fall, capsum_fb, capsum)
        val_wh = cap2 * mass / capsum2[:, grp_of]
    else:
        val_wh = jnp.zeros_like(alive_d)
    open_ = (free_d > qcap_d * 0.25).astype(produced.dtype)
    val_bk = (jnp.maximum(free_d, 1e-9) * alive_d
              * jnp.maximum(open_, 0.05))
    val_nrm = jnp.where(m_wh > 0.5, val_wh,
                        jnp.where(m_bk > 0.5, val_bk,
                                  alive_d)) * is_norm
    rs = rsum(val_nrm, eri, erm)
    ratio_e = jnp.where(rs > 0.0, tot_e / rs, 0.0)
    arr_nrm = val_nrm * ratio_e[:, edge_of]
    arr_scr[...] = jnp.where(m_fwd > 0.5, arr_fwd,
                             jnp.where(m_blk > 0.5, arr_blk,
                                       jnp.where(m_hash > 0.5,
                                                 tot_d * share,
                                                 arr_nrm)))

    # ---- stage 2: dead-single drops + head-of-line ratio scratch
    arriving = arr_scr[...]
    dead_s = (alive_d <= 0.0) & (mode_s_d > 0.0)
    drop_ref[...] = jnp.where(dead_s, arriving, 0.0)
    arriving = jnp.where(dead_s, 0.0, arriving)
    live = arriving > 1e-9
    ratio_scr[...] = jnp.where(live,
                               free_d / jnp.maximum(arriving, 1e-300),
                               jnp.inf)

    # ---- stage 3: row minima over the ratio scratch → accept + overflow
    ratio = ratio_scr[...]
    lam_e = jnp.minimum(rmin(ratio, eri, erm), 1.0)
    if has_blk:
        lam_b = jnp.minimum(rmin(ratio, bri_ref[...], brm_ref[...]), 1.0)
        acc_blk = arriving * lam_b[:, blk_of]
    else:
        acc_blk = arriving
    accepted = jnp.where(m_acc_s > 0.5, arriving * lam_e[:, edge_of],
                         jnp.where(m_acc_b > 0.5, acc_blk,
                                   jnp.minimum(arriving, free_d)))
    acc_ref[...] = accepted
    ovf_ref[...] = rsum(arriving - accepted, eri, erm)


def fused_phase(produced, alive, free, tb, *, has_blk, has_grp,
                seed_block=None, interpret=False):
    """One fused ``pallas_call`` over the seed-block grid; same contract
    as `ref.tick_phase_ref`."""
    S, n_tasks = produced.shape
    D = tb["di"].shape[1]
    E = tb["er_idx"].shape[0]
    sb = min(seed_block or S, S)
    while S % sb:
        sb //= 2
    sb = max(sb, 1)

    def seed_spec(cols):
        return pl.BlockSpec((sb, cols), lambda s: (s, 0))

    def full_spec(shape):
        return pl.BlockSpec(shape, lambda s: (0,) * len(shape))

    dt = produced.dtype
    acc, drop, ovf = pl.pallas_call(
        functools.partial(_phase_kernel, has_blk=has_blk,
                          has_grp=has_grp),
        grid=(S // sb,),
        in_specs=([seed_spec(n_tasks)] * 3
                  + [full_spec(tb[k].shape) for k in TABLE_KEYS]),
        out_specs=[seed_spec(D), seed_spec(D), seed_spec(E)],
        out_shape=[jax.ShapeDtypeStruct((S, D), dt),
                   jax.ShapeDtypeStruct((S, D), dt),
                   jax.ShapeDtypeStruct((S, E), dt)],
        scratch_shapes=[pltpu_scratch((sb, D), dt),
                        pltpu_scratch((sb, D), dt)],
        interpret=interpret,
    )(produced, alive, free, *(tb[k] for k in TABLE_KEYS))
    return acc, drop, ovf
