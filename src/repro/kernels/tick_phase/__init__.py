"""Fused tick-phase kernel: one launch per `engine.CompactPhase`.

The entire routing phase of the tensorized tick — task-state gather,
per-edge normalization, head-of-line ``segment_min``, per-group /
per-block ``segment_sum`` reduces and the accept-mask application —
runs as ONE fused ``pallas_call`` with the seed (scenario) axis as the
Pallas grid dimension and the pow2 row-table buckets as block shapes
(`kernel.py`). `ref.py` is the seed-batched jnp oracle (also the
non-TPU lowering); `ops.py` packs the `CompactPhase` tables and
dispatches pallas / interpret / ref. Consumed by
`streams.jax_engine._build_pallas_run` (``phase_mode="pallas"``).
"""
from repro.kernels.tick_phase.ops import (DF_ROWS, DI_ROWS, TABLE_KEYS,
                                          choose_seed_block,
                                          pack_phase_tables, table_bytes,
                                          tick_phase)

__all__ = ["DF_ROWS", "DI_ROWS", "TABLE_KEYS", "choose_seed_block",
           "pack_phase_tables", "table_bytes", "tick_phase"]
