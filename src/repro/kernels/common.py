"""Shared kernel-dispatch policy.

``impl`` resolution order:
  explicit arg > REPRO_KERNEL_IMPL env > backend default
Backend default: "pallas" on TPU, "ref" elsewhere (the jnp oracle lowers on
any backend, keeping the CPU dry-run compilable). "interpret" runs the Pallas
kernel body in Python — the CPU validation mode used by the kernel tests.
"""
from __future__ import annotations

import os

import jax

VALID = ("pallas", "interpret", "ref")


def resolve_impl(impl: str | None = None) -> str:
    if impl is None:
        impl = os.environ.get("REPRO_KERNEL_IMPL")
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert impl in VALID, impl
    return impl
