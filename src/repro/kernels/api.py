"""Model-facing kernel API (single import point for models/)."""
from repro.kernels.decode_attention.ops import (  # noqa: F401
    decode_attention,
    decode_attention_partial,
    merge_partials,
)
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.ssd_scan.ops import (  # noqa: F401
    ssd_decode_step,
    ssd_scan,
    ssd_scan_naive,
)
from repro.kernels.weakhash_route.ops import (  # noqa: F401
    RouteResult,
    combine,
    dispatch,
    weakhash_route,
)
