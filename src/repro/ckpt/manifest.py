"""Checkpoint manifests: content-addressed, idempotent, region-merged.

A *region snapshot* records one region's state at one step (file keys are
content hashes — duplicated replica weights dedup automatically). The
manifest keeps per-region snapshot histories; `merge_view` implements the
paper's region-checkpoint semantics:

* γ=full  → newest step at which EVERY region has a successful snapshot
            (a region-upload failure keeps the previous snapshot alive, so
            the checkpoint attempt degrades instead of aborting);
* γ=partial → latest snapshot per region (bounded staleness — the paper's
            loss-tolerant completeness relaxation).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from repro.ckpt.storage import content_key


@dataclasses.dataclass(frozen=True)
class RegionSnapshot:
    region_id: int
    step: int
    keys: dict[str, str]      # leaf-path → content key
    nbytes: int
    wall_s: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "RegionSnapshot":
        return RegionSnapshot(**d)


class Manifest:
    def __init__(self, job_id: str, n_regions: int):
        self.job_id = job_id
        self.n_regions = n_regions
        self.history: dict[int, list[RegionSnapshot]] = {
            r: [] for r in range(n_regions)}
        self.meta: dict[str, Any] = {}

    # -- record -----------------------------------------------------------
    def add(self, snap: RegionSnapshot) -> None:
        self.history.setdefault(snap.region_id, []).append(snap)

    def latest(self, region_id: int) -> RegionSnapshot | None:
        h = self.history.get(region_id) or []
        return max(h, key=lambda s: s.step) if h else None

    def steps_with_all_regions(self) -> list[int]:
        if not all(self.history.get(r) for r in range(self.n_regions)):
            return []
        sets = [set(s.step for s in self.history[r])
                for r in range(self.n_regions)]
        return sorted(set.intersection(*sets))

    # -- merge view (the paper's mechanism) --------------------------------
    def merge_view(self, gamma: str, step: int | None = None
                   ) -> dict[int, RegionSnapshot]:
        if gamma == "full":
            steps = self.steps_with_all_regions()
            if not steps:
                raise LookupError("no globally consistent checkpoint")
            target = step if step is not None else steps[-1]
            if target not in steps:
                raise LookupError(f"step {target} not consistent; have {steps}")
            return {r: next(s for s in self.history[r] if s.step == target)
                    for r in range(self.n_regions)}
        view = {}
        for r in range(self.n_regions):
            snap = self.latest(r)
            if snap is None:
                raise LookupError(f"region {r} has no snapshot at all")
            view[r] = snap
        return view

    def staleness(self, view: dict[int, RegionSnapshot]) -> dict[int, int]:
        newest = max(s.step for s in view.values())
        return {r: newest - s.step for r, s in view.items()}

    # -- persistence (idempotent: content-addressed body + LATEST pointer) --
    def to_bytes(self) -> bytes:
        body = {
            "job_id": self.job_id,
            "n_regions": self.n_regions,
            "meta": self.meta,
            "history": {str(r): [s.to_json() for s in hs]
                        for r, hs in self.history.items()},
        }
        return json.dumps(body, sort_keys=True).encode()

    def save(self, storage) -> str:
        data = self.to_bytes()
        key = f"manifests/{self.job_id}/{content_key(data)}.json"
        storage.put(key, data)
        storage.put(f"manifests/{self.job_id}/LATEST",
                    key.encode())  # atomic pointer swap
        return key

    @staticmethod
    def load(storage, job_id: str) -> "Manifest":
        key = storage.get(f"manifests/{job_id}/LATEST").decode()
        body = json.loads(storage.get(key))
        m = Manifest(body["job_id"], body["n_regions"])
        m.meta = body.get("meta", {})
        for r, hs in body["history"].items():
            m.history[int(r)] = [RegionSnapshot.from_json(s) for s in hs]
        return m
