"""Storage backends for checkpoints & metadata.

Production mapping (paper §IV-B, HDFS fault tolerance): a primary store with
HA semantics (SimHDFS — latency model + chaos-injected slow uploads /
failures / namenode outages) and a durable fallback (object store), combined
by FallbackStorage with exponential backoff + idempotent (atomic, content-
addressed) writes.
"""
from __future__ import annotations

import hashlib
import os
import pathlib
import tempfile
import threading

from repro.core.backoff import PermanentError, RetryPolicy, TransientError, retry
from repro.core.chaos import ChaosEngine
from repro.core.clock import WallClock


class StorageUnavailable(TransientError):
    pass


class LocalFS:
    """Atomic-rename local filesystem store (the durability primitive)."""

    def __init__(self, root: str | os.PathLike):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> pathlib.Path:
        p = self.root / key
        p.parent.mkdir(parents=True, exist_ok=True)
        return p

    def put(self, key: str, data: bytes) -> str:
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)  # atomic → idempotent retries are safe
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return content_key(data)

    def get(self, key: str) -> bytes:
        try:
            return self._path(key).read_bytes()
        except FileNotFoundError:
            raise KeyError(key) from None

    def exists(self, key: str) -> bool:
        return self._path(key).exists()

    def delete(self, key: str) -> None:
        try:
            self._path(key).unlink()
        except FileNotFoundError:
            pass

    def list(self, prefix: str = "") -> list[str]:
        base = self.root
        return sorted(str(p.relative_to(base)) for p in base.rglob("*")
                      if p.is_file() and str(p.relative_to(base)).startswith(prefix)
                      and not p.name.startswith(".tmp-"))


def content_key(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()[:32]


class SimHDFS:
    """HDFS stand-in: bandwidth/latency model + chaos injection.

    Time is charged to `clock` (virtual in simulations) so checkpoint-duration
    experiments (Fig 8) are deterministic.
    """

    def __init__(self, root, *, clock=None, chaos: ChaosEngine | None = None,
                 bandwidth_bps: float = 1e9, base_latency_s: float = 0.02):
        self.fs = LocalFS(root)
        self.clock = clock or WallClock()
        self.chaos = chaos or ChaosEngine()
        self.bandwidth_bps = bandwidth_bps
        self.base_latency_s = base_latency_s
        self.available = True  # namenode availability (HA drills)
        self.put_count = 0
        self.slow_puts = 0
        self.slow_gets = 0
        # single-pipeline queueing: an op whose arrival lands while a
        # previous op's (brownout-stretched) service is still draining
        # waits for it, so queue delay scales with `brownout_factor_at`
        # through the service times it inherits (paper §IV: brownouts
        # back up the upload pipeline, they don't just stretch ops
        # independently). Concurrent issuers pass `arrival_s` (e.g. all
        # regions of one snapshot arrive at the snapshot instant).
        self._busy_until = 0.0
        self.queue_wait_s = 0.0

    def _charge(self, nbytes: int, kind: str = "put",
                arrival_s: float | None = None) -> float:
        now = self.clock.now()
        arrival = now if arrival_s is None else min(float(arrival_s), now)
        start = max(now, self._busy_until)
        wait = start - arrival
        # rng slow-factor draw × deterministic brownout ramp at wall time
        # (brownout-stretched ops count as slow: factor > 1 either way)
        factor = (self.chaos.storage_latency_factor()
                  * self.chaos.brownout_factor(start))
        dur = (self.base_latency_s + nbytes / self.bandwidth_bps) * factor
        if factor > 1.0:
            if kind == "put":
                self.slow_puts += 1
            else:
                self.slow_gets += 1
        self.queue_wait_s += wait
        self._busy_until = start + dur
        self.clock.sleep(start + dur - now)
        return wait + dur

    def put(self, key: str, data: bytes, *,
            arrival_s: float | None = None) -> str:
        if not self.available:
            raise StorageUnavailable("namenode down")
        self.put_count += 1
        self._charge(len(data), kind="put", arrival_s=arrival_s)
        if self.chaos.storage_fails():
            raise StorageUnavailable("datanode write failed")
        return self.fs.put(key, data)

    def get(self, key: str, *, arrival_s: float | None = None) -> bytes:
        if not self.available:
            raise StorageUnavailable("namenode down")
        data = self.fs.get(key)
        self._charge(len(data), kind="get", arrival_s=arrival_s)
        return data

    def exists(self, key: str) -> bool:
        if not self.available:
            raise StorageUnavailable("namenode down")
        return self.fs.exists(key)

    def delete(self, key: str) -> None:
        self.fs.delete(key)

    def list(self, prefix: str = "") -> list[str]:
        if not self.available:
            raise StorageUnavailable("namenode down")
        return self.fs.list(prefix)


class ObjectStoreSim(SimHDFS):
    """Fallback durable store: higher latency, no chaos (always available)."""

    def __init__(self, root, *, clock=None, bandwidth_bps: float = 2e8,
                 base_latency_s: float = 0.1):
        super().__init__(root, clock=clock, chaos=ChaosEngine(),
                         bandwidth_bps=bandwidth_bps,
                         base_latency_s=base_latency_s)


class FallbackStorage:
    """Primary-with-fallback store (paper: 'augmenting HDFS with alternative
    durable storage backends provides resilience against prolonged outages').

    put: retry primary with backoff; on give-up, write to fallback.
    get: primary first, fallback second.
    """

    def __init__(self, primary, fallback, *, policy: RetryPolicy | None = None,
                 clock=None, seed: int = 0):
        self.primary = primary
        self.fallback = fallback
        self.policy = policy or RetryPolicy(base_delay_s=0.05, max_attempts=4)
        self.clock = clock or WallClock()
        self.seed = seed
        self.fallback_puts = 0

    def put(self, key: str, data: bytes) -> str:
        try:
            out, _ = retry(lambda: self.primary.put(key, data), self.policy,
                           self.clock, seed=self.seed)
            return out
        except PermanentError:
            self.fallback_puts += 1
            return self.fallback.put(key, data)

    def get(self, key: str) -> bytes:
        try:
            return self.primary.get(key)
        except (KeyError, TransientError):
            return self.fallback.get(key)

    def exists(self, key: str) -> bool:
        try:
            if self.primary.exists(key):
                return True
        except TransientError:
            pass
        return self.fallback.exists(key)

    def list(self, prefix: str = "") -> list[str]:
        keys = set()
        try:
            keys.update(self.primary.list(prefix))
        except TransientError:
            pass
        keys.update(self.fallback.list(prefix))
        return sorted(keys)
