"""StreamShield-JAX: production resiliency framework for multi-pod JAX
training/serving, reproducing "StreamShield: A Production-Proven Resiliency
Solution for Apache Flink at ByteDance" (CS.DB 2026) on TPU-native substrate.
"""
__version__ = "0.1.0"
