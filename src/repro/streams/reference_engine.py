"""Reference (pre-vectorization) stream engine — the per-edge interpreter.

This is the seed implementation of `streams.engine.StreamEngine`, kept
verbatim as the semantic oracle: `tests/test_engine_vectorized.py` pins the
vectorized engine's metrics against it, and `benchmarks/bench_engine.py`
measures the speedup ratio against it. Do not optimize this file — its whole
point is to stay the slow, obviously-correct baseline.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro.core.chaos import ChaosEngine, burst_kill_schedule
from repro.streams.engine import CheckpointConfig, FailoverConfig
from repro.streams.graph import LogicalGraph, PhysicalGraph, expand


@dataclasses.dataclass
class ReferenceEngineMetrics:
    t: list = dataclasses.field(default_factory=list)
    qps: dict = dataclasses.field(default_factory=lambda: defaultdict(list))
    backlog: dict = dataclasses.field(default_factory=lambda: defaultdict(list))
    source_lag: list = dataclasses.field(default_factory=list)
    dropped: float = 0.0
    emitted: float = 0.0
    ckpt_attempts: int = 0
    ckpt_success: int = 0
    ckpt_failed: int = 0
    recoveries: list = dataclasses.field(default_factory=list)


class ReferenceStreamEngine:
    def __init__(self, graph: LogicalGraph, *, n_hosts: int = 8,
                 dt: float = 0.5, queue_cap: float = 256.0,
                 chaos: ChaosEngine | None = None,
                 failover: FailoverConfig | None = None,
                 ckpt: CheckpointConfig | None = None,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0):
        self.g = graph
        self.phys: PhysicalGraph = expand(graph, n_hosts=n_hosts, seed=seed)
        self.dt = dt
        self.queue_cap = queue_cap
        self.chaos = chaos or ChaosEngine()
        self.failover = failover or FailoverConfig()
        self.ckpt_cfg = ckpt
        self.rng = np.random.default_rng(seed)
        self.metrics = ReferenceEngineMetrics()
        self.t = 0.0
        self._next_ckpt = (self.ckpt_cfg.interval_s if ckpt else math.inf)

        ops = {o.name: o for o in graph.ops}
        self.par = {n: ops[n].parallelism for n in ops}
        # credit budget per task: a few ticks of service capacity (bounded
        # buffers = credit-based flow control)
        self.qcap = {n: max(ops[n].service_rate * dt * 4.0, queue_cap)
                     for n in ops}
        # per-op per-task state
        self.queue = {n: np.zeros(self.par[n]) for n in ops}
        self.down_until = {n: np.zeros(self.par[n]) for n in ops}
        self.speed = {n: np.ones(self.par[n]) for n in ops}
        if task_speed_override:
            for t in self.phys.tasks:
                if t.task_id in task_speed_override:
                    self.speed[t.op][t.index] = task_speed_override[t.task_id]
        # chaos host stragglers
        for t in self.phys.tasks:
            self.speed[t.op][t.index] *= self.chaos.host_speed(t.host)
        # external-system events: region-correlated failure bursts are
        # deterministic scheduled kills (no rng), lazy-load restore
        # staggers a region's ready time by its rank
        task_host = np.array([t.host for t in self.phys.tasks])
        task_region = np.array(
            [self.phys.task_region[t.task_id] for t in self.phys.tasks])
        if self.chaos.spec.burst_at:
            self.chaos.schedule_kills(burst_kill_schedule(
                self.chaos.spec.burst_at, task_host, task_region))
        first = int(task_region.min()) if len(task_region) else 0
        self._lazy = ((task_region - first).astype(float)
                      * self.failover.lazyload_stagger_s)
        self._last_ckpt_t = 0.0
        # hashed key-mass shares per keyed edge (Zipf skew)
        self._key_share: dict[tuple[str, str], np.ndarray] = {}
        for e in graph.edges:
            if e.partitioner in ("hash", "weakhash") or e.key_skew_zipf:
                nd = self.par[e.dst]
                nkeys = max(nd * 64, 1024)
                if e.key_skew_zipf > 0:
                    mass = 1.0 / np.arange(1, nkeys + 1) ** e.key_skew_zipf
                else:
                    mass = np.ones(nkeys)
                mass /= mass.sum()
                owner = (np.arange(nkeys) * 2654435761 % nd).astype(int)
                share = np.bincount(owner, weights=mass, minlength=nd)
                self._key_share[(e.src, e.dst)] = share

    # ------------------------------------------------------------------
    def _alive(self, op: str) -> np.ndarray:
        return self.down_until[op] <= self.t

    def _edge_weights(self, e, free_down: np.ndarray) -> np.ndarray:
        """Row-stochastic (n_src, n_dst) routing weights for this tick."""
        conn = self.phys.channels[(e.src, e.dst)].astype(float)
        ns, nd = conn.shape
        alive_d = self._alive(e.dst).astype(float)
        base = conn * alive_d[None, :]

        if e.partitioner in ("rebalance", "rescale", "group_rescale",
                             "forward"):
            w = base
        elif e.partitioner == "hash":
            # strict keyBy: key→task binding cannot divert around dead or
            # congested tasks (records to a dead task are lost under
            # single-task recovery — the γ=partial trade)
            share = self._key_share[(e.src, e.dst)]
            w = conn * share[None, :]
        elif e.partitioner == "weakhash":
            # key mass per group redistributes within the group ∝ free space
            share = self._key_share[(e.src, e.dst)]
            g = e.n_groups
            w = np.zeros_like(base)
            for grp in range(g):
                lo, hi = grp * nd // g, (grp + 1) * nd // g
                mass = share[lo:hi].sum()
                cap = np.maximum(free_down[lo:hi], 1e-9) * alive_d[lo:hi]
                if cap.sum() <= 0:
                    cap = alive_d[lo:hi] + 1e-9
                w[:, lo:hi] = base[:, lo:hi] * (mass * cap / cap.sum())[None, :]
        elif e.partitioner == "backlog":
            cap = self.qcap[e.dst]
            open_ = (free_down > cap * 0.25).astype(float)
            w = base * np.maximum(free_down, 1e-9)[None, :] * \
                np.maximum(open_, 0.05)[None, :]
        else:
            raise ValueError(e.partitioner)
        rs = w.sum(axis=1, keepdims=True)
        return np.divide(w, rs, out=np.zeros_like(w), where=rs > 0)

    # ------------------------------------------------------------------
    def tick(self) -> None:
        dt = self.dt
        g = self.g
        order = g.topo_order()
        free = {n: np.maximum(self.qcap[n] - self.queue[n], 0.0)
                for n in order}
        qps_tick = {n: 0.0 for n in order}
        drop_tick = 0.0

        # MQ/coordinator outage gate: sources emit nothing while the
        # message queue is down (multiplying by 1.0 is exact, so the
        # no-outage path keeps the historical numbers bit-for-bit)
        gate = 1.0 if self.chaos.mq_available(self.t) else 0.0
        # traffic dynamics: deterministic diurnal/flash-crowd source-rate
        # multiplier (empty schedules → exactly 1.0, so the multiply is
        # skipped and historical numbers stay bit-for-bit)
        tf = self.chaos.traffic_factor(self.t)

        for name in order:
            op = g.op(name)
            alive = self._alive(name)
            if op.is_source:
                produced = np.full(self.par[name],
                                   op.source_rate * dt / self.par[name])
                produced *= alive
                if gate != 1.0:
                    produced = produced * gate
                if tf != 1.0:
                    produced = produced * tf
                self.metrics.emitted += produced.sum()
            else:
                cap = op.service_rate * dt * self.speed[name] * alive
                take = np.minimum(self.queue[name], cap)
                self.queue[name] -= take
                produced = take * op.selectivity
                qps_tick[name] = take.sum() / dt

            outs = g.downstream(name)
            if not outs:
                continue
            for e in outs:
                w = self._edge_weights(e, free[e.dst])
                arriving = produced @ w                  # (n_dst,)
                dead = ~self._alive(e.dst)
                # single-task recovery: records keyed/routed to a dead task
                # are dropped (γ=partial) — they cannot stall the pipeline
                if dead.any() and self.failover.mode == "single_task":
                    drop_tick += arriving[dead].sum()
                    arriving = np.where(dead, 0.0, arriving)
                room = free[e.dst]
                if e.partitioner in ("rebalance", "rescale", "forward",
                                     "hash"):
                    # static routing = head-of-line blocking: the most
                    # congested live channel throttles the whole exchange
                    # (credit-based flow control, paper §III-A)
                    live = arriving > 1e-9
                    lam = float(np.min(room[live] / arriving[live])) \
                        if live.any() else 1.0
                    lam = min(1.0, lam)
                    accepted = arriving * lam
                elif e.partitioner == "group_rescale":
                    # blocking confined to each group (Fig 2c): a straggler
                    # stalls its group only
                    nd = len(arriving)
                    gcount = max(e.n_groups, 1)
                    accepted = np.zeros_like(arriving)
                    for grp in range(gcount):
                        lo, hi = grp * nd // gcount, (grp + 1) * nd // gcount
                        a, r = arriving[lo:hi], room[lo:hi]
                        live = a > 1e-9
                        lam = float(np.min(r[live] / a[live])) \
                            if live.any() else 1.0
                        accepted[lo:hi] = a * min(1.0, lam)
                else:
                    # adaptive routing (backlog/weakhash): channels accept up
                    # to their credits; remainder re-queues for re-routing
                    accepted = np.minimum(arriving, room)
                overflow = (arriving - accepted).sum()
                self.queue[name] += overflow / max(self.par[name], 1)
                self.queue[e.dst] += accepted
                free[e.dst] = np.maximum(free[e.dst] - accepted, 0.0)

        # chaos host kills → failover
        kills = self.chaos.step_kills(self.t, self.t + dt,
                                      n_hosts=max(t.host for t in
                                                  self.phys.tasks) + 1)
        for host in kills:
            self._fail_host(host)

        # checkpoint coordinator
        if self.t + dt >= self._next_ckpt:
            self._run_checkpoint()
            self._next_ckpt += self.ckpt_cfg.interval_s

        self.metrics.t.append(self.t)
        for n in order:
            self.metrics.qps[n].append(qps_tick[n])
            self.metrics.backlog[n].append(float(self.queue[n].sum()))
        src = [n for n in order if g.op(n).is_source]
        self.metrics.source_lag.append(
            float(sum(self.queue[n].sum() for n in src)))
        self.metrics.dropped += drop_tick
        self.t += dt

    def run(self, duration_s: float) -> ReferenceEngineMetrics:
        n = int(round(duration_s / self.dt))
        for _ in range(n):
            self.tick()
        return self.metrics

    # ------------------------------------------------------------------
    def _fail_host(self, host: int) -> None:
        fo = self.failover
        victims = [t for t in self.phys.tasks if t.host == host]
        if not victims or fo.mode == "none":
            self.chaos.revive(host)
            return
        # passive-restore surcharge at kill time: checkpoint re-read
        # stretched by the storage brownout, plus replay of work since
        # the last successful checkpoint, plus the task's own lazy-load
        # region ready-time (hot_standby never touches the checkpoint,
        # so it pays none of this)
        extra = np.zeros(len(self.phys.tasks))
        if fo.restore_base_s or fo.replay_rate or fo.lazyload_stagger_s:
            bf = self.chaos.brownout_factor(self.t)
            age = self.t - self._last_ckpt_t
            extra = (fo.restore_base_s * bf + age * fo.replay_rate
                     + self._lazy)
        if fo.mode == "hot_standby":
            down = (fo.detect_s + fo.standby_switch_s
                    + fo.standby_staleness_s)
            until = self.t + down
            for t in victims:
                self.down_until[t.op][t.index] = until
                self.queue[t.op][t.index] = 0.0
            self.metrics.recoveries.append(
                {"t": self.t, "mode": "hot_standby",
                 "tasks": len(victims), "downtime": down})
        elif fo.mode == "single_task":
            base = fo.detect_s + fo.single_restart_s
            for i, t in enumerate(self.phys.tasks):
                if t.host == host:
                    self.down_until[t.op][t.index] = (
                        self.t + (base + extra[i]))
                    self.queue[t.op][t.index] = 0.0  # output discarded
            self.metrics.recoveries.append(
                {"t": self.t, "mode": "single_task", "tasks": len(victims),
                 "downtime": float(base + extra[0])})
        else:
            regions = {self.phys.task_region[t.task_id] for t in victims}
            base = fo.detect_s + fo.region_restart_s
            n_restart = 0
            for i, t in enumerate(self.phys.tasks):
                if self.phys.task_region[t.task_id] in regions:
                    self.down_until[t.op][t.index] = (
                        self.t + (base + extra[i]))
                    self.queue[t.op][t.index] = 0.0
                    n_restart += 1
            self.metrics.recoveries.append(
                {"t": self.t, "mode": "region", "tasks": n_restart,
                 "downtime": float(base + extra[0])})
        self.chaos.revive(host)  # replacement host

    # ------------------------------------------------------------------
    def _run_checkpoint(self) -> None:
        cfg = self.ckpt_cfg
        m = self.metrics
        m.ckpt_attempts += 1
        timeout = cfg.interval_s
        # deterministic brownout ramp stretches every upload of this
        # attempt (computed BEFORE any rng draw — same order as
        # core.chaos.run_checkpoint_attempt)
        bf = self.chaos.brownout_factor(self.t)
        # per-task upload durations with chaos slow factors
        task_fail: dict[int, bool] = {}
        for t in self.phys.tasks:
            dur = cfg.upload_s * self.chaos.storage_latency_factor() * bf
            task_fail[t.task_id] = dur > timeout or not self._alive(t.op)[t.index]
        if cfg.mode == "global":
            ok = not any(task_fail.values())
        else:
            ok = True
            for region in self.phys.regions:
                bad = any(task_fail[tid] for tid in region)
                if bad and cfg.retry_failed_region:
                    # one in-attempt retry of the region's uploads
                    bad = any(cfg.upload_s
                              * self.chaos.storage_latency_factor() * bf
                              > timeout for _ in region)
                if bad:
                    ok = False  # region keeps previous snapshot; attempt
                    break       # counted failed, job continues (no abort)
        m.ckpt_success += int(ok)
        m.ckpt_failed += int(not ok)
        if ok:
            self._last_ckpt_t = self.t
