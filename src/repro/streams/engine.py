"""Credit-based stream engine — precompiled routing plan over a flat task
arena (vectorized micro-tick simulator).

Architecture
------------
`StreamEngine.__init__` lowers the logical graph into a static **routing
plan** so that `tick()` touches no per-task, per-group or per-dst Python
loops:

* **Task arena** — one contiguous float array per state variable
  (`queue`, `speed`, `down_until`, `qcap`), indexed by global task id.
  Tasks of an op occupy a contiguous slice (`expand()` numbers them that
  way), so per-op views are zero-copy slices of the arena.
* **Op plan** — cached topo order plus per-op scalars (service rate,
  selectivity, source rate, arena slice) resolved once.
* **Edge plans** — for every logical edge the per-tick routing weight
  matrix of the reference interpreter collapses analytically:

    - all-to-all hops (rebalance / hash / weakhash / backlog) have
      identical weight rows, so `produced @ W == produced.sum() * w_row`
      — O(n_dst) instead of O(n_src · n_dst);
    - blocky hops (rescale / group_rescale) reduce to CSR-style segment
      sums over precomputed block boundaries (`np.bincount` /
      `np.add.reduceat` / `np.minimum.reduceat`);
    - forward is elementwise.

  Static key-mass shares (Zipf-skewed `keyBy`) and per-group mass sums
  are precomputed into the plan.
* **Metric buffers** — metrics append into preallocated, doubling numpy
  buffers (`EngineMetrics`); per-tick cost is one row write instead of
  O(ops) list appends, and consumers get zero-copy array views.

Semantics are pinned (within float round-off) to the per-edge reference
interpreter preserved in `streams/reference_engine.py`; see
`tests/test_engine_vectorized.py`. Each tick (dt): sources emit, every task
consumes from its bounded input queue at service_rate × host_speed and
pushes downstream according to the edge's partitioner weights. Bounded
queues give credit-based backpressure (paper §III-A). Partitioner weight
policies:

  rebalance / rescale / group_rescale — uniform over connected tasks
  hash      — static weights ∝ hashed key mass (Zipf-skewed when configured)
  weakhash  — key-group mass spread within the group ∝ free capacity
  backlog   — uniform over channels whose backlog < threshold (divert)

Failover (paper §III-B): "region" restarts every task of the failed task's
region (downtime = restore+redeploy); "single_task" restarts only the failed
task while upstream records destined to it are DROPPED (γ=partial, counted).

The checkpoint coordinator implements Fig 8: per-task uploads with
chaos-injected slow factors against the interval timeout; global mode aborts
on any failure, region mode merges + retries the failed region once.

Multi-job mega-arena (paper's cluster perspective)
--------------------------------------------------
`pack_arena(graphs, host_map)` concatenates K co-located job graphs into
ONE flat arena sharing a host pool: ops are namespaced ``j{k}.``, tasks
get arena-global ids (per-job contiguous slices), regions never merge
across jobs, and each job's local round-robin host placement is lifted
into the pool through a per-job host map ("shared" co-locates everything,
"disjoint" reproduces K independent clusters exactly). Both engines accept
the `PackedArena` in place of a graph; a chaos host kill then fans out to
every co-located job on that host while metrics stay segmentable per job
(`job_of_op` / `job_of_task`, per-job emitted/dropped, per-job recovery
events). See the `PackedArena` docstring for the full layout contract.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.chaos import (ChaosEngine, burst_kill_schedule,
                              failover_recovery_entries,
                              run_checkpoint_attempt)
from repro.streams.graph import (LogicalGraph, PhysicalGraph, Task, expand,
                                 namespaced)


@dataclasses.dataclass
class FailoverConfig:
    # "region" | "single_task" | "hot_standby" | "none"
    mode: str = "region"
    detect_s: float = 1.0
    region_restart_s: float = 45.0   # restore state + redeploy the region
    single_restart_s: float = 3.0    # redeploy one task, clean state
    # hybrid replication (paper §IV-A): hot_standby pays switch latency +
    # replay of standby staleness INSTEAD of a checkpoint restore
    standby_switch_s: float = 0.05
    standby_staleness_s: float = 0.5
    # passive-restore surcharge (added to region/single downtimes):
    # restore_base_s is scaled by the storage-brownout factor at kill
    # time (restore bandwidth degrades with the ramp), replay_rate is
    # seconds of replay per second of checkpoint age, and
    # lazyload_stagger_s staggers region ready-times — a task blocks
    # until its own region is materialized (State LazyLoad, §III-B)
    restore_base_s: float = 0.0
    replay_rate: float = 0.0
    lazyload_stagger_s: float = 0.0

    @classmethod
    def from_replication(cls, timing, *, mode: str = "hot_standby",
                         state_bytes: float = 0.0,
                         detect_s: float | None = None) -> "FailoverConfig":
        """Lower a `core.replication.TimingModel` into tick-engine
        failover parameters (active replication → `hot_standby`; passive
        → checkpoint restore whose cost scales with state size, restore
        bandwidth, and checkpoint age)."""
        kw = timing.tick_failover_kwargs(nbytes=state_bytes)
        if detect_s is not None:
            kw["detect_s"] = detect_s
        return cls(mode=mode, **kw)


@dataclasses.dataclass
class CheckpointConfig:
    interval_s: float = 30.0
    mode: str = "region"             # "region" | "global"
    upload_s: float = 4.0            # nominal per-task upload duration
    retry_failed_region: bool = True


@dataclasses.dataclass
class UpgradeConfig:
    """Deployment-drill policy (paper §V): the HOW of a canaried rolling
    upgrade. The WHEN comes from ``ChaosSpec.upgrade_at`` (first entry;
    per-job chaos lists schedule per job), falling back to
    ``t_upgrade_s``. Upgrades are deterministic in-trace events: they
    consume NO rng draws and never touch the pregenerated chaos
    timelines — both engines implement waves, canary config divergence
    and auto-rollback as pure time arithmetic inside the tick.

    A drill canaries the first ``round(canary_frac * n_jobs)`` jobs of a
    packed arena (or the explicit ``canary_jobs`` indices). Canaried
    jobs restart region-sized task slices on a ``wave_stagger_s``
    cadence, each wave paying ``wave_down_s`` of downtime — by default
    the hot-vs-cold `core.hotupdate.deploy_downtime` cost lowered from
    ``startup`` (a `core.startup.StartupConfig`; None = its defaults).
    Once a task's wave completes, the task runs ``canary_failover`` /
    ``canary_ckpt`` / ``canary_sel_scale`` instead of the base configs
    (None = unchanged; canary lazyload staggers are ignored). A drill
    controller EWMAs the canary-vs-stable mean-queue delta over
    ``rollback_window_s`` and, once it exceeds ``rollback_threshold``
    (default inf = never), schedules a rollback: the canary slice
    reverts to the base config and pays a second restart wave. Upgrade
    and rollback waves are *graceful* — queues persist, unlike crash
    failover — so an upgrade to an identical config with
    ``wave_down_s=0`` is an exact no-op."""
    t_upgrade_s: float = 30.0
    wave_stagger_s: float = 2.0
    hot: bool = True
    startup: object | None = None    # core.startup.StartupConfig
    wave_down_s: float | None = None  # override deploy_downtime lowering
    canary_frac: float = 0.5
    canary_jobs: tuple | None = None  # explicit job indices (overrides frac)
    rollback_threshold: float = math.inf
    rollback_window_s: float = 5.0
    canary_failover: FailoverConfig | None = None
    canary_ckpt: CheckpointConfig | None = None
    canary_sel_scale: float = 1.0


def inert_upgrade_leaves(n_tasks: int) -> dict:
    """Drill parameter leaves of a drill-free run: the traced arithmetic
    stays structurally present (stable pytree → one trace for drill and
    non-drill configs) but is an exact arithmetic no-op — act masks are
    identically zero, wave starts are +inf, the controller never arms."""
    z = lambda: np.zeros(n_tasks)                      # noqa: E731
    return {
        "up_cmask": z(), "up_start": np.full(n_tasks, np.inf),
        "up_rstag": np.full(n_tasks, np.inf), "up_wdelta": z(),
        "d_down_s": z(), "d_down_r": z(), "d_down_h": z(),
        "d_mode_s": z(), "d_mode_r": z(), "d_mode_h": z(),
        "d_restore": z(), "d_replay": z(), "d_sel": z(), "d_ck": z(),
        "up_t0": np.float64(np.inf), "up_down": np.float64(0.0),
        "up_thresh": np.float64(np.inf), "up_alpha": np.float64(0.0),
    }


def lower_upgrade(upgrade: UpgradeConfig | None, spec, *, n_tasks: int,
                  job_of_task, task_region, dt: float, base_failover,
                  base_ckpt, sel_task) -> dict:
    """Lower an `UpgradeConfig` into the traced drill parameter leaves
    shared by the numpy and JAX engines (identical float arithmetic —
    the parity contract):

    * ``up_cmask`` — 1.0 on tasks of canaried jobs;
    * ``up_start`` — absolute upgrade-wave start per task
      (``t_up(job) + region_rank * wave_stagger_s``; +inf off-canary);
    * ``up_rstag`` — rollback-wave stagger per task (+inf off-canary so
      a fired rollback never restarts stable tasks);
    * ``up_wdelta`` — controller weights: mean-canary minus mean-stable
      queue in one dot product;
    * ``d_down_*`` / ``d_mode_*`` / ``d_restore`` / ``d_replay`` —
      canary-minus-base failover deltas, applied as ``base + act * d``
      with the traced 0/1 activation mask;
    * ``d_sel`` — canary selectivity delta (``sel * (scale - 1)``);
    * ``d_ck`` — canary checkpoint-interval ratio minus one, scaling the
      replay-age term (the shared attempt/draw stream is untouched —
      jobs whose base config never checkpoints ignore ``canary_ckpt``);
    * scalars ``up_t0`` (controller arming time: first canary wave end),
      ``up_down`` (per-wave downtime), ``up_thresh``, ``up_alpha``
      (EWMA coefficient ``dt / rollback_window_s``).

    `spec` is a `ChaosSpec` or per-job list; `base_failover` is the
    `per_task_failover` tuple of the base config; `sel_task` the per-task
    base selectivity vector. ``upgrade=None`` returns the inert leaves."""
    if upgrade is None:
        return inert_upgrade_leaves(n_tasks)
    from repro.core.chaos import ChaosSpec
    from repro.core.hotupdate import deploy_downtime

    jot = (np.zeros(n_tasks, dtype=int) if job_of_task is None
           else np.asarray(job_of_task))
    n_jobs = int(jot.max()) + 1 if n_tasks else 1
    if upgrade.canary_jobs is not None:
        cjob = np.zeros(n_jobs, dtype=bool)
        cjob[np.asarray(list(upgrade.canary_jobs), dtype=int)] = True
    else:
        k = max(0, min(n_jobs,
                       int(round(upgrade.canary_frac * n_jobs + 1e-9))))
        cjob = np.arange(n_jobs) < k
    cmask = cjob[jot].astype(float)

    if isinstance(spec, (list, tuple)):
        specs = list(spec)
        if len(specs) != n_jobs:
            raise ValueError(f"per-job chaos list must have one entry "
                             f"per job ({len(specs)} != {n_jobs})")
    else:
        specs = [spec] * n_jobs

    def _t_up(sp):
        sp = sp.spec if isinstance(sp, ChaosEngine) else (sp or ChaosSpec())
        ups = tuple(sp.upgrade_at)
        return float(ups[0]) if ups else float(upgrade.t_upgrade_s)

    t_up_j = np.array([_t_up(sp) for sp in specs])
    rank = region_rank(task_region, job_of_task)
    stag = float(upgrade.wave_stagger_s)
    up_down = (float(upgrade.wave_down_s)
               if upgrade.wave_down_s is not None
               else deploy_downtime(upgrade.startup, hot=upgrade.hot))
    canary = cmask > 0
    up_start = np.where(canary, t_up_j[jot] + rank * stag, np.inf)
    up_rstag = np.where(canary, rank * stag, np.inf)
    n_can = float(cmask.sum())
    n_st = float(n_tasks) - n_can
    up_wdelta = (cmask / max(n_can, 1.0)
                 - (1.0 - cmask) / max(n_st, 1.0))
    up_t0 = (float(t_up_j[cjob].min()) + up_down if cjob.any()
             else np.inf)

    b_codes, b_det, b_rs, b_rr, b_fx = base_failover
    if upgrade.canary_failover is not None:
        c_codes, c_det, c_rs, c_rr, c_fx = per_task_failover(
            upgrade.canary_failover, n_tasks, job_of_task)
    else:
        c_codes, c_det, c_rs, c_rr, c_fx = (b_codes, b_det, b_rs, b_rr,
                                            b_fx)
    fcode = lambda codes, v: (np.asarray(codes) == v).astype(float)  # noqa: E731
    d_ck = np.zeros(n_tasks)
    if upgrade.canary_ckpt is not None and base_ckpt is not None:
        if isinstance(base_ckpt, CheckpointConfig):
            b_int = np.full(n_tasks, float(base_ckpt.interval_s))
        else:
            b_int = np.array([float(c.interval_s) if c is not None
                              else np.inf for c in base_ckpt])[jot]
        ok = np.isfinite(b_int) & (b_int > 0)
        d_ck = np.where(
            ok, cmask * (float(upgrade.canary_ckpt.interval_s)
                         / np.where(ok, b_int, 1.0) - 1.0), 0.0)
    return {
        "up_cmask": cmask,
        "up_start": up_start,
        "up_rstag": up_rstag,
        "up_wdelta": up_wdelta,
        "d_down_s": cmask * ((c_det + c_rs) - (b_det + b_rs)),
        "d_down_r": cmask * ((c_det + c_rr) - (b_det + b_rr)),
        "d_down_h": cmask * ((c_det + c_fx["switch"] + c_fx["stale"])
                             - (b_det + b_fx["switch"] + b_fx["stale"])),
        "d_mode_s": cmask * (fcode(c_codes, 2) - fcode(b_codes, 2)),
        "d_mode_r": cmask * (fcode(c_codes, 1) - fcode(b_codes, 1)),
        "d_mode_h": cmask * (fcode(c_codes, 3) - fcode(b_codes, 3)),
        "d_restore": cmask * (c_fx["restore_base"] - b_fx["restore_base"]),
        "d_replay": cmask * (c_fx["replay_rate"] - b_fx["replay_rate"]),
        "d_sel": cmask * np.asarray(sel_task, float)
        * (float(upgrade.canary_sel_scale) - 1.0),
        "d_ck": d_ck,
        "up_t0": np.float64(up_t0),
        "up_down": np.float64(up_down),
        "up_thresh": np.float64(upgrade.rollback_threshold),
        "up_alpha": np.float64(min(1.0, dt / max(
            float(upgrade.rollback_window_s), dt))),
    }


@dataclasses.dataclass
class AutoscaleConfig:
    """In-trace DS2 autoscaler policy (paper §III-A), lowered into both
    engines' ticks as a traced windowed controller: per decision
    interval it EWMAs each task's utilization (records consumed plus a
    backlog-drain term over current capacity — the DS2 true-rate ratio),
    targets ``speed * need / target_utilization``, and fires a per-task
    speed rescale guarded by hysteresis, cooldown, a leaky
    actions-per-window rate limit, a failover-aware circuit breaker and
    a thrash latch. Like deployment drills, autoscale events are
    deterministic in-trace time arithmetic: they consume NO rng draws
    and never touch the pregenerated chaos timelines.

    * Rescales are *graceful* (queues persist) but pay downtime on the
      ``up_until`` leaf: ``rescale_down_s`` (default: the hot-vs-cold
      `core.hotupdate.deploy_downtime` lowering) plus
      ``move_cost_s * |delta|`` state-move seconds (default: the
      `repro.train.elastic.resize_move_seconds` reshard model at
      ``state_bytes_per_task`` / ``move_bandwidth_Bps``).
    * The breaker counts, per task, kills landing within
      ``fail_window_s`` of that task's last rescale (a crash right
      after a resize = a failed adjustment); ``breaker_failures`` such
      events open the breaker for ``breaker_reset_s``, during which the
      controller holds and the task gracefully load-sheds: its
      selectivity is scaled by ``shed_factor``.
    * The thrash latch freezes the controller for the rest of the run
      once the leaky direction-flip counter (decaying over
      ``thrash_window_s``) reaches ``thrash_flips`` — the
      autoscaler-vs-failover oscillation guard. The latch time lands in
      ``EngineMetrics.thrash_t``.
    * Source tasks never rescale (and never pay rescale downtime):
      source emission is governed by the traffic curves, not capacity.

    Defaults are sized for tick-scale drills (dt ~0.5 s, minutes-long
    horizons); production-scale values (paper: 120 s cooldowns, 12
    actions/hour, 1800 s breaker) live in `core.autoscaler.ScalerConfig`
    — the host-side decision loop this controller is lowered from. NOT
    lowered: in-trace rollback of a failed resize to the previous
    parallelism (`DS2Scaler.notify_result` keeps that host-side); the
    breaker + load-shed path is the traced graceful-degradation story.
    Queue capacities stay on the config axis (``qcap_scale``): the
    pallas lowering packs qcap into static per-run kernel tables, so an
    in-trace qcap mutation cannot reach the fused kernel."""
    t0_s: float = 0.0
    interval_s: float = 5.0
    ewma_alpha: float = 0.35
    target_utilization: float = 0.8
    backlog_drain_s: float = 60.0
    hysteresis: float = 0.15
    cooldown_s: float = 20.0
    min_scale: float = 0.25
    max_scale: float = 8.0
    max_actions: float = 12.0        # leaky bucket over rate_window_s
    rate_window_s: float = 3600.0
    breaker_failures: float = 3.0
    breaker_reset_s: float = 300.0
    fail_window_s: float = 10.0
    shed_factor: float = 0.5         # breaker-open selectivity scale
    thrash_flips: float = 6.0
    thrash_window_s: float = 60.0
    hot: bool = True
    startup: object | None = None    # core.startup.StartupConfig
    rescale_down_s: float | None = None   # override deploy_downtime
    move_cost_s: float | None = None      # s per |delta| scale unit
    state_bytes_per_task: float = 64e6
    move_bandwidth_Bps: float = 1e9


#: the 21 traced autoscale leaves (see `lower_autoscale`); ordering is
#: shared with jax_engine's axis dicts and run_config_batch's stacker
AUTOSCALE_KEYS = (
    "as_mask", "as_on", "as_t0", "as_int", "as_alpha", "as_tgt",
    "as_drain", "as_hyst", "as_cool", "as_lo", "as_hi", "as_amax",
    "as_adec", "as_bfail", "as_brs", "as_fw", "as_shed", "as_tflip",
    "as_tdec", "as_down", "as_move")


def inert_autoscale_leaves(n_tasks: int) -> dict:
    """Autoscale leaves of an autoscaler-free run: structurally present
    (stable pytree → one trace for scaled and unscaled configs) but an
    exact arithmetic no-op — ``as_on`` gates every action to False, the
    EWMA coefficient is 0.0, the shed factor multiplies by exactly 1.0.
    Large finite sentinels (1e18) stand in for +inf where the traced
    arithmetic divides or subtracts (inf/inf → nan hazards)."""
    big = np.float64(1e18)
    return {
        "as_mask": np.zeros(n_tasks),
        "as_on": np.float64(0.0), "as_t0": np.float64(0.0),
        "as_int": big, "as_alpha": np.float64(0.0),
        "as_tgt": np.float64(1.0), "as_drain": big,
        "as_hyst": big, "as_cool": np.float64(0.0),
        "as_lo": np.float64(0.0), "as_hi": big,
        "as_amax": big, "as_adec": np.float64(0.0),
        "as_bfail": big, "as_brs": np.float64(0.0),
        "as_fw": np.float64(0.0), "as_shed": np.float64(1.0),
        "as_tflip": big, "as_tdec": np.float64(0.0),
        "as_down": np.float64(0.0), "as_move": np.float64(0.0),
    }


def lower_autoscale(auto: AutoscaleConfig | None, *, n_tasks: int,
                    dt: float, is_src_task=None) -> dict:
    """Lower an `AutoscaleConfig` into the traced controller leaves
    shared by the numpy and JAX engines (identical float arithmetic —
    the parity contract). ``is_src_task`` masks source tasks out of
    ``as_mask`` (sources never rescale). ``auto=None`` returns the
    inert leaves."""
    if auto is None:
        return inert_autoscale_leaves(n_tasks)
    from repro.core.hotupdate import deploy_downtime
    from repro.train.elastic import resize_move_seconds

    if is_src_task is not None:
        mask = 1.0 - np.asarray(is_src_task, float)
    else:
        mask = np.ones(n_tasks)
    down = (float(auto.rescale_down_s)
            if auto.rescale_down_s is not None
            else deploy_downtime(auto.startup, hot=auto.hot))
    move = (float(auto.move_cost_s) if auto.move_cost_s is not None
            else resize_move_seconds(
                1.0, state_bytes_per_unit=auto.state_bytes_per_task,
                bandwidth_Bps=auto.move_bandwidth_Bps))
    return {
        "as_mask": mask,
        "as_on": np.float64(1.0),
        "as_t0": np.float64(auto.t0_s),
        "as_int": np.float64(max(float(auto.interval_s), dt)),
        "as_alpha": np.float64(auto.ewma_alpha),
        "as_tgt": np.float64(auto.target_utilization),
        "as_drain": np.float64(max(float(auto.backlog_drain_s), dt)),
        "as_hyst": np.float64(auto.hysteresis),
        "as_cool": np.float64(auto.cooldown_s),
        "as_lo": np.float64(auto.min_scale),
        "as_hi": np.float64(auto.max_scale),
        "as_amax": np.float64(auto.max_actions),
        "as_adec": np.float64(
            math.exp(-dt / max(float(auto.rate_window_s), dt))),
        "as_bfail": np.float64(auto.breaker_failures),
        "as_brs": np.float64(auto.breaker_reset_s),
        "as_fw": np.float64(auto.fail_window_s),
        "as_shed": np.float64(auto.shed_factor),
        "as_tflip": np.float64(auto.thrash_flips),
        "as_tdec": np.float64(
            math.exp(-dt / max(float(auto.thrash_window_s), dt))),
        "as_down": np.float64(down),
        "as_move": np.float64(move),
    }


class _Series(dict):
    """Read-mostly mapping op name → metric column view."""


class EngineMetrics:
    """Preallocated per-tick metric buffers.

    `t`, `source_lag` and the per-op `qps` / `backlog` entries are numpy
    array views (zero-copy, trimmed to the ticks recorded so far) — they
    support the same indexing/aggregation the old list-based metrics did.
    """

    def __init__(self, op_names: list[str], capacity: int = 1024,
                 n_jobs: int | None = None):
        self._ops = list(op_names)
        self._col = {n: j for j, n in enumerate(self._ops)}
        self._n = 0
        cap = max(capacity, 16)
        self._t = np.zeros(cap)
        self._lag = np.zeros(cap)
        self._qps = np.zeros((cap, len(self._ops)))
        self._backlog = np.zeros((cap, len(self._ops)))
        self.dropped = 0.0
        self.emitted = 0.0
        # per-job metric segments (n_jobs=None: plain single-graph engine
        # — skip the per-op accumulation, derive the view from the scalars)
        self._emitted_by_job = (np.zeros(n_jobs) if n_jobs is not None
                                else None)
        self._dropped_by_job = (np.zeros(n_jobs) if n_jobs is not None
                                else None)
        self.ckpt_attempts = 0
        self.ckpt_success = 0
        self.ckpt_failed = 0
        # (n_jobs, 3) attempts/success/failed — filled only by per-job
        # checkpoint coordinators (per-job CheckpointConfig lists)
        self.ckpt_by_job = (np.zeros((n_jobs, 3), int)
                            if n_jobs is not None else None)
        self.recoveries: list[dict] = []
        # deployment drills: wall time the auto-rollback fired (inf =
        # never). Upgrade/rollback waves are NOT recovery entries — the
        # chaos timelines only know crash failovers, and the jax engines
        # reconstruct `recoveries` from those timelines.
        self.rollback_t = math.inf
        # in-trace autoscaler: wall time the thrash latch froze the
        # controller (inf = never), number of rescale actions fired, and
        # integrated resource-seconds (sum of task speeds × dt — the
        # cost axis of the SLO-vs-cost cube; accumulated whether or not
        # an autoscaler is configured so cube rows stay comparable).
        self.thrash_t = math.inf
        self.n_rescale = 0.0
        self.resource_s = 0.0

    @property
    def emitted_by_job(self) -> np.ndarray:
        return (np.array([self.emitted]) if self._emitted_by_job is None
                else self._emitted_by_job)

    @property
    def dropped_by_job(self) -> np.ndarray:
        return (np.array([self.dropped]) if self._dropped_by_job is None
                else self._dropped_by_job)

    # -- recording (engine-internal) -----------------------------------
    def _reserve(self, n_more: int) -> None:
        need = self._n + n_more
        if need <= len(self._t):
            return
        cap = max(need, 2 * len(self._t))
        grow = lambda a: np.concatenate(  # noqa: E731
            [a, np.zeros((cap - len(a),) + a.shape[1:])])
        self._t, self._lag = grow(self._t), grow(self._lag)
        self._qps, self._backlog = grow(self._qps), grow(self._backlog)

    def _record(self, t: float, qps_row: np.ndarray, backlog_row: np.ndarray,
                lag: float) -> None:
        self._reserve(1)
        i = self._n
        self._t[i] = t
        self._lag[i] = lag
        self._qps[i] = qps_row
        self._backlog[i] = backlog_row
        self._n = i + 1

    # -- views ----------------------------------------------------------
    @property
    def t(self) -> np.ndarray:
        return self._t[:self._n]

    @property
    def source_lag(self) -> np.ndarray:
        return self._lag[:self._n]

    @property
    def qps(self) -> _Series:
        return _Series((n, self._qps[:self._n, j])
                       for n, j in self._col.items())

    @property
    def backlog(self) -> _Series:
        return _Series((n, self._backlog[:self._n, j])
                       for n, j in self._col.items())


@dataclasses.dataclass
class _OpPlan:
    name: str
    lo: int
    hi: int
    par: int
    is_source: bool
    service_rate: float
    selectivity: float
    source_rate: float
    out_edges: list["_EdgePlan"] = dataclasses.field(default_factory=list)
    # precomputed all-alive fast-path rows (speed is static per run)
    cap_row: np.ndarray | None = None       # service_rate·dt·speed
    src_row: np.ndarray | None = None       # per-task source emission
    src_sum: float = 0.0


@dataclasses.dataclass
class _EdgePlan:
    kind: str                       # partitioner name
    src: _OpPlan
    dst: _OpPlan
    static: bool                    # head-of-line acceptance family
    share: np.ndarray | None = None         # hash: normalized key mass
    raw_share: np.ndarray | None = None     # weakhash: unnormalized mass
    grp_starts: np.ndarray | None = None    # weakhash/group_rescale segments
    grp_mass: np.ndarray | None = None      # weakhash: per-group mass sums
    grp_of_dst: np.ndarray | None = None    # weakhash/group_rescale: dst→grp
    mass_of_dst: np.ndarray | None = None   # weakhash: grp_mass gathered
    blk_of_src: np.ndarray | None = None    # rescale/group_rescale: src→blk
    blk_of_dst: np.ndarray | None = None    # dst→blk (-1 = unconnected)
    dst_in_blk: np.ndarray | None = None    # bool: dst has a block
    any_unblocked: bool = False             # static: some dst has no block
    blk_idx: np.ndarray | None = None       # blk_of_dst clipped to >= 0
    n_blocks: int = 0
    dst_qcap: float = 0.0                   # backlog threshold base
    # per-edge scratch (reused every tick — avoids small-array allocations)
    ratio_buf: np.ndarray | None = None
    live_buf: np.ndarray | None = None


@dataclasses.dataclass
class RoutingPlan:
    """Speed-independent lowering of a logical graph: arena layout, per-op
    scalars and per-edge routing constants. Built once per engine by
    `build_plan` and shared (code-wise) between the numpy `StreamEngine`
    and the JAX twin in `streams/jax_engine.py` — the twin converts the
    same plan arrays to device constants instead of re-deriving them."""
    graph: LogicalGraph
    dt: float
    queue_cap: float
    offs: dict[str, int]
    n_tasks: int
    qcap: np.ndarray                     # (n_tasks,)
    ops: list[_OpPlan]                   # topo order, out_edges populated
    by_name: dict[str, _OpPlan]
    arena_starts: np.ndarray
    backlog_perm: np.ndarray
    src_cols: np.ndarray


def build_plan(graph: LogicalGraph, dt: float,
               queue_cap: float) -> RoutingPlan:
    """Lower `graph` into a `RoutingPlan` (everything in
    `StreamEngine.__init__` that does not depend on host speeds/chaos)."""
    order = graph.topo_order()
    ops = {o.name: o for o in graph.ops}
    # expand() numbers tasks contiguously per op, in graph.ops order
    offs: dict[str, int] = {}
    off = 0
    for o in graph.ops:
        offs[o.name] = off
        off += o.parallelism
    n_tasks = off

    qcap = np.zeros(n_tasks)
    for o in graph.ops:
        qcap[offs[o.name]:offs[o.name] + o.parallelism] = \
            max(o.service_rate * dt * 4.0, queue_cap)

    plan_ops: list[_OpPlan] = []
    by_name: dict[str, _OpPlan] = {}
    for name in order:
        o = ops[name]
        p = _OpPlan(name, offs[name], offs[name] + o.parallelism,
                    o.parallelism, o.is_source, o.service_rate,
                    o.selectivity, o.source_rate)
        if o.is_source:
            p.src_row = np.full(o.parallelism,
                                o.source_rate * dt / o.parallelism)
            p.src_sum = float(p.src_row.sum())
        plan_ops.append(p)
        by_name[name] = p
    for name in order:
        for e in graph.downstream(name):
            by_name[name].out_edges.append(
                _plan_edge(e, by_name[name], by_name[e.dst],
                           float(qcap[by_name[e.dst].lo])))

    # metric plumbing: one reduceat over the arena gives every op's
    # backlog; permute arena (declaration) order → topo column order
    arena_order = sorted(plan_ops, key=lambda p: p.lo)
    arena_starts = np.array([p.lo for p in arena_order])
    topo_pos = {p.name: j for j, p in enumerate(plan_ops)}
    backlog_perm = np.argsort([topo_pos[p.name] for p in arena_order])
    src_cols = np.array([j for j, p in enumerate(plan_ops) if p.is_source])
    return RoutingPlan(graph, dt, queue_cap, offs, n_tasks, qcap, plan_ops,
                       by_name, arena_starts, backlog_perm, src_cols)


def _plan_edge(e, src: _OpPlan, dst: _OpPlan, dst_qcap: float) -> _EdgePlan:
    nd = dst.par
    ns = src.par
    plan = _EdgePlan(
        kind=e.partitioner, src=src, dst=dst,
        static=e.partitioner in ("rebalance", "rescale", "forward",
                                 "hash"),
        dst_qcap=dst_qcap)
    if e.partitioner in ("hash", "weakhash"):
        # hashed key-mass share (identical construction to the
        # reference engine — same bincount over the same Zipf mass)
        nkeys = max(nd * 64, 1024)
        if e.key_skew_zipf > 0:
            mass = 1.0 / np.arange(1, nkeys + 1) ** e.key_skew_zipf
        else:
            mass = np.ones(nkeys)
        mass /= mass.sum()
        owner = (np.arange(nkeys) * 2654435761 % nd).astype(int)
        share = np.bincount(owner, weights=mass, minlength=nd)
        if e.partitioner == "hash":
            plan.share = share / share.sum()
        else:
            plan.raw_share = share
    if e.partitioner == "weakhash":
        g = max(e.n_groups, 1)
        starts = np.array([grp * nd // g for grp in range(g)])
        bounds = np.append(starts, nd)
        plan.grp_starts = starts
        # per-group mass via the same slice-sum the reference performs
        plan.grp_mass = np.array(
            [plan.raw_share[bounds[i]:bounds[i + 1]].sum()
             for i in range(g)])
        plan.grp_of_dst = np.searchsorted(starts, np.arange(nd),
                                          side="right") - 1
        plan.mass_of_dst = plan.grp_mass[plan.grp_of_dst]
    if e.partitioner == "group_rescale":
        g = max(e.n_groups, 1)
        starts = np.array([grp * nd // g for grp in range(g)])
        plan.grp_starts = starts
        plan.grp_of_dst = np.searchsorted(starts, np.arange(nd),
                                          side="right") - 1
        plan.blk_of_src = np.arange(ns) * g // ns
        plan.blk_of_dst = plan.grp_of_dst
        plan.n_blocks = g
    if e.partitioner == "rescale":
        per = max(1, nd // ns)
        src_lo = (np.arange(ns) * per) % nd
        blocks, blk_of_src = np.unique(src_lo, return_inverse=True)
        plan.blk_of_src = blk_of_src
        plan.n_blocks = len(blocks)
        blk_of_dst = np.full(nd, -1)
        for b, lo in enumerate(blocks):
            blk_of_dst[lo:lo + per] = b
        plan.blk_of_dst = blk_of_dst
    if plan.blk_of_dst is not None:
        plan.dst_in_blk = plan.blk_of_dst >= 0
        plan.any_unblocked = not bool(plan.dst_in_blk.all())
        plan.blk_idx = np.clip(plan.blk_of_dst, 0, None)
    plan.ratio_buf = np.empty(nd)
    plan.live_buf = np.empty(nd, bool)
    return plan


# ----------------------------------------------------------------------
# Per-task failover normalization (per-job configs, paper §III-B)
# ----------------------------------------------------------------------
def per_task_failover(failover, n_tasks: int,
                      job_of_task: np.ndarray | None = None):
    """Normalize a `FailoverConfig` — or a per-job sequence of them — into
    per-task vectors ``(mode_codes i8, detect, restart_single,
    restart_region, extras)`` where ``extras`` is a dict of per-task
    hybrid-replication vectors: ``switch`` / ``stale`` (hot-standby
    failover latency + staleness replay), ``restore_base`` /
    ``replay_rate`` (passive-restore cost model; restore_base is scaled
    by the brownout factor at kill time, replay_rate by checkpoint age)
    and ``stagger`` (per-rank lazy-load region ready-time spacing).

    Mode codes follow `core.chaos.failover_mode_codes` (0 none, 1 region,
    2 single_task, 3 hot_standby). A sequence means one config per job of a packed arena
    (`job_of_task` maps tasks to jobs; `None` entries fall back to the
    default config), which is how per-job failover policies reach both
    engines and the chaos timeline: everything downstream consumes only
    the per-task vectors, so a shared config is just the constant
    vector."""
    from repro.core.chaos import failover_mode_codes

    if failover is None:
        failover = FailoverConfig()
    if isinstance(failover, FailoverConfig):
        c = failover
        extras = {k: np.full(n_tasks, float(getattr(c, a))) for k, a in
                  _EXTRA_FIELDS}
        return (failover_mode_codes(c.mode, n_tasks),
                np.full(n_tasks, float(c.detect_s)),
                np.full(n_tasks, float(c.single_restart_s)),
                np.full(n_tasks, float(c.region_restart_s)),
                extras)
    cfgs = [c if c is not None else FailoverConfig() for c in failover]
    if job_of_task is None:
        if len(cfgs) != 1:
            raise ValueError(
                "a per-job failover list needs a packed arena "
                f"(got {len(cfgs)} configs for a single-job graph)")
        job_of_task = np.zeros(n_tasks, dtype=int)
    job_of_task = np.asarray(job_of_task)
    n_jobs = int(job_of_task.max()) + 1
    if len(cfgs) != n_jobs:
        raise ValueError(f"per-job failover list must have one entry per "
                         f"job ({len(cfgs)} != {n_jobs})")
    code_of_job = np.concatenate(
        [failover_mode_codes(c.mode, 1) for c in cfgs])
    extras = {k: np.array([float(getattr(c, a)) for c in cfgs])[job_of_task]
              for k, a in _EXTRA_FIELDS}
    return (code_of_job[job_of_task].astype(np.int8),
            np.array([c.detect_s for c in cfgs])[job_of_task],
            np.array([c.single_restart_s for c in cfgs])[job_of_task],
            np.array([c.region_restart_s for c in cfgs])[job_of_task],
            extras)


# extras-dict key → FailoverConfig attribute
_EXTRA_FIELDS = (("switch", "standby_switch_s"),
                 ("stale", "standby_staleness_s"),
                 ("restore_base", "restore_base_s"),
                 ("replay_rate", "replay_rate"),
                 ("stagger", "lazyload_stagger_s"))


def region_rank(task_region: np.ndarray,
                job_of_task: np.ndarray | None) -> np.ndarray:
    """Per-task rank of its failure region *within its job* (the job's
    first region is rank 0). This is the deterministic ordering shared by
    lazy-load ready-time schedules (`lazy_ready_extra`) and
    deployment-drill rolling-upgrade waves (`lower_upgrade`): wave /
    ready slot ``i`` covers the job's rank-``i`` region."""
    task_region = np.asarray(task_region)
    if job_of_task is None:
        first = task_region.min() if len(task_region) else 0
    else:
        job_of_task = np.asarray(job_of_task)
        n_jobs = int(job_of_task.max()) + 1
        first_of_job = np.full(n_jobs, np.iinfo(np.int64).max)
        np.minimum.at(first_of_job, job_of_task, task_region)
        first = first_of_job[job_of_task]
    return (task_region - first).astype(float)


def lazy_ready_extra(stagger: np.ndarray, task_region: np.ndarray | None,
                     job_of_task: np.ndarray | None) -> np.ndarray:
    """Per-task lazy-load restore penalty: region ``rank`` within its job
    times the stagger. Models the State-LazyLoad ready-time schedule —
    regions materialize in priority order, and a task blocks only until
    its OWN region is restored, so later-ranked regions pay
    ``rank * stagger`` extra downtime. No regions → rank 0 → zero."""
    stagger = np.asarray(stagger, dtype=float)
    if task_region is None or not np.any(stagger):
        return np.zeros_like(stagger)
    return region_rank(task_region, job_of_task) * stagger


# ----------------------------------------------------------------------
# Tensorized plan lowering (flat edge tensors for the JAX segment-sum
# tick — see streams/jax_engine.py for the consuming kernel)
# ----------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class PhaseTensors:
    """Flat routing tensors of one tick *phase*.

    A phase is one slot of the tick's static schedule: every op consumes
    (and produces) in exactly one phase, every edge routes in exactly one
    phase, and all of a phase's edges execute as ONE batch of gathers +
    segment reductions over the concatenated destination-channel axis
    (``D`` entries = sum of the phase's edges' dst parallelisms). Blocks
    (rescale families) and key-groups (weakhash) are numbered globally
    within the phase with one trailing dummy segment each, so one
    `segment_sum` covers every edge's blocks/groups at once. `share` /
    `mass` are float routing constants — the JAX engine passes them as
    traced parameters, NOT compile-time constants, so they are excluded
    from the trace-cache key."""
    cons_mask: np.ndarray          # (n_tasks,) f64: ops consuming here
    consumes: bool
    n_edges: int                   # E
    D: int                         # flat dst-channel entries
    dst_task: np.ndarray           # (D,) i32 arena task id per entry
    edge_of: np.ndarray            # (D,) i32 phase-local edge index
    job_of_entry: np.ndarray       # (D,) i32 job of the dst op
    src_op_of_edge: np.ndarray     # (E,) i32 topo op index of the source
    is_fwd: np.ndarray             # (D,) bool  forward
    is_blk: np.ndarray             # (D,) bool  rescale / group_rescale
    is_hash: np.ndarray            # (D,) bool  hash
    is_weakhash: np.ndarray        # (D,) bool
    is_backlog: np.ndarray         # (D,) bool
    is_norm: np.ndarray            # (D,) f64   rebalance|weakhash|backlog
    acc_static: np.ndarray         # (D,) bool  head-of-line accept family
    acc_block: np.ndarray          # (D,) bool  per-block accept
    fwd_src: np.ndarray            # (D,) i32   src task for forward
    B: int                         # blocks in phase (dummy slot = B)
    blk_of: np.ndarray             # (D,) i32
    dst_in_blk: np.ndarray         # (D,) f64
    bsrc_task: np.ndarray          # (Sb,) i32  blocky edges' src tasks
    bsrc_blk: np.ndarray           # (Sb,) i32
    G: int                         # weakhash groups (dummy slot = G)
    grp_of: np.ndarray             # (D,) i32
    share: np.ndarray              # (D,) f64  hash key-mass share (traced)
    mass: np.ndarray               # (D,) f64  weakhash group mass (traced)


@dataclasses.dataclass(eq=False)
class CompactPhase:
    """Sparse twin of `PhaseTensors`: per-phase *active* index sets in
    row-table form.

    Every segment reduction of the dense tick (per-op totals, per-edge
    normalizers, per-group capacities, per-block rates, head-of-line
    minima, per-job metric sums) becomes a small **row table** here: one
    row per segment, holding the segment's member indices padded to a
    pow2 row length with a 0.0 mask column. The JAX tick then reduces
    ``values[idx] * mask`` along the row axis — a vectorized
    gather+reduce whose cost scales with the phase's live entries — in
    place of XLA scatter-based `segment_sum`/`segment_min` over the
    whole arena (the dense tick's dominant cost on deep pipelines).

    Everything except the array *shapes* is a traced parameter of the
    tick (`streams.jax_engine._build_compact_run`), the same pow2
    bucketing discipline as seed-batch padding: the trace-cache key is
    only the shape signature (`sig`), so two plans whose index sets land
    in the same buckets — e.g. same-shape graphs with different
    partitioners, placements or routing tables — share one compiled
    trace."""
    consumes: bool
    D: int                         # flat dst-channel entries (exact)
    E: int                         # edges (exact)
    B: int                         # blocks (+1 dummy row in br/bs)
    G: int                         # weakhash groups (+1 dummy row)
    # consumption (arena-wide elementwise, mask traced)
    cons_mask: np.ndarray          # (n_tasks,) f64
    # qps rows: one row per consuming op (arena indices)
    q_idx: np.ndarray              # (Rq, Lq) i32
    q_mask: np.ndarray             # (Rq, Lq) f64
    q_ops: np.ndarray              # (Rq,) i32 topo op index
    # emitted rows: one row per job with active sources (arena indices)
    e_idx: np.ndarray              # (Re, Le) i32
    e_mask: np.ndarray             # (Re, Le) f64
    e_jobs: np.ndarray             # (Re,) i32
    # per-source-op slots of the phase's edges (arena indices)
    s_idx: np.ndarray              # (Rs, Ls) i32
    s_mask: np.ndarray             # (Rs, Ls) f64
    slot_of_edge: np.ndarray       # (E,) i32
    slot_ops: np.ndarray           # (Rs,) i32 topo op index
    # dst-channel entry arrays (exact D, as in the dense phase)
    dst_task: np.ndarray           # (D,) i32
    fwd_src: np.ndarray            # (D,) i32
    edge_of: np.ndarray            # (D,) i32
    grp_of: np.ndarray             # (D,) i32 (dummy = G)
    blk_of: np.ndarray             # (D,) i32 (dummy = B)
    m_fwd: np.ndarray              # (D,) f64 partitioner masks (traced —
    m_blk: np.ndarray              # (D,) f64  unlike the dense bools,
    m_hash: np.ndarray             # (D,) f64  these are runtime params)
    m_weakhash: np.ndarray         # (D,) f64
    m_backlog: np.ndarray          # (D,) f64
    is_norm: np.ndarray            # (D,) f64
    m_acc_static: np.ndarray       # (D,) f64
    m_acc_block: np.ndarray        # (D,) f64
    dst_in_blk: np.ndarray         # (D,) f64
    share: np.ndarray              # (D,) f64
    mass: np.ndarray               # (D,) f64
    # edge / group / block rows (indices into the D axis)
    er_idx: np.ndarray             # (E, Le2) i32
    er_mask: np.ndarray            # (E, Le2) f64
    gr_idx: np.ndarray             # (G+1, Lg) i32 (last row all-pad)
    gr_mask: np.ndarray            # (G+1, Lg) f64
    br_idx: np.ndarray             # (B+1, Lb) i32 (last row all-pad)
    br_mask: np.ndarray            # (B+1, Lb) f64
    # block-source rows (arena indices of the blocky edges' src tasks)
    bs_idx: np.ndarray             # (B+1, Lbs) i32 (last row all-pad)
    bs_mask: np.ndarray            # (B+1, Lbs) f64
    # dropped rows: one row per dst job (indices into the D axis)
    dj_idx: np.ndarray             # (Rd, Ld) i32
    dj_mask: np.ndarray            # (Rd, Ld) f64
    dj_jobs: np.ndarray            # (Rd,) i32

    @property
    def sig(self) -> tuple:
        shapes = tuple(getattr(self, f.name).shape
                       for f in dataclasses.fields(self)
                       if isinstance(getattr(self, f.name), np.ndarray))
        return (self.consumes, self.D, self.E, self.B, self.G) + shapes

    def traced(self) -> dict:
        """The per-phase traced-parameter dict (everything but `sig`)."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if isinstance(getattr(self, f.name), np.ndarray)}


@dataclasses.dataclass(eq=False)
class TensorPlan:
    """Phase-scheduled flat-tensor lowering of a `RoutingPlan`.

    Equality / hashing go through `key` — a digest of every static
    (integer/structure) array — so two same-shaped graphs share one
    compiled trace while float parameters stay traced. The *number of
    phases* is bounded by the longest in-tick pipeline chain of a single
    job (plus head-of-line ordering between same-destination edges), NOT
    by the number of ops/edges: packing K jobs into one arena leaves it
    unchanged, which is what makes the jitted tick O(1) in graph size.

    ``mode`` selects the lowering flavor: ``"dense"`` phases are
    `PhaseTensors` (arena-wide masks, index structure baked into the
    trace), ``"compact"`` phases are `CompactPhase` (pow2-bucketed
    active index sets passed as traced parameters — per-tick compute
    scales with the live edges/tasks of each phase, and the trace key is
    only the bucket signature)."""
    n_tasks: int
    n_ops: int
    n_jobs: int
    n_phases: int
    op_of_task: np.ndarray         # (n_tasks,) i32 topo op index
    is_src_task: np.ndarray        # (n_tasks,) f64
    job_of_task: np.ndarray        # (n_tasks,) i32
    par_of_op: np.ndarray          # (n_ops,) f64  max(parallelism, 1)
    src_mask_ops: np.ndarray       # (n_ops,) f64  1.0 at source columns
    phases: list
    key: tuple = ()
    mode: str = "dense"

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return isinstance(other, TensorPlan) and self.key == other.key


def _phase_schedule(plan: RoutingPlan):
    """Assign every op a consumption phase and every edge a routing phase
    such that executing each phase as one parallel batch reproduces the
    sequential numpy tick exactly:

    * an op consumes only after every in-edge has deposited
      (``cphase(op) > phase(e)`` for in-edges ``e``);
    * an edge routes no earlier than its source op produces
      (``phase(e) >= cphase(src)`` — same phase is fine, consumption runs
      before routing inside a phase, as in the numpy op turn);
    * edges sharing a destination op serialize in their numpy order
      (free-credit reads/writes on the shared destination queue must nest
      exactly), which also guarantees each dst op receives at most ONE
      edge per phase — deposits within a phase are scatter-unique.
    """
    ops = plan.ops
    topo_idx = {p.name: i for i, p in enumerate(ops)}
    in_waves: list[list[int]] = [[] for _ in ops]
    last_wave_into: dict[int, int] = {}
    cphase = [0] * len(ops)
    edges = []                               # (src_oi, dst_oi, ep, phase)
    for oi, p in enumerate(ops):
        cphase[oi] = max((w + 1 for w in in_waves[oi]), default=0)
        for ep in p.out_edges:
            di = topo_idx[ep.dst.name]
            w = cphase[oi]
            if di in last_wave_into:
                w = max(w, last_wave_into[di] + 1)
            last_wave_into[di] = w
            in_waves[di].append(w)
            edges.append((oi, di, ep, w))
    n_phases = max(cphase + [e[3] for e in edges], default=0) + 1
    return cphase, edges, n_phases


def _bucket(n: int) -> int:
    """Pow2 bucket size for a compact index set (0 stays 0)."""
    return 1 << (n - 1).bit_length() if n > 1 else n


def _phase_work_estimate(plan: RoutingPlan, cphase, edges, n_phases):
    """(dense_work, compact_work) rough per-tick reduction-element counts
    of the two lowerings — the auto-mode selector. The dense tick pays
    arena-sized scatter-based segment reductions per phase (per-job
    emitted + per-op qps when the phase consumes, per-op totals when it
    routes); the compact tick pays row gathers over just the phase's
    active / source tasks. Costs the two modes share (elementwise arena
    passes, dst-axis work, deposits) are left out of both sides."""
    ops = plan.ops
    n_tasks = plan.n_tasks
    dense = compact = 0
    for f in range(n_phases):
        act = sum(p.hi - p.lo for oi, p in enumerate(ops)
                  if cphase[oi] == f)
        src_ops = {oi for (oi, _, _, w) in edges if w == f}
        s = sum(ops[oi].par for oi in src_ops)
        dense += (2 * n_tasks if act else 0) + (n_tasks if src_ops else 0)
        compact += 2 * act + s
    return dense, compact


def select_phase_mode(plan: RoutingPlan, mode: str = "auto",
                      seed_width: int = 1) -> str:
    """Resolve a ``"auto"`` phase-lowering request: compact when the
    arena-sized segment reductions the sparse lowering eliminates
    clearly dominate its row-gather cost (deep pipelines / big
    multi-job arenas where each phase touches a small slice of the
    arena), dense otherwise. ``"pallas"`` (the fused-kernel lowering)
    is never auto-selected — request it explicitly.

    ``seed_width`` is the seed-axis batch width the tick will run
    under (S, or S·C for config grids). The work estimate scores one
    tick, but two compact costs amortize across the vmap width: the
    fixed per-tick overhead (index-table loads are shared, not
    batched) and the absolute-size floor (a 256-wide batch over a
    56-task graph is real work even though one tick isn't). So the
    floor scales with ``n_tasks · width`` and the dense-favoring
    margin decays from 2.5x at width 1 (the single-seed calibration)
    toward the asymptotic ~2.1x row-gather penalty — wide batches
    over small deep-pipeline graphs now pick compact, while shallow
    graphs (estimate ratio ≈ 2) stay dense at any width."""
    if mode in ("dense", "compact", "pallas"):
        return mode
    if mode != "auto":
        raise ValueError(
            f"phase mode must be dense|compact|pallas|auto: {mode!r}")
    w = max(int(seed_width), 1)
    if plan.n_tasks * w < 256:
        return "dense"
    cphase, edges, n_phases = _phase_schedule(plan)
    dense, compact = _phase_work_estimate(plan, cphase, edges, n_phases)
    margin = 2.125 + 0.375 / w
    return "compact" if dense >= margin * compact else "dense"


def lower_tensor_plan(plan: RoutingPlan,
                      job_of_op: np.ndarray | None = None,
                      mode: str = "dense",
                      seed_width: int = 1) -> TensorPlan:
    """Lower a `RoutingPlan` into the flat per-phase tensors consumed by
    the JAX segment-sum tick (`streams/jax_engine.py`).

    ``mode`` is ``"dense"`` (arena-wide `PhaseTensors`, the parity
    baseline), ``"compact"`` (pow2-bucketed `CompactPhase` index sets —
    per-tick compute scales with the live edges per phase), ``"pallas"``
    (the SAME `CompactPhase` tables, lowered through the fused per-phase
    kernel `repro.kernels.tick_phase` by `jax_engine._build_pallas_run`)
    or ``"auto"`` (`select_phase_mode` picks dense/compact by the work
    estimate at the given ``seed_width``; pallas is explicit-only)."""
    import hashlib

    mode = select_phase_mode(plan, mode, seed_width)
    ops = plan.ops
    n_ops = len(ops)
    n_tasks = plan.n_tasks
    if job_of_op is None:
        job_of_op = np.zeros(n_ops, dtype=int)
    job_of_op = np.asarray(job_of_op)
    n_jobs = int(job_of_op.max()) + 1 if n_ops else 1

    op_of_task = np.zeros(n_tasks, np.int32)
    is_src_task = np.zeros(n_tasks)
    job_of_task = np.zeros(n_tasks, np.int32)
    for oi, p in enumerate(ops):
        op_of_task[p.lo:p.hi] = oi
        job_of_task[p.lo:p.hi] = job_of_op[oi]
        if p.is_source:
            is_src_task[p.lo:p.hi] = 1.0
    par_of_op = np.array([max(p.par, 1) for p in ops], float)
    src_mask_ops = np.array([1.0 if p.is_source else 0.0 for p in ops])

    cphase, edges, n_phases = _phase_schedule(plan)
    phases: list[PhaseTensors] = []
    h = hashlib.sha1()

    def feed(*arrays):
        for a in arrays:
            h.update(np.ascontiguousarray(a).tobytes())

    feed(op_of_task, is_src_task.astype(np.int8), job_of_task,
         np.asarray(cphase, np.int32))
    for f in range(n_phases):
        cons = np.zeros(n_tasks)
        for oi, p in enumerate(ops):
            if cphase[oi] == f:
                cons[p.lo:p.hi] = 1.0
        mine = [(oi, di, ep) for (oi, di, ep, w) in edges if w == f]
        assert len({di for _, di, _ in mine}) == len(mine), \
            "phase schedule must keep destination ops unique per phase"
        E = len(mine)
        cols = {k: [] for k in
                ("dst_task", "edge_of", "job_of_entry", "is_fwd", "is_blk",
                 "is_hash", "is_weakhash", "is_backlog", "acc_static",
                 "acc_block", "fwd_src", "blk_of", "dst_in_blk", "grp_of",
                 "share", "mass")}
        src_op_of_edge = np.array([oi for oi, _, _ in mine], np.int32)
        bsrc_task, bsrc_blk = [], []
        blk_base = grp_base = 0
        n_blocks_total = sum(ep.n_blocks for _, _, ep in mine)
        n_groups_total = sum(len(ep.grp_starts)
                             if ep.kind == "weakhash" else 0
                             for _, _, ep in mine)
        for ei, (oi, di, ep) in enumerate(mine):
            nd = ep.dst.hi - ep.dst.lo
            kind = ep.kind
            blocky = kind in ("rescale", "group_rescale")
            cols["dst_task"].append(np.arange(ep.dst.lo, ep.dst.hi,
                                              dtype=np.int32))
            cols["edge_of"].append(np.full(nd, ei, np.int32))
            cols["job_of_entry"].append(
                np.full(nd, int(job_of_op[di]), np.int32))
            cols["is_fwd"].append(np.full(nd, kind == "forward"))
            cols["is_blk"].append(np.full(nd, blocky))
            cols["is_hash"].append(np.full(nd, kind == "hash"))
            cols["is_weakhash"].append(np.full(nd, kind == "weakhash"))
            cols["is_backlog"].append(np.full(nd, kind == "backlog"))
            cols["acc_static"].append(np.full(nd, ep.static))
            cols["acc_block"].append(np.full(nd, kind == "group_rescale"))
            cols["fwd_src"].append(
                np.arange(ep.src.lo, ep.src.hi, dtype=np.int32)
                if kind == "forward" else np.zeros(nd, np.int32))
            if blocky:
                cols["blk_of"].append(
                    (blk_base + ep.blk_idx).astype(np.int32))
                cols["dst_in_blk"].append(ep.dst_in_blk.astype(float))
                bsrc_task.append(np.arange(ep.src.lo, ep.src.hi,
                                           dtype=np.int32))
                bsrc_blk.append((blk_base + ep.blk_of_src)
                                .astype(np.int32))
                blk_base += ep.n_blocks
            else:
                cols["blk_of"].append(np.full(nd, n_blocks_total, np.int32))
                cols["dst_in_blk"].append(np.zeros(nd))
            if kind == "weakhash":
                cols["grp_of"].append(
                    (grp_base + ep.grp_of_dst).astype(np.int32))
                cols["mass"].append(ep.mass_of_dst.astype(float))
                grp_base += len(ep.grp_starts)
            else:
                cols["grp_of"].append(np.full(nd, n_groups_total, np.int32))
                cols["mass"].append(np.zeros(nd))
            cols["share"].append(ep.share.astype(float)
                                 if kind == "hash" else np.zeros(nd))
        cat = {k: (np.concatenate(v) if v else
                   np.zeros(0, np.int32 if k in
                            ("dst_task", "edge_of", "job_of_entry",
                             "fwd_src", "blk_of", "grp_of") else float))
               for k, v in cols.items()}
        ph = PhaseTensors(
            cons_mask=cons, consumes=bool(cons.any()), n_edges=E,
            D=len(cat["dst_task"]), dst_task=cat["dst_task"],
            edge_of=cat["edge_of"], job_of_entry=cat["job_of_entry"],
            src_op_of_edge=src_op_of_edge,
            is_fwd=cat["is_fwd"].astype(bool),
            is_blk=cat["is_blk"].astype(bool),
            is_hash=cat["is_hash"].astype(bool),
            is_weakhash=cat["is_weakhash"].astype(bool),
            is_backlog=cat["is_backlog"].astype(bool),
            is_norm=(cat["is_weakhash"].astype(bool)
                     | cat["is_backlog"].astype(bool)
                     | ~(cat["is_fwd"].astype(bool)
                         | cat["is_blk"].astype(bool)
                         | cat["is_hash"].astype(bool))).astype(float),
            acc_static=cat["acc_static"].astype(bool),
            acc_block=cat["acc_block"].astype(bool),
            fwd_src=cat["fwd_src"], B=n_blocks_total,
            blk_of=cat["blk_of"], dst_in_blk=cat["dst_in_blk"],
            bsrc_task=(np.concatenate(bsrc_task) if bsrc_task
                       else np.zeros(0, np.int32)),
            bsrc_blk=(np.concatenate(bsrc_blk) if bsrc_blk
                      else np.zeros(0, np.int32)),
            G=n_groups_total, grp_of=cat["grp_of"],
            share=cat["share"], mass=cat["mass"])
        if mode in ("compact", "pallas"):
            phases.append(_compact_phase(ph, ops, cphase, f, mine,
                                         job_of_op))
        else:
            phases.append(ph)
            feed(np.int64([f, E, ph.D, ph.B, ph.G]), cons.astype(np.int8),
                 ph.dst_task, ph.edge_of, ph.job_of_entry,
                 ph.src_op_of_edge,
                 ph.is_fwd, ph.is_blk, ph.is_hash, ph.is_weakhash,
                 ph.is_backlog, ph.acc_static, ph.acc_block, ph.fwd_src,
                 ph.blk_of, ph.dst_in_blk.astype(np.int8), ph.bsrc_task,
                 ph.bsrc_blk, ph.grp_of)
    if mode in ("compact", "pallas"):
        # only the bucket signature keys the trace: the index contents
        # are traced parameters, so same-bucket plans share one trace
        # (the mode tag keeps compact and pallas traces apart)
        key = (mode, n_tasks, n_ops, n_jobs, n_phases,
               tuple(p.sig for p in phases))
    else:
        key = (n_tasks, n_ops, n_jobs, n_phases, h.hexdigest())
    return TensorPlan(n_tasks, n_ops, n_jobs, n_phases, op_of_task,
                      is_src_task, job_of_task, par_of_op, src_mask_ops,
                      phases, key, mode=mode)


def _rows(groups, n_rows=None, dtype=np.int32):
    """Row-table builder: `groups` is a list of 1-D index arrays, one
    per segment. Returns ``(idx, mask)`` of shape ``(R, L)`` with ``L``
    the pow2 bucket of the longest group — pads gather index 0 under a
    0.0 mask. `n_rows` appends all-pad rows up to a fixed row count
    (the +1 dummy rows of block/group tables)."""
    R = n_rows if n_rows is not None else len(groups)
    L = _bucket(max((len(g) for g in groups), default=0)) or 1
    idx = np.zeros((R, L), dtype)
    mask = np.zeros((R, L))
    for r, g in enumerate(groups):
        idx[r, :len(g)] = g
        mask[r, :len(g)] = 1.0
    return idx, mask


def _compact_phase(ph: PhaseTensors, ops, cphase, f, mine,
                   job_of_op) -> CompactPhase:
    """Convert one dense phase into its row-table sparse twin. The
    numerics contract vs the dense phase is exact up to the reduction
    order inside a row: rows preserve the dst-axis/arena order of each
    segment's members and pads contribute exact +0.0 (sums) or +inf
    (minima), so compact == dense at 1e-12 over full runs."""
    cons_ops = [(oi, p) for oi, p in enumerate(ops) if cphase[oi] == f]
    q_idx, q_mask = _rows([np.arange(p.lo, p.hi) for _, p in cons_ops])
    q_ops = np.array([oi for oi, _ in cons_ops], np.int32)
    by_job: dict[int, list] = {}
    for oi, p in enumerate(ops):
        if cphase[oi] == f and p.is_source:
            by_job.setdefault(int(job_of_op[oi]), []).append(
                np.arange(p.lo, p.hi))
    e_jobs = sorted(by_job)
    e_idx, e_mask = _rows([np.concatenate(by_job[j]) for j in e_jobs])

    # one slot per distinct source op of the phase's edges
    slot_index: dict[int, int] = {}
    slot_of_edge = np.zeros(ph.n_edges, np.int32)
    for ei, (oi, _, _) in enumerate(mine):
        if oi not in slot_index:
            slot_index[oi] = len(slot_index)
        slot_of_edge[ei] = slot_index[oi]
    slots = sorted(slot_index, key=slot_index.get)
    s_idx, s_mask = _rows([np.arange(ops[oi].lo, ops[oi].hi)
                           for oi in slots])

    # edge / group / block rows index into the D axis; block-source rows
    # index the arena. Dummy rows (B / G) stay all-pad: their sums are
    # 0.0 and their minima inf, matching the dense dummy segments.
    d_pos = np.arange(ph.D)
    er_idx, er_mask = _rows([d_pos[ph.edge_of == ei]
                             for ei in range(ph.n_edges)])
    gr_idx, gr_mask = _rows([d_pos[ph.grp_of == g] for g in range(ph.G)],
                            n_rows=ph.G + 1)
    br_idx, br_mask = _rows([d_pos[ph.blk_of == b] for b in range(ph.B)],
                            n_rows=ph.B + 1)
    bs_idx, bs_mask = _rows([ph.bsrc_task[ph.bsrc_blk == b]
                             for b in range(ph.B)], n_rows=ph.B + 1)
    dj = sorted(set(int(j) for j in ph.job_of_entry))
    dj_idx, dj_mask = _rows([d_pos[ph.job_of_entry == j] for j in dj])

    return CompactPhase(
        consumes=ph.consumes, D=ph.D, E=ph.n_edges, B=ph.B, G=ph.G,
        cons_mask=ph.cons_mask,
        q_idx=q_idx, q_mask=q_mask, q_ops=q_ops,
        e_idx=e_idx, e_mask=e_mask,
        e_jobs=np.array(e_jobs, np.int32),
        s_idx=s_idx, s_mask=s_mask, slot_of_edge=slot_of_edge,
        slot_ops=np.array(slots, np.int32),
        dst_task=ph.dst_task, fwd_src=ph.fwd_src, edge_of=ph.edge_of,
        grp_of=ph.grp_of, blk_of=ph.blk_of,
        m_fwd=ph.is_fwd.astype(float), m_blk=ph.is_blk.astype(float),
        m_hash=ph.is_hash.astype(float),
        m_weakhash=ph.is_weakhash.astype(float),
        m_backlog=ph.is_backlog.astype(float), is_norm=ph.is_norm,
        m_acc_static=ph.acc_static.astype(float),
        m_acc_block=ph.acc_block.astype(float),
        dst_in_blk=ph.dst_in_blk, share=ph.share, mass=ph.mass,
        er_idx=er_idx, er_mask=er_mask, gr_idx=gr_idx, gr_mask=gr_mask,
        br_idx=br_idx, br_mask=br_mask, bs_idx=bs_idx, bs_mask=bs_mask,
        dj_idx=dj_idx, dj_mask=dj_mask,
        dj_jobs=np.array(dj, np.int32))


# ----------------------------------------------------------------------
# Multi-job mega-arena (cluster-perspective co-location, paper §V)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class JobSlice:
    """One job's footprint inside a packed arena.

    ``task_lo:task_hi`` is the job's contiguous task-id slice of the flat
    arena; ``op_cols`` are its columns in the plan's topo op order (also
    contiguous — jobs have no cross edges, so the combined topo order is
    the per-job topo orders concatenated); ``src_cols`` are the subset of
    ``op_cols`` belonging to the job's sources (per-job source lag);
    ``hosts`` maps the job's *local* host ids to the global pool."""
    index: int
    name: str
    graph: LogicalGraph            # the original, un-namespaced graph
    prefix: str
    task_lo: int
    task_hi: int
    op_cols: np.ndarray            # indices into plan.ops (topo order)
    op_names: list[str]            # original names, aligned with op_cols
    src_cols: np.ndarray           # subset of op_cols: the job's sources
    hosts: np.ndarray              # local host id -> global host id
    region_lo: int = 0
    region_hi: int = 0
    # job-local host id per job-local task index (the placement BEFORE
    # lifting through `hosts`) — per-job ChaosSpecs draw stragglers and
    # kills in this local domain, exactly like an independent run
    local_host: np.ndarray | None = None


@dataclasses.dataclass
class PackedArena:
    """K co-located job graphs lowered into ONE flat task arena.

    Arena layout
    ------------
    * Ops of job j are namespaced ``f"j{j}."`` and concatenated in job
      order, so `build_plan` on the combined graph numbers every job's
      tasks contiguously with arena-global offsets — one `RoutingPlan`,
      one task arena, one engine tick for the whole co-located fleet.
      Jobs have no cross edges: records never flow between jobs.
    * Hosts are a single shared pool of size ``n_hosts``. Each job keeps
      its *local* round-robin placement (``local_tid % n_hosts_local``,
      identical to an independent `expand`) and a per-job ``hosts`` map
      lifts local host ids into the pool — overlapping maps co-locate
      jobs on shared hosts, disjoint maps reproduce K independent
      clusters exactly (the parity anchor in tests/test_colocation.py).
    * Failure regions never merge across jobs (no cross-job channels), so
      the arena's region list is the per-job region lists, offset.

    Shared-host kill semantics: a chaos kill of host h downs the tasks of
    EVERY job placed on h — under region failover each affected job's hit
    regions restart; under single_task each affected job drops in-flight
    records routed to its dead tasks. Cross-job interference is therefore
    a first-class swept quantity (one host kill couples many jobs'
    recovery, the paper's cluster-level coupling).

    Per-job metric segments: `job_of_op` / `job_of_task` segment the
    per-op metric columns and the task arena by job; engines use them for
    per-job emitted/dropped accounting and per-job recovery attribution
    (``"job"`` key on recovery events).
    """
    graph: LogicalGraph            # combined, namespaced
    plan: RoutingPlan
    phys: PhysicalGraph
    jobs: list[JobSlice]
    job_of_task: np.ndarray        # (n_tasks,) int
    job_of_op: np.ndarray          # (n_ops,) int, topo order
    n_hosts: int                   # global pool size (kill-draw domain)

    @property
    def n_jobs(self) -> int:
        return len(self.jobs)

    @property
    def dt(self) -> float:
        return self.plan.dt

    @property
    def queue_cap(self) -> float:
        return self.plan.queue_cap

    def job(self, name_or_index) -> JobSlice:
        if isinstance(name_or_index, int):
            return self.jobs[name_or_index]
        return next(j for j in self.jobs if j.name == name_or_index)

    def lift_kills(self, job: int, host_kill_at) -> tuple:
        """Translate a job-local ``host_kill_at`` schedule into the global
        host pool (chaos specs address pool hosts; a drill written against
        one job's local hosts is lifted through that job's host map)."""
        m = self.jobs[job].hosts
        return tuple((t, int(m[h])) for (t, h) in host_kill_at)


def pack_arena(graphs, host_map="shared", *, n_hosts: int = 8,
               dt: float = 0.5, queue_cap: float = 256.0,
               names=None) -> PackedArena:
    """Lower K co-located job graphs into one `PackedArena`.

    `host_map` controls co-location:
      * ``"shared"``   — every job uses the same pool hosts 0..n_hosts-1
                         (full co-location; host kills couple all jobs);
      * ``"disjoint"`` — job j uses hosts ``[j*n_hosts, (j+1)*n_hosts)``
                         (no interference; packed == K independent runs);
      * explicit       — sequence of K int arrays, each mapping the job's
                         local host ids ``0..n_hosts-1`` to pool ids.

    `names` optionally labels jobs (default ``f"j{j}.{graph.name}"``).
    """
    graphs = list(graphs)
    if not graphs:
        raise ValueError("pack_arena requires at least one job graph")
    k = len(graphs)
    if host_map == "shared":
        maps = [np.arange(n_hosts) for _ in range(k)]
    elif host_map == "disjoint":
        maps = [j * n_hosts + np.arange(n_hosts) for j in range(k)]
    else:
        maps = [np.asarray(m, dtype=int) for m in host_map]
        if len(maps) != k:
            raise ValueError(f"host_map has {len(maps)} rows for {k} jobs")
        if any(len(m) != n_hosts for m in maps):
            raise ValueError("each host_map row must map all local hosts")
    n_pool = int(max(m.max() for m in maps)) + 1

    prefixes = [f"j{j}." for j in range(k)]
    parts = [namespaced(g, p) for g, p in zip(graphs, prefixes)]
    combined = LogicalGraph(
        "+".join(g.name for g in graphs),
        ops=tuple(o for g in parts for o in g.ops),
        edges=tuple(e for g in parts for e in g.edges))
    plan = build_plan(combined, dt, queue_cap)

    # physical assembly: per-job expand (regions/channels depend only on
    # connectivity) + manual host lift through the job's host map. Task
    # numbering follows combined-graph op order, which equals per-job
    # expand order with the job's task offset added — the same contract
    # build_plan's offsets assume.
    tasks: list[Task] = []
    channels: dict = {}
    regions: list[set[int]] = []
    task_region: dict[int, int] = {}
    jobs: list[JobSlice] = []
    job_of_task = np.zeros(plan.n_tasks, dtype=int)
    topo_pos = {p.name: i for i, p in enumerate(plan.ops)}
    job_of_op = np.zeros(len(plan.ops), dtype=int)
    task_off = 0
    for j, (g, pre) in enumerate(zip(graphs, prefixes)):
        local = expand(g, n_hosts=n_hosts)
        for tk in local.tasks:
            tasks.append(Task(pre + tk.op, tk.index, task_off + tk.task_id,
                              host=int(maps[j][tk.host])))
        for (s, d), conn in local.channels.items():
            channels[(pre + s, pre + d)] = conn
        region_lo = len(regions)
        for r in local.regions:
            regions.append({task_off + t for t in r})
        for t, r in local.task_region.items():
            task_region[task_off + t] = region_lo + r
        n_local = len(local.tasks)
        job_of_task[task_off:task_off + n_local] = j
        op_cols = np.array(sorted(topo_pos[pre + o.name] for o in g.ops))
        job_of_op[op_cols] = j
        jobs.append(JobSlice(
            index=j,
            name=(names[j] if names is not None else pre + g.name),
            graph=g, prefix=pre, task_lo=task_off,
            task_hi=task_off + n_local, op_cols=op_cols,
            op_names=[plan.ops[c].name[len(pre):] for c in op_cols],
            src_cols=np.array([c for c in op_cols
                               if plan.ops[c].is_source]),
            hosts=maps[j], region_lo=region_lo, region_hi=len(regions),
            local_host=np.array([tk.host for tk in local.tasks])))
        task_off += n_local
    assert task_off == plan.n_tasks
    phys = PhysicalGraph(combined, tasks, channels, regions, task_region)
    return PackedArena(combined, plan, phys, jobs, job_of_task, job_of_op,
                       n_pool)


class StreamEngine:
    def __init__(self, graph: LogicalGraph | PackedArena, *,
                 n_hosts: int = 8,
                 dt: float = 0.5, queue_cap: float = 256.0,
                 chaos: ChaosEngine | None = None,
                 failover: FailoverConfig | None = None,
                 ckpt: CheckpointConfig | None = None,
                 upgrade: UpgradeConfig | None = None,
                 autoscale: AutoscaleConfig | None = None,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0):
        self.arena = graph if isinstance(graph, PackedArena) else None
        if self.arena is not None:
            # packed mega-arena: the lowering (plan + physical placement
            # over the shared host pool) was done by pack_arena; dt and
            # queue_cap come from the arena's plan.
            graph = self.arena.graph
            dt, queue_cap = self.arena.dt, self.arena.queue_cap
        self.g = graph
        self.phys: PhysicalGraph = (
            self.arena.phys if self.arena is not None
            else expand(graph, n_hosts=n_hosts, seed=seed))
        self.dt = dt
        self.queue_cap = queue_cap
        # per-job chaos: one ChaosEngine per co-located job, each drawing
        # in its job's LOCAL host domain and lifted through the job's
        # host map (see build_perjob_chaos_timeline for the contract)
        if isinstance(chaos, (list, tuple)):
            if self.arena is None or len(chaos) != self.arena.n_jobs:
                raise ValueError("a per-job chaos list needs a packed "
                                 "arena with one entry per job")
            self._chaos_list = [
                c if isinstance(c, ChaosEngine)
                else ChaosEngine(c)       # ChaosSpec or None
                for c in chaos]
            self.chaos = self._chaos_list[0]
            # a shared CheckpointConfig has no shared engine to draw
            # from under per-job chaos — lower it onto per-job
            # coordinators, one per job, each on its own stream
            if isinstance(ckpt, CheckpointConfig):
                ckpt = [ckpt] * self.arena.n_jobs
        else:
            self._chaos_list = None
            self.chaos = chaos or ChaosEngine()
        self.failover = (failover if failover is not None
                         else FailoverConfig())
        self.ckpt_cfg = ckpt
        self.rng = np.random.default_rng(seed)
        self.t = 0.0

        # ---- task arena + routing plan --------------------------------
        self.plan = (self.arena.plan if self.arena is not None
                     else build_plan(graph, dt, queue_cap))
        ops = {o.name: o for o in graph.ops}
        offs = self.plan.offs
        n_tasks = self.plan.n_tasks
        assert n_tasks == len(self.phys.tasks)

        self._queue = np.zeros(n_tasks)
        self._down_until = np.zeros(n_tasks)
        self._speed = np.ones(n_tasks)
        self._qcap = self.plan.qcap
        if task_speed_override:
            for tk in self.phys.tasks:
                if tk.task_id in task_speed_override:
                    self._speed[tk.task_id] = task_speed_override[tk.task_id]
        # chaos host stragglers (queried in task order — keeps the chaos rng
        # stream identical to the reference engine). Per-job chaos draws
        # in the job's LOCAL host domain, like an independent run.
        if self._chaos_list is not None:
            jobs_ = self.arena.jobs
            jot = self.arena.job_of_task
            for tk in self.phys.tasks:
                job = jobs_[int(jot[tk.task_id])]
                lh = int(job.local_host[tk.task_id - job.task_lo])
                self._speed[tk.task_id] *= \
                    self._chaos_list[job.index].host_speed(lh)
        else:
            for tk in self.phys.tasks:
                self._speed[tk.task_id] *= self.chaos.host_speed(tk.host)

        self._task_host = np.array([tk.host for tk in self.phys.tasks])
        self._task_region = np.array(
            [self.phys.task_region[tk.task_id] for tk in self.phys.tasks])
        # kill draws cover the whole shared pool for packed arenas (hosts
        # without tasks of SOME job may still host another job's tasks)
        self._n_hosts = (self.arena.n_hosts if self.arena is not None
                         else int(self._task_host.max()) + 1)
        if self.arena is not None:
            self._job_of_op = self.arena.job_of_op
            self._job_of_task = self.arena.job_of_task
        else:
            self._job_of_op = self._job_of_task = None

        # per-task failover vectors (uniform configs are constant vectors;
        # per-job FailoverConfig lists vary by job slice)
        codes, det, rst_s, rst_r, fx = per_task_failover(
            failover, n_tasks, self._job_of_task)
        self._mode_single = codes == 2
        self._mode_region = codes == 1
        self._mode_hot = codes == 3
        self._any_single = bool(self._mode_single.any())
        self._downtime_single = det + rst_s
        self._downtime_region = det + rst_r
        # hot-standby pays switch + staleness replay, never a restore
        self._downtime_hot = det + fx["switch"] + fx["stale"]
        # passive-restore surcharge inputs (zero by default → no-op):
        # extra = restore_base*brownout(t) + ckpt_age(t)*replay + lazy
        self._restore_base = fx["restore_base"]
        self._replay_rate = fx["replay_rate"]
        self._lazy_extra = lazy_ready_extra(
            fx["stagger"], self._task_region, self._job_of_task)
        self._has_extra = bool(self._restore_base.any()
                               or self._replay_rate.any()
                               or self._lazy_extra.any())

        # checkpoint coordinators: one shared (historical semantics, incl.
        # the cross-region short-circuit) or one per job (per-job configs)
        self._last_ckpt_t = 0.0          # shared coordinator
        self._last_ckpt_vec = None       # per-job coordinators
        if ckpt is None or isinstance(ckpt, CheckpointConfig):
            self._ckpt_list = None
            self._next_ckpt = (ckpt.interval_s if ckpt else math.inf)
        else:
            cfgs = list(ckpt)
            if self.arena is None or len(cfgs) != self.arena.n_jobs:
                raise ValueError("per-job ckpt list needs a packed arena "
                                 "with one entry per job")
            self._ckpt_list = cfgs
            self._next_ckpt = math.inf
            self._next_ckpt_j = np.array(
                [c.interval_s if c is not None else math.inf
                 for c in cfgs])
            self._last_ckpt_vec = np.zeros(self.arena.n_jobs)

        # compat: per-op dict views aliasing the arena (tests / tooling)
        self.par = {n: ops[n].parallelism for n in ops}
        self.qcap = {n: float(self._qcap[offs[n]]) for n in ops}
        self.queue = {n: self._queue[offs[n]:offs[n] + self.par[n]]
                      for n in ops}
        self.down_until = {n: self._down_until[offs[n]:offs[n] + self.par[n]]
                           for n in ops}
        self.speed = {n: self._speed[offs[n]:offs[n] + self.par[n]]
                      for n in ops}

        # ---- op + edge plans (speed-dependent fast-path rows) ----------
        self._ops = self.plan.ops
        for p in self._ops:
            if not p.is_source:
                p.cap_row = p.service_rate * dt * self._speed[p.lo:p.hi].copy()
        self._src_ops = [p for p in self._ops if p.is_source]
        self._arena_starts = self.plan.arena_starts
        self._backlog_perm = self.plan.backlog_perm
        self._src_cols = self.plan.src_cols

        # per-tick reusable arena-sized scratch
        self._alive_buf = np.empty(n_tasks, bool)
        self._alive_f_buf = np.empty(n_tasks)
        self._free_buf = np.empty(n_tasks)
        self._qps_buf = np.zeros(len(self._ops))
        self._true_buf = np.ones(n_tasks, bool)
        self._ones_buf = np.ones(n_tasks)
        self._max_down = 0.0          # latest down_until across the arena
        if self._chaos_list is not None:
            self._chaos_kills_possible = any(
                bool(e.spec.host_kill_at or e.spec.host_kill_prob_per_s
                     or e.spec.burst_at)
                for e in self._chaos_list)
            self._gates_possible = any(
                bool(e.spec.mq_down)
                or (bool(e.spec.zk_down) and bool(e.spec.hdfs_down))
                for e in self._chaos_list)
            self._traffic_possible = any(
                bool(e.spec.diurnal or e.spec.flash_at)
                for e in self._chaos_list)
            # region-correlated bursts: lower each job's burst events
            # into scheduled host kills in the job's LOCAL host domain
            for job, eng in zip(self.arena.jobs, self._chaos_list):
                if eng.spec.burst_at:
                    sl = slice(job.task_lo, job.task_hi)
                    eng.schedule_kills(burst_kill_schedule(
                        eng.spec.burst_at, job.local_host,
                        self._task_region[sl]))
        else:
            spec = self.chaos.spec
            self._chaos_kills_possible = bool(
                spec.host_kill_at or spec.host_kill_prob_per_s
                or spec.burst_at)
            self._gates_possible = bool(spec.mq_down) or (
                bool(spec.zk_down) and bool(spec.hdfs_down))
            self._traffic_possible = bool(spec.diurnal or spec.flash_at)
            if spec.burst_at:
                self.chaos.schedule_kills(burst_kill_schedule(
                    spec.burst_at, self._task_host, self._task_region))

        # ---- deployment drill (canaried rolling upgrade) ---------------
        # lowered ONCE into traced per-task leaves; everything below is
        # deterministic time arithmetic — no rng draws, no timeline work
        self.upgrade = upgrade
        if upgrade is not None:
            sel_task = np.zeros(n_tasks)
            for p in self._ops:
                if not p.is_source:
                    sel_task[p.lo:p.hi] = p.selectivity
            dr = lower_upgrade(
                upgrade,
                (self._chaos_list if self._chaos_list is not None
                 else self.chaos.spec),
                n_tasks=n_tasks, job_of_task=self._job_of_task,
                task_region=self._task_region, dt=dt,
                base_failover=(codes, det, rst_s, rst_r, fx),
                base_ckpt=ckpt, sel_task=sel_task)
            self._dr = dr
            self._mode_single_f = self._mode_single.astype(float)
            self._mode_region_f = self._mode_region.astype(float)
            self._mode_hot_f = self._mode_hot.astype(float)
            self._any_single_eff = (self._any_single
                                    or bool((dr["d_mode_s"] > 0).any()))
            self._has_extra_eff = (self._has_extra
                                   or bool(dr["d_restore"].any()
                                           or dr["d_replay"].any()
                                           or dr["d_ck"].any()))
        else:
            self._dr = None
        self._up_until = np.zeros(n_tasks)   # graceful waves: ≠ down_until
        self._rb_t = math.inf                # rollback fire time
        self._dacc = 0.0                     # controller EWMA accumulator
        self._act = np.zeros(n_tasks)        # canary-config activation

        # ---- in-trace DS2 autoscaler (lowered controller leaves) -------
        # mirrors jax_engine's `_finish_tick` controller EXACTLY (same
        # step order, same `where`-gated updates) — the parity contract
        self.autoscale = autoscale
        if autoscale is not None:
            is_src = np.zeros(n_tasks)
            for p in self._ops:
                if p.is_source:
                    is_src[p.lo:p.hi] = 1.0
            self._as = lower_autoscale(autoscale, n_tasks=n_tasks, dt=dt,
                                       is_src_task=is_src)
        else:
            self._as = None
        # capacity base (service_rate·dt on non-source tasks, 0 on
        # sources) — recomputed·speed per tick when the autoscaler
        # mutates speeds (cap_row above is baked with the INITIAL speed)
        self._cap_base = np.zeros(n_tasks)
        for p in self._ops:
            if not p.is_source:
                self._cap_base[p.lo:p.hi] = p.service_rate * dt
        self._rew = np.zeros(n_tasks)        # EWMA'd utilization (need)
        self._lact = np.full(n_tasks, -1e18)  # last rescale time
        self._dirp = np.zeros(n_tasks)       # last rescale direction
        self._failcnt = np.zeros(n_tasks)    # breaker failure counter
        self._brk_until = np.zeros(n_tasks)  # breaker-open-until
        self._used = 0.0                     # leaky action-rate bucket
        self._flip_acc = 0.0                 # leaky direction-flip count
        self._thrash_t = math.inf            # thrash-latch fire time
        self._take_buf = np.zeros(n_tasks)   # records consumed this tick
        self._hit_buf = np.zeros(n_tasks)    # failover-hit this tick

        self.metrics = EngineMetrics(
            [p.name for p in self._ops],
            n_jobs=(self.arena.n_jobs if self.arena is not None else None))
    # ------------------------------------------------------------------
    def _alive(self, op: str) -> np.ndarray:
        return self.down_until[op] <= self.t

    # -- per-edge vectorized routing -----------------------------------
    def _route(self, ep: _EdgePlan, produced: np.ndarray,
               free_down: np.ndarray, alive_d: np.ndarray) -> np.ndarray:
        """arriving (n_dst,) — the collapsed `produced @ W` of the
        reference's row-stochastic weights."""
        kind = ep.kind
        if kind == "forward":
            return produced * alive_d
        if kind in ("rescale", "group_rescale"):
            prod_blk = np.bincount(ep.blk_of_src, weights=produced,
                                   minlength=ep.n_blocks)
            alive_blk = np.bincount(ep.blk_idx[ep.dst_in_blk],
                                    weights=alive_d[ep.dst_in_blk],
                                    minlength=ep.n_blocks)
            prod_blk[alive_blk <= 0] = 0.0
            rate_blk = np.divide(prod_blk, alive_blk, out=prod_blk,
                                 where=alive_blk > 0)
            arriving = rate_blk[ep.blk_idx]
            arriving *= alive_d
            if ep.any_unblocked:
                arriving[~ep.dst_in_blk] = 0.0
            return arriving
        # all-to-all family: identical weight rows → scale a single row
        total = produced.sum()
        if kind == "rebalance":
            val = alive_d
        elif kind == "hash":
            # strict keyBy ignores dst liveness/congestion (γ=partial trade)
            return total * ep.share
        elif kind == "weakhash":
            cap = np.maximum(free_down, 1e-9, out=ep.ratio_buf)
            cap *= alive_d
            capsum = np.add.reduceat(cap, ep.grp_starts)
            # groups with zero capacity fall back to alive-uniform spread
            # (only reachable when a whole group is down — cheap to branch)
            if not capsum.all():
                fall = capsum <= 0
                cap = np.where(fall[ep.grp_of_dst], alive_d + 1e-9, cap)
                capsum = np.where(fall, np.add.reduceat(alive_d + 1e-9,
                                                        ep.grp_starts),
                                  capsum)
                cap *= alive_d   # dead dsts stay weightless (alive² = alive)
            val = cap
            val *= ep.mass_of_dst
            val /= capsum[ep.grp_of_dst]
        elif kind == "backlog":
            open_ = np.greater(free_down, ep.dst_qcap * 0.25,
                               out=ep.live_buf)
            val = np.maximum(free_down, 1e-9, out=ep.ratio_buf)
            val *= alive_d
            val *= np.maximum(open_, 0.05)
        else:
            raise ValueError(kind)
        rs = val.sum()
        return val * (total / rs) if rs > 0 else np.zeros_like(val)

    def _accept(self, ep: _EdgePlan, arriving: np.ndarray,
                room: np.ndarray) -> np.ndarray:
        if ep.static:
            # head-of-line blocking: the most congested live channel
            # throttles the whole exchange (credit-based flow control)
            live = np.greater(arriving, 1e-9, out=ep.live_buf)
            ratio = ep.ratio_buf
            ratio.fill(np.inf)
            np.divide(room, arriving, out=ratio, where=live)
            lam = float(ratio.min())
            if lam >= 1.0:   # includes the no-live-channel case (all inf)
                return arriving
            return arriving * lam
        if ep.kind == "group_rescale":
            # blocking confined to each group (Fig 2c)
            live = np.greater(arriving, 1e-9, out=ep.live_buf)
            ratio = ep.ratio_buf
            ratio.fill(np.inf)
            np.divide(room, arriving, out=ratio, where=live)
            lam_g = np.minimum(np.minimum.reduceat(ratio, ep.grp_starts), 1.0)
            return arriving * lam_g[ep.grp_of_dst]
        # adaptive routing (backlog/weakhash): channels accept up to their
        # credits; remainder re-queues at the source for re-routing
        return np.minimum(arriving, room)

    # ------------------------------------------------------------------
    def tick(self) -> None:
        dt = self.dt
        t = self.t
        q = self._queue
        dr = self._dr
        a = self._as
        if a is not None:
            self._take_buf.fill(0.0)
            self._hit_buf.fill(0.0)
            # breaker-open load shed only multiplies selectivities when
            # some breaker IS open (×1.0 otherwise — exact no-op)
            self._brk_any = bool((self._brk_until > t).any())
        all_alive = t >= self._max_down
        if all_alive:
            alive_all = self._true_buf
            alive_f = self._ones_buf
        else:
            alive_all = np.less_equal(self._down_until, t,
                                      out=self._alive_buf)
            if dr is not None or a is not None:
                # upgrade/rollback waves (and autoscaler rescales) down
                # tasks gracefully (queues persist) on a separate leaf
                # so checkpoint alive masks — and thus the shared rng
                # draw stream — never see them
                np.logical_and(alive_all, self._up_until <= t,
                               out=alive_all)
            np.copyto(self._alive_f_buf, alive_all)   # bool → float cast
            alive_f = self._alive_f_buf
            all_alive = bool(alive_all.all())
        if dr is not None:
            # canary-config activation: 1.0 once a task's upgrade wave
            # completed and its rollback wave (if any) has not yet begun
            np.multiply(
                dr["up_cmask"],
                (t >= dr["up_start"] + dr["up_down"])
                & (t < self._rb_t + dr["up_rstag"]),
                out=self._act)
        act = self._act
        free = np.subtract(self._qcap, q, out=self._free_buf)
        np.maximum(free, 0.0, out=free)
        qps_row = self._qps_buf
        qps_row.fill(0.0)
        drop_tick = 0.0
        any_single = self._any_single if dr is None else self._any_single_eff
        emitted = 0.0

        # MQ/coordinator outage windows gate sources (deterministic, no
        # rng): a down message queue — or a leaderless control plane
        # (ZK quorum AND HDFS metadata both out, paper §IV-B) — means
        # sources emit nothing this tick
        if self._gates_possible:
            if self._chaos_list is not None:
                gate_by_job = np.array(
                    [1.0 if (e.mq_available(t) and e.leader_available(t))
                     else 0.0
                     for e in self._chaos_list])
                gate0 = 1.0
            else:
                gate_by_job = None
                gate0 = (1.0 if (self.chaos.mq_available(t)
                                 and self.chaos.leader_available(t))
                         else 0.0)
        else:
            gate_by_job = None
            gate0 = 1.0

        # traffic dynamics (diurnal curves + flash-crowd ramps) scale
        # source emission — deterministic closed-form curves, NO rng
        if self._traffic_possible:
            if self._chaos_list is not None:
                tf_by_job = np.array(
                    [e.traffic_factor(t) for e in self._chaos_list])
                tf0 = 1.0
            else:
                tf_by_job = None
                tf0 = self.chaos.traffic_factor(t)
        else:
            tf_by_job = None
            tf0 = 1.0

        jobs = self._job_of_op          # per-job segments (packed arenas)
        for oi, op in enumerate(self._ops):
            sl = slice(op.lo, op.hi)
            if op.is_source:
                if all_alive:
                    produced = op.src_row
                    e_op = op.src_sum
                else:
                    produced = op.src_row * alive_f[sl]
                    e_op = produced.sum()
                gate = (gate0 if gate_by_job is None
                        else float(gate_by_job[jobs[oi]]))
                if gate != 1.0:
                    produced = produced * gate
                    e_op = e_op * gate
                tf = (tf0 if tf_by_job is None
                      else float(tf_by_job[jobs[oi]]))
                if tf != 1.0:
                    produced = produced * tf
                    e_op = e_op * tf
                emitted += e_op
                if jobs is not None:
                    self.metrics._emitted_by_job[jobs[oi]] += e_op
            else:
                if a is None:
                    cap = (op.cap_row if all_alive
                           else op.cap_row * alive_f[sl])
                else:
                    # cap_row bakes the INITIAL speed — recompute once
                    # the autoscaler may have rescaled this op's tasks
                    cap = self._cap_base[sl] * self._speed[sl]
                    if not all_alive:
                        cap = cap * alive_f[sl]
                take = np.minimum(q[sl], cap)
                q[sl] -= take
                if dr is None:
                    sel_eff = op.selectivity
                else:
                    # canary slices run their own selectivity vector
                    sel_eff = op.selectivity + act[sl] * dr["d_sel"][sl]
                if a is not None:
                    self._take_buf[sl] = take
                    if self._brk_any:
                        # breaker-open graceful degradation: load-shed
                        # by scaling selectivity (same multiply grouping
                        # as jax's `sel_t * shed_t` — parity contract)
                        sel_eff = sel_eff * np.where(
                            t < self._brk_until[sl], a["as_shed"], 1.0)
                produced = take * sel_eff
                qps_row[oi] = take.sum() / dt

            for ep in op.out_edges:
                dsl = slice(ep.dst.lo, ep.dst.hi)
                arriving = self._route(ep, produced, free[dsl], alive_f[dsl])
                if any_single and not all_alive:
                    # records routed to a dead single_task-mode task drop
                    # (γ=partial); per-job configs scope the mode per dst;
                    # canary slices may flip the mode mask mid-run
                    if dr is None:
                        dead = ~alive_all[dsl] & self._mode_single[dsl]
                    else:
                        ms_eff = (self._mode_single_f[dsl]
                                  + act[dsl] * dr["d_mode_s"][dsl]) > 0.5
                        dead = ~alive_all[dsl] & ms_eff
                    if dead.any():
                        d_edge = arriving[dead].sum()
                        drop_tick += d_edge
                        if jobs is not None:   # edges never cross jobs
                            self.metrics._dropped_by_job[jobs[oi]] += d_edge
                        arriving = np.where(dead, 0.0, arriving)
                accepted = self._accept(ep, arriving, free[dsl])
                if accepted is not arriving:
                    overflow = (arriving - accepted).sum()
                    if overflow != 0.0:
                        q[sl] += overflow / max(op.par, 1)
                q[dsl] += accepted
                free_d = free[dsl]
                free_d -= accepted
                np.maximum(free_d, 0.0, out=free_d)

        # chaos host kills → failover (skip entirely when the chaos spec
        # cannot produce kills — step_kills would draw nothing and return [])
        if self._chaos_kills_possible:
            if self._chaos_list is not None:
                # per-job kill processes: jobs draw in ascending job
                # order over their LOCAL host domains, lifted through
                # the job's host map; a pool host killed by several
                # jobs' processes this tick resolves once
                failed_pool: set[int] = set()
                for job in self.arena.jobs:
                    eng = self._chaos_list[job.index]
                    spec = eng.spec
                    if not (spec.host_kill_at or spec.host_kill_prob_per_s
                            or spec.burst_at):
                        continue
                    m = job.hosts
                    for lh in eng.step_kills(t, t + dt, n_hosts=len(m)):
                        if lh < len(m):
                            pool = int(m[lh])
                            if pool not in failed_pool:
                                failed_pool.add(pool)
                                self._fail_host(pool, revive=False)
                        eng.revive(lh)
            else:
                kills = self.chaos.step_kills(t, t + dt,
                                              n_hosts=self._n_hosts)
                for host in kills:
                    self._fail_host(host)

        # checkpoint coordinator(s): one shared, or one per job
        if t + dt >= self._next_ckpt:
            self._run_checkpoint()
            self._next_ckpt += self.ckpt_cfg.interval_s
        elif self._ckpt_list is not None:
            for j in np.nonzero(t + dt >= self._next_ckpt_j)[0]:
                self._run_checkpoint_job(int(j))
                self._next_ckpt_j[j] += self._ckpt_list[j].interval_s

        # drill controller + wave scheduler (end-of-tick, mirrors the
        # traced order in jax_engine._finish_tick exactly): the EWMA of
        # the canary-vs-stable mean-queue delta updates first, then the
        # rollback decision reads the UPDATED accumulator, then the
        # wave triggers read the UPDATED rollback time
        if dr is not None:
            delta = float(q @ dr["up_wdelta"])
            if t >= dr["up_t0"]:
                self._dacc += dr["up_alpha"] * (delta - self._dacc)
                if self._dacc > dr["up_thresh"] and math.isinf(self._rb_t):
                    self._rb_t = t + dt
                    self.metrics.rollback_t = self._rb_t
            trig = (t <= dr["up_start"]) & (dr["up_start"] < t + dt)
            if trig.any():
                self._up_until[trig] = np.maximum(
                    self._up_until[trig], dr["up_start"][trig]
                    + dr["up_down"])
                self._max_down = max(self._max_down,
                                     float(self._up_until.max()))
            rb_start = self._rb_t + dr["up_rstag"]
            trig = (t <= rb_start) & (rb_start < t + dt)
            if trig.any():
                self._up_until[trig] = np.maximum(
                    self._up_until[trig], rb_start[trig] + dr["up_down"])
                self._max_down = max(self._max_down,
                                     float(self._up_until.max()))

        # autoscale controller (end-of-tick, AFTER kills/ckpt/drill —
        # mirrors jax_engine._finish_tick's traced order exactly): the
        # utilization EWMA updates first, the breaker update reads this
        # tick's failover hits, then the decision reads the UPDATED
        # accumulator and UPDATED breaker state
        if a is not None:
            cap_now = self._cap_base * self._speed
            need = ((self._take_buf + q * (dt / a["as_drain"]))
                    / np.maximum(cap_now, 1e-9))
            self._rew += a["as_alpha"] * (need - self._rew)
            hit = self._hit_buf
            recent = (t - self._lact) <= a["as_fw"]
            failev = (hit > 0.0) & recent
            crossed = (((t - self._lact) > a["as_fw"])
                       & ((t - dt - self._lact) <= a["as_fw"]))
            failcnt = np.where(
                failev, self._failcnt + 1.0,
                np.where(crossed & (hit <= 0.0), 0.0, self._failcnt))
            brk_fire = failcnt >= a["as_bfail"]
            self._brk_until = np.where(brk_fire, t + a["as_brs"],
                                       self._brk_until)
            self._failcnt = np.where(brk_fire, 0.0, failcnt)
            boundary = (math.floor((t + dt - a["as_t0"]) / a["as_int"])
                        > math.floor((t - a["as_t0"]) / a["as_int"]))
            want = np.clip(self._speed * self._rew / a["as_tgt"],
                           a["as_lo"], a["as_hi"])
            rel = (np.abs(want - self._speed)
                   / np.maximum(self._speed, 1e-9))
            fire = (boundary & (a["as_on"] > 0.0) & (a["as_mask"] > 0.0)
                    & (rel >= a["as_hyst"])
                    & ((t - self._lact) >= a["as_cool"])
                    & (t >= self._brk_until)
                    & (self._used < a["as_amax"])
                    & math.isinf(self._thrash_t))
            new_speed = np.where(fire, want, self._speed)
            self._lact = np.where(fire, t, self._lact)
            dirn = np.sign(want - self._speed)
            if fire.any():
                # graceful rescale: queues persist, the task pays
                # deploy downtime + state-move seconds on `up_until`
                downt = (a["as_down"]
                         + a["as_move"] * np.abs(want - self._speed))
                np.maximum(self._up_until, np.where(fire, t + downt, 0.0),
                           out=self._up_until)
                self._max_down = max(self._max_down,
                                     float(self._up_until.max()))
                any_fire = 1.0
            else:
                any_fire = 0.0
            self._used = self._used * a["as_adec"] + any_fire
            flip = fire & (dirn * self._dirp < 0.0)
            self._dirp = np.where(fire, dirn, self._dirp)
            self._flip_acc = (self._flip_acc * a["as_tdec"]
                              + float(flip.sum()))
            if (self._flip_acc >= a["as_tflip"]
                    and math.isinf(self._thrash_t)):
                # thrash latch: freeze the controller for the rest of
                # the run (fire above reads the PRE-latch thrash_t)
                self._thrash_t = t + dt
                self.metrics.thrash_t = self._thrash_t
            np.copyto(self._speed, new_speed)  # keep dict views aliased
            self.metrics.n_rescale += float(fire.sum())
        self.metrics.resource_s += float(self._speed.sum()) * dt

        backlog_row = np.add.reduceat(q, self._arena_starts)[
            self._backlog_perm]
        lag = float(backlog_row[self._src_cols].sum())
        self.metrics._record(t, qps_row, backlog_row, lag)
        self.metrics.emitted += emitted
        self.metrics.dropped += drop_tick
        self.t = t + dt

    def run(self, duration_s: float) -> EngineMetrics:
        n = int(round(duration_s / self.dt))
        self.metrics._reserve(n)
        for _ in range(n):
            self.tick()
        return self.metrics

    # ------------------------------------------------------------------
    def _fail_host(self, host: int, revive: bool = True) -> None:
        """Failover response to one host kill: region-mode victims expand
        to their failure regions, single_task-mode victims restart alone
        (region entries precede single_task entries when a shared-host
        kill hits jobs of both modes — the order the chaos timeline
        replays)."""
        t = self.t
        victims = self._task_host == host
        dr = self._dr
        act = self._act
        # passive-restore surcharge: brownout-inflated restore bandwidth
        # + replay of work since the last successful checkpoint + lazy-
        # load region ready-time (zero vectors → identical old downtimes).
        # Active canary slices pay it under their own config: restore /
        # replay deltas plus the canary-vs-base ckpt-interval ratio
        # scaling the replay-age term (same float arithmetic as the jax
        # engines' _finish_tick — the parity contract).
        has_extra = self._has_extra if dr is None else self._has_extra_eff
        if has_extra:
            if self._chaos_list is not None:
                bfj = np.array([e.brownout_factor(t)
                                for e in self._chaos_list])
                bf_t = bfj[self._job_of_task]
            else:
                bf_t = self.chaos.brownout_factor(t)
            age = t - (self._last_ckpt_vec[self._job_of_task]
                       if self._last_ckpt_vec is not None
                       else self._last_ckpt_t)
            if dr is None:
                extra = (self._restore_base * bf_t
                         + age * self._replay_rate + self._lazy_extra)
            else:
                extra = ((self._restore_base + act * dr["d_restore"])
                         * bf_t
                         + age * (1.0 + act * dr["d_ck"])
                         * (self._replay_rate + act * dr["d_replay"])
                         + self._lazy_extra)
        else:
            extra = None
        if dr is None:
            mr, ms, mh = (self._mode_region, self._mode_single,
                          self._mode_hot)
            dt_r, dt_s, dt_h = (self._downtime_region,
                                self._downtime_single, self._downtime_hot)
        else:
            mr = (self._mode_region_f + act * dr["d_mode_r"]) > 0.5
            ms = (self._mode_single_f + act * dr["d_mode_s"]) > 0.5
            mh = (self._mode_hot_f + act * dr["d_mode_h"]) > 0.5
            dt_r = self._downtime_region + act * dr["d_down_r"]
            dt_s = self._downtime_single + act * dr["d_down_s"]
            dt_h = self._downtime_hot + act * dr["d_down_h"]
        vr = victims & mr
        if vr.any():
            hit = np.isin(self._task_region, self._task_region[vr])
            d = dt_r if extra is None else dt_r + extra
            self._apply_failover(t, "region", hit, d)
        vs = victims & ms
        if vs.any():
            d = dt_s if extra is None else dt_s + extra
            self._apply_failover(t, "single_task", vs, d)
        # hot standby: switch + staleness replay only — no restore, no
        # checkpoint-age replay, no drops (the standby keeps consuming)
        vh = victims & mh
        if vh.any():
            self._apply_failover(t, "hot_standby", vh, dt_h)
        if revive:
            self.chaos.revive(host)  # replacement host

    def _apply_failover(self, t, mode, hit, downtime) -> None:
        until = t + downtime[hit]
        self._max_down = max(self._max_down, float(until.max()))
        self._down_until[hit] = until
        self._queue[hit] = 0.0   # incomplete output / state discarded
        if self._as is not None:
            # autoscaler breaker input: tasks failover-hit this tick
            self._hit_buf[hit] = 1.0
        # packed arenas attribute the event per co-located job hit
        self.metrics.recoveries.extend(failover_recovery_entries(
            t, mode, hit, downtime, self._job_of_task))

    # ------------------------------------------------------------------
    def _run_checkpoint(self) -> None:
        """Whole-arena coordinator — the rng consumption (vectorized
        per-task upload draws, stream-identical to per-task scalar draws
        in task-id order, plus region retries) is the shared
        `core.chaos.run_checkpoint_attempt`, so the pregenerated timeline
        replays it draw-for-draw."""
        cfg = self.ckpt_cfg
        m = self.metrics
        m.ckpt_attempts += 1
        ok = run_checkpoint_attempt(
            self.chaos, self._down_until <= self.t,
            interval_s=cfg.interval_s, mode=cfg.mode,
            upload_s=cfg.upload_s, retry=cfg.retry_failed_region,
            regions=self.phys.regions, t=self.t)
        if ok:
            self._last_ckpt_t = self.t
        m.ckpt_success += int(ok)
        m.ckpt_failed += int(not ok)

    def _run_checkpoint_job(self, j: int) -> None:
        """Per-job coordinator (per-job `CheckpointConfig`s): draws upload
        factors for job j's task slice only and evaluates only its own
        regions, so co-located jobs checkpoint on independent schedules
        and a failing job never short-circuits another job's attempt.
        Shares `core.chaos.run_checkpoint_attempt` with the timeline
        replay (`core.chaos._JobCkpt`), keeping the two draw-for-draw."""
        cfg = self._ckpt_list[j]
        job = self.arena.jobs[j]
        m = self.metrics
        m.ckpt_attempts += 1
        m.ckpt_by_job[j, 0] += 1
        lo = job.task_lo
        eng = (self._chaos_list[j] if self._chaos_list is not None
               else self.chaos)
        ok = run_checkpoint_attempt(
            eng, self._down_until[lo:job.task_hi] <= self.t,
            interval_s=cfg.interval_s, mode=cfg.mode,
            upload_s=cfg.upload_s, retry=cfg.retry_failed_region,
            regions=self.phys.regions[job.region_lo:job.region_hi],
            task_lo=lo, t=self.t)
        if ok:
            self._last_ckpt_vec[j] = self.t
        m.ckpt_success += int(ok)
        m.ckpt_failed += int(not ok)
        m.ckpt_by_job[j, 1 if ok else 2] += 1
