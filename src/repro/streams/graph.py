"""Dataflow graphs: logical operators → physical tasks → failure regions.

Region derivation follows Flink: tasks connected by *pipelined* channels
must recover together; the physical connected components of the channel
graph are the failure-recovery regions. Pointwise hops (forward / rescale
pairs) keep chains separate — a DS-style source→sink pipeline yields one
region per parallel chain — while any all-to-all hop (hash / rebalance /
backlog / weakhash) merges everything it touches (the SS join case).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

POINTWISE = ("forward",)
ALL_TO_ALL = ("hash", "rebalance", "backlog", "weakhash", "group_rescale",
              "rescale")


@dataclasses.dataclass(frozen=True)
class LogicalOp:
    name: str
    parallelism: int
    service_rate: float            # records/s per task at speed 1
    selectivity: float = 1.0       # output records per input record
    is_source: bool = False
    state_bytes_per_task: int = 0  # checkpoint size
    source_rate: float = 0.0       # records/s (whole op) when is_source


@dataclasses.dataclass(frozen=True)
class LogicalEdge:
    src: str
    dst: str
    partitioner: str = "rebalance"     # see core/backlog_shuffle.py names
    n_groups: int = 1                  # for group_rescale / weakhash
    key_skew_zipf: float = 0.0         # >0: keyed traffic with Zipf skew


@dataclasses.dataclass(frozen=True)
class LogicalGraph:
    name: str
    ops: tuple[LogicalOp, ...]
    edges: tuple[LogicalEdge, ...]

    def op(self, name: str) -> LogicalOp:
        return next(o for o in self.ops if o.name == name)

    def downstream(self, name: str) -> list[LogicalEdge]:
        return [e for e in self.edges if e.src == name]

    def upstream(self, name: str) -> list[LogicalEdge]:
        return [e for e in self.edges if e.dst == name]

    def topo_order(self) -> list[str]:
        return list(_topo_order(self))


@functools.lru_cache(maxsize=None)
def _topo_order(g: "LogicalGraph") -> tuple[str, ...]:
    """Cached DFS topo order (graphs are frozen/hashable; the engine asks
    for the order every tick, so recomputing the DFS would dominate small
    graphs' tick time)."""
    order, seen = [], set()

    def visit(n):
        if n in seen:
            return
        seen.add(n)
        for e in g.upstream(n):
            visit(e.src)
        order.append(n)

    for o in g.ops:
        visit(o.name)
    return tuple(order)


def namespaced(graph: LogicalGraph, prefix: str) -> LogicalGraph:
    """Clone `graph` with every op (and edge endpoint) renamed
    ``prefix + name`` — the building block of multi-job arena packing
    (`streams.engine.pack_arena`): namespacing keeps op names unique when
    several jobs' graphs are concatenated into one arena, while the
    per-job structure (edges, partitioners, rates) is untouched."""
    return LogicalGraph(
        graph.name,
        ops=tuple(dataclasses.replace(o, name=prefix + o.name)
                  for o in graph.ops),
        edges=tuple(dataclasses.replace(e, src=prefix + e.src,
                                        dst=prefix + e.dst)
                    for e in graph.edges))


@dataclasses.dataclass
class Task:
    op: str
    index: int
    task_id: int
    host: int


@dataclasses.dataclass
class PhysicalGraph:
    logical: LogicalGraph
    tasks: list[Task]
    # channels[(src_op, dst_op)] = (n_src, n_dst, connectivity)  where
    # connectivity is bool (n_src, n_dst)
    channels: dict[tuple[str, str], np.ndarray]
    regions: list[set[int]]          # sets of task_ids
    task_region: dict[int, int]

    def tasks_of(self, op: str) -> list[Task]:
        return [t for t in self.tasks if t.op == op]


def expand(graph: LogicalGraph, *, n_hosts: int,
           seed: int = 0) -> PhysicalGraph:
    """Logical → physical: instantiate tasks, place them on hosts
    round-robin (co-location emerges naturally), derive channels + regions."""
    tasks: list[Task] = []
    tid = 0
    for op in graph.ops:
        for i in range(op.parallelism):
            tasks.append(Task(op.name, i, tid, host=tid % n_hosts))
            tid += 1
    by_op = {op.name: [t for t in tasks if t.op == op.name]
             for op in graph.ops}

    channels: dict[tuple[str, str], np.ndarray] = {}
    for e in graph.edges:
        ns, nd = len(by_op[e.src]), len(by_op[e.dst])
        conn = np.zeros((ns, nd), bool)
        if e.partitioner == "forward":
            assert ns == nd, (e, ns, nd)
            conn[np.arange(ns), np.arange(nd)] = True
        elif e.partitioner == "rescale":
            # each src connects to a contiguous block of dsts
            per = max(1, nd // ns)
            for s in range(ns):
                lo = (s * per) % nd
                conn[s, lo:lo + per] = True
        elif e.partitioner == "group_rescale":
            g = e.n_groups
            for s in range(ns):
                grp = s * g // ns
                lo, hi = grp * nd // g, (grp + 1) * nd // g
                conn[s, lo:hi] = True
        else:  # all-to-all family
            conn[:] = True
        channels[(e.src, e.dst)] = conn

    # regions = connected components over channel connectivity. For
    # component purposes a src connected to a dst-set only needs a union
    # with ONE member, plus unions chaining the dst-set itself ("hub"
    # unions) — O(ns + nd) per edge instead of O(nnz), which matters for
    # all-to-all hops at large parallelism.
    parent = list(range(len(tasks)))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    for e in graph.edges:
        conn = channels[(e.src, e.dst)]
        st, dtt = by_op[e.src], by_op[e.dst]
        if e.partitioner not in POINTWISE and conn.all():
            # all-to-all: everything merges into one component
            hub = dtt[0].task_id
            for t in st:
                union(t.task_id, hub)
            for t in dtt[1:]:
                union(t.task_id, hub)
            continue
        # pointwise / blocky hops: first connected dst per src acts as the
        # row hub; the rest of the row chains to it once (rows produced by
        # forward/rescale/group_rescale that share a first dst are
        # identical blocks, so one chaining per hub suffices)
        chained: set[int] = set()
        for s, row in enumerate(conn):
            dd = np.nonzero(row)[0]
            if len(dd) == 0:
                continue
            hub = dtt[dd[0]].task_id
            union(st[s].task_id, hub)
            if hub not in chained:
                chained.add(hub)
                for d in dd[1:]:
                    union(dtt[d].task_id, hub)

    groups: dict[int, set[int]] = {}
    for t in tasks:
        groups.setdefault(find(t.task_id), set()).add(t.task_id)
    regions = sorted(groups.values(), key=lambda s: min(s))
    task_region = {t: r for r, s in enumerate(regions) for t in s}
    return PhysicalGraph(graph, tasks, channels, regions, task_region)
