"""Workloads from the paper's Table III: Nexmark Q2, Q12, Data
Synchronization (DS), Sample Stitching (SS) — as logical graphs for the
engine plus record-level vectorized operator kernels (jnp) used by the
correctness tests and the micro benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chaos import ChaosSpec
from repro.streams.graph import LogicalEdge, LogicalGraph, LogicalOp


# ----------------------------------------------------------------------
# Logical graphs (engine workloads)
# ----------------------------------------------------------------------
def ha_drill_spec(seed: int = 0, *, burst_t: float = 60.0,
                  burst_region: int = 0,
                  brownout=(40.0, 120.0, 6.0),
                  mq_outage=(150.0, 165.0),
                  host_kill_prob_per_s: float = 0.0) -> ChaosSpec:
    """The external-system HA drill the paper's release gate runs on the
    Nexmark workloads: a region-correlated failure burst mid-run, a
    storage brownout ramp stretching checkpoint uploads and passive
    restores around it, and an MQ/coordinator outage window gating the
    sources — all deterministic (no extra rng draws), so the same seed
    replays identically across the numpy, dense, compact and pallas
    engines."""
    return ChaosSpec(seed=seed,
                     host_kill_prob_per_s=host_kill_prob_per_s,
                     burst_at=((burst_t, burst_region),),
                     brownout_at=(tuple(brownout),),
                     mq_down=(tuple(mq_outage),))


def traffic_drill_spec(seed: int = 0, *,
                       diurnal=((0.35, 240.0, 0.0),),
                       flash=((90.0, 10.0, 30.0, 3.0),),
                       phase_s: float = 0.0,
                       burst_t: float | None = 110.0,
                       burst_region: int = 0,
                       host_kill_prob_per_s: float = 0.0) -> ChaosSpec:
    """The production traffic-dynamics drill: a diurnal load curve (an
    ``(amp, period_s, phase_s)`` sinusoid family, scaled down from the
    paper's 24h cycle to a sweepable horizon), a flash-crowd spike
    ``(t0, ramp_s, hold_s, peak)`` landing mid-run, and — by default —
    a region-correlated failure burst INSIDE the flash-crowd hold
    window, so rescale-during-recovery and autoscaler-vs-failover
    interactions actually exercise. All rate dynamics are deterministic
    curves (zero extra rng draws): the same seed replays identically
    across the numpy, dense, compact and pallas engines."""
    burst = ((float(burst_t), burst_region),) if burst_t is not None \
        else ()
    return ChaosSpec(seed=seed,
                     host_kill_prob_per_s=host_kill_prob_per_s,
                     burst_at=burst,
                     diurnal=tuple(tuple(d) for d in diurnal),
                     flash_at=tuple(tuple(f) for f in flash),
                     rate_phase_s=phase_s)



def q2(parallelism: int = 8, source_rate: float = 0.8e6,
       service_rate: float = 1.2e5, partitioner: str = "rebalance",
       n_groups: int = 1) -> LogicalGraph:
    """Filter bids on predefined conditions: two logical nodes, one source."""
    return LogicalGraph(
        "nexmark_q2",
        ops=(LogicalOp("source", parallelism, service_rate, is_source=True,
                       source_rate=source_rate),
             LogicalOp("filter", parallelism, service_rate,
                       selectivity=0.2)),
        edges=(LogicalEdge("source", "filter", partitioner,
                           n_groups=n_groups),))


def q12(parallelism: int = 8, source_rate: float = 0.8e6,
        service_rate: float = 1.2e5) -> LogicalGraph:
    """Count bids per bidder in processing-time windows: three nodes."""
    return LogicalGraph(
        "nexmark_q12",
        ops=(LogicalOp("source", parallelism, service_rate, is_source=True,
                       source_rate=source_rate),
             LogicalOp("window_count", parallelism, service_rate,
                       selectivity=0.05,
                       state_bytes_per_task=64 << 20),
             LogicalOp("sink", parallelism, service_rate)),
        edges=(LogicalEdge("source", "window_count", "hash",
                           key_skew_zipf=0.8),
               LogicalEdge("window_count", "sink", "forward")))


def q3(parallelism: int = 4, person_rate: float = 12e3,
       auction_rate: float = 12e3, service_rate: float = 5e3,
       sink_headroom: float = 1.2) -> LogicalGraph:
    """Incremental join of persons and auctions ("who is selling in
    particular states?"): two sources → filter/parse → keyed hash-join →
    sink, five nodes.

    The shape is deliberately *downstream-bottlenecked*: the sink's
    capacity is only ``sink_headroom``× the steady-state join output
    (person_rate·0.25 + auction_rate), so a canary selectivity scale
    above ``sink_headroom`` on the join overloads the canary slice's
    sink while the stable slice keeps draining — the divergence a
    deployment drill's auto-rollback controller detects. (A
    source-bottlenecked graph saturates both slices equally and a
    fully-drained one never builds backlog; neither can regress.)"""
    out_rate = person_rate * 0.25 + auction_rate
    sink_sr = sink_headroom * out_rate / parallelism
    return LogicalGraph(
        "nexmark_q3",
        ops=(LogicalOp("persons", parallelism, service_rate,
                       is_source=True, source_rate=person_rate),
             LogicalOp("auctions", parallelism, service_rate,
                       is_source=True, source_rate=auction_rate),
             LogicalOp("filter_p", parallelism, service_rate,
                       selectivity=0.25),
             LogicalOp("parse_a", parallelism, service_rate,
                       selectivity=1.0),
             LogicalOp("join", parallelism, service_rate,
                       selectivity=1.0,
                       state_bytes_per_task=128 << 20),
             LogicalOp("sink", parallelism, sink_sr)),
        edges=(LogicalEdge("persons", "filter_p", "forward"),
               LogicalEdge("auctions", "parse_a", "forward"),
               LogicalEdge("filter_p", "join", "hash",
                           key_skew_zipf=0.5),
               LogicalEdge("parse_a", "join", "hash", key_skew_zipf=0.5),
               LogicalEdge("join", "sink", "rebalance")))


def q11(parallelism: int = 4, source_rate: float = 16e3,
        service_rate: float = 10e3, session_sel: float = 0.3,
        sink_headroom: float = 1.2) -> LogicalGraph:
    """Bids per user per session window: source → keyed sessionizer →
    sink, three nodes with session state on the middle op.

    Downstream-bottlenecked like `q3` (the sink runs at
    ``sink_headroom``× the sessionizer's steady output), so canary
    configs that emit more windows — a shorter session gap lowered as a
    selectivity scale — regress the canary slice's backlog and exercise
    the drill auto-rollback path."""
    sink_sr = sink_headroom * source_rate * session_sel / parallelism
    return LogicalGraph(
        "nexmark_q11",
        ops=(LogicalOp("source", parallelism, service_rate,
                       is_source=True, source_rate=source_rate),
             LogicalOp("sessionize", parallelism, service_rate,
                       selectivity=session_sel,
                       state_bytes_per_task=96 << 20),
             LogicalOp("sink", parallelism, sink_sr)),
        edges=(LogicalEdge("source", "sessionize", "hash",
                           key_skew_zipf=0.7),
               LogicalEdge("sessionize", "sink", "forward")))


def ds(parallelism: int = 6, source_rate: float = 1e6,
       service_rate: float = 2.5e5) -> LogicalGraph:
    """Data synchronization: MQ → Hive, two nodes, forward chains (the
    region-checkpointing showcase: one region per chain)."""
    return LogicalGraph(
        "data_sync",
        ops=(LogicalOp("mq_source", parallelism, service_rate,
                       is_source=True, source_rate=source_rate,
                       state_bytes_per_task=512 << 20),
             LogicalOp("hive_sink", parallelism, service_rate,
                       state_bytes_per_task=512 << 20)),
        edges=(LogicalEdge("mq_source", "hive_sink", "forward"),))


def ss(parallelism: int = 8, feature_rate: float = 25e3,
       label_rate: float = 20e3, service_rate: float = 1.2e4) -> LogicalGraph:
    """Sample stitching: dual-stream keyed join for a recommender —
    all-to-all exchanges merge everything into ONE region (the single-task
    recovery showcase)."""
    sr = service_rate
    return LogicalGraph(
        "sample_stitching",
        ops=(LogicalOp("features", parallelism, sr, is_source=True,
                       source_rate=feature_rate),
             LogicalOp("labels", parallelism, sr, is_source=True,
                       source_rate=label_rate),
             LogicalOp("parse_f", parallelism, sr, selectivity=1.0),
             LogicalOp("parse_l", parallelism, sr, selectivity=1.0),
             LogicalOp("join", parallelism, sr, selectivity=0.9,
                       state_bytes_per_task=256 << 20),
             LogicalOp("stitch", parallelism, sr, selectivity=1.0),
             LogicalOp("sink", parallelism, sr)),
        edges=(LogicalEdge("features", "parse_f", "forward"),
               LogicalEdge("labels", "parse_l", "forward"),
               LogicalEdge("parse_f", "join", "hash", key_skew_zipf=0.6),
               LogicalEdge("parse_l", "join", "hash", key_skew_zipf=0.6),
               LogicalEdge("join", "stitch", "rebalance"),
               LogicalEdge("stitch", "sink", "forward")))


def q12_arena(n_tasks: int = 10_000, parallelism: int = 8,
              n_hosts: int = 64, source_rate: float = 0.8e6,
              service_rate: float = 1.2e5, dt: float = 0.5,
              queue_cap: float = 256.0, host_map: str = "shared"):
    """10k-task-scale Q12 mega-arena (ROADMAP's large-Nexmark item): K
    co-located Q12 jobs — ``K = n_tasks // (3 * parallelism)`` — packed
    into ONE flat arena over a shared host pool via
    `streams.engine.pack_arena`.

    At the default ``n_tasks=10_000`` that is 416 windowed-state jobs /
    1248 ops / 832 edges in one `RoutingPlan`: the workload whose
    per-op/per-edge unrolled jit trace was unbuildable, and which the
    tensorized phase-scheduled tick compiles in constant trace size
    (benchmarks/bench_compile.py). Returns a `PackedArena`; both engines
    and every sweep axis (seeds × mixes × configs) accept it directly.
    """
    from repro.streams.engine import pack_arena

    per_job = 3 * parallelism
    n_jobs = max(1, n_tasks // per_job)
    jobs = [q12(parallelism=parallelism, source_rate=source_rate,
                service_rate=service_rate) for _ in range(n_jobs)]
    return pack_arena(jobs, host_map, n_hosts=n_hosts, dt=dt,
                      queue_cap=queue_cap)


def ss_arena(n_tasks: int = 10_000, parallelism: int = 8,
             n_hosts: int = 64, dt: float = 0.5,
             queue_cap: float = 256.0, host_map: str = "shared"):
    """10k-task-scale *deep-pipeline* mega-arena: K co-located Sample
    Stitching jobs — ``K = n_tasks // (7 * parallelism)`` — packed into
    ONE flat arena over a shared host pool.

    SS is the deepest paper workload (7 ops, dual sources, a serialized
    two-in-edge join): its packed arena schedules SIX tick phases, each
    touching only 1–2 ops of every job — the workload class where the
    compact (sparse-phase) lowering's per-phase active index sets beat
    the dense arena-wide tick (`engine.lower_tensor_plan(mode=...)`,
    benchmarks/bench_sweep_scale.py). Returns a `PackedArena`.
    """
    from repro.streams.engine import pack_arena

    per_job = 7 * parallelism
    n_jobs = max(1, n_tasks // per_job)
    jobs = [ss(parallelism=parallelism) for _ in range(n_jobs)]
    return pack_arena(jobs, host_map, n_hosts=n_hosts, dt=dt,
                      queue_cap=queue_cap)


def drill_fleet(n_jobs: int = 8, parallelism: int = 4,
                n_hosts: int = 16, dt: float = 0.5,
                queue_cap: float = 256.0, host_map: str = "shared",
                sink_headroom: float = 1.2):
    """Heterogeneous deployment-drill fleet: alternating `q3` (join-
    shaped, 6 ops) and `q11` (session-window-shaped, 3 ops) jobs packed
    into ONE arena over a shared host pool.

    Every job is downstream-bottlenecked with ``sink_headroom``
    capacity slack, so a drill whose canary selectivity scale exceeds
    the headroom regresses exactly the canaried jobs — across two
    different graph shapes — while stable jobs keep draining. This is
    the fleet `chaos_sweep.deployment_drill` cubes sweep and the
    induced-regression fixture of tests/test_deployment_drill.py.
    Returns a `PackedArena`."""
    from repro.streams.engine import pack_arena

    jobs = [q3(parallelism=parallelism, sink_headroom=sink_headroom)
            if i % 2 == 0
            else q11(parallelism=parallelism,
                     sink_headroom=sink_headroom)
            for i in range(n_jobs)]
    return pack_arena(jobs, host_map, n_hosts=n_hosts, dt=dt,
                      queue_cap=queue_cap)


def mega_arena(n_tasks: int = 100_000, workload: str = "q12",
               parallelism: int = 8, n_hosts: int = 256, dt: float = 0.5,
               queue_cap: float = 256.0, host_map: str = "shared"):
    """100k-task-scale mega-arena — the fused-Pallas-tick target size
    (10× `q12_arena` / `ss_arena`). ``workload`` picks the job template:

    * ``"q12"``  — K = n_tasks // (3·parallelism) windowed-count jobs
      (≈ 4166 jobs / 12498 ops at the default 100k).
    * ``"ss"``   — K = n_tasks // (7·parallelism) deep stitching
      pipelines (six tick phases, the compact/pallas showcase).
    * ``"mixed"``— alternating q12 + ss jobs until the task budget is
      spent, exercising ragged pow2 row buckets across phases.

    All jobs share one host pool, so one `ChaosSpec` kill stream fans
    out across every job — a (configs × seeds) grid over this arena in
    ``phase_mode="pallas"`` covers ≥1e6 job-scenarios in a single
    device pass (benchmarks/bench_tick_kernel.py). Returns a
    `PackedArena`.
    """
    from repro.streams.engine import pack_arena

    if workload == "q12":
        mk = [(3 * parallelism, lambda: q12(parallelism=parallelism))]
    elif workload == "ss":
        mk = [(7 * parallelism, lambda: ss(parallelism=parallelism))]
    elif workload == "mixed":
        mk = [(3 * parallelism, lambda: q12(parallelism=parallelism)),
              (7 * parallelism, lambda: ss(parallelism=parallelism))]
    else:
        raise ValueError("workload must be q12|ss|mixed")
    jobs, total, i = [], 0, 0
    while total + mk[i % len(mk)][0] <= n_tasks or not jobs:
        per_job, ctor = mk[i % len(mk)]
        jobs.append(ctor())
        total += per_job
        i += 1
    return pack_arena(jobs, host_map, n_hosts=n_hosts, dt=dt,
                      queue_cap=queue_cap)


# ----------------------------------------------------------------------
# Record-level vectorized operator kernels (correctness oracle + micro bench)
# ----------------------------------------------------------------------
def gen_bids(n: int, seed: int = 0, n_auctions: int = 1000,
             n_bidders: int = 5000):
    rng = np.random.default_rng(seed)
    return {
        "auction": jnp.asarray(rng.integers(0, n_auctions, n)),
        "bidder": jnp.asarray(rng.zipf(1.3, n) % n_bidders),
        "price": jnp.asarray(rng.lognormal(3.0, 1.0, n)),
        "ts": jnp.asarray(np.sort(rng.uniform(0, 600.0, n))),
    }


@jax.jit
def q2_filter(bids: dict) -> jax.Array:
    """Nexmark Q2: bids on a fixed set of auctions (auction % 123 == 0)."""
    return bids["auction"] % 123 == 0


def q12_window_counts(bids: dict, window_s: float = 10.0,
                      n_bidders: int = 5000):
    """Bids per bidder per processing-time window → (n_windows, n_bidders)."""
    win = (bids["ts"] // window_s).astype(jnp.int32)
    n_windows = int(jnp.max(win)) + 1
    flat = win * n_bidders + bids["bidder"].astype(jnp.int32)
    counts = jnp.zeros((n_windows * n_bidders,), jnp.int32).at[flat].add(1)
    return counts.reshape(n_windows, n_bidders)


@jax.jit
def ss_join(feat_keys, feat_vals, label_keys, label_vals):
    """Keyed sample stitching: for each label, attach the latest feature row
    with the same key (hash-join via sorted search; -1 when no match)."""
    order = jnp.argsort(feat_keys)
    fk = feat_keys[order]
    fv = feat_vals[order]
    pos = jnp.searchsorted(fk, label_keys, side="left")
    pos = jnp.clip(pos, 0, fk.shape[0] - 1)
    hit = fk[pos] == label_keys
    joined = jnp.where(hit[:, None], fv[pos], -1.0)
    return jnp.concatenate([label_vals, joined], axis=1), hit
