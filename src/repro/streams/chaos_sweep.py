"""Chaos-sweep driver: batched failure-scenario screening (paper §V-B).

StreamShield's release pipeline validates resiliency by sweeping *many*
injected-failure configurations, not one drill. This driver turns a seed
batch into per-scenario resiliency summaries in a single vmapped `jit`
call of the JAX engine twin (`streams/jax_engine.py`):

    result = sweep(nexmark.q2(parallelism=8), seeds=range(256),
                   base_spec=ChaosSpec(host_kill_prob_per_s=0.002),
                   duration_s=300.0)
    result.summaries[i].recovery_time_s  # per-scenario
    result.aggregate()                   # fleet percentiles

Per scenario it reports recovery time (first post-failure return of
source lag below the SLO threshold), maximum backlog, SLO-violation
tick counts, dropped/emitted records and checkpoint success — the
metrics the paper uses to gate a release.

Cluster-perspective sweeps: pass a `streams.engine.PackedArena` instead
of a graph and the whole co-located fleet (K jobs, shared host pool)
sweeps in the same device call — `SweepResult.job_results` then carries
per-job recovery/SLO breakdowns next to the fleet-level combined
summaries, with shared-host kills coupling the co-located jobs'
recoveries. ``devices=`` shards the seed batch across local devices
(version-gated `repro.dist.sharding` shim: pmap on jax 0.4.x, shard_map
on >= 0.6); seed batches are padded to the next power of two so varying
S reuses one jit trace per bucket. The numpy-engine baseline replay is
opt-in via ``compare_numpy=True`` — production-size sweeps never pay
the single-core replay by default.

Chunked sweeps: every driver (and the cube wrappers forwarding
``**sweep_kw``) takes ``seed_chunk=`` / ``on_chunk=`` — the seed axis
then streams through the engine's double-buffered prep/compute pipeline
and `SweepChunk` partial surfaces are published as each chunk lands
(the `launch.serve.SweepService` incremental-result path), with the
concatenated result bit-identical to the monolithic call. Results carry
the ``prep_s`` / ``device_s`` wall split and per-request trace-cache
hit/miss counts next to the compat total-derived ``scenarios_per_s``.
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.core.chaos import ChaosSpec
from repro.streams.engine import (AutoscaleConfig, CheckpointConfig,
                                  FailoverConfig, PackedArena,
                                  UpgradeConfig)
from repro.streams.graph import LogicalGraph
from repro.streams.jax_engine import (JaxBatchMetrics, normalize_config,
                                      run_batch, run_config_batch)


@dataclasses.dataclass
class ScenarioSummary:
    seed: int
    n_failures: int              # recovery events (host kills that hit)
    recovery_time_s: float       # inf = never recovered, 0 = no SLO breach
    max_backlog: float           # peak total queued records
    max_lag: float               # peak source lag
    slo_threshold: float
    slo_violation_ticks: int
    slo_violation_frac: float
    dropped: float
    emitted: float
    ckpt_attempts: int
    ckpt_success: int


@dataclasses.dataclass
class SweepResult:
    graph_name: str
    duration_s: float
    n_ticks: int
    summaries: list[ScenarioSummary]
    batch: JaxBatchMetrics
    wall_s: float                # end-to-end sweep wall time
    # packed-arena sweeps: per-job breakdown (job name → its own
    # SweepResult over the job's metric segment); None for single jobs
    job_results: dict[str, "SweepResult"] | None = None
    # opt-in numpy cross-check (see sweep(compare_numpy=...)); None unless
    # requested — production sweeps never pay the single-core replay
    numpy_check: dict | None = None
    # wall-time split of the chunked pipeline: host-side timeline prep vs
    # device compute (their sum can exceed `wall_s` when the
    # double-buffered pipeline overlaps them — that gap IS the overlap
    # win). Zero for legacy callers that bypass the timing plumb.
    prep_s: float = 0.0
    device_s: float = 0.0
    # per-request trace-cache traffic of this sweep's jit-fn lookups
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def total_s(self) -> float:
        """End-to-end wall time (alias of `wall_s` — the denominator of
        the compat `scenarios_per_s`)."""
        return self.wall_s

    @property
    def scenarios_per_s(self) -> float:
        # compat: total-derived (wall_s == total_s), NOT device-only
        return len(self.summaries) / self.wall_s if self.wall_s else 0.0

    def aggregate(self) -> dict:
        """Fleet-level percentiles across the scenario batch."""
        rec = np.array([s.recovery_time_s for s in self.summaries])
        fin = rec[np.isfinite(rec)]
        frac = np.array([s.slo_violation_frac for s in self.summaries])
        return {
            "scenarios": len(self.summaries),
            "failed_scenarios": int(sum(s.n_failures > 0
                                        for s in self.summaries)),
            "unrecovered": int(np.sum(~np.isfinite(rec))),
            "recovery_p50_s": float(np.median(fin)) if len(fin) else 0.0,
            "recovery_p95_s": float(np.percentile(fin, 95))
            if len(fin) else 0.0,
            "recovery_max_s": float(fin.max()) if len(fin) else 0.0,
            "slo_violation_frac_p50": float(np.median(frac)),
            "slo_violation_frac_p95": float(np.percentile(frac, 95)),
            "max_backlog": float(max(s.max_backlog
                                     for s in self.summaries)),
            "dropped_total": float(sum(s.dropped for s in self.summaries)),
            "scenarios_per_s": self.scenarios_per_s,
        }


def _recovery_time(ts: np.ndarray, lag: np.ndarray, down_bk: np.ndarray,
                   recs: list[dict]) -> float:
    """Time from the first failure until the job is healthy again.

    Source lag in this sim is *retained* backlog (sources never re-emit
    requeued records), so "lag returns below an absolute threshold"
    would read as never-recovered for any single-task drill. Healthy is
    therefore: the failover outage window has passed, the per-tick lag
    growth is back at its pre-failure level, and downstream queues have
    drained. inf = still unhealthy at horizon end."""
    t_fail = recs[0]["t"]
    outage_end = max(r["t"] + r["downtime"] for r in recs)
    pre = ts < t_fail
    dlag = np.diff(lag, prepend=lag[:1])
    grow_thr = (float(np.percentile(dlag[pre], 95)) if pre.any()
                else 0.0) + 1e-9
    bk_thr = max(2.0 * (float(np.median(down_bk[pre])) if pre.any()
                        else 0.0), 1.0)
    breach = (ts < outage_end) | (dlag > grow_thr) | (down_bk > bk_thr)
    breach &= ts >= t_fail
    if not breach.any():
        return 0.0
    last = int(np.nonzero(breach)[0][-1])
    if last == len(ts) - 1:
        return math.inf
    return float(ts[last + 1] - t_fail)


def summarize(batch: JaxBatchMetrics, seeds, *,
              graph: LogicalGraph | None = None,
              slo_lag: float | None = None,
              wall_s: float = 0.0, graph_name: str = "",
              duration_s: float = 0.0) -> SweepResult:
    """Per-scenario resiliency summaries from stacked batch metrics.

    `slo_lag` is the source-lag SLO threshold (records). When None it is
    derived per scenario as 2× the pre-failure steady-state median lag
    (falling back to the whole-run median for failure-free scenarios).
    `graph` identifies source ops so recovery can watch downstream
    queues; without it every op's backlog counts as downstream.
    """
    ts = batch.t
    src_names = ({o.name for o in graph.ops if o.is_source}
                 if graph is not None else set())
    down_cols = [j for j, n in enumerate(batch.op_names)
                 if n not in src_names]
    summaries = []
    for i, seed in enumerate(seeds):
        lag = batch.source_lag[i]
        recs = batch.recoveries[i]
        t_fail = recs[0]["t"] if recs else None
        down_bk = batch.backlog[i][:, down_cols].sum(axis=1)
        if slo_lag is None:
            pre = lag[ts < t_fail] if t_fail is not None else lag
            steady = float(np.median(pre)) if len(pre) else 0.0
            thr = 2.0 * steady + 1e-9
        else:
            thr = slo_lag
        viol = int(np.sum(lag > thr))
        summaries.append(ScenarioSummary(
            seed=int(getattr(seed, "seed", seed)),   # ChaosSpec or int
            n_failures=len(recs),
            recovery_time_s=(_recovery_time(ts, lag, down_bk, recs)
                             if recs else 0.0),
            max_backlog=float(batch.backlog[i].sum(axis=1).max()),
            max_lag=float(lag.max()),
            slo_threshold=thr,
            slo_violation_ticks=viol,
            slo_violation_frac=viol / max(len(ts), 1),
            dropped=float(batch.dropped[i]),
            emitted=float(batch.emitted[i]),
            ckpt_attempts=int(batch.ckpt_attempts[i]),
            ckpt_success=int(batch.ckpt_success[i]),
        ))
    return SweepResult(graph_name, duration_s, len(ts), summaries, batch,
                       wall_s)


@dataclasses.dataclass
class SweepChunk:
    """One landed seed chunk of a chunked sweep — the incremental unit
    `sweep(on_chunk=...)` / `sweep_configs(on_chunk=...)` publish and
    `launch.serve.SweepService` streams to subscribers. Carries the
    partial ``(C, S_chunk)`` surfaces (C = 1 for plain `sweep`) computed
    with exactly the final result's formulas, so concatenating every
    chunk's columns reproduces the full-cube surfaces bit-for-bit."""
    index: int                     # 0-based landing order == seed order
    seed_lo: int
    seed_hi: int                   # half-open [seed_lo, seed_hi)
    seeds: list
    prep_s: float                  # host timeline prep for this chunk
    device_s: float                # device pass for this chunk
    summaries: list[list[ScenarioSummary]]   # [C][S_chunk]
    recovery_surface: np.ndarray   # (C, S_chunk)
    slo_surface: np.ndarray
    backlog_surface: np.ndarray
    lost_surface: np.ndarray
    rollback_surface: np.ndarray
    thrash_surface: np.ndarray
    rescale_surface: np.ndarray
    cost_surface: np.ndarray

    @property
    def n_seeds(self) -> int:
        return self.seed_hi - self.seed_lo

    @property
    def total_s(self) -> float:
        return self.prep_s + self.device_s


def _chunk_surfaces(batches, results) -> dict:
    """The dense surfaces of a (partial or full) config × seed block,
    computed from per-config `SweepResult`s + raw batches — ONE formula
    set shared by `sweep_configs`' final assembly and the per-chunk
    publisher, so partial surfaces are exact column slices of the final
    ones."""
    n = len(results[0].summaries)
    return dict(
        recovery_surface=np.array([[s.recovery_time_s for s in r.summaries]
                                   for r in results]),
        slo_surface=np.array([[s.slo_violation_frac for s in r.summaries]
                              for r in results]),
        backlog_surface=np.array([[s.max_backlog for s in r.summaries]
                                  for r in results]),
        lost_surface=np.array([[s.dropped for s in r.summaries]
                               for r in results]),
        rollback_surface=np.array([(bm.rollback_t
                                    if bm.rollback_t is not None
                                    else np.full(n, np.inf))
                                   for bm in batches]),
        thrash_surface=np.array([(bm.thrash_t if bm.thrash_t is not None
                                  else np.full(n, np.inf))
                                 for bm in batches]),
        rescale_surface=np.array([(bm.n_rescale
                                   if bm.n_rescale is not None
                                   else np.zeros(n))
                                  for bm in batches]),
        cost_surface=np.array([(bm.resource_s
                                if bm.resource_s is not None
                                else np.zeros(n))
                               for bm in batches]))


def _publish_chunk(on_chunk, index: int, cr, seeds, *, graph, slo_lag,
                   duration_s) -> None:
    """Summarize one engine `ChunkResult` into a `SweepChunk` and hand
    it to the caller's `on_chunk` subscriber."""
    batches = (cr.batches if isinstance(cr.batches, list)
               else [cr.batches])
    chunk_seeds = seeds[cr.seed_lo:cr.seed_hi]
    results = [summarize(bm, chunk_seeds, graph=graph, slo_lag=slo_lag,
                         wall_s=cr.device_s, graph_name=graph.name,
                         duration_s=duration_s) for bm in batches]
    on_chunk(SweepChunk(index=index, seed_lo=cr.seed_lo,
                        seed_hi=cr.seed_hi, seeds=chunk_seeds,
                        prep_s=cr.prep_s, device_s=cr.device_s,
                        summaries=[r.summaries for r in results],
                        **_chunk_surfaces(batches, results)))


def sweep(graph: LogicalGraph | PackedArena, seeds, *,
          base_spec: ChaosSpec,
          duration_s: float, n_hosts: int = 8, dt: float = 0.5,
          queue_cap: float = 256.0,
          failover: FailoverConfig | None = None,
          ckpt: CheckpointConfig | None = None,
          slo_lag: float | None = None,
          task_speed_override: dict[int, float] | None = None,
          seed: int = 0, pad_seeds: bool = True,
          devices: int | str | None = None,
          phase_mode: str = "auto",
          seed_chunk: int | None = None,
          on_chunk=None,
          compare_numpy: bool = False) -> SweepResult:
    """Sweep `seeds` chaos scenarios over `graph` in one vmapped jit call
    (one call per device shard when `devices` is set).

    `graph` may be a `PackedArena`: the co-located fleet sweeps in the
    same call and the result carries per-job recovery/SLO breakdowns in
    ``job_results`` (keyed by job name) next to the fleet-level combined
    summaries.

    ``seed_chunk`` streams the seed axis through fixed-size chunks on
    the engine's double-buffered pipeline (bit-identical result, see
    `jax_engine.run_batch`); ``on_chunk`` receives a `SweepChunk` with
    the partial surfaces as each chunk lands. The result's ``prep_s`` /
    ``device_s`` carry the host-prep vs device wall split either way.

    ``compare_numpy`` is OPT-IN (default False): the numpy-engine
    baseline replay costs a single-core scenario per checked seed, which
    production-size sweeps must not pay on every call. When True, up to 3
    seeds are re-run on `StreamEngine` and the max absolute source-lag
    deviation is attached as ``numpy_check``.
    """
    seeds = list(seeds)
    logical = graph.graph if isinstance(graph, PackedArena) else graph
    timing: dict = {}
    publish = None
    if on_chunk is not None:
        counter = iter(range(len(seeds) + 1))
        publish = lambda cr: _publish_chunk(
            on_chunk, next(counter), cr, seeds, graph=logical,
            slo_lag=slo_lag, duration_s=duration_s)
    t0 = time.perf_counter()
    batch = run_batch(graph, seeds, base_spec=base_spec,
                      duration_s=duration_s, n_hosts=n_hosts, dt=dt,
                      queue_cap=queue_cap, failover=failover, ckpt=ckpt,
                      task_speed_override=task_speed_override, seed=seed,
                      pad_seeds=pad_seeds, devices=devices,
                      phase_mode=phase_mode, seed_chunk=seed_chunk,
                      on_chunk=publish, timing=timing)
    wall = time.perf_counter() - t0
    res = summarize(batch, seeds, graph=logical, slo_lag=slo_lag,
                    wall_s=wall, graph_name=logical.name,
                    duration_s=duration_s)
    res.prep_s = timing.get("prep_s", 0.0)
    res.device_s = timing.get("device_s", 0.0)
    res.cache_hits = timing.get("cache_hits", 0)
    res.cache_misses = timing.get("cache_misses", 0)
    if isinstance(graph, PackedArena) and batch.jobs:
        res.job_results = {
            job.name: summarize(batch.job_view(job), seeds,
                                graph=job.graph, slo_lag=slo_lag,
                                wall_s=wall, graph_name=job.name,
                                duration_s=duration_s)
            for job in batch.jobs}
    if compare_numpy:
        res.numpy_check = _numpy_check(graph, seeds, batch,
                                       base_spec=base_spec,
                                       duration_s=duration_s,
                                       n_hosts=n_hosts, dt=dt,
                                       queue_cap=queue_cap,
                                       failover=failover, ckpt=ckpt,
                                       task_speed_override=
                                       task_speed_override, seed=seed)
    return res


def _numpy_check(graph, seeds, batch: JaxBatchMetrics, *, base_spec,
                 duration_s, n_hosts, dt, queue_cap, failover, ckpt,
                 task_speed_override, seed, n_probe: int = 3) -> dict:
    """Replay up to `n_probe` seeds on the single-core numpy engine and
    report the worst source-lag deviation vs the batched JAX rows. This
    is the sweep driver's opt-in correctness baseline — never run by
    default (the replay is orders of magnitude slower than the sweep)."""
    from repro.core.chaos import ChaosEngine
    from repro.streams.engine import StreamEngine

    checked, max_dev = [], 0.0
    t0 = time.perf_counter()
    for i, s in list(enumerate(seeds))[:n_probe]:
        spec = (dataclasses.replace(base_spec or ChaosSpec(), seed=int(s))
                if isinstance(s, (int, np.integer)) else s)
        kw = {} if isinstance(graph, PackedArena) else \
            {"n_hosts": n_hosts, "dt": dt, "queue_cap": queue_cap}
        eng = StreamEngine(graph, chaos=ChaosEngine(spec),
                           failover=failover, ckpt=ckpt,
                           task_speed_override=task_speed_override,
                           seed=seed, **kw)
        eng.run(duration_s)
        dev = float(np.max(np.abs(np.asarray(eng.metrics.source_lag)
                                  - batch.source_lag[i])))
        scale = float(np.max(np.abs(batch.source_lag[i]))) + 1e-9
        max_dev = max(max_dev, dev / scale)
        checked.append(int(getattr(s, "seed", s)))
    return {"seeds_checked": checked, "max_rel_lag_dev": max_dev,
            "wall_s": time.perf_counter() - t0}


# ----------------------------------------------------------------------
# resiliency-config grid sweeps (recovery-time-vs-budget surfaces)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ConfigSweepResult:
    """A ``(C, S)`` resiliency-config × chaos-seed sweep, one device
    call: per-config `SweepResult`s plus the dense surfaces the paper's
    tuning methodology wants (recovery time vs restart budget, SLO
    violation vs checkpoint interval)."""
    graph_name: str
    duration_s: float
    configs: list[dict]            # normalized grid entries
    labels: list[str]
    results: list[SweepResult]     # one per config row
    recovery_surface: np.ndarray   # (C, S) recovery_time_s
    slo_surface: np.ndarray        # (C, S) slo_violation_frac
    backlog_surface: np.ndarray    # (C, S) max_backlog
    lost_surface: np.ndarray       # (C, S) dropped records (lost work)
    wall_s: float
    # (C, S) deployment-drill auto-rollback fire times (+inf = canary
    # held / no drill on that config row); None for pre-drill callers
    rollback_surface: np.ndarray | None = None
    # (C, S) autoscaler surfaces (None for pre-autoscaler callers):
    # thrash-guard latch times (+inf = never thrashed), rescale action
    # counts, and integrated resource-seconds (the SLO-vs-cost axis)
    thrash_surface: np.ndarray | None = None
    rescale_surface: np.ndarray | None = None
    cost_surface: np.ndarray | None = None
    # chunked-pipeline wall split (see SweepResult) + per-request
    # trace-cache traffic; zero for legacy callers
    prep_s: float = 0.0
    device_s: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def total_s(self) -> float:
        """End-to-end wall time (alias of `wall_s` — the denominator of
        the compat `scenarios_per_s`)."""
        return self.wall_s

    @property
    def scenarios_per_s(self) -> float:
        # compat: total-derived (wall_s == total_s), NOT device-only
        n = self.recovery_surface.size
        return n / self.wall_s if self.wall_s else 0.0

    def rows(self) -> list[dict]:
        """Per-config aggregate rows (label + fleet percentiles) — the
        recovery-time-vs-config curve in tabular form."""
        out = []
        for lbl, res in zip(self.labels, self.results):
            agg = res.aggregate()
            agg["label"] = lbl
            out.append(agg)
        return out


def _config_label(i: int, cfg: dict) -> str:
    if cfg.get("label"):
        return str(cfg["label"])
    bits = []
    fo, ck = cfg.get("failover"), cfg.get("ckpt")
    if isinstance(fo, FailoverConfig):
        if fo.mode == "hot_standby":
            bits.append(f"hot_standby:switch={fo.standby_switch_s:g}s")
        else:
            bits.append(f"{fo.mode}:restart="
                        f"{fo.single_restart_s if fo.mode == 'single_task' else fo.region_restart_s:g}s")
    elif fo is not None:
        bits.append(f"per-job[{len(list(fo))}]")
    if isinstance(ck, CheckpointConfig):
        bits.append(f"ckpt={ck.interval_s:g}s")
    elif ck is not None:
        bits.append("ckpt=per-job")
    if cfg.get("qcap_scale", 1.0) != 1.0:
        bits.append(f"qcap×{cfg['qcap_scale']:g}")
    if cfg.get("sel_scale", 1.0) != 1.0:
        bits.append(f"sel×{cfg['sel_scale']:g}")
    bro = tuple(cfg.get("brownout", ()))
    if bro:
        bits.append("brownout×" + "/".join(f"{r[2]:g}" for r in bro))
    upg = cfg.get("upgrade")
    if isinstance(upg, UpgradeConfig):
        bits.append(f"drill:{'hot' if upg.hot else 'cold'}"
                    f" canary={upg.canary_frac:g}"
                    f" thr={upg.rollback_threshold:g}")
    sc = cfg.get("scaler")
    if isinstance(sc, AutoscaleConfig):
        bits.append(f"ds2:int={sc.interval_s:g}s"
                    f" tgt={sc.target_utilization:g}"
                    f" hyst={sc.hysteresis:g}")
    tr = cfg.get("traffic", ((), ()))
    if tr and (tr[0] or tr[1]):
        tb = []
        if tr[0]:
            tb.append("diurnal×" + "/".join(f"{d[0]:g}" for d in tr[0]))
        if tr[1]:
            tb.append("flash×" + "/".join(f"{f[3]:g}" for f in tr[1]))
        bits.append(" ".join(tb))
    return " ".join(bits) if bits else f"cfg{i}"


def sweep_configs(graph: LogicalGraph | PackedArena, configs, seeds, *,
                  base_spec: ChaosSpec,
                  duration_s: float, n_hosts: int = 8, dt: float = 0.5,
                  queue_cap: float = 256.0,
                  slo_lag: float | None = None,
                  task_speed_override: dict[int, float] | None = None,
                  seed: int = 0, pad_seeds: bool = True,
                  devices: int | str | None = None,
                  phase_mode: str = "auto",
                  seed_chunk: int | None = None,
                  on_chunk=None) -> ConfigSweepResult:
    """Sweep a ``(C, S)`` grid of resiliency configs × chaos seeds over
    `graph` in ONE doubly-vmapped jit call (`jax_engine.run_config_batch`
    — the engine's third vmap axis) and summarize each config row.

    `configs` entries follow `jax_engine.normalize_config`: a
    `FailoverConfig`, a `CheckpointConfig`, a ``(failover, ckpt)`` pair,
    a per-job `FailoverConfig` list (packed arenas), or a dict with
    ``failover`` / ``ckpt`` / ``qcap_scale`` / ``sel_scale`` / ``label``.
    The result's `recovery_surface` / `slo_surface` are the dense (C, S)
    curves — recovery time vs restart budget, SLO violation vs
    checkpoint interval — that StreamShield-style release gating and
    Khaos-style checkpoint-interval optimization read off directly.

    ``devices=`` splits the flat seed axis of the (C, S) grid across
    local devices (`jax_engine.get_sharded_config_fn`; rows stay
    bit-identical to the single-device grid); ``phase_mode`` selects the
    dense vs compact (sparse-phase) tick lowering, default auto.

    ``seed_chunk`` streams the grid's seed axis through fixed-size
    chunks on the engine's double-buffered pipeline — one ``(C,
    S_chunk)`` device pass per chunk, host prep overlapping device
    compute, final surfaces bit-identical to the one-pass grid (see
    `jax_engine.run_config_batch`) — and ``on_chunk`` receives a
    `SweepChunk` with each partial ``(C, S_chunk)`` surface as it lands
    (the service layer's time-to-first-result path). The result's
    ``prep_s`` / ``device_s`` / ``cache_hits`` / ``cache_misses`` carry
    the wall split + per-request trace-cache traffic either way."""
    seeds = list(seeds)
    norm = [normalize_config(c) for c in configs]
    logical = graph.graph if isinstance(graph, PackedArena) else graph
    timing: dict = {}
    publish = None
    if on_chunk is not None:
        counter = iter(range(len(seeds) + 1))
        publish = lambda cr: _publish_chunk(
            on_chunk, next(counter), cr, seeds, graph=logical,
            slo_lag=slo_lag, duration_s=duration_s)
    t0 = time.perf_counter()
    batches = run_config_batch(graph, norm, seeds, base_spec=base_spec,
                               duration_s=duration_s, n_hosts=n_hosts,
                               dt=dt, queue_cap=queue_cap,
                               task_speed_override=task_speed_override,
                               seed=seed, pad_seeds=pad_seeds,
                               devices=devices, phase_mode=phase_mode,
                               seed_chunk=seed_chunk, on_chunk=publish,
                               timing=timing)
    wall = time.perf_counter() - t0
    # each config row gets its share of the one-call wall time, so a
    # row's scenarios_per_s stays comparable with a standalone sweep()
    results = [summarize(bm, seeds, graph=logical, slo_lag=slo_lag,
                         wall_s=wall / len(norm),
                         graph_name=logical.name, duration_s=duration_s)
               for bm in batches]
    surf = _chunk_surfaces(batches, results)
    labels = [_config_label(i, c) for i, c in enumerate(norm)]
    return ConfigSweepResult(logical.name, duration_s, norm, labels,
                             results, surf["recovery_surface"],
                             surf["slo_surface"],
                             surf["backlog_surface"],
                             surf["lost_surface"], wall,
                             rollback_surface=surf["rollback_surface"],
                             thrash_surface=surf["thrash_surface"],
                             rescale_surface=surf["rescale_surface"],
                             cost_surface=surf["cost_surface"],
                             prep_s=timing.get("prep_s", 0.0),
                             device_s=timing.get("device_s", 0.0),
                             cache_hits=timing.get("cache_hits", 0),
                             cache_misses=timing.get("cache_misses", 0))


# ----------------------------------------------------------------------
# replication-vs-checkpoint tradeoff cube (paper §IV-A, Fig 9)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ReplicationTradeoff:
    """The hybrid-replication tuning cube: every surface is shaped
    ``(n_modes, n_intervals, n_brownouts, S)`` — recovery time, SLO
    violation and lost work over replication-mode × checkpoint-interval
    × brownout-severity, all from ONE `sweep_configs` device call."""
    modes: list[str]
    ckpt_intervals: list
    brownout_peaks: list[float]
    recovery: np.ndarray
    slo: np.ndarray
    lost: np.ndarray
    grid: ConfigSweepResult

    def rows(self) -> list[dict]:
        return self.grid.rows()


def replication_tradeoff(graph, seeds, *, base_spec: ChaosSpec,
                         duration_s: float,
                         failovers: dict[str, FailoverConfig],
                         ckpt_intervals=(None, 10.0, 30.0),
                         brownouts=((), ((0.0, 1e9, 4.0),)),
                         ckpt_upload_s: float = 4.0,
                         **sweep_kw) -> ReplicationTradeoff:
    """Sweep the full replication-vs-checkpoint tradeoff cube in ONE
    `sweep_configs` call (hence one traced device pass, flat
    `timeline_build_count`).

    `failovers` maps mode labels (e.g. ``"hot_standby"`` /
    ``"passive"``) to the `FailoverConfig` representing that replication
    strategy; `ckpt_intervals` is a sequence of checkpoint intervals
    (None = no checkpoints → passive restores replay from run start);
    `brownouts` is a sequence of config-level brownout ramp tuples
    (appended to `base_spec`'s own ramps, deterministically). The cube
    axes are ordered (mode, interval, brownout, seed)."""
    mode_names = list(failovers)
    intervals = list(ckpt_intervals)
    bros = [tuple(b) for b in brownouts]
    configs = []
    for m in mode_names:
        for iv in intervals:
            for b in bros:
                peak = max((r[2] for r in b), default=1.0)
                configs.append({
                    "failover": failovers[m],
                    "ckpt": (None if iv is None else CheckpointConfig(
                        interval_s=iv, upload_s=ckpt_upload_s)),
                    "brownout": b,
                    "label": (f"{m} ckpt="
                              f"{'off' if iv is None else f'{iv:g}s'}"
                              f" brownout={peak:g}x")})
    grid = sweep_configs(graph, configs, seeds, base_spec=base_spec,
                         duration_s=duration_s, **sweep_kw)
    shape = (len(mode_names), len(intervals), len(bros), -1)
    return ReplicationTradeoff(
        mode_names, intervals, [max((r[2] for r in b), default=1.0)
                                for b in bros],
        grid.recovery_surface.reshape(shape),
        grid.slo_surface.reshape(shape),
        grid.lost_surface.reshape(shape), grid)


# ----------------------------------------------------------------------
# deployment-drill cube (canary/rolling upgrades + auto-rollback)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class DeploymentDrill:
    """The deployment-drill tuning cube: every surface is shaped
    ``(n_policies, n_fracs, n_thresholds, S)`` — recovery time, SLO
    violation, lost work and auto-rollback fire time over
    upgrade-policy × canary-fraction × rollback-threshold, all from ONE
    `sweep_configs` device call (upgrades are in-trace only, so the
    whole cube shares the drill-free rows' pregenerated timelines and
    `timeline_build_count` stays flat)."""
    policies: list[str]
    canary_fracs: list[float]
    rollback_thresholds: list[float]
    recovery: np.ndarray
    slo: np.ndarray
    lost: np.ndarray
    rollback_t: np.ndarray          # +inf = canary held (no rollback)
    grid: ConfigSweepResult

    @property
    def rollback_frac(self) -> np.ndarray:
        """Fraction of seeds whose drill auto-rolled back, per
        (policy, frac, threshold) cell."""
        return np.isfinite(self.rollback_t).mean(axis=-1)

    def rows(self) -> list[dict]:
        return self.grid.rows()


def deployment_drill(graph, seeds, *, base_spec: ChaosSpec,
                     duration_s: float,
                     policies: dict[str, UpgradeConfig],
                     canary_fracs=(0.25, 0.5),
                     rollback_thresholds=(math.inf, 200.0),
                     failover=None, ckpt=None,
                     **sweep_kw) -> DeploymentDrill:
    """Sweep the full deployment-drill cube in ONE `sweep_configs` call.

    `policies` maps labels (e.g. ``"hot"`` / ``"cold"`` / ``"hot+accel"``)
    to base `UpgradeConfig`s — typically differing in ``hot`` /
    ``startup`` / ``wave_stagger_s`` / canary config deltas; each cube
    cell replaces that policy's ``canary_frac`` and
    ``rollback_threshold`` (``math.inf`` = canary never rolls back — the
    drill-as-control row). `failover` / `ckpt` are the base resiliency
    configs every row shares (per-job lists allowed on packed arenas).

    The cube axes are ordered (policy, canary_frac, threshold, seed);
    `DeploymentDrill.rollback_t` is the per-cell auto-rollback fire-time
    surface and `rollback_frac` the per-cell trigger rate a release
    pipeline gates on."""
    pol_names = list(policies)
    fracs = [float(f) for f in canary_fracs]
    thrs = [float(t) for t in rollback_thresholds]
    configs = []
    for p in pol_names:
        for f in fracs:
            for thr in thrs:
                up = dataclasses.replace(policies[p], canary_frac=f,
                                         rollback_threshold=thr)
                configs.append({
                    "failover": failover, "ckpt": ckpt, "upgrade": up,
                    "label": (f"{p} canary={f:g} thr="
                              f"{'off' if math.isinf(thr) else f'{thr:g}'}")})
    grid = sweep_configs(graph, configs, seeds, base_spec=base_spec,
                         duration_s=duration_s, **sweep_kw)
    shape = (len(pol_names), len(fracs), len(thrs), -1)
    return DeploymentDrill(
        pol_names, fracs, thrs,
        grid.recovery_surface.reshape(shape),
        grid.slo_surface.reshape(shape),
        grid.lost_surface.reshape(shape),
        grid.rollback_surface.reshape(shape), grid)


# ----------------------------------------------------------------------
# traffic-dynamics cube (diurnal/flash load × DS2 autoscaling × failover)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TrafficSweep:
    """The traffic-dynamics tuning cube: every surface is shaped
    ``(n_scalers, n_traffics, n_failovers, S)`` — recovery time, SLO
    violation, lost work, rescale actions, thrash latch times and
    resource-seconds cost over scaler-config × traffic-pattern ×
    failover-mode, all from ONE `sweep_configs` device call (rate
    schedules are rng-free ``rfac`` curves and scalers are traced
    leaves, so the whole cube shares pregenerated timelines and
    `timeline_build_count` stays flat)."""
    scalers: list[str]
    traffics: list[str]
    failovers: list[str]
    recovery: np.ndarray
    slo: np.ndarray
    lost: np.ndarray
    rescales: np.ndarray
    thrash_t: np.ndarray            # +inf = the thrash guard never fired
    cost: np.ndarray                # Σ speed·dt resource-seconds
    grid: ConfigSweepResult

    @property
    def thrash_frac(self) -> np.ndarray:
        """Fraction of seeds whose autoscaler thrash guard latched, per
        (scaler, traffic, failover) cell — the oscillation rate a
        release pipeline gates on."""
        return np.isfinite(self.thrash_t).mean(axis=-1)

    def rows(self) -> list[dict]:
        return self.grid.rows()


def traffic_sweep(graph, seeds, *, base_spec: ChaosSpec,
                  duration_s: float,
                  scalers: dict[str, AutoscaleConfig | None],
                  traffics: dict[str, tuple] | None = None,
                  failovers: dict[str, FailoverConfig | None] | None = None,
                  ckpt=None, **sweep_kw) -> TrafficSweep:
    """Sweep the full traffic-dynamics cube — scaler-config ×
    traffic-pattern × failover-mode × seeds — in ONE `sweep_configs`
    call, the SLO-vs-cost frontier of in-trace DS2 autoscaling under
    production load dynamics.

    `scalers` maps labels to `AutoscaleConfig`s (None = no autoscaler —
    the fixed-provisioning control rows); `traffics` maps labels to
    config-level traffic patterns (`normalize_config`'s ``traffic``
    forms: a ``(diurnal, flash)`` pair, a ``{"diurnal": ..., "flash":
    ...}`` dict, or a bare flash-event tuple — composed on top of
    `base_spec`'s own schedule); `failovers` maps labels to the base
    `FailoverConfig` per row (rescale-during-recovery and
    autoscaler-vs-failover interactions come from crossing these two
    axes). The cube axes are ordered (scaler, traffic, failover, seed);
    `TrafficSweep.cost` is the resource-seconds surface against which
    `slo` trades, and `thrash_frac` the per-cell oscillation rate."""
    sc_names = list(scalers)
    traffics = dict(traffics) if traffics else {"base": ((), ())}
    fo_names_map = dict(failovers) if failovers else {"base": None}
    tr_names = list(traffics)
    fo_names = list(fo_names_map)
    configs = []
    for s in sc_names:
        for tname in tr_names:
            for fname in fo_names:
                configs.append({
                    "failover": fo_names_map[fname], "ckpt": ckpt,
                    "scaler": scalers[s], "traffic": traffics[tname],
                    "label": f"{s} {tname} {fname}"})
    grid = sweep_configs(graph, configs, seeds, base_spec=base_spec,
                         duration_s=duration_s, **sweep_kw)
    shape = (len(sc_names), len(tr_names), len(fo_names), -1)
    return TrafficSweep(
        sc_names, tr_names, fo_names,
        grid.recovery_surface.reshape(shape),
        grid.slo_surface.reshape(shape),
        grid.lost_surface.reshape(shape),
        grid.rescale_surface.reshape(shape),
        grid.thrash_surface.reshape(shape),
        grid.cost_surface.reshape(shape), grid)
