"""Chaos-sweep driver: batched failure-scenario screening (paper §V-B).

StreamShield's release pipeline validates resiliency by sweeping *many*
injected-failure configurations, not one drill. This driver turns a seed
batch into per-scenario resiliency summaries in a single vmapped `jit`
call of the JAX engine twin (`streams/jax_engine.py`):

    result = sweep(nexmark.q2(parallelism=8), seeds=range(256),
                   base_spec=ChaosSpec(host_kill_prob_per_s=0.002),
                   duration_s=300.0)
    result.summaries[i].recovery_time_s  # per-scenario
    result.aggregate()                   # fleet percentiles

Per scenario it reports recovery time (first post-failure return of
source lag below the SLO threshold), maximum backlog, SLO-violation
tick counts, dropped/emitted records and checkpoint success — the
metrics the paper uses to gate a release.
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.core.chaos import ChaosSpec
from repro.streams.engine import CheckpointConfig, FailoverConfig
from repro.streams.graph import LogicalGraph
from repro.streams.jax_engine import JaxBatchMetrics, run_batch


@dataclasses.dataclass
class ScenarioSummary:
    seed: int
    n_failures: int              # recovery events (host kills that hit)
    recovery_time_s: float       # inf = never recovered, 0 = no SLO breach
    max_backlog: float           # peak total queued records
    max_lag: float               # peak source lag
    slo_threshold: float
    slo_violation_ticks: int
    slo_violation_frac: float
    dropped: float
    emitted: float
    ckpt_attempts: int
    ckpt_success: int


@dataclasses.dataclass
class SweepResult:
    graph_name: str
    duration_s: float
    n_ticks: int
    summaries: list[ScenarioSummary]
    batch: JaxBatchMetrics
    wall_s: float                # end-to-end sweep wall time

    @property
    def scenarios_per_s(self) -> float:
        return len(self.summaries) / self.wall_s if self.wall_s else 0.0

    def aggregate(self) -> dict:
        """Fleet-level percentiles across the scenario batch."""
        rec = np.array([s.recovery_time_s for s in self.summaries])
        fin = rec[np.isfinite(rec)]
        frac = np.array([s.slo_violation_frac for s in self.summaries])
        return {
            "scenarios": len(self.summaries),
            "failed_scenarios": int(sum(s.n_failures > 0
                                        for s in self.summaries)),
            "unrecovered": int(np.sum(~np.isfinite(rec))),
            "recovery_p50_s": float(np.median(fin)) if len(fin) else 0.0,
            "recovery_p95_s": float(np.percentile(fin, 95))
            if len(fin) else 0.0,
            "recovery_max_s": float(fin.max()) if len(fin) else 0.0,
            "slo_violation_frac_p50": float(np.median(frac)),
            "slo_violation_frac_p95": float(np.percentile(frac, 95)),
            "max_backlog": float(max(s.max_backlog
                                     for s in self.summaries)),
            "dropped_total": float(sum(s.dropped for s in self.summaries)),
            "scenarios_per_s": self.scenarios_per_s,
        }


def _recovery_time(ts: np.ndarray, lag: np.ndarray, down_bk: np.ndarray,
                   recs: list[dict]) -> float:
    """Time from the first failure until the job is healthy again.

    Source lag in this sim is *retained* backlog (sources never re-emit
    requeued records), so "lag returns below an absolute threshold"
    would read as never-recovered for any single-task drill. Healthy is
    therefore: the failover outage window has passed, the per-tick lag
    growth is back at its pre-failure level, and downstream queues have
    drained. inf = still unhealthy at horizon end."""
    t_fail = recs[0]["t"]
    outage_end = max(r["t"] + r["downtime"] for r in recs)
    pre = ts < t_fail
    dlag = np.diff(lag, prepend=lag[:1])
    grow_thr = (float(np.percentile(dlag[pre], 95)) if pre.any()
                else 0.0) + 1e-9
    bk_thr = max(2.0 * (float(np.median(down_bk[pre])) if pre.any()
                        else 0.0), 1.0)
    breach = (ts < outage_end) | (dlag > grow_thr) | (down_bk > bk_thr)
    breach &= ts >= t_fail
    if not breach.any():
        return 0.0
    last = int(np.nonzero(breach)[0][-1])
    if last == len(ts) - 1:
        return math.inf
    return float(ts[last + 1] - t_fail)


def summarize(batch: JaxBatchMetrics, seeds, *,
              graph: LogicalGraph | None = None,
              slo_lag: float | None = None,
              wall_s: float = 0.0, graph_name: str = "",
              duration_s: float = 0.0) -> SweepResult:
    """Per-scenario resiliency summaries from stacked batch metrics.

    `slo_lag` is the source-lag SLO threshold (records). When None it is
    derived per scenario as 2× the pre-failure steady-state median lag
    (falling back to the whole-run median for failure-free scenarios).
    `graph` identifies source ops so recovery can watch downstream
    queues; without it every op's backlog counts as downstream.
    """
    ts = batch.t
    src_names = ({o.name for o in graph.ops if o.is_source}
                 if graph is not None else set())
    down_cols = [j for j, n in enumerate(batch.op_names)
                 if n not in src_names]
    summaries = []
    for i, seed in enumerate(seeds):
        lag = batch.source_lag[i]
        recs = batch.recoveries[i]
        t_fail = recs[0]["t"] if recs else None
        down_bk = batch.backlog[i][:, down_cols].sum(axis=1)
        if slo_lag is None:
            pre = lag[ts < t_fail] if t_fail is not None else lag
            steady = float(np.median(pre)) if len(pre) else 0.0
            thr = 2.0 * steady + 1e-9
        else:
            thr = slo_lag
        viol = int(np.sum(lag > thr))
        summaries.append(ScenarioSummary(
            seed=int(getattr(seed, "seed", seed)),   # ChaosSpec or int
            n_failures=len(recs),
            recovery_time_s=(_recovery_time(ts, lag, down_bk, recs)
                             if recs else 0.0),
            max_backlog=float(batch.backlog[i].sum(axis=1).max()),
            max_lag=float(lag.max()),
            slo_threshold=thr,
            slo_violation_ticks=viol,
            slo_violation_frac=viol / max(len(ts), 1),
            dropped=float(batch.dropped[i]),
            emitted=float(batch.emitted[i]),
            ckpt_attempts=int(batch.ckpt_attempts[i]),
            ckpt_success=int(batch.ckpt_success[i]),
        ))
    return SweepResult(graph_name, duration_s, len(ts), summaries, batch,
                       wall_s)


def sweep(graph: LogicalGraph, seeds, *, base_spec: ChaosSpec,
          duration_s: float, n_hosts: int = 8, dt: float = 0.5,
          queue_cap: float = 256.0,
          failover: FailoverConfig | None = None,
          ckpt: CheckpointConfig | None = None,
          slo_lag: float | None = None,
          task_speed_override: dict[int, float] | None = None,
          seed: int = 0) -> SweepResult:
    """Sweep `seeds` chaos scenarios over `graph` in one vmapped jit call."""
    seeds = list(seeds)
    t0 = time.perf_counter()
    batch = run_batch(graph, seeds, base_spec=base_spec,
                      duration_s=duration_s, n_hosts=n_hosts, dt=dt,
                      queue_cap=queue_cap, failover=failover, ckpt=ckpt,
                      task_speed_override=task_speed_override, seed=seed)
    wall = time.perf_counter() - t0
    return summarize(batch, seeds, graph=graph, slo_lag=slo_lag,
                     wall_s=wall, graph_name=graph.name,
                     duration_s=duration_s)
