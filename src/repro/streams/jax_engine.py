"""Batched JAX twin of the vectorized stream engine (`jit`/`scan`/`vmap`).

A functional re-expression of `streams.engine.StreamEngine` for chaos
sweeps: where the numpy engine mutates a flat task arena in place, this
twin threads a single pytree of arena state through a pure
`state -> state` tick lowered from the same `RoutingPlan`
(`streams.engine.build_plan`), runs whole horizons as one
`jax.lax.scan` under `jit`, and `vmap`s the scan over a ``(S,)`` batch
of failure seeds so thousands of chaos scenarios execute in a single
device call.

State-pytree layout (`EngineState`, one leaf per arena variable; under
`vmap` every leaf gains a leading ``(S,)`` seed axis):

    queue      (n_tasks,) f64  bounded input queues (records)
    down_until (n_tasks,) f64  failover downtime horizon per task
    speed      (n_tasks,) f64  static host speed (overrides × stragglers)
    ckpt_epoch ()         i32  checkpoints attempted so far
    emitted    ()         f64  source records emitted (running total)
    dropped    ()         f64  records dropped by single_task failover

Chaos pregeneration semantics (the one intentional delta vs the numpy
engine's *mechanism*, not its numbers): a `jit`-ted scan cannot consume
sequential numpy rng draws, so all chaos is materialized up front by
`core.chaos.build_chaos_timeline` — draw-for-draw in the engine's rng
consumption order — into per-tick event tensors (host-kill masks,
checkpoint flags/outcomes, straggler speeds). Event times are thereby
quantized to tick boundaries, which is exactly the resolution at which
the tick-driven numpy engine observes them, so metrics stay pinned to
`StreamEngine` at 1e-5 over full runs (`tests/test_jax_engine.py`);
checkpoint outcomes and recovery events ride along as host-side
metadata because they never feed back into queue dynamics.

Compiled `run` functions are cached per *plan shape* (op slices, edge
kinds, segment counts, failover mode — never float parameters, which
are traced), so two engines over same-shaped graphs share one trace;
`get_cached_run_fns` exposes the cache for tests.

Everything runs in float64 (scoped `jax.experimental.enable_x64`, no
global config flip) to hold parity with the float64 numpy engine.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.chaos import (ChaosEngine, ChaosSpec, ChaosTimeline,
                              build_chaos_timeline)
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  build_plan)
from repro.streams.graph import LogicalGraph, PhysicalGraph, expand

try:  # scoped x64 — keeps the rest of the process on default f32
    from jax.experimental import enable_x64 as _enable_x64
except ImportError:  # pragma: no cover - old/new jax without the ctx
    import contextlib

    @contextlib.contextmanager
    def _enable_x64():
        jax.config.update("jax_enable_x64", True)
        yield


class EngineState(NamedTuple):
    """All mutable arena state of one scenario (see module docstring)."""
    queue: jax.Array
    down_until: jax.Array
    speed: jax.Array
    ckpt_epoch: jax.Array
    emitted: jax.Array
    dropped: jax.Array


class _OpDesc(NamedTuple):
    lo: int
    hi: int
    is_source: bool


class _EdgeDesc(NamedTuple):
    kind: str
    static: bool
    src_op: int
    src_par: int
    dst_lo: int
    dst_hi: int
    n_blocks: int
    n_groups: int
    any_unblocked: bool


# ----------------------------------------------------------------------
# pure routing (mirrors StreamEngine._route / _accept op-for-op)
# ----------------------------------------------------------------------
def _route(ed: _EdgeDesc, ea: dict, produced, free_down, alive_d):
    kind = ed.kind
    if kind == "forward":
        return produced * alive_d
    if kind in ("rescale", "group_rescale"):
        prod_blk = jax.ops.segment_sum(produced, ea["blk_of_src"],
                                       num_segments=ed.n_blocks)
        alive_blk = jax.ops.segment_sum(alive_d * ea["dst_in_blk"],
                                        ea["blk_idx"],
                                        num_segments=ed.n_blocks)
        has = alive_blk > 0.0
        rate_blk = jnp.where(has, prod_blk / jnp.where(has, alive_blk, 1.0),
                             0.0)
        arriving = rate_blk[ea["blk_idx"]] * alive_d
        if ed.any_unblocked:
            arriving = jnp.where(ea["dst_in_blk"] > 0.0, arriving, 0.0)
        return arriving
    # all-to-all family: identical weight rows → scale a single row
    total = produced.sum()
    if kind == "rebalance":
        val = alive_d
    elif kind == "hash":
        return total * ea["share"]
    elif kind == "weakhash":
        cap = jnp.maximum(free_down, 1e-9) * alive_d
        capsum = jax.ops.segment_sum(cap, ea["grp_of_dst"],
                                     num_segments=ed.n_groups)
        # groups with zero capacity fall back to alive-uniform spread
        # (jit evaluates both branches; numpy branches — values match)
        alive_eps = alive_d + 1e-9
        capsum_fb = jax.ops.segment_sum(alive_eps, ea["grp_of_dst"],
                                        num_segments=ed.n_groups)
        fall = capsum <= 0.0
        cap = jnp.where(fall[ea["grp_of_dst"]], alive_eps, cap) * alive_d
        capsum = jnp.where(fall, capsum_fb, capsum)
        val = cap * ea["mass_of_dst"] / capsum[ea["grp_of_dst"]]
    elif kind == "backlog":
        open_ = (free_down > ea["dst_qcap"] * 0.25).astype(alive_d.dtype)
        val = jnp.maximum(free_down, 1e-9) * alive_d * jnp.maximum(open_,
                                                                   0.05)
    else:
        raise ValueError(kind)
    rs = val.sum()
    return jnp.where(rs > 0.0, val * (total / rs), jnp.zeros_like(val))


def _hol_ratio(arriving, room):
    live = arriving > 1e-9
    return jnp.where(live, room / jnp.maximum(arriving, 1e-300), jnp.inf)


def _accept(ed: _EdgeDesc, ea: dict, arriving, room):
    if ed.static:
        # head-of-line blocking: most congested live channel throttles all
        lam = jnp.minimum(_hol_ratio(arriving, room).min(), 1.0)
        return arriving * lam
    if ed.kind == "group_rescale":
        ratio = _hol_ratio(arriving, room)
        lam_g = jnp.minimum(
            jax.ops.segment_min(ratio, ea["blk_idx"],
                                num_segments=ed.n_blocks), 1.0)
        return arriving * lam_g[ea["blk_idx"]]
    # adaptive routing: channels accept up to their credits
    return jnp.minimum(arriving, room)


# ----------------------------------------------------------------------
# tick/run construction + per-plan-shape trace cache
# ----------------------------------------------------------------------
def _build_run(desc):
    (op_descs, edge_descs, edges_of_op, src_cols, n_tasks, n_hosts,
     n_regions, failover_mode) = desc
    single_task = failover_mode == "single_task"

    def tick(pa, state: EngineState, x):
        t = x["t"]
        q = state.queue
        alive_f = (state.down_until <= t).astype(q.dtype)
        free = jnp.maximum(pa["qcap"] - q, 0.0)
        emitted, dropped = state.emitted, state.dropped
        qps_cols = []
        backlog_zero = jnp.zeros((), q.dtype)

        for oi, od in enumerate(op_descs):
            sl = slice(od.lo, od.hi)
            if od.is_source:
                produced = pa["src_row"][sl] * alive_f[sl]
                emitted = emitted + produced.sum()
                qps_cols.append(backlog_zero)
            else:
                cap = pa["cap_base"][sl] * state.speed[sl] * alive_f[sl]
                take = jnp.minimum(q[sl], cap)
                q = q.at[sl].add(-take)
                produced = take * pa["sel"][oi]
                qps_cols.append(take.sum() / pa["dt"])
            for ei in edges_of_op[oi]:
                ed, ea = edge_descs[ei], pa["edges"][ei]
                dsl = slice(ed.dst_lo, ed.dst_hi)
                arriving = _route(ed, ea, produced, free[dsl], alive_f[dsl])
                if single_task:
                    # records routed to a dead task drop (γ=partial)
                    dead = alive_f[dsl] <= 0.0
                    dropped = dropped + jnp.where(dead, arriving, 0.0).sum()
                    arriving = jnp.where(dead, 0.0, arriving)
                accepted = _accept(ed, ea, arriving, free[dsl])
                overflow = (arriving - accepted).sum()
                q = q.at[sl].add(overflow / max(ed.src_par, 1))
                q = q.at[dsl].add(accepted)
                free = free.at[dsl].set(
                    jnp.maximum(free[dsl] - accepted, 0.0))

        # pregenerated chaos host kills → failover
        down_until = state.down_until
        if failover_mode != "none":
            vict = x["kills"][pa["task_host"]]
            if failover_mode == "single_task":
                hit = vict > 0.0
                until = t + pa["detect"] + pa["restart_single"]
            else:
                reg_hit = jax.ops.segment_max(vict, pa["task_region"],
                                              num_segments=n_regions)
                hit = reg_hit[pa["task_region"]] > 0.0
                until = t + pa["detect"] + pa["restart_region"]
            down_until = jnp.where(hit, until, down_until)
            q = jnp.where(hit, 0.0, q)

        ckpt_epoch = state.ckpt_epoch + x["ckpt"].astype(jnp.int32)

        backlog_row = jnp.stack([q[od.lo:od.hi].sum() for od in op_descs])
        qps_row = jnp.stack(qps_cols)
        lag = jnp.stack([backlog_row[j] for j in src_cols]).sum()
        new_state = EngineState(q, down_until, state.speed, ckpt_epoch,
                                emitted, dropped)
        return new_state, {"qps": qps_row, "backlog": backlog_row,
                           "lag": lag}

    def run(pa, state, xs):
        return lax.scan(lambda st, x: tick(pa, st, x), state, xs)

    return run


_FN_CACHE: dict = {}

_XS_AXES = {"t": None, "kills": 0, "ckpt": None}


def get_cached_run_fns(desc):
    """(jitted run, jitted vmapped run) for a static plan descriptor.

    One entry — hence one trace per call signature — per plan *shape*;
    float parameters (rates, selectivities, restart times, …) are traced
    arguments, so sweeping them never re-traces."""
    if desc not in _FN_CACHE:
        run = _build_run(desc)
        _FN_CACHE[desc] = (
            jax.jit(run),
            jax.jit(jax.vmap(run, in_axes=(None, 0, _XS_AXES))))
    return _FN_CACHE[desc]


# ----------------------------------------------------------------------
# lowering: LogicalGraph + configs → static desc + plan arrays
# ----------------------------------------------------------------------
class _Lowered:
    def __init__(self, graph: LogicalGraph, *, n_hosts: int, dt: float,
                 queue_cap: float, failover: FailoverConfig | None,
                 ckpt: CheckpointConfig | None, seed: int):
        self.graph = graph
        self.dt = dt
        self.failover = failover or FailoverConfig()
        self.ckpt_cfg = ckpt
        self.phys: PhysicalGraph = expand(graph, n_hosts=n_hosts, seed=seed)
        self.plan = build_plan(graph, dt, queue_cap)
        self.task_host = np.array([tk.host for tk in self.phys.tasks])
        self.task_region = np.array(
            [self.phys.task_region[tk.task_id] for tk in self.phys.tasks])
        self.n_hosts = int(self.task_host.max()) + 1
        self.n_regions = len(self.phys.regions)

        plan = self.plan
        n_tasks = plan.n_tasks
        src_row = np.zeros(n_tasks)
        cap_base = np.zeros(n_tasks)
        sel = np.zeros(len(plan.ops))
        op_descs, edge_descs, edge_arrays, edges_of_op = [], [], [], []
        for oi, p in enumerate(plan.ops):
            op_descs.append(_OpDesc(p.lo, p.hi, p.is_source))
            sel[oi] = p.selectivity
            if p.is_source:
                src_row[p.lo:p.hi] = p.src_row
            else:
                cap_base[p.lo:p.hi] = p.service_rate * dt
        for oi, p in enumerate(plan.ops):
            mine = []
            for ep in p.out_edges:
                mine.append(len(edge_descs))
                n_groups = (len(ep.grp_starts)
                            if ep.grp_starts is not None else 0)
                edge_descs.append(_EdgeDesc(
                    ep.kind, ep.static, oi, p.par, ep.dst.lo, ep.dst.hi,
                    ep.n_blocks, n_groups, ep.any_unblocked))
                ea: dict = {}
                if ep.kind == "hash":
                    ea["share"] = ep.share
                elif ep.kind == "weakhash":
                    ea["grp_of_dst"] = ep.grp_of_dst.astype(np.int32)
                    ea["mass_of_dst"] = ep.mass_of_dst
                elif ep.kind == "backlog":
                    ea["dst_qcap"] = np.float64(ep.dst_qcap)
                if ep.kind in ("rescale", "group_rescale"):
                    ea["blk_of_src"] = ep.blk_of_src.astype(np.int32)
                    ea["blk_idx"] = ep.blk_idx.astype(np.int32)
                    ea["dst_in_blk"] = ep.dst_in_blk.astype(np.float64)
                edge_arrays.append(ea)
            edges_of_op.append(tuple(mine))

        fo = self.failover
        self.desc = (tuple(op_descs), tuple(edge_descs),
                     tuple(edges_of_op), tuple(int(j) for j in
                                               plan.src_cols),
                     n_tasks, self.n_hosts, self.n_regions, fo.mode)
        self.arrays = {
            "qcap": plan.qcap,
            "src_row": src_row,
            "cap_base": cap_base,
            "sel": sel,
            "dt": np.float64(dt),
            "task_host": self.task_host.astype(np.int32),
            "task_region": self.task_region.astype(np.int32),
            "detect": np.float64(fo.detect_s),
            "restart_region": np.float64(fo.region_restart_s),
            "restart_single": np.float64(fo.single_restart_s),
            "edges": edge_arrays,
        }
        self.op_names = [p.name for p in plan.ops]

    # ------------------------------------------------------------------
    def prepare(self, spec: ChaosSpec, n_ticks: int,
                task_speed_override: dict[int, float] | None = None
                ) -> tuple[EngineState, dict, ChaosTimeline]:
        """Pregenerate one seed's chaos timeline → (state0, scan xs)."""
        fo, ck = self.failover, self.ckpt_cfg
        tl = build_chaos_timeline(
            spec, n_ticks=n_ticks, dt=self.dt, n_hosts=self.n_hosts,
            task_host=self.task_host, task_region=self.task_region,
            regions=self.phys.regions, failover_mode=fo.mode,
            detect_s=fo.detect_s, region_restart_s=fo.region_restart_s,
            single_restart_s=fo.single_restart_s,
            ckpt_interval_s=(ck.interval_s if ck else None),
            ckpt_mode=(ck.mode if ck else "region"),
            ckpt_upload_s=(ck.upload_s if ck else 4.0),
            ckpt_retry=(ck.retry_failed_region if ck else True))
        n_tasks = self.plan.n_tasks
        speed = np.ones(n_tasks)
        if task_speed_override:
            for tid, s in task_speed_override.items():
                speed[tid] = s
        speed *= tl.task_speed
        state = EngineState(
            queue=np.zeros(n_tasks), down_until=np.zeros(n_tasks),
            speed=speed, ckpt_epoch=np.int32(0),
            emitted=np.float64(0.0), dropped=np.float64(0.0))
        xs = {"t": tl.ts, "kills": tl.kills.astype(np.float64),
              "ckpt": tl.ckpt_at}
        return state, xs, tl


# ----------------------------------------------------------------------
# metrics façades (same read API as streams.engine.EngineMetrics)
# ----------------------------------------------------------------------
class JaxEngineMetrics:
    def __init__(self, op_names, t, lag, qps, backlog, emitted, dropped,
                 timeline: ChaosTimeline, ckpt_epoch: int | None = None):
        self.t = t
        self.source_lag = lag
        self.qps = {n: qps[:, j] for j, n in enumerate(op_names)}
        self.backlog = {n: backlog[:, j] for j, n in enumerate(op_names)}
        self.emitted = float(emitted)
        self.dropped = float(dropped)
        self.ckpt_attempts = timeline.ckpt_attempts
        self.ckpt_success = timeline.ckpt_success
        self.ckpt_failed = timeline.ckpt_failed
        # device-side attempt counter (scan state) — must agree with the
        # host-side timeline; pinned in tests/test_jax_engine.py
        self.ckpt_epoch = (timeline.ckpt_attempts if ckpt_epoch is None
                           else int(ckpt_epoch))
        self.recoveries = timeline.recoveries
        self.timeline = timeline


class JaxBatchMetrics:
    """Stacked metrics of a vmapped seed batch; `row(i)` is identical to
    a standalone single-seed run (pinned in tests/test_jax_engine.py)."""

    def __init__(self, op_names, t, lag, qps, backlog, emitted, dropped,
                 timelines, ckpt_epoch=None):
        self.op_names = list(op_names)
        self.t = t                     # (n_ticks,)
        self.source_lag = lag          # (S, n_ticks)
        self.qps = qps                 # (S, n_ticks, n_ops)
        self.backlog = backlog         # (S, n_ticks, n_ops)
        self.emitted = emitted         # (S,)
        self.dropped = dropped         # (S,)
        self.ckpt_epoch = ckpt_epoch   # (S,) device-side attempt counter
        self.timelines = list(timelines)
        self.ckpt_attempts = np.array([tl.ckpt_attempts for tl in timelines])
        self.ckpt_success = np.array([tl.ckpt_success for tl in timelines])
        self.ckpt_failed = np.array([tl.ckpt_failed for tl in timelines])
        self.recoveries = [tl.recoveries for tl in timelines]

    def __len__(self) -> int:
        return len(self.timelines)

    def row(self, i: int) -> JaxEngineMetrics:
        return JaxEngineMetrics(self.op_names, self.t, self.source_lag[i],
                                self.qps[i], self.backlog[i],
                                self.emitted[i], self.dropped[i],
                                self.timelines[i],
                                ckpt_epoch=(self.ckpt_epoch[i]
                                            if self.ckpt_epoch is not None
                                            else None))


# ----------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------
class JaxStreamEngine:
    """Drop-in (single-seed) twin of `StreamEngine`: same constructor
    signature, `run(duration_s)` returns `JaxEngineMetrics` with the
    numpy engine's metric names/values (1e-5)."""

    def __init__(self, graph: LogicalGraph, *, n_hosts: int = 8,
                 dt: float = 0.5, queue_cap: float = 256.0,
                 chaos: ChaosEngine | ChaosSpec | None = None,
                 failover: FailoverConfig | None = None,
                 ckpt: CheckpointConfig | None = None,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0):
        if isinstance(chaos, ChaosEngine):
            chaos = chaos.spec
        self.spec = chaos or ChaosSpec()
        self.g = graph
        self.dt = dt
        self._override = task_speed_override
        self._low = _Lowered(graph, n_hosts=n_hosts, dt=dt,
                             queue_cap=queue_cap, failover=failover,
                             ckpt=ckpt, seed=seed)
        self.metrics: JaxEngineMetrics | None = None

    @property
    def lowered(self) -> _Lowered:
        return self._low

    def run(self, duration_s: float) -> JaxEngineMetrics:
        low = self._low
        n_ticks = int(round(duration_s / self.dt))
        state, xs, tl = low.prepare(self.spec, n_ticks, self._override)
        run_fn, _ = get_cached_run_fns(low.desc)
        with _enable_x64():
            final, ys = run_fn(low.arrays, state, xs)
            qps = np.asarray(ys["qps"])
            backlog = np.asarray(ys["backlog"])
            lag = np.asarray(ys["lag"])
            emitted = float(final.emitted)
            dropped = float(final.dropped)
            ckpt_epoch = int(final.ckpt_epoch)
        self.metrics = JaxEngineMetrics(low.op_names, tl.ts, lag, qps,
                                        backlog, emitted, dropped, tl,
                                        ckpt_epoch=ckpt_epoch)
        return self.metrics


def run_batch(graph: LogicalGraph, seeds, *, duration_s: float,
              base_spec: ChaosSpec | None = None, n_hosts: int = 8,
              dt: float = 0.5, queue_cap: float = 256.0,
              failover: FailoverConfig | None = None,
              ckpt: CheckpointConfig | None = None,
              task_speed_override: dict[int, float] | None = None,
              seed: int = 0) -> JaxBatchMetrics:
    """Run a ``(S,)`` batch of chaos scenarios as ONE vmapped `jit` call.

    `seeds` is a sequence of ints (merged into `base_spec` via
    ``dataclasses.replace(spec, seed=s)``) or of full `ChaosSpec`s.
    """
    specs = [dataclasses.replace(base_spec or ChaosSpec(), seed=int(s))
             if isinstance(s, (int, np.integer)) else s for s in seeds]
    if not specs:
        raise ValueError("run_batch requires at least one seed/spec")
    low = _Lowered(graph, n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
                   failover=failover, ckpt=ckpt, seed=seed)
    n_ticks = int(round(duration_s / dt))
    prepped = [low.prepare(spec, n_ticks, task_speed_override)
               for spec in specs]
    states = [p[0] for p in prepped]
    tls = [p[2] for p in prepped]
    batch_state = EngineState(*(np.stack([getattr(s, f) for s in states])
                                for f in EngineState._fields))
    xs = {"t": prepped[0][1]["t"],                 # identical across seeds
          "kills": np.stack([p[1]["kills"] for p in prepped]),
          "ckpt": prepped[0][1]["ckpt"]}           # static schedule
    _, batch_fn = get_cached_run_fns(low.desc)
    with _enable_x64():
        final, ys = batch_fn(low.arrays, batch_state, xs)
        qps = np.asarray(ys["qps"])
        backlog = np.asarray(ys["backlog"])
        lag = np.asarray(ys["lag"])
        emitted = np.asarray(final.emitted)
        dropped = np.asarray(final.dropped)
        ckpt_epoch = np.asarray(final.ckpt_epoch)
    return JaxBatchMetrics(low.op_names, tls[0].ts, lag, qps, backlog,
                           emitted, dropped, tls, ckpt_epoch=ckpt_epoch)
