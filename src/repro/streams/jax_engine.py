"""Batched JAX twin of the vectorized stream engine (`jit`/`scan`/`vmap`).

A functional re-expression of `streams.engine.StreamEngine` for chaos
sweeps: where the numpy engine mutates a flat task arena in place, this
twin threads a single pytree of arena state through a pure
`state -> state` tick lowered from the same `RoutingPlan`
(`streams.engine.build_plan`), runs whole horizons as one
`jax.lax.scan` under `jit`, and `vmap`s the scan over a ``(S,)`` batch
of failure seeds so thousands of chaos scenarios execute in a single
device call.

Lowering pipeline (plan → padded tensors → segment-sum tick)
------------------------------------------------------------
The jitted tick is O(1) in graph size. The pipeline has three stages:

1. `streams.engine.build_plan` lowers the logical graph into the
   `RoutingPlan` both engines share (arena slices, per-op scalars,
   per-edge routing constants).
2. `streams.engine.lower_tensor_plan` flattens the plan into per-*phase*
   edge tensors: src/dst task index vectors, per-entry partitioner
   masks, globally-numbered block/group tables (one trailing dummy
   segment each, so ragged fan-outs become shape-padded segment ids).
   A phase is one slot of a static schedule that reproduces the numpy
   tick's sequential op order exactly: ops consume after all upstream
   deposits, and edges sharing a destination op serialize across phases
   (the head-of-line `free`-credit reads must nest). The number of
   phases is bounded by the longest in-tick pipeline chain of a single
   job — NOT by op/edge count — so packing hundreds of jobs into one
   arena leaves the trace size unchanged.
3. `_build_run` emits, per phase, a constant number of gathers +
   `segment_sum`/`segment_min`/`segment_max` passes over ALL of the
   phase's edges at once (consume → route → accept → deposit), replacing
   the old per-op/per-edge Python loop whose trace grew O(ops + edges).
   The old unrolled tick survives as `build_unrolled_run` purely as the
   benchmark baseline (benchmarks/bench_compile.py).

Dense / compact / pallas lowering contract (``phase_mode``)
-----------------------------------------------------------
`lower_tensor_plan` has three flavors sharing the phase schedule; every
engine/sweep entry point takes ``phase_mode`` ("dense" | "compact" |
"pallas" | "auto", default auto via `engine.select_phase_mode`):

* **dense** (`engine.PhaseTensors`, `_build_run`) — the parity
  baseline. Per phase it multiplies arena-wide masks and runs
  arena-sized segment reductions; the integer structure (index vectors,
  partitioner masks, segment tables) is BAKED into the trace and
  digested into `TensorPlan.key`, floats are traced. Work per tick is
  O(n_phases × n_tasks) regardless of how few tasks a phase touches.
* **compact** (`engine.CompactPhase`, `_build_compact_run`) — the
  sparse-phase path. Every arena-sized segment reduction becomes a
  row-table gather+reduce over just the phase's active tasks / source
  ops / dst entries (rows pow2-padded with mask columns — the same
  bucketing discipline as seed padding), and ALL index/mask tables ride
  the params pytree as traced leaves: the trace key is only the bucket
  shape signature, so same-bucket plans (e.g. same-shape graphs with
  different partitioner kinds, placements or routing tables) share ONE
  compiled trace. Consumption stays arena-wide elementwise
  (bit-identical to dense); row reductions preserve each segment's
  member order, so compact == dense at 1e-12 over full runs
  (tests/test_sparse_phase.py). On deep pipelines (SS-style, 6 phases)
  at 10k tasks the compact warm tick is 2–4x the dense one
  (benchmarks/bench_sweep_scale.py → results/bench_sweep_scale.json).
* **pallas** (the same `engine.CompactPhase` tables,
  `_build_pallas_run` + `repro.kernels.tick_phase`) — the fused-kernel
  path. The run is NATIVELY seed-batched: every state leaf carries a
  leading ``(S,)`` scenario axis instead of an outer seed vmap, and
  each routing phase executes as ONE fused ``pallas_call`` (task-state
  gather → per-edge normalization → head-of-line row-min → per-group /
  per-block row-sum → accept mask, sharing VMEM scratch across the
  fused stages) with the seed axis as the Pallas grid dimension and
  the pow2 row buckets as block shapes. Config/mix grid axes vmap over
  the native run (one vmap level fewer than compact). Kernel dispatch
  follows `repro.kernels.common.resolve_impl`: compiled Pallas on TPU,
  the jnp reference lowering on CPU by default, and
  ``REPRO_KERNEL_IMPL=interpret`` forces the actual kernel through the
  Pallas interpreter (jit/scan/vmap-traceable — CI's pallas smoke runs
  it). The trace cache keys on (bucket signature, resolved impl).
  Parity with dense/compact holds at 1e-12 (tests/test_pallas_tick.py);
  ``devices=`` sharding is not wired for this mode.

"auto" picks compact exactly when the eliminated arena-wide reductions
dominate the row-gather cost (deep packed arenas), scaled by the
seed-axis width of the requesting sweep (`select_phase_mode`'s
``seed_width``: wide batches amortize the row-table overhead, so
shallow-but-wide sweeps go compact too); small single-seed graphs stay
dense, and pallas is never auto-selected. Setting
``REPRO_REQUIRE_PHASE_MODE=compact`` (or ``dense`` / ``pallas``) turns
a silent fallback into a hard error — scripts/ci.sh's smoke targets
use it.

All resiliency floats are *traced leaves* of the params pytree, never
compile-time constants: per-task failover vectors (detect / restart
budgets / mode masks — per-job `FailoverConfig` lists lower to per-task
vectors via `streams.engine.per_task_failover`), queue capacities,
selectivities, source rates, and the per-phase hash-share / weakhash-
mass tables. Sweeping any of them reuses the compiled trace; only the
integer structure tensors (digested into `TensorPlan.key`) key the
trace cache.

State-pytree layout (`EngineState`, one leaf per arena variable; under
`vmap` every leaf gains a leading ``(S,)`` seed axis):

    queue      (n_tasks,) f64  bounded input queues (records)
    down_until (n_tasks,) f64  failover downtime horizon per task
    speed      (n_tasks,) f64  static host speed (overrides × stragglers)
    ckpt_epoch ()         i32  checkpoint attempts so far
    emitted    (n_jobs,)  f64  source records emitted, per job segment
    dropped    (n_jobs,)  f64  single_task failover drops, per job segment
    up_until   (n_tasks,) f64  upgrade/rollback-wave downtime horizon
                               (separate from down_until so checkpoint
                               alive masks — which must match the
                               pregenerated timelines draw-for-draw —
                               never see deployment downtime)
    rb_t       ()         f64  auto-rollback fire time (+inf = not fired)
    dacc       ()         f64  controller EWMA of canary−stable backlog

Chaos pregeneration semantics (the one intentional delta vs the numpy
engine's *mechanism*, not its numbers): a `jit`-ted scan cannot consume
sequential numpy rng draws, so all chaos is materialized up front by
`core.chaos.build_chaos_timeline` — draw-for-draw in the engine's rng
consumption order — into per-tick event tensors (host-kill masks,
checkpoint attempt counts, straggler speeds). Event times are thereby
quantized to tick boundaries, which is exactly the resolution at which
the tick-driven numpy engine observes them, so metrics stay pinned to
`StreamEngine` at 1e-5 over full runs (`tests/test_jax_engine.py`);
checkpoint outcomes and recovery events ride along as host-side
metadata because they never feed back into queue dynamics.

External-system event tensors + replication recovery modes
----------------------------------------------------------
The per-tick ``xs`` stream carries four deterministic (rng-free)
external-system curves next to the kill masks, always present so the
pytree structure — and hence the trace — is stable:

    bfac  (n_ticks, n_jobs) f64  storage brownout latency factor
                                 (`core.chaos.brownout_curve`: tent
                                 ramps from `ChaosSpec.brownout_at`
                                 plus any config-axis ramps, composed
                                 by tuple concatenation so grid rows
                                 stay bit-identical to rebuilds)
    gate  (n_ticks, n_jobs) f64  MQ/coordinator availability in {0,1}:
                                 `mq_gate_curve` over
                                 `ChaosSpec.mq_down` windows ×
                                 `coordinator_gate_curve` over the
                                 ZK∩HDFS leader-loss overlap (`zk_down`
                                 / `hdfs_down` — leadership survives on
                                 either store, so only overlapping
                                 windows gate); source emission is
                                 multiplied by the gate
    ckage (n_ticks, n_jobs) f64  checkpoint age at tick start
                                 (`ckpt_age_curve`, tick-exclusive:
                                 a success at tick i lowers the age
                                 from tick i+1 on)
    rfac  (n_ticks, n_jobs) f64  traffic-rate factor
                                 (`core.chaos.traffic_curve`: per-job
                                 diurnal sinusoids from
                                 `ChaosSpec.diurnal` — phase-shifted
                                 by `rate_phase_s` — × flash-crowd
                                 trapezoids from `ChaosSpec.flash_at`,
                                 plus config-axis patterns composed by
                                 tuple concatenation exactly like
                                 brownout ramps); source emission is
                                 multiplied by the factor, so a
                                 constant-rate spec yields an exact
                                 all-ones curve and the ``×1.0`` path
                                 is bit-identical to traffic-free runs

All four gather per task through ``pa["job_of_task"]`` inside the
tick. Region-correlated failure bursts (`ChaosSpec.burst_at`) lower as
scheduled kills merged into the same kill scan — none of these events
consume rng draws, preserving the draw-for-draw replay contract.

Failover lowers four recovery modes per task (traced mode masks, so a
config grid can mix them row by row): ``none`` / ``region`` /
``single_task`` pay passive-restore cost — downtime =
``detect + restart + restore_base·bfac(t) + ckage(t)·replay_rate +
lazy_extra`` where ``lazy_extra`` is the lazy-load per-region ready
stagger (`streams.engine.lazy_ready_extra`) — while ``hot_standby``
pays ``detect + standby_switch + standby_staleness`` only (no
brownout/age/drop exposure; the standby assumes execution). The
brownout factor thus stretches both checkpoint attempt durations (in
the timeline build) and passive restores (in the tick), which is what
makes the replication-vs-checkpoint tradeoff surface
(`streams.chaos_sweep.replication_tradeoff`) come out of ONE
`sweep_configs` device pass.

Deployment-event + canary-mask lowering contract (drills)
---------------------------------------------------------
`UpgradeConfig` deployment drills (traced canary/rolling upgrades with
in-trace auto-rollback) lower through `streams.engine.lower_upgrade`
into 18 always-present params leaves (`_DRILL_KEYS`; inert zeros/infs
when no drill is configured, so drill and drill-free runs share one
trace). The contract:

* **Upgrades are in-trace only.** `ChaosSpec.upgrade_at` / the
  `UpgradeConfig` never reach the timeline builders: the kill,
  checkpoint and straggler draw streams are upgrade-free, so the
  draw-for-draw replay contract and a flat ``timeline_build_count``
  hold trivially across the drill axis.
* **Wave downtimes ride a separate state leaf** (``up_until``).
  Routing aliveness is ``(down_until <= t) & (up_until <= t)`` while
  checkpoint alive masks keep reading ``down_until`` alone — matching
  the host-side timelines. Upgrade/rollback restarts are *graceful*:
  queues are NOT zeroed (unlike crash failover), so an
  identical-config upgrade with zero wave downtime is a bit-exact
  no-op.
* **Canary config is a delta, not a branch.** Per-task activation
  ``act = up_cmask · (t >= up_start + up_down) · (t < rb_t +
  up_rstag)`` (a traced float mask) applies every canary override as
  ``base + act · d_*``: failover downtimes/modes, restore/replay
  surcharges, checkpoint-interval age scaling and selectivity. With
  ``act = 0`` each formula reduces to the exact base arithmetic
  (``×1.0`` / ``+0.0``), which is the drill-free parity guarantee.
* **Rollback is a traced scan-carried controller.** Per tick the
  controller EWMAs the mean-canary-minus-mean-stable backlog through
  one dot product (``queue @ up_wdelta``), arms at ``up_t0`` (first
  canary wave's end) and latches ``rb_t`` when the EWMA crosses
  ``up_thresh``; rollback waves then restart only canary tasks
  (``up_rstag`` is +inf off-canary) and ``act`` reverts — no rng, no
  host round-trip, vmappable across (mixes × configs × seeds).
* **Pallas caveat:** the fused kernel packs ``mode_single`` into its
  static phase tables once per lowering, so a canary
  ``d_mode_s``/``d_mode_r``/``d_mode_h`` delta cannot reach the
  kernel's in-phase drop mask; keep canary mode deltas zero under
  ``phase_mode="pallas"`` (selectivity/downtime/ckpt deltas and the
  controller live outside the kernel and are fully supported).

Rate-schedule + scale-event lowering contract (autoscaling)
-----------------------------------------------------------
`engine.AutoscaleConfig` in-trace DS2 autoscalers lower through
`streams.engine.lower_autoscale` into 21 always-present params leaves
(`AUTOSCALE_KEYS`; `engine.inert_autoscale_leaves` no-op values —
finite ``1e18`` sentinels instead of +inf wherever traced arithmetic
divides or subtracts — when no scaler is configured, so scaled and
unscaled runs share one trace). The contract:

* **Rate schedules ride ``xs``, scale events ride the state.** The
  diurnal/flash-crowd curves are pure per-tick tensors (``rfac``
  above, zero rng draws, timeline builders untouched —
  ``timeline_build_count`` stays flat across the traffic axis), while
  the controller's decisions mutate the ``speed`` state leaf inside
  the scan: per decision window (``as_int`` boundaries off ``as_t0``)
  it EWMAs per-task utilization from this tick's consumed records +
  backlog drain demand (DS2's true-rate estimate), proposes
  ``speed · rew / target`` clipped to ``[as_lo, as_hi]``, and fires
  only past hysteresis / cooldown / action-rate / breaker / thrash
  gates. Sources never rescale (``as_mask`` = 0 on source tasks).
* **Rescales are graceful and costed.** A firing task keeps its
  queue and pays ``as_down + as_move · |Δspeed|`` on the ``up_until``
  leaf — deploy downtime from `core.hotupdate.deploy_downtime` plus
  the `train/elastic.resize_move_seconds` state-move model — so
  rescale-during-recovery interactions (both horizons racing) are
  traced, not emulated.
* **Degradation is the breaker path.** ``failcnt`` counts failover
  hits within ``as_fw`` of a rescale; at ``as_bfail`` the breaker
  opens for ``as_brs`` seconds, freezing decisions and load-shedding
  via the ``as_shed`` selectivity factor (the `DS2Scaler` host
  breaker's traced twin). The thrash guard latches ``thrash_t`` when
  the leaky direction-flip counter crosses ``as_tflip``, freezing the
  controller for the rest of the run (autoscaler-vs-failover
  oscillation surfaces as a finite ``thrash_t`` metric).
* **Pallas caveat:** queue capacities (``qcap``) are packed into the
  fused kernel's static phase tables once per lowering, so in-trace
  rescales deliberately do NOT scale qcap in any mode (parity over
  convenience); ``rfac``, the shed factor and the whole controller
  live outside the kernel, so the pallas path needs no kernel-table
  changes. Host-side rollback of failed resizes stays in
  `core.autoscaler.DS2Scaler` — the traced twin models breaker +
  shed instead.

Compiled `run` functions are cached per *plan shape* (the `TensorPlan`
digest + region count — never float parameters, which are traced), so
two engines over same-shaped graphs share one trace; `get_cached_run_fns`
exposes the cache for tests. The state argument is donated, so each
call's arena buffers are reused in place.

Mega-arena sweeps: a `streams.engine.PackedArena` drops in for the
graph everywhere (`JaxStreamEngine`, `run_batch`, `run_mix_batch`,
`run_config_batch`) — K co-located jobs then scan as one arena with
per-job emitted/dropped segment sums (a static job index per op) and
per-job recovery attribution riding the shared-host chaos timeline.
`run_batch` pads the seed axis to the next power of two (retrace-free
batching: one trace per pow2 bucket, pad rows sliced off before
metrics) and can split the padded batch across local devices
(``devices=``) through the version-gated `repro.dist.sharding` shim —
`pmap` on jax 0.4.x, `jax.shard_map` on >= 0.6. `run_mix_batch` adds a
second vmap axis over job-mix configs (per-job source-rate
multipliers); `run_config_batch` adds a third over resiliency-config
grids (`FailoverConfig`/`CheckpointConfig` per grid row, optionally
per job), so a (mixes × configs × seeds) scenario cube runs as one
device call on one trace. `run_config_batch(devices=...)` splits the
grid's flat seed axis across local devices too
(`dist.sharding.sharded_grid_fn`, rows bit-identical to the
single-device grid), and checkpoint-bearing grids refit each config's
attempt schedule onto per-seed draw streams
(`core.chaos.build_grid_timelines`) instead of replaying a host
timeline per (config, seed). ``chaos=`` / ``base_spec=`` accept
per-job `ChaosSpec` lists for packed arenas (per-job kill rates /
straggler intensities drawn in each job's local host domain and lifted
onto the shared pool — `core.chaos.build_perjob_chaos_timeline`).

Chunked execution + shared trace-cache keying (sweep-as-a-service)
------------------------------------------------------------------
Every batch entry point decomposes into a *plan* (`SeedBatchPlan` /
`ConfigGridPlan`: lowering, per-config traced params, timeline-path
selection, trace-cache lookup — all seed-count-independent) plus
`prep_chunk(lo, hi)` / `run_chunk` over half-open seed slices, driven
by `run_chunks`' double-buffered pipeline: host timeline prep for
chunk k+1 runs on the caller thread while chunk k's device pass blocks
on a one-slot executor lane (XLA releases the GIL, so prep and compute
genuinely overlap). The chunking contract:

* **Bit-parity.** All per-seed grid state is seed-separable (one
  `_SeedStream` per seed, per-seed curves, no cross-seed reductions
  device-side), so the `concat_batches` of any chunk partition is
  bit-identical to the monolithic call — including ragged last chunks,
  which pad to their own pow2 bucket before slicing. Pinned by
  tests/test_sweep_service.py.
* **Build-count flatness.** Each seed's timelines are built exactly
  once across all chunks: the ckpt-grid path shares ONE
  `core.chaos.GridTimelineBuilder` (lazy per-seed streams) across
  chunks, and the no-ckpt/exotic paths touch each seed in exactly one
  chunk. `timeline_build_count()` matches the monolithic call.
* **Shared keying.** The six process-global caches (`_FN_CACHE`,
  `_SHARD_CACHE`, `_CFG_SHARD_CACHE`, `_MIX_CACHE`, `_CFG_CACHE`,
  `_CFG_MIX_CACHE`) key on ``(TickDesc, variant)`` where `TickDesc` =
  (`TensorPlan` digest — the bucket signature under compact/pallas —
  and region count) and the variant adds shard count /
  ``shared_kills`` / the resolved pallas kernel impl. Chunk size,
  seed count, request identity and every float are absent from the
  key, so concurrent requests over same-shaped plans hit ONE compiled
  trace; only the pow2 seed-bucket of the *padded* chunk retraces.
  All lookups funnel through `_cache_get` under one lock:
  `trace_cache_stats()` exposes process-wide hit/miss counters and
  `scoped_cache_stats` thread-local per-request ones (each plan
  records its own lookup in `cache_info`, surfaced per request by
  `launch.serve.SweepService`).
* **Boundary errors.** ``devices=`` + ``phase_mode="pallas"`` is
  rejected up front by `_check_pallas_devices` with the actionable
  rewrite (devices=None + seed_chunk=, or compact mode) instead of a
  deep `NotImplementedError`; `SweepService` performs that downgrade
  automatically and records the reason.

Everything runs in float64 (scoped `jax.experimental.enable_x64`, no
global config flip) to hold parity with the float64 numpy engine.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.chaos import (ChaosEngine, ChaosSpec, ChaosTimeline,
                              GridTimelineBuilder, brownout_curve,
                              build_chaos_timeline,
                              build_perjob_chaos_timeline, ckpt_age_curve,
                              coordinator_gate_curve, mq_gate_curve,
                              refit_failover, traffic_curve)
from repro.dist.sharding import (local_shard_count, sharded_grid_fn,
                                 sharded_seed_fn)
from repro.streams.engine import (AUTOSCALE_KEYS, AutoscaleConfig,
                                  CheckpointConfig, FailoverConfig,
                                  JobSlice, PackedArena, TensorPlan,
                                  UpgradeConfig, build_plan,
                                  lazy_ready_extra, lower_autoscale,
                                  lower_tensor_plan, lower_upgrade,
                                  per_task_failover)
from repro.streams.graph import LogicalGraph, PhysicalGraph, expand

try:  # scoped x64 — keeps the rest of the process on default f32
    from jax.experimental import enable_x64 as _enable_x64
except ImportError:  # pragma: no cover - old/new jax without the ctx
    import contextlib

    @contextlib.contextmanager
    def _enable_x64():
        jax.config.update("jax_enable_x64", True)
        yield


class EngineState(NamedTuple):
    """All mutable arena state of one scenario (see module docstring).

    ``emitted`` / ``dropped`` are per-job segment totals of shape
    ``(n_jobs,)`` — single-job engines carry ``(1,)`` vectors (same adds,
    same numerics as the former scalars); packed mega-arenas get the
    per-job breakdown for free from a static segment index per op.

    Deployment-drill leaves (inert zeros/infs without an upgrade):
    ``up_until`` is the graceful-wave downtime per task — kept SEPARATE
    from ``down_until`` so the pregenerated checkpoint draw streams
    (which only know crash failovers) replay draw-for-draw; ``rb_t`` is
    the scalar auto-rollback fire time (+inf = not fired); ``dacc`` the
    drill controller's EWMA of the canary-vs-stable queue delta.

    Autoscaler leaves (config-independent inits; the inert
    `engine.inert_autoscale_leaves` params freeze them exactly):
    ``rew`` per-task EWMA'd utilization, ``lact`` last-rescale time
    (-1e18 = never), ``dirp`` last rescale direction, ``failcnt`` /
    ``brk_until`` circuit-breaker state, ``used`` the leaky
    action-rate bucket, ``flip_acc`` the leaky direction-flip counter,
    ``thrash_t`` the thrash-latch fire time (+inf = not latched),
    ``nact`` rescale actions fired, ``rsec`` integrated
    resource-seconds (Σ speed · dt, the cube's cost axis)."""
    queue: jax.Array
    down_until: jax.Array
    speed: jax.Array
    ckpt_epoch: jax.Array
    emitted: jax.Array
    dropped: jax.Array
    up_until: jax.Array
    rb_t: jax.Array
    dacc: jax.Array
    rew: jax.Array
    lact: jax.Array
    dirp: jax.Array
    failcnt: jax.Array
    brk_until: jax.Array
    used: jax.Array
    flip_acc: jax.Array
    thrash_t: jax.Array
    nact: jax.Array
    rsec: jax.Array


class TickDesc(NamedTuple):
    """Static trace-cache key of a compiled tick: the tensor-plan digest
    plus the placement-level region count (a static `segment_max` size).
    Float parameters — including failover mode masks — are traced, so
    descs are mode- and config-independent."""
    tensor: TensorPlan
    n_regions: int


# ----------------------------------------------------------------------
# tensorized tick: constant number of segment passes per phase
# ----------------------------------------------------------------------
def _build_compact_run(desc: TickDesc):
    """Sparse-phase twin of `_build_run`: every arena-sized segment
    reduction of the dense tick becomes a row-table gather+reduce over
    just the phase's active entries (`engine.CompactPhase`), and all
    index/mask tables are *traced* parameters (`pa["edges"][fi]`), so
    the trace key is only the pow2 bucket signature — same-bucket plans
    share one compiled trace. Numerics are pinned to the dense tick:
    consumption stays arena-wide elementwise (bit-identical), rows
    preserve each segment's member order, and pads contribute exact
    +0.0 to sums and +inf to head-of-line minima."""
    tp, n_regions = desc.tensor, desc.n_regions
    n_ops, n_jobs = tp.n_ops, tp.n_jobs

    def rsum(vals, idx, mask):
        return (vals[idx] * mask).sum(-1)

    def rmin(vals, idx, mask):
        return jnp.where(mask > 0.5, vals[idx], jnp.inf).min(-1)

    def tick(pa, state: EngineState, x):
        t = x["t"]
        q = state.queue
        alive_f = ((state.down_until <= t)
                   & (state.up_until <= t)).astype(q.dtype)
        # canary-config activation: upgrade wave done, rollback wave (if
        # fired) not yet begun — inert leaves make this identically zero
        act = pa["up_cmask"] * ((t >= pa["up_start"] + pa["up_down"])
                                & (t < state.rb_t + pa["up_rstag"])
                                ).astype(q.dtype)
        free = jnp.maximum(pa["qcap"] - q, 0.0)
        # breaker-open load shed (graceful degradation): ×1.0 exactly
        # while every breaker is closed — the autoscale-free no-op
        shed_t = jnp.where(t < state.brk_until, pa["as_shed"], 1.0)
        sel_t = (pa["sel"][pa["op_of_task"]] + act * pa["d_sel"]) * shed_t
        ms_eff = pa["mode_single"] + act * pa["d_mode_s"]
        cap_t = pa["cap_base"] * state.speed * alive_f
        emitted, dropped = state.emitted, state.dropped
        produced = jnp.zeros_like(q)
        qps_acc = jnp.zeros((n_ops,), q.dtype)
        take_all = jnp.zeros_like(q)

        gate_t = x["gate"][pa["job_of_task"]]  # MQ source gate (0/1)
        rfac_t = x["rfac"][pa["job_of_task"]]  # traffic-rate factor
        for fi, ph in enumerate(tp.phases):
            eph = pa["edges"][fi]
            if ph.consumes:
                take = jnp.minimum(q, cap_t * eph["cons_mask"])
                q = q - take
                take_all = take_all + take
                src_emit = (pa["src_row"] * alive_f * eph["cons_mask"]
                            * gate_t * rfac_t)
                produced = produced + (src_emit + take * sel_t)
                if len(ph.e_jobs):
                    emitted = emitted.at[eph["e_jobs"]].add(
                        rsum(src_emit, eph["e_idx"], eph["e_mask"]))
                qps_acc = qps_acc.at[eph["q_ops"]].add(
                    rsum(take, eph["q_idx"], eph["q_mask"]))
            if not ph.D:
                continue
            dst = eph["dst_task"]
            edge_of = eph["edge_of"]
            alive_d = alive_f[dst]
            free_d = free[dst]
            # per-source-op slot totals — O(live src tasks)
            tot_slot = rsum(produced, eph["s_idx"], eph["s_mask"])
            tot_e = tot_slot[eph["slot_of_edge"]]
            tot_d = tot_e[edge_of]
            # forward: pointwise src task → dst task
            arr_fwd = produced[eph["fwd_src"]] * alive_d
            # rescale family: per-block rate over alive destinations
            if ph.B:
                prod_blk = rsum(produced, eph["bs_idx"], eph["bs_mask"])
                alive_blk = rsum(alive_d * eph["dst_in_blk"],
                                 eph["br_idx"], eph["br_mask"])
                has = alive_blk > 0.0
                rate_blk = jnp.where(
                    has, prod_blk / jnp.where(has, alive_blk, 1.0), 0.0)
                arr_blk = jnp.where(eph["dst_in_blk"] > 0.0,
                                    rate_blk[eph["blk_of"]] * alive_d,
                                    0.0)
            else:
                arr_blk = jnp.zeros_like(alive_d)
            # weakhash: group mass spread ∝ free capacity (fallback to
            # alive-uniform when a whole group is down)
            if ph.G:
                wh = eph["m_weakhash"] > 0.5
                grp_of = eph["grp_of"]
                cap_w = jnp.maximum(free_d, 1e-9) * alive_d
                alive_eps = alive_d + 1e-9
                capsum = rsum(jnp.where(wh, cap_w, 0.0), eph["gr_idx"],
                              eph["gr_mask"])
                capsum_fb = rsum(jnp.where(wh, alive_eps, 0.0),
                                 eph["gr_idx"], eph["gr_mask"])
                fall = capsum <= 0.0
                cap2 = jnp.where(fall[grp_of], alive_eps, cap_w) * alive_d
                capsum2 = jnp.where(fall, capsum_fb, capsum)
                val_wh = cap2 * eph["mass"] / capsum2[grp_of]
            else:
                val_wh = jnp.zeros_like(alive_d)
            # backlog: divert away from congested channels
            open_ = (free_d > pa["qcap"][dst] * 0.25).astype(q.dtype)
            val_bk = (jnp.maximum(free_d, 1e-9) * alive_d
                      * jnp.maximum(open_, 0.05))
            val_nrm = jnp.where(eph["m_weakhash"] > 0.5, val_wh,
                                jnp.where(eph["m_backlog"] > 0.5, val_bk,
                                          alive_d)) * eph["is_norm"]
            rs = rsum(val_nrm, eph["er_idx"], eph["er_mask"])
            ratio_e = jnp.where(rs > 0.0, tot_e / rs, 0.0)
            arr_nrm = val_nrm * ratio_e[edge_of]
            arriving = jnp.where(
                eph["m_fwd"] > 0.5, arr_fwd,
                jnp.where(eph["m_blk"] > 0.5, arr_blk,
                          jnp.where(eph["m_hash"] > 0.5,
                                    tot_d * eph["share"], arr_nrm)))
            dead_s = (alive_d <= 0.0) & (ms_eff[dst] > 0.5)
            dropped = dropped.at[eph["dj_jobs"]].add(
                rsum(jnp.where(dead_s, arriving, 0.0), eph["dj_idx"],
                     eph["dj_mask"]))
            arriving = jnp.where(dead_s, 0.0, arriving)
            # acceptance: head-of-line / per-block / adaptive credits
            live = arriving > 1e-9
            ratio = jnp.where(live,
                              free_d / jnp.maximum(arriving, 1e-300),
                              jnp.inf)
            lam_e = jnp.minimum(rmin(ratio, eph["er_idx"],
                                     eph["er_mask"]), 1.0)
            if ph.B:
                lam_b = jnp.minimum(rmin(ratio, eph["br_idx"],
                                         eph["br_mask"]), 1.0)
                acc_blk = arriving * lam_b[eph["blk_of"]]
            else:
                acc_blk = arriving
            accepted = jnp.where(
                eph["m_acc_static"] > 0.5, arriving * lam_e[edge_of],
                jnp.where(eph["m_acc_block"] > 0.5, acc_blk,
                          jnp.minimum(arriving, free_d)))
            # overflow re-queues uniformly at each source op (dense-style
            # broadcast through a small per-slot scatter)
            ovf_e = rsum(arriving - accepted, eph["er_idx"],
                         eph["er_mask"])
            ovf_slot = jax.ops.segment_sum(ovf_e, eph["slot_of_edge"],
                                           num_segments=len(ph.slot_ops))
            ovf_op = jnp.zeros((n_ops,), q.dtype).at[eph["slot_ops"]].add(
                ovf_slot)
            q = q + (ovf_op / pa["par_of_op"])[pa["op_of_task"]]
            q = q.at[dst].add(accepted)
            free = jnp.maximum(free.at[dst].add(-accepted), 0.0)

        return _finish_tick(pa, state, x, q, emitted, dropped,
                            qps_acc, n_regions, n_ops, act, take_all)

    def run(pa, state, xs):
        return lax.scan(lambda st, x: tick(pa, st, x), state, xs)

    return run


def _finish_tick(pa, state, x, q, emitted, dropped, qps_acc,
                 n_regions, n_ops, act, take_all):
    """Shared end-of-tick block of the dense and compact ticks: chaos
    host kills → failover (per-task mode masks + passive-restore
    surcharge from the external-event tensors), checkpoint attempt
    counter, per-op metric rows.

    The restore surcharge ``extra = restore_base * brownout(t) +
    ckpt_age(t) * replay_rate + lazy_extra`` rides the per-tick per-job
    event rows (``x["bfac"]`` / ``x["ckage"]``) gathered per task;
    hot-standby victims pay switch + staleness replay instead and never
    touch checkpoint storage. Zero vectors reduce to the historical
    region/single downtimes bit-for-bit."""
    t = x["t"]
    vict = x["kills"][pa["task_host"]]
    # active canary slices crash under the canary config: mode masks and
    # downtimes apply their ``act``-gated deltas (exact no-ops when
    # inert — adding act * 0.0 and comparing 0/1 masks against 0.5)
    ms_eff = pa["mode_single"] + act * pa["d_mode_s"]
    mr_eff = pa["mode_region"] + act * pa["d_mode_r"]
    mh_eff = pa["mode_hot"] + act * pa["d_mode_h"]
    hit_s = (vict > 0.0).astype(q.dtype) * (ms_eff > 0.5)
    reg_hit = jax.ops.segment_max(vict * (mr_eff > 0.5),
                                  pa["task_region"],
                                  num_segments=n_regions)
    hit_r = (reg_hit[pa["task_region"]] > 0.0).astype(q.dtype)
    hit_h = (vict > 0.0).astype(q.dtype) * (mh_eff > 0.5)
    extra = ((pa["restore_base"] + act * pa["d_restore"])
             * x["bfac"][pa["job_of_task"]]
             + x["ckage"][pa["job_of_task"]] * (1.0 + act * pa["d_ck"])
             * (pa["replay_rate"] + act * pa["d_replay"])
             + pa["lazy_extra"])
    until_s = t + (pa["detect"] + pa["restart_single"]
                   + act * pa["d_down_s"] + extra)
    until_r = t + (pa["detect"] + pa["restart_region"]
                   + act * pa["d_down_r"] + extra)
    until_h = t + (pa["detect"] + pa["standby_switch"]
                   + pa["standby_stale"] + act * pa["d_down_h"])
    down_until = jnp.where(hit_r > 0.0, until_r,
                           jnp.where(hit_s > 0.0, until_s,
                                     jnp.where(hit_h > 0.0, until_h,
                                               state.down_until)))
    hit_any = jnp.maximum(jnp.maximum(hit_r, hit_s), hit_h)
    q = jnp.where(hit_any > 0.0, 0.0, q)

    ckpt_epoch = state.ckpt_epoch + x["ckpt"].astype(jnp.int32)

    # drill controller + wave scheduler (same order as the numpy tick:
    # EWMA update → rollback decision on the UPDATED accumulator → wave
    # triggers on the UPDATED rollback time). up_rstag is +inf off the
    # canary slice, so a fired rollback never restarts stable tasks.
    delta = q @ pa["up_wdelta"]
    g = (t >= pa["up_t0"]).astype(q.dtype)
    dacc = state.dacc + g * pa["up_alpha"] * (delta - state.dacc)
    fire = ((t >= pa["up_t0"]) & (dacc > pa["up_thresh"])
            & jnp.isinf(state.rb_t))
    rb_t = jnp.where(fire, t + pa["dt"], state.rb_t)
    trig_up = ((t <= pa["up_start"])
               & (pa["up_start"] < t + pa["dt"]))
    up_until = jnp.maximum(
        state.up_until,
        jnp.where(trig_up, pa["up_start"] + pa["up_down"], 0.0))
    rb_start = rb_t + pa["up_rstag"]
    trig_rb = (t <= rb_start) & (rb_start < t + pa["dt"])
    up_until = jnp.maximum(
        up_until, jnp.where(trig_rb, rb_start + pa["up_down"], 0.0))

    # in-trace DS2 autoscaler (end-of-tick, AFTER kills/ckpt/drill —
    # same order as the numpy tick): utilization EWMA first, breaker
    # update on this tick's failover hits, then the decision reads the
    # UPDATED accumulator and UPDATED breaker. Inert autoscale leaves
    # make every update an exact arithmetic no-op.
    dt_ = pa["dt"]
    cap_now = pa["cap_base"] * state.speed
    need = ((take_all + q * (dt_ / pa["as_drain"]))
            / jnp.maximum(cap_now, 1e-9))
    rew = state.rew + pa["as_alpha"] * (need - state.rew)
    recent = (t - state.lact) <= pa["as_fw"]
    failev = (hit_any > 0.0) & recent
    crossed = (((t - state.lact) > pa["as_fw"])
               & ((t - dt_ - state.lact) <= pa["as_fw"]))
    failcnt = jnp.where(
        failev, state.failcnt + 1.0,
        jnp.where(crossed & (hit_any <= 0.0), 0.0, state.failcnt))
    brk_fire = failcnt >= pa["as_bfail"]
    brk_until = jnp.where(brk_fire, t + pa["as_brs"], state.brk_until)
    failcnt = jnp.where(brk_fire, 0.0, failcnt)
    boundary = (jnp.floor((t + dt_ - pa["as_t0"]) / pa["as_int"])
                > jnp.floor((t - pa["as_t0"]) / pa["as_int"]))
    want = jnp.clip(state.speed * rew / pa["as_tgt"],
                    pa["as_lo"], pa["as_hi"])
    rel = jnp.abs(want - state.speed) / jnp.maximum(state.speed, 1e-9)
    as_fire = (boundary & (pa["as_on"] > 0.0) & (pa["as_mask"] > 0.0)
               & (rel >= pa["as_hyst"])
               & ((t - state.lact) >= pa["as_cool"])
               & (t >= brk_until) & (state.used < pa["as_amax"])
               & jnp.isinf(state.thrash_t))
    fire_f = as_fire.astype(q.dtype)
    speed = jnp.where(as_fire, want, state.speed)
    lact = jnp.where(as_fire, t, state.lact)
    # graceful rescale: queues persist, the task pays deploy downtime +
    # state-move seconds on the up_until leaf
    downt = pa["as_down"] + pa["as_move"] * jnp.abs(want - state.speed)
    up_until = jnp.maximum(up_until,
                           jnp.where(as_fire, t + downt, 0.0))
    any_fire = (fire_f.sum() > 0.0).astype(q.dtype)
    used = state.used * pa["as_adec"] + any_fire
    dirn = jnp.sign(want - state.speed)
    flip = as_fire & (dirn * state.dirp < 0.0)
    dirp = jnp.where(as_fire, dirn, state.dirp)
    flip_acc = (state.flip_acc * pa["as_tdec"]
                + flip.astype(q.dtype).sum())
    # thrash latch: freezes the controller from the NEXT tick on (the
    # fire gate above read the PRE-latch thrash_t)
    thrash_t = jnp.where((flip_acc >= pa["as_tflip"])
                         & jnp.isinf(state.thrash_t),
                         t + dt_, state.thrash_t)
    nact = state.nact + fire_f.sum()
    rsec = state.rsec + speed.sum() * dt_

    backlog_row = jax.ops.segment_sum(q, pa["op_of_task"],
                                      num_segments=n_ops)
    qps_row = qps_acc / pa["dt"]
    lag = jnp.dot(backlog_row, pa["src_mask_ops"])
    new_state = EngineState(q, down_until, speed, ckpt_epoch,
                            emitted, dropped, up_until, rb_t, dacc,
                            rew, lact, dirp, failcnt, brk_until, used,
                            flip_acc, thrash_t, nact, rsec)
    return new_state, {"qps": qps_row, "backlog": backlog_row,
                       "lag": lag}


def _finish_tick_batched(pa, state, x, q, emitted, dropped, qps_acc,
                         n_regions, n_ops, act, take_all):
    """Seed-batched twin of `_finish_tick` for the native ``(S, ...)``
    pallas run: same math, with the task axis transposed to leading for
    the segment reductions (segment ops reduce over axis 0) and the
    drill scalars (``rb_t`` / ``dacc``) carrying the ``(S,)`` axis."""
    t = x["t"]
    vict = x["kills"][:, pa["task_host"]]
    ms_eff = pa["mode_single"] + act * pa["d_mode_s"]
    mr_eff = pa["mode_region"] + act * pa["d_mode_r"]
    mh_eff = pa["mode_hot"] + act * pa["d_mode_h"]
    hit_s = (vict > 0.0).astype(q.dtype) * (ms_eff > 0.5)
    reg_hit = jax.ops.segment_max((vict * (mr_eff > 0.5)).T,
                                  pa["task_region"],
                                  num_segments=n_regions)
    hit_r = (reg_hit[pa["task_region"]].T > 0.0).astype(q.dtype)
    hit_h = (vict > 0.0).astype(q.dtype) * (mh_eff > 0.5)
    extra = ((pa["restore_base"] + act * pa["d_restore"])
             * x["bfac"][:, pa["job_of_task"]]
             + x["ckage"][:, pa["job_of_task"]]
             * (1.0 + act * pa["d_ck"])
             * (pa["replay_rate"] + act * pa["d_replay"])
             + pa["lazy_extra"])
    until_s = t + (pa["detect"] + pa["restart_single"]
                   + act * pa["d_down_s"] + extra)
    until_r = t + (pa["detect"] + pa["restart_region"]
                   + act * pa["d_down_r"] + extra)
    until_h = t + (pa["detect"] + pa["standby_switch"]
                   + pa["standby_stale"] + act * pa["d_down_h"])
    down_until = jnp.where(hit_r > 0.0, until_r,
                           jnp.where(hit_s > 0.0, until_s,
                                     jnp.where(hit_h > 0.0, until_h,
                                               state.down_until)))
    hit_any = jnp.maximum(jnp.maximum(hit_r, hit_s), hit_h)
    q = jnp.where(hit_any > 0.0, 0.0, q)

    ckpt_epoch = state.ckpt_epoch + x["ckpt"].astype(jnp.int32)

    delta = q @ pa["up_wdelta"]                      # (S,)
    g = (t >= pa["up_t0"]).astype(q.dtype)
    dacc = state.dacc + g * pa["up_alpha"] * (delta - state.dacc)
    fire = ((t >= pa["up_t0"]) & (dacc > pa["up_thresh"])
            & jnp.isinf(state.rb_t))
    rb_t = jnp.where(fire, t + pa["dt"], state.rb_t)
    trig_up = ((t <= pa["up_start"])
               & (pa["up_start"] < t + pa["dt"]))
    up_until = jnp.maximum(
        state.up_until,
        jnp.where(trig_up, pa["up_start"] + pa["up_down"], 0.0))
    rb_start = rb_t[:, None] + pa["up_rstag"]        # (S, T)
    trig_rb = (t <= rb_start) & (rb_start < t + pa["dt"])
    up_until = jnp.maximum(
        up_until, jnp.where(trig_rb, rb_start + pa["up_down"], 0.0))

    # in-trace DS2 autoscaler — `_finish_tick`'s controller with the
    # scalars (`used` / `flip_acc` / `thrash_t` / `nact` / `rsec`)
    # carrying the (S,) axis and task reductions over axis -1
    dt_ = pa["dt"]
    cap_now = pa["cap_base"] * state.speed
    need = ((take_all + q * (dt_ / pa["as_drain"]))
            / jnp.maximum(cap_now, 1e-9))
    rew = state.rew + pa["as_alpha"] * (need - state.rew)
    recent = (t - state.lact) <= pa["as_fw"]
    failev = (hit_any > 0.0) & recent
    crossed = (((t - state.lact) > pa["as_fw"])
               & ((t - dt_ - state.lact) <= pa["as_fw"]))
    failcnt = jnp.where(
        failev, state.failcnt + 1.0,
        jnp.where(crossed & (hit_any <= 0.0), 0.0, state.failcnt))
    brk_fire = failcnt >= pa["as_bfail"]
    brk_until = jnp.where(brk_fire, t + pa["as_brs"], state.brk_until)
    failcnt = jnp.where(brk_fire, 0.0, failcnt)
    boundary = (jnp.floor((t + dt_ - pa["as_t0"]) / pa["as_int"])
                > jnp.floor((t - pa["as_t0"]) / pa["as_int"]))
    want = jnp.clip(state.speed * rew / pa["as_tgt"],
                    pa["as_lo"], pa["as_hi"])
    rel = jnp.abs(want - state.speed) / jnp.maximum(state.speed, 1e-9)
    as_fire = (boundary & (pa["as_on"] > 0.0) & (pa["as_mask"] > 0.0)
               & (rel >= pa["as_hyst"])
               & ((t - state.lact) >= pa["as_cool"])
               & (t >= brk_until)
               & (state.used[:, None] < pa["as_amax"])
               & jnp.isinf(state.thrash_t)[:, None])
    fire_f = as_fire.astype(q.dtype)
    speed = jnp.where(as_fire, want, state.speed)
    lact = jnp.where(as_fire, t, state.lact)
    downt = pa["as_down"] + pa["as_move"] * jnp.abs(want - state.speed)
    up_until = jnp.maximum(up_until,
                           jnp.where(as_fire, t + downt, 0.0))
    any_fire = (fire_f.sum(-1) > 0.0).astype(q.dtype)
    used = state.used * pa["as_adec"] + any_fire
    dirn = jnp.sign(want - state.speed)
    flip = as_fire & (dirn * state.dirp < 0.0)
    dirp = jnp.where(as_fire, dirn, state.dirp)
    flip_acc = (state.flip_acc * pa["as_tdec"]
                + flip.astype(q.dtype).sum(-1))
    thrash_t = jnp.where((flip_acc >= pa["as_tflip"])
                         & jnp.isinf(state.thrash_t),
                         t + dt_, state.thrash_t)
    nact = state.nact + fire_f.sum(-1)
    rsec = state.rsec + speed.sum(-1) * dt_

    backlog_row = jax.ops.segment_sum(q.T, pa["op_of_task"],
                                      num_segments=n_ops).T
    qps_row = qps_acc / pa["dt"]
    lag = backlog_row @ pa["src_mask_ops"]
    new_state = EngineState(q, down_until, speed, ckpt_epoch,
                            emitted, dropped, up_until, rb_t, dacc,
                            rew, lact, dirp, failcnt, brk_until, used,
                            flip_acc, thrash_t, nact, rsec)
    return new_state, {"qps": qps_row, "backlog": backlog_row,
                       "lag": lag}


def _build_pallas_run(desc: TickDesc, impl: str | None = None):
    """Fused-kernel twin of `_build_compact_run`: the run is NATIVELY
    seed-batched — every `EngineState` leaf carries a leading ``(S,)``
    scenario axis, ``xs["kills"]`` arrives ``(S, T, H)``, and there is
    no outer seed vmap — and each routing phase executes as ONE fused
    `repro.kernels.tick_phase` launch (gather → normalize →
    head-of-line row-min → group/block row-sum → accept, sharing VMEM
    scratch across the stages) with the seed axis as the Pallas grid
    dimension. Everything around the phase core (consumption, per-job
    emit/drop segments, overflow requeue, deposits, `_finish_tick`) is
    the compact tick's math batched over the leading axis, so
    pallas == compact == dense at 1e-12.

    ``impl`` resolves through `repro.kernels.common.resolve_impl`:
    compiled Pallas on TPU, the jnp reference on CPU by default,
    ``REPRO_KERNEL_IMPL=interpret`` forces the kernel through the
    Pallas interpreter (CI's pallas smoke). The per-phase kernel tables
    are packed ONCE per run, outside the `lax.scan` (dst-gathered
    qcap/mode rows included), so the scan body carries no re-packing.
    Returned ``ys`` rows are swapped back to the vmapped ``(S, T, ·)``
    layout the batch entry points expect."""
    from repro.kernels.tick_phase import pack_phase_tables, tick_phase

    tp, n_regions = desc.tensor, desc.n_regions
    n_ops, n_jobs = tp.n_ops, tp.n_jobs

    def rsum(vals, idx, mask):
        return (vals[:, idx] * mask).sum(-1)

    def tick(pa, aux, state: EngineState, x):
        t = x["t"]
        q = state.queue
        alive_f = ((state.down_until <= t)
                   & (state.up_until <= t)).astype(q.dtype)
        # drill activation / selectivity computed OUTSIDE the kernel —
        # the fused phase core only sees alive_f/free/produced. The one
        # pallas drill limitation: the kernel's drop mask reads the
        # mode_single row PACKED once outside the scan, so a canary
        # d_mode_s flip cannot reach it — keep canary failover modes
        # equal to base modes (d_mode_s == 0) under the pallas path.
        act = pa["up_cmask"] * ((t >= pa["up_start"] + pa["up_down"])
                                & (t < state.rb_t[:, None]
                                   + pa["up_rstag"])).astype(q.dtype)
        free = jnp.maximum(pa["qcap"] - q, 0.0)
        shed_t = jnp.where(t < state.brk_until, pa["as_shed"], 1.0)
        sel_t = (pa["sel"][pa["op_of_task"]] + act * pa["d_sel"]) * shed_t
        cap_t = pa["cap_base"] * state.speed * alive_f
        emitted, dropped = state.emitted, state.dropped
        produced = jnp.zeros_like(q)
        qps_acc = jnp.zeros((q.shape[0], n_ops), q.dtype)
        take_all = jnp.zeros_like(q)

        gate_t = x["gate"][:, pa["job_of_task"]]  # MQ source gate (0/1)
        rfac_t = x["rfac"][:, pa["job_of_task"]]  # traffic-rate factor
        for fi, ph in enumerate(tp.phases):
            eph = pa["edges"][fi]
            if ph.consumes:
                take = jnp.minimum(q, cap_t * eph["cons_mask"])
                q = q - take
                take_all = take_all + take
                src_emit = (pa["src_row"] * alive_f * eph["cons_mask"]
                            * gate_t * rfac_t)
                produced = produced + (src_emit + take * sel_t)
                if len(ph.e_jobs):
                    emitted = emitted.at[:, eph["e_jobs"]].add(
                        rsum(src_emit, eph["e_idx"], eph["e_mask"]))
                qps_acc = qps_acc.at[:, eph["q_ops"]].add(
                    rsum(take, eph["q_idx"], eph["q_mask"]))
            if not ph.D:
                continue
            # the entire routing phase: ONE fused kernel launch
            accepted, drop_d, ovf_e = tick_phase(
                produced, alive_f, free, aux[fi],
                has_blk=ph.B > 0, has_grp=ph.G > 0, impl=impl)
            dropped = dropped.at[:, eph["dj_jobs"]].add(
                rsum(drop_d, eph["dj_idx"], eph["dj_mask"]))
            ovf_slot = jax.ops.segment_sum(
                ovf_e.T, eph["slot_of_edge"],
                num_segments=len(ph.slot_ops)).T
            ovf_op = jnp.zeros((q.shape[0], n_ops),
                               q.dtype).at[:, eph["slot_ops"]].add(
                                   ovf_slot)
            q = q + (ovf_op / pa["par_of_op"])[:, pa["op_of_task"]]
            dst = eph["dst_task"]
            q = q.at[:, dst].add(accepted)
            free = jnp.maximum(free.at[:, dst].add(-accepted), 0.0)

        return _finish_tick_batched(pa, state, x, q, emitted, dropped,
                                    qps_acc, n_regions, n_ops, act,
                                    take_all)

    def run(pa, state, xs):
        aux = [pack_phase_tables(pa["edges"][fi], pa["qcap"],
                                 pa["mode_single"]) if ph.D else None
               for fi, ph in enumerate(tp.phases)]
        xs_t = dict(xs, **{k: jnp.swapaxes(xs[k], 0, 1)
                           for k in ("kills", "bfac", "gate", "ckage",
                                     "rfac")})
        final, ys = lax.scan(lambda st, x: tick(pa, aux, st, x), state,
                             xs_t)
        return final, {k: jnp.swapaxes(v, 0, 1) for k, v in ys.items()}

    return run


def _build_run(desc: TickDesc):
    if desc.tensor.mode == "pallas":
        return _build_pallas_run(desc)
    if desc.tensor.mode == "compact":
        return _build_compact_run(desc)
    tp, n_regions = desc.tensor, desc.n_regions
    n_ops, n_jobs = tp.n_ops, tp.n_jobs
    op_of_task = tp.op_of_task
    job_of_task = tp.job_of_task
    is_src = tp.is_src_task
    par_of_op = tp.par_of_op
    seg = jax.ops.segment_sum

    def tick(pa, state: EngineState, x):
        t = x["t"]
        q = state.queue
        alive_f = ((state.down_until <= t)
                   & (state.up_until <= t)).astype(q.dtype)
        act = pa["up_cmask"] * ((t >= pa["up_start"] + pa["up_down"])
                                & (t < state.rb_t + pa["up_rstag"])
                                ).astype(q.dtype)
        free = jnp.maximum(pa["qcap"] - q, 0.0)
        shed_t = jnp.where(t < state.brk_until, pa["as_shed"], 1.0)
        sel_t = (pa["sel"][op_of_task] + act * pa["d_sel"]) * shed_t
        ms_eff = pa["mode_single"] + act * pa["d_mode_s"]
        cap_t = pa["cap_base"] * state.speed * alive_f
        emitted, dropped = state.emitted, state.dropped
        produced = jnp.zeros_like(q)
        qps_acc = jnp.zeros((n_ops,), q.dtype)
        take_all = jnp.zeros_like(q)

        gate_t = x["gate"][job_of_task]  # MQ source gate (0/1)
        rfac_t = x["rfac"][job_of_task]  # traffic-rate factor
        for fi, ph in enumerate(tp.phases):
            if ph.consumes:
                take = jnp.minimum(q, cap_t * ph.cons_mask)
                q = q - take
                take_all = take_all + take
                src_emit = (pa["src_row"] * alive_f * ph.cons_mask * is_src
                            * gate_t * rfac_t)
                produced = produced + (src_emit + take * sel_t)
                emitted = emitted + seg(src_emit, job_of_task,
                                        num_segments=n_jobs)
                qps_acc = qps_acc + seg(take, op_of_task,
                                        num_segments=n_ops)
            if not ph.D:
                continue
            eph = pa["edges"][fi]
            dst = ph.dst_task
            alive_d = alive_f[dst]
            free_d = free[dst]
            tot_op = seg(produced, op_of_task, num_segments=n_ops)
            tot_e = tot_op[ph.src_op_of_edge]
            tot_d = tot_e[ph.edge_of]
            # forward: pointwise src task → dst task
            arr_fwd = produced[ph.fwd_src] * alive_d
            # rescale family: per-block rate = block production over the
            # block's alive destinations
            prod_blk = seg(produced[ph.bsrc_task], ph.bsrc_blk,
                           num_segments=ph.B + 1)
            alive_blk = seg(alive_d * ph.dst_in_blk, ph.blk_of,
                            num_segments=ph.B + 1)
            has = alive_blk > 0.0
            rate_blk = jnp.where(has,
                                 prod_blk / jnp.where(has, alive_blk, 1.0),
                                 0.0)
            arr_blk = jnp.where(ph.dst_in_blk > 0.0,
                                rate_blk[ph.blk_of] * alive_d, 0.0)
            # weakhash: key-group mass spread ∝ free capacity; groups with
            # zero capacity fall back to alive-uniform spread
            cap_w = jnp.maximum(free_d, 1e-9) * alive_d
            alive_eps = alive_d + 1e-9
            capsum = seg(jnp.where(ph.is_weakhash, cap_w, 0.0), ph.grp_of,
                         num_segments=ph.G + 1)
            capsum_fb = seg(jnp.where(ph.is_weakhash, alive_eps, 0.0),
                            ph.grp_of, num_segments=ph.G + 1)
            fall = capsum <= 0.0
            cap2 = jnp.where(fall[ph.grp_of], alive_eps, cap_w) * alive_d
            capsum2 = jnp.where(fall, capsum_fb, capsum)
            val_wh = cap2 * eph["mass"] / capsum2[ph.grp_of]
            # backlog: divert away from congested channels
            open_ = (free_d > pa["qcap"][dst] * 0.25).astype(q.dtype)
            val_bk = (jnp.maximum(free_d, 1e-9) * alive_d
                      * jnp.maximum(open_, 0.05))
            # normalized all-to-all family (rebalance/weakhash/backlog):
            # identical weight rows → scale one row to the edge total
            val_nrm = jnp.where(ph.is_weakhash, val_wh,
                                jnp.where(ph.is_backlog, val_bk,
                                          alive_d)) * ph.is_norm
            rs = seg(val_nrm, ph.edge_of, num_segments=ph.n_edges)
            ratio_e = jnp.where(rs > 0.0, tot_e / rs, 0.0)
            arr_nrm = val_nrm * ratio_e[ph.edge_of]
            arriving = jnp.where(
                ph.is_fwd, arr_fwd,
                jnp.where(ph.is_blk, arr_blk,
                          jnp.where(ph.is_hash, tot_d * eph["share"],
                                    arr_nrm)))
            # records routed to a dead single_task-mode task drop
            # (γ=partial); edges never cross jobs, so the dst job segment
            # owns the drop
            dead_s = (alive_d <= 0.0) & (ms_eff[dst] > 0.5)
            dropped = dropped + seg(jnp.where(dead_s, arriving, 0.0),
                                    ph.job_of_entry, num_segments=n_jobs)
            arriving = jnp.where(dead_s, 0.0, arriving)
            # acceptance: head-of-line (per edge), per block
            # (group_rescale), or adaptive credits (weakhash/backlog)
            live = arriving > 1e-9
            ratio = jnp.where(live,
                              free_d / jnp.maximum(arriving, 1e-300),
                              jnp.inf)
            lam_e = jnp.minimum(
                jax.ops.segment_min(ratio, ph.edge_of,
                                    num_segments=ph.n_edges), 1.0)
            lam_b = jnp.minimum(
                jax.ops.segment_min(ratio, ph.blk_of,
                                    num_segments=ph.B + 1), 1.0)
            accepted = jnp.where(
                ph.acc_static, arriving * lam_e[ph.edge_of],
                jnp.where(ph.acc_block, arriving * lam_b[ph.blk_of],
                          jnp.minimum(arriving, free_d)))
            # overflow re-queues uniformly at the source op
            ovf_e = seg(arriving - accepted, ph.edge_of,
                        num_segments=ph.n_edges)
            ovf_op = seg(ovf_e, ph.src_op_of_edge, num_segments=n_ops)
            q = q + (ovf_op / par_of_op)[op_of_task]
            q = q.at[dst].add(accepted)
            free = jnp.maximum(free.at[dst].add(-accepted), 0.0)

        # pregenerated chaos host kills → failover, ckpt counter, metric
        # rows (shared with the compact tick)
        return _finish_tick(pa, state, x, q, emitted, dropped,
                            qps_acc, n_regions, n_ops, act, take_all)

    def run(pa, state, xs):
        return lax.scan(lambda st, x: tick(pa, st, x), state, xs)

    return run


# ----------------------------------------------------------------------
# legacy unrolled tick (pre-tensorized; benchmark baseline ONLY)
# ----------------------------------------------------------------------
class _OpDesc(NamedTuple):
    lo: int
    hi: int
    is_source: bool


class _EdgeDesc(NamedTuple):
    kind: str
    static: bool
    src_op: int
    src_par: int
    dst_lo: int
    dst_hi: int
    n_blocks: int
    n_groups: int
    any_unblocked: bool


def _route(ed: _EdgeDesc, ea: dict, produced, free_down, alive_d):
    kind = ed.kind
    if kind == "forward":
        return produced * alive_d
    if kind in ("rescale", "group_rescale"):
        prod_blk = jax.ops.segment_sum(produced, ea["blk_of_src"],
                                       num_segments=ed.n_blocks)
        alive_blk = jax.ops.segment_sum(alive_d * ea["dst_in_blk"],
                                        ea["blk_idx"],
                                        num_segments=ed.n_blocks)
        has = alive_blk > 0.0
        rate_blk = jnp.where(has, prod_blk / jnp.where(has, alive_blk, 1.0),
                             0.0)
        arriving = rate_blk[ea["blk_idx"]] * alive_d
        if ed.any_unblocked:
            arriving = jnp.where(ea["dst_in_blk"] > 0.0, arriving, 0.0)
        return arriving
    total = produced.sum()
    if kind == "rebalance":
        val = alive_d
    elif kind == "hash":
        return total * ea["share"]
    elif kind == "weakhash":
        cap = jnp.maximum(free_down, 1e-9) * alive_d
        capsum = jax.ops.segment_sum(cap, ea["grp_of_dst"],
                                     num_segments=ed.n_groups)
        alive_eps = alive_d + 1e-9
        capsum_fb = jax.ops.segment_sum(alive_eps, ea["grp_of_dst"],
                                        num_segments=ed.n_groups)
        fall = capsum <= 0.0
        cap = jnp.where(fall[ea["grp_of_dst"]], alive_eps, cap) * alive_d
        capsum = jnp.where(fall, capsum_fb, capsum)
        val = cap * ea["mass_of_dst"] / capsum[ea["grp_of_dst"]]
    elif kind == "backlog":
        open_ = (free_down > ea["dst_qcap"] * 0.25).astype(alive_d.dtype)
        val = jnp.maximum(free_down, 1e-9) * alive_d * jnp.maximum(open_,
                                                                   0.05)
    else:
        raise ValueError(kind)
    rs = val.sum()
    return jnp.where(rs > 0.0, val * (total / rs), jnp.zeros_like(val))


def _hol_ratio(arriving, room):
    live = arriving > 1e-9
    return jnp.where(live, room / jnp.maximum(arriving, 1e-300), jnp.inf)


def _accept(ed: _EdgeDesc, ea: dict, arriving, room):
    if ed.static:
        lam = jnp.minimum(_hol_ratio(arriving, room).min(), 1.0)
        return arriving * lam
    if ed.kind == "group_rescale":
        ratio = _hol_ratio(arriving, room)
        lam_g = jnp.minimum(
            jax.ops.segment_min(ratio, ea["blk_idx"],
                                num_segments=ed.n_blocks), 1.0)
        return arriving * lam_g[ea["blk_idx"]]
    return jnp.minimum(arriving, room)


def build_unrolled_run(legacy_desc):
    """The pre-tensorized tick: one Python-level loop over ops and edges
    per tick, `.at[sl]` scatter per op, one `_route`/`_accept` call per
    edge — trace size O(ops + edges). Kept verbatim as the old-vs-new
    baseline for benchmarks/bench_compile.py; the production path is
    `_build_run`. Consumes `_Lowered.legacy()` descriptors."""
    (op_descs, edge_descs, edges_of_op, src_cols, n_tasks, n_hosts,
     n_regions, failover_mode, job_of_op, n_jobs) = legacy_desc
    single_task = failover_mode == "single_task"

    def tick(pa, state: EngineState, x):
        t = x["t"]
        q = state.queue
        alive_f = (state.down_until <= t).astype(q.dtype)
        free = jnp.maximum(pa["qcap"] - q, 0.0)
        emitted, dropped = state.emitted, state.dropped
        qps_cols = []
        backlog_zero = jnp.zeros((), q.dtype)

        for oi, od in enumerate(op_descs):
            sl = slice(od.lo, od.hi)
            if od.is_source:
                produced = pa["src_row"][sl] * alive_f[sl]
                emitted = emitted.at[job_of_op[oi]].add(produced.sum())
                qps_cols.append(backlog_zero)
            else:
                cap = pa["cap_base"][sl] * state.speed[sl] * alive_f[sl]
                take = jnp.minimum(q[sl], cap)
                q = q.at[sl].add(-take)
                produced = take * pa["sel"][oi]
                qps_cols.append(take.sum() / pa["dt"])
            for ei in edges_of_op[oi]:
                ed, ea = edge_descs[ei], pa["edges"][ei]
                dsl = slice(ed.dst_lo, ed.dst_hi)
                arriving = _route(ed, ea, produced, free[dsl], alive_f[dsl])
                if single_task:
                    dead = alive_f[dsl] <= 0.0
                    dropped = dropped.at[job_of_op[oi]].add(
                        jnp.where(dead, arriving, 0.0).sum())
                    arriving = jnp.where(dead, 0.0, arriving)
                accepted = _accept(ed, ea, arriving, free[dsl])
                overflow = (arriving - accepted).sum()
                q = q.at[sl].add(overflow / max(ed.src_par, 1))
                q = q.at[dsl].add(accepted)
                free = free.at[dsl].set(
                    jnp.maximum(free[dsl] - accepted, 0.0))

        down_until = state.down_until
        if failover_mode != "none":
            vict = x["kills"][pa["task_host"]]
            if failover_mode == "single_task":
                hit = vict > 0.0
                until = t + pa["detect"] + pa["restart_single"]
            else:
                reg_hit = jax.ops.segment_max(vict, pa["task_region"],
                                              num_segments=n_regions)
                hit = reg_hit[pa["task_region"]] > 0.0
                until = t + pa["detect"] + pa["restart_region"]
            down_until = jnp.where(hit, until, down_until)
            q = jnp.where(hit, 0.0, q)

        ckpt_epoch = state.ckpt_epoch + x["ckpt"].astype(jnp.int32)

        backlog_row = jnp.stack([q[od.lo:od.hi].sum() for od in op_descs])
        qps_row = jnp.stack(qps_cols)
        lag = jnp.stack([backlog_row[j] for j in src_cols]).sum()
        # legacy baseline predates deployment drills and the in-trace
        # autoscaler: pass those leaves through untouched
        new_state = EngineState(q, down_until, state.speed, ckpt_epoch,
                                emitted, dropped, state.up_until,
                                state.rb_t, state.dacc, state.rew,
                                state.lact, state.dirp, state.failcnt,
                                state.brk_until, state.used,
                                state.flip_acc, state.thrash_t,
                                state.nact, state.rsec)
        return new_state, {"qps": qps_row, "backlog": backlog_row,
                           "lag": lag}

    def run(pa, state, xs):
        return lax.scan(lambda st, x: tick(pa, st, x), state, xs)

    return run


# ----------------------------------------------------------------------
# per-plan-shape trace caches
# ----------------------------------------------------------------------
_FN_CACHE: dict = {}
_SHARD_CACHE: dict = {}
_CFG_SHARD_CACHE: dict = {}
_MIX_CACHE: dict = {}
_CFG_CACHE: dict = {}
_CFG_MIX_CACHE: dict = {}

# process-global trace-cache accounting: every cache getter goes through
# `_cache_get` under one lock, so concurrent sweep requests (the
# SweepService worker threads) share the compiled-fn caches race-free
# and hit/miss counts are exact. A "hit" means a request reused a fn
# another request (or an earlier call) already built — the
# one-trace-across-requests property tests assert on top of these.
_CACHE_LOCK = threading.RLock()
_TRACE_STATS = {"hits": 0, "misses": 0}
_TLS = threading.local()


def _cache_get(cache: dict, key, build):
    """Thread-safe get-or-build with hit/miss accounting (global plus
    the calling thread's scoped counter — see `scoped_cache_stats`)."""
    with _CACHE_LOCK:
        hit = key in cache
        _TRACE_STATS["hits" if hit else "misses"] += 1
        scoped = getattr(_TLS, "counts", None)
        if scoped is not None:
            scoped["hits" if hit else "misses"] += 1
        if not hit:
            cache[key] = build()
        return cache[key]


def trace_cache_stats() -> dict:
    """Process-global jit-fn cache hit/miss counters (cumulative)."""
    with _CACHE_LOCK:
        return dict(_TRACE_STATS)


class scoped_cache_stats:
    """Context manager capturing this thread's cache hits/misses —
    per-request attribution for the sweep service (global deltas are
    racy under concurrent workers)."""

    def __enter__(self):
        self.prev = getattr(_TLS, "counts", None)
        _TLS.counts = {"hits": 0, "misses": 0}
        return _TLS.counts

    def __exit__(self, *exc):
        self.counts = _TLS.counts
        _TLS.counts = self.prev
        if self.prev is not None:    # nested scopes roll up to parents
            self.prev["hits"] += self.counts["hits"]
            self.prev["misses"] += self.counts["misses"]
        return False

_XS_AXES = {"t": None, "kills": 0, "ckpt": None,
            "bfac": 0, "gate": 0, "ckage": 0, "rfac": 0}

#: the 18 traced deployment-drill leaves (see `engine.lower_upgrade`):
#: per-task canary mask / wave starts / rollback staggers / controller
#: weights / canary-minus-base config deltas, plus four drill scalars
_DRILL_KEYS = ("up_cmask", "up_start", "up_rstag", "up_wdelta",
               "d_down_s", "d_down_r", "d_down_h",
               "d_mode_s", "d_mode_r", "d_mode_h",
               "d_restore", "d_replay", "d_sel", "d_ck",
               "up_t0", "up_down", "up_thresh", "up_alpha")

# job-mix vmap axis: only the per-task source emission row varies with a
# job mix (service capacity / selectivity are per-job constants the mix
# leaves alone); everything else is broadcast
_PA_MIX_AXES = {"qcap": None, "src_row": 0, "cap_base": None, "sel": None,
                "dt": None, "task_host": None, "task_region": None,
                "detect": None, "restart_region": None,
                "restart_single": None, "mode_single": None,
                "mode_region": None, "mode_hot": None,
                "standby_switch": None, "standby_stale": None,
                "restore_base": None, "replay_rate": None,
                "lazy_extra": None, "job_of_task": None,
                "op_of_task": None,
                "par_of_op": None, "src_mask_ops": None, "edges": None,
                **dict.fromkeys(_DRILL_KEYS, None),
                **dict.fromkeys(AUTOSCALE_KEYS, None)}

# resiliency-config vmap axis: the traced failover/queue/selectivity
# leaves vary per grid row (deployment-drill leaves included — upgrade
# policy is part of the config); placement and routing constants are
# broadcast
_PA_CFG_AXES = {"qcap": 0, "src_row": None, "cap_base": None, "sel": 0,
                "dt": None, "task_host": None, "task_region": None,
                "detect": 0, "restart_region": 0, "restart_single": 0,
                "mode_single": 0, "mode_region": 0, "mode_hot": 0,
                "standby_switch": 0, "standby_stale": 0,
                "restore_base": 0, "replay_rate": 0, "lazy_extra": 0,
                "job_of_task": None, "op_of_task": None,
                "par_of_op": None, "src_mask_ops": None, "edges": None,
                **dict.fromkeys(_DRILL_KEYS, 0),
                **dict.fromkeys(AUTOSCALE_KEYS, 0)}


def _tick_impl() -> str:
    """Resolved fused-kernel impl for pallas-mode traces. It is part of
    every pallas cache key: flipping ``REPRO_KERNEL_IMPL`` changes the
    lowering (compiled kernel / interpreter / jnp reference), so a
    cached trace must never outlive the impl it was built with."""
    from repro.kernels.common import resolve_impl
    return resolve_impl(None)


def _lift_single(run_batched):
    """Single-seed façade over a natively seed-batched run: expand every
    state leaf (and the kill tensor) to a width-1 batch, strip the axis
    from the results — same call contract as the dense/compact single
    fns."""
    def run1(pa, state, xs):
        st = EngineState(*(jnp.asarray(l)[None]
                           for l in state))
        xs1 = dict(xs, **{k: jnp.asarray(xs[k])[None]
                          for k in ("kills", "bfac", "gate", "ckage",
                                    "rfac")})
        final, ys = run_batched(pa, st, xs1)
        return (EngineState(*(l[0] for l in final)),
                {k: v[0] for k, v in ys.items()})
    return run1


def get_cached_run_fns(desc: TickDesc):
    """(jitted run, jitted vmapped run) for a static plan descriptor.

    One entry — hence one trace per call signature — per plan *shape*;
    float parameters (rates, selectivities, restart times, queue caps,
    failover mode masks, …) are traced arguments, so sweeping them never
    re-traces. The state argument is donated: arena state buffers are
    consumed in place every call.

    Pallas-mode descs key on (desc, resolved kernel impl) and return
    (single-seed façade, the native seed-batched run) — the batch fn has
    the exact layout of the vmapped dense/compact one."""
    if desc.tensor.mode == "pallas":
        impl = _tick_impl()

        def _build_pl():
            runb = _build_pallas_run(desc, impl)
            return (jax.jit(_lift_single(runb)),
                    jax.jit(runb, donate_argnums=(1,)))
        return _cache_get(_FN_CACHE, (desc, impl), _build_pl)

    def _build():
        run = _build_run(desc)
        return (jax.jit(run, donate_argnums=(1,)),
                jax.jit(jax.vmap(run, in_axes=(None, 0, _XS_AXES)),
                        donate_argnums=(1,)))
    return _cache_get(_FN_CACHE, desc, _build)


def get_sharded_run_fn(desc: TickDesc, n_shards: int):
    """Device-sharded batch run fn (flat seed axis, a multiple of
    `n_shards`) — `pmap` on jax 0.4.x, `jax.shard_map` on >= 0.6 via the
    version-gated `repro.dist.sharding` shim. Cached per (plan shape,
    shard count)."""
    if desc.tensor.mode == "pallas":
        raise NotImplementedError(
            "devices= sharding is not wired for the pallas phase mode "
            "(the native seed-batched run owns the seed axis); run "
            "unsharded or use phase_mode='compact'")
    return _cache_get(
        _SHARD_CACHE, (desc, n_shards),
        lambda: sharded_seed_fn(_build_run(desc), xs_axes=_XS_AXES,
                                n_shards=n_shards))


def get_cached_mix_fn(desc: TickDesc):
    """Doubly-vmapped run fn: outer axis over job-mix configs (per-task
    source-rate rows), inner axis over chaos seeds — one trace sweeps an
    (M, S) grid of mix × scenario in a single device call."""
    if desc.tensor.mode == "pallas":
        # the native run already owns the seed axis: ONE vmap level
        # (over mixes) instead of two
        impl = _tick_impl()
        return _cache_get(
            _MIX_CACHE, (desc, impl),
            lambda: jax.jit(jax.vmap(_build_pallas_run(desc, impl),
                                     in_axes=(_PA_MIX_AXES, None, None))))
    return _cache_get(
        _MIX_CACHE, desc,
        lambda: jax.jit(
            jax.vmap(jax.vmap(_build_run(desc),
                              in_axes=(None, 0, _XS_AXES)),
                     in_axes=(_PA_MIX_AXES, None, None))))


def _cfg_xs_axes(shared_kills: bool) -> dict:
    # checkpoint-free grids share one (S, T, H) kill tensor across every
    # config (kill draws are failover-independent), so the config axis
    # broadcasts it instead of materializing C copies on device;
    # ckpt-bearing grids carry genuinely per-config kills (axis 0).
    # bfac/ckage always carry the config axis (config brownout ramps
    # compose into the factor; ckpt cadence sets the age curve); the MQ
    # gate is seed-only and broadcasts across configs; rfac carries the
    # config axis (config traffic patterns compose into the rate curve).
    return {"t": None, "kills": None if shared_kills else 0, "ckpt": 0,
            "bfac": 0, "gate": None, "ckage": 0, "rfac": 0}


def get_cached_config_fn(desc: TickDesc, shared_kills: bool = False):
    """Doubly-vmapped run fn for resiliency-config grids: outer axis over
    configs (per-task detect/restart/mode/qcap/sel leaves + per-config
    ckpt schedules), inner axis over chaos seeds — a (C, S) grid of
    config × scenario in one device call, one trace per grid shape.
    `shared_kills` selects the broadcast-kills variant (see
    `_cfg_xs_axes`)."""
    if desc.tensor.mode == "pallas":
        impl = _tick_impl()

        def _build_pl():
            # seed axis is native; the config vmap broadcasts the
            # (S, ...) state and rides the same xs layout (the pallas
            # run reads kills as (S, T, H), so the per-config kills
            # axis is the same axis 0 the vmapped path uses)
            return jax.jit(
                jax.vmap(_build_pallas_run(desc, impl),
                         in_axes=(_PA_CFG_AXES, None,
                                  _cfg_xs_axes(shared_kills))))
        return _cache_get(_CFG_CACHE, (desc, shared_kills, impl),
                          _build_pl)
    return _cache_get(
        _CFG_CACHE, (desc, shared_kills),
        lambda: jax.jit(
            jax.vmap(jax.vmap(_build_run(desc),
                              in_axes=(None, 0, _XS_AXES)),
                     in_axes=(_PA_CFG_AXES, None,
                              _cfg_xs_axes(shared_kills)))))


def get_sharded_config_fn(desc: TickDesc, n_shards: int,
                          shared_kills: bool = False):
    """Device-sharded twin of `get_cached_config_fn`: the flat seed axis
    of the (C, S) grid (a multiple of `n_shards`) splits across local
    devices through `repro.dist.sharding.sharded_grid_fn`, the config
    axis rides inside each shard. Cached per (plan shape, shard count,
    kills layout)."""
    if desc.tensor.mode == "pallas":
        raise NotImplementedError(
            "devices= sharding is not wired for the pallas phase mode "
            "(the native seed-batched run owns the seed axis); run "
            "unsharded or use phase_mode='compact'")
    def _build():
        seed_axes = {"t": None, "kills": 0 if shared_kills else 1,
                     "ckpt": None, "bfac": 1, "gate": 0, "ckage": 1,
                     "rfac": 1}
        return sharded_grid_fn(
            _build_run(desc), pa_axes=_PA_CFG_AXES, xs_axes=_XS_AXES,
            cfg_xs_axes=_cfg_xs_axes(shared_kills),
            seed_axes=seed_axes, n_shards=n_shards)
    return _cache_get(_CFG_SHARD_CACHE, (desc, n_shards, shared_kills),
                      _build)


def get_cached_config_mix_fn(desc: TickDesc, shared_kills: bool = False):
    """Triply-vmapped run fn: mixes × configs × seeds in one call (the
    mix axis varies only the source-rate row on top of the config
    axes)."""
    mix_top = dict.fromkeys(_PA_CFG_AXES, None)
    mix_top["src_row"] = 0
    if desc.tensor.mode == "pallas":
        impl = _tick_impl()

        def _build_pl():
            runb = _build_pallas_run(desc, impl)
            return jax.jit(
                jax.vmap(
                    jax.vmap(runb, in_axes=(_PA_CFG_AXES, None,
                                            _cfg_xs_axes(shared_kills))),
                    in_axes=(mix_top, None, None)))
        return _cache_get(_CFG_MIX_CACHE, (desc, shared_kills, impl),
                          _build_pl)

    def _build():
        run = _build_run(desc)
        return jax.jit(
            jax.vmap(
                jax.vmap(jax.vmap(run, in_axes=(None, 0, _XS_AXES)),
                         in_axes=(_PA_CFG_AXES, None,
                                  _cfg_xs_axes(shared_kills))),
                in_axes=(mix_top, None, None)))
    return _cache_get(_CFG_MIX_CACHE, (desc, shared_kills), _build)


# ----------------------------------------------------------------------
# lowering: LogicalGraph + configs → static desc + traced param arrays
# ----------------------------------------------------------------------
class _Lowered:
    def __init__(self, graph: LogicalGraph | PackedArena, *, n_hosts: int,
                 dt: float,
                 queue_cap: float, failover, ckpt, seed: int,
                 phase_mode: str = "auto", seed_width: int = 1,
                 upgrade: UpgradeConfig | None = None,
                 upgrade_spec=None,
                 autoscale: AutoscaleConfig | None = None):
        self.arena = graph if isinstance(graph, PackedArena) else None
        if self.arena is not None:
            graph = self.arena.graph
            dt, queue_cap = self.arena.dt, self.arena.queue_cap
        self.graph = graph
        self.dt = dt
        self.failover = failover
        self.ckpt_cfg = ckpt
        self.phys: PhysicalGraph = (
            self.arena.phys if self.arena is not None
            else expand(graph, n_hosts=n_hosts, seed=seed))
        self.plan = (self.arena.plan if self.arena is not None
                     else build_plan(graph, dt, queue_cap))
        self.task_host = np.array([tk.host for tk in self.phys.tasks])
        self.task_region = np.array(
            [self.phys.task_region[tk.task_id] for tk in self.phys.tasks])
        self.n_hosts = (self.arena.n_hosts if self.arena is not None
                        else int(self.task_host.max()) + 1)
        self.n_regions = len(self.phys.regions)
        self.n_jobs = self.arena.n_jobs if self.arena is not None else 1
        self.job_of_task = (self.arena.job_of_task
                            if self.arena is not None else None)
        self.job_of_op = (self.arena.job_of_op if self.arena is not None
                          else np.zeros(len(self.plan.ops), dtype=int))
        # job-local placements (per-job ChaosSpec lists draw in these)
        self.task_local_host = (
            np.concatenate([j.local_host for j in self.arena.jobs])
            if self.arena is not None else None)

        plan = self.plan
        n_tasks = plan.n_tasks
        src_row = np.zeros(n_tasks)
        cap_base = np.zeros(n_tasks)
        sel = np.zeros(len(plan.ops))
        for oi, p in enumerate(plan.ops):
            sel[oi] = p.selectivity
            if p.is_source:
                src_row[p.lo:p.hi] = p.src_row
            else:
                cap_base[p.lo:p.hi] = p.service_rate * dt

        # per-task failover vectors (per-job config lists lower here)
        codes, det, rst_s, rst_r, fx = per_task_failover(
            failover, n_tasks, self.job_of_task)
        self.fo_codes = codes
        self.fo_detect, self.fo_rs, self.fo_rr = det, rst_s, rst_r
        self.fo_extras = fx
        self.fo_lazy = lazy_ready_extra(fx["stagger"], self.task_region,
                                        self.job_of_task)
        if isinstance(ckpt, (list, tuple)) and (
                self.arena is None or len(list(ckpt)) != self.n_jobs):
            raise ValueError("per-job ckpt list needs a packed arena "
                             "with one entry per job")

        self.tensor = lower_tensor_plan(plan, self.job_of_op,
                                        mode=phase_mode,
                                        seed_width=seed_width)
        required = os.environ.get("REPRO_REQUIRE_PHASE_MODE")
        if required and self.tensor.mode != required:
            raise RuntimeError(
                f"REPRO_REQUIRE_PHASE_MODE={required} but the lowering "
                f"selected the {self.tensor.mode} path (phase_mode="
                f"{phase_mode!r}) — refusing to fall back silently")
        self.desc = TickDesc(self.tensor, self.n_regions)
        # deployment drill: lowered ONCE into traced per-task leaves
        # (inert zeros/infs without an upgrade — exact arithmetic no-ops
        # in the tick, so drill-free runs are numerically untouched)
        sel_task = np.zeros(n_tasks)
        for p in plan.ops:
            if not p.is_source:
                sel_task[p.lo:p.hi] = p.selectivity
        self._sel_task = sel_task
        self._drill = lower_upgrade(
            upgrade, upgrade_spec, n_tasks=n_tasks,
            job_of_task=self.job_of_task, task_region=self.task_region,
            dt=self.dt, base_failover=(codes, det, rst_s, rst_r, fx),
            base_ckpt=ckpt, sel_task=sel_task)
        # in-trace autoscaler: lowered ONCE into traced per-task leaves
        # (inert no-op values without a config — see AUTOSCALE_KEYS)
        self._auto = lower_autoscale(
            autoscale, n_tasks=n_tasks, dt=self.dt,
            is_src_task=self.tensor.is_src_task)
        self.arrays = self._params(plan.qcap, sel, det, rst_s, rst_r,
                                   codes, src_row, cap_base)
        self.op_names = [p.name for p in plan.ops]
        self._src_row, self._cap_base, self._sel = src_row, cap_base, sel

    def _params(self, qcap, sel, det, rst_s, rst_r, codes, src_row=None,
                cap_base=None, fx=None, drill=None,
                autoscale=None) -> dict:
        """Traced-parameter pytree for one resiliency configuration —
        `run_config_batch` stacks one of these per grid row. `drill`
        overrides the lowered deployment-drill leaves (per-config
        `UpgradeConfig` rows), `autoscale` the lowered autoscaler
        leaves (per-config `AutoscaleConfig` rows); default is this
        lowering's own."""
        if fx is None:
            fx = self.fo_extras
            lazy = self.fo_lazy
        else:
            lazy = lazy_ready_extra(fx["stagger"], self.task_region,
                                    self.job_of_task)
        jot = (self.job_of_task if self.job_of_task is not None
               else np.zeros(self.plan.n_tasks, dtype=int))
        return {
            "qcap": np.asarray(qcap, float),
            "src_row": (src_row if src_row is not None
                        else self._src_row),
            "cap_base": (cap_base if cap_base is not None
                         else self._cap_base),
            "sel": np.asarray(sel, float),
            "dt": np.float64(self.dt),
            "task_host": self.task_host.astype(np.int32),
            "task_region": self.task_region.astype(np.int32),
            "detect": np.asarray(det, float),
            "restart_region": np.asarray(rst_r, float),
            "restart_single": np.asarray(rst_s, float),
            "mode_single": (codes == 2).astype(np.float64),
            "mode_region": (codes == 1).astype(np.float64),
            "mode_hot": (codes == 3).astype(np.float64),
            "standby_switch": np.asarray(fx["switch"], float),
            "standby_stale": np.asarray(fx["stale"], float),
            "restore_base": np.asarray(fx["restore_base"], float),
            "replay_rate": np.asarray(fx["replay_rate"], float),
            "lazy_extra": np.asarray(lazy, float),
            "job_of_task": np.asarray(jot, np.int32),
            "op_of_task": self.tensor.op_of_task.astype(np.int32),
            "par_of_op": np.asarray(self.tensor.par_of_op, float),
            "src_mask_ops": np.asarray(self.tensor.src_mask_ops, float),
            # per-phase traced routing parameters: share/mass tables in
            # dense mode, the full pow2-bucketed index/mask sets in
            # compact/pallas mode (the trace key carries only the
            # bucket sizes)
            "edges": [ph.traced()
                      if self.tensor.mode in ("compact", "pallas")
                      else {"share": ph.share, "mass": ph.mass}
                      for ph in self.tensor.phases],
            **(drill if drill is not None else self._drill),
            **(autoscale if autoscale is not None else self._auto),
        }

    # ------------------------------------------------------------------
    def _ckpt_timeline_kw(self, ckpt) -> dict:
        if ckpt is None:
            return dict(ckpt_interval_s=None)
        if isinstance(ckpt, CheckpointConfig):
            return dict(ckpt_interval_s=ckpt.interval_s,
                        ckpt_mode=ckpt.mode, ckpt_upload_s=ckpt.upload_s,
                        ckpt_retry=ckpt.retry_failed_region)
        cfgs = list(ckpt)
        return dict(
            ckpt_interval_s=[c.interval_s if c else None for c in cfgs],
            ckpt_mode=[c.mode if c else "region" for c in cfgs],
            ckpt_upload_s=[c.upload_s if c else 4.0 for c in cfgs],
            ckpt_retry=[c.retry_failed_region if c else True
                        for c in cfgs])

    def timeline(self, spec: ChaosSpec, n_ticks: int, *,
                 fo_codes=None, detect=None, rst_s=None, rst_r=None,
                 extras=None, lazy=None,
                 ckpt="default") -> ChaosTimeline:
        """Pregenerate one seed's chaos timeline, optionally under
        override failover/ckpt parameters (the config-axis path).

        `spec` may be a per-job `ChaosSpec` list (packed arenas): each
        job then runs its own chaos process in its local host domain,
        lifted through the job's host map
        (`core.chaos.build_perjob_chaos_timeline`)."""
        ex = extras if extras is not None else self.fo_extras
        ex_kw = dict(
            standby_switch_s=ex["switch"],
            standby_staleness_s=ex["stale"],
            restore_base_s=ex["restore_base"],
            replay_rate=ex["replay_rate"],
            lazy_extra_s=(lazy if lazy is not None else
                          (self.fo_lazy if extras is None else
                           lazy_ready_extra(ex["stagger"],
                                            self.task_region,
                                            self.job_of_task))))
        if isinstance(spec, (list, tuple)):
            if self.arena is None:
                raise ValueError("a per-job chaos list needs a packed "
                                 "arena with one entry per job")
            specs = [sp.spec if isinstance(sp, ChaosEngine)
                     else (sp or ChaosSpec()) for sp in spec]
            if len(specs) != self.n_jobs:
                raise ValueError(f"per-job chaos list must have one "
                                 f"entry per job ({len(specs)} != "
                                 f"{self.n_jobs})")
            return build_perjob_chaos_timeline(
                specs, n_ticks=n_ticks, dt=self.dt, n_hosts=self.n_hosts,
                task_host=self.task_host,
                job_hosts=[j.hosts for j in self.arena.jobs],
                task_local_host=self.task_local_host,
                job_of_task=self.job_of_task,
                task_region=self.task_region, regions=self.phys.regions,
                failover_mode=(fo_codes if fo_codes is not None
                               else self.fo_codes),
                detect_s=(detect if detect is not None
                          else self.fo_detect),
                region_restart_s=(rst_r if rst_r is not None
                                  else self.fo_rr),
                single_restart_s=(rst_s if rst_s is not None
                                  else self.fo_rs),
                **ex_kw,
                **self._ckpt_timeline_kw(self.ckpt_cfg
                                         if ckpt == "default" else ckpt))
        return build_chaos_timeline(
            spec, n_ticks=n_ticks, dt=self.dt, n_hosts=self.n_hosts,
            task_host=self.task_host, task_region=self.task_region,
            regions=self.phys.regions,
            failover_mode=(fo_codes if fo_codes is not None
                           else self.fo_codes),
            detect_s=(detect if detect is not None else self.fo_detect),
            region_restart_s=(rst_r if rst_r is not None else self.fo_rr),
            single_restart_s=(rst_s if rst_s is not None else self.fo_rs),
            job_of_task=self.job_of_task,
            **ex_kw,
            **self._ckpt_timeline_kw(self.ckpt_cfg if ckpt == "default"
                                     else ckpt))

    def state0(self, tl: ChaosTimeline,
               task_speed_override: dict[int, float] | None
               ) -> EngineState:
        n_tasks = self.plan.n_tasks
        speed = np.ones(n_tasks)
        if task_speed_override:
            for tid, s in task_speed_override.items():
                speed[tid] = s
        speed *= tl.task_speed
        return EngineState(
            queue=np.zeros(n_tasks), down_until=np.zeros(n_tasks),
            speed=speed, ckpt_epoch=np.int32(0),
            emitted=np.zeros(self.n_jobs), dropped=np.zeros(self.n_jobs),
            up_until=np.zeros(n_tasks), rb_t=np.float64(np.inf),
            dacc=np.float64(0.0),
            rew=np.zeros(n_tasks), lact=np.full(n_tasks, -1e18),
            dirp=np.zeros(n_tasks), failcnt=np.zeros(n_tasks),
            brk_until=np.zeros(n_tasks), used=np.float64(0.0),
            flip_acc=np.float64(0.0), thrash_t=np.float64(np.inf),
            nact=np.float64(0.0), rsec=np.float64(0.0))

    def event_curves(self, spec, tl: ChaosTimeline,
                     cfg_ramps=(), cfg_traffic=((), ())) -> tuple:
        """Deterministic per-tick external-event tensors for one seed:
        ``bfac`` storage-brownout factor, ``gate`` source gate (MQ
        outages × coordinator leader-loss windows — the gate is 0 where
        the MQ is down OR a ZK and an HDFS outage overlap, matching
        `ChaosEngine.leader_available`), ``ckage`` checkpoint age and
        ``rfac`` traffic-rate factor (diurnal curves × flash-crowd
        ramps, `core.chaos.traffic_curve`) — each (n_ticks, n_jobs),
        gathered per task through ``pa["job_of_task"]`` inside the
        tick. Config-level brownout ramps / traffic patterns compose
        by tuple concatenation (so the factors are op-identical to the
        numpy engines')."""
        ts = tl.ts
        cfg_diurnal, cfg_flash = (tuple(cfg_traffic[0]),
                                  tuple(cfg_traffic[1]))
        if isinstance(spec, (list, tuple)):
            specs = [sp.spec if isinstance(sp, ChaosEngine)
                     else (sp or ChaosSpec()) for sp in spec]
            bfac = np.stack(
                [brownout_curve(tuple(sp.brownout_at) + tuple(cfg_ramps),
                                ts) for sp in specs], axis=1)
            gate = np.stack(
                [mq_gate_curve(sp.mq_down, ts)
                 * coordinator_gate_curve(sp.zk_down, sp.hdfs_down, ts)
                 for sp in specs], axis=1)
            rfac = np.stack(
                [traffic_curve(tuple(sp.diurnal) + cfg_diurnal,
                               tuple(sp.flash_at) + cfg_flash, ts,
                               phase_s=sp.rate_phase_s)
                 for sp in specs], axis=1)
        else:
            bf = brownout_curve(tuple(spec.brownout_at)
                                + tuple(cfg_ramps), ts)
            gt = (mq_gate_curve(spec.mq_down, ts)
                  * coordinator_gate_curve(spec.zk_down, spec.hdfs_down,
                                           ts))
            rf = traffic_curve(tuple(spec.diurnal) + cfg_diurnal,
                               tuple(spec.flash_at) + cfg_flash, ts,
                               phase_s=spec.rate_phase_s)
            bfac = np.repeat(bf[:, None], self.n_jobs, axis=1)
            gate = np.repeat(gt[:, None], self.n_jobs, axis=1)
            rfac = np.repeat(rf[:, None], self.n_jobs, axis=1)
        ok = (tl.ckpt_ok_by_job if tl.ckpt_ok_by_job is not None
              else tl.ckpt_ok)
        ckage = ckpt_age_curve(ts, ok, self.n_jobs)
        return bfac, gate, ckage, rfac

    def prepare(self, spec: ChaosSpec, n_ticks: int,
                task_speed_override: dict[int, float] | None = None
                ) -> tuple[EngineState, dict, ChaosTimeline]:
        """Pregenerate one seed's chaos timeline → (state0, scan xs)."""
        tl = self.timeline(spec, n_ticks)
        state = self.state0(tl, task_speed_override)
        bfac, gate, ckage, rfac = self.event_curves(spec, tl)
        xs = {"t": tl.ts, "kills": tl.kills.astype(np.float64),
              "ckpt": tl.ckpt_at, "bfac": bfac, "gate": gate,
              "ckage": ckage, "rfac": rfac}
        return state, xs, tl

    # ------------------------------------------------------------------
    def legacy(self):
        """(desc, arrays) of the pre-tensorized unrolled tick — only for
        the old-vs-new compile benchmark (`build_unrolled_run`). Requires
        a uniform (non-per-job) failover config."""
        modes = np.unique(self.fo_codes)
        if len(modes) != 1:
            raise ValueError("legacy unrolled tick supports uniform "
                             "failover configs only")
        mode = {0: "none", 1: "region", 2: "single_task"}[int(modes[0])]
        plan = self.plan
        op_descs, edge_descs, edge_arrays, edges_of_op = [], [], [], []
        for p in plan.ops:
            op_descs.append(_OpDesc(p.lo, p.hi, p.is_source))
        for oi, p in enumerate(plan.ops):
            mine = []
            for ep in p.out_edges:
                mine.append(len(edge_descs))
                n_groups = (len(ep.grp_starts)
                            if ep.grp_starts is not None else 0)
                edge_descs.append(_EdgeDesc(
                    ep.kind, ep.static, oi, p.par, ep.dst.lo, ep.dst.hi,
                    ep.n_blocks, n_groups, ep.any_unblocked))
                ea: dict = {}
                if ep.kind == "hash":
                    ea["share"] = ep.share
                elif ep.kind == "weakhash":
                    ea["grp_of_dst"] = ep.grp_of_dst.astype(np.int32)
                    ea["mass_of_dst"] = ep.mass_of_dst
                elif ep.kind == "backlog":
                    ea["dst_qcap"] = np.float64(ep.dst_qcap)
                if ep.kind in ("rescale", "group_rescale"):
                    ea["blk_of_src"] = ep.blk_of_src.astype(np.int32)
                    ea["blk_idx"] = ep.blk_idx.astype(np.int32)
                    ea["dst_in_blk"] = ep.dst_in_blk.astype(np.float64)
                edge_arrays.append(ea)
            edges_of_op.append(tuple(mine))
        desc = (tuple(op_descs), tuple(edge_descs), tuple(edges_of_op),
                tuple(int(j) for j in plan.src_cols), plan.n_tasks,
                self.n_hosts, self.n_regions, mode,
                tuple(int(j) for j in self.job_of_op), self.n_jobs)
        arrays = dict(self.arrays)
        arrays.pop("mode_single")
        arrays.pop("mode_region")
        arrays["detect"] = np.float64(self.fo_detect[0])
        arrays["restart_region"] = np.float64(self.fo_rr[0])
        arrays["restart_single"] = np.float64(self.fo_rs[0])
        arrays["edges"] = edge_arrays
        return desc, arrays


# ----------------------------------------------------------------------
# metrics façades (same read API as streams.engine.EngineMetrics)
# ----------------------------------------------------------------------
class JaxEngineMetrics:
    def __init__(self, op_names, t, lag, qps, backlog, emitted, dropped,
                 timeline: ChaosTimeline, ckpt_epoch: int | None = None,
                 rollback_t: float = np.inf, thrash_t: float = np.inf,
                 n_rescale: float = 0.0, resource_s: float = 0.0):
        self.t = t
        self.source_lag = lag
        self.qps = {n: qps[:, j] for j, n in enumerate(op_names)}
        self.backlog = {n: backlog[:, j] for j, n in enumerate(op_names)}
        # emitted/dropped arrive as (n_jobs,) segment totals
        self.emitted_by_job = np.atleast_1d(np.asarray(emitted, float))
        self.dropped_by_job = np.atleast_1d(np.asarray(dropped, float))
        self.emitted = float(self.emitted_by_job.sum())
        self.dropped = float(self.dropped_by_job.sum())
        self.ckpt_attempts = timeline.ckpt_attempts
        self.ckpt_success = timeline.ckpt_success
        self.ckpt_failed = timeline.ckpt_failed
        self.ckpt_by_job = timeline.ckpt_by_job
        # device-side attempt counter (scan state) — must agree with the
        # host-side timeline; pinned in tests/test_jax_engine.py
        self.ckpt_epoch = (timeline.ckpt_attempts if ckpt_epoch is None
                           else int(ckpt_epoch))
        self.recoveries = timeline.recoveries
        self.timeline = timeline
        # deployment drill: tick time the in-trace auto-rollback fired
        # (+inf when no drill ran or the canary held)
        self.rollback_t = float(rollback_t)
        # in-trace autoscaler: thrash-guard latch time (+inf = never
        # fired), scale-action count, resource-seconds integral
        self.thrash_t = float(thrash_t)
        self.n_rescale = float(n_rescale)
        self.resource_s = float(resource_s)


class JaxBatchMetrics:
    """Stacked metrics of a vmapped seed batch; `row(i)` is identical to
    a standalone single-seed run (pinned in tests/test_jax_engine.py)."""

    def __init__(self, op_names, t, lag, qps, backlog, emitted, dropped,
                 timelines, ckpt_epoch=None, jobs=None, rollback_t=None,
                 thrash_t=None, n_rescale=None, resource_s=None):
        self.op_names = list(op_names)
        self.t = t                     # (n_ticks,)
        self.source_lag = lag          # (S, n_ticks)
        self.qps = qps                 # (S, n_ticks, n_ops)
        self.backlog = backlog         # (S, n_ticks, n_ops)
        emitted = np.asarray(emitted, float)
        dropped = np.asarray(dropped, float)
        if emitted.ndim == 1:          # legacy (S,) scalar-per-seed form
            emitted, dropped = emitted[:, None], dropped[:, None]
        self.emitted_by_job = emitted  # (S, n_jobs)
        self.dropped_by_job = dropped  # (S, n_jobs)
        self.emitted = emitted.sum(axis=-1)   # (S,)
        self.dropped = dropped.sum(axis=-1)   # (S,)
        self.ckpt_epoch = ckpt_epoch   # (S,) device-side attempt counter
        # (S,) drill auto-rollback fire times (+inf = never fired)
        self.rollback_t = (np.asarray(rollback_t, float)
                           if rollback_t is not None else None)
        # (S,) autoscaler surfaces: thrash-guard latch times, scale
        # action counts, resource-seconds integrals
        self.thrash_t = (np.asarray(thrash_t, float)
                         if thrash_t is not None else None)
        self.n_rescale = (np.asarray(n_rescale, float)
                          if n_rescale is not None else None)
        self.resource_s = (np.asarray(resource_s, float)
                           if resource_s is not None else None)
        self.timelines = list(timelines)
        self.jobs = list(jobs) if jobs is not None else None
        self.ckpt_attempts = np.array([tl.ckpt_attempts for tl in timelines])
        self.ckpt_success = np.array([tl.ckpt_success for tl in timelines])
        self.ckpt_failed = np.array([tl.ckpt_failed for tl in timelines])
        self.recoveries = [tl.recoveries for tl in timelines]

    def __len__(self) -> int:
        return len(self.timelines)

    def row(self, i: int) -> JaxEngineMetrics:
        return JaxEngineMetrics(self.op_names, self.t, self.source_lag[i],
                                self.qps[i], self.backlog[i],
                                self.emitted_by_job[i],
                                self.dropped_by_job[i],
                                self.timelines[i],
                                ckpt_epoch=(self.ckpt_epoch[i]
                                            if self.ckpt_epoch is not None
                                            else None),
                                rollback_t=(self.rollback_t[i]
                                            if self.rollback_t is not None
                                            else np.inf),
                                thrash_t=(self.thrash_t[i]
                                          if self.thrash_t is not None
                                          else np.inf),
                                n_rescale=(self.n_rescale[i]
                                           if self.n_rescale is not None
                                           else 0.0),
                                resource_s=(self.resource_s[i]
                                            if self.resource_s is not None
                                            else 0.0))

    def job_view(self, job: JobSlice) -> "JaxBatchMetrics":
        """Per-job slice of a packed-arena batch: the job's metric columns
        under their original (un-namespaced) op names, source lag summed
        over the job's own sources, per-job emitted/dropped segments, and
        recovery events filtered to the job — shaped exactly like a
        single-job batch so `chaos_sweep.summarize` works per job."""
        cols = np.asarray(job.op_cols)
        lag = self.backlog[:, :, np.asarray(job.src_cols)].sum(axis=-1)
        j = job.index
        tls = [dataclasses.replace(
                   tl, recoveries=[r for r in tl.recoveries
                                   if r.get("job", 0) == j])
               for tl in self.timelines]
        return JaxBatchMetrics(
            job.op_names, self.t, lag, self.qps[:, :, cols],
            self.backlog[:, :, cols],
            self.emitted_by_job[:, j:j + 1],
            self.dropped_by_job[:, j:j + 1], tls,
            ckpt_epoch=self.ckpt_epoch, rollback_t=self.rollback_t,
            thrash_t=self.thrash_t, n_rescale=self.n_rescale,
            resource_s=self.resource_s)


# ----------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------
class JaxStreamEngine:
    """Drop-in (single-seed) twin of `StreamEngine`: same constructor
    signature, `run(duration_s)` returns `JaxEngineMetrics` with the
    numpy engine's metric names/values (1e-5). `failover` / `ckpt` may be
    per-job config lists for packed arenas, exactly as in the numpy
    engine."""

    def __init__(self, graph: LogicalGraph | PackedArena, *,
                 n_hosts: int = 8,
                 dt: float = 0.5, queue_cap: float = 256.0,
                 chaos: ChaosEngine | ChaosSpec | None = None,
                 failover=None,
                 ckpt=None,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0, phase_mode: str = "auto",
                 upgrade: UpgradeConfig | None = None,
                 autoscale: AutoscaleConfig | None = None):
        if isinstance(chaos, ChaosEngine):
            chaos = chaos.spec
        elif isinstance(chaos, (list, tuple)):
            chaos = [c.spec if isinstance(c, ChaosEngine)
                     else (c or ChaosSpec()) for c in chaos]
        self.spec = chaos if chaos is not None else ChaosSpec()
        self.g = graph.graph if isinstance(graph, PackedArena) else graph
        if isinstance(graph, PackedArena):
            dt = graph.dt
        self.dt = dt
        self._override = task_speed_override
        self._low = _Lowered(graph, n_hosts=n_hosts, dt=dt,
                             queue_cap=queue_cap, failover=failover,
                             ckpt=ckpt, seed=seed, phase_mode=phase_mode,
                             upgrade=upgrade, upgrade_spec=self.spec,
                             autoscale=autoscale)
        self.metrics: JaxEngineMetrics | None = None

    @property
    def lowered(self) -> _Lowered:
        return self._low

    def run(self, duration_s: float) -> JaxEngineMetrics:
        low = self._low
        n_ticks = int(round(duration_s / self.dt))
        state, xs, tl = low.prepare(self.spec, n_ticks, self._override)
        run_fn, _ = get_cached_run_fns(low.desc)
        with _enable_x64():
            final, ys = run_fn(low.arrays, state, xs)
            qps = np.asarray(ys["qps"])
            backlog = np.asarray(ys["backlog"])
            lag = np.asarray(ys["lag"])
            emitted = np.asarray(final.emitted)
            dropped = np.asarray(final.dropped)
            ckpt_epoch = int(final.ckpt_epoch)
            rollback_t = float(final.rb_t)
            thrash_t = float(final.thrash_t)
            n_rescale = float(final.nact)
            resource_s = float(final.rsec)
        self.metrics = JaxEngineMetrics(low.op_names, tl.ts, lag, qps,
                                        backlog, emitted, dropped, tl,
                                        ckpt_epoch=ckpt_epoch,
                                        rollback_t=rollback_t,
                                        thrash_t=thrash_t,
                                        n_rescale=n_rescale,
                                        resource_s=resource_s)
        return self.metrics


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def _pad_rows(a: np.ndarray, target: int, axis: int = 0) -> np.ndarray:
    """Pad `axis` to `target` by replicating its first slice (pad rows
    simulate a real scenario, so no NaNs/branches — they are sliced off
    before any aggregate sees them)."""
    if a.shape[axis] == target:
        return a
    first = np.take(a, [0], axis=axis)
    shape = list(a.shape)
    shape[axis] = target - a.shape[axis]
    return np.concatenate([a, np.broadcast_to(first, shape)], axis=axis)


def _pad_batch(batch_state: EngineState, xs: dict, n_seeds: int,
               pad_seeds: bool, n_shards: int = 1,
               seed_axes: dict | None = None):
    """Pad the seed axis to the next power of two (and to a multiple of
    the shard count) — the retrace-free batching contract shared by
    `run_batch`, `run_mix_batch` and `run_config_batch`. `seed_axes`
    names the xs leaves carrying a seed axis (and which axis it is)."""
    if seed_axes is None:
        seed_axes = {"kills": 0, "bfac": 0, "gate": 0, "ckage": 0,
                     "rfac": 0}
    target = _next_pow2(n_seeds) if pad_seeds else n_seeds
    if target % n_shards:
        target = n_shards * -(-target // n_shards)
    if target != n_seeds:
        batch_state = EngineState(*(_pad_rows(getattr(batch_state, f),
                                              target)
                                    for f in EngineState._fields))
        xs = dict(xs, **{k: _pad_rows(np.asarray(xs[k]), target, axis=ax)
                         for k, ax in seed_axes.items()})
    return batch_state, xs


def _prep_batch(low: "_Lowered", specs, n_ticks: int, task_speed_override):
    prepped = [low.prepare(spec, n_ticks, task_speed_override)
               for spec in specs]
    states = [p[0] for p in prepped]
    tls = [p[2] for p in prepped]
    batch_state = EngineState(*(np.stack([getattr(s, f) for s in states])
                                for f in EngineState._fields))
    xs = {"t": prepped[0][1]["t"],                 # identical across seeds
          "kills": np.stack([p[1]["kills"] for p in prepped]),
          "ckpt": prepped[0][1]["ckpt"],           # static schedule
          # per-seed external-event tensors (ckpt ages vary with each
          # seed's success draws even under a static attempt schedule)
          "bfac": np.stack([p[1]["bfac"] for p in prepped]),
          "gate": np.stack([p[1]["gate"] for p in prepped]),
          "ckage": np.stack([p[1]["ckage"] for p in prepped]),
          "rfac": np.stack([p[1]["rfac"] for p in prepped])}
    return batch_state, xs, tls


def perjob_sweep_seed(base_seed: int, sweep_seed: int, job: int) -> int:
    """Collision-free derived seed for job `job` of sweep seed
    `sweep_seed` under a per-job base spec (SeedSequence entropy mix —
    distinct cells cannot share a stream)."""
    return int(np.random.SeedSequence(
        (int(base_seed), int(sweep_seed), int(job))).generate_state(1)[0])


def _as_specs(seeds, base_spec) -> list:
    """Merge sweep seeds into the base spec. A per-job `base_spec` LIST
    (packed arenas) yields one per-job spec list per seed: job j of
    sweep seed s draws from ``perjob_sweep_seed(base[j].seed, s, j)`` —
    a `np.random.SeedSequence` mix of (base seed, sweep seed, job), so
    every (seed, job) cell gets a distinct, reproducible stream even
    when base seeds are heterogeneous (plain ``base.seed + s*K + j``
    arithmetic can collide across cells). Entries of `seeds` that are
    already specs (or per-job spec lists) pass through untouched."""
    if isinstance(base_spec, (list, tuple)):
        base = [b or ChaosSpec() for b in base_spec]
        return [[dataclasses.replace(b, seed=perjob_sweep_seed(
                    b.seed, int(s), j)) for j, b in enumerate(base)]
                if isinstance(s, (int, np.integer)) else s
                for s in seeds]
    return [dataclasses.replace(base_spec or ChaosSpec(), seed=int(s))
            if isinstance(s, (int, np.integer)) else s for s in seeds]


def _check_pallas_devices(low: "_Lowered", devices, entry: str) -> None:
    """Boundary guard: pallas runs are natively seed-batched (the fused
    kernel owns the seed axis as its grid dimension), so `devices=`
    sharding has no lowering. Raise the actionable spelling here instead
    of letting `get_sharded_*` NotImplementedError deep in the run."""
    if devices is not None and low.tensor.mode == "pallas":
        raise NotImplementedError(
            f"{entry}: devices={devices!r} does not compose with "
            "phase_mode='pallas' (the fused kernel natively owns the "
            "seed axis; there is no sharded lowering). Rerun with "
            "devices=None — pass seed_chunk= to bound per-pass device "
            "memory instead — or use phase_mode='compact' for "
            "device-sharded grids.")


class ChunkResult:
    """One seed-chunk's worth of a chunked run: the half-open seed range
    ``[seed_lo, seed_hi)``, its metrics (`JaxBatchMetrics` for seed
    plans; a per-config list — or mixes×configs nest — for grid plans),
    and the host-prep / device wall split."""

    __slots__ = ("seed_lo", "seed_hi", "batches", "prep_s", "device_s")

    def __init__(self, seed_lo, seed_hi, batches, prep_s, device_s):
        self.seed_lo = seed_lo
        self.seed_hi = seed_hi
        self.batches = batches
        self.prep_s = prep_s
        self.device_s = device_s


def run_chunks(plan, chunk_size: int | None = None, on_chunk=None
               ) -> list[ChunkResult]:
    """Execute a `SeedBatchPlan`/`ConfigGridPlan` in seed chunks on a
    double-buffered pipeline: host-side timeline prep for chunk k+1 runs
    on the caller thread WHILE chunk k computes on a one-slot device
    lane (XLA releases the GIL for the blocking device call, so the two
    genuinely overlap). `on_chunk` fires with each `ChunkResult` as it
    lands, in seed order — incremental consumers see partial surfaces
    at time-to-first-chunk instead of time-to-last."""
    n_seeds = plan.n_seeds
    size = n_seeds if not chunk_size else max(1, int(chunk_size))
    bounds = [(lo, min(lo + size, n_seeds))
              for lo in range(0, n_seeds, size)]

    def _run(prepped, prep_s):
        t0 = time.perf_counter()
        batches = plan.run_chunk(prepped)
        return ChunkResult(prepped[0], prepped[1], batches, prep_s,
                           time.perf_counter() - t0)

    out: list[ChunkResult] = []

    def _land(fut):
        res = fut.result()
        out.append(res)
        if on_chunk is not None:
            on_chunk(res)

    with ThreadPoolExecutor(max_workers=1) as lane:
        fut = None
        for lo, hi in bounds:
            t0 = time.perf_counter()
            prepped = plan.prep_chunk(lo, hi)
            prep_s = time.perf_counter() - t0
            if fut is not None:
                _land(fut)          # chunk k lands while k+1 is prepped
            fut = lane.submit(_run, prepped, prep_s)
        _land(fut)
    return out


def concat_batches(parts: list[JaxBatchMetrics]) -> JaxBatchMetrics:
    """Concatenate per-chunk `JaxBatchMetrics` along the seed axis.

    Every per-seed surface is a plain row stack (no cross-seed
    reductions happen device-side), so the concatenation of chunked
    results is bit-identical to the monolithic batch — pinned by
    tests/test_sweep_service.py."""
    if len(parts) == 1:
        return parts[0]
    first = parts[0]

    def cat(name):
        v = getattr(first, name)
        if v is None:
            return None
        return np.concatenate([np.asarray(getattr(p, name))
                               for p in parts], axis=0)

    return JaxBatchMetrics(
        first.op_names, first.t, cat("source_lag"), cat("qps"),
        cat("backlog"), cat("emitted_by_job"), cat("dropped_by_job"),
        [tl for p in parts for tl in p.timelines],
        ckpt_epoch=cat("ckpt_epoch"), jobs=first.jobs,
        rollback_t=cat("rollback_t"), thrash_t=cat("thrash_t"),
        n_rescale=cat("n_rescale"), resource_s=cat("resource_s"))


def _fill_timing(timing: dict, chunks: list[ChunkResult], plan) -> None:
    """Record the prep/device wall split + per-request cache traffic of
    a chunked run into the caller-supplied `timing` dict."""
    timing["prep_s"] = sum(c.prep_s for c in chunks)
    timing["device_s"] = sum(c.device_s for c in chunks)
    timing["chunks"] = len(chunks)
    timing["cache_hits"] = plan.cache_info["hits"]
    timing["cache_misses"] = plan.cache_info["misses"]


class SeedBatchPlan:
    """Chunk-friendly decomposition of `run_batch`: `__init__` does all
    seed-count-independent work (lowering, trace-cache lookup — cache
    traffic lands in `cache_info`), `prep_chunk(lo, hi)` builds the
    host-side tensors for a seed slice, `run_chunk` runs one device
    pass. Driven by `run_chunks`."""

    def __init__(self, graph: LogicalGraph | PackedArena, seeds, *,
                 duration_s: float, base_spec: ChaosSpec | None = None,
                 n_hosts: int = 8, dt: float = 0.5,
                 queue_cap: float = 256.0, failover=None, ckpt=None,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0, pad_seeds: bool = True,
                 devices: int | str | None = None,
                 phase_mode: str = "auto",
                 upgrade: UpgradeConfig | None = None,
                 autoscale: AutoscaleConfig | None = None):
        specs = _as_specs(seeds, base_spec)
        if not specs:
            raise ValueError("run_batch requires at least one seed/spec")
        self.specs = specs
        self.n_seeds = len(specs)
        self.low = low = _Lowered(
            graph, n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
            failover=failover, ckpt=ckpt, seed=seed,
            phase_mode=phase_mode, seed_width=len(specs),
            upgrade=upgrade, upgrade_spec=specs[0], autoscale=autoscale)
        _check_pallas_devices(low, devices, "run_batch")
        self.n_ticks = int(round(duration_s / low.dt))
        self._override = task_speed_override
        self.pad_seeds = pad_seeds
        self.n_shards = local_shard_count(devices)
        with scoped_cache_stats() as counts:
            if devices is not None:
                self.fn = get_sharded_run_fn(low.desc, self.n_shards)
            else:
                _, self.fn = get_cached_run_fns(low.desc)
        self.cache_info = dict(counts)

    def prep_chunk(self, lo: int, hi: int):
        batch_state, xs, tls = _prep_batch(self.low, self.specs[lo:hi],
                                           self.n_ticks, self._override)
        batch_state, xs = _pad_batch(batch_state, xs, hi - lo,
                                     self.pad_seeds, self.n_shards)
        return (lo, hi, batch_state, xs, tls)

    def run_chunk(self, prepped) -> JaxBatchMetrics:
        lo, hi, batch_state, xs, tls = prepped
        n = hi - lo
        low = self.low
        with _enable_x64():
            final, ys = self.fn(low.arrays, batch_state, xs)
            qps = np.asarray(ys["qps"])[:n]
            backlog = np.asarray(ys["backlog"])[:n]
            lag = np.asarray(ys["lag"])[:n]
            emitted = np.asarray(final.emitted)[:n]
            dropped = np.asarray(final.dropped)[:n]
            ckpt_epoch = np.asarray(final.ckpt_epoch)[:n]
            rollback_t = np.asarray(final.rb_t)[:n]
            thrash_t = np.asarray(final.thrash_t)[:n]
            n_rescale = np.asarray(final.nact)[:n]
            resource_s = np.asarray(final.rsec)[:n]
        return JaxBatchMetrics(low.op_names, tls[0].ts, lag, qps, backlog,
                               emitted, dropped, tls,
                               ckpt_epoch=ckpt_epoch,
                               jobs=(low.arena.jobs
                                     if low.arena is not None else None),
                               rollback_t=rollback_t, thrash_t=thrash_t,
                               n_rescale=n_rescale, resource_s=resource_s)


def run_batch(graph: LogicalGraph | PackedArena, seeds, *,
              duration_s: float,
              base_spec: ChaosSpec | None = None, n_hosts: int = 8,
              dt: float = 0.5, queue_cap: float = 256.0,
              failover=None,
              ckpt=None,
              task_speed_override: dict[int, float] | None = None,
              seed: int = 0, pad_seeds: bool = True,
              devices: int | str | None = None,
              phase_mode: str = "auto",
              upgrade: UpgradeConfig | None = None,
              autoscale: AutoscaleConfig | None = None,
              seed_chunk: int | None = None,
              on_chunk=None,
              timing: dict | None = None
              ) -> JaxBatchMetrics:
    """Run a ``(S,)`` batch of chaos scenarios as ONE vmapped `jit` call
    (one call *per device shard* when `devices` is set).

    `seeds` is a sequence of ints (merged into `base_spec` via
    ``dataclasses.replace(spec, seed=s)``) or of full `ChaosSpec`s.
    `graph` may be a `PackedArena` — the whole co-located fleet then
    simulates in the same device call with per-job metric segments, and
    `failover` / `ckpt` may be per-job config lists.

    Retrace-free batching: with ``pad_seeds=True`` the seed axis is
    padded to the next power of two (and to a multiple of the shard
    count) by replicating scenario 0, so varying S reuses one jit trace
    per pow2 bucket instead of recompiling per batch size; pad rows are
    sliced off before the metrics object is built, so no aggregate ever
    sees them. ``devices`` splits the padded batch across local devices
    through the version-gated `repro.dist.sharding` shim (``"auto"`` =
    all local devices).

    ``seed_chunk`` streams the batch through fixed-size seed chunks on
    the double-buffered `run_chunks` pipeline (host prep for chunk k+1
    overlaps device compute for chunk k); the concatenated result is
    bit-identical to the monolithic call. ``on_chunk`` fires with each
    `ChunkResult` as it lands; ``timing``, if given a dict, receives the
    ``prep_s`` / ``device_s`` wall split plus per-request trace-cache
    ``cache_hits`` / ``cache_misses``.
    """
    plan = SeedBatchPlan(graph, seeds, duration_s=duration_s,
                         base_spec=base_spec, n_hosts=n_hosts, dt=dt,
                         queue_cap=queue_cap, failover=failover,
                         ckpt=ckpt,
                         task_speed_override=task_speed_override,
                         seed=seed, pad_seeds=pad_seeds, devices=devices,
                         phase_mode=phase_mode, upgrade=upgrade,
                         autoscale=autoscale)
    chunks = run_chunks(plan, seed_chunk, on_chunk)
    if timing is not None:
        _fill_timing(timing, chunks, plan)
    return concat_batches([c.batches for c in chunks])


def run_mix_batch(graph: LogicalGraph | PackedArena, mixes, seeds, *,
                  duration_s: float,
                  base_spec: ChaosSpec | None = None, n_hosts: int = 8,
                  dt: float = 0.5, queue_cap: float = 256.0,
                  failover=None,
                  ckpt=None,
                  task_speed_override: dict[int, float] | None = None,
                  seed: int = 0, pad_seeds: bool = True,
                  phase_mode: str = "auto",
                  autoscale: AutoscaleConfig | None = None
                  ) -> list[JaxBatchMetrics]:
    """Sweep an ``(M, S)`` grid of job-mix × chaos-seed scenarios in ONE
    doubly-vmapped `jit` call (the second vmap axis over job-mix configs).

    `mixes` is an ``(M, n_jobs)`` array of per-job source-rate
    multipliers (n_jobs = 1 for a plain graph): row m scales every job
    j's source emission by ``mixes[m, j]``. Rates are traced, not baked,
    so the whole grid shares one trace with the plan shape; chaos
    timelines are rate-independent and shared across mixes. Returns one
    `JaxBatchMetrics` per mix row.
    """
    specs = _as_specs(seeds, base_spec)
    if not specs:
        raise ValueError("run_mix_batch requires at least one seed/spec")
    low = _Lowered(graph, n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
                   failover=failover, ckpt=ckpt, seed=seed,
                   phase_mode=phase_mode, seed_width=len(specs),
                   autoscale=autoscale)
    mixes = np.atleast_2d(np.asarray(mixes, dtype=np.float64))
    if mixes.shape[1] != low.n_jobs:
        raise ValueError(
            f"mix rows must have one multiplier per job "
            f"({mixes.shape[1]} != {low.n_jobs})")
    n_ticks = int(round(duration_s / low.dt))
    batch_state, xs, tls = _prep_batch(low, specs, n_ticks,
                                       task_speed_override)
    n_seeds = len(specs)
    batch_state, xs = _pad_batch(batch_state, xs, n_seeds, pad_seeds)
    job_of_task = (low.job_of_task if low.job_of_task is not None
                   else np.zeros(low.plan.n_tasks, dtype=int))
    src_rows = low.arrays["src_row"][None, :] * mixes[:, job_of_task]
    pa = dict(low.arrays, src_row=src_rows)
    mix_fn = get_cached_mix_fn(low.desc)
    with _enable_x64():
        final, ys = mix_fn(pa, batch_state, xs)
        qps = np.asarray(ys["qps"])[:, :n_seeds]
        backlog = np.asarray(ys["backlog"])[:, :n_seeds]
        lag = np.asarray(ys["lag"])[:, :n_seeds]
        emitted = np.asarray(final.emitted)[:, :n_seeds]
        dropped = np.asarray(final.dropped)[:, :n_seeds]
        ckpt_epoch = np.asarray(final.ckpt_epoch)[:, :n_seeds]
        rollback_t = np.asarray(final.rb_t)[:, :n_seeds]
        thrash_t = np.asarray(final.thrash_t)[:, :n_seeds]
        n_rescale = np.asarray(final.nact)[:, :n_seeds]
        resource_s = np.asarray(final.rsec)[:, :n_seeds]
    jobs = low.arena.jobs if low.arena is not None else None
    return [JaxBatchMetrics(low.op_names, tls[0].ts, lag[m], qps[m],
                            backlog[m], emitted[m], dropped[m], tls,
                            ckpt_epoch=ckpt_epoch[m], jobs=jobs,
                            rollback_t=rollback_t[m],
                            thrash_t=thrash_t[m],
                            n_rescale=n_rescale[m],
                            resource_s=resource_s[m])
            for m in range(len(mixes))]


# ----------------------------------------------------------------------
# resiliency-config grid axis
# ----------------------------------------------------------------------
def _normalize_traffic(v) -> tuple:
    """Normalize a config-level traffic pattern into the canonical
    ``(diurnal_events, flash_events)`` pair of tuples. Accepts the pair
    itself, a ``{"diurnal": ..., "flash": ...}`` dict, or a bare tuple
    of ``(t0, ramp_s, hold_s, peak)`` flash-crowd events."""
    if not v:
        return ((), ())
    if isinstance(v, dict):
        unknown = set(v) - {"diurnal", "flash"}
        if unknown:
            raise ValueError(f"unknown traffic keys: {sorted(unknown)}")
        return (tuple(tuple(e) for e in v.get("diurnal", ())),
                tuple(tuple(e) for e in v.get("flash", ())))
    v = tuple(v)
    if (len(v) == 2
            and all(isinstance(x, (list, tuple)) for x in v)
            and all(isinstance(e, (list, tuple)) for x in v for e in x)):
        return (tuple(tuple(e) for e in v[0]),
                tuple(tuple(e) for e in v[1]))
    return ((), tuple(tuple(e) for e in v))


def normalize_config(c) -> dict:
    """Normalize one resiliency-config grid entry into
    ``{"failover", "ckpt", "qcap_scale", "sel_scale", "label"}``.

    Accepted forms: a `FailoverConfig`, a `CheckpointConfig`, a
    ``(failover, ckpt)`` TUPLE, a per-job `FailoverConfig` LIST (packed
    arenas; ``None`` entries fall back to the default config — the
    tuple/list distinction is what disambiguates a 2-job list from a
    pair), or a dict with any of the keys above (the fully explicit
    spelling, and the only way to combine per-job failover lists with
    ckpt/scales). The dict form also accepts ``brownout``: config-level
    storage-brownout ramps ``((t0, t1, peak), ...)`` APPENDED to each
    seed spec's own ramps, so brownout severity rides the config axis
    deterministically (no extra draws). ``upgrade`` puts an
    `UpgradeConfig` deployment drill on the config axis — its lowered
    leaves are all traced floats, so drill rows share the drill-free
    rows' compiled trace AND their pregenerated chaos timelines
    (upgrades are in-trace only; `timeline_build_count` stays flat).
    ``traffic`` puts a traffic pattern on the config axis — canonically
    a ``(diurnal_events, flash_events)`` pair (a dict with
    ``diurnal``/``flash`` keys, or a bare tuple of flash-crowd events,
    also accepted), composed into each seed spec's own pattern by tuple
    concatenation exactly like ``brownout``; ``scaler`` puts an
    `AutoscaleConfig` in-trace autoscaler on the config axis — like
    upgrades, both lower to traced curves/floats, so timelines and the
    compiled trace are untouched."""
    out = {"failover": None, "ckpt": None, "qcap_scale": 1.0,
           "sel_scale": 1.0, "brownout": (), "upgrade": None,
           "traffic": ((), ()), "scaler": None, "label": None}
    if c is None:
        return out
    if isinstance(c, dict):
        unknown = set(c) - set(out)
        if unknown:
            raise ValueError(f"unknown config keys: {sorted(unknown)}")
        out.update(c)
        out["traffic"] = _normalize_traffic(out["traffic"])
        return out
    if isinstance(c, FailoverConfig):
        out["failover"] = c
        return out
    if isinstance(c, CheckpointConfig):
        out["ckpt"] = c
        return out
    if isinstance(c, UpgradeConfig):
        out["upgrade"] = c
        return out
    if isinstance(c, AutoscaleConfig):
        out["scaler"] = c
        return out
    if isinstance(c, tuple):
        if len(c) != 2:
            raise ValueError("tuple config entries must be "
                             "(failover, ckpt) pairs")
        out["failover"], out["ckpt"] = c
        return out
    if isinstance(c, list):        # per-job FailoverConfig sequence
        out["failover"] = c
        return out
    raise ValueError(f"unsupported config entry: {c!r}")


def _merge_bro(sp, bro):
    """Compose config-level brownout ramps into a seed spec by tuple
    concatenation (op-identical to the numpy engines' factor)."""
    if not bro:
        return sp
    if isinstance(sp, (list, tuple)):
        return [_merge_bro(x.spec if isinstance(x, ChaosEngine)
                           else (x or ChaosSpec()), bro) for x in sp]
    return dataclasses.replace(
        sp, brownout_at=tuple(sp.brownout_at) + tuple(bro))


def _spec_has_ramps(sp):
    if isinstance(sp, (list, tuple)):
        return any(
            bool(tuple((x.spec if isinstance(x, ChaosEngine)
                        else (x or ChaosSpec())).brownout_at))
            for x in sp)
    return bool(tuple(sp.brownout_at))


class ConfigGridPlan:
    """Chunk-friendly decomposition of `run_config_batch`.

    `__init__` does every seed-count-independent step ONCE per request:
    config normalization, lowering, per-config traced params, timeline
    path selection (the ckpt-bearing grid path keeps ONE
    `GridTimelineBuilder` whose per-seed draw streams are shared by all
    chunks), and the trace-cache lookup (hit/miss traffic lands in
    `cache_info`). `prep_chunk(lo, hi)` builds the host tensors for the
    seed slice ``[lo, hi)`` — each seed's timelines are built exactly
    once across all chunks, so `timeline_build_count()` matches the
    monolithic call — and `run_chunk` runs one device pass, returning
    the per-config `JaxBatchMetrics` list for that slice. Driven by
    `run_chunks`."""

    def __init__(self, graph: LogicalGraph | PackedArena, configs,
                 seeds, *, duration_s: float,
                 base_spec: ChaosSpec | None = None,
                 mixes=None, n_hosts: int = 8,
                 dt: float = 0.5, queue_cap: float = 256.0,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0, pad_seeds: bool = True,
                 devices: int | str | None = None,
                 phase_mode: str = "auto"):
        specs = _as_specs(seeds, base_spec)
        if not specs:
            raise ValueError(
                "run_config_batch requires at least one seed")
        norm = [normalize_config(c) for c in configs]
        if not norm:
            raise ValueError(
                "run_config_batch requires at least one config")
        self.specs, self.norm = specs, norm
        self.low = low = _Lowered(
            graph, n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
            failover=norm[0]["failover"], ckpt=norm[0]["ckpt"],
            seed=seed, phase_mode=phase_mode,
            seed_width=len(specs) * len(norm))
        _check_pallas_devices(low, devices, "run_config_batch")
        self.n_ticks = n_ticks = int(round(duration_s / low.dt))
        self.n_seeds, self.n_cfg = len(specs), len(norm)
        self._override = task_speed_override
        self.pad_seeds = pad_seeds
        jot = (low.job_of_task if low.job_of_task is not None
               else np.zeros(low.plan.n_tasks, dtype=int))

        # per-config traced params
        pa_rows, fo_vecs = [], []
        for cfg in norm:
            codes, det, rst_s, rst_r, fx = per_task_failover(
                cfg["failover"], low.plan.n_tasks, low.job_of_task)
            lazy = lazy_ready_extra(fx["stagger"], low.task_region,
                                    low.job_of_task)
            fo_vecs.append((codes, det, rst_s, rst_r, fx, lazy))
            # per-config deployment drill (inert leaves when cfg has
            # none) — lowered against the config's OWN failover/ckpt
            drill = lower_upgrade(
                cfg["upgrade"], specs[0], n_tasks=low.plan.n_tasks,
                job_of_task=low.job_of_task,
                task_region=low.task_region,
                dt=low.dt, base_failover=(codes, det, rst_s, rst_r, fx),
                base_ckpt=cfg["ckpt"],
                sel_task=low._sel_task * float(cfg["sel_scale"]))
            # per-config in-trace autoscaler (inert when cfg has none)
            auto = lower_autoscale(
                cfg["scaler"], n_tasks=low.plan.n_tasks, dt=low.dt,
                is_src_task=low.tensor.is_src_task)
            pa_rows.append(low._params(
                low.plan.qcap * float(cfg["qcap_scale"]),
                low._sel * float(cfg["sel_scale"]), det, rst_s, rst_r,
                codes, fx=fx, drill=drill, autoscale=auto))
        pa = dict(pa_rows[0])
        for k in ("qcap", "sel", "detect", "restart_region",
                  "restart_single", "mode_single", "mode_region",
                  "mode_hot", "standby_switch", "standby_stale",
                  "restore_base", "replay_rate",
                  "lazy_extra") + _DRILL_KEYS + AUTOSCALE_KEYS:
            pa[k] = np.stack([row[k] for row in pa_rows])
        self.fo_vecs = fo_vecs
        self.cfg_bros = cfg_bros = [tuple(cfg["brownout"])
                                    for cfg in norm]
        self.cfg_traffics = [cfg["traffic"] for cfg in norm]

        # timelines: shared across configs when nothing checkpoints
        # (kill/straggler draws are failover-independent); rebuilt per
        # config otherwise (storage draws interleave with kill draws).
        # per-job seed specs with restore surcharges AND brownout ramps
        # need per-job brownout factors in the recovery metadata — only
        # the per-(config, seed) rebuild path models that; everything
        # else rides the shared-draws fast paths
        perjob_specs = any(isinstance(sp, (list, tuple)) for sp in specs)
        bf_varies_by_job = perjob_specs and (
            any(cfg_bros)
            or any(_spec_has_ramps(sp) for sp in specs)) and any(
            np.any(v[4]["restore_base"]) for v in fo_vecs)
        self.no_ckpt = no_ckpt = (
            all(cfg["ckpt"] is None for cfg in norm)
            and not bf_varies_by_job)
        self.builder = None
        if no_ckpt:
            self.path = "refit"
        elif all(cfg["ckpt"] is None or isinstance(cfg["ckpt"],
                                                   CheckpointConfig)
                 for cfg in norm) and all(isinstance(sp, ChaosSpec)
                                          for sp in specs):
            # ckpt-bearing grid, single coordinators: ONE chaos draw
            # stream per seed, every config's checkpoint attempt
            # schedule refitted onto it as vectorized offset indexing —
            # zero per-(config, seed) host timeline replays
            # (core.chaos.GridTimelineBuilder; timeline_build_count
            # stays flat, pinned by tests/test_sparse_sweep.py). The
            # builder's lazily-created per-seed streams are shared by
            # every chunk, so a chunked run draws each seed exactly
            # once — bit-identical to the monolithic grid.
            self.path = "grid"
            cfg_rows = []
            for cfg, (codes, det, rst_s, rst_r, fx, lazy), bro in zip(
                    norm, fo_vecs, cfg_bros):
                ck = cfg["ckpt"]
                cfg_rows.append(dict(
                    failover_mode=codes, detect_s=det,
                    region_restart_s=rst_r, single_restart_s=rst_s,
                    standby_switch_s=fx["switch"],
                    standby_staleness_s=fx["stale"],
                    restore_base_s=fx["restore_base"],
                    replay_rate=fx["replay_rate"],
                    lazy_extra_s=lazy, brownout_at=bro,
                    ckpt_interval_s=(ck.interval_s if ck else None),
                    ckpt_mode=(ck.mode if ck else "region"),
                    ckpt_upload_s=(ck.upload_s if ck else 4.0),
                    ckpt_retry=(ck.retry_failed_region if ck else True)))
            self.builder = GridTimelineBuilder(
                specs, cfg_rows, n_ticks=n_ticks, dt=low.dt,
                n_hosts=low.n_hosts, task_host=low.task_host,
                task_region=low.task_region, regions=low.phys.regions,
                job_of_task=low.job_of_task)
        else:
            # exotic rows (per-job coordinator lists / per-job chaos
            # specs): config-specific draw interleavings force
            # per-config rebuilds
            self.path = "exotic"

        if devices is not None and mixes is not None:
            raise ValueError("devices= does not compose with mixes= "
                             "(shard the config grid without a mix "
                             "axis)")
        self.n_shards = local_shard_count(devices)
        self.jobs = low.arena.jobs if low.arena is not None else None
        self.mixes = None
        with scoped_cache_stats() as counts:
            if mixes is None:
                if devices is not None:
                    fn = get_sharded_config_fn(low.desc, self.n_shards,
                                               shared_kills=no_ckpt)
                else:
                    fn = get_cached_config_fn(low.desc,
                                              shared_kills=no_ckpt)
            else:
                mixes = np.atleast_2d(np.asarray(mixes,
                                                 dtype=np.float64))
                if mixes.shape[1] != low.n_jobs:
                    raise ValueError(
                        f"mix rows must have one multiplier per job "
                        f"({mixes.shape[1]} != {low.n_jobs})")
                pa["src_row"] = pa["src_row"][None, :] * mixes[:, jot]
                fn = get_cached_config_mix_fn(low.desc,
                                              shared_kills=no_ckpt)
                self.mixes = mixes
        self.fn = fn
        self.pa = pa
        self.cache_info = dict(counts)

    def prep_chunk(self, lo: int, hi: int):
        low, norm = self.low, self.norm
        specs = self.specs[lo:hi]
        n_ticks, n_cfg = self.n_ticks, self.n_cfg
        if self.path == "refit":
            c0, d0, s0, r0 = self.fo_vecs[0][:4]
            base_tls = [low.timeline(sp, n_ticks, fo_codes=c0,
                                     detect=d0, rst_s=s0, rst_r=r0,
                                     ckpt=None)
                        for sp in specs]
            tls = [[refit_failover(tl, task_host=low.task_host,
                                   task_region=low.task_region,
                                   failover_mode=codes, detect_s=det,
                                   single_restart_s=rst_s,
                                   region_restart_s=rst_r,
                                   job_of_task=low.job_of_task,
                                   standby_switch_s=fx["switch"],
                                   standby_staleness_s=fx["stale"],
                                   restore_base_s=fx["restore_base"],
                                   replay_rate=fx["replay_rate"],
                                   lazy_extra_s=lazy,
                                   spec=(_merge_bro(sp, bro)
                                         if isinstance(sp, ChaosSpec)
                                         else None))
                    for sp, tl in zip(specs, base_tls)]
                   for (codes, det, rst_s, rst_r, fx, lazy), bro
                   in zip(self.fo_vecs, self.cfg_bros)]
            # one (S, T, H) tensor broadcast over the config axis
            kills = np.stack([tl.kills
                              for tl in base_tls]).astype(np.float64)
            ckpt_xs = np.zeros((n_cfg, n_ticks), np.int16)
        elif self.path == "grid":
            tls = self.builder.chunk(lo, hi)
            kills = np.stack([[tl.kills for tl in row]
                              for row in tls]).astype(np.float64)
            ckpt_xs = np.stack([row[0].ckpt_at for row in tls])
        else:
            tls = [[low.timeline(_merge_bro(sp, bro), n_ticks,
                                 fo_codes=codes, detect=det,
                                 rst_s=rst_s, rst_r=rst_r,
                                 extras=fx, lazy=lazy, ckpt=cfg["ckpt"])
                    for sp in specs]
                   for cfg, (codes, det, rst_s, rst_r, fx, lazy), bro
                   in zip(norm, self.fo_vecs, self.cfg_bros)]
            kills = np.stack([[tl.kills for tl in row]
                              for row in tls]).astype(np.float64)
            ckpt_xs = np.stack([row[0].ckpt_at for row in tls])

        states = [low.state0(tl, self._override) for tl in tls[0]]
        batch_state = EngineState(
            *(np.stack([getattr(s, f) for s in states])
              for f in EngineState._fields))
        # external-event tensors: brownout factor and ckpt age ride the
        # config axis (config ramps / per-config success histories),
        # the MQ gate is seed-only and broadcasts across configs
        ev = [[low.event_curves(sp, tls[c][s],
                                cfg_ramps=self.cfg_bros[c],
                                cfg_traffic=self.cfg_traffics[c])
               for s, sp in enumerate(specs)] for c in range(n_cfg)]
        xs = {"t": tls[0][0].ts, "kills": kills, "ckpt": ckpt_xs,
              "bfac": np.stack([[e[0] for e in row] for row in ev]),
              "gate": np.stack([e[1] for e in ev[0]]),
              "ckage": np.stack([[e[2] for e in row] for row in ev]),
              "rfac": np.stack([[e[3] for e in row] for row in ev])}
        batch_state, xs = _pad_batch(
            batch_state, xs, hi - lo, self.pad_seeds, self.n_shards,
            seed_axes={"kills": 0 if self.no_ckpt else 1,
                       "bfac": 1, "gate": 0, "ckage": 1, "rfac": 1})
        return (lo, hi, batch_state, xs, tls)

    def run_chunk(self, prepped):
        lo, hi, batch_state, xs, tls = prepped
        n = hi - lo
        low, mixes = self.low, self.mixes
        with _enable_x64():
            final, ys = self.fn(self.pa, batch_state, xs)
            sl = (slice(None),) * (1 if mixes is None else 2)
            qps = np.asarray(ys["qps"])[sl + (slice(None, n),)]
            backlog = np.asarray(ys["backlog"])[sl + (slice(None, n),)]
            lag = np.asarray(ys["lag"])[sl + (slice(None, n),)]
            emitted = np.asarray(final.emitted)[sl + (slice(None, n),)]
            dropped = np.asarray(final.dropped)[sl + (slice(None, n),)]
            ckpt_ep = np.asarray(
                final.ckpt_epoch)[sl + (slice(None, n),)]
            rb = np.asarray(final.rb_t)[sl + (slice(None, n),)]
            thr = np.asarray(final.thrash_t)[sl + (slice(None, n),)]
            nre = np.asarray(final.nact)[sl + (slice(None, n),)]
            rsc = np.asarray(final.rsec)[sl + (slice(None, n),)]

        def _metrics(c, pre=()):
            ix = pre + (c,)
            return JaxBatchMetrics(low.op_names, tls[0][0].ts,
                                   lag[ix], qps[ix], backlog[ix],
                                   emitted[ix], dropped[ix], tls[c],
                                   ckpt_epoch=ckpt_ep[ix],
                                   jobs=self.jobs,
                                   rollback_t=rb[ix], thrash_t=thr[ix],
                                   n_rescale=nre[ix],
                                   resource_s=rsc[ix])

        if mixes is None:
            return [_metrics(c) for c in range(self.n_cfg)]
        return [[_metrics(c, (m,)) for c in range(self.n_cfg)]
                for m in range(len(mixes))]


def concat_config_batches(parts):
    """Concatenate per-chunk config-grid results (each a per-config
    list, or a mixes × configs nest) along the seed axis — the grid
    analogue of `concat_batches`."""
    if len(parts) == 1:
        return parts[0]
    if parts[0] and isinstance(parts[0][0], list):      # mixes nest
        return [[concat_batches([p[m][c] for p in parts])
                 for c in range(len(parts[0][0]))]
                for m in range(len(parts[0]))]
    return [concat_batches([p[c] for p in parts])
            for c in range(len(parts[0]))]


def run_config_batch(graph: LogicalGraph | PackedArena, configs, seeds, *,
                     duration_s: float,
                     base_spec: ChaosSpec | None = None,
                     mixes=None, n_hosts: int = 8,
                     dt: float = 0.5, queue_cap: float = 256.0,
                     task_speed_override: dict[int, float] | None = None,
                     seed: int = 0, pad_seeds: bool = True,
                     devices: int | str | None = None,
                     phase_mode: str = "auto",
                     seed_chunk: int | None = None,
                     on_chunk=None,
                     timing: dict | None = None):
    """Sweep a ``(C, S)`` grid of resiliency-config × chaos-seed
    scenarios in ONE doubly-vmapped `jit` call — the third vmap axis of
    the engine, over `FailoverConfig`/`CheckpointConfig` grids.

    Every resiliency float is a traced leaf (per-task detect / restart
    budgets / mode masks, queue capacities, selectivities), so the whole
    grid shares one compiled trace per grid *shape*; kill tensors are
    shared across configs whenever no config checkpoints (checkpoint
    storage draws are config-dependent, so ckpt-bearing grids rebuild
    per-config timelines). `configs` entries go through
    `normalize_config` — per-job config lists are supported inside a
    `PackedArena`. With `mixes` (an ``(M, n_jobs)`` source-rate grid) the
    call becomes a triply-vmapped ``(M, C, S)`` cube on the same trace.

    ``seed_chunk`` streams the seed axis through fixed-size chunks on
    the double-buffered `run_chunks` pipeline — one device pass per
    chunk, host timeline prep for chunk k+1 overlapping device compute
    for chunk k, each seed's timelines built exactly once across all
    chunks (`timeline_build_count` matches the monolithic call). The
    concatenated grid is bit-identical to the one-pass grid, so
    chunking is purely a memory-ceiling / time-to-first-result knob.
    ``on_chunk`` fires with each `ChunkResult` as it lands; ``timing``,
    if given a dict, receives the ``prep_s`` / ``device_s`` wall split
    plus per-request trace-cache ``cache_hits`` / ``cache_misses``.

    Returns one `JaxBatchMetrics` per config row — or, with `mixes`, a
    list over mixes of lists over configs.
    """
    plan = ConfigGridPlan(graph, configs, seeds, duration_s=duration_s,
                          base_spec=base_spec, mixes=mixes,
                          n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
                          task_speed_override=task_speed_override,
                          seed=seed, pad_seeds=pad_seeds,
                          devices=devices, phase_mode=phase_mode)
    chunks = run_chunks(plan, seed_chunk, on_chunk)
    if timing is not None:
        _fill_timing(timing, chunks, plan)
    return concat_config_batches([c.batches for c in chunks])
