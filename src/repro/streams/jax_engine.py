"""Batched JAX twin of the vectorized stream engine (`jit`/`scan`/`vmap`).

A functional re-expression of `streams.engine.StreamEngine` for chaos
sweeps: where the numpy engine mutates a flat task arena in place, this
twin threads a single pytree of arena state through a pure
`state -> state` tick lowered from the same `RoutingPlan`
(`streams.engine.build_plan`), runs whole horizons as one
`jax.lax.scan` under `jit`, and `vmap`s the scan over a ``(S,)`` batch
of failure seeds so thousands of chaos scenarios execute in a single
device call.

State-pytree layout (`EngineState`, one leaf per arena variable; under
`vmap` every leaf gains a leading ``(S,)`` seed axis):

    queue      (n_tasks,) f64  bounded input queues (records)
    down_until (n_tasks,) f64  failover downtime horizon per task
    speed      (n_tasks,) f64  static host speed (overrides × stragglers)
    ckpt_epoch ()         i32  checkpoints attempted so far
    emitted    (n_jobs,)  f64  source records emitted, per job segment
    dropped    (n_jobs,)  f64  single_task failover drops, per job segment

Chaos pregeneration semantics (the one intentional delta vs the numpy
engine's *mechanism*, not its numbers): a `jit`-ted scan cannot consume
sequential numpy rng draws, so all chaos is materialized up front by
`core.chaos.build_chaos_timeline` — draw-for-draw in the engine's rng
consumption order — into per-tick event tensors (host-kill masks,
checkpoint flags/outcomes, straggler speeds). Event times are thereby
quantized to tick boundaries, which is exactly the resolution at which
the tick-driven numpy engine observes them, so metrics stay pinned to
`StreamEngine` at 1e-5 over full runs (`tests/test_jax_engine.py`);
checkpoint outcomes and recovery events ride along as host-side
metadata because they never feed back into queue dynamics.

Compiled `run` functions are cached per *plan shape* (op slices, edge
kinds, segment counts, failover mode, per-op job segments — never float
parameters, which are traced), so two engines over same-shaped graphs
share one trace; `get_cached_run_fns` exposes the cache for tests. The
state argument is donated, so each call's arena buffers are reused in
place.

Mega-arena sweeps: a `streams.engine.PackedArena` drops in for the
graph everywhere (`JaxStreamEngine`, `run_batch`, `run_mix_batch`) — K
co-located jobs then scan as one arena with per-job emitted/dropped
segment sums (a static job index per op) and per-job recovery
attribution riding the shared-host chaos timeline. `run_batch` pads the
seed axis to the next power of two (retrace-free batching: one trace
per pow2 bucket, pad rows sliced off before metrics) and can split the
padded batch across local devices (``devices=``) through the
version-gated `repro.dist.sharding` shim — `pmap` on jax 0.4.x,
`jax.shard_map` on >= 0.6. `run_mix_batch` adds a second vmap axis over
job-mix configs (per-job source-rate multipliers): rates are traced,
not baked, so an (M, S) mix × seed grid runs as one device call on the
same trace.

Everything runs in float64 (scoped `jax.experimental.enable_x64`, no
global config flip) to hold parity with the float64 numpy engine.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.chaos import (ChaosEngine, ChaosSpec, ChaosTimeline,
                              build_chaos_timeline)
from repro.dist.sharding import local_shard_count, sharded_seed_fn
from repro.streams.engine import (CheckpointConfig, FailoverConfig,
                                  JobSlice, PackedArena, build_plan)
from repro.streams.graph import LogicalGraph, PhysicalGraph, expand

try:  # scoped x64 — keeps the rest of the process on default f32
    from jax.experimental import enable_x64 as _enable_x64
except ImportError:  # pragma: no cover - old/new jax without the ctx
    import contextlib

    @contextlib.contextmanager
    def _enable_x64():
        jax.config.update("jax_enable_x64", True)
        yield


class EngineState(NamedTuple):
    """All mutable arena state of one scenario (see module docstring).

    ``emitted`` / ``dropped`` are per-job segment totals of shape
    ``(n_jobs,)`` — single-job engines carry ``(1,)`` vectors (same adds,
    same numerics as the former scalars); packed mega-arenas get the
    per-job breakdown for free from a static segment index per op."""
    queue: jax.Array
    down_until: jax.Array
    speed: jax.Array
    ckpt_epoch: jax.Array
    emitted: jax.Array
    dropped: jax.Array


class _OpDesc(NamedTuple):
    lo: int
    hi: int
    is_source: bool


class _EdgeDesc(NamedTuple):
    kind: str
    static: bool
    src_op: int
    src_par: int
    dst_lo: int
    dst_hi: int
    n_blocks: int
    n_groups: int
    any_unblocked: bool


# ----------------------------------------------------------------------
# pure routing (mirrors StreamEngine._route / _accept op-for-op)
# ----------------------------------------------------------------------
def _route(ed: _EdgeDesc, ea: dict, produced, free_down, alive_d):
    kind = ed.kind
    if kind == "forward":
        return produced * alive_d
    if kind in ("rescale", "group_rescale"):
        prod_blk = jax.ops.segment_sum(produced, ea["blk_of_src"],
                                       num_segments=ed.n_blocks)
        alive_blk = jax.ops.segment_sum(alive_d * ea["dst_in_blk"],
                                        ea["blk_idx"],
                                        num_segments=ed.n_blocks)
        has = alive_blk > 0.0
        rate_blk = jnp.where(has, prod_blk / jnp.where(has, alive_blk, 1.0),
                             0.0)
        arriving = rate_blk[ea["blk_idx"]] * alive_d
        if ed.any_unblocked:
            arriving = jnp.where(ea["dst_in_blk"] > 0.0, arriving, 0.0)
        return arriving
    # all-to-all family: identical weight rows → scale a single row
    total = produced.sum()
    if kind == "rebalance":
        val = alive_d
    elif kind == "hash":
        return total * ea["share"]
    elif kind == "weakhash":
        cap = jnp.maximum(free_down, 1e-9) * alive_d
        capsum = jax.ops.segment_sum(cap, ea["grp_of_dst"],
                                     num_segments=ed.n_groups)
        # groups with zero capacity fall back to alive-uniform spread
        # (jit evaluates both branches; numpy branches — values match)
        alive_eps = alive_d + 1e-9
        capsum_fb = jax.ops.segment_sum(alive_eps, ea["grp_of_dst"],
                                        num_segments=ed.n_groups)
        fall = capsum <= 0.0
        cap = jnp.where(fall[ea["grp_of_dst"]], alive_eps, cap) * alive_d
        capsum = jnp.where(fall, capsum_fb, capsum)
        val = cap * ea["mass_of_dst"] / capsum[ea["grp_of_dst"]]
    elif kind == "backlog":
        open_ = (free_down > ea["dst_qcap"] * 0.25).astype(alive_d.dtype)
        val = jnp.maximum(free_down, 1e-9) * alive_d * jnp.maximum(open_,
                                                                   0.05)
    else:
        raise ValueError(kind)
    rs = val.sum()
    return jnp.where(rs > 0.0, val * (total / rs), jnp.zeros_like(val))


def _hol_ratio(arriving, room):
    live = arriving > 1e-9
    return jnp.where(live, room / jnp.maximum(arriving, 1e-300), jnp.inf)


def _accept(ed: _EdgeDesc, ea: dict, arriving, room):
    if ed.static:
        # head-of-line blocking: most congested live channel throttles all
        lam = jnp.minimum(_hol_ratio(arriving, room).min(), 1.0)
        return arriving * lam
    if ed.kind == "group_rescale":
        ratio = _hol_ratio(arriving, room)
        lam_g = jnp.minimum(
            jax.ops.segment_min(ratio, ea["blk_idx"],
                                num_segments=ed.n_blocks), 1.0)
        return arriving * lam_g[ea["blk_idx"]]
    # adaptive routing: channels accept up to their credits
    return jnp.minimum(arriving, room)


# ----------------------------------------------------------------------
# tick/run construction + per-plan-shape trace cache
# ----------------------------------------------------------------------
def _build_run(desc):
    (op_descs, edge_descs, edges_of_op, src_cols, n_tasks, n_hosts,
     n_regions, failover_mode, job_of_op, n_jobs) = desc
    single_task = failover_mode == "single_task"

    def tick(pa, state: EngineState, x):
        t = x["t"]
        q = state.queue
        alive_f = (state.down_until <= t).astype(q.dtype)
        free = jnp.maximum(pa["qcap"] - q, 0.0)
        emitted, dropped = state.emitted, state.dropped
        qps_cols = []
        backlog_zero = jnp.zeros((), q.dtype)

        for oi, od in enumerate(op_descs):
            sl = slice(od.lo, od.hi)
            if od.is_source:
                produced = pa["src_row"][sl] * alive_f[sl]
                # static per-op job index → per-job segment sum for free
                emitted = emitted.at[job_of_op[oi]].add(produced.sum())
                qps_cols.append(backlog_zero)
            else:
                cap = pa["cap_base"][sl] * state.speed[sl] * alive_f[sl]
                take = jnp.minimum(q[sl], cap)
                q = q.at[sl].add(-take)
                produced = take * pa["sel"][oi]
                qps_cols.append(take.sum() / pa["dt"])
            for ei in edges_of_op[oi]:
                ed, ea = edge_descs[ei], pa["edges"][ei]
                dsl = slice(ed.dst_lo, ed.dst_hi)
                arriving = _route(ed, ea, produced, free[dsl], alive_f[dsl])
                if single_task:
                    # records routed to a dead task drop (γ=partial);
                    # edges never cross jobs, so the op's job segment owns
                    # the drop
                    dead = alive_f[dsl] <= 0.0
                    dropped = dropped.at[job_of_op[oi]].add(
                        jnp.where(dead, arriving, 0.0).sum())
                    arriving = jnp.where(dead, 0.0, arriving)
                accepted = _accept(ed, ea, arriving, free[dsl])
                overflow = (arriving - accepted).sum()
                q = q.at[sl].add(overflow / max(ed.src_par, 1))
                q = q.at[dsl].add(accepted)
                free = free.at[dsl].set(
                    jnp.maximum(free[dsl] - accepted, 0.0))

        # pregenerated chaos host kills → failover
        down_until = state.down_until
        if failover_mode != "none":
            vict = x["kills"][pa["task_host"]]
            if failover_mode == "single_task":
                hit = vict > 0.0
                until = t + pa["detect"] + pa["restart_single"]
            else:
                reg_hit = jax.ops.segment_max(vict, pa["task_region"],
                                              num_segments=n_regions)
                hit = reg_hit[pa["task_region"]] > 0.0
                until = t + pa["detect"] + pa["restart_region"]
            down_until = jnp.where(hit, until, down_until)
            q = jnp.where(hit, 0.0, q)

        ckpt_epoch = state.ckpt_epoch + x["ckpt"].astype(jnp.int32)

        backlog_row = jnp.stack([q[od.lo:od.hi].sum() for od in op_descs])
        qps_row = jnp.stack(qps_cols)
        lag = jnp.stack([backlog_row[j] for j in src_cols]).sum()
        new_state = EngineState(q, down_until, state.speed, ckpt_epoch,
                                emitted, dropped)
        return new_state, {"qps": qps_row, "backlog": backlog_row,
                           "lag": lag}

    def run(pa, state, xs):
        return lax.scan(lambda st, x: tick(pa, st, x), state, xs)

    return run


_FN_CACHE: dict = {}
_SHARD_CACHE: dict = {}
_MIX_CACHE: dict = {}

_XS_AXES = {"t": None, "kills": 0, "ckpt": None}

# job-mix vmap axis: only the per-task source emission row varies with a
# job mix (service capacity / selectivity are per-job constants the mix
# leaves alone); everything else is broadcast
_PA_MIX_AXES = {"qcap": None, "src_row": 0, "cap_base": None, "sel": None,
                "dt": None, "task_host": None, "task_region": None,
                "detect": None, "restart_region": None,
                "restart_single": None, "edges": None}


def get_cached_run_fns(desc):
    """(jitted run, jitted vmapped run) for a static plan descriptor.

    One entry — hence one trace per call signature — per plan *shape*;
    float parameters (rates, selectivities, restart times, …) are traced
    arguments, so sweeping them never re-traces. The state argument is
    donated: arena state buffers are consumed in place every call."""
    if desc not in _FN_CACHE:
        run = _build_run(desc)
        _FN_CACHE[desc] = (
            jax.jit(run, donate_argnums=(1,)),
            jax.jit(jax.vmap(run, in_axes=(None, 0, _XS_AXES)),
                    donate_argnums=(1,)))
    return _FN_CACHE[desc]


def get_sharded_run_fn(desc, n_shards: int):
    """Device-sharded batch run fn (flat seed axis, a multiple of
    `n_shards`) — `pmap` on jax 0.4.x, `jax.shard_map` on >= 0.6 via the
    version-gated `repro.dist.sharding` shim. Cached per (plan shape,
    shard count)."""
    key = (desc, n_shards)
    if key not in _SHARD_CACHE:
        _SHARD_CACHE[key] = sharded_seed_fn(
            _build_run(desc), xs_axes=_XS_AXES, n_shards=n_shards)
    return _SHARD_CACHE[key]


def get_cached_mix_fn(desc):
    """Doubly-vmapped run fn: outer axis over job-mix configs (per-task
    source-rate rows), inner axis over chaos seeds — one trace sweeps an
    (M, S) grid of scenario × mix in a single device call."""
    if desc not in _MIX_CACHE:
        run = _build_run(desc)
        _MIX_CACHE[desc] = jax.jit(
            jax.vmap(jax.vmap(run, in_axes=(None, 0, _XS_AXES)),
                     in_axes=(_PA_MIX_AXES, None, None)))
    return _MIX_CACHE[desc]


# ----------------------------------------------------------------------
# lowering: LogicalGraph + configs → static desc + plan arrays
# ----------------------------------------------------------------------
class _Lowered:
    def __init__(self, graph: LogicalGraph | PackedArena, *, n_hosts: int,
                 dt: float,
                 queue_cap: float, failover: FailoverConfig | None,
                 ckpt: CheckpointConfig | None, seed: int):
        self.arena = graph if isinstance(graph, PackedArena) else None
        if self.arena is not None:
            graph = self.arena.graph
            dt, queue_cap = self.arena.dt, self.arena.queue_cap
        self.graph = graph
        self.dt = dt
        self.failover = failover or FailoverConfig()
        self.ckpt_cfg = ckpt
        self.phys: PhysicalGraph = (
            self.arena.phys if self.arena is not None
            else expand(graph, n_hosts=n_hosts, seed=seed))
        self.plan = (self.arena.plan if self.arena is not None
                     else build_plan(graph, dt, queue_cap))
        self.task_host = np.array([tk.host for tk in self.phys.tasks])
        self.task_region = np.array(
            [self.phys.task_region[tk.task_id] for tk in self.phys.tasks])
        self.n_hosts = (self.arena.n_hosts if self.arena is not None
                        else int(self.task_host.max()) + 1)
        self.n_regions = len(self.phys.regions)
        self.n_jobs = self.arena.n_jobs if self.arena is not None else 1
        self.job_of_task = (self.arena.job_of_task
                            if self.arena is not None else None)
        job_of_op = (self.arena.job_of_op if self.arena is not None
                     else np.zeros(len(self.plan.ops), dtype=int))

        plan = self.plan
        n_tasks = plan.n_tasks
        src_row = np.zeros(n_tasks)
        cap_base = np.zeros(n_tasks)
        sel = np.zeros(len(plan.ops))
        op_descs, edge_descs, edge_arrays, edges_of_op = [], [], [], []
        for oi, p in enumerate(plan.ops):
            op_descs.append(_OpDesc(p.lo, p.hi, p.is_source))
            sel[oi] = p.selectivity
            if p.is_source:
                src_row[p.lo:p.hi] = p.src_row
            else:
                cap_base[p.lo:p.hi] = p.service_rate * dt
        for oi, p in enumerate(plan.ops):
            mine = []
            for ep in p.out_edges:
                mine.append(len(edge_descs))
                n_groups = (len(ep.grp_starts)
                            if ep.grp_starts is not None else 0)
                edge_descs.append(_EdgeDesc(
                    ep.kind, ep.static, oi, p.par, ep.dst.lo, ep.dst.hi,
                    ep.n_blocks, n_groups, ep.any_unblocked))
                ea: dict = {}
                if ep.kind == "hash":
                    ea["share"] = ep.share
                elif ep.kind == "weakhash":
                    ea["grp_of_dst"] = ep.grp_of_dst.astype(np.int32)
                    ea["mass_of_dst"] = ep.mass_of_dst
                elif ep.kind == "backlog":
                    ea["dst_qcap"] = np.float64(ep.dst_qcap)
                if ep.kind in ("rescale", "group_rescale"):
                    ea["blk_of_src"] = ep.blk_of_src.astype(np.int32)
                    ea["blk_idx"] = ep.blk_idx.astype(np.int32)
                    ea["dst_in_blk"] = ep.dst_in_blk.astype(np.float64)
                edge_arrays.append(ea)
            edges_of_op.append(tuple(mine))

        fo = self.failover
        self.desc = (tuple(op_descs), tuple(edge_descs),
                     tuple(edges_of_op), tuple(int(j) for j in
                                               plan.src_cols),
                     n_tasks, self.n_hosts, self.n_regions, fo.mode,
                     tuple(int(j) for j in job_of_op), self.n_jobs)
        self.arrays = {
            "qcap": plan.qcap,
            "src_row": src_row,
            "cap_base": cap_base,
            "sel": sel,
            "dt": np.float64(dt),
            "task_host": self.task_host.astype(np.int32),
            "task_region": self.task_region.astype(np.int32),
            "detect": np.float64(fo.detect_s),
            "restart_region": np.float64(fo.region_restart_s),
            "restart_single": np.float64(fo.single_restart_s),
            "edges": edge_arrays,
        }
        self.op_names = [p.name for p in plan.ops]

    # ------------------------------------------------------------------
    def prepare(self, spec: ChaosSpec, n_ticks: int,
                task_speed_override: dict[int, float] | None = None
                ) -> tuple[EngineState, dict, ChaosTimeline]:
        """Pregenerate one seed's chaos timeline → (state0, scan xs)."""
        fo, ck = self.failover, self.ckpt_cfg
        tl = build_chaos_timeline(
            spec, n_ticks=n_ticks, dt=self.dt, n_hosts=self.n_hosts,
            task_host=self.task_host, task_region=self.task_region,
            regions=self.phys.regions, failover_mode=fo.mode,
            detect_s=fo.detect_s, region_restart_s=fo.region_restart_s,
            single_restart_s=fo.single_restart_s,
            ckpt_interval_s=(ck.interval_s if ck else None),
            ckpt_mode=(ck.mode if ck else "region"),
            ckpt_upload_s=(ck.upload_s if ck else 4.0),
            ckpt_retry=(ck.retry_failed_region if ck else True),
            job_of_task=self.job_of_task)
        n_tasks = self.plan.n_tasks
        speed = np.ones(n_tasks)
        if task_speed_override:
            for tid, s in task_speed_override.items():
                speed[tid] = s
        speed *= tl.task_speed
        state = EngineState(
            queue=np.zeros(n_tasks), down_until=np.zeros(n_tasks),
            speed=speed, ckpt_epoch=np.int32(0),
            emitted=np.zeros(self.n_jobs), dropped=np.zeros(self.n_jobs))
        xs = {"t": tl.ts, "kills": tl.kills.astype(np.float64),
              "ckpt": tl.ckpt_at}
        return state, xs, tl


# ----------------------------------------------------------------------
# metrics façades (same read API as streams.engine.EngineMetrics)
# ----------------------------------------------------------------------
class JaxEngineMetrics:
    def __init__(self, op_names, t, lag, qps, backlog, emitted, dropped,
                 timeline: ChaosTimeline, ckpt_epoch: int | None = None):
        self.t = t
        self.source_lag = lag
        self.qps = {n: qps[:, j] for j, n in enumerate(op_names)}
        self.backlog = {n: backlog[:, j] for j, n in enumerate(op_names)}
        # emitted/dropped arrive as (n_jobs,) segment totals
        self.emitted_by_job = np.atleast_1d(np.asarray(emitted, float))
        self.dropped_by_job = np.atleast_1d(np.asarray(dropped, float))
        self.emitted = float(self.emitted_by_job.sum())
        self.dropped = float(self.dropped_by_job.sum())
        self.ckpt_attempts = timeline.ckpt_attempts
        self.ckpt_success = timeline.ckpt_success
        self.ckpt_failed = timeline.ckpt_failed
        # device-side attempt counter (scan state) — must agree with the
        # host-side timeline; pinned in tests/test_jax_engine.py
        self.ckpt_epoch = (timeline.ckpt_attempts if ckpt_epoch is None
                           else int(ckpt_epoch))
        self.recoveries = timeline.recoveries
        self.timeline = timeline


class JaxBatchMetrics:
    """Stacked metrics of a vmapped seed batch; `row(i)` is identical to
    a standalone single-seed run (pinned in tests/test_jax_engine.py)."""

    def __init__(self, op_names, t, lag, qps, backlog, emitted, dropped,
                 timelines, ckpt_epoch=None, jobs=None):
        self.op_names = list(op_names)
        self.t = t                     # (n_ticks,)
        self.source_lag = lag          # (S, n_ticks)
        self.qps = qps                 # (S, n_ticks, n_ops)
        self.backlog = backlog         # (S, n_ticks, n_ops)
        emitted = np.asarray(emitted, float)
        dropped = np.asarray(dropped, float)
        if emitted.ndim == 1:          # legacy (S,) scalar-per-seed form
            emitted, dropped = emitted[:, None], dropped[:, None]
        self.emitted_by_job = emitted  # (S, n_jobs)
        self.dropped_by_job = dropped  # (S, n_jobs)
        self.emitted = emitted.sum(axis=-1)   # (S,)
        self.dropped = dropped.sum(axis=-1)   # (S,)
        self.ckpt_epoch = ckpt_epoch   # (S,) device-side attempt counter
        self.timelines = list(timelines)
        self.jobs = list(jobs) if jobs is not None else None
        self.ckpt_attempts = np.array([tl.ckpt_attempts for tl in timelines])
        self.ckpt_success = np.array([tl.ckpt_success for tl in timelines])
        self.ckpt_failed = np.array([tl.ckpt_failed for tl in timelines])
        self.recoveries = [tl.recoveries for tl in timelines]

    def __len__(self) -> int:
        return len(self.timelines)

    def row(self, i: int) -> JaxEngineMetrics:
        return JaxEngineMetrics(self.op_names, self.t, self.source_lag[i],
                                self.qps[i], self.backlog[i],
                                self.emitted_by_job[i],
                                self.dropped_by_job[i],
                                self.timelines[i],
                                ckpt_epoch=(self.ckpt_epoch[i]
                                            if self.ckpt_epoch is not None
                                            else None))

    def job_view(self, job: JobSlice) -> "JaxBatchMetrics":
        """Per-job slice of a packed-arena batch: the job's metric columns
        under their original (un-namespaced) op names, source lag summed
        over the job's own sources, per-job emitted/dropped segments, and
        recovery events filtered to the job — shaped exactly like a
        single-job batch so `chaos_sweep.summarize` works per job."""
        cols = np.asarray(job.op_cols)
        lag = self.backlog[:, :, np.asarray(job.src_cols)].sum(axis=-1)
        j = job.index
        tls = [dataclasses.replace(
                   tl, recoveries=[r for r in tl.recoveries
                                   if r.get("job", 0) == j])
               for tl in self.timelines]
        return JaxBatchMetrics(
            job.op_names, self.t, lag, self.qps[:, :, cols],
            self.backlog[:, :, cols],
            self.emitted_by_job[:, j:j + 1],
            self.dropped_by_job[:, j:j + 1], tls,
            ckpt_epoch=self.ckpt_epoch)


# ----------------------------------------------------------------------
# public API
# ----------------------------------------------------------------------
class JaxStreamEngine:
    """Drop-in (single-seed) twin of `StreamEngine`: same constructor
    signature, `run(duration_s)` returns `JaxEngineMetrics` with the
    numpy engine's metric names/values (1e-5)."""

    def __init__(self, graph: LogicalGraph | PackedArena, *,
                 n_hosts: int = 8,
                 dt: float = 0.5, queue_cap: float = 256.0,
                 chaos: ChaosEngine | ChaosSpec | None = None,
                 failover: FailoverConfig | None = None,
                 ckpt: CheckpointConfig | None = None,
                 task_speed_override: dict[int, float] | None = None,
                 seed: int = 0):
        if isinstance(chaos, ChaosEngine):
            chaos = chaos.spec
        self.spec = chaos or ChaosSpec()
        self.g = graph.graph if isinstance(graph, PackedArena) else graph
        if isinstance(graph, PackedArena):
            dt = graph.dt
        self.dt = dt
        self._override = task_speed_override
        self._low = _Lowered(graph, n_hosts=n_hosts, dt=dt,
                             queue_cap=queue_cap, failover=failover,
                             ckpt=ckpt, seed=seed)
        self.metrics: JaxEngineMetrics | None = None

    @property
    def lowered(self) -> _Lowered:
        return self._low

    def run(self, duration_s: float) -> JaxEngineMetrics:
        low = self._low
        n_ticks = int(round(duration_s / self.dt))
        state, xs, tl = low.prepare(self.spec, n_ticks, self._override)
        run_fn, _ = get_cached_run_fns(low.desc)
        with _enable_x64():
            final, ys = run_fn(low.arrays, state, xs)
            qps = np.asarray(ys["qps"])
            backlog = np.asarray(ys["backlog"])
            lag = np.asarray(ys["lag"])
            emitted = np.asarray(final.emitted)
            dropped = np.asarray(final.dropped)
            ckpt_epoch = int(final.ckpt_epoch)
        self.metrics = JaxEngineMetrics(low.op_names, tl.ts, lag, qps,
                                        backlog, emitted, dropped, tl,
                                        ckpt_epoch=ckpt_epoch)
        return self.metrics


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def _pad_rows(a: np.ndarray, target: int) -> np.ndarray:
    """Pad the leading axis to `target` by replicating row 0 (pad rows
    simulate a real scenario, so no NaNs/branches — they are sliced off
    before any aggregate sees them)."""
    if len(a) == target:
        return a
    reps = np.broadcast_to(a[:1], (target - len(a),) + a.shape[1:])
    return np.concatenate([a, reps])


def _pad_batch(batch_state: EngineState, xs: dict, n_seeds: int,
               pad_seeds: bool, n_shards: int = 1):
    """Pad the seed axis to the next power of two (and to a multiple of
    the shard count) — the retrace-free batching contract shared by
    `run_batch` and `run_mix_batch`."""
    target = _next_pow2(n_seeds) if pad_seeds else n_seeds
    if target % n_shards:
        target = n_shards * -(-target // n_shards)
    if target != n_seeds:
        batch_state = EngineState(*(_pad_rows(getattr(batch_state, f),
                                              target)
                                    for f in EngineState._fields))
        xs = dict(xs, kills=_pad_rows(xs["kills"], target))
    return batch_state, xs


def _prep_batch(low: "_Lowered", specs, n_ticks: int, task_speed_override):
    prepped = [low.prepare(spec, n_ticks, task_speed_override)
               for spec in specs]
    states = [p[0] for p in prepped]
    tls = [p[2] for p in prepped]
    batch_state = EngineState(*(np.stack([getattr(s, f) for s in states])
                                for f in EngineState._fields))
    xs = {"t": prepped[0][1]["t"],                 # identical across seeds
          "kills": np.stack([p[1]["kills"] for p in prepped]),
          "ckpt": prepped[0][1]["ckpt"]}           # static schedule
    return batch_state, xs, tls


def run_batch(graph: LogicalGraph | PackedArena, seeds, *,
              duration_s: float,
              base_spec: ChaosSpec | None = None, n_hosts: int = 8,
              dt: float = 0.5, queue_cap: float = 256.0,
              failover: FailoverConfig | None = None,
              ckpt: CheckpointConfig | None = None,
              task_speed_override: dict[int, float] | None = None,
              seed: int = 0, pad_seeds: bool = True,
              devices: int | str | None = None) -> JaxBatchMetrics:
    """Run a ``(S,)`` batch of chaos scenarios as ONE vmapped `jit` call
    (one call *per device shard* when `devices` is set).

    `seeds` is a sequence of ints (merged into `base_spec` via
    ``dataclasses.replace(spec, seed=s)``) or of full `ChaosSpec`s.
    `graph` may be a `PackedArena` — the whole co-located fleet then
    simulates in the same device call with per-job metric segments.

    Retrace-free batching: with ``pad_seeds=True`` the seed axis is
    padded to the next power of two (and to a multiple of the shard
    count) by replicating scenario 0, so varying S reuses one jit trace
    per pow2 bucket instead of recompiling per batch size; pad rows are
    sliced off before the metrics object is built, so no aggregate ever
    sees them. ``devices`` splits the padded batch across local devices
    through the version-gated `repro.dist.sharding` shim (``"auto"`` =
    all local devices).
    """
    specs = [dataclasses.replace(base_spec or ChaosSpec(), seed=int(s))
             if isinstance(s, (int, np.integer)) else s for s in seeds]
    if not specs:
        raise ValueError("run_batch requires at least one seed/spec")
    low = _Lowered(graph, n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
                   failover=failover, ckpt=ckpt, seed=seed)
    n_ticks = int(round(duration_s / low.dt))
    batch_state, xs, tls = _prep_batch(low, specs, n_ticks,
                                       task_speed_override)
    n_seeds = len(specs)
    n_shards = local_shard_count(devices)
    batch_state, xs = _pad_batch(batch_state, xs, n_seeds, pad_seeds,
                                 n_shards)
    if devices is not None:
        batch_fn = get_sharded_run_fn(low.desc, n_shards)
    else:
        _, batch_fn = get_cached_run_fns(low.desc)
    with _enable_x64():
        final, ys = batch_fn(low.arrays, batch_state, xs)
        qps = np.asarray(ys["qps"])[:n_seeds]
        backlog = np.asarray(ys["backlog"])[:n_seeds]
        lag = np.asarray(ys["lag"])[:n_seeds]
        emitted = np.asarray(final.emitted)[:n_seeds]
        dropped = np.asarray(final.dropped)[:n_seeds]
        ckpt_epoch = np.asarray(final.ckpt_epoch)[:n_seeds]
    return JaxBatchMetrics(low.op_names, tls[0].ts, lag, qps, backlog,
                           emitted, dropped, tls, ckpt_epoch=ckpt_epoch,
                           jobs=(low.arena.jobs if low.arena is not None
                                 else None))


def run_mix_batch(graph: LogicalGraph | PackedArena, mixes, seeds, *,
                  duration_s: float,
                  base_spec: ChaosSpec | None = None, n_hosts: int = 8,
                  dt: float = 0.5, queue_cap: float = 256.0,
                  failover: FailoverConfig | None = None,
                  ckpt: CheckpointConfig | None = None,
                  task_speed_override: dict[int, float] | None = None,
                  seed: int = 0,
                  pad_seeds: bool = True) -> list[JaxBatchMetrics]:
    """Sweep an ``(M, S)`` grid of job-mix × chaos-seed scenarios in ONE
    doubly-vmapped `jit` call (the second vmap axis over job-mix configs).

    `mixes` is an ``(M, n_jobs)`` array of per-job source-rate
    multipliers (n_jobs = 1 for a plain graph): row m scales every job
    j's source emission by ``mixes[m, j]``. Rates are traced, not baked,
    so the whole grid shares one trace with the plan shape; chaos
    timelines are rate-independent and shared across mixes. Returns one
    `JaxBatchMetrics` per mix row.
    """
    specs = [dataclasses.replace(base_spec or ChaosSpec(), seed=int(s))
             if isinstance(s, (int, np.integer)) else s for s in seeds]
    if not specs:
        raise ValueError("run_mix_batch requires at least one seed/spec")
    low = _Lowered(graph, n_hosts=n_hosts, dt=dt, queue_cap=queue_cap,
                   failover=failover, ckpt=ckpt, seed=seed)
    mixes = np.atleast_2d(np.asarray(mixes, dtype=np.float64))
    if mixes.shape[1] != low.n_jobs:
        raise ValueError(
            f"mix rows must have one multiplier per job "
            f"({mixes.shape[1]} != {low.n_jobs})")
    n_ticks = int(round(duration_s / low.dt))
    batch_state, xs, tls = _prep_batch(low, specs, n_ticks,
                                       task_speed_override)
    n_seeds = len(specs)
    batch_state, xs = _pad_batch(batch_state, xs, n_seeds, pad_seeds)
    job_of_task = (low.job_of_task if low.job_of_task is not None
                   else np.zeros(low.plan.n_tasks, dtype=int))
    src_rows = low.arrays["src_row"][None, :] * mixes[:, job_of_task]
    pa = dict(low.arrays, src_row=src_rows)
    mix_fn = get_cached_mix_fn(low.desc)
    with _enable_x64():
        final, ys = mix_fn(pa, batch_state, xs)
        qps = np.asarray(ys["qps"])[:, :n_seeds]
        backlog = np.asarray(ys["backlog"])[:, :n_seeds]
        lag = np.asarray(ys["lag"])[:, :n_seeds]
        emitted = np.asarray(final.emitted)[:, :n_seeds]
        dropped = np.asarray(final.dropped)[:, :n_seeds]
        ckpt_epoch = np.asarray(final.ckpt_epoch)[:, :n_seeds]
    jobs = low.arena.jobs if low.arena is not None else None
    return [JaxBatchMetrics(low.op_names, tls[0].ts, lag[m], qps[m],
                            backlog[m], emitted[m], dropped[m], tls,
                            ckpt_epoch=ckpt_epoch[m], jobs=jobs)
            for m in range(len(mixes))]
